"""Pod lane: the 3-level tile cache (host DRAM -> HBM -> ICI neighbor)
on mesh_shard devices, staged vs unstaged (beyond-HBM regime).

Each shape in ``SHAPES`` is a deep-k DGEMM whose per-task working set
exceeds one device's modeled HBM but whose *unique* working set fits
the pod's aggregate HBM — the regime the pod tier exists for.  The
shape is scheduled twice on the virtual-clock event engine with
``device_class="mesh_shard"``: once with panel staging
(``plan_panel_staged`` splits each beyond-HBM task into HBM-sized
panel partials + a streaming ring-reduce fix-up), once with
``stage_panels=False`` (every fetch bypasses straight to host DRAM).
Reported per shape:

* ``makespan_staged`` / ``makespan_unstaged`` and their ratio — what
  the third cache level is worth end to end;
* ``staged_le_unstaged`` — the structural invariant
  ``benchmarks/compare.py`` gates: staging through the cache must not
  lose to the bypass baseline in this regime;
* ``ici_time_consistent`` — the ledger decomposition invariant: on
  every device, ICI lane busy seconds == ``ici_bytes / ici_bw``
  exactly (every ICI transfer is charged at exactly the link rate);
* ``ici_gb`` — modeled ICI traffic (ring scatter hops + neighbor-tier
  L2 serves), the pod analogue of Table V's communication volume.

The ``pod/parity`` row runs a small *executing* beyond-HBM DGEMM both
ways and as a flat accelerator run: all three must agree bitwise
(``pod_bitwise_equal`` — the tier reshapes schedules and clocks, never
numerics).

All metrics are virtual-clock derived: deterministic, identical on
every host, so the gate holds them tightly.

``python -m benchmarks.pod --trace trace_pod_pr.json`` additionally
runs an executing beyond-HBM mesh_shard DGEMM through a
``BlasxContext``, exports its Chrome trace, checks ICI-lane spans are
present and account for every ledgered ICI byte, and validates the
trace against the event-engine schema — the CI bench-smoke artifact.
"""
from __future__ import annotations

from typing import Dict, List

MESH_DEVICES = 4     # ring size of one mesh_shard scheduler device
N_STREAMS = 2        # deep-k regime: fewer, longer pipelines win
TILE = 1024
CACHE_TILES = 24     # modeled HBM: 24 f64 tiles = 192 MiB per device

# (n, k, n_devices): deep-k beyond-HBM DGEMMs.  quick keeps CI to one
# shape; full sweeps the measured win-regime corners.
QUICK_SHAPES = ((2048, 16384, 4),)
FULL_SHAPES = ((2048, 16384, 4), (2048, 32768, 8), (4096, 16384, 8))

# executing parity check: small enough to run numerics on 1 core, yet
# beyond the shrunken HBM below (8 tiles of 64x64 f64)
PARITY_N, PARITY_TILE = 512, 64
PARITY_CACHE = 8 * PARITY_TILE * PARITY_TILE * 8


def _shadow(n: int, k: int, n_devices: int, staged: bool):
    from repro.core import task as taskmod
    from repro.core.runtime import BlasxRuntime, RuntimeConfig
    from repro.core.tiling import ShadowMatrix

    rt = BlasxRuntime(RuntimeConfig(
        n_devices=n_devices, n_streams=N_STREAMS, mode="sim",
        execute=False, record_trace=False,
        device_class="mesh_shard", mesh_devices=MESH_DEVICES,
        cache_bytes=CACHE_TILES * TILE * TILE * 8,
        stage_panels=staged))
    mats = {"A": ShadowMatrix("A", n, k, TILE),
            "B": ShadowMatrix("B", k, n, TILE),
            "C": ShadowMatrix("C", n, n, TILE)}
    tasks = taskmod.taskize_gemm(mats["A"].grid, mats["B"].grid,
                                 mats["C"].grid, "N", "N", 1.0, 0.0)
    rt.run(tasks, mats, "C")
    return rt


def _ici_consistent(rt) -> bool:
    """ici_busy_s == ici_bytes / ici_bw on every device (exact up to
    float summation order)."""
    bw = rt.cfg.ici_bw
    return all(abs(d.ledger.ici_busy_s - d.ledger.ici_bytes / bw)
               <= 1e-9 * max(1.0, d.ledger.ici_busy_s)
               for d in rt.devices)


def _parity_row() -> Dict:
    import numpy as np

    from repro.core import blas3
    from repro.core.runtime import RuntimeConfig

    rng = np.random.default_rng(0)
    A = rng.standard_normal((PARITY_N, PARITY_N))
    B = rng.standard_normal((PARITY_N, PARITY_N))
    pod_kw = dict(n_devices=2, mode="sim", cache_bytes=PARITY_CACHE,
                  device_class="mesh_shard", mesh_devices=MESH_DEVICES)
    base = blas3.gemm(A, B, tile=PARITY_TILE, config=RuntimeConfig(
        n_devices=2, mode="sim", cache_bytes=PARITY_CACHE))
    staged = blas3.gemm(A, B, tile=PARITY_TILE,
                        config=RuntimeConfig(**pod_kw))
    unstaged = blas3.gemm(A, B, tile=PARITY_TILE, config=RuntimeConfig(
        stage_panels=False, **pod_kw))
    equal = int(np.array_equal(staged, unstaged)
                and np.array_equal(staged, base)
                and np.allclose(staged, A @ B))
    return {"name": "pod/parity", "us_per_call": "",
            "n": PARITY_N, "tile": PARITY_TILE,
            "pod_bitwise_equal": equal}


def run(quick: bool = True) -> List[Dict]:
    shapes = QUICK_SHAPES if quick else FULL_SHAPES
    rows: List[Dict] = []
    le_flags: List[int] = []
    ici_flags: List[int] = []
    for n, k, n_devices in shapes:
        on = _shadow(n, k, n_devices, staged=True)
        off = _shadow(n, k, n_devices, staged=False)
        le = int(on.makespan() <= off.makespan() * (1 + 1e-9))
        ici_ok = int(_ici_consistent(on) and _ici_consistent(off))
        le_flags.append(le)
        ici_flags.append(ici_ok)
        rows.append({
            "name": f"pod/staged_{n}x{k}x{n_devices}d",
            "us_per_call": "",
            "tile": TILE, "mesh_devices": MESH_DEVICES,
            "makespan_staged": f"{on.makespan():.4f}",
            "makespan_unstaged": f"{off.makespan():.4f}",
            "staged_speedup": f"{off.makespan() / on.makespan():.3f}",
            "ici_gb": f"{on.total_comm_bytes()['ici'] / 1e9:.3f}",
            "staged_le_unstaged": le,
            "ici_time_consistent": ici_ok,
        })
    parity = _parity_row()
    rows.append(parity)
    rows.append({
        "name": "pod/summary",
        "us_per_call": "",
        "staged_le_unstaged_all": int(all(le_flags)),
        "ici_time_consistent_all": int(all(ici_flags)),
        "pod_bitwise_equal": parity["pod_bitwise_equal"],
    })
    return rows


def export_trace_pod(path: str) -> dict:
    """CI artifact: an *executing* beyond-HBM mesh_shard DGEMM traced
    end to end.  Beyond the event-engine schema gate this validates the
    pod tier itself: ICI-lane spans are present and their bytes equal
    the ledgered ICI total, and the lane-time decomposition
    ``ici_busy_s == ici_bytes / ici_bw`` holds on every device."""
    import numpy as np

    from repro.api import BlasxContext
    from repro.core.events import validate_trace
    from repro.core.runtime import RuntimeConfig

    rng = np.random.default_rng(0)
    A = rng.standard_normal((PARITY_N, PARITY_N))
    B = rng.standard_normal((PARITY_N, PARITY_N))
    with BlasxContext(RuntimeConfig(
            n_devices=2, mode="sim", cache_bytes=PARITY_CACHE,
            device_class="mesh_shard", mesh_devices=MESH_DEVICES),
            tile=PARITY_TILE) as ctx:
        out = ctx.gemm(A, B)
        np.testing.assert_allclose(out.array(), A @ B, rtol=1e-10,
                                   atol=1e-10)
        rt = ctx.runtime
        if not _ici_consistent(rt):
            raise ValueError("ici_busy_s != ici_bytes/ici_bw")
        ledgered = rt.total_comm_bytes()["ici"]
        tr = ctx.trace(path)
    summary = validate_trace(tr)
    traced = sum((ev.get("args") or {}).get("nbytes", 0)
                 for ev in tr["traceEvents"]
                 if ev.get("ph") == "B" and ev.get("cat") == "ici")
    if ledgered == 0 or traced != ledgered:
        raise ValueError(
            f"ICI bytes mismatch: {traced} on trace spans vs "
            f"{ledgered} ledgered")
    print(f"# pod trace: {summary['spans']} spans, "
          f"{ledgered} ICI bytes on-lane -> {path}")
    return tr


def main(argv=None) -> int:
    import argparse

    from .common import rows_to_csv

    ap = argparse.ArgumentParser(
        prog="benchmarks.pod",
        description="pod tier lane + Chrome-trace artifact")
    ap.add_argument("--trace", metavar="PATH",
                    help="export + validate the executing beyond-HBM "
                         "mesh_shard DGEMM trace INSTEAD of running the "
                         "lane (the CI artifact step)")
    ap.add_argument("--validate", metavar="PATH",
                    help="round-trip an exported trace file through the "
                         "schema validator and exit non-zero on "
                         "violations (the CI gate step)")
    args = ap.parse_args(argv)
    if not args.trace and not args.validate:
        print(rows_to_csv(run()))
    if args.trace:
        export_trace_pod(args.trace)
    if args.validate:
        from repro.core.events import main as validate_main
        return validate_main([args.validate])
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
