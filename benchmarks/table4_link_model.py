"""Paper Table IV: DMA-engine throughputs (the link model constants) and
the ledger-level consequence: effective bytes/s each transfer class
achieved inside a BLASX run (d2d faster than h2d by ~19%)."""
from __future__ import annotations

import numpy as np

from repro.core import blas3
from repro.core.runtime import BlasxRuntime, RuntimeConfig, D2D_BW, H2D_BW


def run():
    rng = np.random.default_rng(0)
    rt = BlasxRuntime(RuntimeConfig(n_devices=3, policy="blasx",
                                    p2p_groups=[[0, 1, 2]],
                                    cache_bytes=48 << 20, mode="sim",
                                    record_trace=False))
    n = 2048
    A = rng.standard_normal((n, n))
    B = rng.standard_normal((n, n))
    blas3.gemm(A, B, tile=256, runtime=rt)
    comm = rt.total_comm_bytes()
    return [{
        "name": "table4/link_model",
        "us_per_call": "",
        "h2d_GBps": f"{H2D_BW/1e9:.2f}",
        "d2d_GBps": f"{D2D_BW/1e9:.2f}",
        "d2d_advantage": f"{(D2D_BW/H2D_BW - 1):.1%}",
        "run_h2d_MB": f"{comm['h2d']/1e6:.0f}",
        "run_d2d_MB": f"{comm['d2d']/1e6:.0f}",
        "run_d2h_MB": f"{comm['d2h']/1e6:.0f}",
    }]
