"""Paper Fig. 7 + Table III: throughput vs matrix size at 1/2/3 devices
and average parallel efficiency per policy.

Run at the PAPER's scale (tile 1024, N up to 24K, f64) via metadata-only
execution: the virtual-clock engine models K40c compute + Table IV
links with a shared host PCI-E root (the resource cuBLAS-XT's on-demand
traffic saturates).  Headline targets: BLASX near-linear speedup
(paper: 2.91x at 3 GPUs, 93.5% avg efficiency), cuBLAS-XT PCI-E-bound."""
from __future__ import annotations

import numpy as np

from repro.core.blas3 import shadow_run
from repro.core.runtime import BlasxRuntime, RuntimeConfig

SIZES = [8192, 16384, 24576]
TILE = 1024
CACHE = 4 << 30   # 4 GB tile cache per device (12 GB K40 minus workspace)


def _gemm_gflops(n, n_devices, policy):
    rt = BlasxRuntime(RuntimeConfig(n_devices=n_devices, policy=policy,
                                    cache_bytes=CACHE, mode="sim",
                                    execute=False, record_trace=False))
    shadow_run("gemm", n, tile=TILE, runtime=rt, beta=1.0)
    return 2.0 * n ** 3 / rt.makespan() / 1e9


def run():
    rows = []
    eff_acc = {}
    for n in SIZES:
        base = {p: _gemm_gflops(n, 1, p) for p in
                ("blasx", "cublasxt", "supermatrix")}
        for p, g in base.items():
            rows.append({"name": f"fig7/dgemm/N{n}/{p}/x1",
                         "us_per_call": "", "gflops": f"{g:.0f}"})
        for nd in (2, 3):
            for policy in ("blasx", "cublasxt", "supermatrix"):
                g = _gemm_gflops(n, nd, policy)
                speedup = g / base[policy]
                eff = speedup / nd
                eff_acc.setdefault((policy, nd), []).append(eff)
                rows.append({
                    "name": f"fig7/dgemm/N{n}/{policy}/x{nd}",
                    "us_per_call": "",
                    "gflops": f"{g:.0f}",
                    "speedup": f"{speedup:.2f}",
                    "efficiency": f"{eff:.2%}",
                })
    for (policy, nd), effs in sorted(eff_acc.items()):
        rows.append({
            "name": f"table3/dgemm/{policy}/x{nd}",
            "us_per_call": "",
            "avg_parallel_efficiency": f"{float(np.mean(effs)):.2%}",
        })
    return rows
