"""Paper Fig. 10: the only tuning parameter — tile size, at the paper's
two sizes (N=8192, 16384).

Small tiles under-saturate device + link (low arithmetic intensity:
2T^3 flops vs 3T^2 bytes moved); big tiles starve parallelism (Eq. 2).
Paper picks T=1024 on Everest; the modeled curve should rise and
plateau around the same point."""
from __future__ import annotations

from repro.core.blas3 import shadow_run
from repro.core.runtime import BlasxRuntime, RuntimeConfig
from repro.core.tiling import degree_of_parallelism

TILES = [256, 512, 1024, 2048, 4096]
SIZES = [8192, 16384]


def run():
    rows = []
    for n in SIZES:
        best = (None, 0.0)
        for t in TILES:
            rt = BlasxRuntime(RuntimeConfig(n_devices=3, policy="blasx",
                                            cache_bytes=4 << 30, mode="sim",
                                            execute=False,
                                            record_trace=False))
            shadow_run("gemm", n, tile=t, runtime=rt)
            g = 2.0 * n ** 3 / rt.makespan() / 1e9
            if g > best[1]:
                best = (t, g)
            rows.append({
                "name": f"fig10/dgemm/N{n}/T{t}",
                "us_per_call": "",
                "modeled_gflops": f"{g:.0f}",
                "degree_of_parallelism": degree_of_parallelism(n, n, t),
            })
        rows.append({
            "name": f"fig10/dgemm/N{n}/best",
            "us_per_call": "",
            "best_tile": best[0],
            "paper_choice": 1024,
        })
    return rows
