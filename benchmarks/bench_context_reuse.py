"""Warm-context vs per-call H2D traffic (the api_redesign headline).

Two chained workloads run twice each — once as isolated per-call
invocations (every call builds and discards its runtime: the seed
API's behaviour) and once through a single persistent ``BlasxContext``
whose ALRU/MESI-X tile caches stay warm:

* ``serve``  — an LM-projection shape: R requests of ``x @ W`` against
  one shared weight handle (the batched-serving pattern);
* ``sweep``  — a Cholesky-style ``syrk -> trsm -> gemm`` chain reusing
  one operand handle across all three routines.

The context must move strictly fewer H2D bytes; the ledger deltas per
call come from ``ctx.calls``.  Asserted in
``tests/test_api.py::test_chained_beats_per_call_api_multi_device``.

Run:  PYTHONPATH=src python -m benchmarks.bench_context_reuse
"""
from __future__ import annotations

import numpy as np

from repro.api import BlasxContext
from repro.core.runtime import RuntimeConfig

N = 1024
TILE = 128
REQUESTS = 6
TOPOLOGY = dict(n_devices=3, p2p_groups=[[0], [1, 2]],
                cache_bytes=256 << 20, mode="sim")


def _ctx() -> BlasxContext:
    return BlasxContext(RuntimeConfig(policy="blasx", **TOPOLOGY), tile=TILE)


def _serve_bytes(persistent: bool, rng) -> int:
    """R gemm calls sharing one weight matrix."""
    W = rng.standard_normal((N, N))
    xs = [rng.standard_normal((N // 4, N)) for _ in range(REQUESTS)]
    if persistent:
        with _ctx() as ctx:
            Wh = ctx.tile(W)
            for x in xs:
                ctx.gemm(ctx.tile(x), Wh)
            return sum(c.h2d_bytes for c in ctx.calls)
    total = 0
    for x in xs:
        with _ctx() as ctx:               # cold context per call
            ctx.gemm(x, W)
            total += sum(c.h2d_bytes for c in ctx.calls)
    return total


def _sweep_bytes(persistent: bool, rng) -> int:
    """syrk -> trsm -> gemm all touching the same A."""
    A = rng.standard_normal((N, N // 2))
    L = rng.standard_normal((N, N)) / N + np.eye(N)

    def chain(ctx):
        Ah = ctx.tile(A)
        ctx.syrk(Ah, uplo="U")
        X = ctx.trsm(ctx.tile(L), Ah, uplo="L")
        ctx.gemm(X, Ah, transb="T")

    if persistent:
        with _ctx() as ctx:
            chain(ctx)
            return sum(c.h2d_bytes for c in ctx.calls)
    total = 0
    with _ctx() as c1:
        c1.syrk(A, uplo="U")
        total += sum(c.h2d_bytes for c in c1.calls)
    with _ctx() as c2:
        X = c2.trsm(L, A, uplo="L")
        total += sum(c.h2d_bytes for c in c2.calls)
    with _ctx() as c3:
        c3.gemm(X.array(), A, transb="T")
        total += sum(c.h2d_bytes for c in c3.calls)
    return total


def run():
    rows = []
    for name, fn in (("serve", _serve_bytes), ("sweep", _sweep_bytes)):
        cold = fn(False, np.random.default_rng(0))
        warm = fn(True, np.random.default_rng(0))
        assert warm < cold, f"{name}: warm {warm} !< cold {cold}"
        rows.append({
            "name": f"context_reuse/{name}/N{N}",
            "us_per_call": "",
            "cold_h2d_MB": f"{cold/1e6:.1f}",
            "warm_h2d_MB": f"{warm/1e6:.1f}",
            "saved": f"{1 - warm/cold:.1%}",
        })
    return rows


def main() -> None:
    print("workload   cold H2D     warm H2D    saved")
    for r in run():
        print(f"{r['name']:28s} {r['cold_h2d_MB']:>8s}MB "
              f"{r['warm_h2d_MB']:>8s}MB   {r['saved']}")


if __name__ == "__main__":
    main()
