"""Paper Fig. 8: per-device COMPT/COMM/OTHER decomposition at N=16384
and the finish-time gap between fastest and slowest device.

Paper numbers (3x K40 Everest): fastest-slowest gap 0.039 s for BLASX
vs 0.296 s for cuBLAS-XT and 0.784 s for MAGMA's static split.  Here
the same measurement runs on the virtual-clock engine with
heterogeneous realtime speeds under a speed-blind static planner."""
from __future__ import annotations

from repro.core.blas3 import shadow_run
from repro.core.runtime import BlasxRuntime, RuntimeConfig

N = 16384
TILE = 1024
SPEEDS = [1.0, 0.8, 1.3]     # realtime (saturation-dependent)
NOMINAL = [1.0, 1.0, 1.0]    # what static planners believe


def _run(policy):
    rt = BlasxRuntime(RuntimeConfig(
        n_devices=3, policy=policy, speeds=SPEEDS, nominal_speeds=NOMINAL,
        cache_bytes=4 << 30, mode="sim", execute=False,
        record_trace=False))
    shadow_run("gemm", N, tile=TILE, runtime=rt)
    return rt


def run():
    rows = []
    gaps = {}
    for policy in ("blasx", "parsec", "static", "cublasxt"):
        rt = _run(policy)
        clocks = [d.clock for d in rt.devices]
        gaps[policy] = max(clocks) - min(clocks)
        for d in rt.devices:
            led = d.ledger
            rows.append({
                "name": f"fig8/{policy}/device{d.id}",
                "us_per_call": "",
                "compt_s": f"{led.compute_time:.3f}",
                "comm_unoverlapped_s": f"{led.unoverlapped_comm:.3f}",
                "finish_s": f"{d.clock:.3f}",
                "tasks": led.tasks,
            })
        rows.append({
            "name": f"fig8/{policy}/gap",
            "us_per_call": "",
            "fastest_slowest_gap_s": f"{gaps[policy]:.4f}",
        })
    rows.append({
        "name": "fig8/summary",
        "us_per_call": "",
        "static_gap_over_blasx":
            f"{gaps['static']/max(1e-12, gaps['blasx']):.1f}x",
        "cublasxt_gap_over_blasx":
            f"{gaps['cublasxt']/max(1e-12, gaps['blasx']):.1f}x",
        "paper_reported": "7.6x (0.296 vs 0.039)",
    })
    return rows
