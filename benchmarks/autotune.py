"""Autotune lane: the Fig. 10 tile sweep, closed-loop (tuned vs default).

``benchmarks/fig10_tile_size.py`` reproduces the paper's open-loop
curve — makespan as a function of tile size.  This lane runs the
:mod:`repro.tuning` autotuner over the same space and reports, per
routine x {float64, float32}:

* ``tuned_makespan``   — the virtual-clock makespan of the autotuned
  ``(tile, n_streams, policy)`` config;
* ``default_makespan`` — the fixed-default config (T=256, the base
  config's streams/policy) on the same shapes;
* ``tuned_le_default`` — the structural invariant gated by
  ``compare.py``: the tuned pick can never be worse than the default
  (the default is always candidate zero of the sweep);
* ``swept``            — how many shadow runs the search cost.

A second tuner over the same cache then re-tunes every key and the
summary row records ``second_pass_sweeps`` — **zero** means every later
context starts warm (the cache-hit acceptance criterion, also gated).

A second sub-lane (``autotune/longtail``) exercises the learned cost
model on long-tailed shape traffic: a small training distribution is
swept into a fresh cache, then an ``auto``-mode tuner resolves 21
fresh, disjoint shape buckets.  The row records the measured
shadow-run count against the *analytic* count a ``sweep``-mode tuner
would have paid on the same distribution (``len(candidates)`` per
bucket — exact, since a sweep measures every candidate once), and two
gated flags: ``sweep_reduction_ge_5x`` (auto pays >= 5x fewer shadow
runs) and ``tuned_le_default_all`` (every adopted config still
measured tuned <= default — model adoptions are confirmation-verified,
fallback sweeps hold by construction).  See ``docs/TUNING.md``.

All metrics are virtual-clock deterministic: identical on every host,
so ``compare.py`` gates them tightly against ``baseline.json``.

When ``BLASX_TUNING_CACHE`` is set (the CI bench-smoke job points it
at ``TUNING_pr.json``), the tuning cache persists there and is
uploaded as an artifact alongside ``BENCH_pr.json``.  The longtail
sub-lane always uses a private memory-only cache (``TuningCache("")``)
— its training-set contents must be identical under CI and locally.
"""
from __future__ import annotations

from typing import Dict, List

QUICK_N, FULL_N = 2048, 8192
QUICK_TILES = (256, 512, 1024)
FULL_TILES = (256, 512, 1024, 2048)
STREAMS = (2, 4)
POLICIES = ("blasx", "static")
DTYPES = ("float64", "float32")

# longtail sub-lane candidate space: small tiles (the fresh shapes dip
# to 256-buckets) and a wider stream axis, so a full sweep costs 18
# shadow runs per bucket — the cost structure the model collapses to
# at most 2 confirmation runs
LT_TILES = (128, 256, 512)
LT_STREAMS = (2, 4, 8)
LT_POLICIES = ("blasx", "static")
# training distribution: cube shapes plus a few aspect-skewed ones
# (cubes alone leave the model extrapolating on every skewed fresh
# shape), swept; all buckets disjoint from LT_FRESH
LT_TRAIN = tuple((r, (s, s, s)) for r in ("gemm", "syrk")
                 for s in (250, 500, 1000, 2000)) + (
    ("gemm", (250, 250, 500)), ("gemm", (500, 250, 250)),
    ("gemm", (1000, 500, 1000)), ("gemm", (500, 1000, 2000)),
    ("syrk", (1000, 250, 1000)), ("syrk", (2000, 1000, 2000)),
)
# fresh long-tail distribution: 21 non-cube shapes whose buckets are
# all distinct and disjoint from the training cubes
LT_FRESH = tuple(("gemm", s) for s in (
    (250, 500, 1000), (250, 1000, 500), (500, 250, 1000),
    (500, 1000, 250), (1000, 250, 500), (1000, 500, 250),
    (250, 250, 1000), (1000, 250, 250), (250, 1000, 1000),
    (1000, 1000, 250), (500, 500, 2000), (2000, 500, 500),
    (500, 2000, 2000), (2000, 2000, 500),
)) + tuple(("syrk", (n, k, n)) for n, k in (
    (250, 1000), (500, 250), (1000, 500), (2000, 250),
    (250, 2000), (500, 1000), (1000, 2000),
))


def _base_cfg():
    from repro.core.runtime import RuntimeConfig

    # the paper's 3-device Everest-like topology at shadow scale
    return RuntimeConfig(n_devices=3, policy="blasx", cache_bytes=2 << 30,
                         mode="sim", execute=False, record_trace=False)


def run(quick: bool = True) -> List[Dict]:
    from repro.tuning import Autotuner, TuningCache
    from repro.tuning.autotuner import ROUTINES

    n = QUICK_N if quick else FULL_N
    tiles = QUICK_TILES if quick else FULL_TILES
    cfg = _base_cfg()
    cache = TuningCache()   # file-backed iff BLASX_TUNING_CACHE is set
    tuner = Autotuner(cfg, cache=cache, tiles=tiles, streams=STREAMS,
                      policies=POLICIES)
    rows: List[Dict] = []
    ok_flags: List[int] = []
    for routine in ROUTINES:
        for dtype in DTYPES:
            before = tuner.sweeps
            best = tuner.tune(routine, n, n, n, dtype=dtype)
            ok = int(best.makespan <= best.default_makespan * (1 + 1e-9))
            ok_flags.append(ok)
            rows.append({
                "name": f"autotune/{routine}_{'f64' if dtype == 'float64' else 'f32'}",
                "us_per_call": "",
                "n": n,
                "tile": best.tile,
                "n_streams": best.n_streams,
                "policy": best.policy,
                "tuned_makespan": f"{best.makespan:.4f}",
                "default_makespan": f"{best.default_makespan:.4f}",
                "speedup_vs_default": f"{best.speedup_vs_default:.3f}",
                "tuned_le_default": ok,
                "swept": tuner.sweeps - before,
                "source": best.source,
            })
    first_pass_sweeps = tuner.sweeps
    # a later context with the same topology: every key must be a pure
    # cache hit (zero shadow runs)
    second = Autotuner(cfg, cache=cache, tiles=tiles, streams=STREAMS,
                      policies=POLICIES)
    for routine in ROUTINES:
        for dtype in DTYPES:
            second.tune(routine, n, n, n, dtype=dtype)
    rows.append({
        "name": "autotune/summary",
        "us_per_call": "",
        "tuned_le_default_all": int(all(ok_flags)),
        "first_pass_sweeps": first_pass_sweeps,
        "second_pass_sweeps": second.sweeps,
        "second_pass_pure_cache_hit": int(second.sweeps == 0),
        "cache_entries": len(cache),
        "cache_path": cache.path or "",
        "fingerprint": tuner.fingerprint,
    })
    rows.append(_longtail())
    return rows


def _longtail() -> Dict:
    """The learned-cost-model sub-lane (see module docstring)."""
    from repro.tuning import Autotuner, TuningCache
    from repro.tuning.autotuner import shape_bucket

    cfg = _base_cfg()
    lt_kw = dict(tiles=LT_TILES, streams=LT_STREAMS, policies=LT_POLICIES)
    # memory-only by construction: the CI bench job sets
    # BLASX_TUNING_CACHE, and loading the main lane's entries here
    # would change the training set between CI and local runs
    cache = TuningCache("")
    trainer = Autotuner(cfg, cache=cache, mode="sweep", **lt_kw)
    for routine, (m, k, n) in LT_TRAIN:
        trainer.tune(routine, m, k, n, dtype="float64")

    auto = Autotuner(cfg, cache=cache, mode="auto", **lt_kw)
    train_buckets = {(r, shape_bucket(*s)) for r, s in LT_TRAIN}
    fresh_buckets = {(r, shape_bucket(*s)) for r, s in LT_FRESH}
    assert not (train_buckets & fresh_buckets), \
        "longtail fresh distribution overlaps the training distribution"
    # the exact cost a sweep-mode tuner would pay on the fresh
    # distribution: one shadow run per candidate per bucket
    sweep_mode_runs = sum(
        len(auto._candidates(r, shape_bucket(*s))) for r, s in LT_FRESH)
    ok = True
    for routine, (m, k, n) in LT_FRESH:
        best = auto.tune(routine, m, k, n, dtype="float64")
        ok &= best.makespan <= best.default_makespan * (1 + 1e-9)
    auto_mode_runs = auto.sweeps
    reduction = sweep_mode_runs / max(1, auto_mode_runs)
    rep = auto.report()
    return {
        "name": "autotune/longtail",
        "us_per_call": "",
        "train_buckets": len(train_buckets),
        "fresh_buckets": len(fresh_buckets),
        "sweep_mode_runs": sweep_mode_runs,
        "auto_mode_runs": auto_mode_runs,
        "sweep_reduction": f"{reduction:.2f}",
        "sweep_reduction_ge_5x": int(reduction >= 5.0),
        "tuned_le_default_all": int(ok),
        "model_adoptions": rep["model_adoptions"],
        "model_fallbacks": rep["model_fallbacks"],
        "confirmations": rep["confirmations"],
        "model_rows": rep["model"]["n_rows"],
        "model_rmse": f"{rep['model']['rmse']:.4f}",
    }


def main(argv=None) -> int:
    from .common import rows_to_csv

    print(rows_to_csv(run()))
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
