"""Autotune lane: the Fig. 10 tile sweep, closed-loop (tuned vs default).

``benchmarks/fig10_tile_size.py`` reproduces the paper's open-loop
curve — makespan as a function of tile size.  This lane runs the
:mod:`repro.tuning` autotuner over the same space and reports, per
routine x {float64, float32}:

* ``tuned_makespan``   — the virtual-clock makespan of the autotuned
  ``(tile, n_streams, policy)`` config;
* ``default_makespan`` — the fixed-default config (T=256, the base
  config's streams/policy) on the same shapes;
* ``tuned_le_default`` — the structural invariant gated by
  ``compare.py``: the tuned pick can never be worse than the default
  (the default is always candidate zero of the sweep);
* ``swept``            — how many shadow runs the search cost.

A second tuner over the same cache then re-tunes every key and the
summary row records ``second_pass_sweeps`` — **zero** means every later
context starts warm (the cache-hit acceptance criterion, also gated).

All metrics are virtual-clock deterministic: identical on every host,
so ``compare.py`` gates them tightly against ``baseline.json``.

When ``BLASX_TUNING_CACHE`` is set (the CI bench-smoke job points it
at ``TUNING_pr.json``), the tuning cache persists there and is
uploaded as an artifact alongside ``BENCH_pr.json``.
"""
from __future__ import annotations

from typing import Dict, List

QUICK_N, FULL_N = 2048, 8192
QUICK_TILES = (256, 512, 1024)
FULL_TILES = (256, 512, 1024, 2048)
STREAMS = (2, 4)
POLICIES = ("blasx", "static")
DTYPES = ("float64", "float32")


def _base_cfg():
    from repro.core.runtime import RuntimeConfig

    # the paper's 3-device Everest-like topology at shadow scale
    return RuntimeConfig(n_devices=3, policy="blasx", cache_bytes=2 << 30,
                         mode="sim", execute=False, record_trace=False)


def run(quick: bool = True) -> List[Dict]:
    from repro.tuning import Autotuner, TuningCache
    from repro.tuning.autotuner import ROUTINES

    n = QUICK_N if quick else FULL_N
    tiles = QUICK_TILES if quick else FULL_TILES
    cfg = _base_cfg()
    cache = TuningCache()   # file-backed iff BLASX_TUNING_CACHE is set
    tuner = Autotuner(cfg, cache=cache, tiles=tiles, streams=STREAMS,
                      policies=POLICIES)
    rows: List[Dict] = []
    ok_flags: List[int] = []
    for routine in ROUTINES:
        for dtype in DTYPES:
            before = tuner.sweeps
            best = tuner.tune(routine, n, n, n, dtype=dtype)
            ok = int(best.makespan <= best.default_makespan * (1 + 1e-9))
            ok_flags.append(ok)
            rows.append({
                "name": f"autotune/{routine}_{'f64' if dtype == 'float64' else 'f32'}",
                "us_per_call": "",
                "n": n,
                "tile": best.tile,
                "n_streams": best.n_streams,
                "policy": best.policy,
                "tuned_makespan": f"{best.makespan:.4f}",
                "default_makespan": f"{best.default_makespan:.4f}",
                "speedup_vs_default": f"{best.speedup_vs_default:.3f}",
                "tuned_le_default": ok,
                "swept": tuner.sweeps - before,
                "source": best.source,
            })
    first_pass_sweeps = tuner.sweeps
    # a later context with the same topology: every key must be a pure
    # cache hit (zero shadow runs)
    second = Autotuner(cfg, cache=cache, tiles=tiles, streams=STREAMS,
                      policies=POLICIES)
    for routine in ROUTINES:
        for dtype in DTYPES:
            second.tune(routine, n, n, n, dtype=dtype)
    rows.append({
        "name": "autotune/summary",
        "us_per_call": "",
        "tuned_le_default_all": int(all(ok_flags)),
        "first_pass_sweeps": first_pass_sweeps,
        "second_pass_sweeps": second.sweeps,
        "second_pass_pure_cache_hit": int(second.sweeps == 0),
        "cache_entries": len(cache),
        "cache_path": cache.path or "",
        "fingerprint": tuner.fingerprint,
    })
    return rows


def main(argv=None) -> int:
    from .common import rows_to_csv

    print(rows_to_csv(run()))
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
