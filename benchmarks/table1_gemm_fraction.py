"""Paper Table I: GEMM share of L3 BLAS FLOPs grows with matrix size.

We taskize SYRK/TRSM/TRMM/SYR2K/SYMM at three sizes and account the
FLOPs of plain GEMM-shaped steps (full-fill multiply-accumulate) vs the
triangular/symmetric special steps — the tile-algebra version of the
paper's measurement (their N=5K/10K/20K; scaled to fit CPU taskization
time, the fraction depends only on N/T).
"""
from __future__ import annotations

from repro.core import task as taskmod
from repro.core.tiling import TileGrid

SIZES = [(2048, "N=2K"), (4096, "N=4K"), (8192, "N=8K")]
TILE = 256


def _grids(n):
    return (TileGrid("A", n, n, TILE), TileGrid("B", n, n, TILE),
            TileGrid("Cin", n, n, TILE), TileGrid("C", n, n, TILE))


def run():
    rows = []
    for n, label in SIZES:
        ga, gb, gcin, gc = _grids(n)
        cases = {
            "syrk": taskmod.taskize_syrk(ga, gc, "U", "N", 1.0, 1.0),
            "trsm": taskmod.taskize_trsm(ga, gb, gc, "U", "N", "N", 1.0),
            "trmm": taskmod.taskize_trmm(ga, gcin, gc, "U", "N", "N", 1.0),
            "syr2k": taskmod.taskize_syr2k(ga, gb, gc, "U", "N", 1.0, 1.0),
            "symm": taskmod.taskize_symm(ga, gb, gc, "U", 1.0, 1.0),
        }
        for routine, tasks in cases.items():
            frac = taskmod.gemm_fraction(tasks)
            rows.append({
                "name": f"table1/{routine}/{label}",
                "us_per_call": "",
                "gemm_fraction": f"{frac:.4f}",
                "n_tasks": len(tasks),
                "total_gflop": f"{taskmod.total_flops(tasks)/1e9:.1f}",
            })
    return rows
