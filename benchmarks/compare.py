"""Bench regression gate: compare a BENCH_*.json report to the
committed baseline.

Usage (the CI bench-smoke lane)::

    python -m benchmarks.run --quick --json BENCH_pr.json
    python benchmarks/compare.py --baseline benchmarks/baseline.json \
        BENCH_pr.json

Two classes of checks:

* **Invariants** — absolute properties of the PR report that must hold
  on any machine: the batched JaxBackend beats the per-step
  NumpyBackend wall-clock on the quick GEMM benchmark, issues
  strictly fewer kernel launches than scheduled tile tasks, the
  SGEMM lane (float32 storage) is at least as fast as the DGEMM lane
  on the jax backend (half the cache/stage bytes, no f64->f32 staging
  cast — see benchmarks/backends.py), the discrete-event overlap
  lane's structural properties hold (overlap-on makespan <=
  overlap-off on every policy; blasx COMM fraction <= cublasxt;
  work-centric Stream-K scheduling strictly improves both makespan
  and overlap efficiency on every deep-k ragged shape of the ragged
  sub-lane — see benchmarks/overlap.py), the runtime-autotuner
  lane's properties
  hold (tuned makespan <= default on every routine x dtype; the second
  tuning pass is a pure cache hit; on the long-tailed fresh shape
  distribution the learned-cost-model ``auto`` mode pays >= 5x fewer
  shadow runs than a full sweep while every adopted config is still
  measured tuned <= default — see benchmarks/autotune.py), and
  the serving lane's flags hold (quota'd tenant isolation + its
  fails-without counterpart, exact admission rejections, interactive
  before batch, loaded-vs-unloaded p99 bound — see
  benchmarks/serving.py), and the pod lane's flags hold (staged
  makespan <= unstaged on every beyond-HBM shape; ici_busy_s ==
  ici_bytes/ici_bw on every device; the executing parity DGEMM is
  bitwise-equal across staged / unstaged / accelerator runs — see
  benchmarks/pod.py).
* **Regressions vs baseline** — metrics compared against
  ``benchmarks/baseline.json`` with a tolerance (default 20%; CI
  passes 35%): the jax-vs-numpy speedup ratio and the deterministic
  kernel-launch/launches-saved counts.  The speedup is a within-run
  ratio so absolute host speed cancels, but the OpenBLAS-vs-XLA
  *relative* speed still varies by host and carries ~15% run-to-run
  noise — hence the widened CI tolerance and a committed baseline
  taken from the conservative end of several runs; the invariant
  above is the hard floor.  Raw GFLOP/s are *recorded* in the report
  for the trajectory but not gated by default — the committed
  baseline and the CI runner are different machines
  (``--gate-gflops`` opts in when comparing like-for-like hosts).

Exits non-zero with a line per violation.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List


def _rows_by_name(report: dict) -> Dict[str, dict]:
    out: Dict[str, dict] = {}
    for rows in report.get("results", {}).values():
        for row in rows:
            out[row["name"]] = row
    return out


def _num(row: dict, key: str):
    try:
        return float(row[key])
    except (KeyError, TypeError, ValueError):
        return None


class Gate:
    def __init__(self):
        self.failures: List[str] = []
        self.notes: List[str] = []

    def fail(self, msg: str) -> None:
        self.failures.append(msg)

    def note(self, msg: str) -> None:
        self.notes.append(msg)

    def check_ratio(self, name: str, metric: str, pr, base, tol: float,
                    higher_is_better: bool) -> None:
        if pr is None or base is None:
            self.fail(f"{name}: metric {metric!r} missing "
                      f"(pr={pr}, baseline={base})")
            return
        if base == 0:
            self.note(f"{name}.{metric}: baseline is 0, skipping ratio")
            return
        ratio = pr / base
        ok = ratio >= (1 - tol) if higher_is_better else ratio <= (1 + tol)
        arrow = "↑" if higher_is_better else "↓"
        line = (f"{name}.{metric} ({arrow} better): pr={pr:g} "
                f"baseline={base:g} ratio={ratio:.3f} tol={tol:.0%}")
        if ok:
            self.note("OK   " + line)
        else:
            self.fail("FAIL " + line)


def check_invariants(gate: Gate, pr_rows: Dict[str, dict]) -> None:
    summary = pr_rows.get("backends/summary")
    if summary is None:
        gate.fail("backends/summary row missing from PR report")
        return
    if _num(summary, "jax_beats_numpy") != 1:
        gate.fail(
            "invariant: batched JaxBackend must beat NumpyBackend "
            f"wall-clock on the quick GEMM benchmark "
            f"(speedup={summary.get('jax_speedup_vs_numpy')})")
    else:
        gate.note(f"OK   invariant: jax beats numpy "
                  f"(speedup={summary.get('jax_speedup_vs_numpy')}x)")
    if _num(summary, "jax_fewer_launches_than_tasks") != 1:
        gate.fail(
            "invariant: JaxBackend must issue fewer kernel launches "
            f"than scheduled tile tasks "
            f"(launches={summary.get('jax_launches')}, "
            f"tasks={summary.get('jax_tasks')})")
    else:
        gate.note(f"OK   invariant: jax launches "
                  f"{summary.get('jax_launches')} < tasks "
                  f"{summary.get('jax_tasks')}")
    if _num(summary, "jax_f32_ge_f64") != 1:
        gate.fail(
            "invariant: the SGEMM lane (float32 storage) must be at "
            "least as fast as DGEMM on the jax backend (10% noise floor; "
            f"f32 speedup={summary.get('jax_f32_speedup_vs_f64')})")
    else:
        gate.note(f"OK   invariant: jax f32 >= f64 wall-clock "
                  f"(speedup={summary.get('jax_f32_speedup_vs_f64')}x)")
    check_overlap_invariants(gate, pr_rows)
    check_autotune_invariants(gate, pr_rows)
    check_serving_invariants(gate, pr_rows)
    check_pod_invariants(gate, pr_rows)


def check_overlap_invariants(gate: Gate, pr_rows: Dict[str, dict]) -> None:
    """Structural properties of the discrete-event overlap lane.

    Virtual-clock metrics are deterministic and host-independent, so
    these are hard invariants: letting communication overlap compute
    can never *lengthen* the modeled makespan, and the cached
    4-stream blasx schedule must not have a worse Fig. 8 COMM
    fraction than the uncached 2-stream cublasxt one."""
    summary = pr_rows.get("overlap/summary")
    if summary is None:
        gate.fail("overlap/summary row missing from PR report")
        return
    if _num(summary, "overlap_le_off_all") != 1:
        bad = [name for name, row in pr_rows.items()
               if name.startswith("overlap/")
               and _num(row, "overlap_le_off") == 0]
        gate.fail("invariant: overlap-on makespan must be <= overlap-off "
                  f"on every policy (violated by: {bad})")
    else:
        gate.note("OK   invariant: overlap-on makespan <= overlap-off "
                  "on every policy")
    if _num(summary, "blasx_comm_le_cublasxt") != 1:
        gate.fail(
            "invariant: blasx COMM fraction must be <= cublasxt "
            f"(blasx={summary.get('blasx_comm_fraction')}, "
            f"cublasxt={summary.get('cublasxt_comm_fraction')})")
    else:
        gate.note(f"OK   invariant: blasx COMM fraction "
                  f"{summary.get('blasx_comm_fraction')} <= cublasxt "
                  f"{summary.get('cublasxt_comm_fraction')}")
    ragged = pr_rows.get("overlap/ragged_summary")
    if ragged is None:
        gate.fail("overlap/ragged_summary row missing from PR report")
        return
    if _num(ragged, "work_centric_improves_all") != 1:
        bad = [name for name, row in pr_rows.items()
               if name.startswith("overlap/ragged_")
               and name != "overlap/ragged_summary"
               and _num(row, "wc_improves") == 0]
        gate.fail(
            "invariant: work-centric scheduling must strictly improve "
            "both makespan and overlap_efficiency on every deep-k "
            f"ragged shape (violated by: {bad})")
    else:
        gate.note("OK   invariant: work-centric improves makespan AND "
                  "overlap_efficiency on every ragged shape")


def check_autotune_invariants(gate: Gate, pr_rows: Dict[str, dict]) -> None:
    """Structural properties of the runtime-autotuner lane (virtual
    clock, deterministic): the tuned config's makespan never exceeds
    the fixed default's on any routine x dtype (the default is always
    candidate zero of the sweep), and a second tuner over the same
    cache performs ZERO shadow runs — every later context starts warm."""
    summary = pr_rows.get("autotune/summary")
    if summary is None:
        gate.fail("autotune/summary row missing from PR report")
        return
    if _num(summary, "tuned_le_default_all") != 1:
        bad = [name for name, row in pr_rows.items()
               if name.startswith("autotune/")
               and _num(row, "tuned_le_default") == 0]
        gate.fail("invariant: tuned makespan must be <= default makespan "
                  f"on every routine x dtype (violated by: {bad})")
    else:
        gate.note("OK   invariant: tuned makespan <= default on every "
                  "routine x dtype")
    if _num(summary, "second_pass_pure_cache_hit") != 1:
        gate.fail(
            "invariant: the second tuning pass must be a pure cache hit "
            f"(second_pass_sweeps={summary.get('second_pass_sweeps')})")
    else:
        gate.note(f"OK   invariant: second tuning pass swept 0 configs "
                  f"({summary.get('cache_entries')} cached entries)")
    longtail = pr_rows.get("autotune/longtail")
    if longtail is None:
        gate.fail("autotune/longtail row missing from PR report")
        return
    if _num(longtail, "tuned_le_default_all") != 1:
        gate.fail(
            "invariant: every config the auto-mode tuner adopts on the "
            "long-tailed fresh distribution must satisfy measured tuned "
            "makespan <= default")
    else:
        gate.note("OK   invariant: longtail tuned <= default on all "
                  f"{longtail.get('fresh_buckets')} fresh buckets")
    if _num(longtail, "sweep_reduction_ge_5x") != 1:
        gate.fail(
            "invariant: auto mode must pay >= 5x fewer shadow runs than "
            "sweep mode on the fresh long-tailed distribution "
            f"(sweep_mode_runs={longtail.get('sweep_mode_runs')}, "
            f"auto_mode_runs={longtail.get('auto_mode_runs')}, "
            f"reduction={longtail.get('sweep_reduction')}x)")
    else:
        gate.note(f"OK   invariant: longtail sweep reduction "
                  f"{longtail.get('sweep_reduction')}x >= 5x "
                  f"({longtail.get('model_adoptions')} model adoptions, "
                  f"{longtail.get('model_fallbacks')} fallbacks)")


def check_serving_invariants(gate: Gate, pr_rows: Dict[str, dict]) -> None:
    """Structural properties of the serving lane (benchmarks/serving.py).

    The isolation/admission flags are deterministic (sim mode, fixed
    seeds, single worker per context): a quota'd flood must leave the
    other tenant's warm tile set untouched, the identical flood
    without quotas must evict it (the fails-without-feature
    counterpart), admission must shed exactly offered-minus-capacity
    requests, and a queued interactive request must complete before a
    queued batch one.  The wall-clock latency row is gated only
    through its in-lane ``latency_isolation_ok`` flag — tenant B's
    loaded p99 must stay within a generous ratio of its unloaded p99
    while tenant A saturates the pool (host speed cancels)."""
    summary = pr_rows.get("serving/summary")
    if summary is None:
        gate.fail("serving/summary row missing from PR report")
        return
    checks = (
        ("isolation_ok",
         "a quota'd flood must not evict the other tenant's warm set"),
        ("flood_evicts_without_quota",
         "without quotas the same flood must evict the warm set "
         "(fails-without-feature counterpart)"),
        ("rejections_exact",
         "admission must reject exactly offered-minus-capacity "
         "requests at the depth bound"),
        ("interactive_first",
         "a queued interactive request must complete before a queued "
         "batch request"),
        ("latency_isolation_ok",
         "tenant B's p99 under tenant A's flood must stay within the "
         "gated ratio of its unloaded p99"),
    )
    for flag, what in checks:
        if _num(summary, flag) != 1:
            gate.fail(f"invariant: {what} (serving/summary.{flag}="
                      f"{summary.get(flag)})")
        else:
            gate.note(f"OK   invariant: serving {flag}")


def check_pod_invariants(gate: Gate, pr_rows: Dict[str, dict]) -> None:
    """Structural properties of the pod lane (benchmarks/pod.py), all
    virtual-clock deterministic: on every deep-k beyond-HBM shape,
    staging panels through the 3-level cache must not lose to the
    bypass-to-host baseline; ICI lane busy seconds must equal
    ``ici_bytes / ici_bw`` exactly on every device of every run; and
    the executing parity DGEMM must agree bitwise across staged,
    unstaged and flat-accelerator runs."""
    summary = pr_rows.get("pod/summary")
    if summary is None:
        gate.fail("pod/summary row missing from PR report")
        return
    if _num(summary, "staged_le_unstaged_all") != 1:
        bad = [name for name, row in pr_rows.items()
               if name.startswith("pod/staged_")
               and _num(row, "staged_le_unstaged") == 0]
        gate.fail("invariant: staged makespan must be <= unstaged on "
                  f"every beyond-HBM shape (violated by: {bad})")
    else:
        gate.note("OK   invariant: pod staged makespan <= unstaged on "
                  "every beyond-HBM shape")
    if _num(summary, "ici_time_consistent_all") != 1:
        gate.fail("invariant: ICI lane busy seconds must equal "
                  "ici_bytes / ici_bw on every device of every pod run")
    else:
        gate.note("OK   invariant: pod ici_busy_s == ici_bytes/ici_bw "
                  "on every device")
    if _num(summary, "pod_bitwise_equal") != 1:
        gate.fail("invariant: the executing pod parity DGEMM must agree "
                  "bitwise across staged / unstaged / accelerator runs")
    else:
        gate.note("OK   invariant: pod parity DGEMM bitwise-equal "
                  "across staged / unstaged / accelerator")


def check_regressions(gate: Gate, pr_rows: Dict[str, dict],
                      base_rows: Dict[str, dict], tol: float,
                      gate_gflops: bool) -> None:
    def both(name):
        pr, base = pr_rows.get(name), base_rows.get(name)
        if pr is None or base is None:
            gate.fail(f"row {name!r} missing "
                      f"(pr={'yes' if pr else 'no'}, "
                      f"baseline={'yes' if base else 'no'})")
            return None, None
        return pr, base

    pr, base = both("backends/summary")
    if pr is not None:
        gate.check_ratio("backends/summary", "jax_speedup_vs_numpy",
                         _num(pr, "jax_speedup_vs_numpy"),
                         _num(base, "jax_speedup_vs_numpy"),
                         tol, higher_is_better=True)
        gate.check_ratio("backends/summary", "jax_f32_speedup_vs_f64",
                         _num(pr, "jax_f32_speedup_vs_f64"),
                         _num(base, "jax_f32_speedup_vs_f64"),
                         tol, higher_is_better=True)
    for name in ("backends/gemm_numpy", "backends/gemm_jax",
                 "backends/gemm_numpy_f32", "backends/gemm_jax_f32"):
        pr, base = both(name)
        if pr is None:
            continue
        gate.check_ratio(name, "kernel_launches",
                         _num(pr, "kernel_launches"),
                         _num(base, "kernel_launches"),
                         tol, higher_is_better=False)
        gate.check_ratio(name, "launches_saved",
                         _num(pr, "launches_saved"),
                         _num(base, "launches_saved"),
                         tol, higher_is_better=True)
        if gate_gflops:
            gate.check_ratio(name, "gflops", _num(pr, "gflops"),
                             _num(base, "gflops"), tol,
                             higher_is_better=True)
    # overlap lane: virtual-clock metrics, deterministic across hosts
    for name in ("overlap/blasx", "overlap/parsec", "overlap/static",
                 "overlap/cublasxt"):
        pr, base = both(name)
        if pr is None:
            continue
        gate.check_ratio(name, "comm_fraction",
                         _num(pr, "comm_fraction"),
                         _num(base, "comm_fraction"),
                         tol, higher_is_better=False)
        gate.check_ratio(name, "overlap_efficiency",
                         _num(pr, "overlap_efficiency"),
                         _num(base, "overlap_efficiency"),
                         tol, higher_is_better=True)
        gate.check_ratio(name, "makespan_on",
                         _num(pr, "makespan_on"),
                         _num(base, "makespan_on"),
                         tol, higher_is_better=False)
    # ragged sub-lane: deep-k work-centric rows, also virtual-clock
    ragged = sorted(name for name in (set(pr_rows) | set(base_rows))
                    if name.startswith("overlap/ragged_")
                    and name != "overlap/ragged_summary")
    for name in ragged:
        pr, base = both(name)
        if pr is None:
            continue
        gate.check_ratio(name, "makespan_wc",
                         _num(pr, "makespan_wc"),
                         _num(base, "makespan_wc"),
                         tol, higher_is_better=False)
        gate.check_ratio(name, "wc_speedup",
                         _num(pr, "wc_speedup"),
                         _num(base, "wc_speedup"),
                         tol, higher_is_better=True)
    # autotune lane: virtual-clock metrics, deterministic across hosts
    for routine in ("gemm", "syrk", "syr2k", "symm", "trmm", "trsm"):
        for prec in ("f64", "f32"):
            name = f"autotune/{routine}_{prec}"
            pr, base = both(name)
            if pr is None:
                continue
            gate.check_ratio(name, "tuned_makespan",
                             _num(pr, "tuned_makespan"),
                             _num(base, "tuned_makespan"),
                             tol, higher_is_better=False)
            gate.check_ratio(name, "default_makespan",
                             _num(pr, "default_makespan"),
                             _num(base, "default_makespan"),
                             tol, higher_is_better=False)
    # longtail sub-lane: shadow-run counts are deterministic (virtual
    # clock + fixed shape distributions), so a shrinking reduction is a
    # real model/search regression, not noise
    pr, base = both("autotune/longtail")
    if pr is not None:
        gate.check_ratio("autotune/longtail", "sweep_reduction",
                         _num(pr, "sweep_reduction"),
                         _num(base, "sweep_reduction"),
                         tol, higher_is_better=True)
        gate.check_ratio("autotune/longtail", "auto_mode_runs",
                         _num(pr, "auto_mode_runs"),
                         _num(base, "auto_mode_runs"),
                         tol, higher_is_better=False)
    # serving lane: deterministic tile/eviction/rejection counts (sim
    # mode, fixed seeds); the wall-clock latency row is NOT gated here
    pr, base = both("serving/isolation")
    if pr is not None:
        gate.check_ratio("serving/isolation", "warm_tiles_after",
                         _num(pr, "warm_tiles_after"),
                         _num(base, "warm_tiles_after"),
                         tol, higher_is_better=True)
        gate.check_ratio("serving/isolation", "quota_evictions",
                         _num(pr, "quota_evictions"),
                         _num(base, "quota_evictions"),
                         tol, higher_is_better=False)
    pr, base = both("serving/admission")
    if pr is not None:
        gate.check_ratio("serving/admission", "rejected",
                         _num(pr, "rejected"), _num(base, "rejected"),
                         tol, higher_is_better=False)
    # pod lane: virtual-clock staged-vs-unstaged metrics, deterministic
    pod_names = sorted(name for name in (set(pr_rows) | set(base_rows))
                       if name.startswith("pod/staged_"))
    for name in pod_names:
        pr, base = both(name)
        if pr is None:
            continue
        gate.check_ratio(name, "makespan_staged",
                         _num(pr, "makespan_staged"),
                         _num(base, "makespan_staged"),
                         tol, higher_is_better=False)
        gate.check_ratio(name, "staged_speedup",
                         _num(pr, "staged_speedup"),
                         _num(base, "staged_speedup"),
                         tol, higher_is_better=True)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("report", help="BENCH_*.json produced by "
                                   "`python -m benchmarks.run --json`")
    ap.add_argument("--baseline", required=True,
                    help="committed baseline report")
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="allowed relative regression (default 0.20)")
    ap.add_argument("--gate-gflops", action="store_true",
                    help="also gate raw GFLOP/s (like-for-like hosts only)")
    ap.add_argument("--no-invariants", action="store_true",
                    help="skip absolute invariant checks")
    args = ap.parse_args(argv)

    with open(args.report) as f:
        pr_rows = _rows_by_name(json.load(f))
    with open(args.baseline) as f:
        base_rows = _rows_by_name(json.load(f))

    gate = Gate()
    if not args.no_invariants:
        check_invariants(gate, pr_rows)
    check_regressions(gate, pr_rows, base_rows, args.tolerance,
                      args.gate_gflops)

    for line in gate.notes:
        print(line)
    for line in gate.failures:
        print(line, file=sys.stderr)
    if gate.failures:
        print(f"\n{len(gate.failures)} bench gate violation(s)",
              file=sys.stderr)
        return 1
    print("\nbench gate: all checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
