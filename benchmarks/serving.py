"""Serving lane: BlasxServer saturation, admission and cache isolation.

Three sub-benches, split by how host-dependent their numbers are:

* ``serving/isolation`` + ``serving/isolation_noquota`` — the
  multi-tenant ALRU quota invariant, measured on a pool-of-1 server in
  sim mode: tenant A warms a working set, tenant B floods ephemeral
  traffic.  With B quota'd, A's resident tile count must be untouched
  (``isolation_ok``); without quotas the same flood must eat into it
  (``flood_evicts_without_quota`` — the fails-without-feature
  counterpart).  Tile counts and quota-eviction counts are
  deterministic (single sim worker, fixed seed), so ``compare.py``
  ratio-gates them against the baseline.
* ``serving/admission`` — deterministic admission behaviour against a
  stalled worker: exactly ``offered - max_depth`` submissions must be
  rejected (``rejections_exact``), and with one batch and one
  interactive request queued, the interactive one must complete first
  (``interactive_first``).
* ``serving/latency`` — wall-clock saturation numbers on a pool-of-2
  server: tenant B's interactive p50/p99 unloaded, then again while
  tenant A saturates its own lane with batch floods.  Host speed
  cancels in the loaded/unloaded ratio, but thread scheduling noise
  does not, so this row is gated only through its in-lane
  ``latency_isolation_ok`` flag (generous ratio + absolute grace) —
  the raw percentiles are recorded for the trajectory, not gated.

The summary row carries the flags ``compare.py`` enforces.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List

import numpy as np

WARM_N = 128            # tenant A working-set matrices (16 tiles each)
FLOOD_N = 256           # tenant B ephemeral flood matrices
TILE = 32
CACHE_BYTES = 1 << 20
QUOTA_BYTES = 256 << 10

LAT_QUICK_REQS, LAT_FULL_REQS = 24, 80
LAT_N = 64
FLOOD_REQS_QUICK, FLOOD_REQS_FULL = 24, 80
# loaded p99 may exceed unloaded p99 by this ratio plus grace before
# the in-lane flag trips (pool isolation keeps B on its own context,
# so the real ratio is near 1; the slack absorbs host scheduling noise)
LAT_RATIO_LIMIT = 8.0
LAT_GRACE_S = 0.10


def _cfg(cache_bytes=CACHE_BYTES):
    from repro.core.runtime import RuntimeConfig

    return RuntimeConfig(n_devices=1, mode="sim", cache_bytes=cache_bytes)


def _isolation_rows() -> List[Dict]:
    from repro.serve import BlasxServer

    rng = np.random.default_rng(17)
    x_data = rng.standard_normal((WARM_N, WARM_N))
    w_data = rng.standard_normal((WARM_N, WARM_N))
    big = rng.standard_normal((FLOOD_N, FLOOD_N))

    def run_flood(quotas):
        with BlasxServer(_cfg(), pool_size=1, tile=TILE,
                         quotas=quotas) as srv:
            x = srv.tile("a", x_data)
            w = srv.tile("a", w_data)
            srv.submit("a", "gemm", x, w).result(timeout=120)
            ctx = srv._contexts[0]
            warm_ids = (x.matrix_id, w.matrix_id)

            def warm_tiles():
                return sum(1 for d in ctx.runtime.devices
                           for k in d.alru.keys()
                           if k.matrix_id in warm_ids)

            before = warm_tiles()
            for _ in range(3):
                srv.submit("b", "gemm", big, big).result(timeout=120)
            after = warm_tiles()
            for d in ctx.runtime.devices:
                d.alru.check_invariants()
            ctx.runtime.directory.audit(
                [d.alru for d in ctx.runtime.devices])
            return before, after, srv.quota_evictions().get("b", 0)

    before_q, after_q, quota_evictions = run_flood({"b": QUOTA_BYTES})
    before_n, after_n, _ = run_flood(None)
    return [
        {
            "name": "serving/isolation",
            "us_per_call": "",
            "warm_tiles_before": before_q,
            "warm_tiles_after": after_q,
            "quota_evictions": quota_evictions,
            "quota_bytes": QUOTA_BYTES,
            "isolation_ok": int(after_q == before_q and before_q > 0
                                and quota_evictions > 0),
        },
        {
            "name": "serving/isolation_noquota",
            "us_per_call": "",
            "warm_tiles_before": before_n,
            "warm_tiles_after": after_n,
            "flood_evicts_without_quota": int(after_n < before_n),
        },
    ]


def _admission_row() -> Dict:
    from repro.api import BackpressureError
    from repro.serve import BATCH, INTERACTIVE, BlasxServer

    max_depth, offered = 4, 10
    completion_order: List[str] = []
    with BlasxServer(_cfg(), pool_size=1, tile=TILE,
                     max_depth=max_depth) as srv:
        gate = threading.Event()
        running = threading.Event()
        stalled = srv.submit(
            "x", lambda ctx: (running.set(), gate.wait(60)) and None)
        running.wait(60)                    # worker busy, queue empty
        batch_f = srv.submit(
            "slow", lambda ctx: completion_order.append("batch"),
            priority=BATCH)
        inter_f = srv.submit(
            "fast", lambda ctx: completion_order.append("interactive"),
            priority=INTERACTIVE)
        rejected = 0
        accepted = []
        for _ in range(offered):
            try:
                accepted.append(
                    srv.submit("x", lambda ctx: None, priority=BATCH))
            except BackpressureError:
                rejected += 1
        gate.set()
        for f in [stalled, batch_f, inter_f] + accepted:
            f.result(timeout=120)
        st = srv.stats()["tenants"]
        stats_rejected = st["x"]["rejected"]
    expected_rejected = offered - (max_depth - 2)  # 2 slots pre-queued
    return {
        "name": "serving/admission",
        "us_per_call": "",
        "max_depth": max_depth,
        "offered": offered + 2,
        "rejected": rejected,
        "rejections_exact": int(rejected == expected_rejected
                                and stats_rejected == rejected),
        "interactive_first": int(
            completion_order == ["interactive", "batch"]),
    }


def _percentiles(samples: List[float]):
    from repro.serve import percentile

    return percentile(samples, 50.0), percentile(samples, 99.0)


def _latency_row(quick: bool) -> Dict:
    from repro.serve import BATCH, INTERACTIVE, BlasxServer

    n_reqs = LAT_QUICK_REQS if quick else LAT_FULL_REQS
    n_flood = FLOOD_REQS_QUICK if quick else FLOOD_REQS_FULL
    rng = np.random.default_rng(29)
    xs = rng.standard_normal((LAT_N, LAT_N))
    big = rng.standard_normal((2 * LAT_N, 2 * LAT_N))
    with BlasxServer(_cfg(cache_bytes=8 << 20), pool_size=2, tile=TILE,
                     max_depth=4 * (n_reqs + n_flood)) as srv:
        w = srv.tile("b", xs)               # pins B's affinity lane

        def timed_request():
            t0 = time.perf_counter()
            srv.submit("b", "gemm", xs, w,
                       priority=INTERACTIVE).result(timeout=120)
            return time.perf_counter() - t0

        # warmup, then the unloaded profile
        for _ in range(3):
            timed_request()
        unloaded = [timed_request() for _ in range(n_reqs)]
        # tenant A saturates its own lane with batch floods
        t_flood = time.perf_counter()
        flood = [srv.submit("a", "gemm", big, big, priority=BATCH)
                 for _ in range(n_flood)]
        loaded = [timed_request() for _ in range(n_reqs)]
        for f in flood:
            f.result(timeout=300)
        flood_elapsed = time.perf_counter() - t_flood
        st = srv.stats()
    u50, u99 = _percentiles(unloaded)
    l50, l99 = _percentiles(loaded)
    ok = l99 <= u99 * LAT_RATIO_LIMIT + LAT_GRACE_S
    return {
        "name": "serving/latency",
        "us_per_call": f"{np.mean(unloaded) * 1e6:.1f}",
        "requests": n_reqs,
        "flood_requests": n_flood,
        "unloaded_p50_ms": f"{u50 * 1e3:.2f}",
        "unloaded_p99_ms": f"{u99 * 1e3:.2f}",
        "loaded_p50_ms": f"{l50 * 1e3:.2f}",
        "loaded_p99_ms": f"{l99 * 1e3:.2f}",
        "p99_ratio": f"{(l99 / u99 if u99 else 0.0):.2f}",
        "flood_throughput_rps": f"{n_flood / flood_elapsed:.1f}",
        "pool_size": st["pool_size"],
        "latency_isolation_ok": int(ok),
    }


def run(quick: bool = True) -> List[Dict]:
    rows = _isolation_rows()
    rows.append(_admission_row())
    rows.append(_latency_row(quick))
    flags = {
        "isolation_ok": rows[0]["isolation_ok"],
        "flood_evicts_without_quota":
            rows[1]["flood_evicts_without_quota"],
        "rejections_exact": rows[2]["rejections_exact"],
        "interactive_first": rows[2]["interactive_first"],
        "latency_isolation_ok": rows[3]["latency_isolation_ok"],
    }
    rows.append({
        "name": "serving/summary",
        "us_per_call": "",
        **flags,
        "all_ok": int(all(flags.values())),
    })
    return rows


def main(argv=None) -> int:
    from .common import rows_to_csv

    print(rows_to_csv(run()))
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
