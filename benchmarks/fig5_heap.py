"""Paper Fig. 5/§IV-E: BLASX_Malloc amortizes alloc/free overhead.

We time the BLASX first-fit+coalesce heap against a deliberately naive
allocator model (fresh bookkeeping per call, linear occupied-list scan
on free — the cudaMalloc/cudaFree stand-in on this host) over the
actual allocation trace of a tiled GEMM run."""
from __future__ import annotations

import time

import numpy as np

from repro.core.heap import BlasxHeap

TRACE_LEN = 20000
TILE_BYTES = 256 * 256 * 8


class NaiveAllocator:
    """cudaMalloc-style stand-in: no free-list reuse; every alloc scans
    all occupied segments to find a gap (quadratic-ish churn)."""

    def __init__(self, capacity):
        self.capacity = capacity
        self.occupied = []  # sorted (offset, size)

    def malloc(self, size):
        prev_end = 0
        for i, (off, sz) in enumerate(self.occupied):
            if off - prev_end >= size:
                self.occupied.insert(i, (prev_end, size))
                return prev_end
            prev_end = off + sz
        if self.capacity - prev_end >= size:
            self.occupied.append((prev_end, size))
            return prev_end
        return None

    def free(self, offset):
        for i, (off, sz) in enumerate(self.occupied):
            if off == offset:
                del self.occupied[i]
                return
        raise KeyError(offset)


def _trace(alloc, rng):
    live = []
    t0 = time.perf_counter()
    for i in range(TRACE_LEN):
        if live and rng.random() < 0.45:
            off = live.pop(rng.integers(0, len(live)))
            alloc.free(off)
        else:
            off = alloc.malloc(TILE_BYTES)
            if off is None:
                off2 = live.pop(0)
                alloc.free(off2)
                off = alloc.malloc(TILE_BYTES)
            live.append(off)
    return time.perf_counter() - t0


def run():
    cap = 512 << 20
    rng = np.random.default_rng(0)
    t_blasx = _trace(BlasxHeap(cap), rng)
    rng = np.random.default_rng(0)
    t_naive = _trace(NaiveAllocator(cap), rng)
    h = BlasxHeap(cap)
    return [{
        "name": "fig5/alloc_trace",
        "us_per_call": f"{t_blasx/TRACE_LEN*1e6:.2f}",
        "blasx_heap_s": f"{t_blasx:.4f}",
        "naive_alloc_s": f"{t_naive:.4f}",
        "speedup": f"{t_naive/max(1e-9, t_blasx):.1f}x",
    }]
