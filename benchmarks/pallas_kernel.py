"""Beyond-paper: the Pallas tile kernel vs the jnp oracle (interpret
mode on CPU — correctness + dispatch overhead, not TPU wall time) and
the block-shape working-set table that drives VMEM sizing."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from .common import timeit


def run():
    rows = []
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    out_k = ops.matmul(a, b, interpret=True)
    out_r = ref.matmul_ref(a, b)
    err = float(jnp.max(jnp.abs(out_k - out_r)))
    t = timeit(lambda: ops.matmul(a, b, interpret=True).block_until_ready())
    rows.append({
        "name": "pallas/matmul_256_interpret",
        "us_per_call": f"{t*1e6:.0f}",
        "max_err_vs_oracle": f"{err:.2e}",
    })
    for m, n, k, isz in [(4096, 4096, 4096, 2), (8192, 28672, 8192, 2),
                         (1024, 151936, 1024, 4)]:
        bm, bn, bk = ops.default_blocks(m, n, k, isz)
        ws = (bm * bk + bk * bn) * isz + bm * bn * 4 + bm * bn * isz
        rows.append({
            "name": f"pallas/blocks/{m}x{n}x{k}/itemsize{isz}",
            "us_per_call": "",
            "block": f"{bm}x{bn}x{bk}",
            "vmem_working_set_KB": f"{ws/1024:.0f}",
            "mxu_aligned": str(bn % 128 == 0 and bk % 128 == 0),
        })
    return rows
