"""Paper Table V: communication volume per routine at N=16384 — BLASX
vs cuBLAS-XT-mode (on-demand, no cache) vs PaRSEC-mode (L1 only).

Paper numbers: cuBLAS-XT averages 15143 MB = 2.95x BLASX's 5132 MB;
BLASX saves ~12% over PaRSEC; P2P (red numbers) flows only between the
two switch-sharing GPUs.  Same topology here (Everest: P2P pair {1,2}),
exact ledger bytes, metadata-only execution at the paper's exact N."""
from __future__ import annotations

from repro.core.blas3 import shadow_run
from repro.core.runtime import BlasxRuntime, RuntimeConfig

N = 16384
TILE = 1024
TOPOLOGY = dict(n_devices=3, p2p_groups=[[0], [1, 2]],
                cache_bytes=4 << 30, mode="sim", execute=False,
                record_trace=False)


def _volumes(routine: str, policy: str):
    rt = BlasxRuntime(RuntimeConfig(policy=policy, **TOPOLOGY))
    shadow_run(routine, N, tile=TILE, runtime=rt)
    return rt.total_comm_bytes()


def run():
    rows = []
    ratios = []
    for routine in ("gemm", "syrk", "syr2k", "symm", "trmm", "trsm"):
        vols = {p: _volumes(routine, p)
                for p in ("blasx", "parsec", "cublasxt")}
        bx = vols["blasx"]["h2d"] + vols["blasx"]["d2d"]
        xt = vols["cublasxt"]["h2d"]
        pr = vols["parsec"]["h2d"]
        ratios.append(xt / max(1, bx))
        rows.append({
            "name": f"table5/d{routine}/N{N}",
            "us_per_call": "",
            "blasx_MB": f"{bx/1e6:.0f}",
            "blasx_p2p_MB": f"{vols['blasx']['d2d']/1e6:.0f}",
            "parsec_MB": f"{pr/1e6:.0f}",
            "cublasxt_MB": f"{xt/1e6:.0f}",
            "xt_over_blasx": f"{xt/max(1,bx):.2f}",
            "parsec_over_blasx": f"{pr/max(1,bx):.2f}",
        })
    rows.append({
        "name": "table5/summary",
        "us_per_call": "",
        "avg_xt_over_blasx": f"{sum(ratios)/len(ratios):.2f}",
        "paper_reported": "2.95",
    })
    return rows
