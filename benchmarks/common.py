"""Shared helpers for the paper-table benchmarks."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np


def timeit(fn: Callable, repeats: int = 3) -> float:
    """Median wall seconds over repeats."""
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def gflops(flops: float, seconds: float) -> float:
    return flops / seconds / 1e9 if seconds > 0 else 0.0


def rows_to_csv(rows: List[Dict]) -> str:
    out = []
    for r in rows:
        r = dict(r)  # rows are reused for the JSON report; don't mutate
        name = r.pop("name")
        us = r.pop("us_per_call", "")
        derived = ";".join(f"{k}={v}" for k, v in r.items())
        out.append(f"{name},{us},{derived}")
    return "\n".join(out)
