"""Execution-backend benchmark: the same GEMM through every backend.

This is the perf-trajectory anchor for the pluggable-backend work
(PR 2): one DGEMM workload is scheduled by the identical BLASX runtime
and executed by each :mod:`repro.backends` engine, so wall-clock
differences isolate the execution layer — per-step interpreted host
BLAS (``numpy``, the seed behavior) vs one batched jitted dispatch per
step group (``jax``/``pallas``).

Reported per backend: wall-clock + GFLOP/s on warm tile caches, and
the batched-dispatch ledger (scheduled tasks, k-steps, kernel
launches, launches saved).  The ``summary`` row carries the
machine-portable gate metrics: ``jax_speedup_vs_numpy`` (ratio within
one run, robust across hosts) and the deterministic launch counts.

On CPU hosts the jax win comes from two honest, documented effects:
whole k-loop contraction (a task's steps fold into one long-K GEMM)
and the engine's float32 compute for float64 storage (default CPU jax
is 32-bit; results are cast back — mixed-precision execution, ~1e-5
relative error on this workload).  On TPU the pallas backend's batched
kernel dispatch is the point; its CPU interpret-mode row here is a
small-size compositional check, not a speed claim.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

# quick lane: T=128 maximizes the batching story (8 k-steps per task
# fold into one long-K dispatch; the per-step engine pays 512 separate
# calls) — jax wins ~1.4-1.5x here with stable margin across runs
QUICK_N, QUICK_TILE = 1024, 128
FULL_N, FULL_TILE = 2048, 512
PALLAS_N, PALLAS_TILE = 256, 64          # interpret mode is slow on CPU
REPEATS = 9


def _make_ctx(backend: str, n: int, tile: int):
    from repro.api import BlasxContext
    from repro.core.runtime import RuntimeConfig

    rng = np.random.default_rng(0)
    A = rng.standard_normal((n, n))
    B = rng.standard_normal((n, n))
    ctx = BlasxContext(RuntimeConfig(n_devices=1, mode="sim",
                                     backend=backend), tile=tile)
    Ah, Bh = ctx.tile(A), ctx.tile(B)
    return ctx, Ah, Bh


def _launch_delta(ctx, Ah, Bh) -> Dict[str, int]:
    before = ctx.runtime.launch_stats()
    ctx.gemm(Ah, Bh)
    after = ctx.runtime.launch_stats()
    return {k: after[k] - before[k]
            for k in ("tasks", "steps", "kernel_launches", "launches_saved")}


def _bench_backends(backends, n: int, tile: int,
                    repeats: int = REPEATS) -> Dict[str, Dict[str, object]]:
    """Bench each backend on one GEMM workload, one sequential phase
    per backend.  A short settle before each phase lets the previous
    engine's busy-spinning worker threads park (OpenBLAS and XLA
    threadpools thrash each other on small hosts otherwise), and the
    reported time is the *minimum* over repeats — the standard
    noise-robust statistic for contention-prone microbenchmarks; the
    jax/numpy ratio of minima is what the CI gate tracks."""
    flops = 2 * n * n * n
    out = {}
    for be in backends:
        ctx, Ah, Bh = _make_ctx(be, n, tile)
        try:
            time.sleep(0.1)                    # park foreign spinners
            ctx.gemm(Ah, Bh)                   # warm caches + compiles
            delta = _launch_delta(ctx, Ah, Bh)
            ts = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                ctx.gemm(Ah, Bh)
                ts.append(time.perf_counter() - t0)
        finally:
            ctx.close()
        sec = float(min(ts))
        out[be] = {"backend": be, "seconds": sec,
                   "gflops": flops / sec / 1e9, "n": n, "tile": tile,
                   **delta}
    return out


def run(quick: bool = True) -> List[Dict]:
    n, tile = (QUICK_N, QUICK_TILE) if quick else (FULL_N, FULL_TILE)
    rows: List[Dict] = []
    per_backend = _bench_backends(("numpy", "jax"), n, tile)
    for backend in ("numpy", "jax"):
        r = per_backend[backend]
        rows.append({
            "name": f"backends/gemm_{backend}",
            "us_per_call": f"{r['seconds'] * 1e6:.0f}",
            "gflops": f"{r['gflops']:.2f}",
            "tasks": r["tasks"],
            "steps": r["steps"],
            "kernel_launches": r["kernel_launches"],
            "launches_saved": r["launches_saved"],
            "n": n, "tile": tile,
        })
    # pallas: small compositional reference (interpret mode on CPU)
    rp = _bench_backends(("pallas",), PALLAS_N, PALLAS_TILE,
                         repeats=1)["pallas"]
    rows.append({
        "name": "backends/gemm_pallas_small",
        "us_per_call": f"{rp['seconds'] * 1e6:.0f}",
        "gflops": f"{rp['gflops']:.2f}",
        "tasks": rp["tasks"],
        "steps": rp["steps"],
        "kernel_launches": rp["kernel_launches"],
        "launches_saved": rp["launches_saved"],
        "n": PALLAS_N, "tile": PALLAS_TILE,
    })
    npy, jx = per_backend["numpy"], per_backend["jax"]
    rows.append({
        "name": "backends/summary",
        "us_per_call": "",
        "jax_speedup_vs_numpy": f"{npy['seconds'] / jx['seconds']:.3f}",
        "jax_launches": jx["kernel_launches"],
        "jax_tasks": jx["tasks"],
        "numpy_launches": npy["kernel_launches"],
        "jax_beats_numpy": int(jx["seconds"] < npy["seconds"]),
        "jax_fewer_launches_than_tasks":
            int(jx["kernel_launches"] < jx["tasks"]),
    })
    return rows
