"""Execution-backend benchmark: the same GEMM through every backend
and precision.

This is the perf-trajectory anchor for the pluggable-backend work
(PR 2) and the multi-precision work (PR 3): one GEMM workload is
scheduled by the identical BLASX runtime and executed by each
:mod:`repro.backends` engine, so wall-clock differences isolate the
execution layer — per-step interpreted host BLAS (``numpy``, the seed
behavior) vs one batched jitted dispatch per step group
(``jax``/``pallas``) — and, within the jax backend, float64 vs float32
storage (the SGEMM lane), so the precision win is *tracked* by the CI
gate instead of asserted once.

Reported per backend: wall-clock + GFLOP/s on warm tile caches, and
the batched-dispatch ledger (scheduled tasks, k-steps, kernel
launches, launches saved).  The ``summary`` row carries the
machine-portable gate metrics: ``jax_speedup_vs_numpy`` and
``jax_f32_speedup_vs_f64`` (ratios within one run, robust across
hosts) plus the deterministic launch counts.

On CPU hosts the jax win comes from two honest, documented effects:
whole k-loop contraction (a task's steps fold into one long-K GEMM)
and the engine's float32 compute for float64 storage (default CPU jax
is 32-bit; results are cast back — mixed-precision execution, ~1e-5
relative error on this workload).  The SGEMM lane removes the cast:
float32 storage halves every H2D/stage/write byte and skips the
f64->f32 staging copy, so f32 must run at least as fast as f64 on the
jax backend — the compare.py invariant.  On TPU the pallas backend's
batched kernel dispatch is the point; its CPU interpret-mode row here
is a small-size compositional check, not a speed claim.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

# quick lane: T=128 maximizes the batching story (8 k-steps per task
# fold into one long-K dispatch; the per-step engine pays 512 separate
# calls) — jax wins ~1.4-1.5x here with stable margin across runs
QUICK_N, QUICK_TILE = 1024, 128
FULL_N, FULL_TILE = 2048, 512
PALLAS_N, PALLAS_TILE = 256, 64          # interpret mode is slow on CPU
REPEATS = 9


def _make_ctx(backend: str, n: int, tile: int, dtype=np.float64):
    from repro.api import BlasxContext
    from repro.core.runtime import RuntimeConfig

    rng = np.random.default_rng(0)
    A = rng.standard_normal((n, n))
    B = rng.standard_normal((n, n))
    ctx = BlasxContext(RuntimeConfig(n_devices=1, mode="sim",
                                     backend=backend), tile=tile,
                       dtype=dtype)
    Ah, Bh = ctx.tile(A), ctx.tile(B)
    return ctx, Ah, Bh


def _launch_delta(ctx, Ah, Bh) -> Dict[str, int]:
    before = ctx.runtime.launch_stats()
    ctx.gemm(Ah, Bh)
    after = ctx.runtime.launch_stats()
    return {k: after[k] - before[k]
            for k in ("tasks", "steps", "kernel_launches", "launches_saved")}


def _bench_backends(backends, n: int, tile: int, repeats: int = REPEATS,
                    dtype=np.float64) -> Dict[str, Dict[str, object]]:
    """Bench each backend on one GEMM workload, one sequential phase
    per backend.  A short settle before each phase lets the previous
    engine's busy-spinning worker threads park (OpenBLAS and XLA
    threadpools thrash each other on small hosts otherwise), and the
    reported time is the *minimum* over repeats — the standard
    noise-robust statistic for contention-prone microbenchmarks; the
    ratios of minima are what the CI gate tracks."""
    flops = 2 * n * n * n
    out = {}
    for be in backends:
        ctx, Ah, Bh = _make_ctx(be, n, tile, dtype)
        try:
            time.sleep(0.1)                    # park foreign spinners
            ctx.gemm(Ah, Bh)                   # warm caches + compiles
            delta = _launch_delta(ctx, Ah, Bh)
            ts = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                ctx.gemm(Ah, Bh)
                ts.append(time.perf_counter() - t0)
        finally:
            ctx.close()
        sec = float(min(ts))
        out[be] = {"backend": be, "seconds": sec,
                   "gflops": flops / sec / 1e9, "n": n, "tile": tile,
                   "dtype": np.dtype(dtype).name, **delta}
    return out


def _row(name: str, r: Dict[str, object]) -> Dict[str, object]:
    return {
        "name": name,
        "us_per_call": f"{r['seconds'] * 1e6:.0f}",
        "gflops": f"{r['gflops']:.2f}",
        "dtype": r["dtype"],
        "tasks": r["tasks"],
        "steps": r["steps"],
        "kernel_launches": r["kernel_launches"],
        "launches_saved": r["launches_saved"],
        "n": r["n"], "tile": r["tile"],
    }


def run(quick: bool = True) -> List[Dict]:
    n, tile = (QUICK_N, QUICK_TILE) if quick else (FULL_N, FULL_TILE)
    rows: List[Dict] = []
    per_backend = _bench_backends(("numpy", "jax"), n, tile)
    for backend in ("numpy", "jax"):
        rows.append(_row(f"backends/gemm_{backend}", per_backend[backend]))
    # SGEMM lane: the same workload at float32 storage — half the bytes
    # through the tile caches and no f64->f32 staging cast on the jax
    # engine, so f32 >= f64 wall-clock is a gated invariant, not a hope
    per_f32 = _bench_backends(("numpy", "jax"), n, tile, dtype=np.float32)
    for backend in ("numpy", "jax"):
        rows.append(_row(f"backends/gemm_{backend}_f32", per_f32[backend]))
    # pallas: small compositional reference (interpret mode on CPU)
    rp = _bench_backends(("pallas",), PALLAS_N, PALLAS_TILE,
                         repeats=1)["pallas"]
    rows.append(_row("backends/gemm_pallas_small", rp))
    npy, jx = per_backend["numpy"], per_backend["jax"]
    jx32 = per_f32["jax"]
    rows.append({
        "name": "backends/summary",
        "us_per_call": "",
        "jax_speedup_vs_numpy": f"{npy['seconds'] / jx['seconds']:.3f}",
        "jax_f32_speedup_vs_f64": f"{jx['seconds'] / jx32['seconds']:.3f}",
        "jax_launches": jx["kernel_launches"],
        "jax_tasks": jx["tasks"],
        "numpy_launches": npy["kernel_launches"],
        "jax_beats_numpy": int(jx["seconds"] < npy["seconds"]),
        # 10% noise floor: the two lanes are timed in separate phases
        # (seconds apart) on a possibly-shared host, so sustained
        # co-tenant contention can skew one phase; min-of-9 repeats
        # plus this slack still trips when f32 genuinely loses its
        # advantage (observed speedups run 1.14-1.23x)
        "jax_f32_ge_f64": int(jx32["seconds"] <= jx["seconds"] * 1.10),
        "jax_fewer_launches_than_tasks":
            int(jx["kernel_launches"] < jx["tasks"]),
    })
    return rows
