"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (the harness contract)
and, with ``--json PATH``, also writes the full machine-readable
report so the per-PR bench trajectory (``BENCH_*.json``) can
accumulate across PRs and be gated by ``benchmarks/compare.py``.

  table1  GEMM share of L3 BLAS FLOPs            (paper Table I)
  fig5    BLASX_Malloc vs naive allocator        (paper Fig. 5)
  fig7    throughput + speedup 1/2/3 devices     (paper Fig. 7)
  table3  average parallel efficiency            (paper Table III)
  fig8    heterogeneous load balance             (paper Fig. 8)
  fig10   tile-size sweep                        (paper Fig. 10)
  table4  link model / transfer classes          (paper Table IV)
  table5  communication volume by policy         (paper Table V)
  pallas  TPU tile kernel (interpret) + blocks   (beyond paper)
  context_reuse  warm-context vs per-call H2D    (two-layer API)
  backends       execution backends (numpy/jax/pallas batched dispatch)
  overlap        comm/compute overlap per policy (discrete-event engine)
  autotune       tuned-vs-default config search  (runtime autotuner)
  serving        BlasxServer saturation + tenant isolation (repro.serve)
  pod            3-level cache staged-vs-unstaged on mesh_shard devices

``--quick`` runs the fast deterministic subset (the CI bench-smoke
lane): table1 + backends + overlap + autotune + serving + pod.
"""
from __future__ import annotations

import argparse
import inspect
import json
import platform
import sys
import time

from . import (autotune, backends, bench_context_reuse, fig5_heap,
               fig7_throughput, fig8_load_balance, fig10_tile_size, overlap,
               pallas_kernel, pod, serving, table1_gemm_fraction,
               table4_link_model, table5_comm_volume)
from .common import rows_to_csv

MODULES = [
    ("table1", table1_gemm_fraction),
    ("fig5", fig5_heap),
    ("fig7+table3", fig7_throughput),
    ("fig8", fig8_load_balance),
    ("fig10", fig10_tile_size),
    ("autotune", autotune),
    ("table4", table4_link_model),
    ("table5", table5_comm_volume),
    ("pallas", pallas_kernel),
    ("context_reuse", bench_context_reuse),
    ("backends", backends),
    ("overlap", overlap),
    ("serving", serving),
    ("pod", pod),
]

QUICK_MODULES = [
    ("table1", table1_gemm_fraction),
    ("backends", backends),
    ("overlap", overlap),
    ("autotune", autotune),
    ("serving", serving),
    ("pod", pod),
]


def _call_run(mod, quick: bool):
    """Pass quick= through to modules that understand it."""
    fn = mod.run
    try:
        if "quick" in inspect.signature(fn).parameters:
            return fn(quick=quick)
    except (TypeError, ValueError):  # builtins / odd signatures
        pass
    return fn()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="benchmarks.run",
        description="BLASX-repro benchmark harness")
    ap.add_argument("--quick", action="store_true",
                    help="fast deterministic subset (CI bench-smoke lane)")
    ap.add_argument("--json", metavar="PATH",
                    help="also write the machine-readable report here")
    ap.add_argument("--only", metavar="LABELS",
                    help="comma-separated module labels to run")
    args = ap.parse_args(argv)

    modules = QUICK_MODULES if args.quick else MODULES
    if args.only:
        wanted = {w.strip() for w in args.only.split(",")}
        available = [label for label, _ in modules]
        modules = [(label, m) for label, m in modules if label in wanted]
        missing = wanted - {label for label, _ in modules}
        if missing:
            lane = "--quick lane" if args.quick else "full lane"
            ap.error(f"module labels {sorted(missing)} not in the "
                     f"{lane} (available: {available})")

    report = {
        "schema": 1,
        "quick": bool(args.quick),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "started_unix": time.time(),
        "results": {},
        "errors": {},
    }
    print("name,us_per_call,derived")
    for label, mod in modules:
        t0 = time.time()
        try:
            rows = _call_run(mod, args.quick)
        except Exception as e:  # keep the harness going; surface the error
            print(f"{label}/ERROR,,{e!r}")
            report["errors"][label] = repr(e)
            continue
        print(rows_to_csv(rows))
        report["results"][label] = rows
        print(f"# {label} done in {time.time()-t0:.1f}s", file=sys.stderr)
    report["elapsed_s"] = time.time() - report["started_unix"]

    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=False)
            f.write("\n")
        print(f"# wrote {args.json}", file=sys.stderr)
    return 1 if report["errors"] else 0


if __name__ == "__main__":
    sys.exit(main())
