"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (the harness contract).

  table1  GEMM share of L3 BLAS FLOPs            (paper Table I)
  fig5    BLASX_Malloc vs naive allocator        (paper Fig. 5)
  fig7    throughput + speedup 1/2/3 devices     (paper Fig. 7)
  table3  average parallel efficiency            (paper Table III)
  fig8    heterogeneous load balance             (paper Fig. 8)
  fig10   tile-size sweep                        (paper Fig. 10)
  table4  link model / transfer classes          (paper Table IV)
  table5  communication volume by policy         (paper Table V)
  pallas  TPU tile kernel (interpret) + blocks   (beyond paper)
  context_reuse  warm-context vs per-call H2D    (two-layer API)
"""
from __future__ import annotations

import sys
import time

from . import (bench_context_reuse, fig5_heap, fig7_throughput,
               fig8_load_balance, fig10_tile_size, pallas_kernel,
               table1_gemm_fraction, table4_link_model, table5_comm_volume)
from .common import rows_to_csv

MODULES = [
    ("table1", table1_gemm_fraction),
    ("fig5", fig5_heap),
    ("fig7+table3", fig7_throughput),
    ("fig8", fig8_load_balance),
    ("fig10", fig10_tile_size),
    ("table4", table4_link_model),
    ("table5", table5_comm_volume),
    ("pallas", pallas_kernel),
    ("context_reuse", bench_context_reuse),
]


def main() -> None:
    print("name,us_per_call,derived")
    for label, mod in MODULES:
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception as e:  # keep the harness going; surface the error
            print(f"{label}/ERROR,,{e!r}")
            continue
        print(rows_to_csv(rows))
        print(f"# {label} done in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
