"""Overlap lane: communication/computation overlap per scheduling
policy under the discrete-event engine (paper §IV / Fig. 8).

For each policy (``blasx`` / ``parsec`` / ``static`` [MAGMA-like] /
``cublasxt``) one metadata-scale DGEMM is scheduled twice on the
virtual-clock event engine: once with communication/computation
overlap as the policy defines it, once with overlap forced off
(``RuntimeConfig.overlap_comm=False`` — every batch fully serializes
fetch -> compute -> write-back).  Reported per policy:

* ``comm_fraction``   — Fig. 8 "COMM": unoverlapped communication as a
  share of total device time (sum over devices of
  ``unoverlapped_comm / clock``-weighted);
* ``overlap_efficiency`` — share of modeled link seconds hidden under
  compute (1.0 = fully pipelined);
* ``makespan_on`` / ``makespan_off`` and their ratio — what stream
  overlap is worth end to end.

All metrics are *virtual-clock* derived: deterministic, identical on
every host, so ``benchmarks/compare.py`` gates them tightly.  The two
structural invariants (also enforced by the gate): overlap-on makespan
never exceeds overlap-off, and the cached 4-stream ``blasx`` schedule
has a COMM fraction no worse than the uncached 2-stream ``cublasxt``
one.

**Ragged sub-lane** (Stream-K, arXiv 2301.03598): each shape in
``RAGGED_SHAPES`` — small, ragged, deep-k DGEMMs in the serving
regime where Eq. 2's owner taskization underfills the machine — is
scheduled twice, owner mode vs ``RuntimeConfig.work_centric``, on an
NVLink-class fabric (``RAGGED_BW_SCALE`` x the lane's default link
bandwidth; at PCI-E bandwidth these shapes are link-bound and
splitting the k-loop buys nothing).  Per shape: both makespans, both
overlap efficiencies, and a ``wc_improves`` flag; the
``overlap/ragged_summary`` row's ``work_centric_improves_all`` is a
structural invariant gated by ``benchmarks/compare.py`` — the
work-centric mode must strictly improve *both* metrics on every
ragged shape.

``python -m benchmarks.overlap --trace trace_pr.json`` additionally
runs a small *executing* 2-device DGEMM through a ``BlasxContext``,
exports its Chrome trace, and validates it against the schema — the CI
bench-smoke artifact.  ``--trace-wc PATH`` does the same for a ragged
*work-centric* run and additionally checks the split structure:
partial and fix-up compute spans present, every fix-up starting
at-or-after each of its partials' finish.
"""
from __future__ import annotations

from typing import Dict, List, Optional

# quick: CI smoke scale (the baseline-gated config); full: the paper's
# Fig. 8 scale (N=16384, T=1024)
QUICK_N, QUICK_TILE = 8192, 512
FULL_N, FULL_TILE = 16384, 1024
POLICIES = ("blasx", "parsec", "static", "cublasxt")
SPEEDS = [1.0, 0.8, 1.3]     # fig8's heterogeneous realtime speeds
NOMINAL = [1.0, 1.0, 1.0]

# ragged sub-lane: small deep-k serving shapes whose owner DoP (4
# output tiles at T=512) underfills 3 devices x 4 streams, measured on
# an NVLink-class fabric (see module docstring)
RAGGED_SHAPES = ((576, 4600, 576), (700, 3900, 520), (520, 4100, 640))
RAGGED_TILE = 512
RAGGED_BW_SCALE = 4.0


def _shadow(policy: str, overlap: Optional[bool], n: int, tile: int):
    from repro.core.blas3 import shadow_run
    from repro.core.runtime import BlasxRuntime, RuntimeConfig

    rt = BlasxRuntime(RuntimeConfig(
        n_devices=3, policy=policy, speeds=SPEEDS, nominal_speeds=NOMINAL,
        cache_bytes=2 << 30, mode="sim", execute=False,
        overlap_comm=overlap, record_trace=False))
    shadow_run("gemm", n, tile=tile, runtime=rt)
    return rt


def _shadow_ragged(m: int, k: int, n: int, tile: int, work_centric: bool):
    """One metadata run of a ragged (m, k, n) DGEMM — ``shadow_run`` is
    square-only, so taskize directly over shape-only matrices."""
    from repro.core import task as taskmod
    from repro.core.runtime import BlasxRuntime, RuntimeConfig
    from repro.core.tiling import ShadowMatrix

    base = RuntimeConfig()
    rt = BlasxRuntime(RuntimeConfig(
        n_devices=3, speeds=SPEEDS, nominal_speeds=NOMINAL,
        cache_bytes=2 << 30, mode="sim", execute=False,
        record_trace=False, work_centric=work_centric,
        h2d_bw=base.h2d_bw * RAGGED_BW_SCALE,
        d2d_bw=base.d2d_bw * RAGGED_BW_SCALE))
    mats = {"A": ShadowMatrix("A", m, k, tile),
            "B": ShadowMatrix("B", k, n, tile),
            "C": ShadowMatrix("C", m, n, tile)}
    tasks = taskmod.taskize_gemm(mats["A"].grid, mats["B"].grid,
                                 mats["C"].grid, "N", "N", 1.0, 0.0)
    rt.run(tasks, mats, "C")
    return rt


def _metrics(rt) -> Dict[str, float]:
    unovl = sum(d.ledger.unoverlapped_comm for d in rt.devices)
    comm = sum(d.ledger.comm_time for d in rt.devices)
    clocks = sum(d.clock for d in rt.devices)
    idle = sum(d.ledger.idle_time for d in rt.devices)
    return {
        "makespan": rt.makespan(),
        "comm_fraction": unovl / clocks if clocks else 0.0,
        # same definition (incl. the zero clamp) as the per-device
        # Ledger.overlap_efficiency property, aggregated over devices
        "overlap_efficiency":
            max(0.0, 1.0 - unovl / comm) if comm else 1.0,
        "idle_s": idle,
    }


def run(quick: bool = True) -> List[Dict]:
    n, tile = (QUICK_N, QUICK_TILE) if quick else (FULL_N, FULL_TILE)
    rows: List[Dict] = []
    frac: Dict[str, float] = {}
    ok_flags: List[int] = []
    for policy in POLICIES:
        on = _metrics(_shadow(policy, None, n, tile))
        off = _metrics(_shadow(policy, False, n, tile))
        frac[policy] = on["comm_fraction"]
        # tiny epsilon: on == off when a policy hides nothing anyway
        ok = int(on["makespan"] <= off["makespan"] * (1 + 1e-9))
        ok_flags.append(ok)
        rows.append({
            "name": f"overlap/{policy}",
            "us_per_call": "",
            "n": n, "tile": tile,
            "makespan_on": f"{on['makespan']:.4f}",
            "makespan_off": f"{off['makespan']:.4f}",
            "overlap_speedup": f"{off['makespan'] / on['makespan']:.3f}",
            "comm_fraction": f"{on['comm_fraction']:.4f}",
            "overlap_efficiency": f"{on['overlap_efficiency']:.4f}",
            "idle_s": f"{on['idle_s']:.4f}",
            "overlap_le_off": ok,
        })
    rows.append({
        "name": "overlap/summary",
        "us_per_call": "",
        "overlap_le_off_all": int(all(ok_flags)),
        "blasx_comm_le_cublasxt":
            int(frac["blasx"] <= frac["cublasxt"] * (1 + 1e-9)),
        "blasx_comm_fraction": f"{frac['blasx']:.4f}",
        "cublasxt_comm_fraction": f"{frac['cublasxt']:.4f}",
    })
    # ragged sub-lane: owner vs work-centric on each serving shape
    wc_flags: List[int] = []
    for m, k, nn in RAGGED_SHAPES:
        owner = _metrics(_shadow_ragged(m, k, nn, RAGGED_TILE, False))
        wc = _metrics(_shadow_ragged(m, k, nn, RAGGED_TILE, True))
        improves = int(
            wc["makespan"] < owner["makespan"]
            and wc["overlap_efficiency"] > owner["overlap_efficiency"])
        wc_flags.append(improves)
        rows.append({
            "name": f"overlap/ragged_{m}x{k}x{nn}",
            "us_per_call": "",
            "tile": RAGGED_TILE,
            "makespan_owner": f"{owner['makespan']:.4f}",
            "makespan_wc": f"{wc['makespan']:.4f}",
            "wc_speedup": f"{owner['makespan'] / wc['makespan']:.3f}",
            "efficiency_owner": f"{owner['overlap_efficiency']:.4f}",
            "efficiency_wc": f"{wc['overlap_efficiency']:.4f}",
            "wc_improves": improves,
        })
    rows.append({
        "name": "overlap/ragged_summary",
        "us_per_call": "",
        "work_centric_improves_all": int(all(wc_flags)),
    })
    return rows


def export_trace(path: str) -> dict:
    """CI artifact: an *executing* 2-device DGEMM traced end to end,
    validated against the event-engine schema before being returned."""
    import numpy as np

    from repro.api import BlasxContext
    from repro.core.events import max_concurrent, validate_trace
    from repro.core.runtime import RuntimeConfig

    rng = np.random.default_rng(0)
    A = rng.standard_normal((1024, 1024))
    B = rng.standard_normal((1024, 1024))
    with BlasxContext(RuntimeConfig(n_devices=2, mode="sim"),
                      tile=128) as ctx:
        Ah, Bh = ctx.tile(A), ctx.tile(B)
        ctx.gemm(Ah, Bh)   # cold pass: H2D-dominated timeline
        ctx.gemm(Ah, Bh)   # warm pass: full n-stream compute overlap
        tr = ctx.trace(path)
    summary = validate_trace(tr)
    conc = {dev: max_concurrent(tr, device=dev) for dev in range(2)}
    print(f"# trace: {summary['spans']} spans, peak concurrent "
          f"compute per device {conc} -> {path}")
    return tr


def export_trace_wc(path: str) -> dict:
    """CI artifact: an *executing* ragged work-centric DGEMM traced end
    to end.  Beyond the event-engine schema gate this validates the
    Stream-K structure itself: partial and fix-up compute spans are
    present, and every fix-up reduction starts at-or-after each of its
    sibling partials' finish (the deterministic join order)."""
    import numpy as np

    from repro.api import BlasxContext
    from repro.core.events import trace_spans, validate_trace
    from repro.core.runtime import RuntimeConfig

    rng = np.random.default_rng(0)
    A = rng.standard_normal((1100, 900))
    B = rng.standard_normal((900, 700))
    with BlasxContext(RuntimeConfig(n_devices=2, mode="sim",
                                    work_centric=True),
                      tile=512) as ctx:
        out = ctx.gemm(A, B)
        ref = A @ B
        np.testing.assert_allclose(out.array(), ref, rtol=1e-10,
                                   atol=1e-10)
        tr = ctx.trace(path)
    summary = validate_trace(tr)
    compute = [s for s in trace_spans(tr) if s["cat"] == "compute"]
    partials = [s for s in compute if s["kind"] == "partial"]
    fixups = {s["task_id"]: s for s in compute if s["kind"] == "fixup"}
    if not partials or not fixups:
        raise ValueError(
            f"work-centric trace lacks split spans: "
            f"{len(partials)} partial / {len(fixups)} fixup")
    for p in partials:
        f = fixups.get(p["parent"])
        if f is None:
            raise ValueError(f"partial task {p['task_id']} has no "
                             f"fix-up span (parent {p['parent']})")
        if f["start"] < p["end"] - 1e-9:
            raise ValueError(
                f"fix-up {f['task_id']} starts at {f['start']} before "
                f"its partial {p['task_id']} ends at {p['end']}")
    print(f"# wc trace: {summary['spans']} spans, "
          f"{len(partials)} partials joined by {len(fixups)} fix-ups "
          f"-> {path}")
    return tr


def main(argv=None) -> int:
    import argparse

    from .common import rows_to_csv

    ap = argparse.ArgumentParser(
        prog="benchmarks.overlap",
        description="overlap lane + Chrome-trace artifact")
    ap.add_argument("--trace", metavar="PATH",
                    help="export + validate the 2-device DGEMM trace "
                         "INSTEAD of running the lane (the CI artifact "
                         "step; the lane itself already ran via "
                         "benchmarks.run --quick)")
    ap.add_argument("--trace-wc", metavar="PATH",
                    help="export + validate an executing work-centric "
                         "ragged DGEMM trace, including the Stream-K "
                         "structural checks (partial/fix-up spans, "
                         "join ordering) — the CI artifact step")
    ap.add_argument("--validate", metavar="PATH",
                    help="round-trip an exported trace file through the "
                         "schema validator and exit non-zero on "
                         "violations (the CI gate step)")
    args = ap.parse_args(argv)
    if not args.trace and not args.trace_wc and not args.validate:
        print(rows_to_csv(run()))
    if args.trace:
        export_trace(args.trace)
    if args.trace_wc:
        export_trace_wc(args.trace_wc)
    if args.validate:
        from repro.core.events import main as validate_main
        return validate_main([args.validate])
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
