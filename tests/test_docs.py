"""Doc-drift guard: everything README/docs name must actually exist.

Docs rot silently — a renamed flag, a dropped env var or a moved
public symbol leaves the guide describing a repo that no longer
exists.  This suite walks ``README.md`` + ``docs/*.md`` and checks,
against the real code:

* every ``BLASX_*`` environment variable is consumed somewhere in
  ``src/`` or ``benchmarks/``;
* every ``--flag`` shown next to one of the repo's own runnables is
  registered by that runnable's argparse (introspected via
  ``main(["--help"])``);
* every dotted ``repro.*`` path resolves by import + getattr;
* every ``cblas_*`` name is exported by ``repro.api``;
* every ``ctx.<method>`` / ``srv.<method>`` reference is an attribute
  of ``BlasxContext`` / ``BlasxServer``;
* the markdown link checker (``tools/check_links.py``, the CI lint
  step) passes — and still fails on actually-broken links.
"""
import contextlib
import importlib
import io
import re
import subprocess
import sys
from pathlib import Path

import pytest

REPO_ROOT = Path(__file__).resolve().parent.parent
DOC_FILES = [REPO_ROOT / "README.md"] + sorted(
    (REPO_ROOT / "docs").glob("*.md"))


def _doc_text():
    return {p: p.read_text(encoding="utf-8") for p in DOC_FILES}


def _source_text():
    chunks = []
    for root in ("src", "benchmarks"):
        for p in sorted((REPO_ROOT / root).rglob("*.py")):
            chunks.append(p.read_text(encoding="utf-8"))
    return "\n".join(chunks)


def test_required_docs_exist():
    for name in ("ARCHITECTURE.md", "TUNING.md", "BENCHMARKS.md"):
        assert (REPO_ROOT / "docs" / name).exists(), f"docs/{name} missing"


def test_env_vars_in_docs_exist_in_code():
    # BLASX_Malloc (the allocator's name) must not read as an env var,
    # hence the no-lowercase-following lookahead
    pat = re.compile(r"BLASX_[A-Z_]{2,}(?![a-z])")
    source = _source_text()
    seen = set()
    for path, text in _doc_text().items():
        for var in pat.findall(text):
            seen.add(var)
            assert var in source, (
                f"{path.name} documents env var {var} but nothing under "
                f"src/ or benchmarks/ mentions it")
    assert "BLASX_TUNING_CACHE" in seen  # the guide must cover it


# the repo's own runnables, as they appear on doc command lines
_RUNNABLES = {
    "benchmarks.run": "benchmarks.run",
    "benchmarks/run.py": "benchmarks.run",
    "compare.py": "benchmarks.compare",
    "benchmarks.overlap": "benchmarks.overlap",
    "benchmarks.pod": "benchmarks.pod",
    "repro.serve": "repro.serve.__main__",
    "repro.analysis": "repro.analysis",
}


def _argparse_flags(module_name):
    """The --flags a module's main() registers, via --help output."""
    mod = importlib.import_module(module_name)
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf), pytest.raises(SystemExit):
        mod.main(["--help"])
    return set(re.findall(r"--[A-Za-z][A-Za-z0-9-]*", buf.getvalue()))


def test_cli_flags_in_docs_exist():
    flag_re = re.compile(r"(?<![\w-])--[a-z][a-z0-9-]*")
    flags_cache = {}
    checked = 0
    for path, text in _doc_text().items():
        # join backslash continuations so a wrapped command line keeps
        # its runnable token next to its flags
        joined = re.sub(r"\\\n\s*", " ", text)
        for lineno, line in enumerate(joined.splitlines(), 1):
            mods = [m for tok, m in _RUNNABLES.items() if tok in line]
            if not mods:
                continue
            for flag in flag_re.findall(line):
                ok = False
                for module_name in mods:
                    if module_name not in flags_cache:
                        flags_cache[module_name] = _argparse_flags(module_name)
                    ok = ok or flag in flags_cache[module_name]
                assert ok, (
                    f"{path.name}:{lineno} shows flag {flag} for "
                    f"{mods}, but no such argparse option exists")
                checked += 1
    assert checked >= 5  # the docs do show flags; silence = regex rot


def _resolve(dotted):
    parts = dotted.split(".")
    for i in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:i]))
        except ImportError:
            continue
        for attr in parts[i:]:
            obj = getattr(obj, attr)
        return obj
    raise ImportError(dotted)


def test_dotted_repro_paths_resolve():
    pat = re.compile(r"\brepro\.[a-z_][A-Za-z0-9_.]*")
    seen = set()
    for path, text in _doc_text().items():
        for dotted in pat.findall(text):
            dotted = dotted.rstrip(".")
            if dotted in seen:
                continue
            seen.add(dotted)
            try:
                _resolve(dotted)
            except (ImportError, AttributeError) as e:
                pytest.fail(f"{path.name} references {dotted}, which does "
                            f"not resolve: {e}")
    assert len(seen) >= 10


def test_cblas_names_exported():
    api = importlib.import_module("repro.api")
    seen = 0
    for path, text in _doc_text().items():
        for name in set(re.findall(r"\bcblas_[a-z0-9]+\b", text)):
            assert hasattr(api, name), (
                f"{path.name} documents {name}; repro.api does not export it")
            seen += 1
    assert seen >= 12  # both precision families are documented


def test_context_and_server_methods_exist():
    from repro.api import BlasxContext
    from repro.serve import BlasxServer

    for var, cls in (("ctx", BlasxContext), ("srv", BlasxServer)):
        pat = re.compile(rf"\b{var}\.([A-Za-z_][A-Za-z0-9_]*)")
        for path, text in _doc_text().items():
            for attr in set(pat.findall(text)):
                assert hasattr(cls, attr), (
                    f"{path.name} references {var}.{attr}; "
                    f"{cls.__name__} has no such attribute")


def _run_checker(*args):
    return subprocess.run(
        [sys.executable, str(REPO_ROOT / "tools" / "check_links.py"),
         *args],
        capture_output=True, text=True, cwd=str(REPO_ROOT))


def test_markdown_links_are_green():
    proc = _run_checker()
    assert proc.returncode == 0, (
        f"tools/check_links.py failed:\n{proc.stdout}{proc.stderr}")
    assert "0 hard failures" in proc.stdout


def test_link_checker_catches_breakage(tmp_path):
    bad = tmp_path / "bad.md"
    bad.write_text("# Title\n\n[a](#title)\n[b](#no-such)\n[c](gone.md)\n"
                   "```\n[fenced links are ignored](also-gone.md)\n```\n",
                   encoding="utf-8")
    proc = _run_checker(str(bad))
    assert proc.returncode == 1
    assert "broken anchor" in proc.stdout
    assert "broken link" in proc.stdout
    assert "also-gone.md" not in proc.stdout
