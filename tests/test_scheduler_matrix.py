"""Scheduler-policy invariant matrix: for every policy x execution
mode, the knobs the policy claims to disable really stay off (no
steals without stealing, no P2P traffic without the L2 cache, 2
streams under cublasxt) and static splits cover every task exactly
once."""
import itertools

import numpy as np
import pytest

from repro.core import blas3
from repro.core import task as taskmod
from repro.core.runtime import BlasxRuntime, RuntimeConfig
from repro.core.tiling import TileGrid

POLICIES = ("blasx", "parsec", "cublasxt", "static", "supermatrix")
MODES = ("sim", "threads")

RNG = np.random.default_rng(3)
N, TILE = 768, 128


@pytest.mark.parametrize("policy,mode",
                         list(itertools.product(POLICIES, MODES)))
def test_policy_invariant_matrix(policy, mode):
    A = RNG.standard_normal((N, N))
    B = RNG.standard_normal((N, N))
    rt = BlasxRuntime(RuntimeConfig(
        n_devices=3, mode=mode, policy=policy, cache_bytes=32 << 20))
    out = blas3.gemm(A, B, tile=TILE, runtime=rt)
    np.testing.assert_allclose(out, A @ B, rtol=1e-10, atol=1e-10)
    cfg = rt.cfg
    ledgers = [d.ledger for d in rt.devices]
    # every scheduled task ran exactly once
    n_tiles = (N // TILE) ** 2
    assert sum(led.tasks for led in ledgers) == n_tiles
    # stealing really off: zero steal events across the session
    if not cfg.use_stealing:
        assert sum(led.steals for led in ledgers) == 0
    # L2 really off: no P2P ledger traffic anywhere
    if not cfg.use_l2:
        assert sum(led.d2d_bytes for led in ledgers) == 0
        assert all(led.d2d_busy_s == 0.0 for led in ledgers)
    # cublasxt runs 2 streams; everything else the configured width
    if policy == "cublasxt":
        assert cfg.effective_streams == 2
    else:
        assert cfg.effective_streams == cfg.n_streams
    # overlap is a policy property: only supermatrix forks-and-joins
    assert cfg.overlap == (policy != "supermatrix")


@pytest.mark.parametrize("policy", ["cublasxt", "static"])
def test_static_assignment_buckets_cover_every_task_exactly_once(policy):
    ga = TileGrid("A", N, N, TILE)
    gb = TileGrid("B", N, N, TILE)
    gc = TileGrid("C", N, N, TILE)
    tasks = taskmod.taskize_gemm(ga, gb, gc, "N", "N", 1.0, 0.0)
    rt = BlasxRuntime(RuntimeConfig(
        n_devices=3, mode="sim", policy=policy,
        speeds=[1.0, 0.5, 2.0], nominal_speeds=[1.0, 0.5, 2.0]))
    queues = rt._static_split(tasks)
    assert len(queues) == 3
    buckets = [set(q._tasks.keys()) for q in queues]
    all_ids = {t.task_id for t in tasks}
    # disjoint cover: every task in exactly one bucket
    assert set().union(*buckets) == all_ids
    assert sum(len(b) for b in buckets) == len(all_ids)
    if policy == "static":
        # speed-proportional split gives the 2.0x device the most work
        sizes = [len(b) for b in buckets]
        assert sizes[2] == max(sizes) and sizes[1] == min(sizes)
    else:
        # round robin: device d owns tasks with id % 3 == d
        for dev, bucket in enumerate(buckets):
            assert all(tid % 3 == dev for tid in bucket)


def _traced_policy_run(policy):
    """Two passes over persistent handles: the warm second pass is
    where stream concurrency peaks (no fetch stagger)."""
    from repro.api import BlasxContext

    A = RNG.standard_normal((1024, 1024))
    with BlasxContext(RuntimeConfig(n_devices=2, mode="sim",
                                    policy=policy), tile=128) as ctx:
        Ah = ctx.tile(A)
        ctx.gemm(Ah, Ah)
        ctx.gemm(Ah, Ah)
        return ctx.trace(), ctx.cfg


def test_cublasxt_trace_shows_at_most_two_concurrent_computes():
    """The 2-stream cap is visible in the schedule itself, not just
    the config property."""
    from repro.core.events import max_concurrent, validate_trace

    tr, _ = _traced_policy_run("cublasxt")
    validate_trace(tr)
    for dev in range(2):
        assert max_concurrent(tr, device=dev) <= 2


def test_blasx_trace_reaches_full_stream_width():
    from repro.core.events import max_concurrent, validate_trace

    tr, cfg = _traced_policy_run("blasx")
    validate_trace(tr)
    assert max(max_concurrent(tr, device=d) for d in range(2)) \
        >= cfg.n_streams
