"""Correctness of the tiled L3 BLAS routines against pure-numpy oracles,
across policies, modes, tile sizes, transposes, uplo/side/diag."""
import numpy as np
import pytest

from repro.core import (gemm, ref_gemm, ref_symm, ref_syr2k, ref_syrk,
                        ref_trmm, ref_trsm, symm, syr2k, syrk, trmm, trsm)
from repro.core.runtime import RuntimeConfig

RNG = np.random.default_rng(42)
TOL = dict(rtol=1e-10, atol=1e-10)


def cfg(**kw):
    kw.setdefault("n_devices", 2)
    kw.setdefault("mode", "sim")
    kw.setdefault("cache_bytes", 32 << 20)
    return RuntimeConfig(**kw)


# ------------------------------------------------------------------- GEMM
@pytest.mark.parametrize("transa", ["N", "T"])
@pytest.mark.parametrize("transb", ["N", "T"])
def test_gemm_transposes(transa, transb):
    m, k, n = 130, 70, 95
    A = RNG.standard_normal((m, k) if transa == "N" else (k, m))
    B = RNG.standard_normal((k, n) if transb == "N" else (n, k))
    C = RNG.standard_normal((m, n))
    out = gemm(A, B, C, alpha=1.3, beta=-0.4, transa=transa, transb=transb,
               tile=48, config=cfg())
    ref = ref_gemm(A, B, C, alpha=1.3, beta=-0.4, transa=transa, transb=transb)
    np.testing.assert_allclose(out, ref, **TOL)


@pytest.mark.parametrize("tile", [17, 64, 128, 300])
def test_gemm_tile_sizes(tile):
    A = RNG.standard_normal((257, 129))
    B = RNG.standard_normal((129, 200))
    out = gemm(A, B, tile=tile, config=cfg())
    np.testing.assert_allclose(out, A @ B, **TOL)


@pytest.mark.parametrize("policy",
                         ["blasx", "parsec", "cublasxt", "static",
                          "supermatrix"])
def test_gemm_all_policies(policy):
    A = RNG.standard_normal((200, 150))
    B = RNG.standard_normal((150, 180))
    C = RNG.standard_normal((200, 180))
    out = gemm(A, B, C, alpha=0.9, beta=1.7, tile=64,
               config=cfg(n_devices=3, policy=policy))
    np.testing.assert_allclose(out, ref_gemm(A, B, C, alpha=0.9, beta=1.7),
                               **TOL)


def test_gemm_threads_mode():
    A = RNG.standard_normal((256, 256))
    B = RNG.standard_normal((256, 256))
    out = gemm(A, B, tile=64, config=cfg(n_devices=4, mode="threads"))
    np.testing.assert_allclose(out, A @ B, **TOL)


def test_gemm_beta_zero_no_c():
    A = RNG.standard_normal((64, 32))
    B = RNG.standard_normal((32, 48))
    out = gemm(A, B, tile=32)
    np.testing.assert_allclose(out, A @ B, **TOL)


def test_gemm_single_tile():
    A = RNG.standard_normal((30, 20))
    B = RNG.standard_normal((20, 25))
    out = gemm(A, B, tile=512)
    np.testing.assert_allclose(out, A @ B, **TOL)


def test_gemm_shape_errors():
    with pytest.raises(ValueError):
        gemm(np.zeros((3, 4)), np.zeros((5, 6)))
    with pytest.raises(ValueError):
        gemm(np.zeros((3, 4)), np.zeros((4, 6)), beta=1.0)  # needs C


# ------------------------------------------------------------- SYRK/SYR2K
@pytest.mark.parametrize("uplo", ["U", "L"])
@pytest.mark.parametrize("trans", ["N", "T"])
def test_syrk(uplo, trans):
    n, k = 150, 90
    A = RNG.standard_normal((n, k) if trans == "N" else (k, n))
    C = RNG.standard_normal((n, n))
    out = syrk(A, C, alpha=0.7, beta=1.2, uplo=uplo, trans=trans, tile=64,
               config=cfg())
    ref = ref_syrk(A, C, alpha=0.7, beta=1.2, uplo=uplo, trans=trans)
    np.testing.assert_allclose(out, ref, **TOL)


@pytest.mark.parametrize("uplo", ["U", "L"])
@pytest.mark.parametrize("trans", ["N", "T"])
def test_syr2k(uplo, trans):
    n, k = 140, 80
    A = RNG.standard_normal((n, k) if trans == "N" else (k, n))
    B = RNG.standard_normal((n, k) if trans == "N" else (k, n))
    C = RNG.standard_normal((n, n))
    out = syr2k(A, B, C, alpha=0.6, beta=0.8, uplo=uplo, trans=trans,
                tile=48, config=cfg())
    ref = ref_syr2k(A, B, C, alpha=0.6, beta=0.8, uplo=uplo, trans=trans)
    np.testing.assert_allclose(out, ref, **TOL)


def test_syrk_preserves_other_triangle():
    n, k = 100, 50
    A = RNG.standard_normal((n, k))
    C = RNG.standard_normal((n, n))
    out = syrk(A, C, alpha=1.0, beta=0.0, uplo="U", tile=32)
    # strictly-lower triangle must be untouched original C
    low = np.tril_indices(n, -1)
    np.testing.assert_array_equal(out[low], C[low])


# ------------------------------------------------------------------- SYMM
@pytest.mark.parametrize("side", ["L", "R"])
@pytest.mark.parametrize("uplo", ["U", "L"])
def test_symm(side, uplo):
    m, n = 120, 90
    B = RNG.standard_normal((m, n))
    dim = m if side == "L" else n
    A = RNG.standard_normal((dim, dim))
    C = RNG.standard_normal((m, n))
    out = symm(A, B, C, alpha=1.4, beta=-0.2, side=side, uplo=uplo, tile=40,
               config=cfg())
    ref = ref_symm(A, B, C, alpha=1.4, beta=-0.2, side=side, uplo=uplo)
    np.testing.assert_allclose(out, ref, **TOL)


# ------------------------------------------------------------------- TRMM
@pytest.mark.parametrize("side", ["L", "R"])
@pytest.mark.parametrize("uplo", ["U", "L"])
@pytest.mark.parametrize("transa", ["N", "T"])
@pytest.mark.parametrize("diag", ["N", "U"])
def test_trmm(side, uplo, transa, diag):
    m, n = 110, 70
    B = RNG.standard_normal((m, n))
    dim = m if side == "L" else n
    A = RNG.standard_normal((dim, dim))
    out = trmm(A, B, alpha=0.9, side=side, uplo=uplo, transa=transa,
               diag=diag, tile=48, config=cfg())
    ref = ref_trmm(A, B, alpha=0.9, side=side, uplo=uplo, transa=transa,
                   diag=diag)
    np.testing.assert_allclose(out, ref, **TOL)


# ------------------------------------------------------------------- TRSM
@pytest.mark.parametrize("side", ["L", "R"])
@pytest.mark.parametrize("uplo", ["U", "L"])
@pytest.mark.parametrize("transa", ["N", "T"])
@pytest.mark.parametrize("diag", ["N", "U"])
def test_trsm(side, uplo, transa, diag):
    m, n = 100, 60
    B = RNG.standard_normal((m, n))
    dim = m if side == "L" else n
    # well conditioned for BOTH diag modes: small off-diagonal (unit-
    # triangular solves grow with prod(1+|a_ij|)), dominant diagonal
    A = RNG.standard_normal((dim, dim)) / dim + np.eye(dim)
    out = trsm(A, B, alpha=1.1, side=side, uplo=uplo, transa=transa,
               diag=diag, tile=32, config=cfg())
    ref = ref_trsm(A, B, alpha=1.1, side=side, uplo=uplo, transa=transa,
                   diag=diag)
    np.testing.assert_allclose(out, ref, rtol=1e-8, atol=1e-8)


def test_trsm_residual():
    """A @ X == alpha * B (solve property, independent of the oracle)."""
    m, n = 96, 40
    A = RNG.standard_normal((m, m)) + m * np.eye(m)
    B = RNG.standard_normal((m, n))
    X = trsm(A, B, alpha=2.0, uplo="U", tile=32,
             config=cfg(n_devices=3))
    np.testing.assert_allclose(np.triu(A) @ X, 2.0 * B, rtol=1e-8, atol=1e-8)


@pytest.mark.parametrize("policy", ["blasx", "static", "cublasxt"])
def test_trsm_dependency_chain_across_policies(policy):
    m, n = 128, 64
    A = RNG.standard_normal((m, m)) + m * np.eye(m)
    B = RNG.standard_normal((m, n))
    out = trsm(A, B, uplo="L", tile=32,
               config=cfg(n_devices=3, policy=policy))
    np.testing.assert_allclose(out, ref_trsm(A, B, uplo="L"),
                               rtol=1e-8, atol=1e-8)


# ------------------------------------------------------------ JAX kernel
def test_gemm_jax_tile_kernel():
    A = RNG.standard_normal((96, 64)).astype(np.float32)
    B = RNG.standard_normal((64, 80)).astype(np.float32)
    out = gemm(A, B, tile=32, config=cfg(kernel="jax"))
    np.testing.assert_allclose(out, A @ B, rtol=1e-4, atol=1e-4)
