"""System-behaviour tests for the BLASX runtime: tile caches, coherence,
scheduling, communication ledger, heap — the paper's §IV mechanisms."""
import numpy as np
import pytest

from repro.core import gemm
from repro.core.alru import Alru
from repro.core.coherence import MesixDirectory
from repro.core.heap import BlasxHeap, HeapError
from repro.core.runtime import BlasxRuntime, RuntimeConfig
from repro.core.tiling import TiledMatrix, TileGrid, TileKey, degree_of_parallelism

RNG = np.random.default_rng(7)


# ------------------------------------------------------------------ tiling
def test_tile_grid_counts_and_ragged_edges():
    g = TileGrid("A", 100, 70, 32)
    assert (g.n_tile_rows, g.n_tile_cols) == (4, 3)
    assert g.tile_shape(0, 0) == (32, 32)
    assert g.tile_shape(3, 2) == (4, 6)   # ragged corner
    assert degree_of_parallelism(100, 70, 32) == 12  # paper Eq. 2


def test_tiled_matrix_roundtrip():
    data = RNG.standard_normal((90, 50))
    tm = TiledMatrix("A", data.copy(), 32)
    t = tm.read_tile(2, 1)
    tm.write_tile(2, 1, t * 2)
    assert np.allclose(tm.data[64:90, 32:50], data[64:90, 32:50] * 2)


# -------------------------------------------------------------------- heap
def test_heap_alloc_free_coalesce():
    h = BlasxHeap(1000)
    a = h.malloc(100)
    b = h.malloc(200)
    c = h.malloc(300)
    assert (a, b, c) == (0, 100, 300)
    h.free(b)
    h.check_invariants()
    # freeing a and c coalesces everything back into one segment
    h.free(a)
    h.free(c)
    h.check_invariants()
    assert h.free_bytes == 1000
    d = h.malloc(1000)  # full arena available again
    assert d == 0


def test_heap_first_fit_reuse():
    h = BlasxHeap(1000)
    a = h.malloc(400)
    h.malloc(400)
    h.free(a)
    # first fit places the new 300 into the freed hole at offset 0
    assert h.malloc(300) == 0
    h.check_invariants()


def test_heap_exhaustion_and_errors():
    h = BlasxHeap(100)
    assert h.malloc(60) == 0
    assert h.malloc(60) is None  # not enough contiguous room
    with pytest.raises(HeapError):
        h.free(999)


# -------------------------------------------------------------------- ALRU
def _alru(capacity=1000):
    heap = BlasxHeap(capacity)
    a = Alru(0, heap)
    a.on_evict = lambda dev, key: None
    return a


def test_alru_hit_miss_and_eviction_order():
    a = _alru(300)
    k1, k2, k3, k4 = (TileKey("A", 0, i) for i in range(4))
    assert getattr(a.translate(k1, 100), "fresh", False)
    assert getattr(a.translate(k2, 100), "fresh", False)
    assert getattr(a.translate(k3, 100), "fresh", False)
    for k in (k1, k2, k3):
        a.release(k)
    # touch k1 so k2 becomes LRU
    a.translate(k1, 100)
    a.release(k1)
    a.translate(k4, 100)  # forces eviction of k2 (LRU with reader==0)
    assert k2 not in a and k1 in a and k3 in a
    a.check_invariants()


def test_alru_skips_pinned_blocks():
    """The A in ALRU: blocks with readers are never evicted (Alg. 2)."""
    a = _alru(200)
    k1, k2, k3 = (TileKey("A", 1, i) for i in range(3))
    a.translate(k1, 100)            # reader = 1, pinned
    a.translate(k2, 100)
    a.release(k2)                   # k2 evictable, k1 pinned & older
    a.translate(k3, 100)            # must evict k2, not the LRU k1
    assert k1 in a and k2 not in a and k3 in a


def test_alru_all_pinned_returns_none():
    a = _alru(200)
    a.translate(TileKey("A", 2, 0), 100)
    a.translate(TileKey("A", 2, 1), 100)
    assert a.translate(TileKey("A", 2, 2), 100) is None  # caller must sync


# ----------------------------------------------------------------- MESI-X
def test_mesix_state_transitions():
    d = MesixDirectory(3, [[0, 1, 2]])
    key = TileKey("A", 0, 0)
    assert d.state(key) == "I"
    d.on_fill(key, 0)
    assert d.state(key) == "E"
    d.on_fill(key, 1)
    assert d.state(key) == "S"
    d.on_evict(key, 0)
    assert d.state(key) == "E"
    d.on_evict(key, 1)
    assert d.state(key) == "I"


def test_mesix_write_is_ephemeral_m_to_i():
    d = MesixDirectory(2, [[0, 1]])
    key = TileKey("C", 3, 3)
    d.on_fill(key, 0)
    d.on_fill(key, 1)
    holders = d.on_write(key, 0)
    assert sorted(holders) == [0, 1]
    assert d.state(key) == "I"  # M never observable at rest
    assert d.writebacks == 1


def test_mesix_peer_holder_respects_p2p_groups():
    # paper Everest: only GPU 1 and 2 share a switch
    d = MesixDirectory(3, [[0], [1, 2]])
    key = TileKey("B", 0, 0)
    d.on_fill(key, 1)
    assert d.peer_holder(key, 2) == 1   # same switch: L2 hit
    assert d.peer_holder(key, 0) is None  # cross-switch: no P2P
    assert d.peer_holder(key, 1) is None  # self is not a peer


# ------------------------------------------------- runtime system behaviour
def _run_gemm(policy, n_devices=3, n=1024, tile=128, **kw):
    A = RNG.standard_normal((n, n))
    B = RNG.standard_normal((n, n))
    cfg = RuntimeConfig(n_devices=n_devices, mode="sim", policy=policy,
                        cache_bytes=kw.pop("cache_bytes", 32 << 20), **kw)
    rt = BlasxRuntime(cfg)
    out = gemm(A, B, tile=tile, runtime=rt)
    np.testing.assert_allclose(out, A @ B, rtol=1e-10, atol=1e-10)
    return rt


def test_tile_cache_cuts_communication_volume():
    """Paper Table V: cuBLAS-XT's on-demand transfers move ~3x the bytes
    of BLASX's cached engine."""
    rt_blasx = _run_gemm("blasx")
    rt_xt = _run_gemm("cublasxt")
    h2d_blasx = rt_blasx.total_comm_bytes()["h2d"] + \
        rt_blasx.total_comm_bytes()["d2d"]
    h2d_xt = rt_xt.total_comm_bytes()["h2d"]
    assert h2d_xt > 2.0 * h2d_blasx


def test_l2_cache_converts_h2d_to_d2d():
    """Paper §V: the L2 tile cache serves misses from peer devices."""
    rt = _run_gemm("blasx")
    comm = rt.total_comm_bytes()
    assert comm["d2d"] > 0
    rt_l1only = _run_gemm("parsec")
    assert rt_l1only.total_comm_bytes()["d2d"] == 0
    # total input traffic with L2 <= L1-only traffic
    assert comm["h2d"] + comm["d2d"] <= \
        rt_l1only.total_comm_bytes()["h2d"] * 1.05


def test_p2p_disabled_across_groups():
    rt = BlasxRuntime(RuntimeConfig(n_devices=2, mode="sim", policy="blasx",
                                    p2p_groups=[[0], [1]],
                                    cache_bytes=32 << 20))
    A = RNG.standard_normal((512, 512))
    B = RNG.standard_normal((512, 512))
    gemm(A, B, tile=128, runtime=rt)
    assert rt.total_comm_bytes()["d2d"] == 0


def test_d2d_serve_load_spreads_across_holders():
    """Regression (LRU peer rotation): a tile cached on three devices
    used to be served by the lowest id on EVERY L2 hit, draining one
    D2D egress lane.  On a 4-device shared-tile workload the serve
    seconds must now spread evenly across all holders."""
    from repro.core.task import TileRef
    from repro.core.tiling import ShadowMatrix

    cfg = RuntimeConfig(n_devices=4, mode="sim", policy="blasx",
                        cache_bytes=32 << 20, execute=False,
                        record_trace=False)
    rt = BlasxRuntime(cfg)
    mats = {"A": ShadowMatrix("A", 256, 256, 256)}
    rt._matrices = mats
    key = TileKey("A", 0, 0)
    for dev in (0, 1, 2):              # three peers hold the hot tile
        rt.devices[dev].store[key] = np.empty(0)
        rt.directory.on_fill(key, dev)
    ref = TileRef(key)
    requester = rt.devices[3]
    for _ in range(30):                # 30 cold fetches of the shared tile
        acquired, xfers = [], []
        rt._acquire(requester, ref, acquired, xfers)
        assert [x.kind for x in xfers] == ["d2d"]
        assert xfers[0].src in (0, 1, 2)
        for k in acquired:
            requester.alru.release(k)
        # evict so the next fetch misses L1 again
        rt.directory.on_evict(key, 3)
        requester.alru.invalidate(key)
        requester.store.pop(key, None)
    served = [d.ledger.d2d_served_s for d in rt.devices]
    assert served[3] == 0.0            # the requester never serves itself
    assert sum(served) > 0
    # skew collapses: each of the three holders serves exactly a third
    assert served[0] == pytest.approx(served[1], rel=1e-12)
    assert served[1] == pytest.approx(served[2], rel=1e-12)


def test_d2d_served_seconds_balance_requester_charge():
    """System invariant: egress serve seconds across devices equal the
    total modeled d2d wire time charged to requesters."""
    rt = _run_gemm("blasx", n_devices=4, n=1024, tile=128)
    comm = rt.total_comm_bytes()
    assert comm["d2d"] > 0
    total_served = sum(d.ledger.d2d_served_s for d in rt.devices)
    assert total_served == pytest.approx(comm["d2d"] / rt.cfg.d2d_bw,
                                         rel=1e-9)


def test_demand_driven_balances_heterogeneous_devices():
    """Paper Fig. 8 / §IV-C: a static scheduler plans with *nominal*
    speeds; when realtime speeds deviate (kernel saturation, workload
    variation) its devices finish far apart.  Demand-driven BLASX tracks
    realtime speed and keeps the finish-time spread tight."""
    A = RNG.standard_normal((2048, 2048))
    B = RNG.standard_normal((2048, 2048))
    speeds = [1.0, 0.25, 2.0]          # realtime
    nominal = [1.0, 1.0, 1.0]          # what the static planner believes

    def spread(policy):
        # compute-bound regime (10x host link): load balance is what
        # this test measures.  At the paper's PCI-E bandwidth this
        # small workload is link-bound and the discrete-event engine
        # correctly pins every device's finish time to the shared
        # host-link drain — masking the compute imbalance under test.
        rt = BlasxRuntime(RuntimeConfig(
            n_devices=3, mode="sim", policy=policy, speeds=speeds,
            nominal_speeds=nominal, cache_bytes=64 << 20,
            h2d_bw=6.54e10))
        gemm(A, B, tile=256, runtime=rt)
        clocks = [d.clock for d in rt.devices]
        return (max(clocks) - min(clocks)) / max(clocks)

    s_blasx, s_static = spread("blasx"), spread("static")
    assert s_blasx < 0.25
    assert s_static > 2 * s_blasx


def test_work_stealing_happens_when_queue_drains():
    # compute-bound setting (fast links) so the 8x faster device drains
    # its RS, finds the queue empty, and must steal from peers' RSs
    rt = _run_gemm("blasx", n_devices=3, n=2048, tile=256,
                   speeds=[1.0, 1.0, 8.0], h2d_bw=1e12, d2d_bw=1e12)
    assert sum(d.ledger.steals for d in rt.devices) > 0
    # and the fast device consumed the lion's share of tasks
    assert rt.devices[2].ledger.tasks > rt.devices[0].ledger.tasks


def test_every_device_contributes():
    rt = _run_gemm("blasx", n_devices=4, n=1024, tile=128)
    for d in rt.devices:
        assert d.ledger.tasks > 0


def test_writeback_volume_matches_output_size():
    """MESI-X ephemeral M: every task writes its C tile back exactly once."""
    n, tile = 1024, 128
    rt = _run_gemm("blasx", n=n, tile=tile)
    assert rt.total_comm_bytes()["d2h"] == n * n * 8


def test_cache_capacity_respected():
    cap = 4 << 20
    rt = _run_gemm("blasx", cache_bytes=cap, n=1024, tile=128)
    for d in rt.devices:
        assert d.heap.peak_used <= cap
        assert d.alru.evictions > 0  # small cache must evict


def test_threads_and_sim_agree_numerically():
    A = RNG.standard_normal((768, 512))
    B = RNG.standard_normal((512, 640))
    o1 = gemm(A, B, tile=128,
              config=RuntimeConfig(n_devices=3, mode="sim"))
    o2 = gemm(A, B, tile=128,
              config=RuntimeConfig(n_devices=3, mode="threads"))
    np.testing.assert_allclose(o1, o2, rtol=1e-12, atol=1e-12)


def test_stats_exports():
    rt = _run_gemm("blasx")
    st = rt.stats()
    assert set(st) == {"device0", "device1", "device2"}
    for s in st.values():
        assert s["l1_hits"] + s["l1_misses"] > 0
