"""Two-layer API tests: the persistent BlasxContext handle layer (warm
tile caches, per-call ledgers, futures, batching), the CBLAS legacy
layer, and the three-surface equivalence required by the redesign —
every L3 routine must produce oracle-identical results through the
legacy blas3 functions, BlasxContext methods, and cblas_* wrappers."""
import concurrent.futures
import threading
import time

import numpy as np
import pytest

from repro.api import (BackpressureError, BlasxContext, CblasColMajor,
                       CblasLower, CblasNonUnit, CblasNoTrans, CblasRight,
                       CblasRowMajor, CblasTrans, CblasUnit, CblasUpper,
                       MatrixHandle, SerialExecutor, cblas_dgemm,
                       cblas_dsymm, cblas_dsyr2k, cblas_dsyrk, cblas_dtrmm,
                       cblas_dtrsm)
from repro.core import (blas3, ref_gemm, ref_symm, ref_syr2k, ref_syrk,
                        ref_trmm, ref_trsm)
from repro.core.runtime import RuntimeConfig

RNG = np.random.default_rng(11)
TOL = dict(rtol=1e-10, atol=1e-10)


def _ctx(**kw):
    kw.setdefault("n_devices", 2)
    kw.setdefault("mode", "sim")
    kw.setdefault("cache_bytes", 64 << 20)
    return BlasxContext(RuntimeConfig(**kw), tile=48)


def _spd(n):
    """Well-conditioned triangular-solve operand."""
    return RNG.standard_normal((n, n)) / n + np.eye(n)


# ===================================================== three-surface parity
# Each case: (routine, kwargs, operand builder, oracle); beta != 0
# accumulation everywhere a beta exists, side='R' for symm/trmm/trsm.
def _case_gemm():
    A = RNG.standard_normal((110, 70))
    B = RNG.standard_normal((70, 90))
    C = RNG.standard_normal((110, 90))
    kw = dict(alpha=1.3, beta=-0.7)
    return (A, B, C), kw, ref_gemm(A, B, C, **kw)


def _case_syrk():
    A = RNG.standard_normal((96, 60))
    C = RNG.standard_normal((96, 96))
    kw = dict(alpha=0.8, beta=1.4, uplo="L")
    return (A, C), kw, ref_syrk(A, C, **kw)


def _case_syr2k():
    A = RNG.standard_normal((88, 50))
    B = RNG.standard_normal((88, 50))
    C = RNG.standard_normal((88, 88))
    kw = dict(alpha=0.5, beta=0.9, uplo="U")
    return (A, B, C), kw, ref_syr2k(A, B, C, **kw)


def _case_symm():
    B = RNG.standard_normal((72, 100))
    A = RNG.standard_normal((100, 100))      # side='R': A is n x n
    C = RNG.standard_normal((72, 100))
    kw = dict(alpha=1.1, beta=0.6, side="R", uplo="L")
    return (A, B, C), kw, ref_symm(A, B, C, **kw)


def _case_trmm():
    A = RNG.standard_normal((84, 84))
    B = RNG.standard_normal((96, 84))        # side='R'
    kw = dict(alpha=0.9, side="R", uplo="U", transa="T", diag="U")
    return (A, B), kw, ref_trmm(A, B, **kw)


def _case_trsm():
    A = _spd(80)
    B = RNG.standard_normal((64, 80))        # side='R'
    kw = dict(alpha=1.2, side="R", uplo="L", transa="N", diag="N")
    return (A, B), kw, ref_trsm(A, B, **kw)


CASES = {
    "gemm": _case_gemm, "syrk": _case_syrk, "syr2k": _case_syr2k,
    "symm": _case_symm, "trmm": _case_trmm, "trsm": _case_trsm,
}


@pytest.mark.parametrize("routine", sorted(CASES))
def test_legacy_surface_matches_oracle(routine):
    ops, kw, want = CASES[routine]()
    out = getattr(blas3, routine)(*ops, tile=48, **kw)
    np.testing.assert_allclose(out, want, **TOL)


@pytest.mark.parametrize("routine", sorted(CASES))
def test_context_surface_matches_oracle(routine):
    ops, kw, want = CASES[routine]()
    with _ctx() as ctx:
        out = getattr(ctx, routine)(*ops, **kw)
        assert isinstance(out, MatrixHandle)
        np.testing.assert_allclose(out.array(), want, **TOL)


def test_cblas_surface_matches_oracle_all_six():
    with _ctx() as ctx:
        (A, B, C), kw, want = _case_gemm()
        Cb = np.array(C)
        m, n, k = 110, 90, 70
        cblas_dgemm(CblasRowMajor, CblasNoTrans, CblasNoTrans, m, n, k,
                    kw["alpha"], A, k, B, n, kw["beta"], Cb, n, ctx=ctx)
        np.testing.assert_allclose(Cb, want, **TOL)

        (A, C), kw, want = _case_syrk()
        Cb = np.array(C)
        cblas_dsyrk(CblasRowMajor, CblasLower, CblasNoTrans, 96, 60,
                    kw["alpha"], A, 60, kw["beta"], Cb, 96, ctx=ctx)
        np.testing.assert_allclose(Cb, want, **TOL)

        (A, B, C), kw, want = _case_syr2k()
        Cb = np.array(C)
        cblas_dsyr2k(CblasRowMajor, CblasUpper, CblasNoTrans, 88, 50,
                     kw["alpha"], A, 50, B, 50, kw["beta"], Cb, 88, ctx=ctx)
        np.testing.assert_allclose(Cb, want, **TOL)

        (A, B, C), kw, want = _case_symm()
        Cb = np.array(C)
        cblas_dsymm(CblasRowMajor, CblasRight, CblasLower, 72, 100,
                    kw["alpha"], A, 100, B, 100, kw["beta"], Cb, 100,
                    ctx=ctx)
        np.testing.assert_allclose(Cb, want, **TOL)

        (A, B), kw, want = _case_trmm()
        Bb = np.array(B)
        cblas_dtrmm(CblasRowMajor, CblasRight, CblasUpper, CblasTrans,
                    CblasUnit, 96, 84, kw["alpha"], A, 84, Bb, 84, ctx=ctx)
        np.testing.assert_allclose(Bb, want, **TOL)

        (A, B), kw, want = _case_trsm()
        Bb = np.array(B)
        cblas_dtrsm(CblasRowMajor, CblasRight, CblasLower, CblasNoTrans,
                    CblasNonUnit, 64, 80, kw["alpha"], A, 80, Bb, 80,
                    ctx=ctx)
        np.testing.assert_allclose(Bb, want, rtol=1e-8, atol=1e-8)


# ------------------------------------------------- §III-C transpose paths
@pytest.mark.parametrize("side", ["L", "R"])
@pytest.mark.parametrize("uplo", ["U", "L"])
def test_context_symm_sides_with_accumulation(side, uplo):
    m, n = 60, 84
    B = RNG.standard_normal((m, n))
    dim = m if side == "L" else n
    A = RNG.standard_normal((dim, dim))
    C = RNG.standard_normal((m, n))
    with _ctx() as ctx:
        out = ctx.symm(A, B, C, alpha=0.7, beta=1.9, side=side, uplo=uplo)
    np.testing.assert_allclose(
        out.array(), ref_symm(A, B, C, alpha=0.7, beta=1.9, side=side,
                              uplo=uplo), **TOL)


@pytest.mark.parametrize("side", ["L", "R"])
@pytest.mark.parametrize("transa", ["N", "T"])
def test_context_trmm_trsm_sides(side, transa):
    m, n = 72, 56
    B = RNG.standard_normal((m, n))
    dim = m if side == "L" else n
    A = _spd(dim)
    with _ctx() as ctx:
        out_m = ctx.trmm(A, B, alpha=1.3, side=side, transa=transa)
        out_s = ctx.trsm(A, B, alpha=1.3, side=side, transa=transa)
    np.testing.assert_allclose(
        out_m.array(), ref_trmm(A, B, alpha=1.3, side=side, transa=transa),
        **TOL)
    np.testing.assert_allclose(
        out_s.array(), ref_trsm(A, B, alpha=1.3, side=side, transa=transa),
        rtol=1e-8, atol=1e-8)


@pytest.mark.parametrize("routine", ["gemm", "syrk", "syr2k", "symm"])
def test_beta_accumulation_matches_oracle(routine):
    """beta != 0 reads C through the ledgered bypass path — verify the
    accumulation term end to end for every beta-bearing routine."""
    n, k = 64, 40
    A = RNG.standard_normal((n, k))
    B = RNG.standard_normal((n, k))
    Bs = RNG.standard_normal((n, n))
    C = RNG.standard_normal((n, n))
    with _ctx() as ctx:
        if routine == "gemm":
            out = ctx.gemm(A, B, C, alpha=1.1, beta=2.3, transb="T")
            want = ref_gemm(A, B, C, alpha=1.1, beta=2.3, transb="T")
        elif routine == "syrk":
            out = ctx.syrk(A, C, alpha=1.1, beta=2.3)
            want = ref_syrk(A, C, alpha=1.1, beta=2.3)
        elif routine == "syr2k":
            out = ctx.syr2k(A, B, C, alpha=1.1, beta=2.3)
            want = ref_syr2k(A, B, C, alpha=1.1, beta=2.3)
        else:
            out = ctx.symm(Bs, A, np.zeros((n, k)) + C[:, :k], alpha=1.1,
                           beta=2.3)
            want = ref_symm(Bs, A, C[:, :k], alpha=1.1, beta=2.3)
    np.testing.assert_allclose(out.array(), want, **TOL)


# ==================================================== warm-cache contract
def test_chained_calls_reuse_cached_tiles():
    """The redesign's core claim: a second call on the same handles
    moves strictly fewer H2D bytes than the first (acceptance
    criterion: chained < 2 cold calls)."""
    A = RNG.standard_normal((512, 512))
    B = RNG.standard_normal((512, 512))
    with _ctx(n_devices=1, cache_bytes=256 << 20) as ctx:
        Ah, Bh = ctx.tile(A), ctx.tile(B)
        ctx.gemm(Ah, Bh)
        cold = ctx.last_call
        ctx.gemm(Ah, Bh)
        warm = ctx.last_call
        assert warm.h2d_bytes < cold.h2d_bytes
        assert warm.h2d_bytes == 0          # single device: all L1 hits
        assert warm.l1_hits > 0 and warm.l1_misses == 0
        # chained total strictly beats two cold calls
        assert cold.h2d_bytes + warm.h2d_bytes < 2 * cold.h2d_bytes


def test_chained_beats_per_call_api_multi_device():
    """Same comparison across the per-call legacy API — the handle
    path must win on input traffic even with multiple devices."""
    A = RNG.standard_normal((768, 768))
    B = RNG.standard_normal((768, 768))

    def cold_bytes():
        ctx = _ctx(n_devices=3)
        ctx.gemm(A, B, tile=128)
        return ctx.last_call.h2d_bytes

    two_cold = cold_bytes() + cold_bytes()
    with _ctx(n_devices=3) as ctx:
        Ah, Bh = ctx.tile(A, 128), ctx.tile(B, 128)
        r1 = ctx.gemm(Ah, Bh)
        r2 = ctx.gemm(Ah, Bh)
        chained = ctx.calls[-2].h2d_bytes + ctx.calls[-1].h2d_bytes
        np.testing.assert_allclose(r2.array(), A @ B, **TOL)
    assert chained < two_cold


def test_output_handle_feeds_next_call():
    """C := A@B then D := C@B without re-tiling C (Cholesky-sweep
    shape); numerics stay oracle-exact."""
    n = 256
    A = RNG.standard_normal((n, n))
    B = RNG.standard_normal((n, n))
    with _ctx() as ctx:
        Ch = ctx.gemm(ctx.tile(A), ctx.tile(B))
        Dh = ctx.gemm(Ch, ctx.tile(B))
        np.testing.assert_allclose(Dh.array(), (A @ B) @ B, **TOL)


def test_mixed_routine_chain_matches_oracles():
    """syrk -> trsm -> gemm sweep through one context (warm caches all
    along); each stage checked against its oracle."""
    n = 192
    A = RNG.standard_normal((n, 96))
    L = _spd(n)
    with _ctx() as ctx:
        Ah = ctx.tile(A)
        S = ctx.syrk(Ah, alpha=1.0, uplo="U")
        np.testing.assert_allclose(S.array(), ref_syrk(A, alpha=1.0,
                                                       uplo="U"), **TOL)
        X = ctx.trsm(ctx.tile(L), Ah, uplo="L")
        np.testing.assert_allclose(X.array(), ref_trsm(L, A, uplo="L"),
                                   rtol=1e-8, atol=1e-8)
        G = ctx.gemm(X, Ah, transb="T")
        np.testing.assert_allclose(
            G.array(), ref_trsm(L, A, uplo="L") @ A.T, rtol=1e-8, atol=1e-8)


def test_handle_invalidate_after_mutation():
    A = RNG.standard_normal((128, 128))
    B = RNG.standard_normal((128, 128))
    with _ctx(n_devices=1) as ctx:
        Ah, Bh = ctx.tile(A), ctx.tile(B)
        ctx.gemm(Ah, Bh)
        A2 = 2.0 * A                       # handles alias the caller array,
        Ah.array()[:] = A2                 # so snapshot the new value first
        dropped = Ah.invalidate()
        assert dropped > 0
        out = ctx.gemm(Ah, Bh)
        np.testing.assert_allclose(out.array(), A2 @ B, **TOL)


def test_cross_context_handles_rejected():
    with _ctx() as c1, _ctx() as c2:
        h = c1.tile(RNG.standard_normal((32, 32)))
        with pytest.raises(ValueError):
            c2.gemm(h, h)


# ============================================== stats / ledgers / lifecycle
def test_per_call_records_and_cumulative_stats():
    A = RNG.standard_normal((256, 256))
    with _ctx() as ctx:
        Ah = ctx.tile(A)
        ctx.gemm(Ah, Ah)
        ctx.syrk(Ah)
        assert [c.routine for c in ctx.calls] == ["gemm", "syrk"]
        assert all(c.tasks > 0 for c in ctx.calls)
        st = ctx.stats()
        assert st["calls"] == 2
        assert st["comm_bytes"]["h2d"] == sum(c.h2d_bytes for c in ctx.calls)
        assert st["comm_bytes"]["d2h"] == sum(c.d2h_bytes for c in ctx.calls)
        ctx.reset_stats()                  # counters drop, caches stay
        assert ctx.stats()["calls"] == 0
        assert ctx.stats()["comm_bytes"]["h2d"] == 0
        ctx.gemm(Ah, Ah)
        assert ctx.last_call.h2d_bytes == 0   # still warm after reset_stats
        dev0 = ctx.runtime.devices[0].alru
        assert dev0.lifetime_misses > dev0.misses  # lifetime survives reset


def test_context_close_and_reset():
    A = RNG.standard_normal((128, 128))
    ctx = _ctx()
    Ah = ctx.tile(A)
    ctx.gemm(Ah, Ah)
    ctx.reset()                            # cold restart keeps ctx usable
    ctx.gemm(Ah, Ah)
    assert ctx.last_call.h2d_bytes > 0     # caches were dropped
    ctx.close()
    assert ctx.closed
    with pytest.raises(RuntimeError):
        ctx.gemm(Ah, Ah)
    ctx.close()                            # idempotent


# ================================================================== async
def test_submit_returns_future_with_result():
    A = RNG.standard_normal((192, 192))
    B = RNG.standard_normal((192, 192))
    with _ctx() as ctx:
        f1 = ctx.submit("gemm", A, B, alpha=0.5)
        f2 = ctx.submit("syrk", A)
        out1, out2 = f1.result(timeout=60), f2.result(timeout=60)
        assert f1.done() and f2.done()
        assert f1.exception() is None
        np.testing.assert_allclose(out1.array(), 0.5 * A @ B, **TOL)
        np.testing.assert_allclose(out2.array(), ref_syrk(A), **TOL)


def test_submit_propagates_errors_and_validates_names():
    with _ctx() as ctx:
        f = ctx.submit("gemm", np.zeros((3, 4)), np.zeros((5, 6)))
        with pytest.raises(ValueError):
            f.result(timeout=60)
        assert isinstance(f.exception(), ValueError)
        with pytest.raises(ValueError):
            ctx.submit("not_a_routine")


def test_submitted_chain_overlaps_in_order():
    A = RNG.standard_normal((160, 160))
    with _ctx() as ctx:
        Ah = ctx.tile(A)
        futs = [ctx.submit("gemm", Ah, Ah) for _ in range(4)]
        outs = [f.result(timeout=60) for f in futs]
        for o in outs:
            np.testing.assert_allclose(o.array(), A @ A, **TOL)
        # later submissions ran warm
        assert ctx.calls[-1].h2d_bytes < ctx.calls[0].h2d_bytes


def test_serial_executor_backpressure_bound():
    """Fails before the max_pending bound existed: the executor
    accepted unbounded work and never raised."""
    ex = SerialExecutor(max_pending=1)
    gate = threading.Event()
    running = threading.Event()
    try:
        f1 = ex.submit(lambda: (running.set(), gate.wait(30)) and 1 or 1)
        assert running.wait(30)
        with pytest.raises(BackpressureError, match="max_pending"):
            ex.submit(lambda: 2)
        assert ex.pending == 1
        gate.set()
        assert f1.result(timeout=30) == 1
        # slot freed on completion: submitting works again
        assert ex.submit(lambda: 3).result(timeout=30) == 3
    finally:
        gate.set()
        ex.shutdown()


def test_serial_executor_blocking_submit_waits_for_slot():
    ex = SerialExecutor(max_pending=1)
    gate = threading.Event()
    try:
        f1 = ex.submit(lambda: gate.wait(30))
        threading.Timer(0.05, gate.set).start()
        f2 = ex.submit(lambda: 42, block=True, block_timeout=30)
        assert f2.result(timeout=30) == 42
        assert f1.result(timeout=30)
    finally:
        gate.set()
        ex.shutdown()


def test_serial_executor_blocking_submit_times_out():
    ex = SerialExecutor(max_pending=1)
    gate = threading.Event()
    try:
        ex.submit(lambda: gate.wait(30))
        with pytest.raises(BackpressureError, match="timed out"):
            ex.submit(lambda: 2, block=True, block_timeout=0.05)
    finally:
        gate.set()
        ex.shutdown()


def test_serial_executor_unbounded_stays_legacy():
    ex = SerialExecutor()                   # max_pending=None
    gate = threading.Event()
    try:
        futs = [ex.submit(lambda: gate.wait(30)) for _ in range(20)]
        gate.set()
        assert all(f.result(timeout=30) for f in futs)
    finally:
        gate.set()
        ex.shutdown()


def test_blasfuture_cancel_semantics():
    """A queued submission cancels; result()/exception() then raise
    CancelledError; a running submission refuses to cancel."""
    ex = SerialExecutor()
    gate = threading.Event()
    running = threading.Event()
    try:
        f1 = ex.submit(lambda: (running.set(), gate.wait(30)) and "ran")
        assert running.wait(30)
        f2 = ex.submit(lambda: "never")
        assert not f1.cancel()              # already running
        assert f2.cancel()                  # still queued
        assert f2.cancelled() and f2.done()
        assert "cancelled" in repr(f2)
        with pytest.raises(concurrent.futures.CancelledError):
            f2.result(timeout=1)
        with pytest.raises(concurrent.futures.CancelledError):
            f2.exception(timeout=1)
        gate.set()
        assert f1.result(timeout=30) == "ran"
        assert not f1.cancelled()
    finally:
        gate.set()
        ex.shutdown()


def test_cancelled_submission_frees_backpressure_slot():
    ex = SerialExecutor(max_pending=2)
    gate = threading.Event()
    try:
        ex.submit(lambda: gate.wait(30))
        doomed = ex.submit(lambda: None)
        with pytest.raises(BackpressureError):
            ex.submit(lambda: None)
        assert doomed.cancel()
        f = ex.submit(lambda: "fits")       # cancel freed the slot
        gate.set()
        assert f.result(timeout=30) == "fits"
    finally:
        gate.set()
        ex.shutdown()


def test_ctx_submit_close_race_is_clean():
    """submit during close raises cleanly, in-flight work completes,
    and the executor does not leak."""
    gate = threading.Event()
    running = threading.Event()
    ctx = _ctx()
    f = ctx.submit(lambda: (running.set(), gate.wait(30)) and "done")
    assert running.wait(30)
    closer = threading.Thread(target=ctx.close)
    closer.start()
    deadline = time.monotonic() + 30
    while not ctx.closed and time.monotonic() < deadline:
        time.sleep(0.001)
    assert ctx.closed
    with pytest.raises(RuntimeError):       # close flagged before drain
        ctx.submit("gemm", np.eye(8), np.eye(8))
    gate.set()
    closer.join(timeout=30)
    assert not closer.is_alive()
    assert f.result(timeout=30) == "done"   # in-flight work completed
    assert ctx._executor is None            # no executor leak


def test_ctx_submit_fifo_under_concurrent_submitters():
    """The single-lane executor preserves each submitter's relative
    order even when many threads race on submit."""
    order = []
    with _ctx() as ctx:
        def submitter(tid):
            for i in range(8):
                ctx.submit(lambda t=tid, k=i: order.append((t, k)))

        threads = [threading.Thread(target=submitter, args=(t,))
                   for t in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        ctx.submit(lambda: None).result(timeout=30)  # drain barrier
    assert len(order) == 32
    for tid in range(4):
        ks = [k for t, k in order if t == tid]
        assert ks == sorted(ks)             # per-thread FIFO preserved


# ================================================================ batched
def test_gemm_batched_shared_weight_handle():
    W = RNG.standard_normal((128, 96))
    xs = [RNG.standard_normal((64, 128)) for _ in range(5)]
    with _ctx(n_devices=1) as ctx:
        Wh = ctx.tile(W)
        outs = ctx.gemm_batched(xs, [Wh] * len(xs))
        for x, o in zip(xs, outs):
            np.testing.assert_allclose(o.array(), x @ W, **TOL)
        # W transferred once, then served from the warm cache
        w_bytes = W.nbytes
        total_h2d = sum(c.h2d_bytes for c in ctx.calls)
        cold_would_be = sum(x.nbytes for x in xs) + len(xs) * w_bytes
        assert total_h2d <= cold_would_be - (len(xs) - 1) * w_bytes


def test_gemm_batched_submittable_async():
    """Regression: submitting the batch itself must not deadlock the
    single-worker executor (the batch loops synchronously inside)."""
    A = RNG.standard_normal((64, 64))
    with _ctx() as ctx:
        f = ctx.submit("gemm_batched", [A, A], [A, A])
        outs = f.result(timeout=60)
        assert f.done()
        for o in outs:
            np.testing.assert_allclose(o.array(), A @ A, **TOL)


def test_gemm_strided_batched_broadcasts_weights():
    x = RNG.standard_normal((3, 48, 64))
    W = RNG.standard_normal((64, 32))
    C = RNG.standard_normal((3, 48, 32))
    with _ctx() as ctx:
        out = ctx.gemm_strided_batched(x, W, C, alpha=1.5, beta=0.5)
    assert out.shape == (3, 48, 32)
    for i in range(3):
        np.testing.assert_allclose(
            out[i], 1.5 * x[i] @ W + 0.5 * C[i], **TOL)


def test_gemm_batched_validates_lengths():
    with _ctx() as ctx:
        with pytest.raises(ValueError):
            ctx.gemm_batched([np.eye(8)], [np.eye(8), np.eye(8)])


# ================================================================= cblas
def test_cblas_flat_buffers_row_and_col_major():
    m, n, k = 30, 24, 18
    A = RNG.standard_normal((m, k))
    B = RNG.standard_normal((k, n))
    C = RNG.standard_normal((m, n))
    want = ref_gemm(A, B, C, alpha=1.2, beta=0.8)
    with _ctx() as ctx:
        # row-major flat with padded leading dimensions
        lda, ldb, ldc = k + 3, n + 2, n + 5
        Af = np.zeros(m * lda)
        Af.reshape(m, lda)[:, :k] = A
        Bf = np.zeros(k * ldb)
        Bf.reshape(k, ldb)[:, :n] = B
        Cf = np.zeros(m * ldc)
        Cf.reshape(m, ldc)[:, :n] = C
        cblas_dgemm(CblasRowMajor, CblasNoTrans, CblasNoTrans, m, n, k,
                    1.2, Af, lda, Bf, ldb, 0.8, Cf, ldc, ctx=ctx)
        np.testing.assert_allclose(Cf.reshape(m, ldc)[:, :n], want, **TOL)

        # column-major flat (Fortran layout)
        lda, ldb, ldc = m + 1, k + 4, m + 2
        Af = np.zeros(lda * k)
        Af.reshape(k, lda).T[:m, :] = A
        Bf = np.zeros(ldb * n)
        Bf.reshape(n, ldb).T[:k, :] = B
        Cf = np.zeros(ldc * n)
        Cf.reshape(n, ldc).T[:m, :] = C
        cblas_dgemm(CblasColMajor, CblasNoTrans, CblasNoTrans, m, n, k,
                    1.2, Af, lda, Bf, ldb, 0.8, Cf, ldc, ctx=ctx)
        np.testing.assert_allclose(Cf.reshape(n, ldc).T[:m, :], want, **TOL)


def test_cblas_transposed_inputs():
    m, n, k = 26, 22, 34
    A = RNG.standard_normal((k, m))       # op(A) = A^T
    B = RNG.standard_normal((n, k))       # op(B) = B^T
    C = np.zeros((m, n))
    with _ctx() as ctx:
        cblas_dgemm(CblasRowMajor, CblasTrans, CblasTrans, m, n, k,
                    1.0, A, m, B, k, 0.0, C, n, ctx=ctx)
    np.testing.assert_allclose(C, A.T @ B.T, **TOL)


def test_cblas_syrk_preserves_opposite_triangle_beta_zero():
    n, k = 40, 16
    A = RNG.standard_normal((n, k))
    C = RNG.standard_normal((n, n))
    orig = C.copy()
    with _ctx() as ctx:
        cblas_dsyrk(CblasRowMajor, CblasUpper, CblasNoTrans, n, k,
                    1.0, A, k, 0.0, C, n, ctx=ctx)
    low = np.tril_indices(n, -1)
    np.testing.assert_array_equal(C[low], orig[low])
    np.testing.assert_allclose(np.triu(C), np.triu(A @ A.T), **TOL)


def test_cblas_rejects_bad_buffers():
    with _ctx() as ctx:
        C = np.zeros((4, 4), dtype=np.float32)
        with pytest.raises(TypeError):
            cblas_dgemm(CblasRowMajor, CblasNoTrans, CblasNoTrans, 4, 4, 4,
                        1.0, np.eye(4), 4, np.eye(4), 4, 0.0, C, 4, ctx=ctx)
        with pytest.raises(ValueError):   # ld smaller than n cols
            cblas_dgemm(CblasRowMajor, CblasNoTrans, CblasNoTrans, 4, 4, 4,
                        1.0, np.zeros(16), 2, np.eye(4), 4, 0.0,
                        np.zeros((4, 4)), 4, ctx=ctx)
        with pytest.raises(ValueError):   # bogus trans flag
            cblas_dgemm(CblasRowMajor, 999, CblasNoTrans, 4, 4, 4,
                        1.0, np.eye(4), 4, np.eye(4), 4, 0.0,
                        np.zeros((4, 4)), 4, ctx=ctx)


def test_cblas_rejects_list_output_buffer():
    """A list passes np.asarray but the update would land in a detached
    copy — must be rejected loudly, not silently dropped."""
    with _ctx() as ctx:
        with pytest.raises(TypeError):
            cblas_dgemm(CblasRowMajor, CblasNoTrans, CblasNoTrans, 2, 2, 2,
                        1.0, np.eye(2), 2, np.eye(2), 2, 0.0,
                        [0.0] * 4, 2, ctx=ctx)


def test_legacy_output_dtype_preserved():
    """Backward-compat contract: output dtype follows C (or B for trmm)
    exactly as the pre-context implementation did."""
    A = RNG.standard_normal((40, 40))
    B32 = RNG.standard_normal((40, 40)).astype(np.float32)
    C32 = RNG.standard_normal((40, 40)).astype(np.float32)
    assert blas3.gemm(A, B32, C32, beta=1.0, tile=16).dtype == np.float32
    assert blas3.trmm(A, B32, tile=16).dtype == np.float32
    assert blas3.syrk(B32, C32, beta=0.5, tile=16).dtype == np.float32


def test_side_r_leaves_no_intermediate_tiles():
    """The §III-C reduction's intermediate left-side output must not
    squat on cache capacity in a long-lived context."""
    A = _spd(48)
    B = RNG.standard_normal((32, 48))
    with _ctx(n_devices=1) as ctx:
        res = ctx.trsm(A, B, side="R")
        live = {k.matrix_id for d in ctx.runtime.devices[0:1]
                for k in d.alru.keys()}
        # nothing cached except (possibly) tiles of operands that still
        # have a reachable handle — the intermediate result id is gone
        assert res.matrix_id not in live  # transposed copy never ran
        assert len(live) == 0             # ephemerals + intermediate dropped


def test_tile_mismatch_rejected_in_all_two_operand_routines():
    with _ctx() as ctx:
        a64 = ctx.tile(RNG.standard_normal((64, 64)), 64)
        b32 = ctx.tile(RNG.standard_normal((64, 64)), 32)
        for call in (lambda: ctx.gemm(a64, b32),
                     lambda: ctx.syr2k(a64, b32),
                     lambda: ctx.symm(a64, b32),
                     lambda: ctx.trmm(a64, b32),
                     lambda: ctx.trsm(a64, b32)):
            with pytest.raises(ValueError, match="tile mismatch"):
                call()


def test_adopted_runtime_survives_context_close():
    from repro.core.runtime import BlasxRuntime
    rt = BlasxRuntime(RuntimeConfig(n_devices=2, mode="sim",
                                    cache_bytes=32 << 20))
    A = RNG.standard_normal((128, 128))
    with BlasxContext(runtime=rt, tile=32) as ctx:
        ctx.gemm(ctx.tile(A), ctx.tile(A))
    assert rt.total_comm_bytes()["h2d"] > 0   # ledgers not wiped on close


# ===================================================== legacy equivalence
def test_legacy_default_context_is_module_cached():
    from repro.api import default_context
    a = default_context()
    assert default_context() is a
    A = RNG.standard_normal((64, 64))
    out = blas3.gemm(A, A, tile=32)
    np.testing.assert_allclose(out, A @ A, **TOL)
    assert default_context().runtime.runs > 0
