"""ReadyQueue edge-case coverage (paper §IV-C): the dequeue_wait
spurious-wakeup contract, crash-recovery requeue exactly-once
semantics, dependency-gated enqueue of dependents, and the
steal-time priority refresh (Eq. 3 against current cache state)."""
import threading
import time

import pytest

from repro.core.alru import Alru
from repro.core.heap import BlasxHeap
from repro.core.task import Step, Task, TileRef
from repro.core.taskqueue import ReadyQueue, ReservationStation
from repro.core.tiling import TileKey


def _task(tid, deps=()):
    return Task(task_id=tid, routine="gemm", out=TileKey("C", tid, 0),
                i=tid, j=0, steps=(), alpha=1.0, beta=0.0,
                deps=tuple(deps))


# -------------------------------------------------- spurious-wakeup contract
def test_dequeue_wait_none_with_outstanding_means_retry():
    """The documented contract: a None return while tasks are still
    outstanding is a spurious wakeup — the caller must retry, not
    treat the queue as drained."""
    a, b = _task(0), _task(1, deps=[0])
    q = ReadyQueue([a, b])
    got_a = q.try_dequeue()
    assert got_a is a
    # b is dep-blocked: a short wait times out with None...
    assert q.dequeue_wait(timeout=0.01) is None
    # ...and that None does NOT mean drained: work is still outstanding
    assert not q.drained()
    assert q.pending_count() == 1
    q.complete(a)
    assert q.dequeue_wait(timeout=0.01) is b
    q.complete(b)
    assert q.drained()
    # drained queue: None now genuinely means "no more work"
    assert q.dequeue_wait(timeout=0.01) is None


def test_dequeue_wait_wakes_on_cross_thread_completion():
    """A parked worker is woken by a peer completing the producer —
    the retry loop converges without waiting out the timeout."""
    a, b = _task(0), _task(1, deps=[0])
    q = ReadyQueue([a, b])
    assert q.try_dequeue() is a
    result = []

    def consumer():
        while True:
            t = q.dequeue_wait(timeout=0.5)
            if t is not None:
                result.append(t)
                q.complete(t)
                return
            if q.drained():
                return

    th = threading.Thread(target=consumer)
    th.start()
    time.sleep(0.05)           # let the consumer park in dequeue_wait
    q.complete(a)              # releases b and notifies
    th.join(timeout=5)
    assert not th.is_alive()
    assert result == [b]
    assert q.drained()


# ----------------------------------------------------- crash-recovery requeue
def test_requeue_redelivers_exactly_once():
    """Simulated worker crash: a dequeued-but-never-completed task is
    requeued (RS drain path) and must be delivered exactly once more —
    no duplicate, no loss, and accounting still drains to zero."""
    a = _task(0)
    q = ReadyQueue([a])
    t = q.try_dequeue()
    assert t is a and not q.drained()
    q.requeue(t)               # crash recovery
    assert q.has_ready()
    again = q.try_dequeue()
    assert again is a
    assert q.try_dequeue() is None      # exactly once: queue is empty
    assert not q.drained()              # still outstanding until completed
    q.complete(again)
    assert q.drained()


def test_requeue_rejects_foreign_tasks():
    q = ReadyQueue([_task(0)])
    with pytest.raises(ValueError, match="foreign"):
        q.requeue(_task(99))


def test_rs_drain_then_requeue_roundtrip():
    """The runtime's crash path: tasks parked in a reservation station
    drain back to the queue and every one is dequeueable again."""
    tasks = [_task(i) for i in range(4)]
    q = ReadyQueue(tasks)
    rs = ReservationStation(0, 4)
    for _ in range(3):
        rs.put(q.try_dequeue(), 0.0)
    assert len(rs) == 3
    drained = rs.drain()
    assert len(drained) == 3 and len(rs) == 0
    for t in drained:
        q.requeue(t)
    seen = set()
    while True:
        t = q.try_dequeue()
        if t is None:
            break
        seen.add(t.task_id)
        q.complete(t)
    assert seen == {0, 1, 2, 3}
    assert q.drained()


# ------------------------------------------------- steal priority refresh
def _tile_task(tid, matrix_id):
    """A task whose single k-step reads two tiles of ``matrix_id``."""
    return Task(task_id=tid, routine="gemm", out=TileKey("C", tid, 0),
                i=tid, j=0,
                steps=(Step(TileRef(TileKey(matrix_id, 0, 0)),
                            TileRef(TileKey(matrix_id, 0, 1))),),
                alpha=1.0, beta=0.0)


def test_steal_refreshes_priorities_against_current_cache_state():
    """Regression (paper Eq. 3): the victim RS holds put-time
    priorities recorded while its cache was cold (everything 0).  The
    victim's L1 then fills with task B's input tiles, making B the
    task the victim most wants to keep — but the stale table still
    says both tasks are worthless, and pre-fix ``steal()`` walked off
    with B (the L1-hot task).  With the refresh hook the thief gets
    the genuinely coldest task A."""
    heap = BlasxHeap(1 << 20)
    victim_l1 = Alru(0, heap)
    victim_l1.on_evict = lambda dev, key: None

    a, b = _tile_task(0, "X"), _tile_task(1, "Y")
    rs = ReservationStation(0, 4)
    rs.put(a, 0.0)   # put-time: victim cache cold, both priorities 0
    rs.put(b, 0.0)

    # the victim's cache warms up with B's tiles AFTER the puts
    for ref in b.input_refs():
        blk = victim_l1.translate(ref.key, 64)
        assert blk is not None
        victim_l1.release(ref.key)

    def eq3(t):  # +2 per L1-resident input tile (runtime._priority)
        return sum(2.0 for ref in t.input_refs() if ref.key in victim_l1)

    stolen = rs.steal(eq3)
    assert stolen is a, "steal took the victim's L1-hot task"
    # the hot task stays home and is what the victim executes next
    assert rs.take_top(1) == [b]


def test_steal_without_refresh_uses_stored_priorities():
    """FIFO-priority policies (no Eq. 3) keep the old contract: the
    stored lowest-priority slot is the victim."""
    rs = ReservationStation(0, 4)
    hi, lo = _task(0), _task(1)
    rs.put(hi, 5.0)
    rs.put(lo, 1.0)
    assert rs.steal() is lo
    assert rs.steal() is hi
    assert rs.steal() is None


# ----------------------------------------------------- dependency gating
def test_dependent_enqueues_only_after_last_producer():
    """A task with two producers becomes ready exactly when the LAST
    one completes — not the first."""
    a, b = _task(0), _task(1)
    c = _task(2, deps=[0, 1])
    q = ReadyQueue([a, b, c])
    ta, tb = q.try_dequeue(), q.try_dequeue()
    assert {ta.task_id, tb.task_id} == {0, 1}
    assert not q.has_ready() and q.pending_count() == 1
    q.complete(ta)
    assert not q.has_ready()           # one producer is not enough
    assert q.pending_count() == 1
    q.complete(tb)
    assert q.has_ready() and q.pending_count() == 0
    tc = q.try_dequeue()
    assert tc is c
    q.complete(tc)
    assert q.drained()


def test_chain_releases_in_order():
    """A TRSM-style linear chain releases one task per completion."""
    tasks = [_task(0)] + [_task(i, deps=[i - 1]) for i in range(1, 5)]
    q = ReadyQueue(tasks)
    order = []
    while not q.drained():
        t = q.try_dequeue()
        assert t is not None, "chain stalled"
        assert not q.has_ready(), "chain released more than one task"
        order.append(t.task_id)
        q.complete(t)
    assert order == [0, 1, 2, 3, 4]


def test_complete_foreign_task_resolves_edges_only():
    """Static-split semantics: completing a task owned by another
    queue resolves dependency edges here without touching outstanding
    accounting."""
    producer = _task(0)                # lives in ANOTHER device's queue
    dependent = _task(1, deps=[0])
    q = ReadyQueue([dependent])        # only the dependent is ours
    assert not q.has_ready()
    q.complete(producer)               # foreign completion
    assert q.has_ready()
    t = q.try_dequeue()
    assert t is dependent
    q.complete(t)
    assert q.drained()
