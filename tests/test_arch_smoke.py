"""Per-architecture smoke tests (deliverable f): each assigned arch is
instantiated at a REDUCED same-family config and runs one forward +
train step on CPU, asserting output shapes and finiteness; serving
paths are checked for train/prefill/decode logit consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, cell_supported, get_config
from repro.models import Model

pytestmark = pytest.mark.slow  # full per-arch sweeps dominate suite time

KEY = jax.random.PRNGKey(0)
ARCHS = [a for a in ARCH_IDS if a != "blasx_gemm"]


def _inputs(cfg, B=2, S=12, seed=0):
    rng = np.random.default_rng(seed)
    kw = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                jnp.int32)}
    if cfg.family == "encdec":
        kw["enc_embeds"] = jnp.asarray(
            rng.standard_normal((B, 8, cfg.d_model)), jnp.float32)
    return kw


@pytest.fixture(scope="module")
def models():
    out = {}
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        m = Model(cfg)
        out[arch] = (cfg, m, m.init(KEY))
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(models, arch):
    cfg, m, params = models[arch]
    B, S = 2, 12
    logits, aux = m.train_logits(params, **_inputs(cfg, B, S))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    if cfg.family == "moe":
        assert "moe_aux_loss" in aux and np.isfinite(float(aux["moe_aux_loss"]))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_reduces_loss_direction(models, arch):
    """One SGD step on the CE loss must produce finite grads that change
    the loss (sanity of the whole differentiable path)."""
    cfg, m, params = models[arch]
    kw = _inputs(cfg)
    tokens = kw["tokens"]

    def loss_fn(p):
        logits, aux = m.train_logits(p, **kw)
        lg = logits[:, :-1].astype(jnp.float32)
        tg = tokens[:, 1:]
        ce = -jnp.take_along_axis(jax.nn.log_softmax(lg, -1),
                                  tg[..., None], -1).mean()
        if "moe_aux_loss" in aux:
            ce = ce + 0.01 * aux["moe_aux_loss"]
        return ce

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    gnorm = jax.tree_util.tree_reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))),
        grads, jnp.float32(0.0))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0
    params2 = jax.tree.map(lambda p, g: p - 0.3 * g, params, grads)
    loss2 = loss_fn(params2)
    assert float(loss2) != float(loss)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_train_logits(models, arch):
    cfg, m, params = models[arch]
    B, S = 2, 12
    kw = _inputs(cfg, B, S)
    tokens = kw["tokens"]
    full, _ = m.train_logits(params, **kw)
    kw_p = dict(kw)
    kw_p["tokens"] = tokens[:, :S - 2]
    lg, cache = m.prefill(params, **kw_p)
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full[:, S - 3]),
                               rtol=1e-4, atol=1e-4)
    cache = m.pad_cache(cache, S)
    for t in range(S - 2, S):
        pos = jnp.full((B,), t, jnp.int32)
        lg2, cache = m.decode(params, cache, tokens[:, t], pos)
        np.testing.assert_allclose(np.asarray(lg2), np.asarray(full[:, t]),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("arch", ARCHS)
def test_abstract_params_match_init_shapes(models, arch):
    cfg, m, params = models[arch]
    abstract = m.abstract()
    flat_a = jax.tree_util.tree_leaves_with_path(abstract)
    flat_p = {jax.tree_util.keystr(k): v.shape
              for k, v in jax.tree_util.tree_leaves_with_path(params)}
    for k, v in flat_a:
        ks = jax.tree_util.keystr(k)
        assert flat_p[ks] == v.shape, (ks, flat_p[ks], v.shape)


def test_full_config_param_counts():
    """Full (non-reduced) configs must hit the published sizes."""
    expect = {
        "deepseek_v3_671b": (671e9, 0.01),
        "olmoe_1b_7b": (6.9e9, 0.02),
        "qwen3_0_6b": (0.6e9, 0.05),
        "glm4_9b": (9.4e9, 0.05),
        "phi3_medium_14b": (14e9, 0.06),
        "olmo_1b": (1.2e9, 0.1),
    }
    for arch, (want, tol) in expect.items():
        got = get_config(arch).param_count()
        assert abs(got - want) / want < tol + 0.05, (arch, got, want)


def test_moe_active_params():
    c = get_config("deepseek_v3_671b")
    assert 30e9 < c.active_param_count() < 45e9  # paper: 37B activated


def test_long_context_support_flags():
    for arch in ARCHS:
        cfg = get_config(arch)
        ok, why = cell_supported(cfg, SHAPES["long_500k"])
        if arch in ("zamba2_2_7b", "mamba2_780m"):
            assert ok
        else:
            assert not ok and "sub-quadratic" in why


def test_ssm_chunked_equals_sequential():
    """SSD chunked scan == naive recurrence (the duality itself)."""
    from repro.models.ssm import _ssd_chunked
    rng = np.random.default_rng(0)
    B, S, H, P, N = 2, 24, 3, 4, 5
    xh = jnp.asarray(rng.standard_normal((B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 0.9, (B, S, H)), jnp.float32)
    a_log = jnp.asarray(rng.uniform(-1, 0.5, (H,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    y, hlast = _ssd_chunked(xh, dt, a_log, Bm, Cm, chunk=8)

    # naive recurrence
    h = np.zeros((B, H, P, N), np.float32)
    ys = []
    for t in range(S):
        a = np.exp(-np.exp(np.asarray(a_log)) * np.asarray(dt[:, t]))
        upd = np.einsum("bhp,bn->bhpn",
                        np.asarray(xh[:, t]) * np.asarray(dt[:, t])[..., None],
                        np.asarray(Bm[:, t]))
        h = h * a[..., None, None] + upd
        ys.append(np.einsum("bn,bhpn->bhp", np.asarray(Cm[:, t]), h))
    y_ref = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hlast), h, rtol=2e-4, atol=2e-4)


def test_flash_backend_matches_xla_backend(models):
    """The Pallas flash-attention backend is a drop-in for train/prefill
    self-attention: logits must match the XLA path."""
    from repro.models import attention as attn_mod
    cfg, m, params = models["qwen3_0_6b"]
    kw = _inputs(cfg, 2, 16)
    try:
        attn_mod.ATTENTION_BACKEND = "xla"
        ref_logits, _ = m.train_logits(params, **kw)
        attn_mod.ATTENTION_BACKEND = "pallas"
        flash_logits, _ = m.train_logits(params, **kw)
    finally:
        attn_mod.ATTENTION_BACKEND = "xla"
    np.testing.assert_allclose(np.asarray(flash_logits),
                               np.asarray(ref_logits),
                               rtol=2e-4, atol=2e-4)
