"""Execution-backend suite: NumPy/JAX/Pallas parity across all six L3
routines x transpose/uplo/side variants, batched-dispatch launch
accounting, and backend selection through every API layer.

Parity runs the full pipeline (taskize -> schedule -> batched backend
dispatch -> epilogue) on 2 simulated devices with ragged edge tiles,
so group formation covers task-contraction AND per-step fallback
paths.  float32 inputs: the jax engine computes in float32 on default
CPU jax (see repro.backends.jax_backend), so float32 keeps the
comparison apples-to-apples.

The heaviest Pallas cases (interpret mode on CPU) are marked slow;
one case per routine stays in the fast lane.
"""
import numpy as np
import pytest

from repro.backends import available_backends, create_backend
from repro.core import blas3
from repro.core.runtime import BlasxRuntime, RuntimeConfig

M, N, K, TILE = 48, 40, 56, 16   # 40/56 leave ragged edge tiles
TOL = dict(rtol=2e-3, atol=2e-3)


def cfg(backend, **kw):
    kw.setdefault("n_devices", 2)
    kw.setdefault("mode", "sim")
    return RuntimeConfig(backend=backend, **kw)


def _f32(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


def _run_case(case, backend):
    """Returns (got, want) for one routine/variant under one backend."""
    rng = np.random.default_rng(11)
    r = dict(case)
    routine = r.pop("routine")
    config = cfg(backend)
    if routine == "gemm":
        ta, tb = r["transa"], r["transb"]
        A = _f32(rng, *((M, K) if ta == "N" else (K, M)))
        B = _f32(rng, *((K, N) if tb == "N" else (N, K)))
        C = _f32(rng, M, N) if r.get("beta") else None
        got = blas3.gemm(A, B, C, tile=TILE, config=config, **r)
        want = blas3.ref_gemm(A, B, C, **r)
    elif routine == "syrk":
        tr = r["trans"]
        A = _f32(rng, *((M, K) if tr == "N" else (K, M)))
        C = _f32(rng, M, M) if r.get("beta") else None
        got = blas3.syrk(A, C, tile=TILE, config=config, **r)
        want = blas3.ref_syrk(A, C, **r)
    elif routine == "syr2k":
        tr = r["trans"]
        shape = (M, K) if tr == "N" else (K, M)
        A, B = _f32(rng, *shape), _f32(rng, *shape)
        C = _f32(rng, M, M) if r.get("beta") else None
        got = blas3.syr2k(A, B, C, tile=TILE, config=config, **r)
        want = blas3.ref_syr2k(A, B, C, **r)
    elif routine == "symm":
        side = r["side"]
        d = M if side == "L" else N
        A = _f32(rng, d, d)
        B = _f32(rng, M, N)
        C = _f32(rng, M, N) if r.get("beta") else None
        got = blas3.symm(A, B, C, tile=TILE, config=config, **r)
        want = blas3.ref_symm(A, B, C, **r)
    elif routine in ("trmm", "trsm"):
        side = r["side"]
        d = M if side == "L" else N
        A = _f32(rng, d, d)
        if routine == "trsm":  # keep the solve well-conditioned in f32
            A = A + d * np.eye(d, dtype=np.float32)
        B = _f32(rng, M, N)
        fn = blas3.trmm if routine == "trmm" else blas3.trsm
        ref = blas3.ref_trmm if routine == "trmm" else blas3.ref_trsm
        got = fn(A, B, tile=TILE, config=config, **r)
        want = ref(A, B, **r)
    else:  # pragma: no cover
        raise ValueError(routine)
    return got, want


CASES = [
    dict(routine="gemm", transa="N", transb="N"),
    dict(routine="gemm", transa="N", transb="T", beta=0.5),
    dict(routine="gemm", transa="T", transb="N", alpha=-0.5),
    dict(routine="gemm", transa="T", transb="T"),
    dict(routine="syrk", uplo="U", trans="N"),
    dict(routine="syrk", uplo="U", trans="T", beta=0.3),
    dict(routine="syrk", uplo="L", trans="N", alpha=0.7),
    dict(routine="syrk", uplo="L", trans="T"),
    dict(routine="syr2k", uplo="U", trans="N"),
    dict(routine="syr2k", uplo="U", trans="T"),
    dict(routine="syr2k", uplo="L", trans="N", beta=1.5),
    dict(routine="syr2k", uplo="L", trans="T"),
    dict(routine="symm", side="L", uplo="U"),
    dict(routine="symm", side="L", uplo="L", beta=0.5),
    dict(routine="symm", side="R", uplo="U"),
    dict(routine="symm", side="R", uplo="L"),
    dict(routine="trmm", side="L", uplo="U", transa="N"),
    dict(routine="trmm", side="L", uplo="L", transa="T", diag="U"),
    dict(routine="trmm", side="R", uplo="U", transa="T"),
    dict(routine="trmm", side="R", uplo="L", transa="N"),
    dict(routine="trsm", side="L", uplo="U", transa="N"),
    dict(routine="trsm", side="L", uplo="L", transa="T", diag="U"),
    dict(routine="trsm", side="R", uplo="U", transa="T"),
    dict(routine="trsm", side="R", uplo="L", transa="N"),
]


def _case_id(case):
    return "-".join(str(v) for v in case.values())


def _parity_params():
    params = []
    for backend in ("numpy", "jax", "pallas"):
        smoke_done = set()
        for case in CASES:
            marks = []
            if backend == "pallas":
                # interpret mode is slow on CPU: one fast case per
                # routine, the rest ride the slow lane
                if case["routine"] in smoke_done:
                    marks.append(pytest.mark.slow)
                smoke_done.add(case["routine"])
            params.append(pytest.param(
                backend, case, marks=marks,
                id=f"{backend}-{_case_id(case)}"))
    return params


@pytest.mark.parametrize("backend,case", _parity_params())
def test_backend_parity(backend, case):
    got, want = _run_case(case, backend)
    np.testing.assert_allclose(got, want, **TOL)


# ===================================================== launch accounting
def test_batched_dispatch_fewer_launches_than_tasks():
    """The acceptance property: batched backends issue strictly fewer
    kernel launches than scheduled tile tasks (and far fewer than
    k-steps); the per-step numpy baseline pays one launch per step."""
    rng = np.random.default_rng(0)
    A = rng.standard_normal((256, 256)).astype(np.float32)
    B = rng.standard_normal((256, 256)).astype(np.float32)
    per_backend = {}
    for backend in ("numpy", "jax"):
        rt = BlasxRuntime(cfg(backend, n_devices=1))
        out = blas3.gemm(A, B, tile=32, runtime=rt)
        np.testing.assert_allclose(out, A @ B, **TOL)
        per_backend[backend] = rt.launch_stats()
    jx, npy = per_backend["jax"], per_backend["numpy"]
    assert jx["tasks"] == 64 and jx["steps"] == 512
    assert jx["kernel_launches"] < jx["tasks"] < jx["steps"]
    assert jx["launches_saved"] == jx["steps"] - jx["kernel_launches"]
    # numpy = seed behavior: a launch per step, nothing saved
    assert npy["kernel_launches"] == npy["steps"] == 512
    assert npy["launches_saved"] == 0


def test_ledger_attributes_engines_pallas_fallback():
    """PallasBackend routes full-fill groups to the pallas engine and
    sym-fill diagonal steps to the jax fallback; the ledger splits the
    flops accordingly and accounts every dispatched step."""
    rng = np.random.default_rng(1)
    A = rng.standard_normal((96, 96)).astype(np.float32)
    B = rng.standard_normal((96, 64)).astype(np.float32)
    rt = BlasxRuntime(cfg("pallas", n_devices=1))
    out = blas3.symm(A, B, tile=32, runtime=rt)
    np.testing.assert_allclose(out, blas3.ref_symm(A, B), **TOL)
    ls = rt.launch_stats()
    assert ls["engine_flops"].get("pallas", 0) > 0   # full-fill rows
    assert ls["engine_flops"].get("jax", 0) > 0      # sym-fill diagonal
    total = sum(d.ledger.flops for d in rt.devices)
    assert sum(ls["engine_flops"].values()) == total
    assert ls["steps"] == 18   # 3x2 output tiles x 3 k-steps each


def test_launch_stats_reset():
    rng = np.random.default_rng(2)
    A = rng.standard_normal((64, 64))
    rt = BlasxRuntime(cfg("jax", n_devices=1))
    blas3.gemm(A, A, tile=32, runtime=rt)
    assert rt.launch_stats()["kernel_launches"] > 0
    rt.reset_stats()
    ls = rt.launch_stats()
    assert ls["kernel_launches"] == 0 and ls["steps"] == 0
    assert ls["engine_flops"] == {}


def test_threads_mode_jax_parity():
    """Batched dispatch composes with the faithful threaded engine."""
    rng = np.random.default_rng(3)
    A = rng.standard_normal((96, 80)).astype(np.float32)
    B = rng.standard_normal((80, 96)).astype(np.float32)
    out = blas3.gemm(A, B, tile=32,
                     config=cfg("jax", n_devices=2, mode="threads"))
    np.testing.assert_allclose(out, A @ B, **TOL)


# ==================================================== selection threading
def test_backend_selection_through_api_layers():
    from repro.api import BlasxContext, cblas

    rng = np.random.default_rng(4)
    A = rng.standard_normal((48, 32))
    B = rng.standard_normal((32, 40))
    # context kwarg
    with BlasxContext(backend="jax", tile=16) as ctx:
        out = ctx.gemm(A, B)
        st = ctx.stats()
        assert st["backend"] == "jax"
        assert st["launch"]["kernel_launches"] < st["launch"]["tasks"]
        np.testing.assert_allclose(out.array(), A @ B, **TOL)
    # legacy wrapper kwarg
    np.testing.assert_allclose(blas3.gemm(A, B, tile=16, backend="jax"),
                               A @ B, **TOL)
    # cblas kwarg (float64 in-place contract, f32 engine compute)
    C = np.zeros((48, 40))
    cblas.cblas_dgemm(cblas.CblasRowMajor, cblas.CblasNoTrans,
                      cblas.CblasNoTrans, 48, 40, 32, 1.0, A, 32,
                      B, 40, 0.0, C, 40, backend="jax")
    np.testing.assert_allclose(C, A @ B, **TOL)


def test_backend_mismatch_and_unknown_rejected():
    from repro.api import BlasxContext

    rt = BlasxRuntime(cfg("numpy"))
    with pytest.raises(ValueError, match="backend"):
        BlasxContext(runtime=rt, backend="jax")
    with pytest.raises(ValueError, match="unknown backend"):
        RuntimeConfig(backend="cuda")
    with pytest.raises(ValueError, match="unknown backend"):
        create_backend("nope")
    assert set(available_backends()) == {"numpy", "jax", "pallas"}


def test_legacy_kernel_alias():
    assert RuntimeConfig(kernel="jax").backend == "jax"
    assert RuntimeConfig(backend="pallas").kernel == "pallas"
    # explicit backend wins over the legacy spelling
    assert RuntimeConfig(kernel="numpy", backend="jax").kernel == "jax"


def test_execute_false_skips_dispatch():
    """Metadata-only runs schedule and account but never launch."""
    from repro.core.blas3 import shadow_run

    rt = BlasxRuntime(cfg("jax", n_devices=2, execute=False))
    shadow_run("gemm", 2048, tile=256, runtime=rt)
    ls = rt.launch_stats()
    assert ls["tasks"] > 0
    assert ls["kernel_launches"] == 0 and ls["steps"] == 0
