"""Distributed ring-GEMM tests.  jax locks the device count at first
init, so multi-device cases run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count set there."""
import os
import subprocess
import sys
import textwrap


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices} "
                        + env.get("XLA_FLAGS", "")).strip()
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=300)
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    return proc.stdout


COMMON = """
import jax, numpy as np, jax.numpy as jnp
from repro.core import distributed as dist
mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
"""


def test_distributed_gemm_ring_matches_oracle():
    out = run_with_devices(COMMON + """
A = jnp.asarray(rng.standard_normal((64, 128)), jnp.float32)
B = jnp.asarray(rng.standard_normal((128, 96)), jnp.float32)
want = np.asarray(A @ B)
for mode in ["ring", "gspmd"]:
    C = dist.distributed_gemm(A, B, mesh, mode=mode)
    err = np.abs(np.asarray(C) - want).max()
    assert err < 1e-3, (mode, err)
print("OK")
""")
    assert "OK" in out


def test_tp_matmul_column_row_roundtrip():
    out = run_with_devices(COMMON + """
x = jnp.asarray(rng.standard_normal((2, 32, 128)), jnp.float32)
w1 = jnp.asarray(rng.standard_normal((128, 256)), jnp.float32)
w2 = jnp.asarray(rng.standard_normal((256, 128)), jnp.float32)
want = np.asarray(jnp.einsum('bsf,fd->bsd',
                  jnp.einsum('bsd,df->bsf', x, w1), w2))
for mode in ["ring", "gspmd"]:
    y = dist.tp_matmul(x, w1, mesh, kind="column", mode=mode)
    z = dist.tp_matmul(y, w2, mesh, kind="row", mode=mode)
    err = np.abs(np.asarray(z) - want).max()
    assert err < 5e-3, (mode, err)
print("OK")
""")
    assert "OK" in out


def test_ring_uses_collective_permute_not_allgather():
    """The BLASX overlap schedule must lower to neighbor ppermutes (the
    ICI 'P2P' path), not monolithic all-gathers."""
    out = run_with_devices(COMMON + """
A = jnp.zeros((64, 128), jnp.float32)
B = jnp.zeros((128, 96), jnp.float32)
ring = jax.jit(lambda a, b: dist.distributed_gemm(a, b, mesh, mode="ring"))
txt = ring.lower(A, B).compile().as_text()
n_perm = txt.count("collective-permute")
assert n_perm >= 2, f"expected ring ppermutes, found {n_perm}"
print("OK", n_perm)
""")
    assert "OK" in out


def test_ragged_shapes_pad_and_slice():
    """Regression: shapes not divisible by the ring size used to
    hard-error (``rows 3 not divisible by ring size 4``); the kernels
    now pad-and-slice internally, so real (ragged) serving shapes work
    at pod scale and still match the dense oracle."""
    out = run_with_devices(COMMON + """
# M=61 ragged vs the 2-wide row axis, K=99 ragged vs the 4-wide column
A = jnp.asarray(rng.standard_normal((61, 99)), jnp.float32)
B = jnp.asarray(rng.standard_normal((99, 96)), jnp.float32)
want = np.asarray(jnp.dot(A, B, preferred_element_type=jnp.float32))
for mode in ["ring", "gspmd"]:
    C = dist.distributed_gemm(A, B, mesh, mode=mode)
    assert C.shape == (61, 96), (mode, C.shape)
    err = np.abs(np.asarray(C) - want).max()
    assert err < 1e-3, (mode, err)
# seq=3 ragged vs the 4-wide ring (this exact shape used to raise)
x = jnp.asarray(rng.standard_normal((1, 3, 128)), jnp.float32)
w1 = jnp.asarray(rng.standard_normal((128, 256)), jnp.float32)
w2 = jnp.asarray(rng.standard_normal((256, 128)), jnp.float32)
want = np.asarray(jnp.einsum('bsf,fd->bsd',
                  jnp.einsum('bsd,df->bsf', x, w1), w2))
for mode in ["ring", "gspmd"]:
    y = dist.tp_matmul(x, w1, mesh, kind="column", mode=mode,
                       batch_axis=None)
    assert y.shape == (1, 3, 256), (mode, y.shape)
    z = dist.tp_matmul(y, w2, mesh, kind="row", mode=mode,
                       batch_axis=None)
    assert z.shape == (1, 3, 128), (mode, z.shape)
    err = np.abs(np.asarray(z) - want).max()
    assert err < 5e-3, (mode, err)
print("OK")
""")
    assert "OK" in out


def test_ring_vs_gspmd_dtype_matrix():
    """Parity of every ring kernel against its gspmd twin across
    {f64, f32, bf16} on the forced-host 8-device mesh — the ring
    schedule may reorder the reduction but must stay within summation-
    order noise of the oracle, in every precision the library serves."""
    out = run_with_devices("""
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core import distributed as dist
from repro.kernels.pallas_compat import shard_map
mesh = jax.make_mesh((2, 4), ("data", "model"))
ring = jax.make_mesh((8,), ("r",))
rng = np.random.default_rng(7)

TOL = {jnp.float64: 1e-5, jnp.float32: 1e-3, jnp.bfloat16: 1.0}
for dtype, tol in TOL.items():
    A = jnp.asarray(rng.standard_normal((64, 128)), dtype)
    B = jnp.asarray(rng.standard_normal((128, 96)), dtype)
    want = (np.asarray(A, np.float64) @ np.asarray(B, np.float64)
            ).astype(np.float32)
    # raw shard_map twins on the flat 8-ring
    ag = {}
    rs = {}
    for mode, (ag_fn, rs_fn) in dist.MODES.items():
        f = shard_map(lambda a, b: ag_fn(a, b, "r"), mesh=ring,
                      in_specs=(P("r", None), P(None, "r")),
                      out_specs=P(None, "r"), check_rep=False)
        ag[mode] = np.asarray(f(A, B), np.float32)
        f = shard_map(lambda a, b: rs_fn(a, b, "r"), mesh=ring,
                      in_specs=(P(None, "r"), P("r", None)),
                      out_specs=P("r", None), check_rep=False)
        rs[mode] = np.asarray(f(A, B), np.float32)
    for kind in (ag, rs):
        assert np.abs(kind["ring"] - want).max() < tol, (dtype, tol)
        assert np.abs(kind["ring"] - kind["gspmd"]).max() < tol, dtype
    # tp_matmul, both kinds, both modes (includes the padded-ragged
    # path: seq=30 is ragged vs the 4-wide model axis)
    x = jnp.asarray(rng.standard_normal((2, 30, 128)), dtype)
    w1 = jnp.asarray(rng.standard_normal((128, 256)), dtype)
    w2 = jnp.asarray(rng.standard_normal((256, 128)), dtype)
    x64 = np.asarray(x, np.float64)
    want = np.einsum('bsf,fd->bsd',
                     np.einsum('bsd,df->bsf', x64, np.asarray(w1, np.float64)),
                     np.asarray(w2, np.float64)).astype(np.float32)
    z = {}
    for mode in ["ring", "gspmd"]:
        y = dist.tp_matmul(x, w1, mesh, kind="column", mode=mode)
        z[mode] = np.asarray(
            dist.tp_matmul(y, w2, mesh, kind="row", mode=mode), np.float32)
        assert z[mode].shape == want.shape, (mode, z[mode].shape)
        assert np.abs(z[mode] - want).max() < 8 * tol, (dtype, mode)
    assert np.abs(z["ring"] - z["gspmd"]).max() < 8 * tol, dtype
    print("dtype ok", np.dtype(dtype).name)
print("OK")
""")
    assert "OK" in out
    for name in ("float64", "float32", "bfloat16"):
        assert f"dtype ok {name}" in out


def test_bf16_ring_numerics():
    out = run_with_devices(COMMON + """
A = jnp.asarray(rng.standard_normal((64, 128)), jnp.bfloat16)
B = jnp.asarray(rng.standard_normal((128, 96)), jnp.bfloat16)
C = dist.distributed_gemm(A, B, mesh, mode="ring")
want = np.asarray(jnp.dot(A.astype(jnp.float32), B.astype(jnp.float32)))
err = np.abs(np.asarray(C, np.float32) - want).max()
assert err < 1.0, err   # bf16 tolerance
print("OK")
""")
    assert "OK" in out
