"""Distributed ring-GEMM tests.  jax locks the device count at first
init, so multi-device cases run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count set there."""
import os
import subprocess
import sys
import textwrap


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices} "
                        + env.get("XLA_FLAGS", "")).strip()
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=300)
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    return proc.stdout


COMMON = """
import jax, numpy as np, jax.numpy as jnp
from repro.core import distributed as dist
mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
"""


def test_distributed_gemm_ring_matches_oracle():
    out = run_with_devices(COMMON + """
A = jnp.asarray(rng.standard_normal((64, 128)), jnp.float32)
B = jnp.asarray(rng.standard_normal((128, 96)), jnp.float32)
want = np.asarray(A @ B)
for mode in ["ring", "gspmd"]:
    C = dist.distributed_gemm(A, B, mesh, mode=mode)
    err = np.abs(np.asarray(C) - want).max()
    assert err < 1e-3, (mode, err)
print("OK")
""")
    assert "OK" in out


def test_tp_matmul_column_row_roundtrip():
    out = run_with_devices(COMMON + """
x = jnp.asarray(rng.standard_normal((2, 32, 128)), jnp.float32)
w1 = jnp.asarray(rng.standard_normal((128, 256)), jnp.float32)
w2 = jnp.asarray(rng.standard_normal((256, 128)), jnp.float32)
want = np.asarray(jnp.einsum('bsf,fd->bsd',
                  jnp.einsum('bsd,df->bsf', x, w1), w2))
for mode in ["ring", "gspmd"]:
    y = dist.tp_matmul(x, w1, mesh, kind="column", mode=mode)
    z = dist.tp_matmul(y, w2, mesh, kind="row", mode=mode)
    err = np.abs(np.asarray(z) - want).max()
    assert err < 5e-3, (mode, err)
print("OK")
""")
    assert "OK" in out


def test_ring_uses_collective_permute_not_allgather():
    """The BLASX overlap schedule must lower to neighbor ppermutes (the
    ICI 'P2P' path), not monolithic all-gathers."""
    out = run_with_devices(COMMON + """
A = jnp.zeros((64, 128), jnp.float32)
B = jnp.zeros((128, 96), jnp.float32)
ring = jax.jit(lambda a, b: dist.distributed_gemm(a, b, mesh, mode="ring"))
txt = ring.lower(A, B).compile().as_text()
n_perm = txt.count("collective-permute")
assert n_perm >= 2, f"expected ring ppermutes, found {n_perm}"
print("OK", n_perm)
""")
    assert "OK" in out


def test_ring_odd_sizes_raise_cleanly():
    out = run_with_devices(COMMON + """
from repro.core.distributed import ring_reduce_scatter_matmul
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
x = jnp.zeros((2, 30, 128), jnp.float32)  # 30 rows not divisible by 4
w = jnp.zeros((128, 64), jnp.float32)
try:
    dist.tp_matmul(x, jnp.zeros((128, 64), jnp.float32), mesh, kind="row")
except Exception:
    print("OK raised")
else:
    # 30*2=60 rows over ring of 4 -> 60%4==0 actually fine; force odd
    try:
        xo = jnp.zeros((1, 3, 128), jnp.float32)
        dist.tp_matmul(xo, w, mesh, kind="row")
        print("unexpected success")
    except Exception:
        print("OK raised")
""")
    assert "OK raised" in out


def test_bf16_ring_numerics():
    out = run_with_devices(COMMON + """
A = jnp.asarray(rng.standard_normal((64, 128)), jnp.bfloat16)
B = jnp.asarray(rng.standard_normal((128, 96)), jnp.bfloat16)
C = dist.distributed_gemm(A, B, mesh, mode="ring")
want = np.asarray(jnp.dot(A.astype(jnp.float32), B.astype(jnp.float32)))
err = np.abs(np.asarray(C, np.float32) - want).max()
assert err < 1.0, err   # bf16 tolerance
print("OK")
""")
    assert "OK" in out
