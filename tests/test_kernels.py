"""Per-kernel validation: shape/dtype sweeps + hypothesis properties,
always against the pure-jnp oracle, in interpret mode (CPU container)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -e .[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import ops, ref  # noqa: E402

jax.config.update("jax_enable_x64", False)


def _mk(m, k, n, dtype, seed=0):
    r1 = np.random.default_rng(seed)
    a = r1.standard_normal((m, k)).astype(dtype)
    b = r1.standard_normal((k, n)).astype(dtype)
    return jnp.asarray(a), jnp.asarray(b)


SHAPES = [
    (8, 8, 8),            # tiny
    (128, 128, 128),      # exactly one block
    (256, 512, 384),      # multi-block, aligned
    (100, 70, 130),       # ragged everything (padding path)
    (1, 200, 300),        # degenerate M
    (513, 129, 257),      # off-by-one over alignment
]


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_matmul_shapes_dtypes(m, k, n, dtype):
    a, b = _mk(m, k, n, dtype)
    out = ops.matmul(a, b, interpret=True)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=2e-2 if dtype == jnp.bfloat16 else 1e-4)


@pytest.mark.parametrize("activation", [None, "relu", "gelu", "silu", "tanh"])
def test_matmul_fused_epilogue(activation):
    a, b = _mk(96, 64, 160, np.float32, seed=3)
    bias = jnp.asarray(np.random.default_rng(4).standard_normal(160),
                       jnp.float32)
    out = ops.matmul(a, b, bias, activation=activation, interpret=True)
    want = ref.matmul_ref(a, b, bias, activation=activation)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_matmul_explicit_blocks():
    a, b = _mk(256, 256, 256, np.float32, seed=5)
    for bm, bn, bk in [(128, 128, 128), (64, 128, 256), (256, 256, 128)]:
        out = ops.matmul(a, b, block_m=bm, block_n=bn, block_k=bk,
                         interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b),
                                   rtol=1e-4, atol=1e-4)


def test_matmul_out_dtype_cast():
    a, b = _mk(64, 64, 64, np.float32, seed=6)
    out = ops.matmul(a, b, out_dtype=jnp.bfloat16, interpret=True)
    assert out.dtype == jnp.bfloat16


def test_matmul_shape_errors():
    a, b = _mk(32, 16, 32, np.float32)
    with pytest.raises(ValueError):
        ops.matmul(a, jnp.zeros((17, 32), jnp.float32), interpret=True)
    with pytest.raises(ValueError):
        ops.matmul(a, b, bias=jnp.zeros((7,)), interpret=True)


def test_block_heuristic_respects_vmem():
    from repro.kernels.ops import VMEM_BUDGET, default_blocks
    for m, n, k, isz in [(8192, 8192, 8192, 2), (4096, 11008, 4096, 4),
                         (33, 100000, 7, 4)]:
        bm, bn, bk = default_blocks(m, n, k, isz)
        wset = (bm * bk + bk * bn) * isz + bm * bn * 4 + bm * bn * isz
        assert wset <= VMEM_BUDGET
        assert bn % 128 == 0 or bn >= n
        assert bk % 128 == 0 or bk >= k


@settings(max_examples=12, deadline=None)
@given(
    m=st.integers(1, 150), k=st.integers(1, 150), n=st.integers(1, 150),
    alpha_act=st.sampled_from([None, "relu", "silu"]),
    use_bias=st.booleans(),
)
def test_matmul_property_random_shapes(m, k, n, alpha_act, use_bias):
    """Property: kernel == oracle for arbitrary shapes (padding path)."""
    a, b = _mk(m, k, n, np.float32, seed=m * 7919 + k * 31 + n)
    bias = (jnp.asarray(np.random.default_rng(n).standard_normal(n),
                        jnp.float32) if use_bias else None)
    out = ops.matmul(a, b, bias, activation=alpha_act, interpret=True)
    want = ref.matmul_ref(a, b, bias, activation=alpha_act)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_pallas_kernel_inside_blasx_runtime():
    """The TPU tile kernel composes with the reproduction runtime."""
    from repro.core import gemm
    from repro.core.runtime import RuntimeConfig
    rng = np.random.default_rng(8)
    A = rng.standard_normal((96, 64)).astype(np.float32)
    B = rng.standard_normal((64, 96)).astype(np.float32)
    out = gemm(A, B, tile=32,
               config=RuntimeConfig(n_devices=2, mode="sim",
                                    kernel="pallas"))
    np.testing.assert_allclose(out, A @ B, rtol=1e-4, atol=1e-4)


# ===================================================== flash attention
from repro.kernels.flash_attention import flash_attention  # noqa: E402
from repro.kernels.ref import flash_attention_ref  # noqa: E402

FLASH_CASES = [
    # (B, Sq, Sk, H, Hkv, D, causal)
    (2, 256, 256, 4, 4, 64, True),     # MHA causal, aligned
    (1, 200, 200, 4, 2, 32, True),     # GQA, ragged (padding path)
    (2, 128, 384, 8, 2, 64, False),    # cross-attn shape, GQA 4x
    (1, 130, 130, 2, 1, 16, True),     # MQA, tiny head dim
    (1, 64, 64, 1, 1, 128, True),      # single head, single block
]


@pytest.mark.parametrize("B,Sq,Sk,H,Hkv,D,causal", FLASH_CASES)
def test_flash_attention_vs_oracle(B, Sq, Sk, H, Hkv, D, causal):
    rng = np.random.default_rng(B * 31 + Sq)
    q = jnp.asarray(rng.standard_normal((B, Sq, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Sk, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Sk, Hkv, D)), jnp.float32)
    o = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                        interpret=True)
    r = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.standard_normal((1, 128, 4, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 128, 4, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 128, 4, 64)), jnp.bfloat16)
    o = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                        interpret=True)
    r = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_flash_attention_block_shape_independence():
    rng = np.random.default_rng(6)
    q = jnp.asarray(rng.standard_normal((1, 192, 2, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 192, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 192, 2, 32)), jnp.float32)
    outs = [flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                            interpret=True)
            for bq, bk in [(64, 64), (64, 128), (192, 64)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-5, atol=1e-5)
