"""Work-centric (Stream-K) decomposition acceptance: split-planner
units, bitwise parity with owner mode on every routine x precision x
time model x execution mode, fix-up ordering in the Chrome trace,
ledger attribution, the steal-cannot-strand-a-fixup property, and the
shape-bucket aliasing bugfix that motivated the sweep."""
import numpy as np
import pytest

from repro.core import blas3
from repro.core import task as taskmod
from repro.core.runtime import BlasxRuntime, RuntimeConfig
from repro.core.task import KIND_FIXUP, KIND_OWNER, KIND_PARTIAL
from repro.core.taskqueue import ReadyQueue, ReservationStation
from repro.core.tiling import (TileGrid, degree_of_parallelism,
                               split_ranges, workcentric_parts)

RNG = np.random.default_rng(17)


def _cfg(**kw):
    kw.setdefault("n_devices", 3)
    kw.setdefault("mode", "sim")
    kw.setdefault("cache_bytes", 32 << 20)
    return RuntimeConfig(**kw)


# ------------------------------------------------------- planner units
@pytest.mark.parametrize("n_steps,n_parts", [
    (3, 2), (7, 3), (8, 8), (5, 1), (12, 5)])
def test_split_ranges_is_an_exact_partition(n_steps, n_parts):
    ranges = split_ranges(n_steps, n_parts)
    assert len(ranges) == min(n_parts, n_steps)
    covered = [k for start, stop in ranges for k in range(start, stop)]
    assert covered == list(range(n_steps))        # contiguous, in order
    sizes = [stop - start for start, stop in ranges]
    assert max(sizes) - min(sizes) <= 1           # balanced


def test_split_ranges_rejects_nonpositive_parts():
    with pytest.raises(ValueError):
        split_ranges(4, 0)


def test_workcentric_parts_triggers():
    # small problem: 6 owners < capacity 16 -> fill two waves
    assert workcentric_parts(32, 6, 16, ragged=False) == 6  # ceil(32/6)
    # the floor is 2 parts even when one extra task would fill capacity
    assert workcentric_parts(32, 15, 16, ragged=False) == 3
    # never more parts than k-steps
    assert workcentric_parts(2, 1, 16, ragged=False) == 2
    # large problem: only ragged tiles split, and only in half
    assert workcentric_parts(32, 100, 16, ragged=True) == 2
    assert workcentric_parts(32, 100, 16, ragged=False) == 0
    # a 1-step k-loop can never split
    assert workcentric_parts(1, 2, 16, ragged=True) == 0


def _gemm_tasks(n, tile, k=None):
    k = n if k is None else k
    ga = TileGrid("A", n, k, tile)
    gb = TileGrid("B", k, n, tile)
    gc = TileGrid("C", n, n, tile)
    grids = {"A": ga, "B": gb, "C": gc}
    return taskmod.taskize_gemm(ga, gb, gc, "N", "N", 1.0, 0.5), grids


def test_plan_small_problem_splits_every_task():
    tasks, grids = _gemm_tasks(256, 128)          # 4 owners, 2 k-steps
    planned = taskmod.plan_work_centric(tasks, grids, capacity=8)
    owners = [t for t in planned if t.kind == KIND_OWNER]
    partials = [t for t in planned if t.kind == KIND_PARTIAL]
    fixups = [t for t in planned if t.kind == KIND_FIXUP]
    assert not owners                             # 4 < 8: all tasks split
    assert len(fixups) == len(tasks)
    assert len(partials) == 2 * len(tasks)        # min(2 steps, ...) = 2
    for f in fixups:
        orig = next(t for t in tasks if t.task_id == f.task_id)
        sibs = [p for p in partials if p.parent == f.task_id]
        # the fix-up keeps the owner id/steps/beta so downstream deps
        # and the C_ij write stay exactly owner-shaped
        assert f.steps == orig.steps and f.beta == orig.beta
        assert set(f.deps) >= {p.task_id for p in sibs}
        # partials never write: beta forced to 0, k_range recorded
        assert all(p.beta == 0.0 for p in sibs)
        ranges = sorted(p.k_range for p in sibs)
        assert ranges[0][0] == 0 and ranges[-1][1] == len(orig.steps)
        # MAC flops live on the partials; the fix-up charges the join
        assert sum(p.flops for p in sibs) == orig.flops
        h, w = grids["C"].tile_shape(f.i, f.j)
        assert f.flops == len(sibs) * h * w


def test_plan_large_problem_splits_only_ragged_tiles():
    tasks, grids = _gemm_tasks(576, 128)          # 5x5 owners, edge 64
    planned = taskmod.plan_work_centric(tasks, grids, capacity=8)
    split_ids = {t.task_id for t in planned if t.kind == KIND_FIXUP}
    gc = grids["C"]
    for t in tasks:
        ragged = gc.tile_shape(t.i, t.j) != (128, 128)
        assert (t.task_id in split_ids) == ragged
    # interior tasks pass through untouched (same object, owner kind)
    interior = [t for t in planned if t.kind == KIND_OWNER]
    assert all(gc.tile_shape(t.i, t.j) == (128, 128) for t in interior)


def test_plan_narrows_partial_deps_to_their_k_range():
    """TRSM's intra-column chain: the producer of C_kj is only a dep of
    the partial whose k-range actually reads that tile."""
    n, tile = 512, 128
    ga = TileGrid("A", n, n, tile)
    gb = TileGrid("B", n, n, tile)
    gc = TileGrid("C", n, n, tile)
    grids = {"A": ga, "B": gb, "C": gc}
    tasks = taskmod.taskize_trsm(ga, gb, gc, "U", "N", "N", 1.0)
    dep_full = {t.task_id: t for t in tasks}
    planned = taskmod.plan_work_centric(tasks, grids, capacity=64)
    narrowed = 0
    for p in (t for t in planned if t.kind == KIND_PARTIAL):
        owner = dep_full[p.parent]
        assert set(p.deps) <= set(owner.deps)
        start, stop = p.k_range
        read = {s.a.key for s in p.steps} | {s.b.key for s in p.steps}
        for d in p.deps:
            assert dep_full[d].out in read    # dep produces a read tile
        narrowed += len(owner.deps) - len(p.deps)
    assert narrowed > 0   # at least one partial dropped an off-range dep


def test_degree_of_parallelism_counts_partial_tasks():
    # owner mode: Eq. 2 unchanged
    assert degree_of_parallelism(512, 512, 128) == 16
    # small problem, wc on: 4 owners < capacity 8 -> 4 parts each
    # (capacity fill: ceil(2*8/4) = 4), so 4 owners + 4*4 partials
    assert degree_of_parallelism(256, 256, 128, k=512,
                                 work_centric=True, capacity=8) == 20
    # 1-step k-loop: nothing to split
    assert degree_of_parallelism(256, 256, 128, k=128,
                                 work_centric=True, capacity=8) == 4


# ------------------------------------------ shape-bucket aliasing bugfix
def test_shape_bucket_no_longer_aliases_4100_into_8192():
    """Fails before the geometric-midpoint edges: 4100^3 rounded to
    8192^3 — a ~7.97x FLOP inflation — so the tuner swept a problem
    8x the real one and could crown a tile that loses at the true
    shape.  Midpoint edges cap cubic inflation at ~2.83x."""
    from repro.tuning.autotuner import shape_bucket

    bucket = shape_bucket(4100, 4100, 4100)
    assert bucket == (5793, 5793, 5793)
    inflation = (bucket[0] * bucket[1] * bucket[2]) / 4100 ** 3
    assert inflation <= 4.0                       # was ~7.97x
    # idempotent: a bucket edge maps to itself
    assert shape_bucket(*bucket) == bucket
    # legacy edges preserved (docs/TUNING.md example + floor)
    assert shape_bucket(1000, 900, 1020) == (1024, 1024, 1024)
    assert shape_bucket(300, 1, 64) == (362, 64, 64)


# ------------------------------------------------------- bitwise parity
def _run_routine(routine, dtype, *, work_centric, time_model="lump",
                 mode="sim", backend=None):
    n, tile = 320, 128   # ragged edge tiles included
    rng = np.random.default_rng(42)  # identical operands per config
    A = rng.standard_normal((n, n))
    B = rng.standard_normal((n, n))
    C = rng.standard_normal((n, n))
    cfg = _cfg(time_model=time_model, mode=mode,
               work_centric=work_centric)
    kw = dict(tile=tile, config=cfg, dtype=dtype, backend=backend)
    if routine == "gemm":
        return blas3.gemm(A, B, C, beta=0.5, **kw)
    if routine == "symm":
        return blas3.symm(A, B, **kw)
    if routine == "syrk":
        return blas3.syrk(A, C, beta=0.5, uplo="L", **kw)
    if routine == "syr2k":
        return blas3.syr2k(A, B, **kw)
    if routine == "trmm":
        return blas3.trmm(A, B, uplo="L", **kw)
    if routine == "trsm":
        return blas3.trsm(A + n * np.eye(n), B, **kw)
    raise AssertionError(routine)


@pytest.mark.parametrize("dtype", [np.float64, np.float32],
                         ids=["f64", "f32"])
@pytest.mark.parametrize(
    "routine", ["gemm", "symm", "syrk", "syr2k", "trmm", "trsm"])
def test_workcentric_bitwise_parity(routine, dtype):
    """The Stream-K schedule only moves modeled clocks: the fix-up
    re-dispatches the full original k-loop through the identical
    backend path, so outputs are *bitwise* identical to owner mode on
    every routine and precision, under both time models."""
    owner = _run_routine(routine, dtype, work_centric=False)
    wc_lump = _run_routine(routine, dtype, work_centric=True)
    wc_events = _run_routine(routine, dtype, work_centric=True,
                             time_model="events")
    assert owner.dtype == wc_lump.dtype == wc_events.dtype
    assert np.array_equal(owner, wc_lump)
    assert np.array_equal(owner, wc_events)


def test_workcentric_threads_mode_bitwise_parity():
    """Threads mode really schedules the partial/fix-up graph across
    worker threads (the lock-witness CI lane runs this file, so every
    lock acquired on the path is order-tracked); any schedule must
    reproduce the sim-mode owner result bit for bit."""
    owner = _run_routine("gemm", np.float64, work_centric=False)
    for _ in range(3):   # racy schedules differ run to run; results can't
        wc = _run_routine("gemm", np.float64, work_centric=True,
                          mode="threads")
        assert np.array_equal(owner, wc)


def test_workcentric_jax_backend_parity():
    owner = _run_routine("gemm", np.float64, work_centric=False,
                         backend="jax")
    wc = _run_routine("gemm", np.float64, work_centric=True,
                      backend="jax")
    assert np.array_equal(owner, wc)


# --------------------------------------------------- ledger attribution
def test_ledger_attributes_partial_and_fixup_work():
    n, tile = 320, 128    # 3x3 owners with ragged edges, 3 k-steps
    rt = BlasxRuntime(_cfg(n_devices=2, work_centric=True))
    A = RNG.standard_normal((n, n))
    out = blas3.gemm(A, A, tile=tile, runtime=rt)
    np.testing.assert_allclose(out, A @ A, rtol=1e-10, atol=1e-10)
    partials = sum(d.ledger.partial_tasks for d in rt.devices)
    fixups = sum(d.ledger.fixup_tasks for d in rt.devices)
    tasks = sum(d.ledger.tasks for d in rt.devices)
    # 2x4=8 capacity > 9 owners is false -> large-problem path: the 5
    # ragged tiles split in two, the 4 interior tiles stay owners
    assert fixups == 5 and partials == 10
    assert tasks == 4 + partials + fixups
    led = rt.devices[0].ledger
    assert led.partial_flops >= 0 and led.fixup_flops >= 0
    st = rt.stats()["device0"]
    for key in ("partial_tasks", "fixup_tasks",
                "partial_flops", "fixup_flops"):
        assert key in st


# ------------------------------------------------- trace kind + ordering
def test_trace_tags_partials_and_orders_fixups_after_siblings():
    """Compute spans carry the Stream-K role: partials point at their
    owner via ``parent`` and the fix-up (which keeps the owner's
    task_id) must never start before the last sibling partial ends —
    the determinism the reduction join is built on, visible in the
    artifact CI ships."""
    from repro.core.events import trace_spans, validate_trace

    n, tile = 320, 128
    rt = BlasxRuntime(_cfg(n_devices=2, work_centric=True,
                           time_model="events"))
    A = RNG.standard_normal((n, n))
    blas3.gemm(A, A, tile=tile, runtime=rt)
    tr = rt.trace()
    validate_trace(tr)
    compute = [s for s in trace_spans(tr) if s["cat"] == "compute"]
    partials = [s for s in compute if s["kind"] == "partial"]
    fixups = {s["task_id"]: s for s in compute if s["kind"] == "fixup"}
    assert partials and fixups
    for p in partials:
        f = fixups[p["parent"]]                  # every partial has its join
        assert f["start"] >= p["end"] - 1e-12


# ------------------------------------------------ stealing under partials
def test_ready_queue_never_releases_fixup_before_siblings():
    """Why steal() cannot strand a fix-up: a fix-up only ever reaches a
    reservation station once ALL its partials completed, and from that
    point it is runnable on any device — stealing it just moves the
    join.  Pin the release rule at the queue level."""
    tasks, grids = _gemm_tasks(256, 128)
    planned = taskmod.plan_work_centric(tasks, grids, capacity=8)
    partials = [t for t in planned if t.kind == KIND_PARTIAL]
    q = ReadyQueue(planned)
    drained = [q.try_dequeue() for _ in range(len(partials))]
    assert all(t is not None and t.kind == KIND_PARTIAL for t in drained)
    assert q.try_dequeue() is None               # every fix-up still held
    *rest, last = drained
    for t in rest:
        q.complete(t)
    # the other tiles' joins release, but the fix-up whose sibling
    # `last` is still in flight stays pending
    early = []
    while (t := q.try_dequeue()) is not None:
        early.append(t)
    assert all(t.kind == KIND_FIXUP for t in early)
    assert last.parent not in {t.task_id for t in early}
    assert q.pending_count() == 1
    q.complete(last)                             # last sibling lands...
    released = q.try_dequeue()
    assert released is not None and released.kind == KIND_FIXUP
    assert released.task_id == last.parent       # ...and frees its join
    # the join really waited on more than `last` alone
    assert any(t.parent == last.parent for t in rest)


def test_rs_steal_hands_over_a_runnable_fixup():
    tasks, grids = _gemm_tasks(256, 128)
    planned = taskmod.plan_work_centric(tasks, grids, capacity=8)
    fixup = next(t for t in planned if t.kind == KIND_FIXUP)
    victim = ReservationStation(device_id=0, n_slots=4)
    victim.put(fixup, priority=0.0)
    stolen = victim.steal()
    assert stolen is fixup and len(victim) == 0


def test_stealing_with_work_centric_completes_every_fixup():
    """Integration: a 16x speed skew forces the fast device to steal
    from the slow one's station mid-run; numerics stay exact and every
    split tile still gets exactly one fix-up executed."""
    n, tile = 320, 128
    rt = BlasxRuntime(_cfg(
        n_devices=2, work_centric=True,
        speeds=[4.0, 0.25], nominal_speeds=[4.0, 0.25]))
    A = RNG.standard_normal((n, n))
    B = RNG.standard_normal((n, n))
    out = blas3.gemm(A, B, tile=tile, runtime=rt)
    np.testing.assert_allclose(out, A @ B, rtol=1e-10, atol=1e-10)
    assert sum(d.ledger.steals for d in rt.devices) > 0
    assert sum(d.ledger.fixup_tasks for d in rt.devices) == 5
