"""blasxcheck static analyses (repro.analysis): each rule family has
a fails-before fixture reintroducing a shipped bug shape (PR 5 heap
tautology, PR 6 inline-callback deadlock, the serve_lock race, the
audit lock-order cycle), plus the real-tree gate: ``--strict src``
must be clean against the committed baseline.
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis import RULES, Baseline, run_analyses
from repro.analysis import assertions as as_mod
from repro.analysis import determinism as dt_mod
from repro.analysis import locks as ld_mod
from repro.analysis.findings import Finding, normalize_path, split_findings

REPO_ROOT = Path(__file__).resolve().parent.parent
SRC = REPO_ROOT / "src"


def _rules(findings):
    return sorted(f.rule for f in findings)


def ld(src, relpath="repro/core/fixture.py"):
    return ld_mod.analyze_source(textwrap.dedent(src), relpath)


# ---------------------------------------------------------------------------
# rule catalog
# ---------------------------------------------------------------------------

def test_rule_catalog():
    assert set(RULES) == {"LD001", "LD002", "LD003", "LO001",
                          "DT001", "DT002", "AS001", "AS002"}


# ---------------------------------------------------------------------------
# LD001: guarded-field access without the lock (the serve_lock race
# class: a counter written bare that another thread also writes)
# ---------------------------------------------------------------------------

BAD_LD001 = """
    import threading

    class Ledger:
        _GUARDED_BY = {"_lock": ("served", "_depth")}

        def __init__(self):
            self._lock = threading.Lock()
            self.served = 0
            self._depth = 0

        def record(self, secs):
            self.served += secs      # racing += outside the lock

        def depth(self):
            with self._lock:
                return self._depth
"""


def test_ld001_detects_unguarded_access():
    findings = ld(BAD_LD001)
    assert [f.rule for f in findings] == ["LD001"]
    f = findings[0]
    assert f.qualname == "Ledger.record"
    assert f.detail == "served"
    assert f.key == "repro/core/fixture.py::Ledger.record::served"


def test_ld001_clean_when_locked():
    fixed = BAD_LD001.replace(
        "self.served += secs      # racing += outside the lock",
        "with self._lock:\n                self.served += secs")
    assert ld(fixed) == []


def test_ld001_init_exempt_and_locked_suffix_exempt():
    src = """
    import threading

    class Box:
        _GUARDED_BY = {"_lock": ("items",)}

        def __init__(self):
            self._lock = threading.Lock()
            self.items = []

        def _append_locked(self, x):
            self.items.append(x)

        def append(self, x):
            with self._lock:
                self._append_locked(x)
    """
    assert ld(src) == []


def test_ld001_condition_alias_counts_as_lock():
    src = """
    import threading

    class Q:
        _GUARDED_BY = {"_lock": ("_items",)}
        _LOCK_ALIASES = {"_cv": "_lock"}

        def __init__(self):
            self._lock = threading.Lock()
            self._cv = threading.Condition(self._lock)
            self._items = []

        def put(self, x):
            with self._cv:
                self._items.append(x)
                self._cv.notify()
    """
    assert ld(src) == []


# ---------------------------------------------------------------------------
# LD002: blocking under a held lock — the PR 6 deadlock, re-seeded
# ---------------------------------------------------------------------------

PR6_DEADLOCK = """
    import threading

    class SerialExecutor:
        _GUARDED_BY = {"_lock": ("_open", "_pending")}
        _LOCK_ALIASES = {"_slot_free": "_lock"}

        def __init__(self, pool):
            self._pool = pool
            self._lock = threading.Lock()
            self._slot_free = threading.Condition(self._lock)
            self._open = True
            self._pending = 0

        def _on_done(self, fut):
            with self._lock:
                self._pending -= 1
                self._slot_free.notify()

        def submit(self, fn):
            with self._lock:
                self._pending += 1
                fut = self._pool.submit(fn)
                fut.add_done_callback(self._on_done)   # PR 6 bug
            return fut
"""


def test_ld002_detects_pr6_inline_callback_deadlock():
    findings = [f for f in ld(PR6_DEADLOCK, "repro/api/fixture.py")
                if f.rule == "LD002"]
    assert len(findings) == 1
    f = findings[0]
    assert f.qualname == "SerialExecutor.submit"
    assert f.detail == "add_done_callback"


def test_real_serial_executor_keeps_callback_outside_lock():
    """Satellite: the PR 6 fix is now a lint-enforced negative case —
    the shipped SerialExecutor must stay LD002-clean, while the
    reintroduced shape (fixture above) is caught."""
    text = (SRC / "repro/api/futures.py").read_text(encoding="utf-8")
    findings = ld_mod.analyze_source(text, "repro/api/futures.py")
    bad = [f for f in findings if f.rule == "LD002"]
    assert bad == [], [f.render() for f in bad]


def test_ld002_user_callback_and_sleep_and_result():
    src = """
    import threading, time

    class Cache:
        _GUARDED_BY = {"_lock": ("_map",)}
        _CALLBACKS = ("on_evict",)

        def __init__(self):
            self._lock = threading.Lock()
            self._map = {}
            self.on_evict = None

        def evict(self, k):
            with self._lock:
                del self._map[k]
                self.on_evict(k)

        def flush(self, fut):
            with self._lock:
                time.sleep(0.1)
                fut.result()
    """
    details = sorted(f.detail for f in ld(src) if f.rule == "LD002")
    assert details == ["on_evict", "result", "time.sleep"]


def test_ld002_wait_on_own_condition_ok_foreign_wait_flagged():
    src = """
    import threading

    class Q:
        _GUARDED_BY = {"_lock": ("_n",)}
        _LOCK_ALIASES = {"_cv": "_lock"}

        def __init__(self, other):
            self._lock = threading.Lock()
            self._cv = threading.Condition(self._lock)
            self._other = other
            self._n = 0

        def take(self):
            with self._cv:
                while self._n == 0:
                    self._cv.wait()      # fine: releases _lock
                self._n -= 1

        def bad(self):
            with self._lock:
                self._other.wait()       # blocks with _lock held
    """
    flagged = [f for f in ld(src) if f.rule == "LD002"]
    assert [f.qualname for f in flagged] == ["Q.bad"]


def test_ld002_string_join_not_flagged():
    src = """
    import threading

    class R:
        _GUARDED_BY = {"_lock": ("names",)}

        def __init__(self):
            self._lock = threading.Lock()
            self.names = []

        def render(self):
            with self._lock:
                return ", ".join(self.names)
    """
    assert ld(src) == []


def test_ld002_yield_under_lock():
    src = """
    import threading

    class Scope:
        _GUARDED_BY = {"_lock": ("depth",)}

        def __init__(self):
            self._lock = threading.Lock()
            self.depth = 0

        def scope(self):
            with self._lock:
                self.depth += 1
                yield self
                self.depth -= 1
    """
    flagged = [f for f in ld(src) if f.rule == "LD002"]
    assert [f.detail for f in flagged] == ["yield"]


# ---------------------------------------------------------------------------
# LD003: undeclared locks
# ---------------------------------------------------------------------------

def test_ld003_undeclared_lock():
    src = """
    import threading

    class Quiet:
        def __init__(self):
            self.serve_lock = threading.Lock()
    """
    findings = ld(src)
    assert _rules(findings) == ["LD003"]
    assert findings[0].detail == "serve_lock"


def test_ld003_silent_for_declared_class():
    src = """
    import threading

    class Loud:
        _GUARDED_BY = {"_lock": ("x",)}

        def __init__(self):
            self._lock = threading.Lock()
            self.x = 0
    """
    assert ld(src) == []


# ---------------------------------------------------------------------------
# LO001: lock-order cycles — the audit shape (pre-fix
# MesixDirectory.audit querying ALRUs under its own lock while ALRU
# eviction calls back into the directory under the cache lock)
# ---------------------------------------------------------------------------

AUDIT_CYCLE = """
    import threading

    class Cache:
        _GUARDED_BY = {"_lock": ("_map",)}
        _LOCK_HELD = ("_dequeue",)
        _CALLBACKS = ("on_evict",)

        def __init__(self):
            self._lock = threading.RLock()
            self._map = {}
            self.on_evict = None

        def _dequeue(self, k):
            del self._map[k]
            self.on_evict(k)           # cache lock -> directory lock

        def __contains__(self, k):
            with self._lock:
                return k in self._map

    class Directory:
        _GUARDED_BY = {"_lock": ("_holders",)}

        def __init__(self):
            self._lock = threading.RLock()
            self._holders = {}

        def on_evict(self, k):
            with self._lock:
                self._holders.pop(k, None)

        def audit(self, caches):
            with self._lock:
                for k in self._holders:
                    if k not in caches[0]:   # directory lock -> cache lock
                        raise RuntimeError(k)
"""


def test_lo001_detects_audit_cycle():
    findings = [f for f in ld(AUDIT_CYCLE) if f.rule == "LO001"]
    assert len(findings) == 1
    f = findings[0]
    assert f.detail == "cycle:Cache<->Directory"
    assert "on_evict" in f.message and "__contains__" in f.message


def test_lo001_clean_after_snapshot_fix():
    fixed = AUDIT_CYCLE.replace(
        """\
        def audit(self, caches):
            with self._lock:
                for k in self._holders:
                    if k not in caches[0]:   # directory lock -> cache lock
                        raise RuntimeError(k)
""",
        """\
        def audit(self, caches):
            with self._lock:
                snap = list(self._holders)
            for k in snap:
                if k not in caches[0]:
                    raise RuntimeError(k)
""")
    assert fixed != AUDIT_CYCLE
    assert [f for f in ld(fixed) if f.rule == "LO001"] == []


def test_lo001_real_coherence_alru_pair_is_acyclic():
    """The shipped audit takes a snapshot under the lock and queries
    the ALRUs outside it — the real pair must stay cycle-free."""
    import ast
    mods = []
    for rel in ("repro/core/alru.py", "repro/core/coherence.py"):
        mods.append((ast.parse((SRC / rel).read_text(encoding="utf-8")),
                     rel))
    assert ld_mod.check_lock_order(mods) == []


# ---------------------------------------------------------------------------
# DT001/DT002: determinism in virtual-clock paths
# ---------------------------------------------------------------------------

def test_dt001_wall_clock_in_core():
    src = textwrap.dedent("""
    import time

    def span():
        t0 = time.perf_counter()
        return time.time() - t0
    """)
    findings = dt_mod.analyze_source(src, "repro/core/fake_events.py")
    assert _rules(findings) == ["DT001", "DT001"]
    assert sorted(f.detail for f in findings) == \
        ["time.perf_counter", "time.time"]


def test_dt001_clock_reference_without_call_detected():
    src = "import time\nCLOCK = time.perf_counter\n"
    findings = dt_mod.analyze_source(src, "repro/tuning/fake.py")
    assert _rules(findings) == ["DT001"]
    assert findings[0].qualname == "<module>"


def test_dt001_out_of_scope_paths_exempt():
    src = "import time\n\ndef t():\n    return time.time()\n"
    assert dt_mod.analyze_source(src, "repro/launch/fake.py") == []
    assert dt_mod.analyze_source(src, "repro/serve/fake.py") == []


def test_dt002_ambient_rng_flagged_seeded_generator_ok():
    src = textwrap.dedent("""
    import random
    import numpy as np

    def jitter():
        rng = np.random.default_rng(0)   # fine: explicit seed
        return random.random() + np.random.rand()
    """)
    findings = dt_mod.analyze_source(src, "repro/tuning/fake.py")
    assert _rules(findings) == ["DT002", "DT002"]
    assert sorted(f.detail for f in findings) == \
        ["np.random.rand", "random.random"]


# ---------------------------------------------------------------------------
# AS001/AS002: tautological invariant checks — the PR 5 heap shape
# ---------------------------------------------------------------------------

PR5_TAUTOLOGY = """
    class Heap:
        def check_invariants(self):
            walked = sum(1 for _ in self._occupied)
            if sum(1 for _ in self._occupied) != len(self._occupied):
                raise RuntimeError("table mismatch")
            if walked != walked:
                raise RuntimeError("unreachable")
"""


def test_as_rules_detect_pr5_heap_tautology():
    findings = as_mod.analyze_source(
        textwrap.dedent(PR5_TAUTOLOGY), "repro/core/fixture.py")
    assert _rules(findings) == ["AS001", "AS002"]
    as002 = next(f for f in findings if f.rule == "AS002")
    assert as002.qualname == "Heap.check_invariants"
    assert "_occupied" in as002.detail


def test_as_rules_scope_limited_to_check_functions():
    src = textwrap.dedent("""
    def helper(x):
        return x == x      # silly, but not an invariant check

    def validate_table(t):
        return t.n == t.n  # flagged: validate_* is in scope
    """)
    findings = as_mod.analyze_source(src, "repro/core/fixture.py")
    assert _rules(findings) == ["AS001"]
    assert findings[0].qualname == "validate_table"


def test_as001_honest_comparison_not_flagged():
    src = textwrap.dedent("""
    def check_invariants(table, walked):
        if sum(1 for _ in walked) != len(table):
            raise RuntimeError("mismatch")
    """)
    assert as_mod.analyze_source(src, "repro/core/fixture.py") == []


# ---------------------------------------------------------------------------
# baseline + CLI plumbing
# ---------------------------------------------------------------------------

def test_baseline_requires_justification(tmp_path):
    p = tmp_path / "baseline.json"
    p.write_text(json.dumps({"schema": 1, "suppressions": [
        {"rule": "LD001", "key": "a.py::C.m::x", "justification": ""}]}))
    with pytest.raises(ValueError, match="justification"):
        Baseline.load(p)


def test_baseline_covers_and_unused():
    f = Finding("LD001", "a.py", 3, "C.m", "x", "msg")
    b = Baseline([
        {"rule": "LD001", "key": "a.py::C.m::x", "justification": "ok"},
        {"rule": "DT001", "key": "b.py::f::time.time",
         "justification": "stale"}])
    assert b.covers(f)
    unsup, sup = split_findings([f], b)
    assert unsup == [] and sup == [f]
    assert b.unused([f]) == [("DT001", "b.py::f::time.time")]


def test_normalize_path_is_checkout_independent():
    assert normalize_path("/home/x/repo/src/repro/core/alru.py") == \
        "repro/core/alru.py"
    assert normalize_path("src/repro/serve/server.py") == \
        "repro/serve/server.py"
    assert normalize_path("repro/api/futures.py") == \
        "repro/api/futures.py"


def _run_cli(*args, cwd=None):
    env = {"PYTHONPATH": str(SRC), "PATH": "/usr/bin:/bin"}
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, cwd=str(cwd or REPO_ROOT),
        env=env)


def test_cli_strict_fails_on_finding_and_respects_baseline(tmp_path):
    bad = tmp_path / "mod.py"
    bad.write_text(textwrap.dedent("""
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
    """), encoding="utf-8")
    proc = _run_cli("--strict", str(bad))
    assert proc.returncode == 1
    assert "LD003" in proc.stdout

    key = f"{tmp_path.name}/mod.py::C::_lock"
    base = tmp_path / "base.json"
    base.write_text(json.dumps({"schema": 1, "suppressions": [
        {"rule": "LD003", "key": key,
         "justification": "fixture lock, single-threaded"}]}))
    proc = _run_cli("--strict", "--baseline", str(base), str(bad))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "1 suppressed" in proc.stdout


def test_cli_json_and_list_rules(tmp_path):
    bad = tmp_path / "mod.py"
    bad.write_text("import threading\n\n\nclass C:\n"
                   "    def __init__(self):\n"
                   "        self._lock = threading.Lock()\n",
                   encoding="utf-8")
    proc = _run_cli("--json", str(bad))
    data = json.loads(proc.stdout)
    assert data["files"] == 1
    assert [f["rule"] for f in data["findings"]] == ["LD003"]

    proc = _run_cli("--list-rules")
    assert proc.returncode == 0
    for rule in RULES:
        assert rule in proc.stdout


# ---------------------------------------------------------------------------
# the gate itself: the shipped tree is clean vs the committed baseline
# ---------------------------------------------------------------------------

def test_src_tree_is_clean_under_committed_baseline():
    findings, n_files = run_analyses([str(SRC)])
    unsup, sup = split_findings(findings, Baseline.load())
    assert n_files > 50
    assert unsup == [], "\n".join(f.render() for f in unsup)
    # the baseline documents real intentional patterns, not dead keys
    assert len(sup) >= 5
    assert Baseline.load().unused(findings) == []
