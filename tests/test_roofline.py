"""Roofline machinery: HLO collective parser, wire-byte weighting, term
math, and MODEL_FLOPS accounting."""
import pytest

from repro.configs import SHAPES, get_config
from repro.launch import roofline as rf


HLO_SAMPLE = """
HloModule test

ENTRY %main (p0: f32[16,128]) -> f32[16,128] {
  %p0 = f32[16,128]{1,0} parameter(0)
  %ag = f32[64,128]{1,0} all-gather(%p0), replica_groups={{0,1,2,3}}, dimensions={0}
  %c = f32[16,128]{1,0} constant(0)
  %ar.1 = f32[16,128]{1,0} all-reduce(%p0), to_apply=%add
  %rs = f32[4,128]{1,0} reduce-scatter(f32[16,128]{1,0} %ar.1), dimensions={0}
  %cp = f32[16,128]{1,0} collective-permute(%ar.1), source_target_pairs={{0,1}}
  ROOT %out = f32[16,128]{1,0} add(%ar.1, %cp)
}
"""


def test_shape_bytes():
    assert rf.shape_bytes("f32[16,128]{1,0}") == 16 * 128 * 4
    assert rf.shape_bytes("bf16[2,3]") == 12
    assert rf.shape_bytes("(f32[4], bf16[8])") == 16 + 16
    assert rf.shape_bytes("pred[]") == 0 or rf.shape_bytes("pred[]") >= 0


def test_collective_parser_counts_operands():
    out = rf.collective_bytes(HLO_SAMPLE)
    base = 16 * 128 * 4
    assert out["all-gather"] == base          # operand p0
    assert out["all-reduce"] == base
    assert out["reduce-scatter"] == base      # inline-typed operand
    assert out["collective-permute"] == base
    assert out["all-to-all"] == 0


def test_wire_weighting():
    bd = {"all-reduce": 100, "all-gather": 50, "reduce-scatter": 50,
          "all-to-all": 0, "collective-permute": 10}
    assert rf.wire_bytes(bd) == 2 * 100 + 50 + 50 + 10


def test_roofline_terms_and_dominance():
    r = rf.Roofline(arch="x", shape="train_4k", mesh="single", chips=256,
                    hlo_flops=256 * rf.PEAK_FLOPS,        # 1 s compute
                    hlo_bytes=256 * rf.HBM_BW * 2,        # 2 s memory
                    coll_bytes=256 * rf.ICI_BW * 0.5,     # 0.5 s collective
                    coll_breakdown={"all-gather": 1},     # weight 1.0
                    model_flops=256 * rf.PEAK_FLOPS * 0.5)
    assert abs(r.compute_s - 1.0) < 1e-9
    assert abs(r.memory_s - 2.0) < 1e-9
    assert abs(r.collective_s - 0.5) < 1e-9
    assert r.dominant == "memory"
    assert abs(r.useful_flops_frac - 0.5) < 1e-9
    assert abs(r.roofline_frac - 0.25) < 1e-9   # 0.5s ideal vs 2s bound


def test_model_flops_train_vs_decode():
    cfg = get_config("olmo_1b")
    tr = rf.model_flops_for(cfg, SHAPES["train_4k"])
    pf = rf.model_flops_for(cfg, SHAPES["prefill_32k"])
    dc = rf.model_flops_for(cfg, SHAPES["decode_32k"])
    tokens_tr = 256 * 4096
    assert tr == pytest.approx(6 * cfg.param_count() * tokens_tr)
    assert pf == pytest.approx(2 * cfg.param_count() * 32 * 32768)
    assert dc == pytest.approx(2 * cfg.param_count() * 128)


def test_moe_uses_active_params():
    cfg = get_config("deepseek_v3_671b")
    tr = rf.model_flops_for(cfg, SHAPES["train_4k"])
    assert tr < 6 * cfg.param_count() * 256 * 4096 * 0.2  # far below total
    assert tr == pytest.approx(6 * cfg.active_param_count() * 256 * 4096)
