"""Runtime autotuner coverage (repro.tuning + the tile="auto" wiring):
cache hit on a second context, shape-bucket reuse, deterministic
picks, tuned <= default, JSON persistence, the learned cost model
(sweep/model/auto modes, confirmation runs, state persistence), the
provenance-split report counters, and every API surface."""
import json

import numpy as np
import pytest

from repro.api import BlasxContext
from repro.core import blas3
from repro.core.runtime import RuntimeConfig
from repro.tuning import (Autotuner, CostModel, TuningCache, cache_key,
                          reset_shared_cache, shape_bucket,
                          topology_fingerprint, training_rows)

RNG = np.random.default_rng(3)


@pytest.fixture(autouse=True)
def _fresh_shared_cache():
    """Isolate the process-wide default cache between tests."""
    reset_shared_cache()
    yield
    reset_shared_cache()


def _cfg(**kw):
    kw.setdefault("n_devices", 2)
    kw.setdefault("mode", "sim")
    kw.setdefault("cache_bytes", 256 << 20)
    return RuntimeConfig(**kw)


def _shadow_cfg(**kw):
    kw.setdefault("execute", False)
    kw.setdefault("record_trace", False)
    return _cfg(**kw)


# -------------------------------------------------------------- the search
def test_tuned_makespan_never_worse_than_default():
    """Acceptance: on Fig. 10-style sweep shapes the tuned config's
    virtual-clock makespan is <= the fixed default's for every routine
    and both precisions (the default is always candidate zero)."""
    tuner = Autotuner(_shadow_cfg(n_devices=3), cache=TuningCache(),
                      tiles=(128, 256, 512), streams=(2, 4),
                      policies=("blasx", "static"))
    for routine in ("gemm", "syrk", "syr2k", "symm", "trmm", "trsm"):
        for dtype in ("float64", "float32"):
            best = tuner.tune(routine, 1024, 1024, 1024, dtype=dtype)
            assert best.makespan <= best.default_makespan * (1 + 1e-12), \
                (routine, dtype)
            assert best.source == "swept"


def test_tuned_pick_is_deterministic_across_tuners():
    """Same topology + same seed -> bitwise-identical pick from two
    independent tuners with separate caches."""
    picks = []
    for _ in range(2):
        tuner = Autotuner(_shadow_cfg(n_devices=3, seed=7),
                          cache=TuningCache())
        best = tuner.tune("gemm", 2048, 2048, 2048, dtype="float64")
        picks.append((best.tile, best.n_streams, best.policy,
                      best.makespan, best.default_makespan))
    assert picks[0] == picks[1]


def test_shape_bucket_reuse():
    """Shapes in one power-of-two bucket share a cache entry: the
    second tune performs zero shadow runs."""
    assert shape_bucket(1000, 1000, 1000) == (1024, 1024, 1024)
    assert shape_bucket(1, 1, 1) == (64, 64, 64)
    tuner = Autotuner(_shadow_cfg(), cache=TuningCache(),
                      tiles=(128, 256), streams=(2,), policies=("blasx",))
    first = tuner.tune("gemm", 1000, 900, 1020)
    swept = tuner.sweeps
    assert swept > 0
    again = tuner.tune("gemm", 1024, 1024, 1024)
    assert tuner.sweeps == swept            # pure cache hit
    assert again.source == "cache"
    assert (again.tile, again.n_streams) == (first.tile, first.n_streams)


def test_fingerprint_separates_topologies_not_knobs():
    """The fingerprint keys on the machine, not the searched knobs."""
    base = _shadow_cfg(n_devices=2)
    assert topology_fingerprint(base) == topology_fingerprint(
        _shadow_cfg(n_devices=2, n_streams=8, policy="static"))
    assert topology_fingerprint(base) != topology_fingerprint(
        _shadow_cfg(n_devices=3))
    assert topology_fingerprint(base) != topology_fingerprint(
        _shadow_cfg(n_devices=2, h2d_bw=1e12))
    key = cache_key("f", "numpy", "gemm", (64, 64, 64), "float64")
    assert key == "f/numpy/gemm/64x64x64/float64"


def test_cache_file_roundtrip(tmp_path):
    """A file-backed cache persists across tuner (and process) lives."""
    path = str(tmp_path / "tuning.json")
    t1 = Autotuner(_shadow_cfg(), cache=path, tiles=(128, 256),
                   streams=(2,), policies=("blasx",))
    best = t1.tune("syrk", 512, 512, 512)
    assert t1.sweeps > 0
    # a second, cold cache object backed by the same file
    t2 = Autotuner(_shadow_cfg(), cache=TuningCache(path), tiles=(128, 256),
                   streams=(2,), policies=("blasx",))
    again = t2.tune("syrk", 512, 512, 512)
    # provenance: the hit is served from the backing FILE, and the
    # counters say so
    assert t2.sweeps == 0 and again.source == "cache-file"
    assert t2.file_cache_hits == 1 and t2.process_cache_hits == 0
    assert again.tile == best.tile
    assert again.makespan == best.makespan


def test_cache_ignores_unknown_schema(tmp_path):
    path = tmp_path / "bad.json"
    path.write_text('{"schema": 999, "entries": {"x": {}}}')
    cache = TuningCache(str(path))
    assert len(cache) == 0


def test_corrupt_cache_file_degrades_to_resweep(tmp_path):
    """A truncated/garbage cache file must never crash context
    construction — it degrades to a fresh sweep and is overwritten."""
    path = tmp_path / "corrupt.json"
    path.write_text('{"schema": 1, "entries": {"x"')   # truncated JSON
    tuner = Autotuner(_shadow_cfg(), cache=str(path), tiles=(128,),
                      streams=(2,), policies=("blasx",))
    best = tuner.tune("gemm", 256, 256, 256)
    assert tuner.sweeps > 0 and best.source == "swept"
    # the overwritten file round-trips cleanly now
    assert len(TuningCache(str(path))) == 1


def test_entry_from_different_candidate_space_is_not_reused(tmp_path):
    """A cache entry swept under a restricted candidate space (or a
    different default config) must not satisfy a tuner whose
    tuned<=default guarantee refers to a different default — it
    re-sweeps instead of serving someone else's verdict."""
    path = str(tmp_path / "t.json")
    narrow = Autotuner(_shadow_cfg(), cache=path, tiles=(128,),
                       streams=(2,), policies=("blasx",), default_tile=128)
    narrow.tune("gemm", 512, 512, 512)
    wide = Autotuner(_shadow_cfg(), cache=TuningCache(path),
                     tiles=(128, 256), streams=(2, 4),
                     policies=("blasx",), default_tile=256)
    best = wide.tune("gemm", 512, 512, 512)
    assert wide.sweeps > 0 and best.source == "swept"
    assert best.makespan <= best.default_makespan * (1 + 1e-12)
    # same-space tuner after the overwrite: pure hit again (a file
    # hit, from wide2's point of view)
    wide2 = Autotuner(_shadow_cfg(), cache=TuningCache(path),
                      tiles=(128, 256), streams=(2, 4),
                      policies=("blasx",), default_tile=256)
    assert wide2.tune("gemm", 512, 512, 512).source == "cache-file"
    assert wide2.sweeps == 0


# ------------------------------------------------------------ context layer
def test_second_context_same_topology_is_pure_cache_hit():
    """Acceptance: the first auto-tuned context sweeps; a second
    context with the same topology performs ZERO shadow-run sweeps."""
    A = RNG.standard_normal((260, 260))
    B = RNG.standard_normal((260, 260))
    with BlasxContext(_cfg(), auto_tune=True) as ctx1:
        out = ctx1.gemm(A, B, tile="auto")
        np.testing.assert_allclose(out.array(), A @ B, rtol=1e-10,
                                   atol=1e-10)
        rep1 = ctx1.tuning_report()
        assert rep1["sweeps"] > 0 and rep1["cache_hits"] == 0
    with BlasxContext(_cfg(), auto_tune=True) as ctx2:
        out = ctx2.gemm(A, B, tile="auto")
        np.testing.assert_allclose(out.array(), A @ B, rtol=1e-10,
                                   atol=1e-10)
        rep2 = ctx2.tuning_report()
        assert rep2["sweeps"] == 0 and rep2["cache_hits"] == 1
        assert rep2["entries"][0]["source"] == "cache"
        assert rep2["fingerprint"] == rep1["fingerprint"]


def test_auto_tune_default_applies_to_raw_arrays_only():
    """auto_tune=True tunes tile=None raw-array calls, but a handle's
    tile is pinned (re-tiling would break the warm-cache contract)."""
    A = RNG.standard_normal((300, 300))
    with BlasxContext(_cfg(), auto_tune=True, tile=100) as ctx:
        Ah = ctx.tile(A)                 # pinned at the context default
        out = ctx.gemm(Ah, Ah)           # no tuning: handle wins
        assert out.tile == 100
        assert ctx.tuning_report()["sweeps"] == 0
        out2 = ctx.syrk(A)               # raw array: tuned
        rep = ctx.tuning_report()
        assert rep["sweeps"] > 0
        assert out2.tile == rep["entries"][-1]["tile"]


def test_tile_auto_conflicts_with_mismatched_handle():
    A = RNG.standard_normal((300, 300))
    with BlasxContext(_cfg(), tile=100) as ctx:
        Ah = ctx.tile(A)
        tuned = ctx.auto_tile("gemm", 300, 300, 300)
        if tuned != Ah.tile:
            with pytest.raises(ValueError, match="tile"):
                ctx.gemm(Ah, Ah, tile="auto")


def test_ctx_tile_rejects_auto_and_bad_strings():
    with BlasxContext(_cfg()) as ctx:
        with pytest.raises(ValueError, match="auto_tile"):
            ctx.tile(np.eye(8), tile="auto")
        with pytest.raises(ValueError, match="int or 'auto'"):
            ctx.gemm(np.eye(8), np.eye(8), tile="widest")


def test_cold_context_adopts_tuned_schedule():
    """With auto_tune=True the first tuned call on a still-cold
    context applies the tuned (n_streams, policy); the tuner's pick
    and the applied config must agree."""
    A = RNG.standard_normal((520, 520))
    with BlasxContext(_cfg(), auto_tune=True) as ctx:
        out = ctx.trsm(np.tril(A) + 520 * np.eye(520), A, uplo="L",
                       tile="auto")
        np.testing.assert_allclose(
            out.array(),
            blas3.ref_trsm(np.tril(A) + 520 * np.eye(520), A, uplo="L"),
            rtol=1e-8, atol=1e-8)
        entry = ctx.tuning_report()["entries"][0]
        applied = ctx.tuning_report()["applied"]
        assert applied["n_streams"] == entry["n_streams"]
        assert applied["policy"] == entry["policy"]
        assert ctx.cfg.n_streams == entry["n_streams"]


def test_warm_context_never_reconfigures_schedule():
    """After the first executed call the runtime (and its warm caches)
    must survive later tuned calls untouched."""
    A = RNG.standard_normal((300, 300))
    with BlasxContext(_cfg(), auto_tune=True) as ctx:
        ctx.gemm(A, A, tile=100)         # cold -> executed, caches warm
        rt = ctx.runtime
        ctx.gemm(A, A, tile="auto")      # tuned call on a warm context
        assert ctx.runtime is rt         # same runtime object


# --------------------------------------------------------- other surfaces
def test_tile_auto_through_legacy_and_cblas_and_batch():
    from repro.api import CblasNoTrans, CblasRowMajor, cblas_dgemm

    A = RNG.standard_normal((200, 200))
    B = RNG.standard_normal((200, 200))
    r = blas3.gemm(A, B, tile="auto")
    np.testing.assert_allclose(r, A @ B, rtol=1e-10, atol=1e-10)

    C = np.zeros((200, 200))
    cblas_dgemm(CblasRowMajor, CblasNoTrans, CblasNoTrans, 200, 200, 200,
                1.0, A, 200, B, 200, 0.0, C, 200, tile="auto")
    np.testing.assert_allclose(C, A @ B, rtol=1e-10, atol=1e-10)

    with BlasxContext(_cfg(), auto_tune=True) as ctx:
        outs = ctx.gemm_batched([A, B], [B, A], tile="auto")
        np.testing.assert_allclose(outs[0].array(), A @ B, rtol=1e-10,
                                   atol=1e-10)
        np.testing.assert_allclose(outs[1].array(), B @ A, rtol=1e-10,
                                   atol=1e-10)
        assert outs[0].tile == outs[1].tile   # one tuned tile, whole batch
        y = ctx.gemm_strided_batched(np.stack([A, B]), B, tile="auto")
        np.testing.assert_allclose(y[0], A @ B, rtol=1e-10, atol=1e-10)


def test_tile_auto_side_r_reduction():
    A = RNG.standard_normal((96, 96))
    B = RNG.standard_normal((64, 96))
    r = blas3.trmm(A, B, side="R", tile="auto")
    np.testing.assert_allclose(r, blas3.ref_trmm(A, B, side="R"),
                               rtol=1e-9, atol=1e-9)


# ------------------------------------------------------- learned cost model
_MODEL_KW = dict(tiles=(128, 256, 512), streams=(2, 4),
                 policies=("blasx", "static"))


def _seed_cache(cache, routines=("gemm",),
                sizes=(256, 384, 768, 1536, (1500, 150, 1500))):
    """Sweep a training distribution into ``cache`` and return the
    sweep-mode tuner that produced it.  The ragged (m, k, n) entry
    keeps the model's aspect-ratio features exercised — an all-cube
    training set extrapolates badly to thin-k serving shapes."""
    t = Autotuner(_shadow_cfg(), cache=cache, mode="sweep", **_MODEL_KW)
    for routine in routines:
        for m in sizes:
            if isinstance(m, tuple):
                t.tune(routine, *m)
            else:
                t.tune(routine, m, m, m)
    return t


def test_auto_mode_bootstraps_through_sweeps_then_adopts():
    """Cold cache: auto mode falls back to sweeps (model untrained).
    Once enough measured rows accumulate, a fresh bucket costs only
    confirmation runs — and the adopted config is still measured
    tuned <= default."""
    cache = TuningCache("")
    t = Autotuner(_shadow_cfg(), cache=cache, mode="auto", **_MODEL_KW)
    first = t.tune("gemm", 256, 256, 256)
    assert first.source == "swept" and t.model_fallbacks == 1
    for m in (384, 768, 1536):
        t.tune("gemm", m, m, m)
    # the bootstrap swept at least the first buckets; by now the model
    # is trained and trusted on those sweeps' rows
    assert t.bucket_sweeps >= 2
    assert t._model is not None and t._model.rmse <= t.max_model_rmse
    sweeps_before = t.sweeps
    best = t.tune("gemm", 3000, 3000, 3000)       # fresh 4096-bucket
    assert best.source == "model"
    assert t.model_adoptions >= 1
    # the model path paid at most 2 confirmation runs, never a sweep
    assert t.sweeps - sweeps_before <= 2
    assert best.makespan <= best.default_makespan * (1 + 1e-12)


def test_model_mode_confirmation_runs_only():
    """mode='model' with a trained model: a fresh bucket costs at most
    two shadow runs (winner + default), not a full sweep."""
    cache = TuningCache("")
    _seed_cache(cache)
    t = Autotuner(_shadow_cfg(), cache=cache, mode="model", **_MODEL_KW)
    best = t.tune("gemm", 3000, 3000, 3000)
    assert best.source == "model"
    assert t.sweeps == t.confirmations <= 2
    assert t.bucket_sweeps == 0
    assert best.makespan <= best.default_makespan * (1 + 1e-12)


def test_model_adoption_is_disproved_by_confirmation(monkeypatch):
    """A model that predicts a bad winner is caught by the measured
    confirmation run: the tuner falls back to the full sweep and the
    guarantee holds on measurements, never predictions."""
    cache = TuningCache("")
    _seed_cache(cache)
    t = Autotuner(_shadow_cfg(), cache=cache, mode="model", **_MODEL_KW)
    model = t._ensure_model()
    assert model is not None
    # sabotage: find the measured-worst candidate for a FRESH bucket
    # (512x128x512 — the seed only covers cubes) and patch the model
    # to predict it as the winner
    bucket = (512, 128, 512)
    cands = t._candidates("gemm", bucket)
    spans = {c: t._shadow_makespan("gemm", bucket, c[0], "float64",
                                   c[1], c[2], c[3]) for c in cands}
    worst = max(cands, key=spans.get)
    assert spans[worst] > spans[cands[0]]    # strictly worse than default

    def fake_predict(feats):
        tile = round(2 ** feats["ltile"])
        ns = round(2 ** feats["lstreams"])
        policy = next(p for p in ("blasx", "static", "parsec", "cublasxt")
                      if feats.get(f"policy_{p}"))
        wc = bool(feats.get("work_centric"))
        return 0.0 if (tile, ns, policy, wc) == worst else 1.0

    monkeypatch.setattr(model, "predict", fake_predict)
    best = t.tune("gemm", 512, 100, 512)
    assert t.model_fallbacks == 1 and best.source == "swept"
    assert best.makespan <= best.default_makespan * (1 + 1e-12)


def test_model_trains_only_on_measured_rows():
    """Model-adopted entries contribute just their confirmation
    measurements to the training set — predictions never feed back."""
    cache = TuningCache("")
    seeder = _seed_cache(cache)
    rows_before = len(training_rows(cache, seeder.fingerprint,
                                    seeder.cfg.backend,
                                    seeder.cfg.topology()))
    t = Autotuner(_shadow_cfg(), cache=cache, mode="model", **_MODEL_KW)
    best = t.tune("gemm", 3000, 3000, 3000)
    assert best.source == "model"
    entry = cache.get(best.key)
    assert 1 <= len(entry["candidates"]) <= 2      # measured rows only
    assert "predicted" in entry                    # predictions ride along
    rows_after = len(training_rows(cache, t.fingerprint, t.cfg.backend,
                                   t.cfg.topology()))
    assert rows_after == rows_before + len(entry["candidates"])


def test_model_state_persists_in_cache_file(tmp_path):
    """Fitted model state lands in the cache JSON next to the entries;
    a fresh process (new cache + tuner) starts with a trained model."""
    path = str(tmp_path / "tuning.json")
    cache = TuningCache(path)
    _seed_cache(cache)
    t = Autotuner(_shadow_cfg(), cache=cache, mode="model", **_MODEL_KW)
    assert t.tune("gemm", 3000, 3000, 3000).source == "model"
    with open(path) as f:
        payload = json.load(f)
    assert payload["model"]["trained"] is True
    cold = Autotuner(_shadow_cfg(), cache=TuningCache(path), mode="model",
                     **_MODEL_KW)
    assert cold._model is not None and cold._model.trained
    best = cold.tune("syrk", 2000, 500)
    assert best.source == "model" and cold.bucket_sweeps == 0


def test_cost_model_state_roundtrip_and_malformed_state():
    cache = TuningCache("")
    seeder = _seed_cache(cache, routines=("gemm", "syrk"))
    rows = training_rows(cache, seeder.fingerprint, seeder.cfg.backend,
                         seeder.cfg.topology())
    model = CostModel().fit(rows)
    assert model.trained and model.n_rows == len(rows)
    clone = CostModel.from_state(model.state())
    feats = rows[7]["features"]
    assert clone.predict(feats) == pytest.approx(model.predict(feats))
    lo, hi = model.interval(feats)
    assert lo <= model.predict(feats) <= hi
    # malformed / foreign state degrades to untrained, never raises
    assert not CostModel.from_state(None).trained
    assert not CostModel.from_state({"schema": 999}).trained
    assert not CostModel.from_state(
        {"schema": 1, "trained": True, "coef": "garbage"}).trained


def test_autotuner_rejects_unknown_mode():
    with pytest.raises(ValueError, match="mode"):
        Autotuner(_shadow_cfg(), cache=TuningCache(""), mode="bogus")
    with pytest.raises(ValueError, match="auto_tune"):
        BlasxContext(_cfg(), auto_tune="bogus")


def test_context_threads_auto_tune_mode():
    cache = TuningCache("")
    _seed_cache(cache)
    with BlasxContext(_cfg(), auto_tune="auto", tuning_cache=cache) as ctx:
        assert ctx.tuning_report()["mode"] == "auto"
        A = RNG.standard_normal((3000, 300))
        out = ctx.gemm(A, A.T)
        np.testing.assert_allclose(out.array(), A @ A.T, rtol=1e-10,
                                   atol=1e-10)
        rep = ctx.tuning_report()
        assert rep["model_adoptions"] == 1 and rep["bucket_sweeps"] == 0
    with BlasxContext(_cfg(), auto_tune=True) as ctx:
        assert ctx.tuning_report()["mode"] == "sweep"   # bool back-compat


# ------------------------------------------------- provenance-split counters
def test_tuning_report_provenance_counts(tmp_path):
    """Regression: the report distinguishes file-cache hits,
    process-cache hits, model adoptions and sweeps — with pinned
    counts (the ISSUE-7 small fix)."""
    path = str(tmp_path / "tuning.json")
    seeder = Autotuner(_shadow_cfg(), cache=path, **_MODEL_KW)
    seeder.tune("gemm", 256, 256, 256)               # -> file via put()
    t = Autotuner(_shadow_cfg(), cache=TuningCache(path), **_MODEL_KW)
    t.tune("gemm", 256, 256, 256)      # hit, origin "file"
    t.tune("syrk", 256, 256, 256)      # miss -> sweep
    t.tune("syrk", 200, 200, 200)      # hit, origin "process" (same bucket)
    rep = t.report()
    assert rep["cache_hits"] == 2
    assert rep["file_cache_hits"] == 1
    assert rep["process_cache_hits"] == 1
    assert rep["bucket_sweeps"] == 1
    assert rep["model_adoptions"] == 0 and rep["model_fallbacks"] == 0
    sources = [e["source"] for e in rep["entries"]]
    assert sources == ["cache-file", "swept", "cache"]


def test_tuning_report_before_any_tuning():
    with BlasxContext(_cfg()) as ctx:
        rep = ctx.tuning_report()
        assert rep == {"enabled": False, "mode": "sweep",
                       "sweeps": 0, "bucket_sweeps": 0,
                       "confirmations": 0,
                       "cache_hits": 0, "file_cache_hits": 0,
                       "process_cache_hits": 0,
                       "model_adoptions": 0, "model_fallbacks": 0,
                       "cache_entries": 0, "entries": []}
