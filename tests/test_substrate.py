"""Substrate tests: optimizer, data pipeline, checkpointing, fault
tolerance, and the end-to-end train/serve drivers on reduced configs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.data import DataConfig, DataIterator, batch_at_step
from repro.optim import adamw


# ---------------------------------------------------------------- optimizer
def test_adamw_converges_on_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                            total_steps=200)
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    state = adamw.init_state(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.apply_updates(cfg, params, g, state)
    assert float(loss(params)) < 1e-2


def test_adamw_schedule_warmup_and_decay():
    cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                            min_lr_frac=0.1)
    lrs = [float(adamw.schedule(cfg, jnp.asarray(s))) for s in
           [0, 5, 10, 50, 100]]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 5e-4) < 1e-8          # mid-warmup
    assert abs(lrs[2] - 1e-3) < 1e-8          # peak
    assert lrs[3] < lrs[2]                    # decaying
    assert abs(lrs[4] - 1e-4) < 1e-8          # floor


def test_grad_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}        # norm 5
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-6
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5


def test_int8_error_feedback_compression_unbiased():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    err = jnp.zeros_like(g)
    # accumulate dequantized payloads over steps with a CONSTANT gradient:
    # error feedback must make the running mean converge to g
    total = jnp.zeros_like(g)
    steps = 64
    for _ in range(steps):
        q, scale, err = adamw.compress_int8(g, err)
        total = total + adamw.decompress_int8(q, scale)
    np.testing.assert_allclose(np.asarray(total / steps), np.asarray(g),
                               atol=2e-2)


# -------------------------------------------------------------------- data
def test_data_determinism_and_restart_exactness():
    dc = DataConfig(vocab_size=128, seq_len=16, global_batch=4, seed=7)
    b5a = batch_at_step(dc, 5)
    b5b = batch_at_step(dc, 5)
    np.testing.assert_array_equal(b5a["tokens"], b5b["tokens"])

    it = DataIterator(dc)
    seen = [next(it)["tokens"] for _ in range(4)]
    state = it.state()
    rest1 = [next(it)["tokens"] for _ in range(3)]
    it2 = DataIterator(dc)
    it2.restore(state)
    rest2 = [next(it2)["tokens"] for _ in range(3)]
    for a, b in zip(rest1, rest2):
        np.testing.assert_array_equal(a, b)


def test_data_labels_are_shifted_tokens():
    dc = DataConfig(vocab_size=64, seq_len=8, global_batch=2, seed=1)
    b = batch_at_step(dc, 0)
    assert b["tokens"].shape == (2, 8)
    assert b["labels"].shape == (2, 8)
    # label[t] is the next token of an (a*x+b)%V chain most of the time
    # (5% noise) — just check dtype/range here
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 64


# ------------------------------------------------------------- checkpointer
def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    ck.save(10, tree, extra={"loss": 1.5})
    out, extra = ck.restore(10, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert extra["loss"] == 1.5


def test_checkpoint_retention_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    assert ck.all_steps() == [3, 4]
    assert ck.latest_step() == 4


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"x": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        ck.restore(1, {"x": jnp.zeros((3, 3))})


def test_checkpoint_async_save(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"x": jnp.arange(10_000).astype(jnp.float32)}
    ck.save(5, tree, blocking=False)
    ck.wait()
    out, _ = ck.restore(5, tree)
    np.testing.assert_array_equal(np.asarray(out["x"]), np.asarray(tree["x"]))


def test_checkpoint_resharding_hook(tmp_path):
    """Elastic restore: a sharding_fn re-places arrays arbitrarily."""
    ck = Checkpointer(str(tmp_path))
    tree = {"w": jnp.arange(8).astype(jnp.float32)}
    ck.save(1, tree)
    calls = []

    def reshard(path, arr):
        calls.append(path)
        return jax.device_put(jnp.asarray(arr) * 1.0)

    out, _ = ck.restore(1, tree, sharding_fn=reshard)
    assert calls and "w" in calls[0]
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(8))


# ----------------------------------------------------------- train driver
def test_train_loop_loss_decreases(tmp_path):
    from repro.launch.train import TrainConfig, run
    out = run(TrainConfig(arch="qwen3_0_6b", smoke=True, steps=30,
                          seq_len=32, global_batch=4,
                          ckpt_dir=str(tmp_path / "ck"), ckpt_every=10,
                          log_every=0))
    assert out["final_step"] == 30
    assert out["last_loss"] < out["first_loss"]  # learnable synthetic data


@pytest.mark.slow
def test_train_resume_from_checkpoint(tmp_path):
    from repro.launch.train import TrainConfig, run
    ck = str(tmp_path / "ck")
    base = dict(arch="qwen3_0_6b", smoke=True, seq_len=32, global_batch=4,
                ckpt_dir=ck, ckpt_every=5, log_every=0)
    run(TrainConfig(steps=10, **base))
    out = run(TrainConfig(steps=20, **base))     # resumes at 10
    assert out["final_step"] == 20
    # resumed run trained only the remaining 10 steps
    assert len(out["losses"]) == 10


def test_serve_driver_completes_all_requests():
    from repro.launch.serve import ServeConfig, run
    out = run(ServeConfig(arch="olmo_1b", smoke=True, batch_slots=3,
                          prompt_len=8, max_len=32, requests=5, max_new=6))
    assert out["requests"] == 5
    assert out["tokens"] == 5 * 6
    assert out["tok_per_s"] > 0
