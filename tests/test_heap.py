"""BLASX_Malloc invariant coverage (paper §IV-E, Fig. 6).

The headline regression: ``check_invariants`` used to compare
``sum(1 for _ in self._occupied)`` against ``len(self._occupied)`` — a
tautology that could never fire — so a corrupted occupied table (the
hashtable that makes ``free`` O(1)) passed every property test.  The
strengthened check walks the meta-data list and cross-checks the
walked occupied segments against the table in both directions.

The random driver mirrors the hypothesis property test in
``test_property.py`` but is seeded-pytest so it runs in environments
without hypothesis (the module there self-skips).
"""
import random

import pytest

from repro.core.heap import BlasxHeap, HeapError, _Segment


# ------------------------------------------------- corruption regressions
def test_stale_occupied_entry_is_detected():
    """Regression: an extra table entry with no backing occupied
    segment must fail check_invariants (the pre-fix tautology passed)."""
    h = BlasxHeap(1024)
    off = h.malloc(100)
    assert off is not None
    h.check_invariants()
    # corrupt: a stale entry whose segment is not in the meta-data list
    h._occupied[999] = _Segment(offset=999, length=1, occupied=True)
    with pytest.raises(HeapError, match="stale"):
        h.check_invariants()


def test_stale_entry_for_freed_segment_is_detected():
    """A freed offset lingering in the table (a broken free()) fails."""
    h = BlasxHeap(1024)
    a = h.malloc(128)
    b = h.malloc(128)
    seg = h._occupied[a]
    h.free(a)
    h.check_invariants()
    h._occupied[a] = seg          # resurrect the popped entry
    seg.occupied = False          # ...but the segment itself is free
    with pytest.raises(HeapError, match="stale"):
        h.check_invariants()
    del h._occupied[a]
    h.free(b)
    h.check_invariants()


def test_missing_occupied_entry_is_detected():
    """The complementary direction (already covered pre-fix): an
    occupied segment absent from the table fails."""
    h = BlasxHeap(1024)
    off = h.malloc(64)
    del h._occupied[off]
    with pytest.raises(HeapError, match="out of sync"):
        h.check_invariants()


def test_aliased_occupied_entry_is_detected():
    """Table entry pointing at the wrong segment object fails."""
    h = BlasxHeap(1024)
    a = h.malloc(64)
    h.malloc(64)
    h._occupied[a] = _Segment(offset=a, length=64, occupied=True)
    with pytest.raises(HeapError, match="out of sync"):
        h.check_invariants()


# ------------------------------------------------ random property driver
def _brute_largest_attainable(h: BlasxHeap, freeable) -> int:
    """Oracle: longest run of segments that are free or freeable."""
    freeable = set(freeable)
    runs = []
    run = 0
    seg = h._head
    while seg is not None:
        if not seg.occupied or seg.offset in freeable:
            run += seg.length
        else:
            runs.append(run)
            run = 0
        seg = seg.next
    runs.append(run)
    return max(runs)


@pytest.mark.parametrize("seed", range(8))
def test_heap_invariants_under_random_traces(seed):
    """Random malloc/free/largest_attainable_run sequences: after every
    op the strengthened invariants hold, largest_attainable_run agrees
    with a brute-force walk, and full teardown returns the arena."""
    rng = random.Random(seed)
    h = BlasxHeap(4096)
    live = []
    for _ in range(300):
        op = rng.random()
        if op < 0.55 or not live:
            off = h.malloc(rng.randint(1, 400))
            if off is not None:
                live.append(off)
        elif op < 0.9:
            h.free(live.pop(rng.randrange(len(live))))
        else:
            # query path: any subset of live offsets may be "freeable"
            subset = [o for o in live if rng.random() < 0.5]
            got = h.largest_attainable_run(subset)
            assert got == _brute_largest_attainable(h, subset)
            assert got >= h.largest_free_run()
        h.check_invariants()
        assert set(h._occupied) == set(live)
    for off in live:
        h.free(off)
    h.check_invariants()
    assert h.free_bytes == 4096
    assert h.largest_free_run() == 4096


@pytest.mark.parametrize("seed", [11, 13])
def test_random_trace_then_corruption_always_caught(seed):
    """After an arbitrary trace, injecting a stale table entry is
    always caught — the invariant is load-bearing, not vacuous."""
    rng = random.Random(seed)
    h = BlasxHeap(2048)
    live = []
    for _ in range(80):
        if rng.random() < 0.6 or not live:
            off = h.malloc(rng.randint(1, 300))
            if off is not None:
                live.append(off)
        else:
            h.free(live.pop(rng.randrange(len(live))))
    h.check_invariants()
    h._occupied[h.capacity + 1] = _Segment(
        offset=h.capacity + 1, length=5, occupied=True)
    with pytest.raises(HeapError, match="stale"):
        h.check_invariants()
