"""MESI-X directory coverage (paper §IV-B, Fig. 3): derived E/S/I
states, the ephemeral-M write-back path, P2P group fencing, and
concurrent mutation safety."""
import threading

import pytest

from repro.core.coherence import MesixDirectory
from repro.core.tiling import TileKey


def _key(i, j=0, mat="A"):
    return TileKey(mat, i, j)


# ---------------------------------------------------- derived transitions
def test_states_are_derived_from_holder_sets():
    d = MesixDirectory(4, [[0, 1, 2, 3]])
    k = _key(0)
    assert d.state(k) == "I" and d.holders(k) == set()
    assert d.on_fill(k, 2) == "E"
    assert d.state(k) == "E" and d.holders(k) == {2}
    assert d.on_fill(k, 0) == "S"
    assert d.on_fill(k, 3) == "S"
    assert d.holders(k) == {0, 2, 3}
    # idempotent refill never double-counts a holder
    assert d.on_fill(k, 2) == "S"
    assert d.holders(k) == {0, 2, 3}
    assert d.on_evict(k, 0) == "S"
    assert d.on_evict(k, 3) == "E"
    assert d.on_evict(k, 2) == "I"
    assert d.holders(k) == set()
    d.check_invariants()


def test_evict_of_non_holder_is_harmless():
    d = MesixDirectory(2, [[0, 1]])
    k = _key(1)
    assert d.on_evict(k, 0) == "I"     # never filled
    d.on_fill(k, 0)
    assert d.on_evict(k, 1) == "E"     # device 1 never held it
    assert d.holders(k) == {0}
    d.check_invariants()


# ------------------------------------------------------- ephemeral M path
def test_write_invalidates_every_copy_including_writer():
    d = MesixDirectory(3, [[0, 1, 2]])
    k = _key(0, mat="C")
    d.on_fill(k, 0)
    d.on_fill(k, 1)
    d.on_fill(k, 2)
    holders = d.on_write(k, 1)
    assert holders == [0, 1, 2]        # writer included, sorted
    assert d.state(k) == "I"           # M -> I immediately: never at rest
    assert d.holders(k) == set()
    assert d.writebacks == 1
    assert d.invalidations == 3


def test_write_with_no_cached_copies_still_counts_writeback():
    d = MesixDirectory(2, [[0, 1]])
    k = _key(5, mat="C")
    assert d.on_write(k, 0) == []
    assert d.writebacks == 1 and d.invalidations == 0
    assert d.state(k) == "I"


def test_write_then_refill_restarts_at_exclusive():
    d = MesixDirectory(2, [[0, 1]])
    k = _key(2, mat="C")
    d.on_fill(k, 0)
    d.on_write(k, 0)
    assert d.on_fill(k, 1) == "E"      # fresh I -> E, history gone
    assert d.holders(k) == {1}


# ------------------------------------------------------------ P2P fencing
def test_peer_holder_never_crosses_p2p_groups():
    """Exhaustive over a two-switch topology + an isolated device:
    every answered peer is in the requester's group and never the
    requester itself; cross-group holders are invisible."""
    groups = [[0, 1], [2, 3]]
    d = MesixDirectory(5, groups)      # device 4 isolated (no group)
    group_of = {0: 0, 1: 0, 2: 1, 3: 1}
    k = _key(7)
    for holder in range(5):
        d = MesixDirectory(5, groups)
        d.on_fill(k, holder)
        for requester in range(5):
            peer = d.peer_holder(k, requester)
            if peer is not None:
                assert peer == holder
                assert peer != requester
                assert group_of[peer] == group_of[requester]
            else:
                same = (requester != holder
                        and group_of.get(requester) is not None
                        and group_of.get(requester) == group_of.get(holder))
                assert not same, (requester, holder)
    # isolated device: nobody serves it, it serves nobody
    d = MesixDirectory(5, groups)
    d.on_fill(k, 4)
    assert all(d.peer_holder(k, r) is None for r in range(5))


def test_peer_holder_rotates_least_recently_served():
    """Regression: peer_holder used to always answer the lowest
    same-group id, draining one device's D2D lane.  It now answers the
    least-recently-served eligible holder (ties toward the lowest id),
    and the query itself is read-only — only mark_served rotates."""
    d = MesixDirectory(4, [[0, 1, 2, 3]])
    k = _key(3)
    d.on_fill(k, 3)
    d.on_fill(k, 1)
    assert d.peer_holder(k, 0) == 1    # never served: lowest id wins
    assert d.peer_holder(k, 0) == 1    # pure query: no rotation
    assert d.peer_holder(k, 1) == 3    # self excluded
    d.mark_served(1)                   # device 1 actually served a fetch
    assert d.peer_holder(k, 0) == 3    # 3 is now least-recently-served
    d.mark_served(3)
    assert d.peer_holder(k, 0) == 1    # back to 1: round-robin emerges


def test_peer_holder_serves_spread_evenly_across_holders():
    """A tile held by three peers serves a stream of fetches 1/3 each
    when the requester marks every serve (the runtime's contract)."""
    d = MesixDirectory(4, [[0, 1, 2, 3]])
    k = _key(0)
    for holder in (0, 1, 2):
        d.on_fill(k, holder)
    served = {0: 0, 1: 0, 2: 0}
    for _ in range(9):
        peer = d.peer_holder(k, 3)
        served[peer] += 1
        d.mark_served(peer)
    assert served == {0: 3, 1: 3, 2: 3}


# ------------------------------------------------------------ concurrency
@pytest.mark.parametrize("seed_offset", [0, 1])
def test_concurrent_register_and_invalidate(seed_offset):
    """Two threads hammer overlapping keys with fill/evict/write; the
    directory must stay internally consistent (no empty holder sets
    kept, no bogus devices) and every key must settle in a derived
    state."""
    d = MesixDirectory(2, [[0, 1]])
    keys = [_key(i % 8, i // 8) for i in range(32)]
    errors = []
    barrier = threading.Barrier(2)

    def worker(dev):
        try:
            barrier.wait()
            for rep in range(200):
                k = keys[(rep * (dev + 1) + seed_offset) % len(keys)]
                d.on_fill(k, dev)
                if rep % 3 == 0:
                    d.on_evict(k, dev)
                if rep % 7 == 0:
                    d.on_write(k, dev)
                d.peer_holder(k, dev)
        except BaseException as e:  # surfaced after join
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(dev,))
               for dev in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    d.check_invariants()
    for k in keys:
        holders = d.holders(k)
        state = d.state(k)
        assert state == {0: "I", 1: "E"}.get(len(holders), "S")
        assert holders <= {0, 1}
    # cleanup converges to all-invalid
    for k in keys:
        for dev in range(2):
            d.on_evict(k, dev)
        assert d.state(k) == "I"
    d.check_invariants()
