"""Multi-precision suite: dtype threading through every API layer
(legacy blas3 / BlasxContext / cblas_s*), per-dtype byte accounting in
the ALRU/heap/ledger, f32-accumulation engines on jax/pallas for the
half precisions, and the backend gating rules — plus regression tests
for the cache/threads-mode bugfix sweep that rode along:

  * ALRU over-eviction guard + on_evict-after-heap.free ordering,
  * cblas ``_view`` honoring (or rejecting) padded 2-D leading dims,
  * threads-mode condition-variable wakeup + RS drain on worker crash.
"""
import threading
import time

import numpy as np
import pytest

from repro.api import (BlasxContext, CblasColMajor, CblasNonUnit,
                       CblasNoTrans, CblasRight, CblasRowMajor, CblasUpper,
                       cblas_dgemm, cblas_sgemm, cblas_ssymm, cblas_ssyr2k,
                       cblas_ssyrk, cblas_strmm, cblas_strsm)
from repro.core import blas3
from repro.core.alru import Alru
from repro.core.dtypes import (canonical_dtype, promote_dtypes,
                               validate_backend_dtype)
from repro.core.heap import BlasxHeap
from repro.core.runtime import BlasxRuntime, RuntimeConfig
from repro.core.tiling import TileKey

RNG = np.random.default_rng(23)
F32_TOL = dict(rtol=2e-3, atol=2e-3)

M, N, K, TILE = 48, 40, 56, 16    # ragged edges, same shapes as parity


def _cfg(backend="numpy", **kw):
    kw.setdefault("n_devices", 2)
    kw.setdefault("mode", "sim")
    return RuntimeConfig(backend=backend, **kw)


def _f64(*shape):
    return RNG.standard_normal(shape)


# ========================================================= dtype registry
def test_canonical_dtype_spellings():
    assert canonical_dtype("float32") == np.float32
    assert canonical_dtype(np.float64) == np.float64
    assert canonical_dtype(np.dtype("float16")) == np.float16
    assert canonical_dtype("bfloat16").name == "bfloat16"
    with pytest.raises(ValueError, match="unsupported dtype"):
        canonical_dtype("int32")
    with pytest.raises(ValueError, match="unsupported dtype"):
        canonical_dtype("complex128")


def test_backend_dtype_matrix():
    for be in ("numpy", "jax", "pallas"):
        validate_backend_dtype("float64", be)
        validate_backend_dtype("float32", be)
    for half in ("float16", "bfloat16"):
        validate_backend_dtype(half, "jax")
        validate_backend_dtype(half, "pallas")
        with pytest.raises(ValueError, match="not supported"):
            validate_backend_dtype(half, "numpy")


def test_promote_dtypes_handles_bfloat16():
    bf = canonical_dtype("bfloat16")
    assert promote_dtypes(bf, bf) == bf       # fast path, no numpy table
    assert promote_dtypes(np.float32, np.float64) == np.float64


# ============================================= dtype through the surfaces
@pytest.mark.parametrize("backend", ["numpy", "jax", "pallas"])
def test_f32_gemm_matches_f32_oracle_all_backends(backend):
    A, B, C = _f64(M, K), _f64(K, N), _f64(M, N)
    got = blas3.gemm(A, B, C, alpha=1.3, beta=-0.7, tile=TILE,
                     dtype=np.float32, config=_cfg(backend))
    assert got.dtype == np.float32
    want = blas3.ref_gemm(A.astype(np.float32), B.astype(np.float32),
                          C.astype(np.float32), alpha=1.3, beta=-0.7)
    np.testing.assert_allclose(got, want, **F32_TOL)


@pytest.mark.parametrize("routine", ["syrk", "syr2k", "symm", "trmm", "trsm"])
def test_f32_dtype_through_legacy_wrappers(routine):
    A = _f64(M, K)
    S = _f64(M, M) / M + np.eye(M)            # well-conditioned for trsm
    B = _f64(M, N)
    if routine == "syrk":
        got = blas3.syrk(A, tile=TILE, dtype="float32")
        want = blas3.ref_syrk(A.astype(np.float32))
    elif routine == "syr2k":
        B2 = _f64(M, K)
        got = blas3.syr2k(A, B2, tile=TILE, dtype="float32")
        want = blas3.ref_syr2k(A.astype(np.float32), B2.astype(np.float32))
    elif routine == "symm":
        got = blas3.symm(S, B, tile=TILE, dtype="float32")
        want = blas3.ref_symm(S.astype(np.float32), B.astype(np.float32))
    elif routine == "trmm":
        got = blas3.trmm(S, B, tile=TILE, dtype="float32")
        want = blas3.ref_trmm(S.astype(np.float32), B.astype(np.float32))
    else:
        got = blas3.trsm(S, B, tile=TILE, dtype="float32")
        want = blas3.ref_trsm(S.astype(np.float32), B.astype(np.float32))
    assert got.dtype == np.float32
    np.testing.assert_allclose(got, want, rtol=1e-2, atol=1e-2)


def test_context_default_dtype_casts_and_propagates():
    A, B = _f64(M, K), _f64(K, N)
    with BlasxContext(_cfg(), tile=TILE, dtype=np.float32) as ctx:
        Ah = ctx.tile(A)
        assert Ah.dtype == np.float32
        out = ctx.gemm(Ah, B)                 # raw B cast on coercion
        assert out.dtype == np.float32
        # per-call override beats the context default
        out64 = ctx.gemm(A, B, dtype=np.float64)
        assert out64.dtype == np.float64
    np.testing.assert_allclose(
        out.array(), A.astype(np.float32) @ B.astype(np.float32), **F32_TOL)


def test_per_call_dtype_override_beats_context_default_on_inputs():
    """Regression: raw-array coercion used to re-tile through the
    context default, recasting a per-call dtype= override — wrong
    numerics in one direction (inputs quantized through a narrower
    default) and wrong byte accounting in the other."""
    A, B = _f64(64, 64), _f64(64, 64)
    with BlasxContext(_cfg(n_devices=1), tile=32, dtype=np.float64) as ctx:
        out = ctx.gemm(A, B, dtype=np.float32)
        assert out.dtype == np.float32
        # inputs moved at f32, not silently at the f64 context default
        assert ctx.last_call.h2d_bytes == (A.size + B.size) * 4
    with BlasxContext(_cfg("jax", n_devices=1), tile=32,
                      dtype="bfloat16") as ctx:
        E = np.eye(8) * 1.001
        out = ctx.gemm(E, np.eye(8), tile=8, dtype=np.float64)
        assert out.dtype == np.float64
        # a bf16 default must not quantize the f64-requested inputs:
        # bf16(1.001) == 1.0 exactly (error 1e-3); the f32-computing
        # CPU jax engine keeps it to ~1e-8
        assert abs(out.array()[0, 0] - 1.001) < 1e-4


def test_side_r_keeps_per_call_dtype_over_context_default():
    """Regression: the side='R' transpose epilogue used to re-tile the
    result through ctx.tile(), re-applying the context default dtype
    and silently recasting a per-call dtype= override."""
    n, m = 40, 32
    S = _f64(n, n) / n + np.eye(n)
    B = _f64(m, n)
    with BlasxContext(_cfg(), tile=16, dtype=np.float64) as ctx:
        out = ctx.symm(S, B, side="R", dtype=np.float32)
        assert out.dtype == np.float32
        np.testing.assert_allclose(
            out.array(),
            blas3.ref_symm(S.astype(np.float32), B.astype(np.float32),
                           side="R"), **F32_TOL)
        sol = ctx.trsm(S, B, side="R", dtype=np.float32)
        assert sol.dtype == np.float32


def test_context_rejects_handle_dtype_mismatch():
    with BlasxContext(_cfg(), tile=TILE) as ctx:
        Ah = ctx.tile(_f64(32, 32))           # float64 handle
        with pytest.raises(ValueError, match="re-tile"):
            ctx.gemm(Ah, Ah, dtype=np.float32)


def test_override_tiled_handle_usable_without_repeating_dtype():
    """Regression: a handle tiled with a per-call dtype override in a
    context with a default dtype was rejected by every subsequent
    dtype-less call (the default was enforced against the handle).
    Only an explicit per-call dtype= is strict; the context default
    still governs raw arrays and the output."""
    A = _f64(32, 32)
    with BlasxContext(_cfg(n_devices=1), tile=16,
                      dtype=np.float64) as ctx:
        h = ctx.tile(A, dtype=np.float32)     # documented override
        assert h.dtype == np.float32
        out = ctx.gemm(h, h)                  # must not raise
        assert out.dtype == np.float64        # output follows the default
        np.testing.assert_allclose(
            out.array(),
            A.astype(np.float32) @ A.astype(np.float32), **F32_TOL)
        assert ctx.tile(h) is h               # re-adoption also fine


def test_half_precision_rejected_on_numpy_backend():
    with pytest.raises(ValueError, match="not supported"):
        BlasxContext(_cfg("numpy"), dtype="float16")
    with pytest.raises(ValueError, match="not supported"):
        blas3.gemm(_f64(8, 8), _f64(8, 8), tile=8, dtype="bfloat16")
    # registration is validated too, not just the routine call
    with BlasxContext(_cfg("numpy"), tile=8) as ctx:
        with pytest.raises(ValueError, match="not supported"):
            ctx.tile(_f64(8, 8), dtype="float16")


def test_half_precision_input_rejected_even_when_promotion_widens():
    """Regression: a bf16 operand mixed with a wider one used to slip
    past the numpy-backend gate (the promoted output is f64) and crawl
    through ml_dtypes scalar paths."""
    bf = canonical_dtype("bfloat16")
    A16 = _f64(16, 16).astype(bf)
    B64 = _f64(16, 16)
    with BlasxContext(_cfg("numpy"), tile=8) as ctx:
        with pytest.raises(ValueError, match="not supported"):
            ctx.gemm(A16, B64)
        with pytest.raises(ValueError, match="not supported"):
            ctx.trmm(A16, B64)


def test_mixed_half_precisions_get_clear_error():
    """bfloat16 x float16 has no common numpy dtype; the promotion
    helper must surface a clear ValueError, not DTypePromotionError."""
    bf = canonical_dtype("bfloat16")
    A16 = _f64(16, 16).astype(bf)
    B16 = _f64(16, 16).astype(np.float16)
    with pytest.raises(ValueError, match="no common precision"):
        promote_dtypes(bf, np.float16)
    with BlasxContext(_cfg("jax"), tile=8) as ctx:
        with pytest.raises(ValueError, match="no common precision"):
            ctx.gemm(A16, B16)


def test_side_r_rejects_handle_dtype_mismatch_like_side_l():
    """Regression: side='R' degraded handles to raw arrays before the
    dtype-mismatch guard ran, silently recasting where side='L'
    raises."""
    n, m = 32, 24
    with BlasxContext(_cfg(), tile=16) as ctx:
        Ah = ctx.tile(_f64(n, n))             # float64 handle
        B = _f64(m, n)
        with pytest.raises(ValueError, match="re-tile"):
            ctx.symm(Ah, B, side="R", dtype=np.float32)
        with pytest.raises(ValueError, match="re-tile"):
            ctx.trsm(Ah, B, side="R", dtype=np.float32)


def test_c_seed_handle_casts_freely_on_both_sides():
    """C only seeds the output (it never becomes a cached-tile
    operand), so a dtype-mismatched C handle is cast — identically —
    on side='L' and side='R'."""
    n, m = 32, 24
    with BlasxContext(_cfg(), tile=16) as ctx:
        S32 = _f64(n, n).astype(np.float32)
        B32 = _f64(m, n).astype(np.float32)
        Ch = ctx.tile(_f64(m, n))             # float64 seed handle
        for side, A_, B_ in (("L", _f64(m, m).astype(np.float32), B32),
                             ("R", S32, B32)):
            out = ctx.symm(A_, B_, Ch, beta=0.5, side=side,
                           dtype=np.float32)
            assert out.dtype == np.float32
            want = blas3.ref_symm(A_, B_, Ch.array().astype(np.float32),
                                  beta=0.5, side=side)
            np.testing.assert_allclose(out.array(), want, **F32_TOL)


def test_half_precision_c_rejected_on_numpy_backend():
    """Regression: a bf16 C seed slipped past the gate — with
    force=False the output keeps C's dtype, so C's dtype is the real
    output dtype and must pass the backend check."""
    bf = canonical_dtype("bfloat16")
    A = _f64(16, 16).astype(np.float32)
    C16 = _f64(16, 16).astype(bf)
    with BlasxContext(_cfg("numpy"), tile=8) as ctx:
        with pytest.raises(ValueError, match="not supported"):
            ctx.gemm(A, A, C16, beta=1.0)
    # but the same C is fine where bf16 is supported
    with BlasxContext(_cfg("jax"), tile=8) as ctx:
        out = ctx.gemm(A, A, C16, beta=1.0)
        assert out.dtype.name == "bfloat16"


@pytest.mark.parametrize("dtype", ["bfloat16", "float16"])
def test_half_precision_gemm_on_jax_backend(dtype):
    A, B = _f64(M, K), _f64(K, N)
    got = blas3.gemm(A, B, tile=TILE, dtype=dtype, config=_cfg("jax"))
    assert got.dtype == canonical_dtype(dtype)
    # f32 accumulation: error is dominated by the input rounding, so a
    # half-precision-tolerance compare against the f64 oracle passes
    np.testing.assert_allclose(got.astype(np.float64), A @ B,
                               rtol=0.06, atol=0.3)


def test_half_precision_gemm_on_pallas_backend():
    n = 32
    A, B = _f64(n, n), _f64(n, n)
    got = blas3.gemm(A, B, tile=16, dtype="bfloat16", config=_cfg("pallas"))
    assert got.dtype.name == "bfloat16"
    np.testing.assert_allclose(got.astype(np.float64), A @ B,
                               rtol=0.06, atol=0.3)


def test_step_groups_key_on_dtype():
    """Mixed-precision session: f32 and f64 calls through one runtime
    must never share a dispatch group (the compile caches key on dtype
    via StepGroupKey)."""
    rt = BlasxRuntime(_cfg("jax", n_devices=1))
    A = _f64(64, 64)
    blas3.gemm(A, A, tile=32, runtime=rt, dtype=np.float32)
    blas3.gemm(A, A, tile=32, runtime=rt, dtype=np.float64)
    ls = rt.launch_stats()
    assert ls["groups"] >= 2                  # one per dtype at minimum


# ===================================== precision-aware byte accounting
def test_tile_nbytes_track_storage_dtype():
    """The ALRU/heap/ledger accounting and the comm model are storage-
    dtype aware: the same workload in f32 moves and caches exactly half
    the bytes of f64 (bf16 a quarter)."""
    A = _f64(256, 256)

    def run(dtype, backend="numpy"):
        ctx = BlasxContext(_cfg(backend, n_devices=1), tile=64)
        try:
            ctx.gemm(A, A, dtype=dtype)
            rec = ctx.last_call
            heap_used = ctx.runtime.devices[0].heap.used
            return rec.h2d_bytes, rec.d2h_bytes, heap_used
        finally:
            ctx.close()

    h64, w64, u64 = run(np.float64)
    h32, w32, u32 = run(np.float32)
    assert h64 == 2 * h32 and w64 == 2 * w32 and u64 == 2 * u32
    h16, w16, u16 = run("bfloat16", backend="jax")
    assert h64 == 4 * h16 and w64 == 4 * w16 and u64 == 4 * u16


def test_shadow_run_models_precision():
    rt64 = BlasxRuntime(_cfg(execute=False))
    blas3.shadow_run("gemm", 2048, tile=256, runtime=rt64)
    rt32 = BlasxRuntime(_cfg(execute=False))
    blas3.shadow_run("gemm", 2048, tile=256, runtime=rt32, dtype="float32")
    assert rt64.total_comm_bytes()["h2d"] == \
        2 * rt32.total_comm_bytes()["h2d"]
    # half the bytes -> half the modeled transfer time -> faster clock
    assert rt32.makespan() < rt64.makespan()


# ================================================== cblas single precision
def test_cblas_sgemm_matches_f32_oracle_all_backends():
    m, n, k = 48, 40, 32
    A = _f64(m, k).astype(np.float32)
    B = _f64(k, n).astype(np.float32)
    for backend in ("numpy", "jax", "pallas"):
        C = _f64(m, n).astype(np.float32)
        want = blas3.ref_gemm(A, B, C, alpha=1.2, beta=0.8)
        cblas_sgemm(CblasRowMajor, CblasNoTrans, CblasNoTrans, m, n, k,
                    1.2, A, k, B, n, 0.8, C, n, backend=backend)
        np.testing.assert_allclose(C, want, **F32_TOL)


def test_cblas_single_precision_surface_all_six():
    n, k, m = 40, 24, 32
    A = _f64(n, k).astype(np.float32)
    B = _f64(n, k).astype(np.float32)
    S = (_f64(n, n) / n + np.eye(n)).astype(np.float32)
    X = _f64(m, n).astype(np.float32)
    with BlasxContext(_cfg(), tile=16) as ctx:
        C = np.zeros((n, n), np.float32)
        cblas_ssyrk(CblasRowMajor, CblasUpper, CblasNoTrans, n, k, 1.0,
                    A, k, 0.0, C, n, ctx=ctx)
        np.testing.assert_allclose(np.triu(C), np.triu(A @ A.T), **F32_TOL)

        C = np.zeros((n, n), np.float32)
        cblas_ssyr2k(CblasRowMajor, CblasUpper, CblasNoTrans, n, k, 0.5,
                     A, k, B, k, 0.0, C, n, ctx=ctx)
        np.testing.assert_allclose(
            np.triu(C), np.triu(0.5 * (A @ B.T + B @ A.T)), **F32_TOL)

        C = np.zeros((m, n), np.float32)
        cblas_ssymm(CblasRowMajor, CblasRight, CblasUpper, m, n, 1.0,
                    S, n, X, n, 0.0, C, n, ctx=ctx)
        want = blas3.ref_symm(S, X, side="R", uplo="U")
        np.testing.assert_allclose(C, want, **F32_TOL)

        Bb = X.copy()
        cblas_strmm(CblasRowMajor, CblasRight, CblasUpper, CblasNoTrans,
                    CblasNonUnit, m, n, 0.9, S, n, Bb, n, ctx=ctx)
        np.testing.assert_allclose(
            Bb, blas3.ref_trmm(S, X, alpha=0.9, side="R"), **F32_TOL)

        Bb = X.copy()
        cblas_strsm(CblasRowMajor, CblasRight, CblasUpper, CblasNoTrans,
                    CblasNonUnit, m, n, 1.1, S, n, Bb, n, ctx=ctx)
        np.testing.assert_allclose(
            Bb, blas3.ref_trsm(S, X, alpha=1.1, side="R"),
            rtol=5e-3, atol=5e-3)

        # every tile the f32 surface cached is 4 bytes/element
        assert all(c.h2d_bytes % 4 == 0 for c in ctx.calls)


def test_cblas_sgemm_rejects_f64_output_buffer():
    with pytest.raises(TypeError, match="float32"):
        cblas_sgemm(CblasRowMajor, CblasNoTrans, CblasNoTrans, 4, 4, 4,
                    1.0, np.eye(4, dtype=np.float32), 4,
                    np.eye(4, dtype=np.float32), 4, 0.0,
                    np.zeros((4, 4)), 4)
    with pytest.raises(TypeError, match="float64"):
        cblas_dgemm(CblasRowMajor, CblasNoTrans, CblasNoTrans, 4, 4, 4,
                    1.0, np.eye(4), 4, np.eye(4), 4, 0.0,
                    np.zeros((4, 4), np.float32), 4)


# ================================= bugfix: _view padded 2-D leading dims
@pytest.mark.parametrize("dtype,fn", [(np.float64, cblas_dgemm),
                                      (np.float32, cblas_sgemm)])
def test_cblas_padded_ld_row_major_round_trip(dtype, fn):
    """2-D operands that are strided views into padded storage: ld is
    honored (the pre-fix code silently returned dense semantics)."""
    m, n, k = 20, 14, 12
    lda, ldb, ldc = k + 5, n + 3, n + 7
    A = _f64(m, k).astype(dtype)
    B = _f64(k, n).astype(dtype)
    C = _f64(m, n).astype(dtype)
    want = blas3.ref_gemm(A, B, C, alpha=1.1, beta=0.4)
    Abuf = np.zeros((m, lda), dtype)
    Abuf[:, :k] = A
    Bbuf = np.zeros((k, ldb), dtype)
    Bbuf[:, :n] = B
    Cbuf = np.zeros((m, ldc), dtype)
    Cbuf[:, :n] = C
    fn(CblasRowMajor, CblasNoTrans, CblasNoTrans, m, n, k, 1.1,
       Abuf[:, :k], lda, Bbuf[:, :n], ldb, 0.4, Cbuf[:, :n], ldc)
    np.testing.assert_allclose(Cbuf[:, :n], want,
                               **(F32_TOL if dtype == np.float32
                                  else dict(rtol=1e-10, atol=1e-10)))
    # the padding columns were never touched
    assert not Cbuf[:, n:].any()


@pytest.mark.parametrize("dtype,fn", [(np.float64, cblas_dgemm),
                                      (np.float32, cblas_sgemm)])
def test_cblas_padded_ld_col_major_round_trip(dtype, fn):
    m, n, k = 18, 16, 10
    lda, ldb, ldc = m + 4, k + 2, m + 6
    A = _f64(m, k).astype(dtype)
    B = _f64(k, n).astype(dtype)
    want = blas3.ref_gemm(A, B)
    # column-major padded storage: F-ordered buffers, logical view on top
    Abuf = np.zeros((lda, k), dtype, order="F")
    Abuf[:m, :] = A
    Bbuf = np.zeros((ldb, n), dtype, order="F")
    Bbuf[:k, :] = B
    Cbuf = np.zeros((ldc, n), dtype, order="F")
    fn(CblasColMajor, CblasNoTrans, CblasNoTrans, m, n, k, 1.0,
       Abuf[:m, :], lda, Bbuf[:k, :], ldb, 0.0, Cbuf[:m, :], ldc)
    np.testing.assert_allclose(Cbuf[:m, :], want,
                               **(F32_TOL if dtype == np.float32
                                  else dict(rtol=1e-10, atol=1e-10)))
    assert not Cbuf[m:, :].any()


def test_cblas_padded_ld_input_of_other_dtype_is_cast_not_rejected():
    """The documented contract: read-only inputs of other dtypes are
    cast AND a padded ld is honored — the layout check must run on the
    caller's buffer, not on the cast's dense copy."""
    m, n, k = 10, 8, 6
    lda = k + 4
    A = _f64(m, k)                            # float64 into cblas_sgemm
    B = _f64(k, n).astype(np.float32)
    Abuf = np.zeros((m, lda))
    Abuf[:, :k] = A
    C = np.zeros((m, n), np.float32)
    cblas_sgemm(CblasRowMajor, CblasNoTrans, CblasNoTrans, m, n, k, 1.0,
                Abuf[:, :k], lda, B, n, 0.0, C, n)
    np.testing.assert_allclose(C, A.astype(np.float32) @ B, **F32_TOL)


def test_cblas_single_row_accepts_any_ld():
    """With one row (row major) the leading stride is never exercised,
    so a larger-than-dense ld is legal C usage on a dense buffer."""
    k, n = 4, 5
    A = _f64(1, k)
    B = _f64(k, n)
    C = np.zeros((1, n))
    cblas_dgemm(CblasRowMajor, CblasNoTrans, CblasNoTrans, 1, n, k,
                1.0, A, k + 4, B, n, 0.0, C, n + 7)
    np.testing.assert_allclose(C, A @ B, rtol=1e-10, atol=1e-10)


def test_cblas_dense_buffer_with_padded_ld_raises():
    """Regression: a dense 2-D array with ld > dense leading dimension
    used to be silently accepted with dense semantics."""
    m, n, k = 8, 6, 4
    A = np.zeros((m, k))
    B = np.zeros((k, n))
    C = np.zeros((m, n))
    with pytest.raises(ValueError, match="memory layout"):
        cblas_dgemm(CblasRowMajor, CblasNoTrans, CblasNoTrans, m, n, k,
                    1.0, A, k + 3, B, n, 0.0, C, n)
    with pytest.raises(ValueError, match="memory layout"):
        cblas_dgemm(CblasColMajor, CblasNoTrans, CblasNoTrans, m, n, k,
                    1.0, np.asfortranarray(A), m, np.asfortranarray(B), k,
                    0.0, np.asfortranarray(C), m + 2)


# ============================ bugfix: ALRU over-eviction + evict ordering
def test_alru_unattainable_translate_evicts_nothing():
    """Regression: a request that can never fit (pinned blocks fence
    the heap) used to wipe every zero-reader block before failing."""
    heap = BlasxHeap(300)
    a = Alru(0, heap)
    evicted = []
    a.on_evict = lambda dev, key: evicted.append(key)
    k1, k2, k3 = (TileKey("A", 0, i) for i in range(3))
    a.translate(k1, 100)
    a.release(k1)
    a.translate(k2, 100)                      # pinned: reader stays 1
    a.translate(k3, 100)
    a.release(k3)
    assert a.translate(TileKey("A", 0, 9), 250) is None
    assert evicted == []                      # no over-eviction
    assert k1 in a and k2 in a and k3 in a
    a.check_invariants()
    heap.check_invariants()
    # an attainable request still succeeds by evicting only what it
    # needs (one 100-byte run opens by evicting the LRU block alone)
    assert a.translate(TileKey("A", 0, 10), 100) is not None
    assert k2 in a                            # pinned block untouched
    assert len(evicted) == 1                  # exactly one victim


def test_alru_on_evict_fires_after_heap_free():
    """Regression: on_evict used to fire before heap.free, so the
    directory observed an evicted tile whose bytes were still
    allocated."""
    heap = BlasxHeap(200)
    a = Alru(0, heap)
    used_at_evict = []
    a.on_evict = lambda dev, key: used_at_evict.append(heap.used)
    for j in range(2):
        k = TileKey("A", 0, j)
        a.translate(k, 100)
        a.release(k)
    a.translate(TileKey("A", 0, 7), 150)      # evicts both 100-byte blocks
    # at each callback the victim's bytes were already freed:
    # first eviction leaves <=100 used, second leaves 0
    assert used_at_evict == [100, 0]


def test_heap_largest_attainable_run():
    h = BlasxHeap(100)
    a = h.malloc(30)
    b = h.malloc(30)
    h.malloc(30)
    assert h.largest_free_run() == 10
    # freeing a (offset 0) alone yields its 30-byte run; freeing b too
    # bridges a+b; the tail free 10 only joins via the occupied third
    assert h.largest_attainable_run({a}) == 30
    assert h.largest_attainable_run({a, b}) == 60
    h.free(b)
    assert h.largest_free_run() == 30
    assert h.largest_attainable_run({a}) == 60


# =========================== bugfix: threads-mode wakeup + crash recovery
def test_threads_crash_leaves_no_stranded_tasks():
    """Regression: a crashed worker used to strand its RS-resident
    (incl. stolen) tasks; survivors now drain every RS back to the
    global queue and the injected error surfaces as raised."""
    A = RNG.standard_normal((256, 256))
    rt = BlasxRuntime(RuntimeConfig(n_devices=2, mode="threads",
                                    cache_bytes=32 << 20))
    orig = rt._execute_batch

    def boom(d, batch):
        if d.id == 1:
            raise RuntimeError("injected-crash")
        time.sleep(0.005)   # keep the healthy device slow enough that
        return orig(d, batch)  # the crash lands with work still queued

    rt._execute_batch = boom
    t0 = time.perf_counter()
    with pytest.raises(RuntimeError, match="injected-crash"):
        blas3.gemm(A, A, tile=32, runtime=rt)
    assert time.perf_counter() - t0 < 30      # survivors exit promptly
    assert all(len(d.rs) == 0 for d in rt.devices)
    assert rt._queue.has_ready()              # drained tasks were requeued
    # full accounting: every task is either completed or dequeueable
    # again — the crashed worker's in-flight batch included
    executed = sum(d.ledger.tasks for d in rt.devices)
    assert executed + len(rt._queue) == 64    # 8x8 tiles at tile=32


def test_failed_batch_releases_acquired_readers():
    """Regression: a batch failing after gather (backend error) left
    its acquired tiles pinned (reader > 0) for the whole session —
    blocking eviction and making handle.invalidate() raise."""
    A = RNG.standard_normal((128, 128))
    rt = BlasxRuntime(RuntimeConfig(n_devices=2, mode="threads",
                                    cache_bytes=32 << 20))
    orig = rt._dispatch_steps

    def boom(d, recs):
        if d.id == 1:
            raise RuntimeError("dispatch-crash")   # after gather
        time.sleep(0.002)   # keep the healthy device slow enough that
        return orig(d, recs)  # the crashing one always gets a batch

    rt._dispatch_steps = boom
    with pytest.raises(RuntimeError, match="dispatch-crash"):
        blas3.gemm(A, A, tile=32, runtime=rt)
    for d in rt.devices:
        for k in d.alru.keys():
            assert d.alru.peek(k).reader == 0, (d.id, k)


def test_threads_workers_never_sleep_poll(monkeypatch):
    """Regression: starved workers used to busy-wait with
    time.sleep(0.0005); they now park on a condition variable."""
    import repro.core.runtime as rtmod

    calls = []
    real_sleep = time.sleep

    class TimeProxy:
        perf_counter = staticmethod(time.perf_counter)

        @staticmethod
        def sleep(s):
            calls.append(s)
            return real_sleep(s)

    monkeypatch.setattr(rtmod, "time", TimeProxy)
    A = RNG.standard_normal((192, 192))
    # more devices than work at the tail -> pre-fix this spins sleep()
    out = blas3.gemm(A, A, tile=64,
                     config=RuntimeConfig(n_devices=4, mode="threads"))
    np.testing.assert_allclose(out, A @ A, rtol=1e-10, atol=1e-10)
    assert calls == []


def test_threads_condition_variable_wakes_on_completion():
    """A worker parked on the CV (deps pending) is woken by a peer's
    completion, not by a poll timeout: TRSM's intra-column chains
    complete in threads mode well before any timeout-paced schedule
    could."""
    n = 128
    A = RNG.standard_normal((n, n)) / n + np.eye(n)
    B = RNG.standard_normal((n, n))
    out = blas3.trsm(A, B, tile=32,
                     config=RuntimeConfig(n_devices=3, mode="threads"))
    np.testing.assert_allclose(out, blas3.ref_trsm(A, B),
                               rtol=1e-8, atol=1e-8)
