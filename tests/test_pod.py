"""Pod-scale tier acceptance: device classes, the 3-level tile cache
(host DRAM -> device HBM -> ICI neighbor), panel staging for
beyond-HBM GEMMs, ICI lane/ledger accounting, knob threading through
context/blas3/cblas, and the autotuner topology fingerprint."""
import numpy as np
import pytest

from repro.core import blas3
from repro.core import task as taskmod
from repro.core.runtime import (DEVICE_CLASSES, ICI_BW, BlasxRuntime,
                                DeviceClass, RuntimeConfig)
from repro.core.task import KIND_FIXUP, KIND_OWNER, KIND_PARTIAL
from repro.core.tiling import TileGrid, TiledMatrix, panel_parts

RNG = np.random.default_rng(11)

TILE = 64
TILE_BYTES = TILE * TILE * 8                     # f64 tile
# beyond-HBM regime: 512x512 needs 8x8=64 A-tiles alone, HBM holds 8
SMALL_HBM = 8 * TILE_BYTES


def _pod_cfg(**kw):
    kw.setdefault("n_devices", 2)
    kw.setdefault("mode", "sim")
    kw.setdefault("device_class", "mesh_shard")
    kw.setdefault("mesh_devices", 4)
    return RuntimeConfig(**kw)


# ------------------------------------------------------- device classes
def test_device_class_registry_and_peaks():
    acc, mesh = DEVICE_CLASSES["accelerator"], DEVICE_CLASSES["mesh_shard"]
    assert not acc.ring and mesh.ring
    assert acc.peak_flops(1e12, 4) == 1e12       # flat device ignores mesh
    assert mesh.peak_flops(1e12, 4) == 4e12      # a device IS the ring
    assert acc.hop_bytes(1000, 4) == 0           # fills never touch ICI
    assert mesh.hop_bytes(1000, 4) == 750        # (d-1)/d scatter traffic
    assert mesh.hop_bytes(1000, 1) == 0
    assert DeviceClass("x", ring=False).hop_bytes(8, 16) == 0


def test_config_validation():
    with pytest.raises(ValueError, match="device_class"):
        RuntimeConfig(device_class="tpu")
    with pytest.raises(ValueError, match="mesh_devices"):
        RuntimeConfig(device_class="mesh_shard", mesh_devices=1)
    with pytest.raises(ValueError, match="mesh_shard"):
        RuntimeConfig(mesh_devices=4)            # ring size on a flat class
    with pytest.raises(ValueError, match="ici_bw"):
        RuntimeConfig(ici_bw=0.0)
    cfg = _pod_cfg()
    assert cfg.device_peak_flops == cfg.peak_flops * 4
    assert cfg.stage_panels_on                   # derived from the class
    assert not RuntimeConfig().stage_panels_on
    # explicit stage_panels wins over the class default either way
    assert not _pod_cfg(stage_panels=False).stage_panels_on
    assert RuntimeConfig(stage_panels=True).stage_panels_on


def test_topology_fingerprint_carries_pod_fields():
    base, pod = RuntimeConfig().topology(), _pod_cfg(n_devices=2).topology()
    for k in ("device_class", "mesh_devices", "ici_bw"):
        assert k in base and k in pod
    assert base["device_class"] == "accelerator"
    assert pod != base
    # the learned cost model ingests only numeric topology features —
    # the string class stays fingerprint-only, the ring fields join
    from repro.tuning.model import feature_names
    names = feature_names(pod)
    assert "topo_device_class" not in names
    assert "topo_mesh_devices" in names and "topo_ici_bw" in names


# ------------------------------------------------------- panel planner
def test_panel_parts_triggers_only_beyond_hbm():
    cache = 100
    assert panel_parts(80, cache, 8) == 0        # fits HBM: never split
    assert panel_parts(100, cache, 8) == 0       # boundary still fits
    assert panel_parts(101, cache, 8) == 3       # ceil(101/50) panels
    assert panel_parts(400, cache, 8) == 8       # capped at k-steps
    assert panel_parts(400, cache, 1) == 0       # 1-step loop can't split
    assert panel_parts(400, 0, 8) == 0           # no cache model: off


def _gemm_tasks(n, tile, k=None):
    k = k if k is not None else n
    ga, gb, gc = (TileGrid("A", n, k, tile), TileGrid("B", k, n, tile),
                  TileGrid("C", n, n, tile))
    grids = {"A": ga, "B": gb, "C": gc}
    tasks = taskmod.taskize_gemm(ga, gb, gc, "N", "N", 1.0, 0.0)
    mats = {m: TiledMatrix(g.matrix_id, np.zeros((g.rows, g.cols)), tile)
            for m, g in grids.items()}
    return tasks, grids, mats


def test_plan_panel_staged_splits_beyond_hbm_tasks():
    tasks, grids, mats = _gemm_tasks(512, TILE)  # 8 k-steps/task
    planned = taskmod.plan_panel_staged(tasks, mats, SMALL_HBM)
    owners = [t for t in planned if t.kind == KIND_OWNER]
    partials = [t for t in planned if t.kind == KIND_PARTIAL]
    fixups = [t for t in planned if t.kind == KIND_FIXUP]
    assert not owners and len(fixups) == len(tasks)
    # each task reads 8 A + 8 B tiles = 16 tiles >> the 8-tile HBM;
    # panels sized to cache/2 = 4 tiles -> ceil(16/4) = 4 parts
    assert len(partials) == 4 * len(tasks)
    for f in fixups:
        sibs = [p for p in partials if p.parent == f.task_id]
        assert f.deps[-len(sibs):] == tuple(p.task_id for p in sibs)
        assert all(p.beta == 0.0 for p in sibs)  # partials never write C
    # within-HBM problems pass through untouched
    small, _, smats = _gemm_tasks(128, TILE)
    assert taskmod.plan_panel_staged(small, smats, 1 << 30) == small


# --------------------------------------------- beyond-HBM GEMM numerics
def test_beyond_hbm_staged_gemm_is_bitwise_identical():
    """The tentpole acceptance: a GEMM whose working set exceeds one
    device's HBM runs through the 3-level staged path and matches the
    unstaged pod run, the flat accelerator run, and the dense oracle —
    the accelerator path is bit-and-result identical to before."""
    n = 512
    A = RNG.standard_normal((n, n))
    B = RNG.standard_normal((n, n))
    base = blas3.gemm(A, B, tile=TILE, config=RuntimeConfig(
        n_devices=2, mode="sim", cache_bytes=SMALL_HBM))
    staged = blas3.gemm(A, B, tile=TILE, config=_pod_cfg(
        cache_bytes=SMALL_HBM))
    unstaged = blas3.gemm(A, B, tile=TILE, config=_pod_cfg(
        cache_bytes=SMALL_HBM, stage_panels=False))
    assert np.array_equal(staged, unstaged)
    assert np.array_equal(staged, base)
    np.testing.assert_allclose(staged, A @ B, rtol=1e-10, atol=1e-10)


@pytest.mark.parametrize("routine", ["syrk", "trsm"])
def test_pod_parity_beyond_gemm(routine):
    n = 384
    A = RNG.standard_normal((n, n))
    if routine == "trsm":
        A = A + n * np.eye(n)                    # well-conditioned solve
    B = RNG.standard_normal((n, n))
    kw = dict(tile=TILE)
    fn = getattr(blas3, routine)
    args = (A,) if routine == "syrk" else (A, B)
    base = fn(*args, config=RuntimeConfig(
        n_devices=2, mode="sim", cache_bytes=SMALL_HBM), **kw)
    pod = fn(*args, config=_pod_cfg(cache_bytes=SMALL_HBM), **kw)
    assert np.array_equal(base, pod)


# ------------------------------------------------------ ICI accounting
def test_ici_busy_equals_bytes_over_bandwidth():
    """The ledger decomposition the bench gate relies on: every ICI
    transfer is charged at exactly ici_bw, so lane busy seconds equal
    ici_bytes / ici_bw on every device — by construction, not fit."""
    n = 512
    A = RNG.standard_normal((n, n))
    rt = BlasxRuntime(_pod_cfg(cache_bytes=SMALL_HBM))
    blas3.gemm(A, A, tile=TILE, runtime=rt)
    total = 0
    for d in rt.devices:
        assert d.ledger.ici_bytes > 0
        np.testing.assert_allclose(
            d.ledger.ici_busy_s, d.ledger.ici_bytes / rt.cfg.ici_bw,
            rtol=1e-12)
        total += d.ledger.ici_bytes
    assert rt.total_comm_bytes()["ici"] == total


def test_accelerator_path_never_touches_ici():
    n = 512
    A = RNG.standard_normal((n, n))
    rt = BlasxRuntime(RuntimeConfig(n_devices=2, mode="sim",
                                    cache_bytes=SMALL_HBM))
    blas3.gemm(A, A, tile=TILE, runtime=rt)
    assert rt.total_comm_bytes()["ici"] == 0
    assert all(d.ledger.ici_busy_s == 0.0 for d in rt.devices)


def test_trace_has_ici_lane_spans():
    from repro.core.events import trace_spans, validate_trace

    n = 512
    A = RNG.standard_normal((n, n))
    rt = BlasxRuntime(_pod_cfg(cache_bytes=SMALL_HBM))
    blas3.gemm(A, A, tile=TILE, runtime=rt)
    tr = rt.trace()
    validate_trace(tr)
    assert [s for s in trace_spans(tr) if s["cat"] == "ici"]
    # every modeled ICI byte shows up on a trace span
    nbytes = sum((ev.get("args") or {}).get("nbytes", 0)
                 for ev in tr["traceEvents"]
                 if ev.get("ph") == "B" and ev.get("cat") == "ici")
    assert nbytes == rt.total_comm_bytes()["ici"]


def test_neighbor_tier_serves_ride_ici_not_pcie():
    """Level 3 of the cache: an L2 hit between mesh_shard devices is a
    neighbor-ICI transfer (fast lane, ici ledger), not a PCIe peer copy
    — d2d stays reserved for the flat accelerator fabric."""
    n = 512
    A = RNG.standard_normal((n, n))
    pod = BlasxRuntime(_pod_cfg(cache_bytes=SMALL_HBM))
    blas3.gemm(A, A, tile=TILE, runtime=pod)
    acc = BlasxRuntime(RuntimeConfig(n_devices=2, mode="sim",
                                     cache_bytes=SMALL_HBM))
    blas3.gemm(A, A, tile=TILE, runtime=acc)
    assert pod.total_comm_bytes()["d2d"] == 0
    assert acc.total_comm_bytes()["d2d"] > 0


# ------------------------------------------------- staged wins deep-k
def test_staged_beats_unstaged_on_deep_k_shadow():
    """The regime the tier exists for: a deep-k beyond-HBM DGEMM whose
    unique working set fits the *pod's aggregate* HBM.  Staging panels
    through the cache must beat the bypass-everything baseline on the
    virtual clock (same invariant benchmarks/compare.py gates)."""
    from repro.core.tiling import ShadowMatrix

    n, k, tile = 2048, 16384, 1024
    cache = 24 * tile * tile * 8                 # 24 f64 tiles of HBM
    makespans = {}
    for staged in (True, False):
        rt = BlasxRuntime(_pod_cfg(
            n_devices=4, n_streams=2, cache_bytes=cache, execute=False,
            record_trace=False, stage_panels=staged))
        mats = {"A": ShadowMatrix("A", n, k, tile),
                "B": ShadowMatrix("B", k, n, tile),
                "C": ShadowMatrix("C", n, n, tile)}
        tasks = taskmod.taskize_gemm(mats["A"].grid, mats["B"].grid,
                                     mats["C"].grid, "N", "N", 1.0, 0.0)
        rt.run(tasks, mats, "C")
        makespans[staged] = rt.makespan()
    assert makespans[True] < makespans[False]


# --------------------------------------------------- API knob threading
def test_context_knobs_thread_to_config_and_records():
    from repro.api import BlasxContext

    A = RNG.standard_normal((300, 200))
    B = RNG.standard_normal((200, 250))
    with BlasxContext(mesh=4, tile=TILE) as ctx:
        # mesh= alone implies the mesh_shard class
        assert ctx.cfg.device_class == "mesh_shard"
        assert ctx.cfg.mesh_devices == 4
        out = ctx.gemm(A, B).array()
        np.testing.assert_allclose(out, A @ B, rtol=1e-10, atol=1e-10)
        rec = ctx.calls[-1]
        assert rec.ici_bytes > 0
        assert rec.input_bytes >= rec.ici_bytes
    with BlasxContext(tile=TILE) as ctx:
        ctx.gemm(A, B)
        assert ctx.calls[-1].ici_bytes == 0
    with pytest.raises(ValueError, match="runtime"):
        BlasxContext(runtime=BlasxRuntime(RuntimeConfig()), mesh=4)


def test_blas3_and_cblas_knobs():
    from repro.api import cblas

    A = RNG.standard_normal((192, 160))
    B = RNG.standard_normal((160, 128))
    base = blas3.gemm(A, B, tile=TILE,
                      config=RuntimeConfig(n_devices=2, mode="sim"))
    pod = blas3.gemm(A, B, tile=TILE, device_class="mesh_shard", mesh=4)
    assert np.array_equal(base, pod)
    C = np.zeros((192, 128))
    cblas.cblas_dgemm(cblas.CblasRowMajor, cblas.CblasNoTrans,
                      cblas.CblasNoTrans, 192, 128, 160, 1.0, A, 160,
                      B, 128, 0.0, C, 128, mesh=4, tile=TILE)
    assert np.array_equal(C, base)
    # pod knobs conflicting with an explicit ctx= are config errors
    from repro.api import BlasxContext
    with BlasxContext(mesh=4) as ctx:
        with pytest.raises(ValueError, match="mesh"):
            cblas._ctx(ctx, mesh=8)
        with pytest.raises(ValueError, match="device_class"):
            cblas._ctx(ctx, device_class="accelerator")
