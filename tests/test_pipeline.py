"""Pipeline parallelism: 1F1B schedule correctness + executor gradients
equal the unpipelined reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.pipeline import (PipelineExecutor, bubble_fraction,
                                   make_stages_from_model, schedule_1f1b)


def _validate_schedule(ticks, S, M):
    fwd_done = [[False] * M for _ in range(S)]
    bwd_done = [[False] * M for _ in range(S)]
    for row in ticks:
        assert len(row) == S
        for t in row:
            if t is None:
                continue
            if t.kind == "fwd":
                assert not fwd_done[t.stage][t.micro]
                if t.stage > 0:          # upstream fwd must be done
                    assert fwd_done[t.stage - 1][t.micro]
                fwd_done[t.stage][t.micro] = True
            else:
                assert fwd_done[t.stage][t.micro]
                assert not bwd_done[t.stage][t.micro]
                if t.stage < S - 1:      # downstream bwd must be done
                    assert bwd_done[t.stage + 1][t.micro]
                bwd_done[t.stage][t.micro] = True
    assert all(all(r) for r in fwd_done)
    assert all(all(r) for r in bwd_done)


@pytest.mark.parametrize("S,M", [(2, 4), (4, 8), (4, 4), (3, 1), (1, 3)])
def test_1f1b_schedule_is_valid(S, M):
    _validate_schedule(schedule_1f1b(S, M), S, M)


def test_1f1b_memory_bound():
    """1F1B's point: at most ~S microbatch residuals live per stage."""
    S, M = 4, 16
    ticks = schedule_1f1b(S, M)
    live = set()
    peak = 0
    for row in ticks:
        for t in row:
            if t is None:
                continue
            if t.kind == "fwd":
                live.add((t.stage, t.micro))
            else:
                live.discard((t.stage, t.micro))
        peak = max(peak, len(live))
    # GPipe would hold S*M = 64; 1F1B stays near S*(S+1)/2
    assert peak <= S * (S + 1)


def test_bubble_fraction():
    assert bubble_fraction(4, 4) == pytest.approx(3 / 7)
    assert bubble_fraction(1, 8) == 0.0
    assert bubble_fraction(4, 28) < 0.1


def test_pipeline_executor_matches_reference_grads():
    """2-stage pipelined fwd+bwd == monolithic jax.grad."""
    rng = np.random.default_rng(0)
    d = 8
    w1 = jnp.asarray(rng.standard_normal((d, d)) * 0.3, jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((d, d)) * 0.3, jnp.float32)
    xs = [jnp.asarray(rng.standard_normal((4, d)), jnp.float32)
          for _ in range(6)]

    def stage_f(w, x):
        return jnp.tanh(x @ w)

    fwd, bwd = make_stages_from_model(stage_f, 2)
    ex = PipelineExecutor(fwd, bwd, [w1, w2])
    outs, grads, stats = ex.run(xs, dy_fn=lambda m, y: jnp.ones_like(y))

    # reference: full model, summed over microbatches
    def full_loss(ws, x):
        return jnp.sum(stage_f(ws[1], stage_f(ws[0], x)))

    ref_g = None
    for x in xs:
        g = jax.grad(lambda ws: full_loss(ws, x))((w1, w2))
        ref_g = g if ref_g is None else jax.tree.map(jnp.add, ref_g, g)
        y_ref = stage_f(w2, stage_f(w1, x))
    np.testing.assert_allclose(np.asarray(outs[-1]), np.asarray(y_ref),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(grads[0]), np.asarray(ref_g[0]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(grads[1]), np.asarray(ref_g[1]),
                               rtol=1e-5, atol=1e-6)
    assert stats["bubble_frac"] == pytest.approx(1 / 7)


def test_int8_optimizer_state():
    """8-bit moments: converges on the quadratic and uses ~2 bytes/param."""
    from repro.optim import adamw
    cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=1,
                            total_steps=400)
    params = {"w": jnp.asarray(np.random.default_rng(0)
                               .standard_normal(512) * 3, jnp.float32)}
    state = adamw.init_state_int8(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    l0 = float(loss(params))
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.apply_updates_int8(cfg, params, g, state)
    # quantization noise leaves a small floor; demand a >500x reduction
    assert float(loss(params)) < min(5.0, l0 / 500)
    m_bytes = state["m"]["w"]["q"].nbytes + state["m"]["w"]["scale"].nbytes
    assert m_bytes < 512 * 1.2  # ~1.03 bytes/param for m
