"""Serving front-end tests: admission queue (bound, priority classes,
tenant fairness), per-tenant ALRU quotas (the isolation invariant and
its fails-without-quotas counterpart), tenant/priority threading
through the runtime, the MESI-X directory audit, and the BlasxServer
end to end (numerics, affinity, overflow, rejection, cancellation,
stats, close)."""
import concurrent.futures
import threading
import time

import numpy as np
import pytest

from repro.api import BackpressureError, BlasxContext
from repro.core.alru import Alru
from repro.core.coherence import MesixDirectory
from repro.core.heap import BlasxHeap
from repro.core.runtime import BlasxRuntime, RuntimeConfig
from repro.core.task import taskize_gemm
from repro.core.tiling import TiledMatrix, TileKey
from repro.serve import (BATCH, INTERACTIVE, AdmissionQueue, BlasxServer,
                         ServeRequest, ServerStats, percentile)

RNG = np.random.default_rng(23)


def _req(tenant, priority=BATCH, lane=0):
    return ServeRequest(tenant=tenant, routine="gemm", args=(), kwargs={},
                        priority=priority, lane=lane,
                        future=concurrent.futures.Future())


# ========================================================= admission queue
def test_admission_rejects_bad_priority_class():
    with pytest.raises(ValueError, match="priority"):
        _req("a", priority="urgent")


def test_admission_depth_bound():
    q = AdmissionQueue(max_depth=2)
    assert q.offer(_req("a"))
    assert q.offer(_req("b"))
    assert not q.offer(_req("c"))      # at the bound: shed
    assert q.depth == 2
    q.take()
    assert q.offer(_req("c"))          # slot freed


def test_admission_interactive_before_batch():
    q = AdmissionQueue(max_depth=8)
    first = _req("a", priority=BATCH)
    second = _req("b", priority=INTERACTIVE)
    q.offer(first)
    q.offer(second)
    # plain FIFO would return `first`; the class split must not
    assert q.take() is second
    assert q.take() is first


def test_admission_tenant_round_robin_fairness():
    q = AdmissionQueue(max_depth=16)
    flood = [_req("flood") for _ in range(4)]
    polite = [_req("polite") for _ in range(2)]
    for r in flood[:2]:
        q.offer(r)
    for r in polite:
        q.offer(r)
    for r in flood[2:]:
        q.offer(r)
    order = [q.take().tenant for _ in range(6)]
    # naive FIFO: flood flood polite polite flood flood — the polite
    # tenant waits behind the whole flood prefix.  Round-robin
    # interleaves: each tenant advances one position per turn.
    assert order == ["flood", "polite", "flood", "polite",
                     "flood", "flood"]


def test_admission_lanes_are_disjoint():
    q = AdmissionQueue(max_depth=8, n_lanes=2)
    r0, r1 = _req("a", lane=0), _req("a", lane=1)
    q.offer(r0)
    q.offer(r1)
    assert q.take(1, timeout=0) is r1
    assert q.take(1, timeout=0) is None
    assert q.take(0, timeout=0) is r0


def test_admission_close_drains_then_returns_none():
    q = AdmissionQueue(max_depth=8)
    a, b = _req("a"), _req("b")
    q.offer(a)
    q.offer(b)
    q.close()
    assert not q.offer(_req("c"))      # closed: refuse new work
    assert q.take() in (a, b)
    assert q.take() in (a, b)
    assert q.take() is None            # drained + closed: immediate


def test_admission_drain_empties_lane():
    q = AdmissionQueue(max_depth=8)
    reqs = [_req("a") for _ in range(3)]
    for r in reqs:
        q.offer(r)
    assert q.drain(0) == reqs
    assert q.depth == 0


# ====================================================== ALRU tenant quotas
def _alru(capacity=1000):
    return Alru(0, BlasxHeap(capacity))


def _fill(alru, owner, matrix_id, n, nbytes=100):
    """Cache n tiles for owner and release them (zero-reader, warm)."""
    for i in range(n):
        b = alru.translate(TileKey(matrix_id, i, 0), nbytes, owner=owner)
        assert b is not None
        alru.release(b.host_addr)


def test_quota_flood_cannot_evict_other_tenants_set():
    """The serving isolation invariant at the cache level."""
    alru = _alru(1000)
    _fill(alru, "a", "WA", 5)               # tenant A's warm 500 bytes
    alru.set_quota("b", 300)
    _fill(alru, "b", "XB", 10)              # B floods 1000 bytes of tiles
    # every one of A's tiles survived; B stayed under its cap by
    # recycling its own blocks
    assert all(TileKey("WA", i, 0) in alru for i in range(5))
    assert alru.owner_bytes("a") == 500
    assert alru.owner_bytes("b") <= 300
    assert alru.quota_evictions >= 7
    assert alru.quota_evictions_by_owner["b"] == alru.quota_evictions
    alru.check_invariants()


def test_without_quotas_flood_evicts_the_other_tenant():
    """Fails-without-feature counterpart: legacy (no quota) behaviour
    lets a flood take the whole cache."""
    alru = _alru(1000)
    _fill(alru, "a", "WA", 5)
    _fill(alru, "b", "XB", 10)              # no quota: capacity eviction
    assert any(TileKey("WA", i, 0) not in alru for i in range(5))
    assert alru.quota_evictions == 0        # plain evictions, not quota
    alru.check_invariants()


def test_quota_self_eviction_keeps_owner_under_cap():
    alru = _alru(1000)
    alru.set_quota("b", 250)
    _fill(alru, "b", "XB", 4)
    assert alru.owner_bytes("b") == 200     # 2 evicted to fit 3rd/4th
    assert TileKey("XB", 3, 0) in alru      # newest survive
    assert TileKey("XB", 0, 0) not in alru  # LRU victims were its own
    alru.check_invariants()


def test_quota_oversized_request_degrades_without_eviction():
    alru = _alru(1000)
    alru.set_quota("b", 50)
    _fill(alru, "a", "WA", 3)
    before = alru.keys()
    assert alru.translate(TileKey("XB", 0, 0), 100, owner="b") is None
    assert alru.keys() == before            # nothing was touched
    alru.check_invariants()


def test_quota_all_own_blocks_pinned_degrades():
    alru = _alru(1000)
    alru.set_quota("b", 200)
    # two pinned blocks (readers never released) fill the cap
    assert alru.translate(TileKey("XB", 0, 0), 100, owner="b") is not None
    assert alru.translate(TileKey("XB", 1, 0), 100, owner="b") is not None
    assert alru.translate(TileKey("XB", 2, 0), 100, owner="b") is None
    alru.check_invariants()


def test_quota_lowering_cap_trims_immediately():
    alru = _alru(1000)
    alru.set_quota("b", 500)
    _fill(alru, "b", "XB", 5)
    alru.set_quota("b", 150)
    assert alru.owner_bytes("b") <= 150
    assert alru.quota_evictions >= 4
    alru.check_invariants()


def test_quota_untagged_blocks_stay_evictable():
    """Legacy (owner-less) blocks are fair game even in quota mode —
    only *tenant* working sets are protected."""
    alru = _alru(500)
    _fill(alru, None, "U", 5)               # untagged fills the heap
    alru.set_quota("b", 300)
    b = alru.translate(TileKey("XB", 0, 0), 100, owner="b")
    assert b is not None                    # evicted an untagged block
    assert len([k for k in alru.keys() if k.matrix_id == "U"]) == 4
    alru.check_invariants()


def test_quota_removed_restores_legacy_eviction():
    alru = _alru(1000)
    _fill(alru, "a", "WA", 5)
    alru.set_quota("b", 300)
    alru.set_quota("b", None)               # cap removed -> legacy mode
    _fill(alru, "b", "XB", 10)
    assert any(TileKey("WA", i, 0) not in alru for i in range(5))
    alru.check_invariants()


def test_quota_invariant_checker_catches_ledger_desync():
    alru = _alru(1000)
    _fill(alru, "a", "WA", 2)
    alru._owner_bytes["a"] = 9999           # corrupt the ledger
    with pytest.raises(RuntimeError, match="owner byte ledger"):
        alru.check_invariants()


# ======================================== runtime tenant/priority threading
def _gemm_problem(n=96, tile=32):
    A = TiledMatrix("A", RNG.standard_normal((n, n)), tile)
    B = TiledMatrix("B", RNG.standard_normal((n, n)), tile)
    C = TiledMatrix("C", np.zeros((n, n)), tile)
    tasks = taskize_gemm(A.grid, B.grid, C.grid, "N", "N", 1.0, 0.0)
    return tasks, {"A": A, "B": B, "C": C}


def test_run_tags_cached_blocks_with_tenant():
    rt = BlasxRuntime(RuntimeConfig(n_devices=1, mode="sim",
                                    cache_bytes=8 << 20))
    tasks, mats = _gemm_problem()
    rt.run(tasks, mats, "C", tenant="t1")
    owners = {b.owner for d in rt.devices
              for b in [d.alru.peek(k) for k in d.alru.keys()]}
    assert owners == {"t1"}
    np.testing.assert_allclose(mats["C"].data,
                               mats["A"].data @ mats["B"].data,
                               rtol=1e-10, atol=1e-10)


def test_priority_boost_is_additive_on_eq3():
    rt = BlasxRuntime(RuntimeConfig(n_devices=1, mode="sim",
                                    policy="blasx", cache_bytes=8 << 20))
    tasks, mats = _gemm_problem()
    rt.run(tasks, mats, "C", priority_boost=0.0)
    d, t = rt.devices[0], tasks[0]
    base = rt._priority(d, t)
    rt._boost = 2.5
    assert rt._priority(d, t) == pytest.approx(base + 2.5)


def test_run_sets_boost_for_the_duration():
    rt = BlasxRuntime(RuntimeConfig(n_devices=1, mode="sim",
                                    cache_bytes=8 << 20))
    tasks, mats = _gemm_problem(n=64)
    rt.run(tasks, mats, "C", priority_boost=3.0)
    assert rt._boost == 3.0
    rt.run(tasks, mats, "C")                # default run clears it
    assert rt._boost == 0.0


def test_set_tenant_quota_applies_everywhere_and_survives_reset():
    rt = BlasxRuntime(RuntimeConfig(n_devices=2, mode="sim",
                                    cache_bytes=8 << 20))
    rt.set_tenant_quota("t", 1 << 20)
    assert all(d.alru.quota_of("t") == 1 << 20 for d in rt.devices)
    rt.reset()                              # rebuilds the devices
    assert all(d.alru.quota_of("t") == 1 << 20 for d in rt.devices)
    rt.set_tenant_quota("t", None)
    assert all(d.alru.quota_of("t") is None for d in rt.devices)
    assert "quota_evictions" in rt.stats()["device0"]


# ======================================================== directory audit
def test_directory_audit_passes_after_runs():
    rt = BlasxRuntime(RuntimeConfig(n_devices=2, mode="sim",
                                    cache_bytes=8 << 20))
    tasks, mats = _gemm_problem()
    rt.run(tasks, mats, "C", tenant="t1")
    rt.directory.audit([d.alru for d in rt.devices])


def test_directory_audit_detects_desync_both_ways():
    directory = MesixDirectory(1, [[0]])
    alru = Alru(0, BlasxHeap(1000))
    key = TileKey("A", 0, 0)
    directory.on_fill(key, 0)               # directory-only: no block
    with pytest.raises(RuntimeError, match="ALRU has no such block"):
        directory.audit([alru])
    directory.on_evict(key, 0)
    b = alru.translate(key, 100)            # cache-only: no holder entry
    alru.release(b.host_addr)
    with pytest.raises(RuntimeError, match="does not list it"):
        directory.audit([alru])


# ============================================================ BlasxServer
def _server(pool_size=2, **kw):
    cfg = kw.pop("cfg", RuntimeConfig(n_devices=1, mode="sim",
                                      cache_bytes=8 << 20))
    kw.setdefault("tile", 32)
    return BlasxServer(cfg, pool_size=pool_size, **kw)


def test_server_serves_correct_results_to_two_tenants():
    with _server() as srv:
        a1, b1 = (RNG.standard_normal((64, 48)),
                  RNG.standard_normal((48, 80)))
        a2, b2 = (RNG.standard_normal((96, 64)),
                  RNG.standard_normal((64, 32)))
        f1 = srv.submit("t1", "gemm", a1, b1, priority=INTERACTIVE)
        f2 = srv.submit("t2", "gemm", a2, b2)
        np.testing.assert_allclose(f1.result(timeout=30).array(),
                                   a1 @ b1, rtol=1e-10, atol=1e-10)
        np.testing.assert_allclose(f2.result(timeout=30).array(),
                                   a2 @ b2, rtol=1e-10, atol=1e-10)
        st = srv.stats()
        assert st["tenants"]["t1"]["completed"] == 1
        assert st["tenants"]["t2"]["completed"] == 1


def test_server_affinity_keeps_tenant_on_one_context():
    with _server() as srv:
        seen = set()
        for _ in range(4):
            f = srv.submit("sticky", lambda ctx: id(ctx))
            seen.add(f.result(timeout=30))
        assert len(seen) == 1
        lane = srv.context_of("sticky")
        assert id(srv._contexts[lane]) in seen


def test_server_handles_pin_requests_to_their_context():
    with _server() as srv:
        w = srv.tile("t1", RNG.standard_normal((64, 64)))
        home = srv.context_of("t1")
        x = RNG.standard_normal((48, 64))
        got = srv.submit("t1", "gemm", x, w).result(timeout=30)
        np.testing.assert_allclose(got.array(), x @ w.array(),
                                   rtol=1e-10, atol=1e-10)
        assert srv.context_of("t1") == home
        with pytest.raises(ValueError, match="outside this server"):
            with BlasxContext(RuntimeConfig(n_devices=1, mode="sim")) as o:
                srv.submit("t1", "gemm", x, o.tile(np.eye(64)))


def test_server_overflow_routes_to_least_loaded_context():
    with _server(overflow_depth=0) as srv:
        gate = threading.Event()
        stalled = srv.submit("t", lambda ctx: gate.wait(30))
        try:
            home = srv.context_of("t")
            # home lane is 1 deep, other lane idle -> overflow
            f = srv.submit("t", lambda ctx: id(ctx))
            other = 1 - home
            assert f.result(timeout=30) == id(srv._contexts[other])
            assert srv.context_of("t") == home   # affinity did not move
        finally:
            gate.set()
        stalled.result(timeout=30)


def test_server_without_overflow_queues_behind_home_lane():
    """Fails-without-feature counterpart for overflow routing: a deep
    overflow threshold keeps the tenant glued to its (busy) home."""
    with _server(overflow_depth=100) as srv:
        gate = threading.Event()
        stalled = srv.submit("t", lambda ctx: gate.wait(30))
        home = srv.context_of("t")
        f = srv.submit("t", lambda ctx: id(ctx))
        assert not f.done()                  # stuck behind the stall
        gate.set()
        assert f.result(timeout=30) == id(srv._contexts[home])
        stalled.result(timeout=30)


def test_server_sheds_load_with_backpressure_error():
    with _server(pool_size=1, max_depth=2) as srv:
        gate = threading.Event()
        running = threading.Event()
        stalled = srv.submit(
            "a", lambda ctx: (running.set(), gate.wait(30)) and None)
        assert running.wait(30)              # worker busy; queue empty
        q1 = srv.submit("a", lambda ctx: 1)
        q2 = srv.submit("b", lambda ctx: 2)
        with pytest.raises(BackpressureError):
            srv.submit("c", lambda ctx: 3)
        gate.set()
        assert (q1.result(timeout=30), q2.result(timeout=30)) == (1, 2)
        stalled.result(timeout=30)
        st = srv.stats()["tenants"]
        assert st["c"]["rejected"] == 1
        assert st["c"]["completed"] == 0


def test_server_cancels_queued_requests():
    with _server(pool_size=1) as srv:
        gate = threading.Event()
        running = threading.Event()
        stalled = srv.submit(
            "a", lambda ctx: (running.set(), gate.wait(30)) and None)
        assert running.wait(30)
        doomed = srv.submit("a", lambda ctx: 1)
        assert doomed.cancel()
        assert doomed.cancelled()
        with pytest.raises(concurrent.futures.CancelledError):
            doomed.result(timeout=1)
        gate.set()
        stalled.result(timeout=30)
        deadline = time.monotonic() + 30
        while time.monotonic() < deadline:
            if srv.stats()["tenants"].get("a", {}).get("cancelled"):
                break
            time.sleep(0.01)
        assert srv.stats()["tenants"]["a"]["cancelled"] == 1


def test_server_quota_isolation_end_to_end():
    """Acceptance invariant: tenant A's warm set survives tenant B's
    flood when B is quota'd; the directory stays in sync throughout."""
    cfg = RuntimeConfig(n_devices=1, mode="sim", cache_bytes=1 << 20)
    with _server(pool_size=1, cfg=cfg, quotas={"b": 256 << 10}) as srv:
        x = srv.tile("a", RNG.standard_normal((128, 128)))
        w = srv.tile("a", RNG.standard_normal((128, 128)))
        srv.submit("a", "gemm", x, w).result(timeout=30)
        ctx = srv._contexts[0]
        resident = {k for d in ctx.runtime.devices
                    for k in d.alru.keys()
                    if k.matrix_id in (x.matrix_id, w.matrix_id)}
        assert resident                      # A's working set is warm
        big = RNG.standard_normal((256, 256))
        for _ in range(3):                   # ephemeral flood traffic
            srv.submit("b", "gemm", big, big).result(timeout=30)
        for d in ctx.runtime.devices:
            still = {k for k in d.alru.keys()}
            d.alru.check_invariants()
        survivors = {k for d in ctx.runtime.devices
                     for k in d.alru.keys()
                     if k.matrix_id in (x.matrix_id, w.matrix_id)}
        assert survivors == resident         # nothing of A's was evicted
        ctx.runtime.directory.audit(
            [d.alru for d in ctx.runtime.devices])
        assert srv.quota_evictions().get("b", 0) > 0
        assert srv.stats()["tenants"]["b"]["quota_evictions"] > 0


def test_server_flood_evicts_warm_set_without_quota():
    """Fails-without-feature counterpart: the identical flood with no
    quota configured does evict tenant A's warm tiles."""
    cfg = RuntimeConfig(n_devices=1, mode="sim", cache_bytes=1 << 20)
    with _server(pool_size=1, cfg=cfg) as srv:
        x = srv.tile("a", RNG.standard_normal((128, 128)))
        w = srv.tile("a", RNG.standard_normal((128, 128)))
        srv.submit("a", "gemm", x, w).result(timeout=30)
        ctx = srv._contexts[0]
        resident = {k for d in ctx.runtime.devices
                    for k in d.alru.keys()
                    if k.matrix_id in (x.matrix_id, w.matrix_id)}
        big = RNG.standard_normal((256, 256))
        for _ in range(3):
            srv.submit("b", "gemm", big, big).result(timeout=30)
        survivors = {k for d in ctx.runtime.devices
                     for k in d.alru.keys()
                     if k.matrix_id in (x.matrix_id, w.matrix_id)}
        assert survivors < resident          # flood ate into A's set


def test_server_stats_shape_and_percentiles():
    with _server() as srv:
        for _ in range(3):
            srv.submit("t", lambda ctx: None).result(timeout=30)
        row = srv.stats()["tenants"]["t"]
        for field in ("completed", "failed", "rejected", "cancelled",
                      "latency_p50_ms", "latency_p99_ms",
                      "queue_wait_p50_ms", "queue_wait_p99_ms",
                      "quota_evictions"):
            assert field in row
        assert row["completed"] == 3
        assert row["latency_p99_ms"] >= row["latency_p50_ms"] >= 0.0


def test_server_routine_errors_surface_and_count_as_failed():
    with _server() as srv:
        def boom(ctx):
            raise ValueError("kaput")
        f = srv.submit("t", boom)
        with pytest.raises(ValueError, match="kaput"):
            f.result(timeout=30)
        assert srv.stats()["tenants"]["t"]["failed"] == 1


def test_server_close_waits_then_rejects():
    srv = _server()
    f = srv.submit("t", lambda ctx: 7)
    srv.close()
    assert f.result(timeout=1) == 7          # queued work drained
    with pytest.raises(RuntimeError, match="closed"):
        srv.submit("t", lambda ctx: 8)
    srv.close()                              # idempotent


def test_server_adopted_contexts_survive_close():
    with BlasxContext(RuntimeConfig(n_devices=1, mode="sim")) as ctx:
        srv = BlasxServer(contexts=[ctx])
        srv.submit("t", lambda c: None).result(timeout=30)
        srv.close()
        assert not ctx.closed                # owner keeps the context
        ctx.gemm(np.eye(8), np.eye(8))       # still serviceable


def test_percentile_nearest_rank():
    assert percentile([], 99.0) == 0.0
    assert percentile([5.0], 50.0) == 5.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 50.0) == 2.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 99.0) == 4.0


def test_server_stats_ledger_direct():
    st = ServerStats(window=4)
    st.record("t", wait_s=0.001, latency_s=0.002, ok=True)
    st.record("t", wait_s=0.002, latency_s=0.004, ok=False)
    st.record_rejection("t")
    st.record_cancelled("t")
    snap = st.snapshot({"t": 3, "ghost": 1})
    assert snap["t"]["completed"] == 1
    assert snap["t"]["failed"] == 1
    assert snap["t"]["rejected"] == 1
    assert snap["t"]["cancelled"] == 1
    assert snap["t"]["quota_evictions"] == 3
    assert snap["ghost"]["quota_evictions"] == 1
    assert snap["t"]["latency_p50_ms"] == pytest.approx(2.0)
