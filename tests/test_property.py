"""Hypothesis property tests on the system's invariants: heap arena
integrity, ALRU pinning discipline, MESI-X single-writer consistency,
taskization flop accounting, tiled-GEMM correctness over random shapes."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -e .[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import gemm  # noqa: E402
from repro.core.alru import Alru  # noqa: E402
from repro.core.coherence import MesixDirectory  # noqa: E402
from repro.core.heap import BlasxHeap  # noqa: E402
from repro.core.runtime import RuntimeConfig  # noqa: E402
from repro.core.task import taskize_gemm, total_flops  # noqa: E402
from repro.core.tiling import TileGrid, TileKey  # noqa: E402


# ------------------------------------------------------------------- heap
@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.integers(1, 400)),
                min_size=1, max_size=120))
def test_heap_invariants_under_random_traces(ops):
    """After any alloc/free trace: segments exactly tile the arena, free
    neighbors are coalesced, accounting is consistent."""
    h = BlasxHeap(4096)
    live = []
    for is_alloc, size in ops:
        if is_alloc or not live:
            off = h.malloc(size)
            if off is not None:
                live.append(off)
        else:
            h.free(live.pop(len(live) % max(1, len(live)) - 1))
        h.check_invariants()
    for off in live:
        h.free(off)
    h.check_invariants()
    assert h.free_bytes == 4096


# ------------------------------------------------------------------- ALRU
@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 15), min_size=1, max_size=80),
       st.integers(2, 6))
def test_alru_never_evicts_pinned_blocks(accesses, cap_tiles):
    """Property (the A in ALRU): a block with readers > 0 survives any
    sequence of other translations."""
    heap = BlasxHeap(cap_tiles * 100)
    a = Alru(0, heap)
    a.on_evict = lambda dev, key: None
    pinned = TileKey("P", 0, 0)
    blk = a.translate(pinned, 100)   # reader = 1, never released
    assert blk is not None
    for t in accesses:
        key = TileKey("A", 0, t)
        b = a.translate(key, 100)
        if b is not None and key != pinned:
            a.release(key)
        a.check_invariants()
        assert pinned in a           # the pinned block must survive


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 11),          # tile identity
                          st.sampled_from([60, 100, 140, 220]),  # nbytes
                          st.booleans()),               # release after?
                min_size=1, max_size=100))
def test_alru_fragmented_heap_translate_invariants(ops):
    """Drive a fragmented heap (mixed tile sizes, some tiles left
    pinned) through Alru.translate.  Invariants at every step:

    * no over-eviction — a translate that fails (None) evicted nothing;
    * directory/heap agreement — the eviction-callback mirror matches
      the ALRU's resident set, and heap.used equals the sum of
      resident block sizes (on_evict fires only after heap.free);
    * list/map/heap structural invariants hold.
    """
    heap = BlasxHeap(500)
    a = Alru(0, heap)
    mirror = {}           # key -> nbytes, maintained via on_evict

    def on_evict(dev, key):
        blk = a.peek(key)
        assert blk is None                 # already unlinked
        nb = mirror.pop(key)
        # the victim's bytes are free by the time observers hear of it
        assert heap.used + nb <= heap.capacity
        assert heap.used == sum(mirror.values())

    a.on_evict = on_evict
    pinned = set()
    for ident, nbytes, release in ops:
        key = TileKey("T", 0, ident)
        before = dict(mirror)
        if key in a:                       # hit path: sizes stay stable
            nbytes = a.peek(key).nbytes
        blk = a.translate(key, nbytes)
        if blk is None:
            assert mirror == before        # failed translate evicts nothing
            assert heap.largest_attainable_run(
                {b.gpu_addr for b in (a.peek(k) for k in a.keys())
                 if b.reader == 0}) < nbytes
        else:
            mirror[key] = blk.nbytes
            if release:
                a.release(key)
                pinned.discard(key)
            else:
                pinned.add(key)
        assert set(a.keys()) == set(mirror)
        assert heap.used == sum(mirror.values())
        assert pinned <= set(a.keys())     # pinned blocks never evicted
        a.check_invariants()
        heap.check_invariants()


# ----------------------------------------------------------------- MESI-X
@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2),        # device
                          st.sampled_from(["fill", "evict", "write"])),
                min_size=1, max_size=60))
def test_mesix_states_always_consistent(events):
    d = MesixDirectory(3, [[0, 1, 2]])
    key = TileKey("C", 1, 1)
    holders = set()
    for dev, ev in events:
        if ev == "fill":
            d.on_fill(key, dev)
            holders.add(dev)
        elif ev == "evict":
            d.on_evict(key, dev)
            holders.discard(dev)
        else:
            d.on_write(key, dev)
            holders.clear()          # ephemeral M -> I invalidates all
        d.check_invariants()
        want = "I" if not holders else ("E" if len(holders) == 1 else "S")
        assert d.state(key) == want


# ---------------------------------------------------------------- tiling
@settings(max_examples=40, deadline=None)
@given(st.integers(1, 300), st.integers(1, 300), st.integers(1, 64))
def test_tile_grid_partitions_exactly(rows, cols, tile):
    g = TileGrid("A", rows, cols, tile)
    area = sum(g.tile_shape(i, j)[0] * g.tile_shape(i, j)[1]
               for i in range(g.n_tile_rows) for j in range(g.n_tile_cols))
    assert area == rows * cols


@settings(max_examples=20, deadline=None)
@given(st.integers(32, 200), st.integers(32, 200), st.integers(32, 200),
       st.integers(16, 96))
def test_gemm_taskization_flops_exact(m, k, n, tile):
    ga = TileGrid("A", m, k, tile)
    gb = TileGrid("B", k, n, tile)
    gc = TileGrid("C", m, n, tile)
    tasks = taskize_gemm(ga, gb, gc, "N", "N", 1.0, 0.0)
    assert total_flops(tasks) == 2 * m * k * n
    # every output tile owned by exactly one task
    outs = [t.out for t in tasks]
    assert len(outs) == len(set(outs)) == gc.n_tiles


# ------------------------------------------------------ end-to-end gemm
@settings(max_examples=10, deadline=None)
@given(st.integers(17, 120), st.integers(17, 120), st.integers(17, 120),
       st.integers(16, 64), st.integers(1, 3))
def test_gemm_random_shapes_match_oracle(m, k, n, tile, n_devices):
    rng = np.random.default_rng(m * 7 + k * 3 + n)
    A = rng.standard_normal((m, k))
    B = rng.standard_normal((k, n))
    out = gemm(A, B, tile=tile,
               config=RuntimeConfig(n_devices=n_devices, mode="sim",
                                    cache_bytes=8 << 20))
    np.testing.assert_allclose(out, A @ B, rtol=1e-10, atol=1e-10)
