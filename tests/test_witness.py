"""Runtime lock-witness (repro.analysis.witness): a synthetic two-lock
inversion across two threads is reported with both acquisition stacks;
a clean threads-mode DGEMM shows real edges and zero inversions; the
audit snapshot fix is pinned by a probe that fails if the directory
lock is ever held while querying an ALRU.
"""
import subprocess
import sys
import threading
from pathlib import Path

import numpy as np
import pytest

from repro.analysis.witness import LockWitness

REPO_ROOT = Path(__file__).resolve().parent.parent
SRC = REPO_ROOT / "src"


def test_synthetic_inversion_reported_with_both_stacks():
    w = LockWitness()
    with w.activate():
        lock_a = threading.Lock()
        lock_b = threading.Lock()

        def ab():
            with lock_a:
                with lock_b:
                    pass

        def ba():
            with lock_b:
                with lock_a:
                    pass

        # two threads, opposite order, serialized by join so the run
        # itself cannot deadlock — the witness records order, not luck
        t1 = threading.Thread(target=ab)
        t1.start()
        t1.join()
        t2 = threading.Thread(target=ba)
        t2.start()
        t2.join()

    inversions = w.inversions()
    assert len(inversions) == 1
    report = w.report()
    assert "INVERSION" in report
    # both acquisition stacks point back into this test
    assert report.count("test_witness.py") >= 4
    assert "ab" in report and "ba" in report
    with pytest.raises(AssertionError, match="inversion"):
        w.assert_clean()


def test_nested_same_order_is_not_an_inversion():
    w = LockWitness()
    with w.activate():
        lock_a = threading.Lock()
        lock_b = threading.Lock()
        for _ in range(3):
            with lock_a:
                with lock_b:
                    pass
    assert w.inversions() == []
    assert w.edge_names() != []
    w.assert_clean()


def test_rlock_reentrancy_records_no_self_edge():
    w = LockWitness()
    with w.activate():
        lock = threading.RLock()
        with lock:
            with lock:
                pass
    assert w.edge_names() == []
    assert w.inversions() == []


def test_condition_wait_releases_witnessed_lock():
    """Condition(wrapped_lock) must go through the wrapper's
    _release_save/_acquire_restore: while the waiter is parked, the
    lock reads as free to the witness and to other threads."""
    w = LockWitness()
    with w.activate():
        lock = threading.Lock()
        cv = threading.Condition(lock)
        state = {"entered": False, "done": False}

        def waiter():
            with cv:
                state["entered"] = True
                cv.notify_all()
                while not state["done"]:
                    cv.wait(timeout=1.0)

        t = threading.Thread(target=waiter)
        t.start()
        with cv:
            while not state["entered"]:
                cv.wait(timeout=1.0)
            state["done"] = True
            cv.notify_all()
        t.join(timeout=5.0)
        assert not t.is_alive()
    # a single shared lock: no ordering edges, certainly no inversions
    assert w.inversions() == []


def test_witness_names_repro_locks():
    w = LockWitness()
    with w.activate():
        from repro.core.heap import BlasxHeap
        from repro.core.alru import Alru
        alru = Alru(0, BlasxHeap(1 << 20))
        len(alru)  # first acquire happens inside a method -> named
    assert any(lk.name == "Alru._lock" for lk in w._locks.values())


def test_clean_threads_mode_dgemm_zero_inversions():
    """Acceptance: a real threads-mode multi-device DGEMM under the
    witness completes with real ordering edges and zero inversions."""
    w = LockWitness()
    with w.activate():
        from repro.api.context import BlasxContext
        from repro.core.runtime import RuntimeConfig

        rng = np.random.default_rng(7)
        a = rng.standard_normal((160, 160))
        b = rng.standard_normal((160, 160))
        with BlasxContext(RuntimeConfig(n_devices=2, mode="threads"),
                          tile=64) as ctx:
            out = ctx.gemm(a, b).array()
    np.testing.assert_allclose(out, a @ b, rtol=1e-10, atol=1e-10)
    assert w.acquisitions > 0
    assert w.edge_names() != []      # the runtime really interleaves
    assert w.inversions() == []
    w.assert_clean()


def test_pytest_plugin_fails_inverting_test_and_passes_clean(tmp_path):
    """The CI stress smoke's plugin: a test that interleaves two locks
    in opposite orders errors with the inversion report; a clean file
    passes with the witness summary printed."""
    bad = tmp_path / "test_inv.py"
    bad.write_text(
        "import threading\n\n\n"
        "def test_inverts():\n"
        "    a, b = threading.Lock(), threading.Lock()\n"
        "    with a:\n"
        "        with b:\n"
        "            pass\n"
        "    with b:\n"
        "        with a:\n"
        "            pass\n",
        encoding="utf-8")
    good = tmp_path / "test_ok.py"
    good.write_text(
        "import threading\n\n\n"
        "def test_ordered():\n"
        "    a, b = threading.Lock(), threading.Lock()\n"
        "    with a:\n"
        "        with b:\n"
        "            pass\n",
        encoding="utf-8")

    def run(target):
        return subprocess.run(
            [sys.executable, "-m", "pytest", "-q", "-p",
             "repro.analysis.pytest_witness", str(target)],
            capture_output=True, text=True, cwd=str(tmp_path),
            env={"PYTHONPATH": str(SRC), "PATH": "/usr/bin:/bin"})

    proc = run(bad)
    assert proc.returncode != 0
    assert "lock-order inversion" in proc.stdout
    assert "test_inv.py" in proc.stdout   # both stacks shown

    proc = run(good)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "lock-witness:" in proc.stdout


# ---------------------------------------------------------------------------
# the real finding the pass surfaced: MesixDirectory.audit used to
# query ALRUs while holding the directory lock — the reverse of the
# eviction callback's order.  The probe fails on the pre-fix shape.
# ---------------------------------------------------------------------------

class _ProbeAlru:
    """Quacks like an Alru for audit(); every query asserts the
    directory lock is NOT held by the querying thread."""

    def __init__(self, directory, keys):
        self._dir = directory
        self._keys = set(keys)

    def _assert_unlocked(self):
        assert not self._dir._lock._is_owned(), (
            "audit holds the directory lock while querying an ALRU — "
            "the Alru<->MesixDirectory lock-order inversion")

    def __contains__(self, key):
        self._assert_unlocked()
        return key in self._keys

    def keys(self):
        self._assert_unlocked()
        return list(self._keys)


def test_audit_queries_alrus_outside_directory_lock():
    from repro.core.coherence import MesixDirectory
    from repro.core.tiling import TileKey

    d = MesixDirectory(2, [[0, 1]])
    k1 = TileKey("A", 0, 0)
    k2 = TileKey("A", 0, 1)
    d.on_fill(k1, 0)
    d.on_fill(k2, 1)
    alrus = [_ProbeAlru(d, [k1]), _ProbeAlru(d, [k2])]
    d.audit(alrus)  # pre-fix: _ProbeAlru's assert trips

    # the cross-check itself still bites in both directions
    with pytest.raises(RuntimeError, match="no such block"):
        d.audit([_ProbeAlru(d, []), _ProbeAlru(d, [k2])])
    with pytest.raises(RuntimeError, match="not list"):
        d.audit([_ProbeAlru(d, [k1, k2]), _ProbeAlru(d, [k2])])
