"""Discrete-event engine acceptance: bitwise parity with the lump-sum
model, overlap invariants, idle accounting, and the Chrome-trace
schema round trip."""
import json

import numpy as np
import pytest

from repro.core import blas3
from repro.core.events import (EventEngine, LinkTimeline, TimedTask,
                               TimedXfer, max_concurrent, trace_spans,
                               validate_trace)
from repro.core.runtime import BlasxRuntime, RuntimeConfig

RNG = np.random.default_rng(11)


def _cfg(time_model, **kw):
    kw.setdefault("n_devices", 3)
    kw.setdefault("mode", "sim")
    kw.setdefault("cache_bytes", 32 << 20)
    return RuntimeConfig(time_model=time_model, **kw)


def _run_routine(routine, dtype, time_model):
    n, tile = 320, 128   # ragged edge tiles included
    rng = np.random.default_rng(42)  # identical operands per engine
    A = rng.standard_normal((n, n))
    B = rng.standard_normal((n, n))
    C = rng.standard_normal((n, n))
    cfg = _cfg(time_model)
    if routine == "gemm":
        return blas3.gemm(A, B, C, beta=0.5, tile=tile, config=cfg,
                          dtype=dtype)
    if routine == "symm":
        return blas3.symm(A, B, tile=tile, config=cfg, dtype=dtype)
    if routine == "syrk":
        return blas3.syrk(A, C, beta=0.5, uplo="L", tile=tile, config=cfg,
                          dtype=dtype)
    if routine == "syr2k":
        return blas3.syr2k(A, B, tile=tile, config=cfg, dtype=dtype)
    if routine == "trmm":
        return blas3.trmm(A, B, uplo="L", tile=tile, config=cfg,
                          dtype=dtype)
    if routine == "trsm":
        return blas3.trsm(A + n * np.eye(n), B, tile=tile, config=cfg,
                          dtype=dtype)
    raise AssertionError(routine)


# ------------------------------------------------------------- parity
@pytest.mark.parametrize("dtype", [np.float64, np.float32],
                         ids=["f64", "f32"])
@pytest.mark.parametrize(
    "routine", ["gemm", "symm", "syrk", "syr2k", "trmm", "trsm"])
def test_event_engine_bitwise_parity(routine, dtype):
    """The event engine only reassigns clocks: outputs must be
    *bitwise* identical to the lump-sum model on every routine and
    precision (numerics never consult the time model)."""
    out_events = _run_routine(routine, dtype, "events")
    out_lump = _run_routine(routine, dtype, "lump")
    assert out_events.dtype == out_lump.dtype
    assert np.array_equal(out_events, out_lump)


# --------------------------------------------------- overlap invariant
@pytest.mark.parametrize(
    "policy", ["blasx", "parsec", "cublasxt", "static", "supermatrix"])
def test_overlap_on_never_slower_than_off(policy):
    """Letting communication hide behind compute can only shorten the
    modeled makespan — on every policy."""
    def makespan(overlap):
        rt = BlasxRuntime(RuntimeConfig(
            n_devices=2, mode="sim", policy=policy, execute=False,
            cache_bytes=1 << 30, overlap_comm=overlap,
            record_trace=False))
        blas3.shadow_run("gemm", 4096, tile=512, runtime=rt)
        return rt.makespan()

    assert makespan(True) <= makespan(False) * (1 + 1e-9)


# -------------------------------------------------- idle-time accounting
@pytest.mark.parametrize("time_model", ["events", "lump"])
def test_trsm_chain_stall_is_accounted_idle(time_model):
    """A single-tile-column TRSM chain forces the second device to
    stall-nudge while the chain serializes on its peer; the nudged
    time must be ledger-charged so busy + idle sums to the clock
    (regression: nudges used to inflate makespan with no trace)."""
    n, tile = 512, 128
    A = RNG.standard_normal((n, n)) + n * np.eye(n)
    B = RNG.standard_normal((n, tile))   # one tile column -> pure chain
    rt = BlasxRuntime(_cfg(time_model, n_devices=2))
    out = blas3.trsm(A, B, tile=tile, runtime=rt)
    np.testing.assert_allclose(np.triu(A) @ out, B, rtol=1e-8, atol=1e-8)
    assert sum(d.ledger.idle_time for d in rt.devices) > 0
    for d in rt.devices:
        assert d.ledger.busy_time + d.ledger.idle_time == \
            pytest.approx(d.clock, rel=1e-9, abs=1e-12)


def test_dependency_wait_is_idle_not_busy():
    """Static round-robin TRSM: the device whose batch waits on a
    producer running elsewhere records the wait as idle time."""
    n, tile = 512, 128
    A = RNG.standard_normal((n, n)) + n * np.eye(n)
    B = RNG.standard_normal((n, n))
    rt = BlasxRuntime(_cfg("events", n_devices=2, policy="cublasxt"))
    blas3.trsm(A, B, tile=tile, runtime=rt)
    for d in rt.devices:
        assert d.ledger.busy_time + d.ledger.idle_time == \
            pytest.approx(d.clock, rel=1e-9, abs=1e-12)
    assert sum(d.ledger.idle_time for d in rt.devices) > 0


# ----------------------------------------------------- ledger additions
def test_event_ledger_link_busy_and_overlap_efficiency():
    rt = BlasxRuntime(_cfg("events", n_devices=2))
    A = RNG.standard_normal((512, 512))
    blas3.gemm(A, A, tile=128, runtime=rt)
    led0 = rt.devices[0].ledger
    assert led0.h2d_busy_s > 0 and led0.d2h_busy_s > 0
    for d in rt.devices:
        led = d.ledger
        # link busy seconds decompose the comm ledger exactly
        assert led.h2d_busy_s + led.d2d_busy_s + led.d2h_busy_s == \
            pytest.approx(led.comm_time, rel=1e-9)
        assert 0.0 <= led.overlap_efficiency <= 1.0
        assert led.unoverlapped_comm <= led.comm_time * (1 + 1e-9)
    stats = rt.stats()["device0"]
    assert "overlap_efficiency" in stats and "idle_time" in stats
    assert "h2d_busy_s" in stats


# ------------------------------------------------------------- tracing
def _traced_gemm_ctx(n_devices=2, policy="blasx", passes=2):
    from repro.api import BlasxContext

    A = RNG.standard_normal((1024, 1024))
    B = RNG.standard_normal((1024, 1024))
    ctx = BlasxContext(RuntimeConfig(n_devices=n_devices, mode="sim",
                                     policy=policy), tile=128)
    Ah, Bh = ctx.tile(A), ctx.tile(B)
    for _ in range(passes):
        ctx.gemm(Ah, Bh)
    return ctx


def test_trace_roundtrip_and_stream_concurrency(tmp_path):
    """Acceptance: a 2-device DGEMM trace round-trips through the
    schema validator with >= n_streams concurrent compute spans
    observable on at least one device (the warm pass overlaps all
    streams)."""
    ctx = _traced_gemm_ctx()
    try:
        path = tmp_path / "trace.json"
        tr = ctx.trace(str(path))
        summary = validate_trace(tr)
        assert summary["spans"] > 0
        reloaded = json.loads(path.read_text())
        assert validate_trace(reloaded) == summary
        n_streams = ctx.cfg.n_streams
        assert max(max_concurrent(reloaded, device=d)
                   for d in range(2)) >= n_streams
        # every span category is one of the modeled lanes
        cats = {sp["cat"] for sp in trace_spans(reloaded)}
        assert cats <= {"compute", "h2d", "d2d", "d2h"}
        assert "compute" in cats and "h2d" in cats
    finally:
        ctx.close()


def test_trace_cublasxt_caps_streams_at_two():
    ctx = _traced_gemm_ctx(policy="cublasxt")
    try:
        tr = ctx.trace()
        validate_trace(tr)
        for dev in range(2):
            conc = max_concurrent(tr, device=dev)
            assert 1 <= conc <= 2
    finally:
        ctx.close()


def test_trace_empty_but_valid_outside_event_engine():
    rt = BlasxRuntime(_cfg("lump", n_devices=2))
    A = RNG.standard_normal((256, 256))
    blas3.gemm(A, A, tile=128, runtime=rt)
    tr = rt.trace()
    summary = validate_trace(tr)
    assert summary["spans"] == 0


def test_trace_resets_with_runtime():
    rt = BlasxRuntime(_cfg("events", n_devices=2))
    A = RNG.standard_normal((256, 256))
    blas3.gemm(A, A, tile=128, runtime=rt)
    assert validate_trace(rt.trace())["spans"] > 0
    rt.reset()
    assert validate_trace(rt.trace())["spans"] == 0


# ----------------------------------------------- validator adversarial
def test_validator_rejects_malformed_traces():
    good = {"traceEvents": [
        {"name": "x", "cat": "compute", "ph": "B", "ts": 0.0,
         "pid": 0, "tid": 0, "args": {}},
        {"name": "x", "cat": "compute", "ph": "E", "ts": 5.0,
         "pid": 0, "tid": 0},
    ], "otherData": {"schema": 1}}
    validate_trace(good)
    unbalanced = {"traceEvents": good["traceEvents"][:1],
                  "otherData": {"schema": 1}}
    with pytest.raises(ValueError, match="unbalanced"):
        validate_trace(unbalanced)
    orphan_e = {"traceEvents": [good["traceEvents"][1]],
                "otherData": {"schema": 1}}
    with pytest.raises(ValueError, match="E without matching B"):
        validate_trace(orphan_e)
    backwards = {"traceEvents": [
        dict(good["traceEvents"][0], ts=7.0),
        dict(good["traceEvents"][1], ts=5.0),
    ], "otherData": {"schema": 1}}
    with pytest.raises(ValueError, match="monotonic"):
        validate_trace(backwards)
    with pytest.raises(ValueError, match="schema"):
        validate_trace({"traceEvents": [], "otherData": {}})


# ----------------------------------------------------- engine unit level
def test_shared_host_link_serializes_h2d_across_devices():
    """Two devices fetching concurrently on a shared host link must
    serialize; on private links they proceed in parallel."""
    def span_of(shared):
        cfg = RuntimeConfig(n_devices=2, mode="sim",
                            shared_host_link=shared)
        eng = EventEngine(cfg)
        items = [TimedTask(task_id=0, name="t", compute_s=0.0,
                           fetches=[TimedXfer("h2d", 8, 1.0, "A")])]
        s0, _, _ = eng.schedule_batch(0, 0.0, items, 4, True)
        s1, _, _ = eng.schedule_batch(1, 0.0, items, 4, True)
        return s0, s1

    s0, s1 = span_of(shared=True)
    assert (s0, s1) == (1.0, 2.0)   # second device queues behind the first
    s0, s1 = span_of(shared=False)
    assert (s0, s1) == (1.0, 1.0)   # private lanes: no contention

def test_link_timeline_fifo():
    link = LinkTimeline()
    assert link.acquire(0.0, 2.0) == 0.0
    assert link.acquire(1.0, 1.0) == 2.0   # queued behind in-flight xfer
    assert link.acquire(5.0, 1.0) == 5.0   # idle gap: starts on request
    assert link.busy_s == pytest.approx(4.0)


def test_no_overlap_batch_serializes_on_one_lane():
    cfg = RuntimeConfig(n_devices=1, mode="sim")
    eng = EventEngine(cfg)
    items = [TimedTask(task_id=i, name=f"t{i}", compute_s=1.0,
                       fetches=[TimedXfer("h2d", 8, 1.0, "A")])
             for i in range(2)]
    span_overlap, _, _ = eng.schedule_batch(0, 0.0, items, 4, True)
    eng2 = EventEngine(cfg)
    span_serial, finishes, _ = eng2.schedule_batch(0, 0.0, items, 4, False)
    assert span_serial == pytest.approx(4.0)   # (fetch+compute) x 2, chained
    assert finishes == [pytest.approx(2.0), pytest.approx(4.0)]
    assert span_overlap < span_serial
