from .pipeline import DataConfig, DataIterator, batch_at_step

__all__ = ["DataConfig", "DataIterator", "batch_at_step"]
