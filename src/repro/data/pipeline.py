"""Deterministic synthetic token pipeline.

Restart-exact by construction: batch(step) is a pure function of
(seed, step), so resuming from a checkpoint at step k replays the exact
remaining stream — the data-side half of fault tolerance.  Shardable:
``global_batch`` is laid out along the ("pod","data") mesh axes by the
caller's in_shardings; per-host slicing uses the same pure function.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # synthetic structure: orderly n-gram-ish stream so the LM loss
    # actually decreases (pure uniform noise has no learnable signal)
    ngram: int = 3


def batch_at_step(cfg: DataConfig, step: int,
                  frontend_dim: Optional[int] = None) -> dict:
    """Pure function (seed, step) -> batch dict of numpy arrays."""
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
    B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    # structured stream: x_{t} = (a * x_{t-1} + b) mod V with per-sample
    # (a, b) — learnable first-order structure
    a = rng.integers(1, 8, (B, 1))
    b = rng.integers(0, V, (B, 1))
    x0 = rng.integers(0, V, (B, 1))
    toks = np.empty((B, S + 1), np.int32)
    toks[:, :1] = x0
    for t in range(1, S + 1):
        toks[:, t] = (a[:, 0] * toks[:, t - 1] + b[:, 0]) % V
    noise = rng.random((B, S + 1)) < 0.05
    toks = np.where(noise, rng.integers(0, V, (B, S + 1)), toks)
    out = {"tokens": toks[:, :-1].astype(np.int32),
           "labels": toks[:, 1:].astype(np.int32)}
    if frontend_dim is not None:
        out["embeds"] = rng.standard_normal(
            (B, S, frontend_dim)).astype(np.float32)
    return out


class DataIterator:
    """Stateful wrapper with explicit step save/restore (checkpointable)."""

    def __init__(self, cfg: DataConfig, start_step: int = 0,
                 frontend_dim: Optional[int] = None):
        self.cfg = cfg
        self.step = start_step
        self.frontend_dim = frontend_dim

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        b = batch_at_step(self.cfg, self.step, self.frontend_dim)
        self.step += 1
        return b

    def state(self) -> dict:
        return {"step": self.step}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])
