"""Pallas TPU tiled-matmul kernel — the tile-algorithm compute hot-spot.

This is the BLASX tile kernel adapted to the TPU memory hierarchy:
the paper's T x T tile living in GPU RAM becomes a (block_m, block_k) /
(block_k, block_n) VMEM working set streamed from HBM by ``BlockSpec``;
the paper's L1-cache reuse of the stationary C tile becomes the f32
VMEM accumulator that stays resident across the K-loop (output-
stationary blocking).  The MXU sees hardware-aligned (multiple-of-128)
matmul dims chosen by ``ops.matmul``.

An optional fused epilogue (bias + activation) implements the
transformer projections of the model zoo without a second HBM
round-trip.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import COMPILER_PARAMS as _COMPILER_PARAMS

ACTIVATIONS = {
    None: lambda x: x,
    "none": lambda x: x,
    "relu": lambda x: jnp.maximum(x, 0.0),
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "tanh": jnp.tanh,
}


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int,
                   activation: Optional[str]):
    """Grid = (m_blocks, n_blocks, k_blocks); K is the innermost
    (fastest-varying) axis so the accumulator stays VMEM-resident."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _store():
        out = ACTIVATIONS[activation](acc_ref[...])
        o_ref[...] = out.astype(o_ref.dtype)


def _matmul_bias_kernel(a_ref, b_ref, bias_ref, o_ref, acc_ref, *, n_k: int,
                        activation: Optional[str]):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _store():
        out = acc_ref[...] + bias_ref[...].astype(jnp.float32)
        out = ACTIVATIONS[activation](out)
        o_ref[...] = out.astype(o_ref.dtype)


def matmul_pallas(a: jax.Array, b: jax.Array, bias: Optional[jax.Array],
                  *, block_m: int, block_n: int, block_k: int,
                  out_dtype, activation: Optional[str],
                  interpret: bool = False) -> jax.Array:
    """Raw pallas_call.  Requires M % block_m == N % block_n ==
    K % block_k == 0 (``ops.matmul`` pads)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    n_k = k // block_k
    grid = (m // block_m, n // block_n, n_k)

    in_specs = [
        pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
    ]
    args = [a, b]
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)))
        args.append(bias.reshape(1, n))
        kernel = functools.partial(_matmul_bias_kernel, n_k=n_k,
                                   activation=activation)
    else:
        kernel = functools.partial(_matmul_kernel, n_k=n_k,
                                   activation=activation)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*args)
