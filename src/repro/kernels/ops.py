"""Jit'd public wrappers around the Pallas kernels.

``matmul`` pads operands to hardware-aligned block multiples (MXU wants
multiples of 128 in the lane dim, 8 in the sublane dim), clamps block
shapes to a VMEM budget, invokes the kernel, and slices the result.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .matmul import matmul_pallas

VMEM_BUDGET = 12 << 20  # bytes; leave headroom below the 16 MiB/core VMEM


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def default_blocks(m: int, n: int, k: int, itemsize: int):
    """Pick (block_m, block_n, block_k): MXU-aligned, VMEM-bounded."""
    bm = min(512, _round_up(m, 8))
    bn = min(512, _round_up(n, 128))
    bk = min(512, _round_up(k, 128))

    def vmem(bm, bn, bk):
        return (bm * bk + bk * bn) * itemsize + bm * bn * 4 + bm * bn * itemsize

    while vmem(bm, bn, bk) > VMEM_BUDGET:
        # shrink the largest dim first, never below hardware alignment
        if bk >= bm and bk >= bn and bk > 128:
            bk //= 2
        elif bm >= bn and bm > 128:
            bm //= 2
        elif bn > 128:
            bn //= 2
        else:
            break
    return bm, bn, bk


@functools.partial(
    jax.jit,
    static_argnames=("activation", "block_m", "block_n", "block_k",
                     "out_dtype", "interpret"))
def matmul(a: jax.Array, b: jax.Array, bias: Optional[jax.Array] = None, *,
           activation: Optional[str] = None,
           block_m: Optional[int] = None, block_n: Optional[int] = None,
           block_k: Optional[int] = None, out_dtype=None,
           interpret: bool = False) -> jax.Array:
    """C = activation(A @ B + bias), Pallas-tiled.

    Works for any (M, K) x (K, N); inputs are zero-padded to block
    multiples and the output sliced back.
    """
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError(f"matmul expects 2-D operands, got {a.shape} {b.shape}")
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {a.shape} @ {b.shape}")
    out_dtype = out_dtype or jnp.promote_types(a.dtype, b.dtype)
    itemsize = max(jnp.dtype(a.dtype).itemsize, jnp.dtype(b.dtype).itemsize)
    dbm, dbn, dbk = default_blocks(m, n, k, itemsize)
    bm, bn, bk = block_m or dbm, block_n or dbn, block_k or dbk

    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    a_p = jnp.pad(a, ((0, mp - m), (0, kp - k))) if (mp != m or kp != k) else a
    b_p = jnp.pad(b, ((0, kp - k), (0, np_ - n))) if (kp != k or np_ != n) else b
    bias_p = None
    if bias is not None:
        bias = bias.reshape(-1)
        if bias.shape[0] != n:
            raise ValueError(f"bias length {bias.shape[0]} != N {n}")
        bias_p = jnp.pad(bias, (0, np_ - n)) if np_ != n else bias

    out = matmul_pallas(a_p, b_p, bias_p, block_m=bm, block_n=bn, block_k=bk,
                        out_dtype=out_dtype, activation=activation,
                        interpret=interpret)
    if mp != m or np_ != n:
        out = out[:m, :n]
    return out
