"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .matmul import ACTIVATIONS


def matmul_ref(a: jax.Array, b: jax.Array, bias: Optional[jax.Array] = None,
               activation: Optional[str] = None,
               out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or jnp.promote_types(a.dtype, b.dtype)
    acc = jnp.dot(a, b, preferred_element_type=jnp.float32)
    if bias is not None:
        acc = acc + bias.astype(jnp.float32)
    acc = ACTIVATIONS[activation](acc)
    return acc.astype(out_dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        scale: Optional[float] = None) -> jax.Array:
    """Oracle for the flash kernel.  q: (B, Sq, H, D); k/v (B, Sk, Hkv, D)."""
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    scale = scale if scale is not None else d ** -0.5
    qf = q.reshape(b, sq, hkv, g, d).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32)) * scale
    if causal:
        sk = k.shape[1]
        mask = jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None]
        s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
    return o.reshape(b, sq, h, d).astype(q.dtype)
