"""Pallas flash-attention kernel (online-softmax, causal, GQA).

The second compute hot-spot after the matmul: prefill attention at 32k
context.  The BLASX tile insight applies directly — the (block_q, d)
query tile is the stationary operand resident in VMEM (L1 tile cache);
K/V panels stream past it (the ring of tiles); the running (m, l, acc)
statistics are the cached partial result, so the S x S score matrix
never exists in HBM.  Causal block-skipping prunes the upper-triangle
tiles entirely (the tile-algebra triangle walks of Eq. 1c/1d).

Layout: q (BH, Sq, D), k/v (BH_kv, Sk, D); grid (BH, Sq/bq, Sk/bk),
K innermost so the VMEM carry lives across the K-walk.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import COMPILER_PARAMS as _COMPILER_PARAMS

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  n_k: int, scale: float, causal: bool, block_q: int,
                  block_k: int, kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: skip blocks entirely above the diagonal
    first_q = qi * block_q
    last_q = first_q + block_q - 1
    first_k = ki * block_k

    @pl.when(jnp.logical_or(not causal, last_q >= first_k))
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0].astype(jnp.float32)          # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = first_q + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = first_k + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = kpos < kv_len                       # padding
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                        # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                     # (bq, bk)
        corr = jnp.exp(m_prev - m_new)             # (bq, 1)
        l_ref[...] = corr * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _store():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True,
                         scale: Optional[float] = None,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = False) -> jax.Array:
    """q: (BH, Sq, D); k/v: (BHkv, Sk, D) with BH % BHkv == 0 (GQA)."""
    bh, sq, d = q.shape
    bh_kv, sk, _ = k.shape
    assert bh % bh_kv == 0, (bh, bh_kv)
    group = bh // bh_kv
    scale = scale if scale is not None else d ** -0.5

    def pad_to(x, blk, axis):
        rem = (-x.shape[axis]) % blk
        if rem == 0:
            return x
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, rem)
        return jnp.pad(x, widths)

    qp = pad_to(q, block_q, 1)
    kp = pad_to(k, block_k, 1)
    vp = pad_to(v, block_k, 1)
    sqp, skp = qp.shape[1], kp.shape[1]
    n_k = skp // block_k
    grid = (bh, sqp // block_q, n_k)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, n_k=n_k, scale=scale,
                          causal=causal, block_q=block_q, block_k=block_k,
                          kv_len=sk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda b, i, j, g=group: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sqp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom
            pltpu.VMEM((block_q, d), jnp.float32),   # output accumulator
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :sq, :]


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """Convenience layout: q (B, Sq, H, D); k/v (B, Sk, Hkv, D)."""
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    q2 = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    k2 = k.transpose(0, 2, 1, 3).reshape(b * hkv, k.shape[1], d)
    v2 = v.transpose(0, 2, 1, 3).reshape(b * hkv, v.shape[1], d)
    o = flash_attention_bhsd(q2, k2, v2, causal=causal, scale=scale,
                             block_q=block_q, block_k=block_k,
                             interpret=interpret)
    return o.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
