"""Version shims for the jax pallas TPU surface shared by the kernels.

Newer jax releases renamed ``pltpu.TPUCompilerParams`` to
``pltpu.CompilerParams``; resolve whichever exists once, here, so the
kernels stay importable across versions.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")
