"""Version shims for the jax pallas/SPMD surface shared by the kernels.

Newer jax releases renamed ``pltpu.TPUCompilerParams`` to
``pltpu.CompilerParams`` and promoted ``shard_map`` out of
``jax.experimental`` to ``jax.shard_map`` (dropping the ``check_rep``
kwarg along the way); resolve whichever exists once, here, so the
kernels and the model stack stay importable across versions.
"""
from __future__ import annotations

import jax
from jax.experimental.pallas import tpu as pltpu

COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")


def _resolve_shard_map():
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn
    from jax.experimental.shard_map import shard_map as exp_fn
    return exp_fn


_SHARD_MAP = _resolve_shard_map()


def shard_map(body, *, mesh, in_specs, out_specs, check_rep=False):
    """``shard_map`` across jax versions: prefers ``jax.shard_map``,
    falls back to the deprecated experimental import, and tolerates
    APIs that no longer accept ``check_rep`` (replication checking is
    simply skipped there — every caller in this repo passes False)."""
    try:
        return _SHARD_MAP(body, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_rep)
    except TypeError:
        return _SHARD_MAP(body, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs)
