"""Pipeline parallelism: 1F1B microbatch schedule over model stages.

For pods beyond the (data, model) mesh, depth can be split over the
``pod`` axis: stage s holds layers [s*L/S, (s+1)*L/S).  This module
provides the schedule itself — which microbatch runs fwd/bwd on which
stage at each tick — plus a host-orchestrated executor that runs real
jitted stage functions in that order (exercised on CPU by the tests;
on hardware the same schedule drives per-stage pjit programs with
device-to-device transfers between stages).

1F1B (one-forward-one-back) keeps at most ``n_stages`` microbatch
activations live per stage (vs GPipe's n_micro), with bubble fraction
(S-1)/(M+S-1).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Tick:
    stage: int
    kind: str          # 'fwd' | 'bwd'
    micro: int


def schedule_1f1b(n_stages: int, n_micro: int) -> List[List[Optional[Tick]]]:
    """Per-timestep list of per-stage work items (None = bubble)."""
    if n_micro < 1 or n_stages < 1:
        raise ValueError("need n_stages >= 1 and n_micro >= 1")
    # per-stage state machines
    next_fwd = [0] * n_stages
    next_bwd = [0] * n_stages
    fwd_ready: List[set] = [set(range(n_micro))] + \
        [set() for _ in range(n_stages - 1)]
    bwd_ready: List[set] = [set() for _ in range(n_stages - 1)] + [set()]
    in_flight = [0] * n_stages   # fwd-done-not-yet-bwd per stage
    done_bwd = 0
    ticks: List[List[Optional[Tick]]] = []
    guard = 0
    while done_bwd < n_stages * n_micro:
        guard += 1
        if guard > 10 * n_stages * (n_micro + n_stages):
            raise RuntimeError("1F1B schedule did not converge")
        row: List[Optional[Tick]] = [None] * n_stages
        fwd_emitted: List[Tuple[int, int]] = []
        bwd_emitted: List[Tuple[int, int]] = []
        for s in range(n_stages):
            warm = in_flight[s] < (n_stages - s)  # warmup depth
            m = next_bwd[s]
            can_bwd = (m < n_micro and m in (bwd_ready[s] if s < n_stages - 1
                                             else fwd_done_set(s, next_fwd)))
            # steady-state 1F1B: prefer bwd unless still warming up
            if can_bwd and not warm:
                row[s] = Tick(s, "bwd", m)
                bwd_emitted.append((s, m))
            elif next_fwd[s] < n_micro and next_fwd[s] in fwd_ready[s]:
                row[s] = Tick(s, "fwd", next_fwd[s])
                fwd_emitted.append((s, next_fwd[s]))
            elif can_bwd:
                row[s] = Tick(s, "bwd", m)
                bwd_emitted.append((s, m))
        if all(t is None for t in row):
            raise RuntimeError("pipeline deadlock")
        for s, m in fwd_emitted:
            fwd_ready[s].discard(m)
            next_fwd[s] += 1
            in_flight[s] += 1
            if s + 1 < n_stages:
                fwd_ready[s + 1].add(m)
            else:
                bwd_ready_last_add(bwd_ready, s, m)
        for s, m in bwd_emitted:
            next_bwd[s] += 1
            in_flight[s] -= 1
            done_bwd += 1
            if s - 1 >= 0:
                bwd_ready[s - 1].add(m)
        ticks.append(row)
    return ticks


def fwd_done_set(stage: int, next_fwd: List[int]) -> set:
    # last stage can run bwd for any microbatch whose fwd it finished
    return set(range(next_fwd[stage]))


def bwd_ready_last_add(bwd_ready, s, m):
    bwd_ready[s] = bwd_ready[s] | {m} if isinstance(bwd_ready[s], set) \
        else bwd_ready[s]


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


# --------------------------------------------------------------- executor
class PipelineExecutor:
    """Runs real stage functions under the 1F1B schedule.

    stage_fwd[s](params_s, x) -> (y, residuals)
    stage_bwd[s](params_s, residuals, dy) -> (dx, grads_s)
    """

    def __init__(self, stage_fwd: Sequence[Callable],
                 stage_bwd: Sequence[Callable], params: Sequence[Any]):
        assert len(stage_fwd) == len(stage_bwd) == len(params)
        self.n_stages = len(stage_fwd)
        self.stage_fwd = stage_fwd
        self.stage_bwd = stage_bwd
        self.params = params

    def run(self, micro_inputs: Sequence[Any], dy_fn: Callable
            ) -> Tuple[List[Any], List[Any], Dict]:
        """Returns (outputs per microbatch, grads per stage, stats).
        ``dy_fn(micro_idx, y)`` provides the loss cotangent at the last
        stage (e.g. from a per-microbatch loss)."""
        S, M = self.n_stages, len(micro_inputs)
        ticks = schedule_1f1b(S, M)
        acts: Dict[Tuple[int, int], Any] = {}      # (stage, micro) -> input
        resid: Dict[Tuple[int, int], Any] = {}
        cotan: Dict[Tuple[int, int], Any] = {}     # (stage, micro) -> dy
        outputs: List[Any] = [None] * M
        grads: List[Any] = [None] * S
        peak_live = 0
        for m in range(M):
            acts[(0, m)] = micro_inputs[m]
        for row in ticks:
            for t in row:
                if t is None:
                    continue
                if t.kind == "fwd":
                    x = acts.pop((t.stage, t.micro))
                    y, r = self.stage_fwd[t.stage](self.params[t.stage], x)
                    resid[(t.stage, t.micro)] = r
                    if t.stage + 1 < S:
                        acts[(t.stage + 1, t.micro)] = y
                    else:
                        outputs[t.micro] = y
                        cotan[(t.stage, t.micro)] = dy_fn(t.micro, y)
                else:
                    r = resid.pop((t.stage, t.micro))
                    dy = cotan.pop((t.stage, t.micro))
                    dx, g = self.stage_bwd[t.stage](self.params[t.stage],
                                                    r, dy)
                    grads[t.stage] = g if grads[t.stage] is None else \
                        jax.tree.map(jnp.add, grads[t.stage], g)
                    if t.stage - 1 >= 0:
                        cotan[(t.stage - 1, t.micro)] = dx
            peak_live = max(peak_live, len(resid))
        stats = {"ticks": len(ticks), "peak_residuals": peak_live,
                 "bubble_frac": bubble_fraction(S, M)}
        return outputs, grads, stats


def make_stages_from_model(fwd_fn: Callable, n_stages: int):
    """Build stage fwd/bwd callables from a per-stage forward via
    jax.vjp (generic: any differentiable stage)."""
    def stage_fwd(params, x):
        y, vjp = jax.vjp(lambda p, xx: fwd_fn(p, xx), params, x)
        return y, vjp

    def stage_bwd(params, vjp, dy):
        dparams, dx = vjp(dy)
        return dx, dparams

    return ([stage_fwd] * n_stages, [stage_bwd] * n_stages)
