"""End-to-end training driver with fault tolerance.

Features (the 1000+-node checklist, exercised here at CPU scale):
  * checkpoint every K steps (atomic, retained, optionally async)
  * auto-resume from the latest checkpoint (restart-exact data stream)
  * preemption handling: SIGTERM/SIGINT trigger save-then-exit
  * crash retry: a failing step rolls back to the last checkpoint
  * elastic restore: device count may differ from save time
  * per-step metrics + straggler watchdog (flags slow steps; on a real
    multi-pod deployment this feeds the grad-accum rebalancer)

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3_0_6b \
      --smoke --steps 50 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import signal
import sys
import time
from typing import Optional

import jax
import numpy as np

from ..checkpoint import Checkpointer
from ..configs import get_config
from ..data import DataConfig, batch_at_step
from ..models.sharding import rules_for_mesh, NO_MESH
from ..optim import adamw
from .mesh import make_mesh_for_devices
from .steps import make_train_step


@dataclasses.dataclass
class TrainConfig:
    arch: str = "qwen3_0_6b"
    smoke: bool = True              # use the reduced config
    steps: int = 100
    seq_len: int = 128
    global_batch: int = 8
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 20
    ckpt_async: bool = False
    seed: int = 0
    lr: float = 3e-4
    use_mesh: bool = False          # shard over available devices
    model_parallel: int = 1
    log_every: int = 10
    straggler_factor: float = 3.0   # step slower than 3x median -> flag


def run(tc: TrainConfig) -> dict:
    cfg = get_config(tc.arch)
    if tc.smoke:
        cfg = cfg.reduced()
    mesh = make_mesh_for_devices(model_parallel=tc.model_parallel) \
        if tc.use_mesh else None
    rules = rules_for_mesh(mesh) if mesh is not None else NO_MESH

    opt_cfg = adamw.AdamWConfig(lr=tc.lr, total_steps=tc.steps,
                                warmup_steps=max(1, tc.steps // 10))
    step_fn, model = make_train_step(cfg, rules, opt_cfg)
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    params = model.init(jax.random.PRNGKey(tc.seed))
    opt_state = adamw.init_state(params)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=tc.seq_len,
                    global_batch=tc.global_batch, seed=tc.seed)

    start_step = 0
    ckpt = Checkpointer(tc.ckpt_dir) if tc.ckpt_dir else None
    if ckpt is not None:
        latest = ckpt.restore_latest({"params": params, "opt": opt_state})
        if latest is not None:
            start_step, tree, extra = latest
            params, opt_state = tree["params"], tree["opt"]
            print(f"[train] resumed from step {start_step}")

    # ---- preemption: save on SIGTERM/SIGINT then exit cleanly
    preempted = {"flag": False}

    def _on_signal(signum, frame):
        preempted["flag"] = True
    old_term = signal.signal(signal.SIGTERM, _on_signal)
    old_int = signal.signal(signal.SIGINT, _on_signal)

    losses, step_times = [], []
    last_good = start_step
    step = start_step
    try:
        while step < tc.steps:
            t0 = time.perf_counter()
            batch_np = batch_at_step(dc, step,
                                     frontend_dim=cfg.d_model
                                     if cfg.frontend else None)
            batch = {k: jax.numpy.asarray(v) for k, v in batch_np.items()
                     if k in ("tokens", "labels", "embeds")}
            if cfg.family == "encdec":
                batch["enc_embeds"] = jax.numpy.asarray(
                    np.random.default_rng(step).standard_normal(
                        (tc.global_batch, tc.seq_len, cfg.d_model)
                    ).astype(np.float32))
            try:
                params, opt_state, metrics = jit_step(params, opt_state,
                                                      batch)
                loss = float(metrics["loss"])
                if not np.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at {step}")
            except (FloatingPointError, RuntimeError) as e:
                # crash retry: roll back to the last checkpoint
                if ckpt is None or ckpt.latest_step() is None:
                    raise
                print(f"[train] step {step} failed ({e}); rolling back")
                s, tree, _ = ckpt.restore_latest(
                    {"params": params, "opt": opt_state})
                params, opt_state = tree["params"], tree["opt"]
                step = s
                continue

            dt = time.perf_counter() - t0
            losses.append(loss)
            step_times.append(dt)
            if len(step_times) > 8:
                med = float(np.median(step_times[-50:]))
                if dt > tc.straggler_factor * med:
                    print(f"[train] WARNING straggler step {step}: "
                          f"{dt:.2f}s vs median {med:.2f}s")
            step += 1
            if tc.log_every and step % tc.log_every == 0:
                print(f"[train] step {step:5d} loss={loss:.4f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"gnorm={float(metrics['grad_norm']):.2f} "
                      f"{dt*1e3:.0f}ms")
            if ckpt is not None and step % tc.ckpt_every == 0:
                ckpt.save(step, {"params": params, "opt": opt_state},
                          extra={"loss": loss}, blocking=not tc.ckpt_async)
                last_good = step
            if preempted["flag"]:
                print(f"[train] preemption signal: saving at step {step}")
                if ckpt is not None:
                    ckpt.save(step, {"params": params, "opt": opt_state},
                              extra={"preempted": True})
                break
    finally:
        if ckpt is not None:
            ckpt.wait()
        signal.signal(signal.SIGTERM, old_term)
        signal.signal(signal.SIGINT, old_int)

    return {"final_step": step, "losses": losses,
            "first_loss": losses[0] if losses else None,
            "last_loss": losses[-1] if losses else None,
            "last_ckpt": last_good}


def main(argv=None):
    ap = argparse.ArgumentParser()
    for f in dataclasses.fields(TrainConfig):
        name = "--" + f.name.replace("_", "-")
        if f.type == "bool" or isinstance(f.default, bool):
            ap.add_argument(name, action="store_true", default=f.default)
        else:
            ap.add_argument(name, type=type(f.default)
                            if f.default is not None else str,
                            default=f.default)
    args = ap.parse_args(argv)
    tc = TrainConfig(**{f.name: getattr(args, f.name)
                        for f in dataclasses.fields(TrainConfig)})
    out = run(tc)
    print(f"[train] done: loss {out['first_loss']:.4f} -> "
          f"{out['last_loss']:.4f} over {out['final_step']} steps")
    return 0


if __name__ == "__main__":
    sys.exit(main())
