"""Roofline-term derivation from compiled dry-run artifacts.

    compute    = HLO_FLOPs      / (chips * PEAK_FLOPS)
    memory     = HLO_bytes      / (chips * HBM_BW)
    collective = collective_bytes / (chips * ICI_BW)

``cost_analysis`` supplies FLOPs and bytes; collective bytes are parsed
out of the HLO text by summing the *operand* sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op (a
symbol table of instruction result types resolves operand references).

Hardware constants: TPU v5e-class chip.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

# wire-byte weights: ring all-reduce moves 2(n-1)/n of the payload per
# participant; gather/scatter/a2a move (n-1)/n; a permute moves exactly
# its operand.  With n=256 the factors round to 2/1/1/1/1.
WIRE_WEIGHT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}


def wire_bytes(breakdown: Dict[str, int]) -> float:
    """Parsed per-kind operand bytes -> modeled wire bytes."""
    return float(sum(WIRE_WEIGHT.get(k, 1.0) * v
                     for k, v in breakdown.items()))

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(.+?)\s+"
                       r"([\w\-]+)\((.*)\)")


def shape_bytes(type_str: str) -> int:
    """Bytes of one HLO type string, incl. tuple types."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand sizes per collective kind across the module."""
    # symbol table: %name -> result type string
    symtab: Dict[str, str] = {}
    pending: List[tuple] = []
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rtype, op, args = m.groups()
        symtab[name.lstrip("%")] = rtype
        base = op.rstrip(".0123456789")
        for kind in COLLECTIVE_OPS:
            if base == kind or base.startswith(kind + "-"):
                pending.append((kind, rtype, args))
                break

    out = {k: 0 for k in COLLECTIVE_OPS}
    for kind, rtype, args in pending:
        nbytes = 0
        # operands may carry inline types, else resolve via symtab
        for arg in _split_args(args):
            arg = arg.strip()
            if not arg:
                continue
            inline = _SHAPE_RE.search(arg.split("%")[0])
            if inline:
                nbytes += shape_bytes(arg.split("%")[0])
                continue
            ref = arg.lstrip("%").split(" ")[0].split(")")[0]
            t = symtab.get(ref)
            if t:
                nbytes += shape_bytes(t)
        if nbytes == 0:   # fallback: use the result type
            nbytes = shape_bytes(rtype)
        out[kind] += nbytes
    return out


def _split_args(args: str) -> List[str]:
    """Split HLO operand list at top-level commas."""
    parts, depth, cur = [], 0, []
    for ch in args:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return parts


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_breakdown: Dict[str, int]
    model_flops: float
    bytes_per_device: Optional[float] = None

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        # coll_bytes carries parsed operand bytes x chips; weight to wire
        return (wire_bytes(self.coll_breakdown)
                / sum(self.coll_breakdown.values())
                * self.coll_bytes if sum(self.coll_breakdown.values())
                else self.coll_bytes) / (self.chips * ICI_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful
        (catches remat recompute + dispatch waste)."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_frac(self) -> float:
        """Fraction of the step spent at the roofline if the dominant
        term were perfectly attained by useful model FLOPs."""
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        return ideal / self.bound_s if self.bound_s else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_flops_frac": self.useful_flops_frac,
            "roofline_frac": self.roofline_frac,
            "bytes_per_device": self.bytes_per_device,
            "coll_breakdown": self.coll_breakdown,
        }


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense train) / 6*N_active*D (MoE), with the
    2*N*D forward-only variant for serving shapes."""
    n_params = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_params * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_params * tokens
    # decode: one token per sequence
    return 2.0 * n_params * shape.global_batch
