"""Batched serving driver: continuous-batching-lite inference loop.

Maintains a fixed-size decode batch; each slot holds one request.
Finished requests (EOS or max_tokens) free their slot, and queued
requests are prefilled into it — the serving analogue of the paper's
demand-driven scheduling (slots pull work as they free up, so fast and
slow requests never block each other).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_0_6b --smoke \
      --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models import Model
from ..models.sharding import NO_MESH


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (S,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class ServeConfig:
    arch: str = "qwen3_0_6b"
    smoke: bool = True
    batch_slots: int = 4
    prompt_len: int = 16
    max_len: int = 64
    requests: int = 8
    max_new: int = 16
    greedy: bool = True
    seed: int = 0


class Server:
    """One-model batch server with per-slot caches."""

    def __init__(self, cfg, model: Model, params, batch_slots: int,
                 max_len: int):
        self.cfg = cfg
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self._decode = jax.jit(model.decode)
        self.cache = None        # batched cache, built from first prefill
        self.pos = np.zeros((batch_slots,), np.int32)
        self.active: List[Optional[Request]] = [None] * batch_slots
        self.last_token = np.zeros((batch_slots,), np.int32)

    # ------------------------------------------------------------- admit
    def admit(self, req: Request, slot: int) -> None:
        logits, cache = self.model.prefill(
            self.params, tokens=jnp.asarray(req.prompt[None, :]))
        cache = self.model.pad_cache(cache, self.max_len)
        tok = int(jnp.argmax(logits[0, -1]))
        req.out.append(tok)
        if self.cache is None:
            # build the batched cache by tiling the first request's
            self.cache = jax.tree.map(
                lambda a: jnp.repeat(a, self.slots, axis=1), cache)
        # write this request's cache into its slot
        self.cache = jax.tree.map(
            lambda big, one: big.at[:, slot].set(one[:, 0]),
            self.cache, cache)
        self.pos[slot] = len(req.prompt)
        self.last_token[slot] = tok
        self.active[slot] = req

    # ------------------------------------------------------------- step
    def step(self) -> List[Request]:
        """One batched decode step; returns requests that finished."""
        tok = jnp.asarray(self.last_token)
        pos = jnp.asarray(self.pos)
        logits, self.cache = self._decode(self.params, self.cache, tok, pos)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        done: List[Request] = []
        for s, req in enumerate(self.active):
            if req is None or req.done:
                continue
            req.out.append(int(nxt[s]))
            self.pos[s] += 1
            self.last_token[s] = nxt[s]
            if len(req.out) >= req.max_new or self.pos[s] >= self.max_len - 1:
                req.done = True
                done.append(req)
                self.active[s] = None  # slot freed -> next request pulls in
        return done


def run(sc: ServeConfig) -> dict:
    cfg = get_config(sc.arch)
    if sc.smoke:
        cfg = cfg.reduced()
    model = Model(cfg, NO_MESH)
    params = model.init(jax.random.PRNGKey(sc.seed))
    rng = np.random.default_rng(sc.seed)
    queue = [Request(i, rng.integers(0, cfg.vocab_size,
                                     (sc.prompt_len,)).astype(np.int32),
                     sc.max_new) for i in range(sc.requests)]
    server = Server(cfg, model, params, sc.batch_slots, sc.max_len)
    finished: List[Request] = []
    t0 = time.perf_counter()
    steps = 0
    while queue or any(r is not None for r in server.active):
        # demand-driven admission: every free slot pulls from the queue
        for s in range(server.slots):
            if server.active[s] is None and queue:
                server.admit(queue.pop(0), s)
        finished.extend(server.step())
        steps += 1
        if steps > 10000:
            raise RuntimeError("serve loop did not converge")
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in finished)
    assert len(finished) == sc.requests
    return {"steps": steps, "wall_s": dt, "requests": len(finished),
            "tokens": toks, "tok_per_s": toks / dt if dt else 0.0,
            "outputs": {r.rid: r.out for r in finished}}


def main(argv=None):
    ap = argparse.ArgumentParser()
    for f in dataclasses.fields(ServeConfig):
        name = "--" + f.name.replace("-", "-").replace("_", "-")
        if isinstance(f.default, bool):
            ap.add_argument(name, action="store_true", default=f.default)
        else:
            ap.add_argument(name, type=type(f.default), default=f.default)
    args = ap.parse_args(argv)
    sc = ServeConfig(**{f.name: getattr(args, f.name)
                        for f in dataclasses.fields(ServeConfig)})
    out = run(sc)
    print(f"[serve] {out['requests']} requests, {out['tokens']} tokens in "
          f"{out['wall_s']:.2f}s ({out['tok_per_s']:.1f} tok/s, "
          f"{out['steps']} decode steps)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
