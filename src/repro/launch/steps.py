"""jit-able train / prefill / decode steps + their abstract input specs.

``input_specs`` returns ShapeDtypeStructs (weak-type-correct, sharded,
no allocation) for every model input — the dry-run lowers against these
directly; real drivers feed arrays of the same shapes.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from ..models import Model
from ..models.sharding import MeshRules
from ..optim import adamw

MOE_AUX_WEIGHT = 0.01
MTP_WEIGHT = 0.3

# §Perf toggle: compute the CE loss in sequence chunks (the (B,S,V) f32
# logits tensor is the single largest buffer of every train step; the
# chunked form never materializes it — remat recomputes per chunk).
CHUNKED_CE = True
CE_CHUNKS = 16


def _chunked_ce(hidden, head, labels, rules: MeshRules,
                mask=None, n_chunks: int = CE_CHUNKS) -> jax.Array:
    """Mean next-token CE without a full logits tensor.  ``mask``
    (B, S) of {0,1} optionally excludes positions."""
    B, S, d = hidden.shape
    while S % n_chunks != 0 and n_chunks > 1:
        n_chunks //= 2
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    hc = hidden.reshape(B, n_chunks, S // n_chunks, d).swapaxes(0, 1)
    lc = labels.reshape(B, n_chunks, S // n_chunks).swapaxes(0, 1)
    mc = mask.reshape(B, n_chunks, S // n_chunks).swapaxes(0, 1)

    @jax.checkpoint
    def body(acc, inp):
        h, l, m = inp
        logits = jnp.dot(h, head.astype(h.dtype),
                         preferred_element_type=jnp.float32)
        logits = rules.constrain(logits, "batch", "seq", "model")
        lg = jax.nn.log_softmax(logits, axis=-1)
        ce = -(jnp.take_along_axis(lg, l[..., None], axis=-1)[..., 0]
               * m).sum()
        return acc + ce, None

    total, _ = jax.lax.scan(body, jnp.float32(0.0), (hc, lc, mc))
    return total / jnp.maximum(1.0, mask.sum())


# ---------------------------------------------------------------- specs
def input_specs(cfg: ModelConfig, shape: ShapeConfig, rules: MeshRules,
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract inputs for the given (arch, shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    # single-sequence long decode: batch axis unshardable -> replicate
    b_ax = "batch" if rules.batch_size_divides(B) else None

    def sds(shp, dtype, *logical):
        sh = (rules.fitted_sharding(shp, *logical)
              if rules.mesh is not None else None)
        if sh is not None:
            return jax.ShapeDtypeStruct(shp, dtype, sharding=sh)
        return jax.ShapeDtypeStruct(shp, dtype)

    if shape.kind == "train":
        out = {"tokens": sds((B, S), jnp.int32, b_ax, "seq"),
               "labels": sds((B, S), jnp.int32, b_ax, "seq")}
        if cfg.family == "encdec":
            out["enc_embeds"] = sds((B, S, cfg.d_model), jnp.bfloat16,
                                    b_ax, "seq", None)
        elif cfg.frontend:
            # modality frontend stub: precomputed patch/frame embeddings
            out["embeds"] = sds((B, S, cfg.d_model), jnp.bfloat16,
                                b_ax, "seq", None)
            del out["tokens"]
        return out
    if shape.kind == "prefill":
        out = {"tokens": sds((B, S), jnp.int32, b_ax, "seq")}
        if cfg.family == "encdec":
            out["enc_embeds"] = sds((B, S, cfg.d_model), jnp.bfloat16,
                                    b_ax, "seq", None)
        elif cfg.frontend:
            out = {"embeds": sds((B, S, cfg.d_model), jnp.bfloat16,
                                 b_ax, "seq", None)}
        return out
    if shape.kind == "decode":
        return {"token": sds((B,), jnp.int32, b_ax),
                "pos": sds((B,), jnp.int32, b_ax)}
    raise ValueError(shape.kind)


# ---------------------------------------------------------------- train
def make_train_step(cfg: ModelConfig, rules: MeshRules,
                    opt_cfg: Optional[adamw.AdamWConfig] = None):
    model = Model(cfg, rules)
    opt_cfg = opt_cfg or adamw.AdamWConfig()

    def loss_fn(params, batch):
        kw = {}
        if "embeds" in batch:
            kw["embeds"] = batch["embeds"]
        else:
            kw["tokens"] = batch["tokens"]
        if "enc_embeds" in batch:
            kw["enc_embeds"] = batch["enc_embeds"]
        labels = batch["labels"]
        if CHUNKED_CE:
            hidden, aux = model.train_logits(params, return_hidden=True,
                                             **kw)
            head = model.head_matrix(params)
            ce = _chunked_ce(hidden, head, labels, rules)
            loss = ce
            if "moe_aux_loss" in aux:
                loss = loss + MOE_AUX_WEIGHT * aux["moe_aux_loss"]
            if "mtp_hidden" in aux:
                # predict t+2: shift labels, mask the final position
                l2 = jnp.concatenate(
                    [labels[:, 1:], jnp.zeros_like(labels[:, :1])], axis=1)
                m2 = jnp.concatenate(
                    [jnp.ones_like(labels[:, 1:], jnp.float32),
                     jnp.zeros_like(labels[:, :1], jnp.float32)], axis=1)
                mtp_ce = _chunked_ce(aux["mtp_hidden"], head, l2, rules,
                                     mask=m2)
                loss = loss + MTP_WEIGHT * mtp_ce
            return loss, {"ce": ce}
        logits, aux = model.train_logits(params, **kw)
        lg = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ce = -jnp.take_along_axis(lg, labels[..., None], axis=-1).mean()
        loss = ce
        if "moe_aux_loss" in aux:
            loss = loss + MOE_AUX_WEIGHT * aux["moe_aux_loss"]
        if "mtp_logits" in aux:
            # MTP: predict t+2 with the extra block's logits
            mlg = jax.nn.log_softmax(
                aux["mtp_logits"][:, :-1].astype(jnp.float32), axis=-1)
            mtp_ce = -jnp.take_along_axis(
                mlg, labels[:, 1:][..., None], axis=-1).mean()
            loss = loss + MTP_WEIGHT * mtp_ce
        return loss, {"ce": ce}

    def train_step(params, opt_state, batch):
        (loss, aux), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        params, opt_state, opt_metrics = adamw.apply_updates(
            opt_cfg, params, grads, opt_state)
        metrics = {"loss": loss, **aux, **opt_metrics}
        return params, opt_state, metrics

    return train_step, model


# §Perf toggle: serve with model-only-sharded params when they fit —
# inference has no optimizer state, so FSDP's per-layer weight
# all-gathers are pure overhead there.
SERVING_NO_FSDP = True
SERVING_FIT_GB = 8.0


def serving_rules(cfg: ModelConfig, rules: MeshRules) -> MeshRules:
    import dataclasses as _dc
    if not SERVING_NO_FSDP or rules.mesh is None:
        return rules
    model_n = max(1, rules.axis_size(rules.model_axis))
    params_gb = cfg.param_count() * 2 / 2 ** 30  # bf16
    if params_gb / model_n <= SERVING_FIT_GB:
        return _dc.replace(rules, fsdp_axis=None)
    return rules


# ----------------------------------------------------------------- serve
def make_prefill_step(cfg: ModelConfig, rules: MeshRules):
    model = Model(cfg, rules)

    def prefill_step(params, batch):
        kw = {k: v for k, v in batch.items()
              if k in ("tokens", "embeds", "enc_embeds")}
        return model.prefill(params, **kw)

    return prefill_step, model


def make_decode_step(cfg: ModelConfig, rules: MeshRules):
    """serve_step: ONE new token against a kv/state cache (the
    ``decode_*`` / ``long_*`` dry-run shapes lower THIS, not train)."""
    model = Model(cfg, rules)

    def decode_step(params, cache, token, pos):
        return model.decode(params, cache, token, pos)

    return decode_step, model


# -------------------------------------------------------------- abstract
def abstract_train_state(cfg: ModelConfig, rules: MeshRules
                         ) -> Tuple[Any, Any]:
    model = Model(cfg, rules)
    params = model.abstract()
    opt_state = adamw.abstract_state(params)
    return params, opt_state


def abstract_serve_state(cfg: ModelConfig, rules: MeshRules,
                         shape: ShapeConfig) -> Tuple[Any, Any]:
    model = Model(cfg, rules)
    params = model.abstract()
    cache = model.abstract_cache(shape.global_batch, shape.seq_len,
                                 enc_len=min(shape.seq_len, 4096))
    return params, cache
