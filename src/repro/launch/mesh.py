"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (the dry-run sets
``xla_force_host_platform_device_count`` before any jax init; tests and
benches must keep seeing 1 device).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — the
    pod axis carries only the cross-pod gradient reduction (DCN), TP
    stays ICI-local."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_for_devices(n_devices: Optional[int] = None,
                          model_parallel: int = 1):
    """Elastic helper: build a (data, model) mesh from whatever devices
    exist (restart with N != save-time devices reshards via the
    checkpointer)."""
    n = n_devices or len(jax.devices())
    if n % model_parallel != 0:
        raise ValueError(f"{n} devices not divisible by mp={model_parallel}")
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))


def mesh_axes(mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def n_chips(mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
