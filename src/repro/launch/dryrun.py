import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", "")).strip()
# ^ MUST run before any jax import: jax locks the device count on first
# init.  Only the dry-run sees 512 placeholder devices.

"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape) cell, lower + compile the
appropriate step (train_step for train shapes, serve/prefill steps for
inference shapes) against ShapeDtypeStruct inputs on

  * the single-pod 16x16 mesh (256 chips, axes data x model), and
  * the 2-pod 2x16x16 mesh (512 chips, axes pod x data x model),

printing memory_analysis() (proves the per-device working set) and
cost_analysis() (FLOPs/bytes for the §Roofline table), plus the
collective-byte breakdown parsed from the HLO.

Usage:
  python -m repro.launch.dryrun --arch qwen3_0_6b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod --out results.json
"""
import argparse
import json
import sys
import time
import traceback
from typing import Optional

import jax

from ..configs import ARCH_IDS, SHAPES, cell_supported, get_config
from ..models.sharding import rules_for_mesh
from . import roofline as rf
from .mesh import make_production_mesh, n_chips
from .steps import (abstract_serve_state, abstract_train_state, input_specs,
                    make_decode_step, make_prefill_step, make_train_step)


def _depth_handle(cfg):
    """(u_full, make(u)) — rebuild the config at ``u`` depth units so
    per-unit costs can be measured on small UNROLLED programs and
    extrapolated linearly (costs are exactly linear in depth for
    homogeneous stacks)."""
    import dataclasses as dc
    fam = cfg.family
    if fam == "dense" or fam == "ssm":
        return cfg.n_layers, lambda u: dc.replace(cfg, n_layers=u)
    if fam == "moe":
        nd = cfg.n_dense_layers
        return (cfg.n_layers - nd,
                lambda u: dc.replace(cfg, n_layers=nd + u))
    if fam == "hybrid":
        per = cfg.attn_every
        return (cfg.n_layers // per,
                lambda u: dc.replace(cfg, n_layers=u * per))
    if fam == "encdec":
        return (cfg.n_layers,
                lambda u: dc.replace(cfg, n_layers=u, n_encoder_layers=u))
    raise ValueError(fam)


def _lower_step(cfg, shape, rules):
    if shape.kind == "train":
        step, _ = make_train_step(cfg, rules)
        params, opt_state = abstract_train_state(cfg, rules)
        batch = input_specs(cfg, shape, rules)
        return jax.jit(step).lower(params, opt_state, batch)
    from .steps import serving_rules
    srules = serving_rules(cfg, rules)
    if shape.kind == "prefill":
        step, _ = make_prefill_step(cfg, srules)
        params, _ = abstract_train_state(cfg, srules)
        batch = input_specs(cfg, shape, srules)
        return jax.jit(step).lower(params, batch)
    step, _ = make_decode_step(cfg, srules)
    params, cache = abstract_serve_state(cfg, srules, shape)
    io = input_specs(cfg, shape, srules)
    return jax.jit(step).lower(params, cache, io["token"], io["pos"])


def _costs_of(compiled) -> dict:
    cost = compiled.cost_analysis()
    coll = rf.collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": coll}


def dryrun_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
                verbose: bool = True, costs: bool = True) -> dict:
    from ..models import transformer as _tf
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for_mesh(mesh)
    chips = n_chips(mesh)
    t0 = time.perf_counter()

    # 1) the DEPLOYABLE program: full depth, layer scan.  This is the
    #    compile-success proof and the memory_analysis source.
    _tf.SCAN_UNROLL = False
    with mesh:
        compiled = _lower_step(cfg, shape, rules).compile()
    mem = compiled.memory_analysis()

    if not costs:
        # multi-pod pass: compile-success + memory proof only (the
        # roofline cost table is single-pod per the assignment)
        result = {
            "arch": arch, "shape": shape_name,
            "mesh": "multi" if multi_pod else "single",
            "status": "ok", "chips": chips,
            "compile_s": time.perf_counter() - t0,
            "memory": {k: _mem_attr(mem, k) for k in (
                "temp_size_in_bytes", "argument_size_in_bytes",
                "output_size_in_bytes")},
        }
        if verbose:
            print(f"== {arch} x {shape_name} x "
                  f"{'2x16x16' if multi_pod else '16x16'} OK "
                  f"[{result['compile_s']:.1f}s] mem={result['memory']}")
        return result

    # 2) cost accounting: XLA counts while-loop bodies ONCE, so FLOPs /
    #    bytes / collective counts from (1) would miss (L-1)/L of the
    #    model.  Compile two small UNROLLED depth variants and
    #    extrapolate linearly to full depth (exact for homogeneous
    #    stacks: cost(u) = const + u * per_unit).
    u_full, make = _depth_handle(cfg)
    u1, u2 = (1, 2) if u_full >= 2 else (u_full, u_full)
    _tf.SCAN_UNROLL = True
    with mesh:
        c1 = _costs_of(_lower_step(make(u1), shape, rules).compile())
        c2 = (_costs_of(_lower_step(make(u2), shape, rules).compile())
              if u2 != u1 else c1)
    _tf.SCAN_UNROLL = False

    def extrap(k):
        per_unit = (c2[k] - c1[k]) / max(1, (u2 - u1))
        return c1[k] + (u_full - u1) * per_unit

    coll = {key: max(0.0, c1["coll"][key]
                     + (u_full - u1) * (c2["coll"][key] - c1["coll"][key])
                     / max(1, (u2 - u1)))
            for key in c1["coll"]}

    # cost_analysis + HLO text describe the PER-DEVICE program; scale by
    # chips so the roofline numerators are global (the per-chip divisor
    # in the roofline terms cancels back to per-chip time).
    flops = extrap("flops") * chips
    bytes_accessed = extrap("bytes") * chips
    roof = rf.Roofline(
        arch=arch, shape=shape_name,
        mesh="multi" if multi_pod else "single", chips=chips,
        hlo_flops=flops, hlo_bytes=bytes_accessed,
        coll_bytes=float(sum(coll.values())) * chips,
        coll_breakdown={k: int(v) for k, v in coll.items()},
        model_flops=rf.model_flops_for(cfg, shape),
        bytes_per_device=_mem_attr(mem, "temp_size_in_bytes"),
    )

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok", "chips": chips,
        "compile_s": time.perf_counter() - t0,
        "memory": {k: _mem_attr(mem, k) for k in (
            "temp_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")},
        "roofline": roof.row(),
    }
    if verbose:
        print(f"== {arch} x {shape_name} x "
              f"{'2x16x16' if multi_pod else '16x16'} "
              f"({chips} chips) [{result['compile_s']:.1f}s compile]")
        print(f"   memory_analysis: {result['memory']}")
        print(f"   cost_analysis: flops={flops:.3e} bytes={bytes_accessed:.3e}")
        print(f"   collectives: { {k: v for k, v in coll.items() if v} }")
        r = roof
        print(f"   roofline: compute={r.compute_s:.4f}s "
              f"memory={r.memory_s:.4f}s collective={r.collective_s:.4f}s "
              f"-> dominant={r.dominant} useful={r.useful_flops_frac:.2%} "
              f"frac={r.roofline_frac:.2%}")
    return result


def _mem_attr(mem, name: str) -> Optional[float]:
    try:
        v = getattr(mem, name, None)
        return float(v() if callable(v) else v) if v is not None else None
    except Exception:
        return None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None,
                    help="architecture id (default: all LM archs)")
    ap.add_argument("--shape", default=None,
                    help="shape name (default: all four)")
    ap.add_argument("--all", action="store_true",
                    help="run the full 40-cell sweep")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2x16x16 multi-pod mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-costs", action="store_true",
                    help="compile-success + memory proof only")
    ap.add_argument("--out", default=None, help="write JSON results here")
    args = ap.parse_args(argv)

    archs = ([args.arch] if args.arch
             else [a for a in ARCH_IDS if a != "blasx_gemm"])
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results, failures = [], []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    results.append(dryrun_cell(arch, shape, multi_pod=mp,
                                               costs=not args.no_costs))
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, shape, mp, repr(e)))
                    results.append({"arch": arch, "shape": shape,
                                    "mesh": "multi" if mp else "single",
                                    "status": "failed", "error": repr(e)})
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results if r["status"] == "ok")
    n_skip = sum(1 for r in results if r["status"] == "skipped")
    print(f"\n== dry-run summary: {n_ok} ok, {n_skip} skipped "
          f"(documented), {len(failures)} FAILED")
    for f in failures:
        print("   FAILED:", f)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
