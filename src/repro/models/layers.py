"""Shared building blocks: parameter maker, norms, RoPE, activations."""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .sharding import MeshRules

ACT = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}


class Maker:
    """Builds the parameter pytree either as real arrays (``init``) or as
    ShapeDtypeStructs with shardings attached (``abstract`` — used by the
    dry-run so no host allocation ever happens)."""

    def __init__(self, mode: str, rules: MeshRules, dtype,
                 key: Optional[jax.Array] = None):
        assert mode in ("init", "abstract")
        self.mode = mode
        self.rules = rules
        self.dtype = dtype
        self._key = key
        self._counter = 0

    def param(self, shape: Sequence[int], logical: Sequence[Optional[str]],
              scale: Optional[float] = None, zeros: bool = False,
              dtype=None) -> jax.Array:
        shape = tuple(int(s) for s in shape)
        dtype = dtype or self.dtype
        assert len(shape) == len(logical), (shape, logical)
        sharding = self.rules.fitted_sharding(shape, *logical)
        if self.mode == "abstract":
            if sharding is not None:
                return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)
            return jax.ShapeDtypeStruct(shape, dtype)
        self._counter += 1
        if zeros:
            arr = jnp.zeros(shape, dtype)
        else:
            k = jax.random.fold_in(self._key, self._counter)
            if scale is None:
                fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
                scale = 1.0 / np.sqrt(max(1, fan_in))
            arr = (jax.random.normal(k, shape, jnp.float32) * scale
                   ).astype(dtype)
        if sharding is not None:
            arr = jax.device_put(arr, sharding)
        return arr

    def ones(self, shape, logical, dtype=None):
        shape = tuple(int(s) for s in shape)
        dtype = dtype or self.dtype
        sharding = self.rules.fitted_sharding(shape, *logical)
        if self.mode == "abstract":
            if sharding is not None:
                return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)
            return jax.ShapeDtypeStruct(shape, dtype)
        arr = jnp.ones(shape, dtype)
        if sharding is not None:
            arr = jax.device_put(arr, sharding)
        return arr


# ------------------------------------------------------------------ norms
def rms_norm(x: jax.Array, weight: Optional[jax.Array],
             eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    if weight is not None:
        y = y * weight.astype(jnp.float32)
    return y.astype(dt)


def layer_norm(x: jax.Array, weight: Optional[jax.Array],
               bias: Optional[jax.Array], eps: float = 1e-5) -> jax.Array:
    """Supports OLMo's non-parametric LN (weight=bias=None)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if weight is not None:
        y = y * weight.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dt)


def apply_norm(cfg, x: jax.Array, p: Optional[jax.Array]) -> jax.Array:
    if cfg.nonparametric_ln:
        return layer_norm(x, None, None, cfg.norm_eps)
    return rms_norm(x, p, cfg.norm_eps)


# ------------------------------------------------------------------- RoPE
def rope_angles(positions: jax.Array, head_dim: int,
                theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions: (...,) int -> cos/sin of shape (..., head_dim//2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D); cos/sin: (B, S, D/2) or (S, D/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:        # (S, D/2) -> (1, S, 1, D/2)
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    elif cos.ndim == 3:      # (B, S, D/2) -> (B, S, 1, D/2)
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


# §Perf toggle: row-parallel projections reduce their partial sums with
# an EXPLICIT bf16 psum (shard_map) instead of letting SPMD all-reduce
# the f32 dot partials — halves the dominant TP collective payload.
# (Within-shard accumulation stays f32 via preferred_element_type.)
BF16_ROW_PSUM = True


def row_parallel_matmul(x: jax.Array, w: jax.Array,
                        rules: MeshRules) -> jax.Array:
    """y = x @ w for w row-sharded on the model axis; psum in x.dtype."""
    ax = rules.model_axis
    n = rules.axis_size(ax)
    if (not BF16_ROW_PSUM or rules.mesh is None or n <= 1
            or x.ndim != 3 or x.shape[-1] % n or w.shape[0] % n):
        return x @ w
    from jax.sharding import PartitionSpec as P

    from ..kernels.pallas_compat import shard_map
    bspec = rules.physical("batch")

    def body(xl, wl):
        part = jnp.dot(xl, wl, preferred_element_type=jnp.float32)
        return jax.lax.psum(part.astype(x.dtype), ax)

    fn = shard_map(body, mesh=rules.mesh,
                   in_specs=(P(bspec, None, ax), P(ax, None)),
                   out_specs=P(bspec, None, None), check_rep=False)
    return fn(x, w)


# ------------------------------------------------------------------- MLP
def make_mlp_params(mk: Maker, d: int, ff: int) -> dict:
    return {
        "wi": mk.param((d, ff), ("embed", "model")),
        "wg": mk.param((d, ff), ("embed", "model")),
        "wo": mk.param((ff, d), ("model", "embed")),
    }


def mlp(cfg, p: dict, x: jax.Array, rules: MeshRules) -> jax.Array:
    act = ACT[cfg.act]
    h = act(x @ p["wg"]) * (x @ p["wi"])
    h = rules.constrain(h, "batch", None, "model")
    return row_parallel_matmul(h, p["wo"], rules)
