"""Attention: GQA (with qk-norm), MLA (DeepSeek), cross-attention, KV
caches for serving, and query-chunked computation for long prefills.

Softmax/score math in f32; weights/activations in the config dtype.
The decode path for MLA uses the *absorbed* formulation (cache is the
compressed c_kv + shared RoPE key): at 32k context x128 batch the
expanded cache would not fit the pod, and absorption is the published
DeepSeek-V3 serving scheme — i.e. faithful, not an optimization.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import Maker, apply_rope, rms_norm, rope_angles, row_parallel_matmul
from .sharding import MeshRules

DEFAULT_Q_CHUNK = 1024

# Attention backend for train/prefill self-attention:
#   "xla"    — chunked einsum SDPA (works everywhere; CPU dry-run path)
#   "pallas" — the flash-attention kernel (TPU target; interpret=True on
#              CPU).  Decode and cross-attention always use the XLA path
#              (tiny workloads / cached K,V).
ATTENTION_BACKEND = "xla"
_FLASH_INTERPRET = True  # CPU container; flip False on real TPU


# ---------------------------------------------------------------- params
def make_attn_params(mk: Maker, cfg) -> dict:
    d, H, Hkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    p = {
        "wq": mk.param((d, H * hd), ("embed", "model")),
        "wk": mk.param((d, Hkv * hd), ("embed", "model")),
        "wv": mk.param((d, Hkv * hd), ("embed", "model")),
        "wo": mk.param((H * hd, d), ("model", "embed")),
    }
    if cfg.qk_norm:
        p["q_norm"] = mk.ones((hd,), (None,))
        p["k_norm"] = mk.ones((hd,), (None,))
    return p


def make_mla_params(mk: Maker, cfg) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    qh = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    return {
        "wq_a": mk.param((d, cfg.q_lora_rank), ("embed", None)),
        "q_a_norm": mk.ones((cfg.q_lora_rank,), (None,)),
        "wq_b": mk.param((cfg.q_lora_rank, H * qh), (None, "model")),
        "wkv_a": mk.param((d, cfg.kv_lora_rank + cfg.qk_rope_head_dim),
                          ("embed", None)),
        "kv_a_norm": mk.ones((cfg.kv_lora_rank,), (None,)),
        "wkv_b": mk.param(
            (cfg.kv_lora_rank,
             H * (cfg.qk_nope_head_dim + cfg.v_head_dim)), (None, "model")),
        "wo": mk.param((H * cfg.v_head_dim, d), ("model", "embed")),
    }


# ------------------------------------------------------------- core math
def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array,
          qpos: jax.Array, kpos: jax.Array, *, causal: bool,
          scale: float, kv_valid: Optional[jax.Array] = None) -> jax.Array:
    """Grouped scaled-dot-product attention.
    q: (B, Sq, H, Dk); k: (B, Skv, Hkv, Dk); v: (B, Skv, Hkv, Dv).
    qpos: (Sq,) or (B, Sq); kpos: (Skv,).  kv_valid: (B,) count of valid
    cache entries (decode).  Returns (B, Sq, H, Dv)."""
    B, Sq, H, Dk = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qf = q.reshape(B, Sq, Hkv, G, Dk).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) * scale

    if qpos.ndim == 1:
        qpos = qpos[None, :]
    mask = jnp.ones((B, Sq, k.shape[1]), bool)
    if causal:
        mask &= kpos[None, None, :] <= qpos[:, :, None]
    if kv_valid is not None:
        mask &= kpos[None, None, :] < kv_valid[:, None, None]
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, v.shape[-1]).astype(v.dtype)


def _sdpa_chunked(q, k, v, qpos, kpos, *, causal, scale,
                  kv_valid=None, chunk=DEFAULT_Q_CHUNK):
    """Query-chunked SDPA: O(chunk * Skv) live scores instead of
    O(Sq * Skv) — the long-prefill memory saver."""
    B, Sq = q.shape[0], q.shape[1]
    if Sq <= chunk or Sq % chunk != 0:
        return _sdpa(q, k, v, qpos=qpos, kpos=kpos, causal=causal,
                     scale=scale, kv_valid=kv_valid)
    n = Sq // chunk
    qc = q.reshape(B, n, chunk, *q.shape[2:]).swapaxes(0, 1)
    pc = qpos.reshape(n, chunk) if qpos.ndim == 1 else \
        qpos.reshape(B, n, chunk).swapaxes(0, 1)

    def one(args):
        qi, pi = args
        return _sdpa(qi, k, v, qpos=pi, kpos=kpos, causal=causal,
                     scale=scale, kv_valid=kv_valid)

    out = jax.lax.map(one, (qc, pc))
    return out.swapaxes(0, 1).reshape(B, Sq, q.shape[2], v.shape[-1])


# ------------------------------------------------------------ GQA module
def gqa_attention(cfg, p: dict, x: jax.Array, positions: jax.Array,
                  rules: MeshRules, *,
                  cache: Optional[dict] = None,
                  cache_index: Optional[jax.Array] = None,
                  make_cache: bool = False,
                  causal: bool = True,
                  kv_input: Optional[jax.Array] = None,
                  q_chunk: int = DEFAULT_Q_CHUNK,
                  ) -> Tuple[jax.Array, Optional[dict]]:
    """Self- or cross-attention with optional KV cache.

    Modes:
      train:    cache=None, make_cache=False
      prefill:  make_cache=True -> returns cache sized to S
      decode:   cache given, cache_index = current position (B,)
      cross:    kv_input = encoder states (cache stores projected K/V)
    """
    B, S, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    kv_src = kv_input if kv_input is not None else x
    Skv_in = kv_src.shape[1]

    if cache is not None and kv_input is not None:
        # cross-attention decode: K/V were projected once at prefill
        k, v = cache["k"], cache["v"]
        new_cache = cache
    else:
        k = (kv_src @ p["wk"]).reshape(B, Skv_in, Hkv, hd)
        v = (kv_src @ p["wv"]).reshape(B, Skv_in, Hkv, hd)
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"], cfg.norm_eps)
            k = rms_norm(k, p["k_norm"], cfg.norm_eps)
        if kv_input is None:  # RoPE only for self-attention
            kv_pos = positions if cache is None else positions
            cos, sin = rope_angles(positions, hd, cfg.rope_theta)
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)
        new_cache = None
        if cache is not None:
            # decode: write this step's K/V at cache_index
            k_cache, v_cache = cache["k"], cache["v"]
            idx = cache_index  # (B,) int32 current length
            k_cache = jax.vmap(
                lambda c, u, i: jax.lax.dynamic_update_slice(
                    c, u, (i, 0, 0)))(k_cache, k, idx)
            v_cache = jax.vmap(
                lambda c, u, i: jax.lax.dynamic_update_slice(
                    c, u, (i, 0, 0)))(v_cache, v, idx)
            new_cache = {"k": k_cache, "v": v_cache}
            k, v = k_cache, v_cache
        elif make_cache:
            new_cache = {"k": k, "v": v}

    if cache is None:
        # training/prefill layout; decode keeps the cache's own sharding
        # (which may be context-parallel for long single-sequence decode)
        k = rules.constrain(k, "batch", None, "kv", None)
        v = rules.constrain(v, "batch", None, "kv", None)
        q = rules.constrain(q, "batch", None, "model", None)

    scale = 1.0 / np.sqrt(hd)
    Skv = k.shape[1]
    kpos = jnp.arange(Skv, dtype=jnp.int32)
    kv_valid = None
    if cache is not None and kv_input is None:
        kv_valid = cache_index + 1
        qpos = positions
        causal_eff = False  # masking handled by kv_valid
    else:
        qpos = positions
        causal_eff = causal and kv_input is None

    if (ATTENTION_BACKEND == "pallas" and cache is None
            and kv_input is None and kv_valid is None):
        from ..kernels.flash_attention import flash_attention
        out = flash_attention(q, k, v, causal=causal_eff, scale=scale,
                              block_q=min(128, max(8, S)),
                              block_k=min(128, max(8, k.shape[1])),
                              interpret=_FLASH_INTERPRET)
    else:
        out = _sdpa_chunked(q, k, v, qpos=qpos, kpos=kpos,
                            causal=causal_eff, scale=scale,
                            kv_valid=kv_valid, chunk=q_chunk)
    y = row_parallel_matmul(out.reshape(B, S, H * hd), p["wo"], rules)
    return y, new_cache


# ------------------------------------------------------------ MLA module
def _mla_qkv(cfg, p, x):
    B, S, _ = x.shape
    H = cfg.n_heads
    nope, rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    cq = rms_norm(x @ p["wq_a"], p["q_a_norm"], cfg.norm_eps)
    q = (cq @ p["wq_b"]).reshape(B, S, H, nope + rope)
    ckv_full = x @ p["wkv_a"]
    ckv = rms_norm(ckv_full[..., :cfg.kv_lora_rank], p["kv_a_norm"],
                   cfg.norm_eps)
    k_rope = ckv_full[..., cfg.kv_lora_rank:]
    return q, ckv, k_rope


def mla_attention(cfg, p: dict, x: jax.Array, positions: jax.Array,
                  rules: MeshRules, *,
                  cache: Optional[dict] = None,
                  cache_index: Optional[jax.Array] = None,
                  make_cache: bool = False,
                  q_chunk: int = DEFAULT_Q_CHUNK,
                  ) -> Tuple[jax.Array, Optional[dict]]:
    B, S, d = x.shape
    H = cfg.n_heads
    nope, rope_d = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    vd = cfg.v_head_dim
    scale = 1.0 / np.sqrt(nope + rope_d)

    q, ckv, k_rope = _mla_qkv(cfg, p, x)
    cos, sin = rope_angles(positions, rope_d, cfg.rope_theta)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]

    if cache is None:
        # train / prefill: expand K,V (no cache pressure), full attention
        kv = (ckv @ p["wkv_b"]).reshape(B, S, H, nope + vd)
        k_nope, v = kv[..., :nope], kv[..., nope:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (B, S, H, rope_d))], axis=-1)
        qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = _sdpa_chunked(qfull, k, v, qpos=positions,
                            kpos=jnp.arange(S, dtype=jnp.int32),
                            causal=True, scale=scale, chunk=q_chunk)
        y = row_parallel_matmul(out.reshape(B, S, H * vd), p["wo"], rules)
        new_cache = {"ckv": ckv, "k_rope": k_rope} if make_cache else None
        return y, new_cache

    # ---------------- absorbed decode: cache is compressed (c_kv, k_rope)
    idx = cache_index  # (B,)
    ckv_c = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
        c, u, (i, 0)))(cache["ckv"], ckv, idx)
    krope_c = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
        c, u, (i, 0)))(cache["k_rope"], k_rope, idx)
    new_cache = {"ckv": ckv_c, "k_rope": krope_c}

    wkv_b = p["wkv_b"].reshape(cfg.kv_lora_rank, H, nope + vd)
    w_uk = wkv_b[..., :nope]           # (r, H, nope)
    w_uv = wkv_b[..., nope:]           # (r, H, vd)
    # absorb W_uk into q: q_c (B, S=1, H, r)
    q_c = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32),
                     w_uk.astype(jnp.float32))
    Skv = ckv_c.shape[1]
    kpos = jnp.arange(Skv, dtype=jnp.int32)
    valid = (idx + 1)[:, None, None, None]
    scores = (jnp.einsum("bshr,bkr->bhsk", q_c,
                         ckv_c.astype(jnp.float32))
              + jnp.einsum("bshr,bkr->bhsk", q_rope.astype(jnp.float32),
                           krope_c.astype(jnp.float32))) * scale
    mask = kpos[None, None, None, :] < valid
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    o_c = jnp.einsum("bhsk,bkr->bshr", w, ckv_c.astype(jnp.float32))
    out = jnp.einsum("bshr,rhv->bshv", o_c, w_uv.astype(jnp.float32))
    out = out.astype(x.dtype).reshape(B, S, H * vd)
    y = row_parallel_matmul(out, p["wo"], rules)
    return y, new_cache
