"""Model assembly for the five assigned families.

Layers are *stacked* (leading L dim) and driven by ``lax.scan`` — one
compiled block body per homogeneous group regardless of depth (61-layer
DeepSeek compiles as fast as 2-layer smoke).  Heterogeneous stacks
(DeepSeek's 3 dense + 58 MoE layers; Zamba2's shared attention block
every 6 Mamba layers) are expressed as segments of scans.

``Model`` exposes:
  init(key)            real parameters (smoke tests / small training)
  abstract()           ShapeDtypeStruct pytree with shardings (dry-run)
  train_logits(...)    full-sequence logits (+ aux losses)
  prefill(...)         logits of last position + serving cache
  decode(...)          one-token step with cache
  abstract_cache(...)  ShapeDtypeStruct cache for serve-step dry-runs
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import gqa_attention, make_attn_params, make_mla_params, \
    mla_attention
from .layers import Maker, apply_norm, make_mlp_params, mlp
from .moe import make_moe_params, moe_block
from .sharding import MeshRules, NO_MESH
from .ssm import make_mamba_params, mamba_block


# Layer-scan unrolling.  False (default): compact while-loop programs —
# fastest compiles, but XLA's cost_analysis counts loop bodies ONCE.
# The dry-run sets this True so FLOPs/bytes/collective counts in the
# roofline reflect every layer.
SCAN_UNROLL = False


class _Stacked:
    """Maker proxy that prepends the layer dimension to every param."""

    def __init__(self, base: Maker, n: int):
        self._base = base
        self._n = n

    def param(self, shape, logical, **kw):
        return self._base.param((self._n,) + tuple(shape),
                                (None,) + tuple(logical), **kw)

    def ones(self, shape, logical, **kw):
        return self._base.ones((self._n,) + tuple(shape),
                               (None,) + tuple(logical), **kw)


def _dtype_of(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ==========================================================================
# parameter construction
# ==========================================================================
def _attn_block_params(mk, cfg, ff: Optional[int] = None,
                       moe: bool = False, cross: bool = False) -> dict:
    p: Dict[str, Any] = {}
    if not cfg.nonparametric_ln:
        p["ln1"] = mk.ones((cfg.d_model,), (None,))
        p["ln2"] = mk.ones((cfg.d_model,), (None,))
    p["attn"] = (make_mla_params(mk, cfg) if cfg.use_mla
                 else make_attn_params(mk, cfg))
    if cross:
        if not cfg.nonparametric_ln:
            p["ln_cross"] = mk.ones((cfg.d_model,), (None,))
        p["cross"] = make_attn_params(mk, cfg)
    if moe:
        p["moe"] = make_moe_params(mk, cfg)
    else:
        p["mlp"] = make_mlp_params(mk, cfg.d_model, ff or cfg.d_ff)
    return p


def _mamba_block_params(mk, cfg) -> dict:
    return {
        "ln": mk.ones((cfg.d_model,), (None,)),
        "mixer": make_mamba_params(mk, cfg),
    }


def build_params(cfg, mode: str, rules: MeshRules,
                 key: Optional[jax.Array] = None) -> dict:
    mk = Maker(mode, rules, _dtype_of(cfg), key)
    p: Dict[str, Any] = {
        "embed": mk.param((cfg.vocab_size, cfg.d_model), ("model", "embed"),
                          scale=0.02),
        "final_norm": mk.ones((cfg.d_model,), (None,)),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = mk.param((cfg.d_model, cfg.vocab_size),
                                ("embed", "model"))

    fam = cfg.family
    if fam == "dense":
        p["blocks"] = _attn_block_params(_Stacked(mk, cfg.n_layers), cfg)
    elif fam == "moe":
        nd = cfg.n_dense_layers
        if nd:
            p["dense_blocks"] = _attn_block_params(_Stacked(mk, nd), cfg)
        p["moe_blocks"] = _attn_block_params(
            _Stacked(mk, cfg.n_layers - nd), cfg, moe=True)
        if cfg.mtp:
            p["mtp_block"] = _attn_block_params(mk, cfg)
            p["mtp_norm"] = mk.ones((cfg.d_model,), (None,))
    elif fam == "ssm":
        p["blocks"] = _mamba_block_params(_Stacked(mk, cfg.n_layers), cfg)
    elif fam == "hybrid":
        p["blocks"] = _mamba_block_params(_Stacked(mk, cfg.n_layers), cfg)
        p["shared_attn"] = _attn_block_params(mk, cfg)  # ONE shared block
    elif fam == "encdec":
        p["enc_blocks"] = _attn_block_params(
            _Stacked(mk, cfg.n_encoder_layers), cfg)
        p["dec_blocks"] = _attn_block_params(
            _Stacked(mk, cfg.n_layers), cfg, cross=True)
        p["enc_norm"] = mk.ones((cfg.d_model,), (None,))
    else:
        raise ValueError(f"unknown family {fam}")
    return p


# ==========================================================================
# block applications
# ==========================================================================
def _attn_block(cfg, rules, p, x, positions, *, cache=None, cache_index=None,
                make_cache=False, causal=True, enc_out=None, q_chunk=1024):
    h = apply_norm(cfg, x, p.get("ln1"))
    if cfg.use_mla:
        a, new_cache = mla_attention(cfg, p["attn"], h, positions, rules,
                                     cache=cache, cache_index=cache_index,
                                     make_cache=make_cache, q_chunk=q_chunk)
    else:
        a, new_cache = gqa_attention(cfg, p["attn"], h, positions, rules,
                                     cache=cache, cache_index=cache_index,
                                     make_cache=make_cache, causal=causal,
                                     q_chunk=q_chunk)
    x = x + a
    aux = {}
    if "cross" in p:
        h = apply_norm(cfg, x, p.get("ln_cross"))
        if enc_out is not None:  # train / prefill: project encoder K,V
            c, cross_cache = gqa_attention(
                cfg, p["cross"], h, positions, rules,
                make_cache=make_cache, causal=False, kv_input=enc_out)
            if make_cache:
                new_cache = dict(new_cache or {})
                new_cache["cross_k"] = cross_cache["k"]
                new_cache["cross_v"] = cross_cache["v"]
        else:  # decode: K,V were projected once at prefill
            cc = {"k": cache["cross_k"], "v": cache["cross_v"]}
            c, _ = gqa_attention(
                cfg, p["cross"], h, positions, rules, cache=cc,
                causal=False, kv_input=h)  # kv_input= sentinel: use cache
            new_cache = dict(new_cache or {})
            new_cache["cross_k"] = cache["cross_k"]
            new_cache["cross_v"] = cache["cross_v"]
        x = x + c
    h = apply_norm(cfg, x, p.get("ln2"))
    if "moe" in p:
        m, aux = moe_block(cfg, p["moe"], h, rules)
    else:
        m = mlp(cfg, p["mlp"], h, rules)
    x = x + m
    x = rules.constrain(x, "batch", "seq", None)
    return x, new_cache, aux


def _mamba_block_apply(cfg, rules, p, x, *, state=None, make_state=False):
    h = apply_norm(cfg, x, p.get("ln"))
    y, new_state = mamba_block(cfg, p["mixer"], h, rules, state=state,
                               make_state=make_state)
    x = x + y
    x = rules.constrain(x, "batch", "seq", None)
    return x, new_state


def _scan_blocks(cfg, rules, stacked, x, positions, *, kind,
                 caches=None, cache_index=None, make_cache=False,
                 causal=True, enc_out=None, remat=False, q_chunk=1024):
    """Scan a homogeneous stacked group over the layer dim.  Returns
    (x, new_caches_stacked, aux_summed)."""

    def body(carry, layer_in):
        xc = carry
        lp = layer_in["p"]
        lcache = layer_in.get("cache")
        if kind == "attn":
            xc, ncache, aux = _attn_block(
                cfg, rules, lp, xc, positions, cache=lcache,
                cache_index=cache_index, make_cache=make_cache,
                causal=causal, enc_out=enc_out, q_chunk=q_chunk)
            aux_vec = jnp.stack(
                [aux.get("moe_aux_loss", jnp.float32(0.0)),
                 aux.get("moe_drop_frac", jnp.float32(0.0))])
            return xc, {"cache": ncache, "aux": aux_vec}
        else:
            xc, nstate = _mamba_block_apply(cfg, rules, lp, xc, state=lcache,
                                            make_state=make_cache)
            return xc, {"cache": nstate}

    if remat:
        body = jax.checkpoint(body)
    xs: Dict[str, Any] = {"p": stacked}
    if caches is not None:
        xs["cache"] = caches
    x, ys = jax.lax.scan(body, x, xs, unroll=SCAN_UNROLL)
    new_caches = ys.get("cache")
    aux = {}
    if kind == "attn" and "aux" in ys:
        s = jnp.sum(ys["aux"], axis=0)
        aux = {"moe_aux_loss": s[0], "moe_drop_frac": s[1]}
    return x, new_caches, aux


# ==========================================================================
# the Model facade
# ==========================================================================
@dataclasses.dataclass
class Model:
    cfg: Any
    rules: MeshRules = NO_MESH

    # ------------------------------------------------------------ params
    def init(self, key) -> dict:
        return build_params(self.cfg, "init", self.rules, key)

    def abstract(self) -> dict:
        return build_params(self.cfg, "abstract", self.rules)

    # ------------------------------------------------------------ embed
    def _embed(self, params, tokens=None, embeds=None):
        if embeds is not None:
            return embeds.astype(_dtype_of(self.cfg))
        return jnp.take(params["embed"], tokens, axis=0)

    def head_matrix(self, params) -> jax.Array:
        """(d_model, vocab) unembedding matrix."""
        return (params["embed"].T if self.cfg.tie_embeddings
                else params["lm_head"])

    def _logits(self, params, x):
        x = apply_norm(self.cfg, x, params["final_norm"])
        head = self.head_matrix(params)
        logits = jnp.dot(x, head.astype(x.dtype),
                         preferred_element_type=jnp.float32)
        return self.rules.constrain(logits, "batch", "seq", "model")

    def _encode(self, params, enc_embeds, remat):
        cfg, rules = self.cfg, self.rules
        pos = jnp.arange(enc_embeds.shape[1], dtype=jnp.int32)
        x = enc_embeds.astype(_dtype_of(cfg))
        x, _, _ = _scan_blocks(cfg, rules, params["enc_blocks"], x, pos,
                               kind="attn", causal=False, remat=remat)
        return apply_norm(cfg, x, params["enc_norm"])

    # ----------------------------------------------------------- forward
    def train_logits(self, params, *, tokens=None, embeds=None,
                     enc_embeds=None, return_hidden: bool = False
                     ) -> Tuple[jax.Array, dict]:
        """Full-sequence logits.  Returns (logits, aux); with
        ``return_hidden`` returns the final-norm hidden states instead
        (aux carries ``mtp_hidden``) so the caller can compute a
        CHUNKED cross-entropy without materializing (B, S, V) logits."""
        cfg, rules = self.cfg, self.rules
        x = self._embed(params, tokens, embeds)
        x = rules.constrain(x, "batch", "seq", None)
        S = x.shape[1]
        pos = jnp.arange(S, dtype=jnp.int32)
        remat = cfg.remat
        aux: Dict[str, jax.Array] = {}

        if cfg.family == "dense":
            x, _, _ = _scan_blocks(cfg, rules, params["blocks"], x, pos,
                                   kind="attn", remat=remat)
        elif cfg.family == "moe":
            if cfg.n_dense_layers:
                x, _, _ = _scan_blocks(cfg, rules, params["dense_blocks"], x,
                                       pos, kind="attn", remat=remat)
            x, _, aux = _scan_blocks(cfg, rules, params["moe_blocks"], x, pos,
                                     kind="attn", remat=remat)
            if cfg.mtp:
                xm, _, _ = _attn_block(cfg, rules, params["mtp_block"],
                                       apply_norm(cfg, x, params["mtp_norm"]),
                                       pos)
                aux = dict(aux)
                aux["mtp_hidden"] = xm
        elif cfg.family == "ssm":
            x, _, _ = _scan_blocks(cfg, rules, params["blocks"], x, pos,
                                   kind="mamba", remat=remat)
        elif cfg.family == "hybrid":
            x = self._hybrid_stack(params, x, pos, remat=remat)
        elif cfg.family == "encdec":
            enc = self._encode(params, enc_embeds, remat)
            x, _, _ = _scan_blocks(cfg, rules, params["dec_blocks"], x, pos,
                                   kind="attn", enc_out=enc, remat=remat)
        if return_hidden:
            xh = apply_norm(cfg, x, params["final_norm"])
            if cfg.family == "moe" and cfg.mtp and "mtp_hidden" in aux:
                aux = dict(aux)
                aux["mtp_hidden"] = apply_norm(cfg, aux["mtp_hidden"],
                                               params["final_norm"])
            return xh, aux
        logits = self._logits(params, x)
        if cfg.family == "moe" and cfg.mtp and "mtp_hidden" in aux:
            aux["mtp_logits"] = self._logits(params, aux.pop("mtp_hidden"))
        return logits, aux

    def _hybrid_stack(self, params, x, pos, *, remat, caches=None,
                      cache_index=None, make_cache=False):
        """Zamba2: segments of ``attn_every`` Mamba layers, each followed
        by THE shared attention block (weights reused; caches distinct)."""
        cfg, rules = self.cfg, self.rules
        period = cfg.attn_every
        n_seg = cfg.n_layers // period
        new_mamba, new_attn = [], []
        for s in range(n_seg):
            seg = jax.tree.map(lambda a: a[s * period:(s + 1) * period],
                               params["blocks"])
            seg_cache = None
            if caches is not None:
                seg_cache = jax.tree.map(
                    lambda a: a[s * period:(s + 1) * period],
                    caches["mamba"])
            x, nm, _ = _scan_blocks(cfg, rules, seg, x, pos, kind="mamba",
                                    caches=seg_cache, make_cache=make_cache,
                                    cache_index=cache_index, remat=remat)
            a_cache = (jax.tree.map(lambda a: a[s], caches["attn"])
                       if caches is not None else None)
            x, na, _ = _attn_block(cfg, rules, params["shared_attn"], x, pos,
                                   cache=a_cache, cache_index=cache_index,
                                   make_cache=make_cache)
            if nm is not None:
                new_mamba.append(nm)
            if na is not None:
                new_attn.append(na)
        rem = cfg.n_layers - n_seg * period
        if rem:
            seg = jax.tree.map(lambda a: a[-rem:], params["blocks"])
            seg_cache = (jax.tree.map(lambda a: a[-rem:], caches["mamba"])
                         if caches is not None else None)
            x, nm, _ = _scan_blocks(cfg, rules, seg, x, pos, kind="mamba",
                                    caches=seg_cache, make_cache=make_cache,
                                    cache_index=cache_index, remat=remat)
            if nm is not None:
                new_mamba.append(nm)
        if make_cache or caches is not None:
            cat = lambda parts: jax.tree.map(
                lambda *xs: jnp.concatenate(xs, 0), *parts)
            stk = lambda parts: jax.tree.map(
                lambda *xs: jnp.stack(xs, 0), *parts)
            self._last_hybrid_cache = {
                "mamba": cat(new_mamba), "attn": stk(new_attn)}
        return x

    # ----------------------------------------------------------- serving
    @staticmethod
    def pad_cache(cache: dict, pad_to: int) -> dict:
        """Grow prompt-sized KV caches to the serving max length (the
        sequence axis is axis 2 for k/v/ckv/k_rope leaves; SSM states and
        cross-attention K/V are length-free)."""
        def pad(path, leaf):
            name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
            if name in ("k", "v", "ckv", "k_rope"):
                s = leaf.shape[2]
                if s < pad_to:
                    widths = [(0, 0)] * leaf.ndim
                    widths[2] = (0, pad_to - s)
                    return jnp.pad(leaf, widths)
            return leaf
        return jax.tree_util.tree_map_with_path(pad, cache)

    def prefill(self, params, *, tokens=None, embeds=None, enc_embeds=None
                ) -> Tuple[jax.Array, dict]:
        """Process the prompt; return (last-position logits, cache)."""
        cfg, rules = self.cfg, self.rules
        x = self._embed(params, tokens, embeds)
        S = x.shape[1]
        pos = jnp.arange(S, dtype=jnp.int32)
        cache: Dict[str, Any] = {}
        if cfg.family in ("dense", "moe"):
            if cfg.family == "dense":
                x, kv, _ = _scan_blocks(cfg, rules, params["blocks"], x, pos,
                                        kind="attn", make_cache=True)
                cache["blocks"] = kv
            else:
                if cfg.n_dense_layers:
                    x, kvd, _ = _scan_blocks(cfg, rules,
                                             params["dense_blocks"], x, pos,
                                             kind="attn", make_cache=True)
                    cache["dense_blocks"] = kvd
                x, kvm, _ = _scan_blocks(cfg, rules, params["moe_blocks"], x,
                                         pos, kind="attn", make_cache=True)
                cache["moe_blocks"] = kvm
        elif cfg.family == "ssm":
            x, st, _ = _scan_blocks(cfg, rules, params["blocks"], x, pos,
                                    kind="mamba", make_cache=True)
            cache["blocks"] = st
        elif cfg.family == "hybrid":
            x = self._hybrid_stack(params, x, pos, remat=False,
                                   make_cache=True)
            cache = self._last_hybrid_cache
        elif cfg.family == "encdec":
            enc = self._encode(params, enc_embeds, False)
            x, kv, _ = _scan_blocks(cfg, rules, params["dec_blocks"], x, pos,
                                    kind="attn", enc_out=enc,
                                    make_cache=True)
            cache["dec_blocks"] = kv
        logits = self._logits(params, x[:, -1:, :])
        return logits, cache

    def decode(self, params, cache: dict, token: jax.Array,
               pos_index: jax.Array) -> Tuple[jax.Array, dict]:
        """One decode step.  token: (B,) int32; pos_index: (B,) int32
        (number of tokens already in the cache)."""
        cfg, rules = self.cfg, self.rules
        x = jnp.take(params["embed"], token[:, None], axis=0)
        positions = pos_index[:, None]
        new_cache: Dict[str, Any] = {}
        if cfg.family == "dense":
            x, kv, _ = _scan_blocks(cfg, rules, params["blocks"], x,
                                    positions, kind="attn",
                                    caches=cache["blocks"],
                                    cache_index=pos_index)
            new_cache["blocks"] = kv
        elif cfg.family == "moe":
            if cfg.n_dense_layers:
                x, kvd, _ = _scan_blocks(cfg, rules, params["dense_blocks"],
                                         x, positions, kind="attn",
                                         caches=cache["dense_blocks"],
                                         cache_index=pos_index)
                new_cache["dense_blocks"] = kvd
            x, kvm, _ = _scan_blocks(cfg, rules, params["moe_blocks"], x,
                                     positions, kind="attn",
                                     caches=cache["moe_blocks"],
                                     cache_index=pos_index)
            new_cache["moe_blocks"] = kvm
        elif cfg.family == "ssm":
            x, st, _ = _scan_blocks(cfg, rules, params["blocks"], x,
                                    positions, kind="mamba",
                                    caches=cache["blocks"],
                                    cache_index=pos_index)
            new_cache["blocks"] = st
        elif cfg.family == "hybrid":
            x = self._hybrid_stack(params, x, positions, remat=False,
                                   caches=cache, cache_index=pos_index)
            new_cache = self._last_hybrid_cache
        elif cfg.family == "encdec":
            x, kv, _ = _scan_blocks(cfg, rules, params["dec_blocks"], x,
                                    positions, kind="attn",
                                    caches=cache["dec_blocks"],
                                    cache_index=pos_index,
                                    enc_out=None)
            new_cache["dec_blocks"] = kv
        logits = self._logits(params, x)
        return logits[:, 0, :], new_cache

    # ------------------------------------------------- abstract cache
    def abstract_cache(self, batch: int, max_len: int,
                       enc_len: Optional[int] = None) -> dict:
        """ShapeDtypeStruct cache tree for serve-step dry-runs."""
        cfg = self.cfg
        dt = _dtype_of(cfg)
        rules = self.rules

        def sds(shape, *logical):
            sh = (rules.fitted_sharding(shape, *logical)
                  if rules.mesh is not None else None)
            if sh is not None:
                return jax.ShapeDtypeStruct(shape, dt, sharding=sh)
            return jax.ShapeDtypeStruct(shape, dt)

        hd = cfg.resolved_head_dim
        Hkv = cfg.n_kv_heads
        model_n = rules.axis_size(rules.model_axis)
        batch_ok = rules.batch_size_divides(batch)
        # long-context single-sequence decode: shard the cache SEQ axis
        # over 'data' (context parallelism) instead of the batch axis
        b_ax = "batch" if batch_ok else None
        s_ax = None if batch_ok else "seq"
        if not batch_ok:
            rules = dataclasses.replace(rules, seq_axis=rules.fsdp_axis)
        # TP placement inside the cache: kv-heads if divisible, else
        # head_dim (both contract cleanly in the attention einsum)
        if Hkv and Hkv % model_n == 0:
            h_ax, d_ax = "kv", None
        elif hd and hd % model_n == 0:
            h_ax, d_ax = None, "model"
        else:
            h_ax, d_ax = None, None

        def kv_cache(L):
            return {"k": sds((L, batch, max_len, Hkv, hd),
                             None, b_ax, s_ax, h_ax, d_ax),
                    "v": sds((L, batch, max_len, Hkv, hd),
                             None, b_ax, s_ax, h_ax, d_ax)}

        def mla_cache(L):
            r_ax = "model" if cfg.kv_lora_rank % model_n == 0 else None
            return {"ckv": sds((L, batch, max_len, cfg.kv_lora_rank),
                               None, b_ax, s_ax, r_ax),
                    "k_rope": sds((L, batch, max_len, cfg.qk_rope_head_dim),
                                  None, b_ax, s_ax, None)}

        def mamba_state(L):
            conv_dim = cfg.d_inner + 2 * cfg.ssm_state
            c_ax = "model" if conv_dim % model_n == 0 else None
            h_ax2 = "model" if cfg.ssm_heads % model_n == 0 else None
            return {
                "conv": sds((L, batch, cfg.ssm_conv - 1, conv_dim),
                            None, b_ax, None, c_ax),
                "ssm": jax.ShapeDtypeStruct(
                    (L, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                     cfg.ssm_state), jnp.float32,
                    sharding=rules.fitted_sharding(
                        (L, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                         cfg.ssm_state), None, b_ax, h_ax2, None, None)
                    if rules.mesh is not None else None),
            }

        if cfg.family == "dense":
            return {"blocks": (mla_cache(cfg.n_layers) if cfg.use_mla
                               else kv_cache(cfg.n_layers))}
        if cfg.family == "moe":
            mkc = mla_cache if cfg.use_mla else kv_cache
            out = {"moe_blocks": mkc(cfg.n_layers - cfg.n_dense_layers)}
            if cfg.n_dense_layers:
                out["dense_blocks"] = mkc(cfg.n_dense_layers)
            return out
        if cfg.family == "ssm":
            return {"blocks": mamba_state(cfg.n_layers)}
        if cfg.family == "hybrid":
            n_seg = cfg.n_layers // cfg.attn_every
            return {"mamba": mamba_state(cfg.n_layers),
                    "attn": kv_cache(n_seg)}
        if cfg.family == "encdec":
            c = kv_cache(cfg.n_layers)
            c["cross_k"] = sds((cfg.n_layers, batch, enc_len or max_len,
                                Hkv, hd), None, b_ax, None, h_ax, d_ax)
            c["cross_v"] = sds((cfg.n_layers, batch, enc_len or max_len,
                                Hkv, hd), None, b_ax, None, h_ax, d_ax)
            return {"dec_blocks": c}
        raise ValueError(cfg.family)
