"""Mixture-of-Experts with capacity-based scatter dispatch.

Design notes (also in DESIGN.md §Arch-applicability): expert FFNs are
batched tile GEMMs — the closest LM analogue of the paper's variable-
workload task pool.  Dispatch is exact-topk with a fixed per-expert
capacity C = ceil(tokens * top_k * capacity_factor / E): tokens beyond
capacity are dropped (standard GShard semantics).  The (E, C, d)
buffers shard E over the "model" axis (expert parallelism); GSPMD
materializes the all-to-all at the scatter/gather boundaries.

FLOP cost scales with top_k (not n_experts) — crucial for an honest
roofline on the 256-expert DeepSeek config.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from .layers import ACT, Maker
from .sharding import MeshRules


def make_moe_params(mk: Maker, cfg) -> dict:
    d, E, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    p = {
        "router": mk.param((d, E), ("embed", None), dtype=jnp.float32),
        "w_gate": mk.param((E, d, ff), ("expert", "embed", None)),
        "w_up": mk.param((E, d, ff), ("expert", "embed", None)),
        "w_down": mk.param((E, ff, d), ("expert", None, "embed")),
    }
    if cfg.n_shared_experts:
        sff = cfg.moe_d_ff * cfg.n_shared_experts
        p["shared"] = {
            "wg": mk.param((d, sff), ("embed", "model")),
            "wi": mk.param((d, sff), ("embed", "model")),
            "wo": mk.param((sff, d), ("model", "embed")),
        }
    return p


def _positions_in_expert(flat_e: jax.Array, n_experts: int) -> jax.Array:
    """Rank of each (token, slot) within its expert via stable argsort —
    the slot index into the expert's capacity buffer."""
    n = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # index within each expert segment
    idx = jnp.arange(n, dtype=jnp.int32)
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(n_experts,
                                                      dtype=flat_e.dtype))
    pos_sorted = idx - seg_start[sorted_e]
    inv = jnp.argsort(order, stable=True)
    return pos_sorted[inv]


# Toggle for the §Perf hillclimb: expert-local dispatch (shard_map) vs
# the baseline global scatter.  The baseline lets GSPMD materialize and
# all-reduce the (E*C, d) buffer per layer; the sharded path keeps the
# dispatch entirely device-local (tokens are replicated across the
# model axis, experts are sharded over it) and pays ONE activation-sized
# psum per layer.
SHARDED_DISPATCH = True


def moe_block(cfg, p: dict, x: jax.Array, rules: MeshRules,
              ) -> Tuple[jax.Array, dict]:
    """x: (B, S, d) -> (y, aux) with load-balance metrics in aux."""
    E, K = cfg.n_experts, cfg.top_k
    model_n = rules.axis_size(rules.model_axis)
    if (SHARDED_DISPATCH and rules.mesh is not None and model_n > 1
            and E % model_n == 0):
        return _moe_block_sharded(cfg, p, x, rules)
    return _moe_block_dense(cfg, p, x, rules)


def _router(cfg, p, xt):
    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_idx = jax.lax.top_k(probs, cfg.top_k)
    gate_w = gate_w / (jnp.sum(gate_w, axis=-1, keepdims=True) + 1e-9)
    return probs, gate_w, gate_idx


def _aux(cfg, probs, gate_idx, keep):
    E, K = cfg.n_experts, cfg.top_k
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(gate_idx, E, dtype=jnp.float32).sum(1),
                  axis=0)
    return {"moe_aux_loss": E * jnp.sum(me * ce) / K,
            "moe_drop_frac": 1.0 - jnp.mean(keep.astype(jnp.float32))}


def _shared_expert(cfg, p, xt):
    act = ACT[cfg.act]
    sp = p["shared"]
    return (act(xt @ sp["wg"]) * (xt @ sp["wi"])) @ sp["wo"]


def _moe_block_sharded(cfg, p: dict, x: jax.Array, rules: MeshRules,
                       ) -> Tuple[jax.Array, dict]:
    """Expert-parallel dispatch with zero cross-device data movement for
    the token buffers: every model-column holds the full (data-sharded)
    token block, scatters locally into ITS E/model_n experts' capacity
    buffers, computes, and contributes a partial (N_local, d) output —
    combined by a single psum over the model axis."""
    from jax.sharding import PartitionSpec as P

    from ..kernels.pallas_compat import shard_map

    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    N = B * S
    xt = x.reshape(N, d)
    probs, gate_w, gate_idx = _router(cfg, p, xt)

    model_ax = rules.model_axis
    model_n = rules.axis_size(model_ax)
    batch_phys = rules.physical("batch")
    data_n = rules.axis_size(batch_phys)
    n_local = N // data_n if N % data_n == 0 else N
    dspec = batch_phys if N % data_n == 0 else None
    C = int(math.ceil((n_local if dspec else N) * K
                      * cfg.capacity_factor / E))
    C = max(1, C)
    e_local = E // model_n

    def body(xl, gw, gi, w_gate, w_up, w_down):
        # xl: (n_loc, d) — replicated across the model axis
        # w_*: (e_local, ...) — this column's experts
        m_idx = jax.lax.axis_index(model_ax)
        lo = m_idx * e_local
        flat_e = gi.reshape(-1)
        pos = _positions_in_expert(flat_e, E)
        mine = (flat_e >= lo) & (flat_e < lo + e_local)
        keep = (pos < C) & mine
        local_e = jnp.where(mine, flat_e - lo, 0)
        dest = jnp.where(keep, local_e * C + pos, e_local * C)
        x_rep = jnp.repeat(xl, K, axis=0)
        buf = jnp.zeros((e_local * C, xl.shape[1]), xl.dtype
                        ).at[dest].add(x_rep, mode="drop")
        buf = buf.reshape(e_local, C, xl.shape[1])
        act = ACT[cfg.act]
        h = act(jnp.einsum("ecd,edf->ecf", buf, w_gate)) * \
            jnp.einsum("ecd,edf->ecf", buf, w_up)
        out_buf = jnp.einsum("ecf,efd->ecd", h, w_down).reshape(
            e_local * C, xl.shape[1])
        gathered = jnp.where(
            keep[:, None], out_buf[jnp.minimum(dest, e_local * C - 1)], 0.0)
        y = jnp.sum((gathered * gw.reshape(-1)[:, None]
                     ).reshape(-1, K, xl.shape[1]), axis=1)
        return jax.lax.psum(y.astype(xl.dtype), model_ax)

    fn = shard_map(
        body, mesh=rules.mesh,
        in_specs=(P(dspec, None), P(dspec, None), P(dspec, None),
                  P(model_ax, None, None), P(model_ax, None, None),
                  P(model_ax, None, None)),
        out_specs=P(dspec, None),
        check_rep=False,
    )
    y = fn(xt, gate_w, gate_idx, p["w_gate"], p["w_up"], p["w_down"])
    if cfg.n_shared_experts:
        y = y + _shared_expert(cfg, p, xt)
    # aux computed on the replicated router outputs (keep == capacity
    # estimate only; exact drop accounting lives in the sharded body)
    pos = _positions_in_expert(gate_idx.reshape(-1), E)
    aux = _aux(cfg, probs, gate_idx, pos < C * model_n)
    return y.reshape(B, S, d).astype(x.dtype), aux


def _moe_block_dense(cfg, p: dict, x: jax.Array, rules: MeshRules,
                     ) -> Tuple[jax.Array, dict]:
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    N = B * S
    xt = x.reshape(N, d)

    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_idx = jax.lax.top_k(probs, K)              # (N, K)
    gate_w = gate_w / (jnp.sum(gate_w, axis=-1, keepdims=True) + 1e-9)

    C = int(math.ceil(N * K * cfg.capacity_factor / E))
    C = max(1, min(C, N))

    flat_e = gate_idx.reshape(-1)                            # (N*K,)
    pos = _positions_in_expert(flat_e, E)                    # (N*K,)
    keep = pos < C
    dest = jnp.where(keep, flat_e * C + pos, E * C)          # OOB -> dropped

    x_rep = jnp.repeat(xt, K, axis=0)                        # (N*K, d)
    buf = jnp.zeros((E * C, d), x.dtype).at[dest].add(
        x_rep, mode="drop")
    buf = buf.reshape(E, C, d)
    buf = rules.constrain(buf, "expert", None, None)

    act = ACT[cfg.act]
    h = act(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    out_buf = rules.constrain(out_buf, "expert", None, None)

    flat_out = out_buf.reshape(E * C, d)
    gathered = jnp.where(keep[:, None], flat_out[jnp.minimum(dest, E * C - 1)],
                         0.0)
    y = jnp.sum(
        (gathered * gate_w.reshape(-1)[:, None]).reshape(N, K, d), axis=1)

    if cfg.n_shared_experts:
        sp = p["shared"]
        y = y + (act(xt @ sp["wg"]) * (xt @ sp["wi"])) @ sp["wo"]

    # aux: GShard load-balance loss + stats
    me = jnp.mean(probs, axis=0)                             # (E,)
    ce = jnp.mean(
        jax.nn.one_hot(gate_idx, E, dtype=jnp.float32).sum(1), axis=0)
    aux_loss = E * jnp.sum(me * ce) / K
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    return y.reshape(B, S, d).astype(x.dtype), {"moe_aux_loss": aux_loss,
                                                "moe_drop_frac": dropped}
