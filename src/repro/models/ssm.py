"""Mamba2 (SSD — state-space duality) blocks, chunked, plus O(1) decode.

The SSD recurrence per head (state N = ssm_state, head dim P):
    h_t = a_t * h_{t-1} + (dt_t * x_t) outer B_t        a_t = exp(-exp(A_log) dt_t)
    y_t = C_t . h_t + D * x_t

Chunked algorithm (the duality): within a chunk the output is an
attention-like quadratic form with decay mask; across chunks only the
(H, P, N) boundary states are carried by a short scan — this is what
makes 500k-token contexts O(S) compute / O(1) cache, and why the
``long_500k`` shape runs for the SSM/hybrid archs only.

Tile-engine connection (DESIGN.md §Arch-applicability): the intra-chunk
quadratic forms are exactly BLASX-shaped tile GEMMs; the inter-chunk
recurrence is a scan outside the tile algebra.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import Maker, rms_norm
from .sharding import MeshRules


def make_mamba_params(mk: Maker, cfg) -> dict:
    d = cfg.d_model
    di = cfg.d_inner
    ds = cfg.ssm_state
    H = cfg.ssm_heads
    conv_dim = di + 2 * ds
    return {
        # order: [z (di), x (di), B (ds), C (ds), dt (H)]
        "in_proj": mk.param((d, 2 * di + 2 * ds + H), ("embed", "model")),
        "conv_w": mk.param((cfg.ssm_conv, conv_dim), (None, "model"),
                           scale=0.5),
        "conv_b": mk.param((conv_dim,), ("model",), zeros=True),
        "A_log": mk.ones((H,), (None,), dtype=jnp.float32),
        "D": mk.ones((H,), (None,), dtype=jnp.float32),
        "dt_bias": mk.param((H,), (None,), zeros=True, dtype=jnp.float32),
        "norm": mk.ones((di,), ("model",)),
        "out_proj": mk.param((di, d), ("model", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d.  x: (B, S, C); w: (K, C).
    state: (B, K-1, C) carry for decode.  Returns (y, new_state)."""
    B, S, Cdim = x.shape
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((B, K - 1, Cdim), x.dtype)
    xx = jnp.concatenate([state, x], axis=1)          # (B, K-1+S, C)
    y = sum(xx[:, i:i + S, :] * w[i][None, None, :] for i in range(K))
    y = y + b[None, None, :]
    new_state = xx[:, -(K - 1):, :] if K > 1 else state
    return jax.nn.silu(y), new_state


def _ssd_chunked(xh, dt, a_log, Bmat, Cmat, chunk: int):
    """Chunked SSD scan.
    xh: (B, S, H, P); dt: (B, S, H); Bmat/Cmat: (B, S, N).
    Returns y: (B, S, H, P) and final state (B, H, P, N)."""
    Bsz, S, H, P = xh.shape
    N = Bmat.shape[-1]
    nc = S // chunk
    assert S % chunk == 0, (S, chunk)

    f32 = jnp.float32
    lg_a = (-jnp.exp(a_log.astype(f32))[None, None, :]
            * dt.astype(f32))                         # (B, S, H) log decay
    xdt = xh.astype(f32) * dt.astype(f32)[..., None]  # (B, S, H, P)

    def r(t, shape):  # chunked reshape helper
        return t.reshape(shape)

    lg = r(lg_a, (Bsz, nc, chunk, H))
    xc = r(xdt, (Bsz, nc, chunk, H, P))
    Bc = r(Bmat.astype(f32), (Bsz, nc, chunk, N))
    Cc = r(Cmat.astype(f32), (Bsz, nc, chunk, N))

    csum = jnp.cumsum(lg, axis=2)                     # (B, nc, L, H)
    # ----- intra-chunk (quadratic "attention" with decay mask)
    scores = jnp.einsum("bcln,bcsn->bcls", Cc, Bc)    # (B, nc, L, L)
    li = csum[:, :, :, None, :] - csum[:, :, None, :, :]   # (B,nc,L,S,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(li), 0.0)
    y_intra = jnp.einsum("bcls,bclsh,bcshp->bclhp", scores, decay, xc)

    # ----- chunk boundary states
    tail = csum[:, :, -1:, :] - csum                  # exp(l_end - l_s)
    st = jnp.einsum("bcsh,bcsn,bcshp->bchpn",
                    jnp.exp(tail), Bc, xc)            # (B, nc, H, P, N)
    a_chunk = jnp.exp(csum[:, :, -1, :])              # (B, nc, H)

    # ----- inter-chunk scan (tiny: nc steps)
    def step(h, inp):
        a_k, s_k = inp                                # (B,H), (B,H,P,N)
        h_new = h * a_k[..., None, None] + s_k
        return h_new, h                               # emit state BEFORE chunk

    h0 = jnp.zeros((Bsz, H, P, N), f32)
    h_last, h_before = jax.lax.scan(
        step, h0, (a_chunk.swapaxes(0, 1), st.swapaxes(0, 1)))
    h_before = h_before.swapaxes(0, 1)                # (B, nc, H, P, N)

    y_inter = jnp.einsum("bcsn,bcsh,bchpn->bcshp",
                         Cc, jnp.exp(csum), h_before)
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y.astype(xh.dtype), h_last


def mamba_block(cfg, p: dict, x: jax.Array, rules: MeshRules, *,
                state: Optional[dict] = None, make_state: bool = False,
                ) -> Tuple[jax.Array, Optional[dict]]:
    """Full Mamba2 mixer.  x: (B, S, d).
    state (decode): {"conv": (B, K-1, conv_dim), "ssm": (B, H, P, N)}."""
    B, S, d = x.shape
    di, ds, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim

    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * ds]
    dt_raw = zxbcdt[..., -H:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])

    conv_state = state["conv"] if state is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xs = xbc[..., :di].reshape(B, S, H, P)
    Bmat = xbc[..., di:di + ds]
    Cmat = xbc[..., di + ds:]

    if state is not None:
        # -------- decode: O(1) recurrent update (S == 1)
        h = state["ssm"]                              # (B, H, P, N) f32
        a = jnp.exp(-jnp.exp(p["A_log"].astype(jnp.float32))
                    * dt[:, 0, :])                    # (B, H)
        xdt = xs[:, 0].astype(jnp.float32) * dt[:, 0, :, None]
        upd = jnp.einsum("bhp,bn->bhpn", xdt, Bmat[:, 0].astype(jnp.float32))
        h = h * a[..., None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", Cmat[:, 0].astype(jnp.float32), h)
        y = y + p["D"].astype(jnp.float32)[None, :, None] * \
            xs[:, 0].astype(jnp.float32)
        y = y.reshape(B, 1, di).astype(x.dtype)
        new_state = {"conv": new_conv, "ssm": h}
    else:
        chunk = min(cfg.ssm_chunk, S)
        pad = (-S) % chunk
        if pad:
            # zero-pad to a chunk multiple; padded steps use dt=0 so they
            # neither decay nor update the state (a=1, dB=0)
            xs_p = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            B_p = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
            C_p = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
        else:
            xs_p, dt_p, B_p, C_p = xs, dt, Bmat, Cmat
        y, h_last = _ssd_chunked(xs_p, dt_p, p["A_log"], B_p, C_p, chunk)
        y = y[:, :S]
        y = y + p["D"].astype(jnp.float32)[None, None, :, None] * \
            xs.astype(jnp.float32)
        y = y.reshape(B, S, di).astype(x.dtype)
        new_state = ({"conv": new_conv, "ssm": h_last}
                     if make_state else None)

    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    return out, new_state
