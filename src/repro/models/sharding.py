"""Logical-axis sharding rules (MaxText-style) for the model zoo.

Parameters and activations are annotated with *logical* axes; the rules
map them to mesh axes for the active topology:

  single-pod  (16, 16)   ("data", "model")
  multi-pod   (2, 16, 16)("pod", "data", "model")

Weights are fully sharded ("fsdp" on the non-TP dim, tensor-parallel on
"model"); batch shards over ("pod","data"); per-layer all-gathers are
GSPMD's job.  On a CPU/no-mesh context every helper degrades to a
no-op so smoke tests run unmodified.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis vocabulary
BATCH = "batch"       # global batch            -> ("pod","data") / ("data",)
SEQ = "seq"           # sequence (usually unsharded; CP uses it)
EMBED = "embed"       # d_model                 -> fsdp ("data")
MODEL = "model"       # TP dim (heads/ff/vocab) -> "model"
EXPERT = "expert"     # MoE experts             -> "model"
KV = "kv"             # kv heads                -> "model"
NONE = None


@dataclasses.dataclass(frozen=True)
class MeshRules:
    mesh: Optional[Mesh] = None
    batch_axes: Tuple[str, ...] = ("data",)
    fsdp_axis: Optional[str] = "data"
    model_axis: Optional[str] = "model"
    seq_axis: Optional[str] = None   # context parallelism when set

    def axis_size(self, name: Optional[str]) -> int:
        if self.mesh is None or name is None:
            return 1
        if isinstance(name, (tuple, list)):
            n = 1
            for a in name:
                n *= self.mesh.shape.get(a, 1)
            return n
        return self.mesh.shape.get(name, 1)

    def batch_size_divides(self, b: int) -> bool:
        return b % max(1, self.axis_size(self.batch_axes)) == 0

    def physical(self, logical: Optional[str]):
        if logical is None:
            return None
        if logical == BATCH:
            return self.batch_axes if len(self.batch_axes) > 1 \
                else self.batch_axes[0]
        if logical == SEQ:
            return self.seq_axis
        if logical == EMBED:
            return self.fsdp_axis
        if logical in (MODEL, EXPERT, KV):
            return self.model_axis
        raise ValueError(f"unknown logical axis {logical!r}")

    def spec(self, *logical) -> P:
        return P(*(self.physical(l) for l in logical))

    def sharding(self, *logical) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(*logical))

    def fitted_sharding(self, shape, *logical) -> Optional[NamedSharding]:
        """Like ``sharding`` but drops any axis that does not divide the
        corresponding dim (odd vocab sizes, few kv heads, batch=1...).
        Use for every concrete array/SDS placement."""
        if self.mesh is None:
            return None
        assert len(shape) == len(logical), (shape, logical)
        fitted = []
        for dim, log in zip(shape, logical):
            ax = self.physical(log)
            n = self.axis_size(ax)
            fitted.append(ax if (ax is not None and n > 1
                                 and dim % n == 0) else None)
        return NamedSharding(self.mesh, P(*fitted))

    def constrain(self, x: jax.Array, *logical) -> jax.Array:
        """with_sharding_constraint if a mesh is active, else identity.
        Divisibility-fitted: axes that don't divide the dim are dropped
        (avoids involuntary full rematerialization in SPMD)."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, self.fitted_sharding(x.shape, *logical))


def rules_for_mesh(mesh: Optional[Mesh], *,
                   seq_axis: Optional[str] = None) -> MeshRules:
    if mesh is None:
        return MeshRules(mesh=None, batch_axes=(), fsdp_axis=None,
                         model_axis=None, seq_axis=None)
    names = mesh.axis_names
    batch = tuple(n for n in names if n in ("pod", "data")) or (names[0],)
    fsdp = "data" if "data" in names else None
    model = "model" if "model" in names else None
    return MeshRules(mesh=mesh, batch_axes=batch, fsdp_axis=fsdp,
                     model_axis=model, seq_axis=seq_axis)


NO_MESH = MeshRules(mesh=None, batch_axes=(), fsdp_axis=None,
                    model_axis=None)
