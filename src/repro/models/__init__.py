"""Model zoo: the 10 assigned architectures over 5 families."""
from .sharding import MeshRules, rules_for_mesh, NO_MESH
from .transformer import Model, build_params

__all__ = ["Model", "build_params", "MeshRules", "rules_for_mesh", "NO_MESH"]
