"""Checkpoint/restore with atomic writes, retention, resharding restore
(elastic scaling), and preemption-safe semantics.

Format: one ``.npz`` per checkpoint step (flattened pytree keyed by
path string) + a JSON manifest.  Writes go to a temp dir and are
``rename``d into place — a partially-written checkpoint is never
visible, so a preemption mid-save cannot corrupt the restore path.

Resharding: arrays are saved *unsharded* (logical value) and the
restore re-places them under whatever mesh/sharding the new topology
uses — N devices at save, M at load (elastic scaling).  For true
multi-host deployments this becomes per-host shard files + a gather-on-
read; the single-process layout keeps the same API.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
import threading
from typing import Any, Callable, List, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten(tree: Any) -> dict:
    out = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        out[jax.tree_util.keystr(path)] = np.asarray(leaf)
    return out


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._async_thread: Optional[threading.Thread] = None

    # --------------------------------------------------------------- save
    def save(self, step: int, tree: Any, extra: Optional[dict] = None,
             blocking: bool = True) -> str:
        """Atomic save.  ``blocking=False`` runs serialization on a
        side thread (async checkpointing) — call ``wait()`` before the
        next save or at exit."""
        flat = _flatten(tree)   # device->host copy happens here
        meta = {"step": int(step), "extra": extra or {},
                "keys": sorted(flat.keys())}

        def _write():
            tmp = tempfile.mkdtemp(dir=self.directory, prefix=".tmp_")
            try:
                np.savez(os.path.join(tmp, "arrays.npz"), **flat)
                with open(os.path.join(tmp, "meta.json"), "w") as f:
                    json.dump(meta, f)
                final = os.path.join(self.directory, f"step_{step}")
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)   # atomic visibility
            except BaseException:
                shutil.rmtree(tmp, ignore_errors=True)
                raise
            self._retain()

        if blocking:
            _write()
        else:
            self.wait()
            self._async_thread = threading.Thread(target=_write, daemon=True)
            self._async_thread.start()
        return os.path.join(self.directory, f"step_{step}")

    def wait(self) -> None:
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    def _retain(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    # ------------------------------------------------------------ restore
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.directory, name,
                                                 "meta.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any,
                sharding_fn: Optional[Callable[[str, Any], Any]] = None
                ) -> tuple[Any, dict]:
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  ``sharding_fn(path, array) -> jax.Array``
        lets the caller re-place each array under a NEW mesh (elastic
        resharding); default placement is plain device_put."""
        path = os.path.join(self.directory, f"step_{step}")
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))

        leaves_with_path = jax.tree_util.tree_leaves_with_path(like)
        treedef = jax.tree_util.tree_structure(like)
        new_leaves = []
        for p, leaf in leaves_with_path:
            key = jax.tree_util.keystr(p)
            if key not in data:
                raise KeyError(f"checkpoint missing {key}")
            arr = data[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"{key}: checkpoint shape {arr.shape} != {leaf.shape}")
            if sharding_fn is not None:
                new_leaves.append(sharding_fn(key, arr))
            else:
                sh = getattr(leaf, "sharding", None)
                if sh is not None and hasattr(sh, "mesh"):
                    new_leaves.append(jax.device_put(arr, sh))
                else:
                    new_leaves.append(jax.device_put(
                        arr.astype(leaf.dtype)))
        tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
        return tree, meta["extra"]

    def restore_latest(self, like: Any, **kw):
        step = self.latest_step()
        if step is None:
            return None
        tree, extra = self.restore(step, like, **kw)
        return step, tree, extra
