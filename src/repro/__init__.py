"""repro — a growing reproduction of BLASX (locality-aware multi-GPU
L3 BLAS) on the jax/pallas substrate.

Public entry points:

* ``repro.api``  — the two-layer BLAS API: persistent
  :class:`~repro.api.BlasxContext` handles with warm tile caches,
  async :class:`~repro.api.BlasFuture` submission, batched GEMM, and
  the CBLAS-compatible ``cblas_*`` legacy layer.
* ``repro.core`` — the runtime underneath: tiling, ALRU/MESI-X tile
  caches, the dynamic scheduler, and legacy numpy-in/numpy-out
  routines.

Heavier subsystems (``repro.models``, ``repro.kernels``,
``repro.launch`` ...) import jax and are intentionally NOT imported
here; pull them in explicitly.
"""
from .core import (BlasxRuntime, RuntimeConfig, TiledMatrix, TileGrid,
                   TileKey, gemm, ref_gemm, ref_symm, ref_syr2k, ref_syrk,
                   ref_trmm, ref_trsm, symm, syr2k, syrk, trmm, trsm)
from .api import (BlasFuture, BlasxContext, CallRecord, MatrixHandle,
                  cblas_dgemm, cblas_dsymm, cblas_dsyr2k, cblas_dsyrk,
                  cblas_dtrmm, cblas_dtrsm, default_context,
                  gemm_batched, gemm_strided_batched, set_default_context)

__all__ = [
    "BlasxContext", "MatrixHandle", "CallRecord", "BlasFuture",
    "default_context", "set_default_context",
    "gemm_batched", "gemm_strided_batched",
    "cblas_dgemm", "cblas_dsymm", "cblas_dsyrk", "cblas_dsyr2k",
    "cblas_dtrmm", "cblas_dtrsm",
    "gemm", "syrk", "syr2k", "symm", "trmm", "trsm",
    "ref_gemm", "ref_syrk", "ref_syr2k", "ref_symm", "ref_trmm",
    "ref_trsm",
    "BlasxRuntime", "RuntimeConfig", "TiledMatrix", "TileGrid", "TileKey",
]
