"""Model/runtime configuration system.

One dataclass covers the five assigned families (dense / moe / ssm /
hybrid / encdec).  Each architecture file exports ``CONFIG`` (the exact
published dims) and the registry maps ``--arch <id>`` to it.  Every
config can produce a ``reduced()`` variant for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None    # default d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                 # per-expert FFN width
    n_shared_experts: int = 0
    n_dense_layers: int = 0           # leading dense layers (deepseek)
    capacity_factor: float = 1.25

    # --- MLA (deepseek) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    mtp: bool = False                 # multi-token-prediction extra head

    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    attn_every: int = 0               # hybrid: shared attn block period
    shared_attn: bool = False         # zamba2: reuse one attn block

    # --- enc-dec ---
    n_encoder_layers: int = 0

    # --- misc ---
    qk_norm: bool = False
    nonparametric_ln: bool = False    # olmo: LN without affine params
    act: str = "silu"
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    frontend: Optional[str] = None    # None | 'vision' | 'audio' (stubs)
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    remat: bool = True                # activation checkpoint per block

    # ------------------------------------------------------------ derived
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:         # mamba2 expansion
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can this arch serve 500k-token contexts?  (SSM state is O(1);
        hybrids pay only for the sparse shared-attention blocks.)"""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Approximate parameter count (embeddings included once)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
            + self.n_heads * hd * d
        if self.use_mla:
            qh = self.qk_nope_head_dim + self.qk_rope_head_dim
            attn = (d * self.q_lora_rank + self.q_lora_rank * self.n_heads * qh
                    + d * (self.kv_lora_rank + self.qk_rope_head_dim)
                    + self.kv_lora_rank * self.n_heads *
                    (self.qk_nope_head_dim + self.v_head_dim)
                    + self.n_heads * self.v_head_dim * d)
        mlp_dense = 3 * d * ff
        total = 0
        if self.family in ("dense", "encdec"):
            n = self.n_layers + self.n_encoder_layers
            total = n * (attn + mlp_dense)
        elif self.family == "moe":
            moe = (d * self.n_experts
                   + self.n_experts * 3 * d * self.moe_d_ff
                   + self.n_shared_experts * 3 * d * self.moe_d_ff)
            total = (self.n_dense_layers * (attn + mlp_dense)
                     + (self.n_layers - self.n_dense_layers) * (attn + moe))
        elif self.family == "ssm":
            di, ds, H = self.d_inner, self.ssm_state, self.ssm_heads
            conv_dim = di + 2 * ds
            mamba = (d * (2 * di + 2 * ds + H) + self.ssm_conv * conv_dim
                     + 3 * H + di + di * d)
            total = self.n_layers * mamba
        elif self.family == "hybrid":
            di, ds, H = self.d_inner, self.ssm_state, self.ssm_heads
            conv_dim = di + 2 * ds
            mamba = (d * (2 * di + 2 * ds + H) + self.ssm_conv * conv_dim
                     + 3 * H + di + di * d)
            n_attn_apps = self.n_layers // max(1, self.attn_every)
            n_attn_blocks = 1 if self.shared_attn else n_attn_apps
            total = (self.n_layers * mamba
                     + n_attn_blocks * (attn + mlp_dense))
        total += V * d * (1 if self.tie_embeddings else 2)
        if self.mtp:
            total += attn + mlp_dense
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed top-k)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        full_moe = self.n_experts * 3 * d * self.moe_d_ff
        act_moe = self.top_k * 3 * d * self.moe_d_ff
        n_moe_layers = self.n_layers - self.n_dense_layers
        return self.param_count() - n_moe_layers * (full_moe - act_moe)

    # ----------------------------------------------------------- reduced
    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        kw = dataclasses.asdict(self)
        hd = 8
        kw.update(
            n_layers=min(self.n_layers, 2 if self.family != "hybrid"
                         else max(2, self.attn_every)),
            d_model=64, d_ff=128, vocab_size=256,
            n_heads=4, n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=hd, remat=False, dtype="float32",
        )
        if self.family == "moe":
            # capacity_factor = E/K: no token drops, so smoke tests can
            # check train/prefill/decode logit consistency exactly
            kw.update(n_experts=4, top_k=2, moe_d_ff=32,
                      n_dense_layers=min(self.n_dense_layers, 1),
                      n_layers=2 + min(self.n_dense_layers, 1),
                      capacity_factor=2.0)
        if self.use_mla:
            kw.update(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=hd,
                      qk_rope_head_dim=hd // 2, v_head_dim=hd)
        if self.family in ("ssm", "hybrid"):
            kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=8)
        if self.family == "hybrid":
            kw.update(n_layers=4, attn_every=2)
        if self.family == "encdec":
            kw.update(n_encoder_layers=2)
        kw["name"] = self.name + "-smoke"
        return ModelConfig(**kw)


# --------------------------------------------------------------- shapes
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Is (arch x shape) runnable?  (long_500k needs sub-quadratic paths;
    pure full-attention archs skip it — recorded, per the assignment.)"""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("full-attention arch: no sub-quadratic path for "
                       "524288-token decode (skip per assignment)")
    return True, ""
