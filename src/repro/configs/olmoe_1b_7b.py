"""OLMoE-1B-7B [arXiv:2409.02060; hf] — 64 experts, top-8, qk-norm."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,             # (unused: all layers MoE)
    vocab_size=50304,
    n_experts=64,
    top_k=8,
    moe_d_ff=1024,
    n_dense_layers=0,
    qk_norm=True,
    act="silu",
)
