"""Architecture registry: ``--arch <id>`` -> ModelConfig."""
from __future__ import annotations

import importlib
from typing import Dict, List

from .base import ModelConfig

ARCH_IDS: List[str] = [
    "internvl2_26b",
    "olmo_1b",
    "phi3_medium_14b",
    "qwen3_0_6b",
    "glm4_9b",
    "deepseek_v3_671b",
    "olmoe_1b_7b",
    "seamless_m4t_medium",
    "zamba2_2_7b",
    "mamba2_780m",
    "blasx_gemm",          # the paper's own workload (tiled GEMM engine)
]

_ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    arch = _ALIASES.get(arch, arch)
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS if a != "blasx_gemm"}
