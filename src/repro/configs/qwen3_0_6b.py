"""Qwen3-0.6B [hf:Qwen/Qwen3-8B family; hf] — qk_norm, GQA, head_dim=128."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,          # GQA
    d_ff=3072,
    vocab_size=151936,
    head_dim=128,          # decoupled from d_model/n_heads in qwen3
    qk_norm=True,
    act="silu",
    tie_embeddings=True,
)
