"""InternVL2-26B language backbone (InternLM2-20B) [arXiv:2404.16821; hf].
VLM: the InternViT-6B frontend is a stub — input_specs() supplies
precomputed patch embeddings (per assignment)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,          # GQA
    d_ff=16384,
    vocab_size=92553,
    act="silu",
    frontend="vision",
)
