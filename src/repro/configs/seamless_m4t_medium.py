"""SeamlessM4T-medium [arXiv:2308.11596; hf] — encoder-decoder backbone.
Audio: the speech frontend (w2v-BERT conformer) is a stub — input_specs()
supplies precomputed frame embeddings (per assignment)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,           # decoder layers
    n_encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    act="gelu",
    frontend="audio",
)
