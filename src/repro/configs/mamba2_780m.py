"""Mamba2-780M [arXiv:2405.21060; unverified] — SSD, attention-free."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,             # attention-free
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
)
