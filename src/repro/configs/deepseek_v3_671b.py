"""DeepSeek-V3 671B [arXiv:2412.19437; hf] — MLA, 1 shared + 256 routed
top-8 MoE, MTP head, 3 leading dense layers."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,        # per assignment; attention is MLA below
    d_ff=18432,            # dense-layer FFN width
    vocab_size=129280,
    n_experts=256,
    top_k=8,
    moe_d_ff=2048,         # per assignment: d_ff=2048 per expert
    n_shared_experts=1,
    n_dense_layers=3,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    mtp=True,
    act="silu",
)
