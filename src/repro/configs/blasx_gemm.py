"""The paper's own workload: the tiled L3 BLAS engine at pod scale.
Not an LM — used by the BLAS dry-run/benchmark paths."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="blasx-gemm",
    family="dense",
    n_layers=0, d_model=16384, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab_size=0,
)
