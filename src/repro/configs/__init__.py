from .base import SHAPES, ModelConfig, ShapeConfig, cell_supported
from .registry import ARCH_IDS, all_configs, get_config

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "cell_supported",
           "ARCH_IDS", "get_config", "all_configs"]
