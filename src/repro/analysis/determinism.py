"""Determinism lint (DT001/DT002) for virtual-clock paths.

The discrete-event engine, the tuning layer and the sim-mode runtime
promise byte-identical replays: traces are diffed across runs, the
tuning cache must be portable between machines, and shadow runs
re-execute recorded schedules.  One ``time.time()`` or ambient
``random.random()`` in those paths breaks all three silently.

Scope: modules under ``repro/core/`` and ``repro/tuning/``.  Threads
mode *does* measure real wall time by design — those sites live in
``core/runtime.py`` and are baselined with that justification rather
than exempted structurally, so a new wall-clock read anywhere else in
``core/`` still fails.

* **DT001** — any reference (call *or* bare function reference, which
  is how a clock leaks in as a default argument) to ``time.time``,
  ``time.perf_counter``, ``time.monotonic``, ``time.process_time``,
  ``datetime.now/utcnow/today``.
* **DT002** — ambient RNG: ``random.<anything>`` and
  ``np.random.<fn>`` / ``numpy.random.<fn>`` except the seeded
  constructors (``default_rng``, ``SeedSequence``, ``Generator``,
  ``PCG64``) — explicit generators are the allowed idiom.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from .findings import Finding, normalize_path

_WALL_CLOCK = {
    ("time", "time"), ("time", "perf_counter"), ("time", "monotonic"),
    ("time", "process_time"), ("time", "perf_counter_ns"),
    ("time", "monotonic_ns"), ("time", "time_ns"),
    ("datetime", "now"), ("datetime", "utcnow"), ("datetime", "today"),
    ("date", "today"),
}
_RNG_MODULES = {"random"}
_NP_RANDOM_OK = {"default_rng", "SeedSequence", "Generator", "PCG64",
                 "Philox"}
_SCOPE_PREFIXES = ("repro/core/", "repro/tuning/")


def in_scope(relpath: str) -> bool:
    return relpath.startswith(_SCOPE_PREFIXES)


class _Visitor(ast.NodeVisitor):
    def __init__(self, relpath: str):
        self.relpath = relpath
        self.findings: List[Finding] = []
        self._stack: List[str] = []
        self._seen = set()  # (line, detail): a Call visits its
        # Attribute child too; report each site once

    def _qualname(self) -> str:
        return ".".join(self._stack) if self._stack else "<module>"

    def _emit(self, rule: str, node: ast.AST, detail: str, message: str):
        if (node.lineno, detail) in self._seen:
            return
        self._seen.add((node.lineno, detail))
        self.findings.append(Finding(
            rule=rule, path=self.relpath, line=node.lineno,
            qualname=self._qualname(), detail=detail, message=message))

    def visit_FunctionDef(self, node):
        self._stack.append(node.name)
        self.generic_visit(node)
        self._stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_ClassDef(self, node):
        self._stack.append(node.name)
        self.generic_visit(node)
        self._stack.pop()

    def _dotted(self, node: ast.Attribute) -> Optional[str]:
        if isinstance(node.value, ast.Name):
            return node.value.id
        if isinstance(node.value, ast.Attribute) and \
                isinstance(node.value.value, ast.Name):
            # np.random.rand -> receiver "np.random"
            return f"{node.value.value.id}.{node.value.attr}"
        return None

    def visit_Attribute(self, node: ast.Attribute):
        recv = self._dotted(node)
        if recv is not None:
            if (recv, node.attr) in _WALL_CLOCK:
                self._emit(
                    "DT001", node, f"{recv}.{node.attr}",
                    f"{recv}.{node.attr} in a virtual-clock path — "
                    "sim/tuning code must take time from the event "
                    "engine, not the wall")
            elif recv in _RNG_MODULES:
                self._emit(
                    "DT002", node, f"{recv}.{node.attr}",
                    f"ambient RNG {recv}.{node.attr} in a virtual-clock "
                    "path — pass an explicit seeded generator instead")
            elif recv in ("np.random", "numpy.random") and \
                    node.attr not in _NP_RANDOM_OK:
                self._emit(
                    "DT002", node, f"{recv}.{node.attr}",
                    f"ambient RNG {recv}.{node.attr} — use "
                    "np.random.default_rng(seed) and thread it through")
        self.generic_visit(node)


def check_determinism(tree: ast.Module, relpath: str) -> List[Finding]:
    if not in_scope(relpath):
        return []
    v = _Visitor(relpath)
    v.visit(tree)
    return v.findings


def analyze_source(text: str, relpath: str) -> List[Finding]:
    return check_determinism(ast.parse(text), normalize_path(relpath))
