"""Pytest plugin: run the whole session under the lock-witness.

Usage (the CI threads-mode stress smoke)::

    python -m pytest -p repro.analysis.pytest_witness \
        tests/test_taskqueue.py tests/test_serve.py ...

The witness activates at configure time, before test modules import,
so module-level locks are wrapped too.  After each test the inversion
count is checked; the first test that introduces a dynamic lock-order
inversion errors in teardown with both acquisition stacks, so blame
lands on the test that interleaved the locks — not on session exit.
"""
from __future__ import annotations

from .witness import LockWitness

_witness = None
_active = None
_seen_inversions = 0


def pytest_configure(config):
    global _witness, _active
    _witness = LockWitness()
    _active = _witness.activate()
    _active.__enter__()


def pytest_runtest_teardown(item, nextitem):
    global _seen_inversions
    if _witness is None:
        return
    inv = _witness.inversions()
    if len(inv) > _seen_inversions:
        new = inv[_seen_inversions:]
        _seen_inversions = len(inv)
        detail = "\n".join(
            f"INVERSION:\n  {ab.describe()}\n  {ba.describe()}"
            for ab, ba in new)
        raise AssertionError(
            f"lock-witness: {len(new)} new lock-order inversion(s) "
            f"during {item.nodeid}:\n{detail}")


def pytest_unconfigure(config):
    global _active
    if _active is not None:
        _active.__exit__(None, None, None)
        _active = None


def pytest_terminal_summary(terminalreporter, exitstatus, config):
    if _witness is not None:
        terminalreporter.write_line(_witness.report().splitlines()[0])
