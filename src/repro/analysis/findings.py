"""Finding model + suppression baseline for ``repro.analysis``.

Every analysis emits :class:`Finding` records carrying a rule id, a
repo-relative ``file:line`` anchor and a **stable suppression key**
(``path::qualname::detail``) that survives unrelated edits — line
numbers are for humans, keys are for the committed baseline.

The baseline (``src/repro/analysis/baseline.json``) is the list of
*intentional* patterns: each entry names the rule, the key and a
one-line justification (review policy: a new suppression needs the
justification to say why the pattern is safe, not just that it is
old).  ``python -m repro.analysis --strict`` fails on any finding not
covered by the baseline — new violations break CI, grandfathered
patterns stay green and documented.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Tuple

BASELINE_SCHEMA = 1
DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.json"

# rule catalog: id -> one-line description (docs/ANALYSIS.md mirrors
# this table; test_analysis cross-checks the ids)
RULES: Dict[str, str] = {
    "LD001": "guarded field accessed without holding its declared lock",
    "LD002": "blocking call / user callback / yield while a lock is held",
    "LD003": "class allocates a threading lock but declares no _GUARDED_BY",
    "LO001": "static lock-order cycle between lock-owning classes",
    "DT001": "wall-clock read (time.time/perf_counter/...) in a "
             "virtual-clock path",
    "DT002": "ambient RNG (random.*, np.random.*) in a virtual-clock path",
    "AS001": "invariant check compares an expression to itself",
    "AS002": "invariant check counts an iterable against its own len()",
}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analysis result, anchored to ``path:line``.

    ``qualname`` is the enclosing ``Class.method`` (or ``<module>``);
    ``detail`` is the rule-specific stable token (field name, callee,
    clock function ...) that makes the suppression key edit-stable."""

    rule: str
    path: str
    line: int
    qualname: str
    detail: str
    message: str

    @property
    def key(self) -> str:
        return f"{self.path}::{self.qualname}::{self.detail}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} [{self.qualname}] " \
               f"{self.message}"


def normalize_path(path) -> str:
    """Repo-relative posix path starting at ``repro/`` when the file
    lives under a ``src/`` layout — baseline keys must not depend on
    where the repo is checked out or which prefix the CLI was given."""
    p = Path(path).as_posix()
    for marker in ("/src/repro/", "src/repro/"):
        idx = p.find(marker)
        if idx >= 0:
            return "repro/" + p[idx + len(marker):]
    if p.startswith("repro/"):
        return p
    # keep the last two components so fixture files get stable keys
    parts = p.split("/")
    return "/".join(parts[-2:]) if len(parts) > 1 else p


class Baseline:
    """Committed suppression set: ``(rule, key) -> justification``."""

    def __init__(self, entries: Optional[Iterable[dict]] = None):
        self._entries: Dict[Tuple[str, str], str] = {}
        for e in entries or ():
            self._entries[(e["rule"], e["key"])] = e.get("justification", "")

    def __len__(self) -> int:
        return len(self._entries)

    def covers(self, f: Finding) -> bool:
        return (f.rule, f.key) in self._entries

    def unused(self, findings: Iterable[Finding]) -> List[Tuple[str, str]]:
        """Suppressions that matched nothing — stale entries worth
        pruning (reported as warnings, never failures)."""
        hit = {(f.rule, f.key) for f in findings}
        return sorted(k for k in self._entries if k not in hit)

    @classmethod
    def load(cls, path=None) -> "Baseline":
        p = Path(path) if path is not None else DEFAULT_BASELINE
        if not p.exists():
            return cls()
        data = json.loads(p.read_text(encoding="utf-8"))
        if data.get("schema") != BASELINE_SCHEMA:
            raise ValueError(
                f"baseline {p} has schema {data.get('schema')!r}, "
                f"expected {BASELINE_SCHEMA}")
        entries = data.get("suppressions", [])
        for e in entries:
            if not e.get("justification", "").strip():
                raise ValueError(
                    f"baseline entry {e.get('rule')}/{e.get('key')} has no "
                    "justification (review policy: every suppression says "
                    "why the pattern is safe)")
        return cls(entries)


def split_findings(findings: Iterable[Finding], baseline: Baseline
                   ) -> Tuple[List[Finding], List[Finding]]:
    """(unsuppressed, suppressed) partition, both sorted for stable
    output."""
    unsup, sup = [], []
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule)):
        (sup if baseline.covers(f) else unsup).append(f)
    return unsup, sup
