"""``python -m repro.analysis`` — run the static analyses (and the
optional witness smoke) from the command line.

Stdlib-only on purpose: the CI lint job installs nothing but ruff, so
the gate runs straight off the checkout (``PYTHONPATH=src python -m
repro.analysis --strict src``).

Exit status: 0 when every finding is covered by the baseline (or with
no ``--strict``, always unless the run itself errors); 1 under
``--strict`` when unsuppressed findings remain; 2 for usage/IO errors.
"""
from __future__ import annotations

import argparse
import ast
import json
import sys
from pathlib import Path
from typing import List, Optional, Sequence, Tuple

from . import assertions, determinism, locks
from .findings import (Baseline, Finding, RULES, normalize_path,
                       split_findings)


def _collect_files(paths: Sequence[str]) -> List[Path]:
    out: List[Path] = []
    for raw in paths:
        p = Path(raw)
        if p.is_dir():
            out.extend(sorted(
                f for f in p.rglob("*.py")
                if "__pycache__" not in f.parts))
        elif p.suffix == ".py":
            out.append(p)
        else:
            raise FileNotFoundError(f"not a python file or directory: {raw}")
    return out


def run_analyses(paths: Sequence[str]) -> Tuple[List[Finding], int]:
    """All findings over the given files/dirs + number of files read.

    Per-module rules run file by file; the lock-order graph is built
    once over the whole set (cycles cross module boundaries — the
    Alru<->MesixDirectory shape lives in two files).
    """
    files = _collect_files(paths)
    findings: List[Finding] = []
    modules: List[Tuple[ast.Module, str]] = []
    for f in files:
        rel = normalize_path(f)
        try:
            tree = ast.parse(f.read_text(encoding="utf-8"))
        except SyntaxError as e:
            raise SyntaxError(f"{f}: {e}") from e
        modules.append((tree, rel))
        findings.extend(locks.check_lock_discipline(tree, rel))
        findings.extend(determinism.check_determinism(tree, rel))
        findings.extend(assertions.check_assertions(tree, rel))
    findings.extend(locks.check_lock_order(modules))
    return findings, len(files)


def _witness_smoke(verbose: bool) -> int:
    """Drive a threads-mode multi-device workload (context routines +
    the serving front end) under the lock-witness; non-zero exit on
    any dynamic lock-order inversion."""
    from .witness import LockWitness

    witness = LockWitness()
    with witness.activate():
        # imports happen inside the activation so module-level locks
        # (tuning shared cache, default-context registry) are witnessed
        import numpy as np

        from repro.api.context import BlasxContext
        from repro.core.runtime import RuntimeConfig
        from repro.serve.server import BlasxServer

        rng = np.random.default_rng(0)
        n = 192
        a = rng.standard_normal((n, n))
        b = rng.standard_normal((n, n))
        spd = np.tril(rng.standard_normal((n, n))) + n * np.eye(n)

        ctx = BlasxContext(RuntimeConfig(n_devices=2, mode="threads"),
                           tile=64)
        try:
            ctx.gemm(a, b)
            ctx.syrk(a)
            ctx.trsm(spd, b, uplo="L")
        finally:
            ctx.close()

        srv = BlasxServer(RuntimeConfig(n_devices=2, mode="threads"),
                          pool_size=2, tile=64)
        try:
            futs = [srv.submit(t, "gemm", a, b)
                    for t in ("alice", "bob", "alice", "bob")]
            for f in futs:
                f.result(timeout=120)
        finally:
            srv.close()

    print(witness.report() if verbose else
          witness.report().splitlines()[0])
    return 1 if witness.inversions() else 0


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="lock-discipline, lock-order, determinism and "
                    "assertion-strength analyses for the repro tree")
    parser.add_argument(
        "paths", nargs="*", default=["src"],
        help="files/directories to scan (default: src)")
    parser.add_argument(
        "--strict", action="store_true",
        help="exit 1 if any finding is not covered by the baseline")
    parser.add_argument(
        "--baseline", default=None, metavar="JSON",
        help="suppression baseline (default: the committed "
             "src/repro/analysis/baseline.json)")
    parser.add_argument(
        "--json", action="store_true",
        help="emit findings as JSON instead of text")
    parser.add_argument(
        "--list-rules", action="store_true",
        help="print the rule catalog and exit")
    parser.add_argument(
        "--witness-smoke", action="store_true",
        help="run a threads-mode workload under the runtime "
             "lock-witness; exit 1 on any dynamic inversion")
    parser.add_argument(
        "--verbose", action="store_true",
        help="show suppressed findings / full witness report too")
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule, desc in sorted(RULES.items()):
            print(f"{rule}  {desc}")
        return 0

    if args.witness_smoke:
        return _witness_smoke(args.verbose)

    try:
        baseline = Baseline.load(args.baseline)
        findings, n_files = run_analyses(args.paths)
    except (FileNotFoundError, ValueError, SyntaxError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    unsup, sup = split_findings(findings, baseline)

    if args.json:
        print(json.dumps({
            "files": n_files,
            "findings": [vars(f) | {"key": f.key, "suppressed": False}
                         for f in unsup]
            + [vars(f) | {"key": f.key, "suppressed": True}
               for f in sup],
        }, indent=2))
    else:
        for f in unsup:
            print(f.render())
        if args.verbose:
            for f in sup:
                print(f"{f.render()}  [suppressed]")
        stale = baseline.unused(findings)
        for rule, key in stale:
            print(f"warning: stale baseline entry {rule} {key}",
                  file=sys.stderr)
        print(f"repro.analysis: {n_files} files, "
              f"{len(unsup)} findings, {len(sup)} suppressed")

    return 1 if (args.strict and unsup) else 0


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
