"""Assertion-strength lint (AS001/AS002).

PR 5 shipped a heap ``check_invariants`` that compared the free-list
walk against itself — green forever, checking nothing.  These rules
target that class of tautology inside the functions whose *job* is
checking: ``check_invariants``, ``audit``, ``validate_*``.

* **AS001** — a comparison whose two sides are structurally identical
  ASTs (``x == x``, ``len(a.b) <= len(a.b)``).  Always true (NaN
  aside), so the check it anchors is vacuous.
* **AS002** — counting an iterable against its own length:
  ``sum(1 for _ in X)`` compared with ``len(X)`` for the same ``X``.
  Both sides enumerate the same container, so corruption shows up in
  both and cancels — the PR 5 heap shape exactly.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from .findings import Finding, normalize_path

_CHECK_NAMES = ("check_invariants", "audit")
_CHECK_PREFIXES = ("validate_",)


def _is_check_function(name: str) -> bool:
    return name in _CHECK_NAMES or name.startswith(_CHECK_PREFIXES)


def _snippet(node: ast.AST) -> str:
    try:
        s = ast.unparse(node)
    except Exception:  # pragma: no cover - unparse is py3.9+ stdlib
        s = "<expr>"
    return s if len(s) <= 48 else s[:45] + "..."


def _count_target(node: ast.AST) -> Optional[ast.AST]:
    """If ``node`` is ``sum(1 for _ in X)``, return X."""
    if not (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id == "sum" and len(node.args) == 1):
        return None
    gen = node.args[0]
    if not isinstance(gen, ast.GeneratorExp):
        return None
    if not (isinstance(gen.elt, ast.Constant) and gen.elt.value == 1):
        return None
    if len(gen.generators) != 1:
        return None
    return gen.generators[0].iter


def _len_target(node: ast.AST) -> Optional[ast.AST]:
    if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id == "len" and len(node.args) == 1):
        return node.args[0]
    return None


class _Visitor(ast.NodeVisitor):
    def __init__(self, relpath: str, qualprefix: str, funcname: str):
        self.relpath = relpath
        self.qualname = f"{qualprefix}{funcname}" if qualprefix \
            else funcname
        self.findings: List[Finding] = []

    def visit_Compare(self, node: ast.Compare):
        sides = [node.left] + list(node.comparators)
        for left, right in zip(sides, sides[1:]):
            if ast.dump(left) == ast.dump(right):
                self.findings.append(Finding(
                    rule="AS001", path=self.relpath, line=node.lineno,
                    qualname=self.qualname,
                    detail=f"self-compare:{_snippet(left)}",
                    message=f"both comparison sides are the same "
                            f"expression ({_snippet(left)}) — the check "
                            "is vacuously true"))
                continue
            for a, b in ((left, right), (right, left)):
                counted = _count_target(a)
                measured = _len_target(b)
                if counted is not None and measured is not None and \
                        ast.dump(counted) == ast.dump(measured):
                    self.findings.append(Finding(
                        rule="AS002", path=self.relpath, line=node.lineno,
                        qualname=self.qualname,
                        detail=f"count-vs-len:{_snippet(counted)}",
                        message=f"sum(1 for _ in {_snippet(counted)}) vs "
                                f"len(...) over the same iterable — both "
                                "walk the same container, corruption "
                                "cancels (PR 5 heap-tautology shape)"))
                    break
        self.generic_visit(node)


def check_assertions(tree: ast.Module, relpath: str) -> List[Finding]:
    findings: List[Finding] = []

    def scan(body, qualprefix):
        for node in body:
            if isinstance(node, ast.ClassDef):
                scan(node.body, f"{qualprefix}{node.name}.")
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if _is_check_function(node.name):
                    v = _Visitor(relpath, qualprefix, node.name)
                    v.visit(node)
                    findings.extend(v.findings)
                scan(node.body, f"{qualprefix}{node.name}.")

    scan(tree.body, "")
    return findings


def analyze_source(text: str, relpath: str) -> List[Finding]:
    return check_assertions(ast.parse(text), normalize_path(relpath))
