"""blasxcheck: lock-discipline, lock-order, determinism and
assertion-strength analyses for the BLASX repro tree, plus the runtime
lock-witness.

Static side (stdlib ``ast`` only — the CI lint job runs it without
installing the package):

* :func:`repro.analysis.locks.check_lock_discipline` — LD001/LD002/
  LD003 against the ``_GUARDED_BY`` declarations;
* :func:`repro.analysis.locks.check_lock_order` — LO001 cycles in the
  cross-module acquisition graph;
* :func:`repro.analysis.determinism.check_determinism` — DT001/DT002
  wall-clock / ambient-RNG leaks into virtual-clock paths;
* :func:`repro.analysis.assertions.check_assertions` — AS001/AS002
  tautological invariant checks.

Dynamic side: :class:`repro.analysis.witness.LockWitness` wraps
repro-allocated locks during threads-mode tests and reports lock-order
inversions with both acquisition stacks
(``-p repro.analysis.pytest_witness`` runs a whole pytest session
under it).

CLI: ``python -m repro.analysis --strict src`` — see docs/ANALYSIS.md.
"""
from .cli import main, run_analyses
from .findings import Baseline, Finding, RULES
from .witness import LockWitness

__all__ = ["main", "run_analyses", "Baseline", "Finding", "RULES",
           "LockWitness"]
