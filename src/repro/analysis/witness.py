"""Runtime lock-witness: dynamic lock-order recording for threads mode.

The static graph in :mod:`.locks` over-approximates by name; the
witness closes the loop from the other side.  Inside
``LockWitness.activate()`` the ``threading.Lock`` / ``threading.RLock``
factories are patched so that locks *allocated from repro source or
test files* come back wrapped.  Each wrapper reports acquire/release
to the witness, which keeps a per-thread stack of held locks and a
directed edge ``A -> B`` whenever ``B`` is acquired while ``A`` is
held — with both acquisition stacks captured the first time the edge
is seen.  An edge pair ``A -> B`` and ``B -> A`` between the same two
lock *instances* is an inversion: two threads interleaving those
regions can deadlock.

Design notes:

* the caller-filename filter at allocation time keeps stdlib and
  third-party locks (ThreadPoolExecutor internals, logging, ...) out
  of the graph — ``threading.Condition()``'s internally-allocated
  RLock is born in ``threading.py`` and therefore unwrapped;
* wrappers implement ``_release_save`` / ``_acquire_restore`` /
  ``_is_owned`` so ``threading.Condition(wrapped_lock)`` works and
  ``cv.wait`` correctly pops the held stack while parked;
* reentrant acquisition of a lock already held by the thread records
  no edge (an RLock deadlocks with nobody over itself);
* witness bookkeeping is serialized by a lock from the *original*
  factory, so the witness never traces itself;
* lock names are inferred lazily on first acquire by walking a few
  caller frames for a ``self`` that owns the wrapper — yielding
  ``Alru._lock``-style names in reports.
"""
from __future__ import annotations

import contextlib
import sys
import threading
import traceback
from typing import Dict, List, Optional, Tuple

_STACK_LIMIT = 12


def _default_filter(filename: str) -> bool:
    """Wrap locks allocated from repro source or repo tests."""
    f = filename.replace("\\", "/")
    if "/analysis/" in f:
        return False  # never trace the tracer
    return "repro/" in f or "/tests/" in f or "test_" in f.rsplit("/", 1)[-1]


def _capture_stack(skip: int) -> "traceback.StackSummary":
    frame = sys._getframe(skip)
    return traceback.StackSummary.extract(
        traceback.walk_stack(frame), limit=_STACK_LIMIT,
        lookup_lines=False)


def _format_stack(stack) -> str:
    # walk_stack yields innermost-first; print outermost-first like a
    # normal traceback
    return "".join(reversed(stack.format()))


class _Held:
    """One entry on a thread's held-lock stack."""

    __slots__ = ("lock", "stack")

    def __init__(self, lock: "WitnessedLock", stack):
        self.lock = lock
        self.stack = stack


class _Edge:
    """First-seen evidence for lock A held while acquiring lock B."""

    __slots__ = ("held_name", "acq_name", "held_stack", "acq_stack",
                 "count")

    def __init__(self, held_name, acq_name, held_stack, acq_stack):
        self.held_name = held_name
        self.acq_name = acq_name
        self.held_stack = held_stack
        self.acq_stack = acq_stack
        self.count = 1

    def describe(self) -> str:
        return (
            f"{self.held_name} held while acquiring {self.acq_name} "
            f"(seen {self.count}x)\n"
            f"  -- {self.held_name} acquired at:\n"
            f"{_format_stack(self.held_stack)}"
            f"  -- {self.acq_name} acquired at:\n"
            f"{_format_stack(self.acq_stack)}")


class WitnessedLock:
    """Wrapper recording acquire/release; Condition-compatible."""

    def __init__(self, inner, witness: "LockWitness", site: str,
                 kind: str):
        self._inner = inner
        self._witness = witness
        self.site = site        # "file.py:lineno" of the allocation
        self.kind = kind        # "Lock" | "RLock"
        self.name: Optional[str] = None  # inferred on first acquire

    # -- plain lock protocol ----------------------------------------
    def acquire(self, *args, **kwargs):
        got = self._inner.acquire(*args, **kwargs)
        if got:
            self._witness._note_acquire(self, skip=2)
        return got

    def release(self):
        self._witness._note_release(self)
        self._inner.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def locked(self):
        return self._inner.locked()

    # -- Condition(lock) protocol -----------------------------------
    def _release_save(self):
        self._witness._note_release(self)
        if hasattr(self._inner, "_release_save"):
            return self._inner._release_save()
        self._inner.release()
        return None

    def _acquire_restore(self, state):
        if hasattr(self._inner, "_acquire_restore"):
            self._inner._acquire_restore(state)
        else:
            self._inner.acquire()
        self._witness._note_acquire(self, skip=2)

    def _is_owned(self):
        if hasattr(self._inner, "_is_owned"):
            return self._inner._is_owned()
        # plain Lock heuristic, mirroring threading.Condition._is_owned
        if self._inner.acquire(False):
            self._inner.release()
            return False
        return True

    def __repr__(self):
        return f"<WitnessedLock {self.display_name} at {hex(id(self))}>"

    @property
    def display_name(self) -> str:
        return self.name or f"{self.kind}@{self.site}"


class LockWitness:
    """Records per-thread acquisition order; reports inversions."""

    def __init__(self, capture_stacks: bool = True):
        self.capture_stacks = capture_stacks
        self._boot_lock_factory = threading.Lock
        self._meta = self._boot_lock_factory()  # bookkeeping guard
        self._held: Dict[int, List[_Held]] = {}
        self._edges: Dict[Tuple[int, int], _Edge] = {}
        self._locks: Dict[int, "WitnessedLock"] = {}
        self.acquisitions = 0

    # -- factory patching -------------------------------------------
    @contextlib.contextmanager
    def activate(self, wrap_filter=_default_filter):
        """Patch threading.Lock/RLock so repro-allocated locks are
        witnessed.  Locks created before activation are untouched."""
        orig_lock, orig_rlock = threading.Lock, threading.RLock

        def factory(orig, kind):
            def alloc():
                caller = sys._getframe(1)
                if not wrap_filter(caller.f_code.co_filename):
                    return orig()
                site = f"{caller.f_code.co_filename.rsplit('/', 1)[-1]}" \
                       f":{caller.f_lineno}"
                lock = WitnessedLock(orig(), self, site, kind)
                with self._meta:
                    self._locks[id(lock)] = lock
                return lock
            return alloc

        threading.Lock = factory(orig_lock, "Lock")
        threading.RLock = factory(orig_rlock, "RLock")
        try:
            yield self
        finally:
            threading.Lock = orig_lock
            threading.RLock = orig_rlock

    # -- acquire/release callbacks ----------------------------------
    def _note_acquire(self, lock: WitnessedLock, skip: int):
        if lock.name is None:
            lock.name = self._infer_name(lock, skip + 1)
        stack = _capture_stack(skip + 1) if self.capture_stacks else None
        tid = threading.get_ident()
        with self._meta:
            self.acquisitions += 1
            held = self._held.setdefault(tid, [])
            reentrant = any(h.lock is lock for h in held)
            if not reentrant:
                for h in held:
                    self._record_edge(h, lock, stack)
            held.append(_Held(lock, stack))

    def _note_release(self, lock: WitnessedLock):
        tid = threading.get_ident()
        with self._meta:
            held = self._held.get(tid, [])
            for i in range(len(held) - 1, -1, -1):
                if held[i].lock is lock:
                    del held[i]
                    break

    def _record_edge(self, held: _Held, acq: WitnessedLock, acq_stack):
        key = (id(held.lock), id(acq))
        edge = self._edges.get(key)
        if edge is not None:
            edge.count += 1
            return
        self._edges[key] = _Edge(
            held.lock.display_name, acq.display_name,
            held.stack if held.stack is not None
            else traceback.StackSummary.from_list([]),
            acq_stack if acq_stack is not None
            else traceback.StackSummary.from_list([]))

    def _infer_name(self, lock: WitnessedLock, skip: int) -> str:
        """``Owner._attr`` from the nearest caller frame whose ``self``
        holds this wrapper as an attribute."""
        try:
            frame = sys._getframe(skip)
        except ValueError:
            return lock.display_name
        for _ in range(6):
            if frame is None:
                break
            owner = frame.f_locals.get("self")
            if owner is not None and owner is not lock \
                    and not isinstance(owner, LockWitness):
                try:
                    attrs = vars(owner)
                except TypeError:
                    attrs = {}
                for attr_name, val in attrs.items():
                    if val is lock:
                        return f"{type(owner).__name__}.{attr_name}"
            frame = frame.f_back
        return f"{lock.kind}@{lock.site}"

    # -- reporting ---------------------------------------------------
    def inversions(self) -> List[Tuple[_Edge, _Edge]]:
        """Pairs of opposed edges between the same two lock instances."""
        with self._meta:
            out = []
            for (a, b), ab in sorted(self._edges.items()):
                if a < b:
                    ba = self._edges.get((b, a))
                    if ba is not None:
                        out.append((ab, ba))
            return out

    def edge_names(self) -> List[Tuple[str, str]]:
        with self._meta:
            return sorted({(e.held_name, e.acq_name)
                           for e in self._edges.values()})

    def report(self) -> str:
        inv = self.inversions()
        lines = [f"lock-witness: {self.acquisitions} acquisitions, "
                 f"{len(self._locks)} witnessed locks, "
                 f"{len(self._edges)} order edges, "
                 f"{len(inv)} inversions"]
        for ab, ba in inv:
            lines.append("INVERSION:")
            lines.append("  " + ab.describe().replace("\n", "\n  "))
            lines.append("  " + ba.describe().replace("\n", "\n  "))
        return "\n".join(lines)

    def assert_clean(self):
        inv = self.inversions()
        if inv:
            raise AssertionError(
                f"lock-witness detected {len(inv)} lock-order "
                f"inversion(s):\n{self.report()}")
