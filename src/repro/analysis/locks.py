"""Lock-discipline (LD00x) and lock-order (LO001) static analyses.

Guard-declaration convention (plain class attributes, readable by
``ast.literal_eval`` — no imports, no runtime cost):

``_GUARDED_BY = {"_lock": ("_map", "hits", ...)}``
    lock attribute -> fields every method must only touch while
    holding that lock.  Declaring *any* ``_GUARDED_BY`` opts the class
    into LD001/LD002 and into the lock-order graph.
``_LOCK_ALIASES = {"_cv": "_lock"}``
    attribute that *wraps* a lock (a ``Condition`` built over it):
    ``with self._cv`` counts as holding ``_lock``.
``_LOCK_HELD = ("_dequeue", ...)``
    methods only ever called with the lock already held; their bodies
    are analysed as locked regions.  A ``*_locked`` name suffix means
    the same thing without the declaration.
``_CALLBACKS = ("on_evict",)``
    attributes holding *user* callbacks; invoking one inside a locked
    region is LD002 (the PR 6 inline-callback deadlock shape).

Rules:

* **LD001** — a declared guarded field is read/written in a method
  body outside any ``with self.<lock>`` region (and the method is not
  lock-held by convention).  ``__init__`` is exempt: no concurrent
  observer exists before ``__init__`` returns.
* **LD002** — a blocking call while a lock is held: ``time.sleep``,
  ``.wait(...)`` on anything that is not an alias of a lock already
  held, ``Future.result()``, ``Thread.join()`` (string receivers are
  exempt — ``", ".join``), ``Executor.shutdown()``,
  ``add_done_callback`` (may run the callback inline), invoking a
  declared ``_CALLBACKS`` attribute, and ``yield`` (a generator/
  contextmanager parks arbitrary caller code under the lock).
* **LD003** — a class assigns ``self.x = threading.Lock/RLock/
  Condition(...)`` but declares no ``_GUARDED_BY``: undeclared locks
  escape every other rule, so coverage itself is enforced.
* **LO001** — cycles in the static acquisition graph.  Inside each
  class's locked regions, calls ``recv.m(...)`` are resolved by *name*
  to every declared class whose method ``m`` acquires its own lock;
  each resolution adds an edge ``C -> D``.  ``x in y`` resolves to
  ``__contains__`` unless ``y`` is a plain ``self.<attr>`` (dict/set
  fields would drown the graph in noise).  Self-edges are dropped
  (RLock reentrancy; same-name false positives).  A strongly-connected
  component with >1 class is a potential deadlock and one finding.

The name-based call resolution is deliberately over-approximate: a
false edge is cheap (baseline it), a missed real cycle is not.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .findings import Finding, normalize_path

_LOCK_FACTORIES = {"Lock", "RLock", "Condition", "Semaphore",
                   "BoundedSemaphore"}
_BLOCKING_ATTRS = {"result", "join", "shutdown", "add_done_callback"}


class GuardSpec:
    """Parsed guard declarations for one class."""

    def __init__(self, cls: ast.ClassDef):
        self.name = cls.name
        self.guarded_by: Dict[str, Tuple[str, ...]] = {}
        self.aliases: Dict[str, str] = {}
        self.lock_held: Tuple[str, ...] = ()
        self.callbacks: Tuple[str, ...] = ()
        for stmt in cls.body:
            if not isinstance(stmt, ast.Assign) or len(stmt.targets) != 1:
                continue
            tgt = stmt.targets[0]
            if not isinstance(tgt, ast.Name):
                continue
            try:
                val = ast.literal_eval(stmt.value)
            except (ValueError, SyntaxError):
                continue
            if tgt.id == "_GUARDED_BY":
                self.guarded_by = {k: tuple(v) for k, v in dict(val).items()}
            elif tgt.id == "_LOCK_ALIASES":
                self.aliases = dict(val)
            elif tgt.id == "_LOCK_HELD":
                self.lock_held = tuple(val)
            elif tgt.id == "_CALLBACKS":
                self.callbacks = tuple(val)

    @property
    def declared(self) -> bool:
        return bool(self.guarded_by)

    @property
    def lock_names(self) -> Set[str]:
        return set(self.guarded_by) | set(self.aliases)

    def canonical(self, attr: str) -> Optional[str]:
        """Canonical lock name for an acquired attribute, or None."""
        if attr in self.guarded_by:
            return attr
        return self.aliases.get(attr)

    def field_lock(self, field: str) -> Optional[str]:
        for lock, fields in self.guarded_by.items():
            if field in fields:
                return lock
        return None

    def is_lock_held_method(self, name: str) -> bool:
        return name in self.lock_held or name.endswith("_locked")


def _self_attr(node: ast.AST) -> Optional[str]:
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def _acquired_lock(item: ast.withitem, spec: GuardSpec) -> Optional[str]:
    """Canonical lock name a ``with`` item acquires, or None."""
    expr = item.context_expr
    attr = _self_attr(expr)
    if attr is None and isinstance(expr, ast.Call):
        # with self._lock.acquire_timeout(...) style — not used here,
        # but resolve plain with self._lock() defensively
        attr = _self_attr(expr.func)
    return spec.canonical(attr) if attr else None


class _MethodChecker(ast.NodeVisitor):
    """LD001/LD002 over one method body, tracking the held-lock set.

    Lambdas inherit the held set (sort keys and the like run inline);
    nested ``def``s are skipped entirely — they may escape the region
    and analysing them either way guesses wrong.
    """

    def __init__(self, spec: GuardSpec, method: str, path: str,
                 findings: List[Finding], all_held: bool):
        self.spec = spec
        self.method = method
        self.path = path
        self.findings = findings
        self.held: Set[str] = set(spec.guarded_by) if all_held else set()
        self._depth = 0  # >0 once inside the method body proper

    # -- helpers -----------------------------------------------------
    def _emit(self, rule: str, node: ast.AST, detail: str, message: str):
        self.findings.append(Finding(
            rule=rule, path=self.path, line=node.lineno,
            qualname=f"{self.spec.name}.{self.method}",
            detail=detail, message=message))

    # -- region tracking --------------------------------------------
    def visit_With(self, node: ast.With):
        acquired = []
        for item in node.items:
            lock = _acquired_lock(item, self.spec)
            if lock is not None and lock not in self.held:
                acquired.append(lock)
            # the context expression itself evaluates outside the region
            self.visit(item.context_expr)
        self.held.update(acquired)
        for stmt in node.body:
            self.visit(stmt)
        self.held.difference_update(acquired)

    def visit_FunctionDef(self, node: ast.FunctionDef):
        if self._depth == 0:
            self._depth += 1
            for stmt in node.body:
                self.visit(stmt)
            self._depth -= 1
        # nested defs: skipped (see class docstring)

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node: ast.Lambda):
        self.visit(node.body)  # inherits held set

    # -- LD001: guarded field access --------------------------------
    def visit_Attribute(self, node: ast.Attribute):
        attr = _self_attr(node)
        if attr is not None:
            lock = self.spec.field_lock(attr)
            if lock is not None and lock not in self.held:
                self._emit(
                    "LD001", node, attr,
                    f"field self.{attr} is guarded by self.{lock} "
                    f"(declared in _GUARDED_BY) but accessed without it")
        self.generic_visit(node)

    # -- LD002: blocking while holding ------------------------------
    def visit_Call(self, node: ast.Call):
        if self.held:
            self._check_blocking(node)
        self.generic_visit(node)

    def visit_Yield(self, node: ast.Yield):
        if self.held:
            self._emit(
                "LD002", node, "yield",
                f"yield while holding {sorted(self.held)}: the caller "
                "runs arbitrary code inside the locked region")
        self.generic_visit(node)

    visit_YieldFrom = visit_Yield

    def _check_blocking(self, node: ast.Call):
        func = node.func
        if not isinstance(func, ast.Attribute):
            return
        recv, meth = func.value, func.attr
        recv_attr = _self_attr(func)
        # user callback invoked under the lock (PR 6 deadlock shape)
        if recv_attr in self.spec.callbacks:
            self._emit(
                "LD002", node, recv_attr,
                f"user callback self.{recv_attr}() invoked while holding "
                f"{sorted(self.held)} — callback code can re-enter and "
                "deadlock (PR 6 shape)")
            return
        if meth == "sleep" and isinstance(recv, ast.Name) \
                and recv.id == "time":
            self._emit("LD002", node, "time.sleep",
                       f"time.sleep while holding {sorted(self.held)}")
            return
        if meth in ("wait", "wait_for"):
            # waiting on an alias of a lock we hold releases it (a
            # Condition over that lock) — that is the one safe shape
            if isinstance(recv, ast.Attribute):
                wait_attr = _self_attr(recv)
                if wait_attr and self.spec.canonical(wait_attr) in self.held:
                    return
            self._emit(
                "LD002", node, f"{meth}",
                f".{meth}() on a foreign object while holding "
                f"{sorted(self.held)} — blocks with the lock held")
            return
        if meth in _BLOCKING_ATTRS:
            if meth == "join" and isinstance(recv, ast.Constant) \
                    and isinstance(recv.value, str):
                return  # ", ".join(...)
            why = ("may run the callback inline under the lock"
                   if meth == "add_done_callback"
                   else "blocks (or runs arbitrary code) with the lock held")
            self._emit(
                "LD002", node, meth,
                f".{meth}() while holding {sorted(self.held)} — {why}")


def _iter_classes(tree: ast.Module):
    for node in tree.body:
        if isinstance(node, ast.ClassDef):
            yield node


def _methods(cls: ast.ClassDef):
    for node in cls.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def check_lock_discipline(tree: ast.Module, relpath: str) -> List[Finding]:
    """LD001/LD002/LD003 over one parsed module."""
    findings: List[Finding] = []
    for cls in _iter_classes(tree):
        spec = GuardSpec(cls)
        if not spec.declared:
            _check_undeclared_lock(cls, relpath, findings)
            continue
        for meth in _methods(cls):
            if meth.name == "__init__":
                continue
            checker = _MethodChecker(
                spec, meth.name, relpath, findings,
                all_held=spec.is_lock_held_method(meth.name))
            checker.visit(meth)
    return findings


def _check_undeclared_lock(cls: ast.ClassDef, relpath: str,
                           findings: List[Finding]):
    """LD003: ``self.x = threading.Lock()`` without ``_GUARDED_BY``."""
    for node in ast.walk(cls):
        if not isinstance(node, ast.Assign) or \
                not isinstance(node.value, ast.Call):
            continue
        func = node.value.func
        is_factory = (
            isinstance(func, ast.Attribute)
            and isinstance(func.value, ast.Name)
            and func.value.id == "threading"
            and func.attr in _LOCK_FACTORIES
        ) or (isinstance(func, ast.Name) and func.id in _LOCK_FACTORIES)
        if not is_factory:
            continue
        for tgt in node.targets:
            attr = _self_attr(tgt)
            if attr is not None:
                findings.append(Finding(
                    rule="LD003", path=relpath, line=node.lineno,
                    qualname=cls.name, detail=attr,
                    message=f"self.{attr} is a threading lock but "
                            f"{cls.name} declares no _GUARDED_BY — "
                            "undeclared locks escape LD001/LD002/LO001"))


# ---------------------------------------------------------------------------
# LO001: static lock-order graph
# ---------------------------------------------------------------------------

class _ClassInfo:
    def __init__(self, spec: GuardSpec, relpath: str,
                 method_names: Set[str]):
        self.spec = spec
        self.relpath = relpath
        self.method_names = method_names
        # method name -> True if the method acquires this class's lock
        self.acquiring: Set[str] = set()
        # call sites inside locked regions: (callee name, line)
        self.locked_calls: List[Tuple[str, int]] = []


class _RegionCallCollector(ast.NodeVisitor):
    """Collect (callee-name, line) for calls made inside locked
    regions of one method, plus whether the method acquires at all."""

    def __init__(self, info: _ClassInfo, all_held: bool):
        self.info = info
        self.spec = info.spec
        self.held = bool(all_held)
        self.acquires = False
        self._depth = 0

    def visit_With(self, node: ast.With):
        acquired = any(
            _acquired_lock(item, self.spec) is not None
            for item in node.items)
        if acquired:
            self.acquires = True
        prev = self.held
        self.held = self.held or acquired
        for stmt in node.body:
            self.visit(stmt)
        self.held = prev

    def visit_FunctionDef(self, node: ast.FunctionDef):
        if self._depth == 0:
            self._depth += 1
            for stmt in node.body:
                self.visit(stmt)
            self._depth -= 1

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node: ast.Lambda):
        self.visit(node.body)

    def visit_Call(self, node: ast.Call):
        if self.held and isinstance(node.func, ast.Attribute):
            recv_attr = _self_attr(node.func)
            # plain self.m() where m is a method of this class stays
            # in-class (reentrant RLock) — but self.cb() where cb is a
            # *callback attribute* escapes to whatever was wired in,
            # so it participates in the graph under the callee's name
            if recv_attr is None or recv_attr not in self.info.method_names:
                self.info.locked_calls.append(
                    (node.func.attr, node.lineno))
        self.generic_visit(node)

    def visit_Compare(self, node: ast.Compare):
        if self.held:
            for op, comparator in zip(node.ops, node.comparators):
                if isinstance(op, (ast.In, ast.NotIn)) \
                        and _self_attr(comparator) is None:
                    # `x in something-not-self-attr` -> __contains__
                    self.info.locked_calls.append(
                        ("__contains__", node.lineno))
        self.generic_visit(node)


def build_lock_graph(modules: Sequence[Tuple[ast.Module, str]]):
    """(classes, edges): edges is ``{(C, D): (relpath, line, callee)}``
    keyed on first-seen site."""
    classes: Dict[str, _ClassInfo] = {}
    for tree, relpath in modules:
        for cls in _iter_classes(tree):
            spec = GuardSpec(cls)
            if not spec.declared:
                continue
            info = _ClassInfo(spec, relpath,
                              {m.name for m in _methods(cls)})
            for meth in _methods(cls):
                if meth.name == "__init__":
                    continue
                col = _RegionCallCollector(
                    info, all_held=spec.is_lock_held_method(meth.name))
                col.visit(meth)
                if col.acquires or spec.is_lock_held_method(meth.name):
                    info.acquiring.add(meth.name)
            # a declared callback attribute is a lock-acquiring call
            # target for *whoever the runtime wires in*; the witness
            # covers that dynamically, the static graph covers the
            # one wiring the repo itself ships (directory.on_evict)
            classes[spec.name] = info

    # method name -> classes whose method of that name acquires
    acquiring_by_name: Dict[str, Set[str]] = {}
    for cname, info in classes.items():
        for m in info.acquiring:
            acquiring_by_name.setdefault(m, set()).add(cname)

    edges: Dict[Tuple[str, str], Tuple[str, int, str]] = {}
    for cname, info in classes.items():
        for callee, line in info.locked_calls:
            for target in acquiring_by_name.get(callee, ()):
                if target != cname and (cname, target) not in edges:
                    edges[(cname, target)] = (info.relpath, line, callee)
    return classes, edges


def _sccs(nodes: Set[str], edges) -> List[List[str]]:
    """Tarjan, iterative-enough for our graph sizes (recursive is fine
    for tens of classes)."""
    adj: Dict[str, List[str]] = {n: [] for n in nodes}
    for (a, b) in edges:
        adj.setdefault(a, []).append(b)
        adj.setdefault(b, adj.get(b, []))
    index: Dict[str, int] = {}
    low: Dict[str, int] = {}
    on_stack: Set[str] = set()
    stack: List[str] = []
    out: List[List[str]] = []
    counter = [0]

    def strong(v: str):
        index[v] = low[v] = counter[0]
        counter[0] += 1
        stack.append(v)
        on_stack.add(v)
        for w in adj.get(v, ()):
            if w not in index:
                strong(w)
                low[v] = min(low[v], low[w])
            elif w in on_stack:
                low[v] = min(low[v], index[w])
        if low[v] == index[v]:
            comp = []
            while True:
                w = stack.pop()
                on_stack.discard(w)
                comp.append(w)
                if w == v:
                    break
            out.append(comp)

    for n in sorted(adj):
        if n not in index:
            strong(n)
    return out


def check_lock_order(modules: Sequence[Tuple[ast.Module, str]]
                     ) -> List[Finding]:
    """LO001 over the whole scanned module set."""
    classes, edges = build_lock_graph(modules)
    findings: List[Finding] = []
    for comp in _sccs(set(classes), edges):
        if len(comp) < 2:
            continue
        comp_set = set(comp)
        cyc_edges = sorted(
            (a, b, edges[(a, b)]) for (a, b) in edges
            if a in comp_set and b in comp_set)
        first = cyc_edges[0][2]
        detail = "cycle:" + "<->".join(sorted(comp))
        lines = "; ".join(
            f"{a}->{b} via .{site[2]}() at {site[0]}:{site[1]}"
            for a, b, site in cyc_edges)
        findings.append(Finding(
            rule="LO001", path=first[0], line=first[1],
            qualname="<lock-graph>", detail=detail,
            message=f"lock-order cycle {' <-> '.join(sorted(comp))}: "
                    f"{lines}"))
    return findings


def analyze_source(text: str, relpath: str) -> List[Finding]:
    """LD001/LD002/LD003 + single-module LO001 over source text —
    the fixture-test entry point."""
    tree = ast.parse(text)
    rel = normalize_path(relpath)
    findings = check_lock_discipline(tree, rel)
    findings.extend(check_lock_order([(tree, rel)]))
    return findings
