"""`BlasxContext` — the persistent handle layer of the two-layer BLAS API.

The paper's central claim is that a locality-aware runtime with a
two-level tile cache (ALRU L1 per device + MESI-X L2 across peers)
makes communication cost trivial.  That only holds if the caches
*survive* between calls: a context owns one long-lived
:class:`~repro.core.runtime.BlasxRuntime` and keeps its tile caches
warm across routines, so chained workloads (Cholesky-style
``syrk -> trsm -> gemm`` sweeps, LM serving layers calling ``gemm``
per projection) stop re-paying H2D traffic on every call.

Key objects
-----------
``BlasxContext``
    cuBLAS-handle-style lifetime object.  All six L3 routines are
    methods (``ctx.gemm`` ... ``ctx.trsm``); each returns a
    :class:`MatrixHandle` that can be fed straight into the next call
    without re-tiling.  Per-call ledger snapshots live in
    ``ctx.calls``; cumulative counters in ``ctx.stats()``.
``MatrixHandle``
    A host matrix bound to a context under a globally unique
    ``matrix_id``.  Tile keys derive from that id, so a handle's tiles
    hit the warm ALRU/MESI-X caches on every subsequent call.  Handles
    from different contexts never alias.
``default_context()``
    Module-cached context used by the legacy ``repro.core.blas3``
    wrappers and the ``repro.api.cblas`` layer.

Example
-------
>>> from repro.api import BlasxContext
>>> with BlasxContext() as ctx:
...     W = ctx.tile(weights)          # device-resident handle
...     for x in batches:
...         y = ctx.gemm(ctx.tile(x), W)   # W's tiles stay cached
...         use(y.array())
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import json
import threading
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from ..core import task as taskmod
from ..core.dtypes import (SUPPORTED_DTYPES, canonical_dtype,
                           promote_dtypes, validate_backend_dtype)
from ..core.runtime import BlasxRuntime, RuntimeConfig
from ..core.tiling import TiledMatrix
from .futures import BlasFuture, SerialExecutor

DEFAULT_TILE = 256

# ctx.calls keeps at most this many CallRecords (cumulative counters in
# stats() are unaffected) so a long-lived default context stays bounded
MAX_CALL_RECORDS = 512

ArrayLike = Union[np.ndarray, "MatrixHandle"]

# one global id stream so handles never alias across contexts either
_MATRIX_IDS = itertools.count()


def _as2d(x, name: str, dtype=None) -> np.ndarray:
    a = np.asarray(x) if dtype is None else np.asarray(x, dtype=dtype)
    if a.ndim != 2:
        raise ValueError(f"{name} must be 2-D, got shape {a.shape}")
    return a


class MatrixHandle:
    """A tiled matrix registered with one :class:`BlasxContext`.

    The handle pins a globally unique ``matrix_id`` so that tile keys
    are stable across calls — the warm-cache contract.  The underlying
    data stays host-resident (the paper's out-of-core model); device
    copies of individual tiles live in the runtime's ALRU caches.

    Mutating ``handle.array()`` in place after tiles have been cached
    makes device copies stale; call :meth:`invalidate` afterwards.
    """

    def __init__(self, ctx: "BlasxContext", tiled: TiledMatrix):
        self._ctx = ctx
        self._tiled = tiled

    @property
    def matrix_id(self) -> str:
        return self._tiled.matrix_id

    @property
    def shape(self):
        return self._tiled.data.shape

    @property
    def tile(self) -> int:
        return self._tiled.grid.tile

    @property
    def dtype(self) -> np.dtype:
        """Storage precision of the handle (and of its cached tiles)."""
        return self._tiled.data.dtype

    @property
    def tiled(self) -> TiledMatrix:
        return self._tiled

    def array(self) -> np.ndarray:
        """The host-resident data (no copy)."""
        return self._tiled.data

    def invalidate(self) -> int:
        """Drop every cached device copy of this matrix's tiles.

        Needed after in-place mutation of :meth:`array`.  Returns the
        number of tiles dropped."""
        return self._ctx._invalidate_matrix(self.matrix_id)

    def __repr__(self) -> str:
        return (f"MatrixHandle({self.matrix_id}, shape={self.shape}, "
                f"tile={self.tile})")


@dataclasses.dataclass(frozen=True)
class CallRecord:
    """Ledger snapshot of one routine executed by a context (deltas
    against the runtime's cumulative counters)."""

    index: int
    routine: str
    h2d_bytes: int
    d2h_bytes: int
    d2d_bytes: int
    tasks: int
    steals: int
    l1_hits: int
    l1_misses: int
    makespan: float        # modeled seconds this call added (sim mode)
    # pod tier: ICI ring-scatter hops + neighbor-tier serves (0 on
    # plain accelerator contexts); defaulted so pickled/legacy records
    # stay constructible
    ici_bytes: int = 0

    @property
    def input_bytes(self) -> int:
        return self.h2d_bytes + self.d2d_bytes + self.ici_bytes


class BlasxContext:
    """Persistent two-level-cache BLAS handle (cuBLAS-handle analogue).

    Parameters
    ----------
    config:
        Any :class:`~repro.core.runtime.RuntimeConfig`; defaults to a
        single simulated device.  Ignored when ``runtime`` is given.
    runtime:
        Adopt an existing :class:`BlasxRuntime` instead of building
        one (used by the legacy wrappers' ``runtime=`` passthrough).
    tile:
        Default tile size for :meth:`tile` and auto-tiled numpy inputs.
    backend:
        Execution backend shorthand (``"numpy" | "jax" | "pallas"``);
        overrides ``config.backend``.  With ``runtime=`` it must match
        the adopted runtime's backend (a runtime's backend is fixed at
        construction).
    dtype:
        Default storage/compute precision for the context.  When set,
        :meth:`tile` and the routines cast raw-array operands to it
        and outputs are produced in it; tile byte sizes (ALRU/heap
        capacity, MESI-X transfer ledger, comm model) follow the
        storage dtype.  ``float64``/``float32`` run on every backend;
        ``float16``/``bfloat16`` need the jax or pallas backend (the
        engines accumulate them in float32).  ``None`` (default)
        preserves the legacy promote-from-inputs behaviour.  Each
        routine also takes a per-call ``dtype=`` that overrides this.
    auto_tune:
        Enable the shape-adaptive runtime autotuner
        (``repro.tuning``).  Raw-array calls without an explicit
        ``tile=`` then resolve their tile size per (routine, shape
        bucket, dtype) from the tuning cache — resolving cache misses
        per the tuner *mode* — and, while the context is still cold
        (no call has executed), the first tuned call may rebuild the
        runtime with the tuned ``n_streams``/``policy``.  Accepts a
        bool or a mode string: ``True`` / ``"sweep"`` sweeps every
        candidate ``(tile, n_streams, policy)`` through metadata-only
        shadow runs; ``"model"`` predicts makespans with the learned
        cost model (``repro.tuning.model``) and confirms the predicted
        winner in a single shadow run; ``"auto"`` uses the model only
        once it is trained and its uncertainty is tight, sweeping
        otherwise (see ``docs/TUNING.md``).  Calls on
        :class:`MatrixHandle` operands keep the handle's tile
        (re-tiling would break the warm-cache contract).  Any call may
        also pass ``tile="auto"`` explicitly — with or without
        ``auto_tune`` — to resolve just the tile size.
    tuning_cache:
        Where tuned configs persist: ``None`` (default) shares the
        process-wide cache (second context with the same topology is a
        pure cache hit), a path string gives a JSON file that also
        survives processes, or pass a ``repro.tuning.TuningCache``.

    The context is a context manager; :meth:`close` shuts down the
    async executor and drops all cached tiles.  All methods are
    thread-safe: calls serialize on one internal lock (the runtime is
    not re-entrant), which is also what makes :meth:`submit` futures
    well-ordered.
    """

    # lock-discipline declarations (repro.analysis, docs/ANALYSIS.md):
    # _lock is reentrant, so the routine wrappers may take it around
    # the lock-held helpers.  runtime/cfg/tile_size/dtype/_auto_tune/
    # _tune_mode/_tuning_cache/_owns_runtime are fixed after __init__
    # and stay unlisted.
    _GUARDED_BY = {"_lock": (
        "_closed", "_executor", "calls", "n_calls", "_tenant",
        "_boost", "_tuner")}
    _LOCK_HELD = ("_run", "_get_tuner", "_maybe_adopt_schedule")

    def __init__(self, config: Optional[RuntimeConfig] = None, *,
                 runtime: Optional[BlasxRuntime] = None,
                 tile: int = DEFAULT_TILE,
                 backend: Optional[str] = None,
                 dtype=None,
                 auto_tune: Union[bool, str] = False,
                 tuning_cache=None,
                 device_class: Optional[str] = None,
                 mesh: Optional[int] = None):
        if backend is not None:
            if runtime is not None:
                if runtime.cfg.backend != backend:
                    raise ValueError(
                        f"backend={backend!r} conflicts with adopted "
                        f"runtime's backend {runtime.cfg.backend!r}")
            elif config is None:
                config = RuntimeConfig(n_devices=1, mode="sim",
                                       backend=backend)
            elif config.backend != backend:
                config = dataclasses.replace(config, backend=backend)
        # pod-tier knobs: device_class= selects the DeviceClass each
        # runtime device models; mesh= sets the per-device ring width
        # and implies the mesh_shard class (a ring of 1 is just an
        # accelerator, so a bare mesh=N means "make these pod shards")
        if device_class is not None or mesh is not None:
            if runtime is not None:
                raise ValueError(
                    "device_class=/mesh= cannot be combined with an "
                    "adopted runtime= (set them on its RuntimeConfig)")
            config = config or RuntimeConfig(n_devices=1, mode="sim")
            if device_class is None and config.device_class == "accelerator":
                device_class = "mesh_shard"
            changes = {}
            if device_class is not None:
                changes["device_class"] = device_class
            if mesh is not None:
                changes["mesh_devices"] = mesh
            config = dataclasses.replace(config, **changes)
        self._owns_runtime = runtime is None
        self.runtime = runtime if runtime is not None else BlasxRuntime(
            config or RuntimeConfig(n_devices=1, mode="sim"))
        self.cfg = self.runtime.cfg
        self.tile_size = tile
        # fail fast: an unsupported (dtype, backend) pair is a config
        # error, not something to surface on the first routine call
        self.dtype = (validate_backend_dtype(dtype, self.cfg.backend)
                      if dtype is not None else None)
        self.calls: List[CallRecord] = []   # last MAX_CALL_RECORDS only
        self.n_calls = 0                    # lifetime count
        self._lock = threading.RLock()
        self._executor: Optional[SerialExecutor] = None
        self._closed = False
        # auto_tune accepts a bool (True == "sweep", the pre-model
        # behaviour) or a mode string; the mode also applies to
        # explicit tile="auto" calls on an auto_tune=False context
        if isinstance(auto_tune, str):
            from ..tuning import MODES
            if auto_tune not in MODES:
                raise ValueError(f"auto_tune must be a bool or one of "
                                 f"{MODES}, got {auto_tune!r}")
            self._auto_tune = True
            self._tune_mode = auto_tune
        else:
            self._auto_tune = bool(auto_tune)
            self._tune_mode = "sweep"
        self._tuning_cache = tuning_cache
        self._tuner = None                  # built lazily (repro.tuning)
        # serving attribution (repro.serve): tenant tag + priority-class
        # boost the next _run threads into the runtime; set via
        # request_scope so they cover exactly one request
        self._tenant: Optional[str] = None
        self._boost: float = 0.0

    # ------------------------------------------------------------ lifecycle
    def __enter__(self) -> "BlasxContext":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        """Shut down the async executor and drop all cached tiles.
        Idempotent; further routine calls raise ``RuntimeError``.

        The executor is drained *outside* the context lock: in-flight
        workers take that lock to run routines, so holding it through
        ``shutdown(wait=True)`` would deadlock the closing thread."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            executor, self._executor = self._executor, None
        if executor is not None:
            executor.shutdown()
        with self._lock:
            # an adopted runtime (runtime= in the constructor) belongs
            # to the caller — leave its caches and ledgers alone
            if self._owns_runtime:
                self.runtime.reset()

    @property
    def closed(self) -> bool:
        # _closed flips under _lock in close(); an unlocked read races
        # with a closing thread (LD001).  The RLock makes this safe to
        # take even from code already holding it.
        with self._lock:
            return self._closed

    def _check_open(self) -> None:
        with self._lock:
            closed = self._closed
        if closed:
            raise RuntimeError("BlasxContext is closed")

    def _resolve_dtype(self, dtype) -> Optional[np.dtype]:
        """Per-call ``dtype=`` beats the context default; ``None`` when
        neither is set (legacy promote-from-inputs).  Validated against
        the execution backend (half precisions are jax/pallas-only)."""
        if dtype is None:
            return self.dtype
        return validate_backend_dtype(dtype, self.cfg.backend)

    # ------------------------------------------------------------- handles
    def tile(self, data, tile: Optional[int] = None,
             dtype=None) -> MatrixHandle:
        """Register a host matrix and return its device-resident handle.

        Tiles fetched during later calls stay in the runtime's L1/L2
        caches keyed by this handle's unique ``matrix_id`` — reusing
        the handle is what turns repeat traffic into cache hits.

        ``dtype`` (or the context default) casts the data on
        registration; the handle then stores — and its tiles are
        cached/transferred at — that precision.  Validated against the
        backend up front: registering tiles at a precision the engine
        can never execute is a config error.  Re-registering an
        existing handle only enforces a dtype that was passed
        explicitly — a handle deliberately tiled at a non-default
        precision stays adoptable under the context default."""
        self._check_open()
        if isinstance(tile, str):
            # a handle has no routine context to tune against; callers
            # wanting tuned handles pre-resolve via auto_tile
            raise ValueError(
                "tile='auto' is resolved per routine call; use "
                "ctx.auto_tile(routine, m, k, n) to pre-resolve a tuned "
                "tile for ctx.tile()")
        dt = self._resolve_dtype(dtype)
        if isinstance(data, MatrixHandle):
            return self._adopt(data, dt if dtype is not None else None,
                               "matrix")
        a = _as2d(data, "matrix", dt)
        mid = f"M{next(_MATRIX_IDS)}"
        return MatrixHandle(self, TiledMatrix(mid, a, tile or self.tile_size))

    def _adopt(self, h: MatrixHandle, dtype=None,
               name: str = "matrix") -> MatrixHandle:
        if h._ctx is not self:
            raise ValueError(
                f"handle {h.matrix_id} belongs to a different context; "
                "tile caches do not transfer between contexts")
        if dtype is not None and h.array().dtype != dtype:
            # a handle owns its storage; recasting behind the caller's
            # back would silently decouple it from its cached tiles
            raise ValueError(
                f"{name}: handle {h.matrix_id} is {h.array().dtype}, "
                f"call requested dtype {np.dtype(dtype).name}; re-tile "
                "the data at the desired precision")
        return h

    def _coerce(self, x: ArrayLike, name: str, tile: Optional[int],
                ephemeral: List["MatrixHandle"],
                dtype: Optional[np.dtype] = None,
                strict: bool = False) -> MatrixHandle:
        """Handle passthrough; raw arrays are tiled fresh (cold) and
        recorded in ``ephemeral`` — their matrix id is unique to this
        one call, so any tiles they leave in the caches could never be
        hit again and are dropped right after the run (keeps legacy
        per-call traffic from squatting on cache capacity).  ``dtype``
        casts raw arrays; handles must already match it only when
        ``strict`` (an explicit per-call ``dtype=``) — a handle tiled
        at a non-default precision stays usable under the context
        default (its tiles are cached at its own dtype; only the
        output follows the default)."""
        if isinstance(x, MatrixHandle):
            if tile is not None and x.tile != tile:
                raise ValueError(
                    f"{name}: handle tile {x.tile} != requested tile {tile}")
            return self._adopt(x, dtype if strict else None, name)
        a = _as2d(x, name, dtype)
        # pass the resolved dtype through: tile() would otherwise
        # re-resolve against the context default and recast a per-call
        # dtype= override (None stays None -> tile applies the default)
        h = self.tile(a, tile or self.tile_size, dtype=dtype)
        ephemeral.append(h)
        return h

    def _fresh_out(self, rows: int, cols: int, tile: int, dtype,
                   seed: Optional[np.ndarray] = None) -> MatrixHandle:
        """New output matrix under a fresh id (seeded from C or zeros)."""
        if seed is not None:
            data = np.array(seed, dtype=dtype, copy=True)
        else:
            data = np.zeros((rows, cols), dtype=dtype)
        mid = f"M{next(_MATRIX_IDS)}"
        return MatrixHandle(self, TiledMatrix(mid, data, tile))

    def _invalidate_matrix(self, matrix_id: str) -> int:
        with self._lock:
            n = 0
            for dev in self.runtime.devices:
                for key in dev.alru.keys():
                    if key.matrix_id == matrix_id:
                        self.runtime.directory.on_evict(key, dev.id)
                        dev.alru.invalidate(key)
                        dev.store.pop(key, None)
                        n += 1
            return n

    # ------------------------------------------------------------ plumbing
    def _run(self, routine: str, tasks, mats: Dict[str, TiledMatrix],
             out_id: str,
             ephemeral: Optional[List[MatrixHandle]] = None) -> CallRecord:
        """Execute one taskized routine and append a ledger snapshot."""
        rt = self.runtime
        before_comm = rt.total_comm_bytes()
        before = [(d.ledger.tasks, d.ledger.steals, d.alru.hits,
                   d.alru.misses) for d in rt.devices]
        t0 = rt.makespan()
        rt.run(tasks, mats, out_id,
               tenant=self._tenant, priority_boost=self._boost)
        after_comm = rt.total_comm_bytes()
        d_tasks = sum(d.ledger.tasks for d in rt.devices) - \
            sum(b[0] for b in before)
        d_steals = sum(d.ledger.steals for d in rt.devices) - \
            sum(b[1] for b in before)
        d_hits = sum(d.alru.hits for d in rt.devices) - \
            sum(b[2] for b in before)
        d_miss = sum(d.alru.misses for d in rt.devices) - \
            sum(b[3] for b in before)
        for h in ephemeral or ():
            self._invalidate_matrix(h.matrix_id)
        rec = CallRecord(
            index=self.n_calls, routine=routine,
            h2d_bytes=after_comm["h2d"] - before_comm["h2d"],
            d2h_bytes=after_comm["d2h"] - before_comm["d2h"],
            d2d_bytes=after_comm["d2d"] - before_comm["d2d"],
            ici_bytes=after_comm["ici"] - before_comm["ici"],
            tasks=d_tasks, steals=d_steals,
            l1_hits=d_hits, l1_misses=d_miss,
            makespan=rt.makespan() - t0,
        )
        self.n_calls += 1
        self.calls.append(rec)
        if len(self.calls) > MAX_CALL_RECORDS:
            del self.calls[0]
        return rec

    @property
    def last_call(self) -> Optional[CallRecord]:
        # calls is mutated under _lock by _run; lock the read too
        with self._lock:
            return self.calls[-1] if self.calls else None

    # ------------------------------------------------------------- serving
    @contextlib.contextmanager
    def request_scope(self, tenant: Optional[str] = None,
                      priority_boost: float = 0.0):
        """Attribute every routine executed inside the ``with`` body to
        ``tenant`` (tagging its cached tiles for the per-tenant ALRU
        quotas) and add ``priority_boost`` to each task's Eq. 3
        locality priority.  Holds the context lock for the duration —
        routine execution takes the same (reentrant) lock, so scopes
        from concurrent threads serialize rather than interleave their
        attribution.  This is the channel ``repro.serve`` uses per
        request."""
        self._check_open()
        with self._lock:
            prev = (self._tenant, self._boost)
            self._tenant = tenant
            self._boost = float(priority_boost)
            try:
                yield self
            finally:
                self._tenant, self._boost = prev

    def set_tenant_quota(self, tenant: str,
                         nbytes: Optional[int]) -> None:
        """Cap ``tenant``'s resident tile-cache bytes on every device
        (None removes the cap); see
        :meth:`repro.core.runtime.BlasxRuntime.set_tenant_quota`."""
        self._check_open()
        with self._lock:
            self.runtime.set_tenant_quota(tenant, nbytes)

    # --------------------------------------------------------------- stats
    def stats(self) -> Dict[str, object]:
        """Cumulative session counters: total comm bytes, per-device
        ledgers, call count, modeled makespan."""
        rt = self.runtime
        with self._lock:
            n_calls = self.n_calls
        return {
            "calls": n_calls,
            "backend": rt.cfg.backend,
            "comm_bytes": rt.total_comm_bytes(),
            "makespan": rt.makespan(),
            "launch": rt.launch_stats(),
            "devices": rt.stats(),
        }

    def trace(self, path: Optional[str] = None) -> dict:
        """Chrome-trace JSON of every sim batch this context scheduled.

        Open the written file in ``chrome://tracing`` or
        https://ui.perfetto.dev: one track group per device, one track
        per stream and per H2D/D2D/D2H link lane, so stream overlap
        and host-link contention are visible span by span.  The trace
        accumulates across calls; :meth:`reset` starts a fresh one.
        With ``path`` the JSON is also written to disk.  Outside the
        sim event engine (``mode="threads"`` /
        ``time_model="lump"``) the trace is valid but has no spans."""
        self._check_open()
        with self._lock:
            tr = self.runtime.trace()
        if path is not None:
            with open(path, "w") as f:
                json.dump(tr, f)
        return tr

    def reset_stats(self) -> None:
        """Zero every ledger/counter *without* dropping cached tiles —
        session-boundary accounting for long-lived contexts."""
        with self._lock:
            self.runtime.reset_stats()
            self.calls.clear()
            self.n_calls = 0

    def reset(self) -> None:
        """Drop all cached tiles AND zero all counters (cold restart)."""
        with self._lock:
            self.runtime.reset()
            self.calls.clear()
            self.n_calls = 0

    # ---------------------------------------------------------------- async
    def submit(self, routine, *args, **kwargs) -> BlasFuture:
        """Submit an L3 call for asynchronous execution.

        ``routine`` is a routine name (``"gemm"`` ... ``"trsm"``,
        ``"gemm_batched"``) or any callable.  Returns a
        :class:`BlasFuture`; the result is whatever the synchronous
        method returns (a :class:`MatrixHandle` for the six routines).
        Submissions execute in order on a background thread, so
        independent calls overlap with the caller and chained calls
        may safely pass not-yet-materialized handles obtained from
        ``future.result()``."""
        if isinstance(routine, str):
            fn = getattr(self, routine, None)
            if fn is None or not callable(fn):
                raise ValueError(f"unknown routine {routine!r}")
        else:
            fn = routine
        # closed-check, lazy creation and enqueue all under the lock so a
        # concurrent close() can neither leak a fresh executor nor null
        # the one we are about to use
        with self._lock:
            self._check_open()
            if self._executor is None:
                self._executor = SerialExecutor(name="blasx-ctx")
            return self._executor.submit(fn, *args, **kwargs)

    # ==================================================== runtime autotuning
    def _get_tuner(self):
        """Lazily build the :class:`repro.tuning.Autotuner` bound to
        this context's topology (imported here: tuning depends on
        core.runtime, the api layer must not import it eagerly)."""
        if self._tuner is None:
            from ..tuning import Autotuner
            self._tuner = Autotuner(self.cfg, cache=self._tuning_cache,
                                    mode=self._tune_mode,
                                    default_tile=self.tile_size)
        return self._tuner

    def auto_tile(self, routine: str, m: int, k: Optional[int] = None,
                  n: Optional[int] = None, dtype=None) -> int:
        """Resolve the tuned tile size for one (routine, shape, dtype).

        Consults the tuning cache (topology fingerprint + routine +
        shape bucket + dtype); on a miss, sweeps candidate
        ``(tile, n_streams, policy)`` configs through metadata-only
        shadow runs on the virtual clock and caches the winner.  With
        ``auto_tune=True`` and a still-cold context the tuned
        scheduling knobs are also adopted (see :meth:`tuning_report`).
        This is what ``tile="auto"`` calls under the hood; batched
        entry points use it to resolve one tile for a whole batch."""
        self._check_open()
        with self._lock:
            dt = self._resolve_dtype(dtype)
            best = self._get_tuner().tune(
                routine, m, k, n, dtype=dt if dt is not None else np.float64)
            self._maybe_adopt_schedule(best)
            return best.tile

    def _maybe_adopt_schedule(self, best) -> None:
        """Adopt the tuned ``(n_streams, policy)`` by rebuilding the
        runtime — only with ``auto_tune=True``, only on a context that
        owns its runtime, and only while it is still cold (nothing
        executed, so no warm cache or ledger is lost).  The first
        tuned call pins the schedule; later calls tune tiles only."""
        if not self._auto_tune or not self._owns_runtime:
            return
        if self.runtime.runs > 0 or self.n_calls > 0:
            return
        wc = bool(getattr(best, "work_centric", False))
        if (best.n_streams == self.cfg.n_streams
                and best.policy == self.cfg.policy
                and wc == self.cfg.work_centric):
            return
        cfg = dataclasses.replace(self.cfg, n_streams=best.n_streams,
                                  rs_slots=None, policy=best.policy,
                                  work_centric=wc)
        self.runtime = BlasxRuntime(cfg)
        self.cfg = cfg

    def _tile_arg(self, tile, routine: str, m: int, k: int, n: int,
                  dtype, operands) -> Optional[int]:
        """Resolve a routine's ``tile=`` argument, which may be an int
        (as ever), ``"auto"`` (tune this call), or ``None`` — which
        under ``auto_tune=True`` tunes too, unless an operand is a
        :class:`MatrixHandle` (its tile is pinned by the warm-cache
        contract; re-tiling behind the caller would break it)."""
        if isinstance(tile, str):
            if tile != "auto":
                raise ValueError(f"tile must be an int or 'auto', "
                                 f"got {tile!r}")
        elif not (tile is None and self._auto_tune and not any(
                isinstance(x, MatrixHandle) for x in operands)):
            return tile
        if dtype is None:
            # tune at the operands' storage precision (it halves/doubles
            # the modeled byte volume); fall back to f64 for exotic
            # legacy dtypes outside the registry
            try:
                dt = _array_of(operands[0]).dtype
                for x in operands[1:]:
                    dt = promote_dtypes(dt, _array_of(x).dtype)
                dtype = canonical_dtype(dt)
            except Exception:
                dtype = np.float64
        return self.auto_tile(routine, m, k, n, dtype=dtype)

    def tuning_report(self) -> Dict[str, object]:
        """Introspection for the autotuner: fingerprint, sweep/cache
        counters split by provenance (file-cache vs process-cache hits,
        model adoptions vs sweeps vs fallbacks), candidate spaces, the
        per-key tuning decisions this context made, and the schedule
        knobs currently applied."""
        with self._lock:
            if self._tuner is None:
                return {"enabled": self._auto_tune,
                        "mode": self._tune_mode,
                        "sweeps": 0, "bucket_sweeps": 0,
                        "confirmations": 0,
                        "cache_hits": 0, "file_cache_hits": 0,
                        "process_cache_hits": 0,
                        "model_adoptions": 0, "model_fallbacks": 0,
                        "cache_entries": 0, "entries": []}
            rep = self._get_tuner().report()
            rep["enabled"] = self._auto_tune
            rep["applied"] = {"tile_default": self.tile_size,
                              "n_streams": self.cfg.n_streams,
                              "policy": self.cfg.policy,
                              "work_centric": self.cfg.work_centric}
            return rep

    # ======================================================== L3 routines
    def gemm(self, A: ArrayLike, B: ArrayLike, C: Optional[ArrayLike] = None,
             *, alpha: float = 1.0, beta: float = 0.0,
             transa: str = "N", transb: str = "N",
             tile: Optional[int] = None, dtype=None) -> MatrixHandle:
        """C = alpha * op(A) @ op(B) + beta * C   (Eq. 1a)."""
        self._check_open()
        transa, transb = transa.upper()[0], transb.upper()[0]
        dt = self._resolve_dtype(dtype)
        strict = dtype is not None
        with self._lock:
            a_sh, b_sh = _shape_of(A), _shape_of(B)
            tile = self._tile_arg(
                tile, "gemm",
                a_sh[0] if transa == "N" else a_sh[1],
                a_sh[1] if transa == "N" else a_sh[0],
                b_sh[1] if transb == "N" else b_sh[0], dt, (A, B))
            eph: List[MatrixHandle] = []
            Ah = self._coerce(A, "A", tile, eph, dt, strict)
            Bh = self._coerce(B, "B", tile, eph, dt, strict)
            self._check_tiles(Ah, Bh)
            t = Ah.tile
            m = Ah.shape[0] if transa == "N" else Ah.shape[1]
            k = Ah.shape[1] if transa == "N" else Ah.shape[0]
            kb = Bh.shape[0] if transb == "N" else Bh.shape[1]
            n = Bh.shape[1] if transb == "N" else Bh.shape[0]
            if k != kb:
                raise ValueError(f"inner dims mismatch: {k} vs {kb}")
            out_dt = dt if dt is not None else promote_dtypes(
                Ah.array().dtype, Bh.array().dtype)
            self._check_exec_dtype(out_dt, Ah.dtype, Bh.dtype)
            out = self._prep_c(C, (m, n), t, out_dt, beta,
                               force=dt is not None)
            tasks = taskmod.taskize_gemm(Ah.tiled.grid, Bh.tiled.grid,
                                         out.tiled.grid, transa, transb,
                                         alpha, beta)
            mats = {h.matrix_id: h.tiled for h in (Ah, Bh, out)}
            self._run("gemm", tasks, mats, out.matrix_id, eph)
            return out

    def syrk(self, A: ArrayLike, C: Optional[ArrayLike] = None, *,
             alpha: float = 1.0, beta: float = 0.0, uplo: str = "U",
             trans: str = "N", tile: Optional[int] = None,
             dtype=None) -> MatrixHandle:
        """C = alpha * op(A) @ op(A)^T + beta * C, uplo triangle (Eq. 1b)."""
        self._check_open()
        trans = trans.upper()[0]
        dt = self._resolve_dtype(dtype)
        strict = dtype is not None
        with self._lock:
            a_sh = _shape_of(A)
            nt, kt = (a_sh if trans == "N" else a_sh[::-1])
            tile = self._tile_arg(tile, "syrk", nt, kt, nt, dt, (A,))
            eph: List[MatrixHandle] = []
            Ah = self._coerce(A, "A", tile, eph, dt, strict)
            n = Ah.shape[0] if trans == "N" else Ah.shape[1]
            out_dt = dt if dt is not None else Ah.array().dtype
            self._check_exec_dtype(out_dt, Ah.dtype)
            out = self._prep_c(C, (n, n), Ah.tile, out_dt, beta,
                               force=dt is not None)
            tasks = taskmod.taskize_syrk(Ah.tiled.grid, out.tiled.grid,
                                         uplo, trans, alpha, beta)
            mats = {h.matrix_id: h.tiled for h in (Ah, out)}
            self._run("syrk", tasks, mats, out.matrix_id, eph)
            return out

    def syr2k(self, A: ArrayLike, B: ArrayLike,
              C: Optional[ArrayLike] = None, *, alpha: float = 1.0,
              beta: float = 0.0, uplo: str = "U", trans: str = "N",
              tile: Optional[int] = None, dtype=None) -> MatrixHandle:
        """C = alpha*(op(A)op(B)^T + op(B)op(A)^T) + beta*C (Eq. 1e)."""
        self._check_open()
        trans = trans.upper()[0]
        dt = self._resolve_dtype(dtype)
        strict = dtype is not None
        with self._lock:
            a_sh = _shape_of(A)
            nt, kt = (a_sh if trans == "N" else a_sh[::-1])
            tile = self._tile_arg(tile, "syr2k", nt, kt, nt, dt, (A, B))
            eph: List[MatrixHandle] = []
            Ah = self._coerce(A, "A", tile, eph, dt, strict)
            Bh = self._coerce(B, "B", tile, eph, dt, strict)
            self._check_tiles(Ah, Bh)
            n = Ah.shape[0] if trans == "N" else Ah.shape[1]
            out_dt = dt if dt is not None else promote_dtypes(
                Ah.array().dtype, Bh.array().dtype)
            self._check_exec_dtype(out_dt, Ah.dtype, Bh.dtype)
            out = self._prep_c(C, (n, n), Ah.tile, out_dt, beta,
                               force=dt is not None)
            tasks = taskmod.taskize_syr2k(Ah.tiled.grid, Bh.tiled.grid,
                                          out.tiled.grid, uplo, trans,
                                          alpha, beta)
            mats = {h.matrix_id: h.tiled for h in (Ah, Bh, out)}
            self._run("syr2k", tasks, mats, out.matrix_id, eph)
            return out

    def symm(self, A: ArrayLike, B: ArrayLike,
             C: Optional[ArrayLike] = None, *, alpha: float = 1.0,
             beta: float = 0.0, side: str = "L", uplo: str = "U",
             tile: Optional[int] = None, dtype=None) -> MatrixHandle:
        """C = alpha * sym(A) @ B + beta * C (side='L'; Eq. 1f).

        ``side='R'`` reduces to the left-side tile algorithm via the
        §III-C transpose identity; it operates on transposed host
        copies, so cache reuse applies within — not across — the call,
        and the copies are coerced like raw arrays: a context default
        dtype applies to them (a handle's storage precision is only
        preserved on ``side='L'``; pass an explicit per-call ``dtype=``
        to pin the precision on either side).
        """
        self._check_open()
        side = side.upper()[0]
        if side == "R":
            # same handle-ownership/dtype rules as side='L' before the
            # operands degrade to raw transposed copies.  C is exempt
            # on both sides: it only seeds the output (cast freely),
            # it never becomes a cached-tile operand.
            self._check_side_r_handles(dtype, A=A, B=B)
            # C = alpha*B*A + beta*C  ==  (alpha*A*B^T + beta*C^T)^T
            Bt = np.ascontiguousarray(_array_of(B).T)
            Ct = None if C is None else \
                np.ascontiguousarray(_as2d(_array_of(C), "C").T)
            out = self.symm(_array_of(A), Bt, Ct, alpha=alpha, beta=beta,
                            side="L", uplo=uplo, tile=tile, dtype=dtype)
            return self._transposed_result(out)
        dt = self._resolve_dtype(dtype)
        strict = dtype is not None
        with self._lock:
            b_sh = _shape_of(B)
            tile = self._tile_arg(tile, "symm", b_sh[0], b_sh[0], b_sh[1],
                                  dt, (A, B))
            eph: List[MatrixHandle] = []
            Ah = self._coerce(A, "A", tile, eph, dt, strict)
            Bh = self._coerce(B, "B", tile, eph, dt, strict)
            self._check_tiles(Ah, Bh)
            m, n = Bh.shape
            if Ah.shape != (m, m):
                raise ValueError(f"A must be ({m},{m}), got {Ah.shape}")
            out_dt = dt if dt is not None else promote_dtypes(
                Ah.array().dtype, Bh.array().dtype)
            self._check_exec_dtype(out_dt, Ah.dtype, Bh.dtype)
            out = self._prep_c(C, (m, n), Ah.tile, out_dt, beta,
                               force=dt is not None)
            tasks = taskmod.taskize_symm(Ah.tiled.grid, Bh.tiled.grid,
                                         out.tiled.grid, uplo, alpha, beta)
            mats = {h.matrix_id: h.tiled for h in (Ah, Bh, out)}
            self._run("symm", tasks, mats, out.matrix_id, eph)
            return out

    def trmm(self, A: ArrayLike, B: ArrayLike, *, alpha: float = 1.0,
             side: str = "L", uplo: str = "U", transa: str = "N",
             diag: str = "N", tile: Optional[int] = None,
             dtype=None) -> MatrixHandle:
        """B := alpha * op(tri(A)) @ B (side='L'; Eq. 1d), returned as a
        new handle (functional, B is not overwritten)."""
        self._check_open()
        side = side.upper()[0]
        if side == "R":
            self._check_side_r_handles(dtype, A=A, B=B)
            # B*op(A) == (op(A)^T B^T)^T — §III-C at matrix granularity
            flip = "T" if transa.upper()[0] == "N" else "N"
            out = self.trmm(_array_of(A),
                            np.ascontiguousarray(_array_of(B).T),
                            alpha=alpha, side="L", uplo=uplo, transa=flip,
                            diag=diag, tile=tile, dtype=dtype)
            return self._transposed_result(out)
        dt = self._resolve_dtype(dtype)
        strict = dtype is not None
        with self._lock:
            b_sh = _shape_of(B)
            tile = self._tile_arg(tile, "trmm", b_sh[0], b_sh[0], b_sh[1],
                                  dt, (A, B))
            eph: List[MatrixHandle] = []
            Ah = self._coerce(A, "A", tile, eph, dt, strict)
            Bh = self._coerce(B, "B", tile, eph, dt, strict)
            self._check_tiles(Ah, Bh)
            m, n = Bh.shape
            if Ah.shape != (m, m):
                raise ValueError(f"A must be ({m},{m}), got {Ah.shape}")
            # legacy semantics: TRMM's result keeps B's dtype (unless an
            # explicit dtype= pinned the call's precision)
            out_dt = dt if dt is not None else Bh.array().dtype
            self._check_exec_dtype(out_dt, Ah.dtype, Bh.dtype)
            out = self._fresh_out(m, n, Ah.tile, out_dt)
            # B's tiles are the taskization's Cin inputs: a reused handle
            # serves them straight from the warm cache.
            tasks = taskmod.taskize_trmm(Ah.tiled.grid, Bh.tiled.grid,
                                         out.tiled.grid, uplo, transa,
                                         diag, alpha)
            mats = {h.matrix_id: h.tiled for h in (Ah, Bh, out)}
            self._run("trmm", tasks, mats, out.matrix_id, eph)
            return out

    def trsm(self, A: ArrayLike, B: ArrayLike, *, alpha: float = 1.0,
             side: str = "L", uplo: str = "U", transa: str = "N",
             diag: str = "N", tile: Optional[int] = None,
             dtype=None) -> MatrixHandle:
        """Solve op(tri(A)) @ X = alpha * B (side='L'; Eq. 1c); returns X."""
        self._check_open()
        side = side.upper()[0]
        if side == "R":
            self._check_side_r_handles(dtype, A=A, B=B)
            # X*op(A) = alpha*B  ==  op(A)^T X^T = alpha B^T
            flip = "T" if transa.upper()[0] == "N" else "N"
            out = self.trsm(_array_of(A),
                            np.ascontiguousarray(_array_of(B).T),
                            alpha=alpha, side="L", uplo=uplo, transa=flip,
                            diag=diag, tile=tile, dtype=dtype)
            return self._transposed_result(out)
        dt = self._resolve_dtype(dtype)
        strict = dtype is not None
        with self._lock:
            b_sh = _shape_of(B)
            tile = self._tile_arg(tile, "trsm", b_sh[0], b_sh[0], b_sh[1],
                                  dt, (A, B))
            eph: List[MatrixHandle] = []
            Ah = self._coerce(A, "A", tile, eph, dt, strict)
            Bh = self._coerce(B, "B", tile, eph, dt, strict)
            self._check_tiles(Ah, Bh)
            m, n = Bh.shape
            if Ah.shape != (m, m):
                raise ValueError(f"A must be ({m},{m}), got {Ah.shape}")
            out_dt = dt if dt is not None else promote_dtypes(
                Ah.array().dtype, Bh.array().dtype)
            self._check_exec_dtype(out_dt, Ah.dtype, Bh.dtype)
            out = self._fresh_out(m, n, Ah.tile, out_dt)
            tasks = taskmod.taskize_trsm(Ah.tiled.grid, Bh.tiled.grid,
                                         out.tiled.grid, uplo, transa,
                                         diag, alpha)
            mats = {h.matrix_id: h.tiled for h in (Ah, Bh, out)}
            self._run("trsm", tasks, mats, out.matrix_id, eph)
            return out

    # --------------------------------------------------------- batched API
    def gemm_batched(self, As: Sequence[ArrayLike], Bs: Sequence[ArrayLike],
                     Cs: Optional[Sequence[ArrayLike]] = None, *,
                     alpha: float = 1.0, beta: float = 0.0,
                     transa: str = "N", transb: str = "N",
                     tile: Optional[int] = None,
                     dtype=None) -> List[MatrixHandle]:
        """Pointer-array style batch (cublasDgemmBatched analogue)."""
        from .batch import gemm_batched
        return gemm_batched(self, As, Bs, Cs, alpha=alpha, beta=beta,
                            transa=transa, transb=transb, tile=tile,
                            dtype=dtype)

    def gemm_strided_batched(self, A, B, C=None, *, alpha: float = 1.0,
                             beta: float = 0.0, transa: str = "N",
                             transb: str = "N",
                             tile: Optional[int] = None,
                             dtype=None) -> np.ndarray:
        """3-D strided batch (cublasDgemmStridedBatched analogue)."""
        from .batch import gemm_strided_batched
        return gemm_strided_batched(self, A, B, C, alpha=alpha, beta=beta,
                                    transa=transa, transb=transb, tile=tile,
                                    dtype=dtype)

    # ------------------------------------------------------------- helpers
    def _check_side_r_handles(self, dtype, **operands) -> None:
        """side='R' reductions degrade handles to raw transposed
        copies; enforce the same ownership and dtype-mismatch rules
        the side='L' coercion path applies, so both sides reject an
        explicit ``dtype=`` that contradicts a handle's storage instead
        of silently recasting.  Like side='L', the context default is
        not enforced against handles — only a per-call override is."""
        dt = self._resolve_dtype(dtype) if dtype is not None else None
        for name, x in operands.items():
            if isinstance(x, MatrixHandle):
                self._adopt(x, dt, name)

    def _check_exec_dtype(self, *dts) -> None:
        """Gate inferred dtypes — the output AND every input's storage
        dtype (a half-precision operand crawls through the engine even
        when promotion widens the output) — against the backend.  Only
        registry dtypes with a restricted backend set are checked
        (currently the half precisions, jax/pallas-only —
        ``repro.core.dtypes`` is the source of truth); anything
        outside the registry — legacy exotic dtypes numpy happens to
        promote to — keeps the pre-multi-precision behaviour."""
        for dt in dts:
            allowed = SUPPORTED_DTYPES.get(np.dtype(dt).name)
            if allowed is not None and self.cfg.backend not in allowed:
                validate_backend_dtype(dt, self.cfg.backend)  # raises

    @staticmethod
    def _check_tiles(*handles: "MatrixHandle") -> None:
        tiles = {h.tile for h in handles}
        if len(tiles) > 1:
            names = ", ".join(f"{h.matrix_id}={h.tile}" for h in handles)
            raise ValueError(f"tile mismatch: {names}")

    def _transposed_result(self, out: MatrixHandle) -> MatrixHandle:
        """§III-C side='R' epilogue: re-tile the transposed result and
        drop the intermediate handle's cached tiles — the caller never
        sees it, so they could only ever be dead weight.

        The handle is built directly (like :meth:`_fresh_out`): the
        left-side call already resolved and validated the output dtype,
        and ``tile(dtype=arr.dtype)`` would re-validate it against the
        registry — rejecting legacy exotic result dtypes (e.g. integer
        inputs promoted by the left-side call) that this epilogue must
        preserve as-is."""
        arr = np.ascontiguousarray(out.array().T)
        mid = f"M{next(_MATRIX_IDS)}"
        res = MatrixHandle(self, TiledMatrix(mid, arr, out.tile))
        out.invalidate()
        return res

    def _prep_c(self, C: Optional[ArrayLike], shape, tile: int, dtype,
                beta: float, force: bool = False) -> MatrixHandle:
        if C is None:
            if beta != 0.0:
                raise ValueError("beta != 0 requires C")
            return self._fresh_out(shape[0], shape[1], tile, dtype)
        c = _as2d(_array_of(C), "C")
        if c.shape != shape:
            raise ValueError(f"C shape {c.shape} != {shape}")
        if force:
            # explicit dtype= call: the requested precision wins (C is
            # cast into the output seed; dtype was validated upstream)
            return self._fresh_out(shape[0], shape[1], tile, dtype, seed=c)
        # legacy semantics: the output keeps C's dtype (the runtime
        # downcasts each written tile via astype).  C's dtype IS the
        # real output dtype here, so it — not the promoted out_dt the
        # call site checked — must pass the backend gate: a bf16 C
        # would otherwise put half-precision tiles through the engine.
        self._check_exec_dtype(c.dtype)
        return self._fresh_out(shape[0], shape[1], tile, c.dtype, seed=c)


def _array_of(x: ArrayLike) -> np.ndarray:
    return x.array() if isinstance(x, MatrixHandle) else np.asarray(x)


def _shape_of(x: ArrayLike):
    """2-D shape of an operand without coercing it (tile resolution
    needs dims before tiling can happen)."""
    sh = x.shape if isinstance(x, MatrixHandle) else np.asarray(x).shape
    if len(sh) != 2:
        raise ValueError(f"operand must be 2-D, got shape {sh}")
    return tuple(sh)


# ---------------------------------------------------------- default context
_default_ctx: Optional[BlasxContext] = None
_default_lock = threading.Lock()

# per-backend default contexts: legacy callers opting into an execution
# backend per call (backend="jax") share one warm-cache context per
# backend, mirroring the unnamed default below
_backend_ctxs: Dict[str, BlasxContext] = {}


def default_context() -> BlasxContext:
    """The module-cached context backing the legacy ``blas3`` functions
    and the ``cblas_*`` layer (created on first use, kept warm)."""
    global _default_ctx
    with _default_lock:
        if _default_ctx is None or _default_ctx.closed:
            _default_ctx = BlasxContext(
                RuntimeConfig(n_devices=1, mode="sim"))
        return _default_ctx


def backend_context(backend: str) -> BlasxContext:
    """The module-cached warm context for one execution backend — the
    ``backend=`` analogue of :func:`default_context`, shared by the
    ``blas3`` and ``cblas`` legacy layers so chained per-call usage
    still hits warm tile caches.

    When the requested backend matches the unnamed default context's
    (the usual ``numpy`` case), the *same* context is shared — mixing
    ``gemm(A, B)`` and ``gemm(A, B, backend="numpy")`` must warm one
    tile cache, not two."""
    global _default_ctx
    with _default_lock:
        d = _default_ctx
        if d is not None and not d.closed and d.cfg.backend == backend:
            return d
        ctx = _backend_ctxs.get(backend)
        if ctx is None or ctx.closed:
            ctx = BlasxContext(RuntimeConfig(n_devices=1, mode="sim",
                                             backend=backend))
            if backend == "numpy" and (d is None or d.closed):
                # this IS the default config; claim the default slot so a
                # later default_context() shares the same warm caches
                _default_ctx = ctx
            else:
                _backend_ctxs[backend] = ctx
        return ctx


def set_default_context(ctx: Optional[BlasxContext]) -> Optional[BlasxContext]:
    """Swap the process-wide default context; returns the previous one
    (not closed — the caller decides its fate)."""
    global _default_ctx
    with _default_lock:
        prev, _default_ctx = _default_ctx, ctx
        return prev
