"""Batched GEMM for serving-shaped workloads (cuBLAS batched analogues).

Serving traffic is many small/medium GEMMs against shared weights
(per-layer projections, per-request adapters).  Both entry points run
every problem through ONE context, so shared operands — e.g. the same
weight handle across the whole batch — are fetched once and then served
from the warm tile caches.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np


def gemm_batched(ctx, As: Sequence, Bs: Sequence,
                 Cs: Optional[Sequence] = None, *, alpha: float = 1.0,
                 beta: float = 0.0, transa: str = "N", transb: str = "N",
                 tile: Optional[int] = None, dtype=None) -> List:
    """Pointer-array batch: ``out[i] = alpha*op(As[i])@op(Bs[i]) +
    beta*Cs[i]``.

    ``As``/``Bs`` may mix numpy arrays and ``MatrixHandle``s; repeating
    one handle across the batch (shared weights) is the intended warm
    path.  ``dtype`` pins the batch's storage precision (same rules as
    ``ctx.gemm``).  ``tile="auto"`` resolves ONE tuned tile from the
    first entry's shape (via the runtime autotuner) and applies it to
    the whole batch — batch entries share tile keys, so they must
    share a tile size.  Returns a list of ``MatrixHandle``s.
    """
    if len(As) != len(Bs):
        raise ValueError(f"batch mismatch: {len(As)} A's vs {len(Bs)} B's")
    if Cs is not None and len(Cs) != len(As):
        raise ValueError(f"batch mismatch: {len(As)} A's vs {len(Cs)} C's")
    if tile == "auto":
        tile = _auto_batch_tile(ctx, As[0], Bs[0], transa, transb, dtype)
    # pre-register handles so every batch entry shares tile keys
    Ahs = [ctx.tile(a, tile, dtype=dtype) for a in As]
    Bhs = [ctx.tile(b, tile, dtype=dtype) for b in Bs]
    # synchronous loop, NOT ctx.submit per entry: the context serializes
    # execution on its lock anyway, and nesting submissions would
    # deadlock the single-worker executor when the batch itself was
    # submitted asynchronously (ctx.submit("gemm_batched", ...)).
    return [
        ctx.gemm(Ahs[i], Bhs[i], None if Cs is None else Cs[i],
                 alpha=alpha, beta=beta, transa=transa, transb=transb,
                 tile=tile, dtype=dtype)
        for i in range(len(As))
    ]


def _auto_batch_tile(ctx, a0, b0, transa: str, transb: str, dtype) -> int:
    """Resolve one tuned tile for a whole GEMM batch from its first
    entry's logical (m, k, n) — batched entries are same-shaped in the
    cuBLAS contract, and near-shaped entries land in the same tuning
    bucket anyway."""
    a_sh = a0.shape if hasattr(a0, "shape") else np.asarray(a0).shape
    b_sh = b0.shape if hasattr(b0, "shape") else np.asarray(b0).shape
    ta, tb = transa.upper()[0], transb.upper()[0]
    m, k = (a_sh[0], a_sh[1]) if ta == "N" else (a_sh[1], a_sh[0])
    n = b_sh[1] if tb == "N" else b_sh[0]
    return ctx.auto_tile("gemm", m, k, n, dtype=dtype)


def gemm_strided_batched(ctx, A, B, C=None, *, alpha: float = 1.0,
                         beta: float = 0.0, transa: str = "N",
                         transb: str = "N",
                         tile: Optional[int] = None,
                         dtype=None) -> np.ndarray:
    """Strided batch over 3-D operands (batch axis first).

    A 2-D operand broadcasts across the batch (stride 0 — the shared
    weight matrix of an LM projection); its handle is registered once
    so all batch entries hit the same cached tiles.  Returns the
    stacked 3-D result.
    """
    A = np.asarray(A) if not hasattr(A, "array") else A
    B = np.asarray(B) if not hasattr(B, "array") else B

    def _entries(x):
        if hasattr(x, "array") or np.asarray(x).ndim == 2:
            return None  # broadcast
        a = np.asarray(x)
        if a.ndim != 3:
            raise ValueError(f"strided batch operand must be 2-D or 3-D, "
                             f"got {a.shape}")
        return a

    a3, b3 = _entries(A), _entries(B)
    c3 = None if C is None else _entries(C)
    sizes = {x.shape[0] for x in (a3, b3, c3) if x is not None}
    if len(sizes) > 1:
        raise ValueError(f"inconsistent batch sizes {sorted(sizes)}")
    if not sizes:
        raise ValueError("at least one operand must be 3-D")
    nb = sizes.pop()

    if tile == "auto":
        tile = _auto_batch_tile(ctx, A if a3 is None else a3[0],
                                B if b3 is None else b3[0],
                                transa, transb, dtype)
    # broadcast operands become one shared handle (stride-0 reuse)
    Ah = ctx.tile(A, tile, dtype=dtype) if a3 is None else None
    Bh = ctx.tile(B, tile, dtype=dtype) if b3 is None else None
    outs = gemm_batched(
        ctx,
        [Ah if a3 is None else a3[i] for i in range(nb)],
        [Bh if b3 is None else b3[i] for i in range(nb)],
        None if C is None else [C if c3 is None else c3[i]
                                for i in range(nb)],
        alpha=alpha, beta=beta, transa=transa, transb=transb, tile=tile,
        dtype=dtype)
    return np.stack([o.array() for o in outs])
