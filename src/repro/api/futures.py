"""Asynchronous submission primitives for the context API.

The runtime is not re-entrant (one scheduling pass owns the devices),
so a context serializes submissions onto a single background worker —
the host-side analogue of enqueueing kernels on a stream: ``submit``
returns immediately, work proceeds in order, and the caller overlaps
its own work until it blocks on ``BlasFuture.result()``.

Two flow-control features matter to the serving layer
(``repro.serve``): a ``max_pending`` bound on the executor (callers
either get :class:`BackpressureError` or opt into blocking until a
slot frees), and ``BlasFuture.cancel()`` for submissions that have not
started yet — the admission queue sheds load with both.
"""
from __future__ import annotations

import concurrent.futures
import threading
from typing import Any, Callable, Optional

CancelledError = concurrent.futures.CancelledError


class BackpressureError(RuntimeError):
    """Raised by ``SerialExecutor.submit`` when the pending-work bound
    (``max_pending``) is hit and the caller did not ask to block."""


class BlasFuture:
    """Handle to an in-flight L3 routine (cudaEvent/cudaStream flavour).

    Thin, deliberately minimal wrapper over
    :class:`concurrent.futures.Future`: ``result()`` blocks (and
    re-raises the routine's exception, if any), ``done()`` never
    blocks, ``exception()`` reports without raising, ``cancel()``
    withdraws a submission that has not started running.
    """

    def __init__(self, fut: "concurrent.futures.Future[Any]"):
        self._fut = fut

    def result(self, timeout: Optional[float] = None) -> Any:
        """Block until the routine finishes; returns its value (a
        ``MatrixHandle`` for the six L3 routines).  Raises
        :class:`concurrent.futures.CancelledError` if the submission
        was cancelled before it started."""
        return self._fut.result(timeout)

    def done(self) -> bool:
        """Non-blocking completion probe (True for cancelled too)."""
        return self._fut.done()

    def cancel(self) -> bool:
        """Withdraw the submission if it has not started running.
        Returns True on success; a running or finished routine cannot
        be cancelled (the runtime has no preemption) and returns
        False.  After a successful cancel, ``result()`` and
        ``exception()`` raise ``CancelledError``."""
        return self._fut.cancel()

    def cancelled(self) -> bool:
        return self._fut.cancelled()

    def exception(self, timeout: Optional[float] = None):
        """The routine's exception, or None if it succeeded.  Like the
        stdlib future, raises ``CancelledError`` when the submission
        was cancelled rather than run."""
        return self._fut.exception(timeout)

    def add_done_callback(self, fn: Callable[["BlasFuture"], None]) -> None:
        self._fut.add_done_callback(lambda _f: fn(self))

    def __repr__(self) -> str:
        if self.cancelled():
            state = "cancelled"
        else:
            state = "done" if self.done() else "pending"
        return f"BlasFuture({state})"


class SerialExecutor:
    """One daemon worker draining submissions in FIFO order.

    ``max_pending`` bounds the number of not-yet-finished submissions
    (queued + running).  At the bound, ``submit`` raises
    :class:`BackpressureError` — or, with ``block=True``, waits until
    a slot frees (``block_timeout`` seconds, then the same error).
    ``max_pending=None`` keeps the historical unbounded behaviour.
    """

    # lock-discipline declarations (repro.analysis, docs/ANALYSIS.md):
    # _slot_free wraps _lock.  The PR 6 deadlock was exactly
    # add_done_callback under _lock — LD002 now forbids it here, and
    # tests/test_analysis.py keeps the fixed shape as a permanent
    # negative case.
    _GUARDED_BY = {"_lock": ("_open", "_pending")}
    _LOCK_ALIASES = {"_slot_free": "_lock"}

    def __init__(self, name: str = "blasx",
                 max_pending: Optional[int] = None):
        if max_pending is not None and max_pending < 1:
            raise ValueError("max_pending must be >= 1 (or None)")
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix=name)
        self._lock = threading.Lock()
        self._slot_free = threading.Condition(self._lock)
        self._open = True
        self._max_pending = max_pending
        self._pending = 0

    @property
    def pending(self) -> int:
        """Submissions not yet finished (queued + running)."""
        with self._lock:
            return self._pending

    def _on_done(self, _fut: "concurrent.futures.Future[Any]") -> None:
        # fires on completion, failure AND cancellation — every path
        # that retires a submission frees its slot
        with self._lock:
            self._pending -= 1
            self._slot_free.notify()

    def submit(self, fn: Callable[..., Any], *args,
               block: bool = False, block_timeout: Optional[float] = None,
               **kwargs) -> BlasFuture:
        """Enqueue ``fn(*args, **kwargs)`` on the worker.

        Keyword-only ``block``/``block_timeout`` are flow control for
        a bounded executor and are *not* forwarded to ``fn``."""
        with self._lock:
            if not self._open:
                raise RuntimeError("executor is shut down")
            if self._max_pending is not None:
                while self._pending >= self._max_pending:
                    if not block:
                        raise BackpressureError(
                            f"executor has {self._pending} pending "
                            f"submissions (max_pending="
                            f"{self._max_pending})")
                    if not self._slot_free.wait(timeout=block_timeout):
                        raise BackpressureError(
                            "timed out waiting for a pending slot "
                            f"(max_pending={self._max_pending})")
                    if not self._open:
                        raise RuntimeError("executor is shut down")
            self._pending += 1
            try:
                fut = self._pool.submit(fn, *args, **kwargs)
            except BaseException:
                self._pending -= 1
                self._slot_free.notify()
                raise
        # outside the lock: a fast task's done-callback can fire inline
        # right here, and _on_done needs the lock itself
        fut.add_done_callback(self._on_done)
        return BlasFuture(fut)

    def shutdown(self, wait: bool = True) -> None:
        with self._lock:
            if not self._open:
                return
            self._open = False
            self._slot_free.notify_all()
        self._pool.shutdown(wait=wait)
