"""Asynchronous submission primitives for the context API.

The runtime is not re-entrant (one scheduling pass owns the devices),
so a context serializes submissions onto a single background worker —
the host-side analogue of enqueueing kernels on a stream: ``submit``
returns immediately, work proceeds in order, and the caller overlaps
its own work until it blocks on ``BlasFuture.result()``.
"""
from __future__ import annotations

import concurrent.futures
import threading
from typing import Any, Callable, Optional


class BlasFuture:
    """Handle to an in-flight L3 routine (cudaEvent/cudaStream flavour).

    Thin, deliberately minimal wrapper over
    :class:`concurrent.futures.Future`: ``result()`` blocks (and
    re-raises the routine's exception, if any), ``done()`` never
    blocks, ``exception()`` reports without raising.
    """

    def __init__(self, fut: "concurrent.futures.Future[Any]"):
        self._fut = fut

    def result(self, timeout: Optional[float] = None) -> Any:
        """Block until the routine finishes; returns its value (a
        ``MatrixHandle`` for the six L3 routines)."""
        return self._fut.result(timeout)

    def done(self) -> bool:
        """Non-blocking completion probe."""
        return self._fut.done()

    def exception(self, timeout: Optional[float] = None):
        return self._fut.exception(timeout)

    def add_done_callback(self, fn: Callable[["BlasFuture"], None]) -> None:
        self._fut.add_done_callback(lambda _f: fn(self))

    def __repr__(self) -> str:
        state = "done" if self.done() else "pending"
        return f"BlasFuture({state})"


class SerialExecutor:
    """One daemon worker draining submissions in FIFO order."""

    def __init__(self, name: str = "blasx"):
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix=name)
        self._lock = threading.Lock()
        self._open = True

    def submit(self, fn: Callable[..., Any], *args, **kwargs) -> BlasFuture:
        with self._lock:
            if not self._open:
                raise RuntimeError("executor is shut down")
            return BlasFuture(self._pool.submit(fn, *args, **kwargs))

    def shutdown(self, wait: bool = True) -> None:
        with self._lock:
            if not self._open:
                return
            self._open = False
        self._pool.shutdown(wait=wait)
