"""CBLAS-compatible legacy layer (the paper's backward-compatibility
goal, after the GSL two-layer design).

Strict C-interface signatures for the six double-precision L3 routines
— ``cblas_dgemm``, ``cblas_dsymm``, ``cblas_dsyrk``, ``cblas_dsyr2k``,
``cblas_dtrmm``, ``cblas_dtrsm`` — with order/trans/side/uplo/diag
enums, explicit leading dimensions, and in-place updates of the output
buffer, all executed by a persistent :class:`~repro.api.BlasxContext`
(the module default unless ``ctx=`` is given).

Buffers may be

* flat 1-D float64 arrays, interpreted through ``ld`` under the given
  ``Order`` exactly as C callers lay them out, or
* 2-D numpy arrays of the routine's logical shape (``ld`` is then
  validated against the dense leading dimension).

The output buffer (``C`` for gemm/symm/syrk/syr2k, ``B`` for
trmm/trsm) must be float64 and writable — the routines update it in
place and return ``None``, as legacy callers expect.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from .context import BlasxContext, backend_context, default_context

# ------------------------------------------------------ CBLAS enum values
CblasRowMajor = 101
CblasColMajor = 102
CblasNoTrans = 111
CblasTrans = 112
CblasConjTrans = 113   # == Trans for real matrices
CblasUpper = 121
CblasLower = 122
CblasNonUnit = 131
CblasUnit = 132
CblasLeft = 141
CblasRight = 142

_TRANS = {CblasNoTrans: "N", CblasTrans: "T", CblasConjTrans: "T",
          "N": "N", "T": "T", "C": "T", "n": "N", "t": "T", "c": "T"}
_UPLO = {CblasUpper: "U", CblasLower: "L", "U": "U", "L": "L",
         "u": "U", "l": "L"}
_DIAG = {CblasNonUnit: "N", CblasUnit: "U", "N": "N", "U": "U",
         "n": "N", "u": "U"}
_SIDE = {CblasLeft: "L", CblasRight: "R", "L": "L", "R": "R",
         "l": "L", "r": "R"}


def _flag(table, value, what: str) -> str:
    try:
        return table[value]
    except KeyError:
        raise ValueError(f"invalid {what} flag: {value!r}") from None


def _view(buf, rows: int, cols: int, ld: int, order: int, name: str,
          writable: bool = False) -> np.ndarray:
    """Logical ``rows x cols`` view of a CBLAS buffer.

    Flat buffers follow the C convention: element (i, j) lives at
    ``i*ld + j`` (row major) or ``i + j*ld`` (column major).  The
    returned array is a *view* whenever numpy allows, which is what
    makes the in-place output update visible to the caller.
    """
    if writable and not isinstance(buf, np.ndarray):
        # np.asarray on a list would update a detached copy and the
        # caller's buffer would silently keep its old contents
        raise TypeError(f"{name}: output buffer must be a numpy array, "
                        f"got {type(buf).__name__}")
    a = np.asarray(buf)
    if writable:
        if a.dtype != np.float64:
            raise TypeError(f"{name}: output buffer must be float64, "
                            f"got {a.dtype}")
        if not a.flags.writeable:
            raise ValueError(f"{name}: output buffer is read-only")
    elif a.dtype != np.float64:
        a = a.astype(np.float64)
    if a.ndim == 2:
        if a.shape != (rows, cols):
            raise ValueError(f"{name}: expected shape ({rows},{cols}), "
                             f"got {a.shape}")
        dense_ld = cols if order == CblasRowMajor else rows
        if ld < dense_ld:
            raise ValueError(f"{name}: ld {ld} < {dense_ld}")
        return a
    if a.ndim != 1:
        raise ValueError(f"{name}: expected 1-D or 2-D buffer, "
                         f"got {a.ndim}-D")
    if order == CblasRowMajor:
        if ld < max(1, cols):
            raise ValueError(f"{name}: ld {ld} < n cols {cols}")
        if a.size < rows * ld:
            raise ValueError(f"{name}: buffer too small "
                             f"({a.size} < {rows * ld})")
        return a[:rows * ld].reshape(rows, ld)[:, :cols]
    if order == CblasColMajor:
        if ld < max(1, rows):
            raise ValueError(f"{name}: ld {ld} < n rows {rows}")
        if a.size < cols * ld:
            raise ValueError(f"{name}: buffer too small "
                             f"({a.size} < {cols * ld})")
        return a[:cols * ld].reshape(cols, ld).T[:rows, :]
    raise ValueError(f"invalid Order flag: {order!r}")


def _ctx(ctx: Optional[BlasxContext],
         backend: Optional[str] = None) -> BlasxContext:
    if ctx is not None:
        if backend is not None and ctx.cfg.backend != backend:
            raise ValueError(
                f"backend={backend!r} conflicts with ctx backend "
                f"{ctx.cfg.backend!r}")
        return ctx
    if backend is None:
        return default_context()
    # calls sharing a backend share one warm-cache module context
    return backend_context(backend)


# =========================================================== the routines
def cblas_dgemm(order, transa, transb, m: int, n: int, k: int,
                alpha: float, A, lda: int, B, ldb: int,
                beta: float, C, ldc: int, *,
                ctx: Optional[BlasxContext] = None,
                backend: Optional[str] = None) -> None:
    """C := alpha*op(A)*op(B) + beta*C  (C is m x n, updated in place)."""
    ta, tb = _flag(_TRANS, transa, "Trans"), _flag(_TRANS, transb, "Trans")
    ar, ac = (m, k) if ta == "N" else (k, m)
    br, bc = (k, n) if tb == "N" else (n, k)
    Av = _view(A, ar, ac, lda, order, "A")
    Bv = _view(B, br, bc, ldb, order, "B")
    Cv = _view(C, m, n, ldc, order, "C", writable=True)
    out = _ctx(ctx, backend).gemm(Av, Bv, Cv if beta != 0.0 else None,
                         alpha=alpha, beta=beta, transa=ta, transb=tb)
    Cv[...] = out.array()


def cblas_dsymm(order, side, uplo, m: int, n: int, alpha: float,
                A, lda: int, B, ldb: int, beta: float, C, ldc: int, *,
                ctx: Optional[BlasxContext] = None,
                backend: Optional[str] = None) -> None:
    """C := alpha*A*B + beta*C (Left) or alpha*B*A + beta*C (Right),
    A symmetric with the ``uplo`` triangle stored."""
    sd, ul = _flag(_SIDE, side, "Side"), _flag(_UPLO, uplo, "Uplo")
    ka = m if sd == "L" else n
    Av = _view(A, ka, ka, lda, order, "A")
    Bv = _view(B, m, n, ldb, order, "B")
    Cv = _view(C, m, n, ldc, order, "C", writable=True)
    out = _ctx(ctx, backend).symm(Av, Bv, Cv if beta != 0.0 else None,
                         alpha=alpha, beta=beta, side=sd, uplo=ul)
    Cv[...] = out.array()


def cblas_dsyrk(order, uplo, trans, n: int, k: int, alpha: float,
                A, lda: int, beta: float, C, ldc: int, *,
                ctx: Optional[BlasxContext] = None,
                backend: Optional[str] = None) -> None:
    """C := alpha*op(A)*op(A)^T + beta*C on the ``uplo`` triangle."""
    ul, tr = _flag(_UPLO, uplo, "Uplo"), _flag(_TRANS, trans, "Trans")
    ar, ac = (n, k) if tr == "N" else (k, n)
    Av = _view(A, ar, ac, lda, order, "A")
    Cv = _view(C, n, n, ldc, order, "C", writable=True)
    # BLAS syrk always reads C's uplo triangle (beta scales it), so seed
    # the context call with Cv even for beta == 0 to preserve the
    # untouched opposite triangle in the writeback.
    out = _ctx(ctx, backend).syrk(Av, Cv, alpha=alpha, beta=beta, uplo=ul, trans=tr)
    Cv[...] = out.array()


def cblas_dsyr2k(order, uplo, trans, n: int, k: int, alpha: float,
                 A, lda: int, B, ldb: int, beta: float, C, ldc: int, *,
                 ctx: Optional[BlasxContext] = None,
                backend: Optional[str] = None) -> None:
    """C := alpha*op(A)*op(B)^T + alpha*op(B)*op(A)^T + beta*C."""
    ul, tr = _flag(_UPLO, uplo, "Uplo"), _flag(_TRANS, trans, "Trans")
    ar, ac = (n, k) if tr == "N" else (k, n)
    Av = _view(A, ar, ac, lda, order, "A")
    Bv = _view(B, ar, ac, ldb, order, "B")
    Cv = _view(C, n, n, ldc, order, "C", writable=True)
    out = _ctx(ctx, backend).syr2k(Av, Bv, Cv, alpha=alpha, beta=beta,
                          uplo=ul, trans=tr)
    Cv[...] = out.array()


def cblas_dtrmm(order, side, uplo, transa, diag, m: int, n: int,
                alpha: float, A, lda: int, B, ldb: int, *,
                ctx: Optional[BlasxContext] = None,
                backend: Optional[str] = None) -> None:
    """B := alpha*op(tri(A))*B (Left) or alpha*B*op(tri(A)) (Right),
    B (m x n) updated in place."""
    sd, ul = _flag(_SIDE, side, "Side"), _flag(_UPLO, uplo, "Uplo")
    ta, dg = _flag(_TRANS, transa, "Trans"), _flag(_DIAG, diag, "Diag")
    ka = m if sd == "L" else n
    Av = _view(A, ka, ka, lda, order, "A")
    Bv = _view(B, m, n, ldb, order, "B", writable=True)
    out = _ctx(ctx, backend).trmm(Av, Bv, alpha=alpha, side=sd, uplo=ul,
                         transa=ta, diag=dg)
    Bv[...] = out.array()


def cblas_dtrsm(order, side, uplo, transa, diag, m: int, n: int,
                alpha: float, A, lda: int, B, ldb: int, *,
                ctx: Optional[BlasxContext] = None,
                backend: Optional[str] = None) -> None:
    """Solve op(tri(A))*X = alpha*B (Left) or X*op(tri(A)) = alpha*B
    (Right); X overwrites B (m x n) in place."""
    sd, ul = _flag(_SIDE, side, "Side"), _flag(_UPLO, uplo, "Uplo")
    ta, dg = _flag(_TRANS, transa, "Trans"), _flag(_DIAG, diag, "Diag")
    ka = m if sd == "L" else n
    Av = _view(A, ka, ka, lda, order, "A")
    Bv = _view(B, m, n, ldb, order, "B", writable=True)
    out = _ctx(ctx, backend).trsm(Av, Bv, alpha=alpha, side=sd, uplo=ul,
                         transa=ta, diag=dg)
    Bv[...] = out.array()
