"""CBLAS-compatible legacy layer (the paper's backward-compatibility
goal, after the GSL two-layer design).

Strict C-interface signatures for the six L3 routines in both
precisions — double (``cblas_dgemm``, ``cblas_dsymm``, ``cblas_dsyrk``,
``cblas_dsyr2k``, ``cblas_dtrmm``, ``cblas_dtrsm``) and single
(``cblas_sgemm``, ``cblas_ssymm``, ``cblas_ssyrk``, ``cblas_ssyr2k``,
``cblas_strmm``, ``cblas_strsm``) — with order/trans/side/uplo/diag
enums, explicit leading dimensions, and in-place updates of the output
buffer, all executed by a persistent :class:`~repro.api.BlasxContext`
(the module default unless ``ctx=`` is given).  The two precision
families share one implementation parameterized by dtype; the ``d``
routines run float64 end to end, the ``s`` routines float32 (the
jax/pallas engines accumulate f32 either way — see
``repro.core.dtypes``).

Buffers may be

* flat 1-D arrays of the routine's dtype, interpreted through ``ld``
  under the given ``Order`` exactly as C callers lay them out, or
* 2-D numpy arrays of the routine's logical shape.  ``ld`` must then
  describe the array's actual memory layout: the dense leading
  dimension, or — for a strided view into padded storage — the padded
  one (a ``ld`` that matches neither raises instead of silently
  reading the wrong elements).

The output buffer (``C`` for gemm/symm/syrk/syr2k, ``B`` for
trmm/trsm) must be exactly the routine's dtype and writable — the
routines update it in place and return ``None``, as legacy callers
expect.

Every routine also takes keyword-only ``tile=``: an int pins the tile
size, ``"auto"`` resolves it through the runtime autotuner
(``repro.tuning``) for the call's routine/shape/dtype, and ``None``
(default) keeps the context default.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from .context import BlasxContext, backend_context, default_context

# ------------------------------------------------------ CBLAS enum values
CblasRowMajor = 101
CblasColMajor = 102
CblasNoTrans = 111
CblasTrans = 112
CblasConjTrans = 113   # == Trans for real matrices
CblasUpper = 121
CblasLower = 122
CblasNonUnit = 131
CblasUnit = 132
CblasLeft = 141
CblasRight = 142

_TRANS = {CblasNoTrans: "N", CblasTrans: "T", CblasConjTrans: "T",
          "N": "N", "T": "T", "C": "T", "n": "N", "t": "T", "c": "T"}
_UPLO = {CblasUpper: "U", CblasLower: "L", "U": "U", "L": "L",
         "u": "U", "l": "L"}
_DIAG = {CblasNonUnit: "N", CblasUnit: "U", "N": "N", "U": "U",
         "n": "N", "u": "U"}
_SIDE = {CblasLeft: "L", CblasRight: "R", "L": "L", "R": "R",
         "l": "L", "r": "R"}


def _flag(table, value, what: str) -> str:
    try:
        return table[value]
    except KeyError:
        raise ValueError(f"invalid {what} flag: {value!r}") from None


def _view(buf, rows: int, cols: int, ld: int, order: int, name: str,
          writable: bool = False, dtype=np.float64) -> np.ndarray:
    """Logical ``rows x cols`` view of a CBLAS buffer.

    Flat buffers follow the C convention: element (i, j) lives at
    ``i*ld + j`` (row major) or ``i + j*ld`` (column major).  The
    returned array is a *view* whenever numpy allows, which is what
    makes the in-place output update visible to the caller.

    ``dtype`` is the routine's precision (float64 for the ``d``
    family, float32 for ``s``): output buffers must match it exactly;
    read-only inputs of other dtypes are cast.
    """
    dtype = np.dtype(dtype)
    if writable and not isinstance(buf, np.ndarray):
        # np.asarray on a list would update a detached copy and the
        # caller's buffer would silently keep its old contents
        raise TypeError(f"{name}: output buffer must be a numpy array, "
                        f"got {type(buf).__name__}")
    a = np.asarray(buf)
    if writable:
        if a.dtype != dtype:
            raise TypeError(f"{name}: output buffer must be {dtype.name}, "
                            f"got {a.dtype}")
        if not a.flags.writeable:
            raise ValueError(f"{name}: output buffer is read-only")
    if a.ndim == 2:
        if a.shape != (rows, cols):
            raise ValueError(f"{name}: expected shape ({rows},{cols}), "
                             f"got {a.shape}")
        if order not in (CblasRowMajor, CblasColMajor):
            raise ValueError(f"invalid Order flag: {order!r}")
        dense_ld = cols if order == CblasRowMajor else rows
        if ld < dense_ld:
            raise ValueError(f"{name}: ld {ld} < {dense_ld}")
        if ld > dense_ld:
            # A padded leading dimension is only meaningful when the
            # 2-D array's memory really is strided that way (a view
            # into padded storage).  A dense array with ld > dense_ld
            # used to be accepted and silently given dense semantics —
            # the C caller meant element (i, j) at i*ld + j, which this
            # buffer does not contain.  Honor matching strides; raise
            # otherwise.  Checked on the CALLER's buffer, before any
            # read-only dtype cast (a cast copy is dense and would
            # fail the check for perfectly valid strided inputs).
            # With a single row (row major) / column (col major) the
            # leading stride is never exercised, so any ld is valid.
            it = a.itemsize
            single = rows == 1 if order == CblasRowMajor else cols == 1
            strided_ok = single or (
                a.strides == (ld * it, it) if order == CblasRowMajor
                else a.strides == (it, ld * it))
            if not strided_ok:
                raise ValueError(
                    f"{name}: ld {ld} does not match the 2-D buffer's "
                    f"memory layout (dense leading dimension {dense_ld}, "
                    f"strides {a.strides}); pass a strided view into the "
                    f"padded storage or ld={dense_ld}")
        if a.dtype != dtype:
            a = a.astype(dtype)   # read-only inputs: writable returned above
        return a
    if a.ndim != 1:
        raise ValueError(f"{name}: expected 1-D or 2-D buffer, "
                         f"got {a.ndim}-D")
    if a.dtype != dtype:
        a = a.astype(dtype)       # flat read-only input: cast copy is fine
    if order == CblasRowMajor:
        if ld < max(1, cols):
            raise ValueError(f"{name}: ld {ld} < n cols {cols}")
        if a.size < rows * ld:
            raise ValueError(f"{name}: buffer too small "
                             f"({a.size} < {rows * ld})")
        return a[:rows * ld].reshape(rows, ld)[:, :cols]
    if order == CblasColMajor:
        if ld < max(1, rows):
            raise ValueError(f"{name}: ld {ld} < n rows {rows}")
        if a.size < cols * ld:
            raise ValueError(f"{name}: buffer too small "
                             f"({a.size} < {cols * ld})")
        return a[:cols * ld].reshape(cols, ld).T[:rows, :]
    raise ValueError(f"invalid Order flag: {order!r}")


def _ctx(ctx: Optional[BlasxContext],
         backend: Optional[str] = None,
         device_class: Optional[str] = None,
         mesh: Optional[int] = None) -> BlasxContext:
    if ctx is not None:
        if backend is not None and ctx.cfg.backend != backend:
            raise ValueError(
                f"backend={backend!r} conflicts with ctx backend "
                f"{ctx.cfg.backend!r}")
        if (device_class is not None
                and ctx.cfg.device_class != device_class):
            raise ValueError(
                f"device_class={device_class!r} conflicts with ctx "
                f"device class {ctx.cfg.device_class!r}")
        if mesh is not None and ctx.cfg.mesh_devices != mesh:
            raise ValueError(
                f"mesh={mesh} conflicts with ctx mesh_devices "
                f"{ctx.cfg.mesh_devices}")
        return ctx
    if device_class is not None or mesh is not None:
        # pod-tier call without a context: private per-call context
        # (mirrors blas3's config= semantics)
        return BlasxContext(backend=backend, device_class=device_class,
                            mesh=mesh)
    if backend is None:
        return default_context()
    # calls sharing a backend share one warm-cache module context
    return backend_context(backend)


# ============================================= dtype-parameterized bodies
def _gemm(dtype, order, transa, transb, m, n, k, alpha, A, lda, B, ldb,
          beta, C, ldc, ctx, backend, tile=None, device_class=None,
          mesh=None) -> None:
    ta, tb = _flag(_TRANS, transa, "Trans"), _flag(_TRANS, transb, "Trans")
    ar, ac = (m, k) if ta == "N" else (k, m)
    br, bc = (k, n) if tb == "N" else (n, k)
    Av = _view(A, ar, ac, lda, order, "A", dtype=dtype)
    Bv = _view(B, br, bc, ldb, order, "B", dtype=dtype)
    Cv = _view(C, m, n, ldc, order, "C", writable=True, dtype=dtype)
    out = _ctx(ctx, backend, device_class, mesh).gemm(Av, Bv, Cv if beta != 0.0 else None,
                                  alpha=alpha, beta=beta, transa=ta,
                                  transb=tb, tile=tile, dtype=dtype)
    Cv[...] = out.array()


def _symm(dtype, order, side, uplo, m, n, alpha, A, lda, B, ldb, beta,
          C, ldc, ctx, backend, tile=None, device_class=None,
          mesh=None) -> None:
    sd, ul = _flag(_SIDE, side, "Side"), _flag(_UPLO, uplo, "Uplo")
    ka = m if sd == "L" else n
    Av = _view(A, ka, ka, lda, order, "A", dtype=dtype)
    Bv = _view(B, m, n, ldb, order, "B", dtype=dtype)
    Cv = _view(C, m, n, ldc, order, "C", writable=True, dtype=dtype)
    out = _ctx(ctx, backend, device_class, mesh).symm(Av, Bv, Cv if beta != 0.0 else None,
                                  alpha=alpha, beta=beta, side=sd, uplo=ul,
                                  tile=tile, dtype=dtype)
    Cv[...] = out.array()


def _syrk(dtype, order, uplo, trans, n, k, alpha, A, lda, beta, C, ldc,
          ctx, backend, tile=None, device_class=None, mesh=None) -> None:
    ul, tr = _flag(_UPLO, uplo, "Uplo"), _flag(_TRANS, trans, "Trans")
    ar, ac = (n, k) if tr == "N" else (k, n)
    Av = _view(A, ar, ac, lda, order, "A", dtype=dtype)
    Cv = _view(C, n, n, ldc, order, "C", writable=True, dtype=dtype)
    # BLAS syrk always reads C's uplo triangle (beta scales it), so seed
    # the context call with Cv even for beta == 0 to preserve the
    # untouched opposite triangle in the writeback.
    out = _ctx(ctx, backend, device_class, mesh).syrk(Av, Cv, alpha=alpha, beta=beta, uplo=ul,
                                  trans=tr, tile=tile, dtype=dtype)
    Cv[...] = out.array()


def _syr2k(dtype, order, uplo, trans, n, k, alpha, A, lda, B, ldb, beta,
           C, ldc, ctx, backend, tile=None, device_class=None,
           mesh=None) -> None:
    ul, tr = _flag(_UPLO, uplo, "Uplo"), _flag(_TRANS, trans, "Trans")
    ar, ac = (n, k) if tr == "N" else (k, n)
    Av = _view(A, ar, ac, lda, order, "A", dtype=dtype)
    Bv = _view(B, ar, ac, ldb, order, "B", dtype=dtype)
    Cv = _view(C, n, n, ldc, order, "C", writable=True, dtype=dtype)
    out = _ctx(ctx, backend, device_class, mesh).syr2k(Av, Bv, Cv, alpha=alpha, beta=beta,
                                   uplo=ul, trans=tr, tile=tile,
                                   dtype=dtype)
    Cv[...] = out.array()


def _trmm(dtype, order, side, uplo, transa, diag, m, n, alpha, A, lda,
          B, ldb, ctx, backend, tile=None, device_class=None,
          mesh=None) -> None:
    sd, ul = _flag(_SIDE, side, "Side"), _flag(_UPLO, uplo, "Uplo")
    ta, dg = _flag(_TRANS, transa, "Trans"), _flag(_DIAG, diag, "Diag")
    ka = m if sd == "L" else n
    Av = _view(A, ka, ka, lda, order, "A", dtype=dtype)
    Bv = _view(B, m, n, ldb, order, "B", writable=True, dtype=dtype)
    out = _ctx(ctx, backend, device_class, mesh).trmm(Av, Bv, alpha=alpha, side=sd, uplo=ul,
                                  transa=ta, diag=dg, tile=tile,
                                  dtype=dtype)
    Bv[...] = out.array()


def _trsm(dtype, order, side, uplo, transa, diag, m, n, alpha, A, lda,
          B, ldb, ctx, backend, tile=None, device_class=None,
          mesh=None) -> None:
    sd, ul = _flag(_SIDE, side, "Side"), _flag(_UPLO, uplo, "Uplo")
    ta, dg = _flag(_TRANS, transa, "Trans"), _flag(_DIAG, diag, "Diag")
    ka = m if sd == "L" else n
    Av = _view(A, ka, ka, lda, order, "A", dtype=dtype)
    Bv = _view(B, m, n, ldb, order, "B", writable=True, dtype=dtype)
    out = _ctx(ctx, backend, device_class, mesh).trsm(Av, Bv, alpha=alpha, side=sd, uplo=ul,
                                  transa=ta, diag=dg, tile=tile,
                                  dtype=dtype)
    Bv[...] = out.array()


# ================================================ double-precision surface
def cblas_dgemm(order, transa, transb, m: int, n: int, k: int,
                alpha: float, A, lda: int, B, ldb: int,
                beta: float, C, ldc: int, *,
                ctx: Optional[BlasxContext] = None,
                backend: Optional[str] = None,
                tile=None, device_class: Optional[str] = None,
                mesh: Optional[int] = None) -> None:
    """C := alpha*op(A)*op(B) + beta*C  (C is m x n, updated in place)."""
    _gemm(np.float64, order, transa, transb, m, n, k, alpha, A, lda,
          B, ldb, beta, C, ldc, ctx, backend, tile, device_class, mesh)


def cblas_dsymm(order, side, uplo, m: int, n: int, alpha: float,
                A, lda: int, B, ldb: int, beta: float, C, ldc: int, *,
                ctx: Optional[BlasxContext] = None,
                backend: Optional[str] = None,
                tile=None, device_class: Optional[str] = None,
                mesh: Optional[int] = None) -> None:
    """C := alpha*A*B + beta*C (Left) or alpha*B*A + beta*C (Right),
    A symmetric with the ``uplo`` triangle stored."""
    _symm(np.float64, order, side, uplo, m, n, alpha, A, lda, B, ldb,
          beta, C, ldc, ctx, backend, tile, device_class, mesh)


def cblas_dsyrk(order, uplo, trans, n: int, k: int, alpha: float,
                A, lda: int, beta: float, C, ldc: int, *,
                ctx: Optional[BlasxContext] = None,
                backend: Optional[str] = None,
                tile=None, device_class: Optional[str] = None,
                mesh: Optional[int] = None) -> None:
    """C := alpha*op(A)*op(A)^T + beta*C on the ``uplo`` triangle."""
    _syrk(np.float64, order, uplo, trans, n, k, alpha, A, lda, beta,
          C, ldc, ctx, backend, tile, device_class, mesh)


def cblas_dsyr2k(order, uplo, trans, n: int, k: int, alpha: float,
                 A, lda: int, B, ldb: int, beta: float, C, ldc: int, *,
                 ctx: Optional[BlasxContext] = None,
                 backend: Optional[str] = None,
                 tile=None, device_class: Optional[str] = None,
                 mesh: Optional[int] = None) -> None:
    """C := alpha*op(A)*op(B)^T + alpha*op(B)*op(A)^T + beta*C."""
    _syr2k(np.float64, order, uplo, trans, n, k, alpha, A, lda, B, ldb,
           beta, C, ldc, ctx, backend, tile, device_class, mesh)


def cblas_dtrmm(order, side, uplo, transa, diag, m: int, n: int,
                alpha: float, A, lda: int, B, ldb: int, *,
                ctx: Optional[BlasxContext] = None,
                backend: Optional[str] = None,
                tile=None, device_class: Optional[str] = None,
                mesh: Optional[int] = None) -> None:
    """B := alpha*op(tri(A))*B (Left) or alpha*B*op(tri(A)) (Right),
    B (m x n) updated in place."""
    _trmm(np.float64, order, side, uplo, transa, diag, m, n, alpha,
          A, lda, B, ldb, ctx, backend, tile, device_class, mesh)


def cblas_dtrsm(order, side, uplo, transa, diag, m: int, n: int,
                alpha: float, A, lda: int, B, ldb: int, *,
                ctx: Optional[BlasxContext] = None,
                backend: Optional[str] = None,
                tile=None, device_class: Optional[str] = None,
                mesh: Optional[int] = None) -> None:
    """Solve op(tri(A))*X = alpha*B (Left) or X*op(tri(A)) = alpha*B
    (Right); X overwrites B (m x n) in place."""
    _trsm(np.float64, order, side, uplo, transa, diag, m, n, alpha,
          A, lda, B, ldb, ctx, backend, tile, device_class, mesh)


# ================================================ single-precision surface
def cblas_sgemm(order, transa, transb, m: int, n: int, k: int,
                alpha: float, A, lda: int, B, ldb: int,
                beta: float, C, ldc: int, *,
                ctx: Optional[BlasxContext] = None,
                backend: Optional[str] = None,
                tile=None, device_class: Optional[str] = None,
                mesh: Optional[int] = None) -> None:
    """Single-precision GEMM: C := alpha*op(A)*op(B) + beta*C, all
    buffers float32, C updated in place."""
    _gemm(np.float32, order, transa, transb, m, n, k, alpha, A, lda,
          B, ldb, beta, C, ldc, ctx, backend, tile, device_class, mesh)


def cblas_ssymm(order, side, uplo, m: int, n: int, alpha: float,
                A, lda: int, B, ldb: int, beta: float, C, ldc: int, *,
                ctx: Optional[BlasxContext] = None,
                backend: Optional[str] = None,
                tile=None, device_class: Optional[str] = None,
                mesh: Optional[int] = None) -> None:
    """Single-precision SYMM (see :func:`cblas_dsymm`)."""
    _symm(np.float32, order, side, uplo, m, n, alpha, A, lda, B, ldb,
          beta, C, ldc, ctx, backend, tile, device_class, mesh)


def cblas_ssyrk(order, uplo, trans, n: int, k: int, alpha: float,
                A, lda: int, beta: float, C, ldc: int, *,
                ctx: Optional[BlasxContext] = None,
                backend: Optional[str] = None,
                tile=None, device_class: Optional[str] = None,
                mesh: Optional[int] = None) -> None:
    """Single-precision SYRK (see :func:`cblas_dsyrk`)."""
    _syrk(np.float32, order, uplo, trans, n, k, alpha, A, lda, beta,
          C, ldc, ctx, backend, tile, device_class, mesh)


def cblas_ssyr2k(order, uplo, trans, n: int, k: int, alpha: float,
                 A, lda: int, B, ldb: int, beta: float, C, ldc: int, *,
                 ctx: Optional[BlasxContext] = None,
                 backend: Optional[str] = None,
                 tile=None, device_class: Optional[str] = None,
                 mesh: Optional[int] = None) -> None:
    """Single-precision SYR2K (see :func:`cblas_dsyr2k`)."""
    _syr2k(np.float32, order, uplo, trans, n, k, alpha, A, lda, B, ldb,
           beta, C, ldc, ctx, backend, tile, device_class, mesh)


def cblas_strmm(order, side, uplo, transa, diag, m: int, n: int,
                alpha: float, A, lda: int, B, ldb: int, *,
                ctx: Optional[BlasxContext] = None,
                backend: Optional[str] = None,
                tile=None, device_class: Optional[str] = None,
                mesh: Optional[int] = None) -> None:
    """Single-precision TRMM (see :func:`cblas_dtrmm`)."""
    _trmm(np.float32, order, side, uplo, transa, diag, m, n, alpha,
          A, lda, B, ldb, ctx, backend, tile, device_class, mesh)


def cblas_strsm(order, side, uplo, transa, diag, m: int, n: int,
                alpha: float, A, lda: int, B, ldb: int, *,
                ctx: Optional[BlasxContext] = None,
                backend: Optional[str] = None,
                tile=None, device_class: Optional[str] = None,
                mesh: Optional[int] = None) -> None:
    """Single-precision TRSM (see :func:`cblas_dtrsm`)."""
    _trsm(np.float32, order, side, uplo, transa, diag, m, n, alpha,
          A, lda, B, ldb, ctx, backend, tile, device_class, mesh)
