"""repro.api — the two-layer public BLAS API (GSL/CBLAS design).

High-level layer (recommended): :class:`BlasxContext` — a persistent
handle (cuBLAS-handle analogue) whose ALRU/MESI-X tile caches stay
warm across calls, with :class:`MatrixHandle` device-resident
operands, per-call ledger snapshots (:class:`CallRecord`), async
submission (:class:`BlasFuture`) and batched GEMM for serving-shaped
workloads.

Low-level layer: ``repro.api.cblas`` — strict CBLAS signatures in both
precisions (``cblas_dgemm`` / ``cblas_sgemm`` et al.) with
order/leading-dimension semantics and in-place output updates, for
legacy callers.

The legacy numpy-in/numpy-out functions in ``repro.core.blas3`` are
thin wrappers over :func:`default_context`.  Every surface takes
``dtype=`` (see ``repro.core.dtypes`` for the supported set per
backend).
"""
from .batch import gemm_batched, gemm_strided_batched
from .cblas import (CblasColMajor, CblasLeft, CblasLower, CblasNonUnit,
                    CblasNoTrans, CblasRight, CblasRowMajor, CblasTrans,
                    CblasConjTrans, CblasUnit, CblasUpper, cblas_dgemm,
                    cblas_dsymm, cblas_dsyr2k, cblas_dsyrk, cblas_dtrmm,
                    cblas_dtrsm, cblas_sgemm, cblas_ssymm, cblas_ssyr2k,
                    cblas_ssyrk, cblas_strmm, cblas_strsm)
from .context import (BlasxContext, CallRecord, MatrixHandle,
                      default_context, set_default_context)
from .futures import BackpressureError, BlasFuture, SerialExecutor

__all__ = [
    "BlasxContext", "MatrixHandle", "CallRecord", "BlasFuture",
    "BackpressureError", "SerialExecutor",
    "default_context", "set_default_context",
    "gemm_batched", "gemm_strided_batched",
    "cblas_dgemm", "cblas_dsymm", "cblas_dsyrk", "cblas_dsyr2k",
    "cblas_dtrmm", "cblas_dtrsm",
    "cblas_sgemm", "cblas_ssymm", "cblas_ssyrk", "cblas_ssyr2k",
    "cblas_strmm", "cblas_strsm",
    "CblasRowMajor", "CblasColMajor", "CblasNoTrans", "CblasTrans",
    "CblasConjTrans", "CblasUpper", "CblasLower", "CblasNonUnit",
    "CblasUnit", "CblasLeft", "CblasRight",
]
