"""`BlasxServer` — multi-tenant serving front end over warm contexts.

The runtime stack below this module is single-lane: one
:class:`~repro.api.BlasxContext` serializes its routine calls because
the runtime's scheduling pass is not re-entrant.  The server is the
front door the ROADMAP's "millions of users" shape needs — it
multiplexes many concurrent clients onto a *pool* of contexts:

admission   a single bounded :class:`~repro.serve.admission.AdmissionQueue`
            (interactive before batch, tenants round-robin within a
            class); at the bound, ``submit`` sheds load with
            :class:`~repro.api.BackpressureError`.
affinity    a tenant's requests route to the context already holding
            its warm tiles/handles; new tenants and overflow beyond
            ``overflow_depth`` spill to the least-loaded context.
            Requests carrying a :class:`~repro.api.MatrixHandle` are
            pinned to the handle's own context (handles never cross
            contexts).
isolation   per-tenant ALRU quotas (``quotas=``) tag every cached tile
            with its owner; once any quota exists, cross-tenant
            eviction is off — a flooding tenant recycles its own
            blocks, never another tenant's warm set.
priority    each request's class maps to an additive Eq. 3 term
            (``priority_boosts``), so interactive tasks outrank batch
            tasks inside every reservation station they share.

One worker thread drains each context's lane (the context lane stays
serial; concurrency comes from pool width).  ``stats()`` merges the
:class:`~repro.serve.stats.ServerStats` ledger with the ALRU
quota-eviction counters.
"""
from __future__ import annotations

import concurrent.futures
import threading
import time
from typing import Any, Callable, Dict, Optional, Sequence, Union

from ..api.context import BlasxContext, MatrixHandle
from ..api.futures import BackpressureError, BlasFuture
from ..core.runtime import RuntimeConfig
from .admission import (BATCH, DEFAULT_BOOSTS, INTERACTIVE,
                        PRIORITY_CLASSES, AdmissionQueue, ServeRequest)
from .stats import ServerStats

__all__ = ["BlasxServer", "INTERACTIVE", "BATCH"]

_TAKE_TIMEOUT_S = 0.05  # worker poll granularity on an idle lane


class BlasxServer:
    """Serve L3 BLAS traffic from a pool of warm ``BlasxContext``s.

    Parameters
    ----------
    config:
        ``RuntimeConfig`` used to build each pooled context (default:
        2-device sim).  Mutually exclusive with ``contexts``.
    contexts:
        Pre-built contexts to adopt (the caller keeps ownership:
        ``close()`` will not close them).
    pool_size:
        Number of contexts to build when ``contexts`` is not given.
    max_depth:
        Admission-queue bound across all lanes/classes/tenants.
    overflow_depth:
        A tenant's home lane may run this many requests deeper than
        the shallowest lane before its traffic overflows there.
    quotas:
        ``tenant -> bytes`` resident-tile caps, applied to every
        pooled context (see ``Alru.set_quota``).
    priority_boosts:
        ``class -> additive Eq. 3 term`` (default interactive=+3,
        batch=+0).
    """

    # lock-discipline declarations (repro.analysis, docs/ANALYSIS.md):
    # _contexts/_queue/_stats/_boosts/_workers are immutable references
    # after __init__ (their own locks guard their insides) and stay
    # unlisted; the *_locked helpers run with _lock already held.
    _GUARDED_BY = {"_lock": (
        "_affinity", "_lane_load", "_lane_tenants", "_closed")}

    def __init__(self, config: Optional[RuntimeConfig] = None, *,
                 contexts: Optional[Sequence[BlasxContext]] = None,
                 pool_size: int = 2,
                 tile: Optional[int] = None,
                 max_depth: int = 64,
                 overflow_depth: int = 4,
                 quotas: Optional[Dict[str, int]] = None,
                 priority_boosts: Optional[Dict[str, float]] = None):
        if contexts is not None and config is not None:
            raise ValueError("pass config= or contexts=, not both")
        if contexts is not None:
            if not contexts:
                raise ValueError("contexts must be non-empty")
            self._contexts = list(contexts)
            self._owns_contexts = False
        else:
            if pool_size < 1:
                raise ValueError("pool_size must be >= 1")
            cfg = config or RuntimeConfig(n_devices=2, mode="sim")
            kw = {"tile": tile} if tile is not None else {}
            self._contexts = [BlasxContext(cfg, **kw)
                              for _ in range(pool_size)]
            self._owns_contexts = True
        n = len(self._contexts)
        self._boosts = dict(DEFAULT_BOOSTS)
        if priority_boosts:
            for cls in priority_boosts:
                if cls not in PRIORITY_CLASSES:
                    raise ValueError(f"unknown priority class {cls!r}")
            self._boosts.update(priority_boosts)
        self._queue = AdmissionQueue(max_depth=max_depth, n_lanes=n)
        self._overflow_depth = overflow_depth
        self._stats = ServerStats()
        self._lock = threading.Lock()
        self._affinity: Dict[str, int] = {}
        self._lane_load = [0] * n           # queued + running per lane
        self._lane_tenants = [0] * n        # tenants homed per lane
        self._closed = False
        if quotas:
            for tenant, nbytes in quotas.items():
                self.set_tenant_quota(tenant, nbytes)
        self._workers = [
            threading.Thread(target=self._worker, args=(i,),
                             name=f"blasx-serve-{i}", daemon=True)
            for i in range(n)
        ]
        for w in self._workers:
            w.start()

    # ------------------------------------------------------------ lifecycle
    def __enter__(self) -> "BlasxServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self, wait: bool = True) -> None:
        """Stop admitting, then either drain queued work (``wait=True``)
        or cancel it; workers exit once their lane is empty.  Owned
        contexts are closed, adopted ones are left to their owner.
        Idempotent."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._queue.close()
        if not wait:
            for lane in range(len(self._contexts)):
                for req in self._queue.drain(lane):
                    if req.future.cancel():
                        self._stats.record_cancelled(req.tenant)
                    self._lane_done(req.lane)
        for w in self._workers:
            w.join()
        if self._owns_contexts:
            for ctx in self._contexts:
                ctx.close()

    @property
    def closed(self) -> bool:
        # _closed is written under _lock by close(); reading it bare
        # is a data race the lock-discipline lint (LD001) rejects
        with self._lock:
            return self._closed

    @property
    def pool_size(self) -> int:
        return len(self._contexts)

    # ------------------------------------------------------------- tenants
    def set_tenant_quota(self, tenant: str,
                         nbytes: Optional[int]) -> None:
        """Cap ``tenant``'s resident tile bytes on every pooled
        context's devices (None removes the cap)."""
        for ctx in self._contexts:
            ctx.set_tenant_quota(tenant, nbytes)

    def tile(self, tenant: str, data, **kwargs) -> MatrixHandle:
        """Register a warm handle for ``tenant`` on its home context
        (assigning affinity for a new tenant) and return it.  Requests
        that later carry the handle are pinned to that context."""
        self._check_open()
        with self._lock:
            lane = self._assign_affinity_locked(tenant)
        return self._contexts[lane].tile(data, **kwargs)

    def context_of(self, tenant: str) -> Optional[int]:
        """The tenant's home lane (None before its first request)."""
        with self._lock:
            return self._affinity.get(tenant)

    # ------------------------------------------------------------ serving
    def submit(self, tenant: str,
               routine: Union[str, Callable[..., Any]], *args,
               priority: str = BATCH, **kwargs) -> BlasFuture:
        """Admit one request; returns a :class:`BlasFuture` (supports
        ``cancel()`` while still queued).  ``routine`` is a context
        method name (``"gemm"`` ...) or a callable invoked as
        ``routine(ctx, *args, **kwargs)``.  Raises
        :class:`BackpressureError` when the admission queue is full."""
        self._check_open()
        fut = concurrent.futures.Future()
        req = ServeRequest(tenant=tenant, routine=routine, args=args,
                           kwargs=kwargs, priority=priority, future=fut,
                           t_submit=time.perf_counter())
        with self._lock:
            pinned = self._pinned_lane(args, kwargs)
            if pinned is not None:
                req.lane = pinned
                if tenant not in self._affinity:
                    self._affinity[tenant] = pinned
                    self._lane_tenants[pinned] += 1
            else:
                req.lane = self._route_locked(tenant)
            admitted = self._queue.offer(req)
            if admitted:
                self._lane_load[req.lane] += 1
        if not admitted:
            self._stats.record_rejection(tenant)
            raise BackpressureError(
                f"admission queue full (max_depth="
                f"{self._queue.max_depth}); request for tenant "
                f"{tenant!r} rejected")
        return BlasFuture(fut)

    # ------------------------------------------------------------- routing
    def _pinned_lane(self, args, kwargs) -> Optional[int]:
        """Handles never cross contexts: a request carrying one is
        pinned to the context that owns it."""
        for x in list(args) + list(kwargs.values()):
            if isinstance(x, MatrixHandle):
                for i, ctx in enumerate(self._contexts):
                    if x._ctx is ctx:
                        return i
                raise ValueError(
                    f"handle {x.matrix_id} belongs to a context "
                    "outside this server's pool")
        return None

    def _assign_affinity_locked(self, tenant: str) -> int:
        """A new tenant homes on the lane with the least load, breaking
        ties toward the lane hosting the fewest tenants — tenants
        spread across the pool instead of piling onto lane 0."""
        lane = self._affinity.get(tenant)
        if lane is None:
            lane = min(range(len(self._lane_load)),
                       key=lambda i: (self._lane_load[i],
                                      self._lane_tenants[i], i))
            self._affinity[tenant] = lane
            self._lane_tenants[lane] += 1
        return lane

    def _route_locked(self, tenant: str) -> int:
        """Affinity lane unless it is ``overflow_depth`` deeper than
        the shallowest lane; overflow goes to the least-loaded lane
        without moving affinity — the warm set stays where it is."""
        home = self._assign_affinity_locked(tenant)
        coldest = min(range(len(self._lane_load)),
                      key=lambda i: (self._lane_load[i], i))
        if self._lane_load[home] - self._lane_load[coldest] \
                > self._overflow_depth:
            return coldest
        return home

    def _lane_done(self, lane: int) -> None:
        with self._lock:
            self._lane_load[lane] -= 1

    # ------------------------------------------------------------- workers
    def _worker(self, lane: int) -> None:
        ctx = self._contexts[lane]
        while True:
            req = self._queue.take(lane, timeout=_TAKE_TIMEOUT_S)
            if req is None:
                if self._queue.closed:
                    return
                continue
            try:
                if not req.future.set_running_or_notify_cancel():
                    self._stats.record_cancelled(req.tenant)
                    continue
                req.t_start = time.perf_counter()
                boost = self._boosts[req.priority]
                try:
                    with ctx.request_scope(tenant=req.tenant,
                                           priority_boost=boost):
                        if isinstance(req.routine, str):
                            fn = getattr(ctx, req.routine, None)
                            if fn is None or not callable(fn):
                                raise ValueError(
                                    f"unknown routine {req.routine!r}")
                            result = fn(*req.args, **req.kwargs)
                        else:
                            result = req.routine(ctx, *req.args,
                                                 **req.kwargs)
                except BaseException as exc:
                    req.future.set_exception(exc)
                    ok = False
                else:
                    req.future.set_result(result)
                    ok = True
                done = time.perf_counter()
                self._stats.record(req.tenant,
                                   wait_s=req.t_start - req.t_submit,
                                   latency_s=done - req.t_submit, ok=ok)
            finally:
                self._lane_done(req.lane)

    # --------------------------------------------------------------- stats
    def quota_evictions(self) -> Dict[str, int]:
        """Tenant -> quota-eviction count summed over every pooled
        context's devices."""
        out: Dict[str, int] = {}
        for ctx in self._contexts:
            for d in ctx.runtime.devices:
                for tenant, n in d.alru.quota_evictions_by_owner.items():
                    out[tenant] = out.get(tenant, 0) + n
        return out

    def stats(self) -> Dict[str, Any]:
        """Server-level ledger: per-tenant latency/wait percentiles and
        counters (rejections, cancellations, quota evictions), queue
        depth, per-lane load and affinity map."""
        with self._lock:
            lane_load = list(self._lane_load)
            affinity = dict(self._affinity)
        return {
            "pool_size": self.pool_size,
            "queue_depth": self._queue.depth,
            "lane_load": lane_load,
            "affinity": affinity,
            "tenants": self._stats.snapshot(self.quota_evictions()),
        }

    # ------------------------------------------------------------- helpers
    def _check_open(self) -> None:
        with self._lock:
            closed = self._closed
        if closed:
            raise RuntimeError("BlasxServer is closed")
