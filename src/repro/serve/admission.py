"""Admission control for the serving front end.

One bounded queue fronts the whole context pool.  Internally the queue
is laned — one lane per pool context, chosen by the dispatcher's
affinity routing at submit time — and each lane keeps two priority
classes (``interactive`` drains strictly before ``batch``) of
per-tenant FIFO deques.  Within a class, tenants are served
round-robin: a tenant that just got a request dequeued rotates to the
back, so a flood from one tenant costs every other tenant at most one
queue position per turn.  The depth bound is global across lanes and
classes — admission is the single place load is shed.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict, deque
from typing import Any, Callable, Dict, Optional, Tuple, Union

INTERACTIVE = "interactive"
BATCH = "batch"
PRIORITY_CLASSES = (INTERACTIVE, BATCH)

# Eq. 3 additive term per class: locality contributes +2 (L1) / +1
# (L2) per input tile, so +3.0 lets one interactive task outrank a
# batch task even when the batch task has every input L1-resident.
DEFAULT_BOOSTS: Dict[str, float] = {INTERACTIVE: 3.0, BATCH: 0.0}


@dataclasses.dataclass
class ServeRequest:
    """One client submission travelling through the server."""
    tenant: str
    routine: Union[str, Callable[..., Any]]
    args: Tuple[Any, ...]
    kwargs: Dict[str, Any]
    priority: str = BATCH
    lane: int = 0
    future: Any = None                  # concurrent.futures.Future
    t_submit: float = 0.0               # perf_counter at admission
    t_start: float = 0.0                # perf_counter at dequeue

    def __post_init__(self):
        if self.priority not in PRIORITY_CLASSES:
            raise ValueError(
                f"priority must be one of {PRIORITY_CLASSES}, "
                f"got {self.priority!r}")


class AdmissionQueue:
    """Bounded, laned, priority-classed, tenant-fair request queue."""

    # lock-discipline declarations (repro.analysis, docs/ANALYSIS.md):
    # _nonempty wraps _lock; _pop_locked's suffix marks it lock-held.
    _GUARDED_BY = {"_lock": ("_closed", "_depth", "_lanes")}
    _LOCK_ALIASES = {"_nonempty": "_lock"}

    def __init__(self, max_depth: int = 64, n_lanes: int = 1):
        if max_depth < 1:
            raise ValueError("max_depth must be >= 1")
        if n_lanes < 1:
            raise ValueError("n_lanes must be >= 1")
        self.max_depth = max_depth
        self.n_lanes = n_lanes
        self._lock = threading.Lock()
        self._nonempty = threading.Condition(self._lock)
        self._closed = False
        self._depth = 0
        # lane -> class -> tenant -> FIFO of requests.  OrderedDict
        # order IS the round-robin order; move_to_end on dequeue.
        self._lanes = [
            {cls: OrderedDict() for cls in PRIORITY_CLASSES}
            for _ in range(n_lanes)
        ]

    # ------------------------------------------------------------- queries
    @property
    def depth(self) -> int:
        with self._lock:
            return self._depth

    @property
    def closed(self) -> bool:
        with self._lock:
            return self._closed

    def lane_depth(self, lane: int) -> int:
        with self._lock:
            return sum(len(q) for cls in self._lanes[lane].values()
                       for q in cls.values())

    # ----------------------------------------------------------- mutations
    def offer(self, req: ServeRequest) -> bool:
        """Admit ``req`` into its lane; False when the queue is at its
        depth bound or closed (the caller records the rejection)."""
        with self._lock:
            if self._closed or self._depth >= self.max_depth:
                return False
            tenants = self._lanes[req.lane][req.priority]
            q = tenants.get(req.tenant)
            if q is None:
                q = tenants[req.tenant] = deque()
            q.append(req)
            self._depth += 1
            self._nonempty.notify_all()
            return True

    def take(self, lane: int = 0,
             timeout: Optional[float] = None) -> Optional[ServeRequest]:
        """Next request for ``lane``: interactive before batch, tenants
        round-robin within a class.  Blocks up to ``timeout`` seconds;
        returns None on timeout, or immediately once the queue is
        closed and the lane is drained."""
        with self._lock:
            while True:
                req = self._pop_locked(lane)
                if req is not None:
                    self._depth -= 1
                    return req
                if self._closed:
                    return None
                if not self._nonempty.wait(timeout=timeout):
                    return None

    def _pop_locked(self, lane: int) -> Optional[ServeRequest]:
        for cls in PRIORITY_CLASSES:
            tenants = self._lanes[lane][cls]
            for tenant, q in tenants.items():
                req = q.popleft()
                if q:
                    tenants.move_to_end(tenant)  # rotate to the back
                else:
                    del tenants[tenant]
                return req
        return None

    def drain(self, lane: int) -> list:
        """Remove and return every queued request for ``lane`` (close
        path: the server cancels their futures)."""
        out = []
        with self._lock:
            while True:
                req = self._pop_locked(lane)
                if req is None:
                    return out
                self._depth -= 1
                out.append(req)

    def close(self) -> None:
        """Refuse new offers and wake every blocked ``take``; queued
        requests remain takeable (drain-on-close)."""
        with self._lock:
            self._closed = True
            self._nonempty.notify_all()
