"""``python -m repro.serve --demo`` — drive the server without client
code: two tenants on a two-context pool, one interactive with a warm
weight handle and a cache quota protecting it, one flooding batch
traffic; prints the per-tenant stats ledger and exits non-zero if the
scenario misbehaves (used as a CI smoke step)."""
from __future__ import annotations

import argparse
import sys
from typing import Optional, Sequence

import numpy as np

from ..core.runtime import RuntimeConfig
from . import BATCH, INTERACTIVE, BlasxServer


def demo(n: int = 96, tile: int = 32, floods: int = 8,
         serves: int = 6) -> int:
    rng = np.random.default_rng(0)
    cfg = RuntimeConfig(n_devices=2, mode="sim", cache_bytes=8 << 20)
    with BlasxServer(cfg, pool_size=2, tile=tile, max_depth=64,
                     quotas={"flood": 256 << 10}) as srv:
        w = srv.tile("interactive-app",
                     rng.standard_normal((n, n)))
        x = srv.tile("interactive-app",
                     rng.standard_normal((n, n)))
        big = rng.standard_normal((2 * n, 2 * n))
        futs = [srv.submit("flood", "gemm", big, big, priority=BATCH)
                for _ in range(floods)]
        outs = [srv.submit("interactive-app", "gemm", x, w,
                           priority=INTERACTIVE)
                for _ in range(serves)]
        ref = x.array() @ w.array()
        for f in outs:
            if not np.allclose(f.result(timeout=60).array(), ref,
                               atol=1e-8):
                print("demo FAILED: wrong gemm result", file=sys.stderr)
                return 1
        for f in futs:
            f.result(timeout=60)
        st = srv.stats()
    for tenant, row in sorted(st["tenants"].items()):
        print(f"{tenant:16s} completed={row['completed']:3d} "
              f"rejected={row['rejected']:3d} "
              f"p50={row['latency_p50_ms']:8.2f}ms "
              f"p99={row['latency_p99_ms']:8.2f}ms "
              f"wait_p50={row['queue_wait_p50_ms']:8.2f}ms "
              f"quota_evictions={row['quota_evictions']}")
    print(f"pool={st['pool_size']} lane_load={st['lane_load']} "
          f"affinity={st['affinity']}")
    if st["tenants"]["interactive-app"]["completed"] != serves:
        print("demo FAILED: interactive requests lost", file=sys.stderr)
        return 1
    print("demo OK")
    return 0


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.serve",
        description="BlasxServer smoke entrypoint")
    ap.add_argument("--demo", action="store_true",
                    help="run the two-tenant demo scenario")
    ap.add_argument("--n", type=int, default=96,
                    help="interactive matrix size (default 96)")
    args = ap.parse_args(argv)
    if not args.demo:
        ap.print_help()
        return 2
    return demo(n=args.n)


if __name__ == "__main__":  # pragma: no cover - exercised by CI
    sys.exit(main())
