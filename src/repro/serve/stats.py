"""Per-tenant serving ledger.

The runtime's ledgers count bytes and tasks; the serving layer adds
the client-visible half — latency and queue-wait percentiles, shed
load, cancellations — keyed by tenant.  Sample windows are bounded
(last ``window`` samples per tenant) so a long-lived server's stats
stay O(1) in memory; counters are lifetime.
"""
from __future__ import annotations

import math
import threading
from collections import deque
from typing import Deque, Dict, List, Optional

DEFAULT_WINDOW = 4096


def percentile(samples: List[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]); 0.0 on no samples."""
    if not samples:
        return 0.0
    xs = sorted(samples)
    rank = max(1, math.ceil(q / 100.0 * len(xs)))
    return xs[min(rank, len(xs)) - 1]


class _TenantLedger:
    __slots__ = ("latencies", "waits", "completed", "failed",
                 "rejected", "cancelled")

    def __init__(self, window: int):
        self.latencies: Deque[float] = deque(maxlen=window)
        self.waits: Deque[float] = deque(maxlen=window)
        self.completed = 0
        self.failed = 0
        self.rejected = 0
        self.cancelled = 0


class ServerStats:
    """Thread-safe per-tenant counters + latency/wait percentiles."""

    # lock-discipline declarations (repro.analysis, docs/ANALYSIS.md)
    _GUARDED_BY = {"_lock": ("_tenants",)}
    _LOCK_HELD = ("_ledger",)

    def __init__(self, window: int = DEFAULT_WINDOW):
        self._window = window
        self._lock = threading.Lock()
        self._tenants: Dict[str, _TenantLedger] = {}

    def _ledger(self, tenant: str) -> _TenantLedger:
        led = self._tenants.get(tenant)
        if led is None:
            led = self._tenants[tenant] = _TenantLedger(self._window)
        return led

    # ----------------------------------------------------------- recording
    def record(self, tenant: str, wait_s: float, latency_s: float,
               ok: bool) -> None:
        with self._lock:
            led = self._ledger(tenant)
            led.waits.append(wait_s)
            led.latencies.append(latency_s)
            if ok:
                led.completed += 1
            else:
                led.failed += 1

    def record_rejection(self, tenant: str) -> None:
        with self._lock:
            self._ledger(tenant).rejected += 1

    def record_cancelled(self, tenant: str) -> None:
        with self._lock:
            self._ledger(tenant).cancelled += 1

    # ------------------------------------------------------------ reporting
    def tenant_p99(self, tenant: str) -> float:
        with self._lock:
            led = self._tenants.get(tenant)
            return percentile(list(led.latencies), 99.0) if led else 0.0

    def snapshot(self, quota_evictions: Optional[Dict[str, int]] = None
                 ) -> Dict[str, Dict[str, float]]:
        """Per-tenant dict: counters plus p50/p99 latency and queue
        wait in milliseconds.  ``quota_evictions`` (tenant -> count,
        from the ALRU owner ledgers) is merged in when given."""
        out: Dict[str, Dict[str, float]] = {}
        with self._lock:
            items = [(t, led, list(led.latencies), list(led.waits))
                     for t, led in self._tenants.items()]
        for tenant, led, lats, waits in items:
            out[tenant] = {
                "completed": led.completed,
                "failed": led.failed,
                "rejected": led.rejected,
                "cancelled": led.cancelled,
                "latency_p50_ms": percentile(lats, 50.0) * 1e3,
                "latency_p99_ms": percentile(lats, 99.0) * 1e3,
                "queue_wait_p50_ms": percentile(waits, 50.0) * 1e3,
                "queue_wait_p99_ms": percentile(waits, 99.0) * 1e3,
                "quota_evictions": (quota_evictions or {}).get(tenant, 0),
            }
        # quota'd tenants that never completed a request still show up
        for tenant, n in (quota_evictions or {}).items():
            if tenant not in out:
                out[tenant] = {
                    "completed": 0, "failed": 0, "rejected": 0,
                    "cancelled": 0, "latency_p50_ms": 0.0,
                    "latency_p99_ms": 0.0, "queue_wait_p50_ms": 0.0,
                    "queue_wait_p99_ms": 0.0, "quota_evictions": n,
                }
        return out
