"""repro.serve — multi-tenant serving front end (ROADMAP north star).

:class:`BlasxServer` multiplexes concurrent clients onto a pool of
warm :class:`~repro.api.BlasxContext`s: bounded admission with
priority classes and tenant-fair dequeue, affinity routing to the
context holding a tenant's warm tiles, per-tenant ALRU quotas for
cache isolation, and a per-tenant latency ledger.

Quickstart::

    from repro.serve import BlasxServer, INTERACTIVE

    with BlasxServer(pool_size=2,
                     quotas={"tenant-a": 8 << 20}) as srv:
        w = srv.tile("tenant-b", weights)        # warm handle, home ctx
        f = srv.submit("tenant-b", "gemm", x, w, priority=INTERACTIVE)
        y = f.result().array()
        print(srv.stats()["tenants"]["tenant-b"]["latency_p99_ms"])

``python -m repro.serve --demo`` drives a two-tenant smoke scenario.
"""
from .admission import (BATCH, DEFAULT_BOOSTS, INTERACTIVE,
                        PRIORITY_CLASSES, AdmissionQueue, ServeRequest)
from .server import BlasxServer
from .stats import ServerStats, percentile

__all__ = [
    "BlasxServer", "AdmissionQueue", "ServeRequest", "ServerStats",
    "percentile", "INTERACTIVE", "BATCH", "PRIORITY_CLASSES",
    "DEFAULT_BOOSTS",
]
