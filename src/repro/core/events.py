"""Discrete-event multi-stream timing engine (sim mode).

The seed engine modeled a device batch as a single lump —
``max(compute_s, comm_s)`` — so stream concurrency, H2D/P2P pipelining
and host-link contention were asserted by formula, never simulated.
This module replaces the lump with a deterministic discrete-event
schedule over explicit *resources*:

* **stream timelines** — each device owns ``effective_streams`` lanes;
  one task of a batch runs on one lane (fetch -> compute -> write-back
  in program order), so concurrent tasks overlap exactly where their
  per-lane chains allow it;
* **link timelines** — per-device H2D, D2D (P2P), D2H and (pod tier)
  ICI lanes.  With
  ``RuntimeConfig.shared_host_link`` every device's H2D (and D2H)
  transfers serialize on ONE host lane per direction at full link
  bandwidth — the paper's "cuBLAS-XT overloads the PCI-E" contention
  emerges from the schedule instead of a bandwidth divide.

Every tile fetch, compute span (one task's backend dispatch share) and
MESI-X write-back becomes a :class:`Span` on a ``(device, lane)``
timeline.  Overlap, stalls and the 2-stream-vs-4-stream policy gap are
*observed* properties of the resulting timeline; the numerics path is
untouched (the engine only assigns clocks — see the bitwise parity
suite in ``tests/test_events.py``).

Determinism: link requests are honored in scheduler issue order (the
sim loop's earliest-free-device order), i.e. deterministic list
scheduling.  ``Date``-free, RNG-free — the same run always produces
the same timeline.

The recorded timeline exports as Chrome-trace JSON
(``chrome://tracing`` / https://ui.perfetto.dev): one *process* per
device, one *thread* per stream/link lane, balanced ``B``/``E`` event
pairs.  :func:`validate_trace` is the schema gate used by tests and
the CI bench-smoke job (CLI:
``python -m benchmarks.overlap --validate trace.json``).
"""
from __future__ import annotations

import bisect
import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple

# lane ids within one device's trace process: streams are 0..n-1, links
# get fixed high ids so stream count never collides with them
LANE_H2D = 100
LANE_D2D = 101
LANE_D2H = 102
LANE_ICI = 103  # pod tier: inter-chip ring hops of a mesh_shard device
LINK_LANES = {"h2d": LANE_H2D, "d2d": LANE_D2D, "d2h": LANE_D2H,
              "ici": LANE_ICI}

TRACE_SCHEMA = 1
# recording cap: a runaway metadata-scale session stops *recording*
# (never stops timing); the trace metadata flags the truncation
MAX_TRACE_SPANS = 1_000_000


@dataclasses.dataclass(frozen=True)
class TimedXfer:
    """One modeled transfer: direction, payload and link seconds.

    ``src`` names the *serving* device of a P2P (d2d) or neighbor-tier
    (ici) transfer; the engine then reserves the server's egress lane,
    so contention lands on the device actually being drained.  ``-1``
    (h2d/d2h, or legacy callers) keeps the transfer on the requester's
    own lane."""

    kind: str       # "h2d" | "d2d" | "d2h" | "ici"
    nbytes: int
    secs: float
    label: str = ""
    src: int = -1   # serving device of a d2d/ici transfer (-1 = requester)


@dataclasses.dataclass
class TimedTask:
    """Timing raw material for one task of a device batch: the gather
    phase's fetches, the task's compute share of the batch dispatch,
    and the finalize phase's write-back."""

    task_id: int
    name: str
    compute_s: float
    fetches: Sequence[TimedXfer]
    writeback: Optional[TimedXfer] = None
    routine: str = ""
    steps: int = 0
    flops: int = 0
    kind: str = "owner"  # owner | partial | fixup (work-centric mode)
    parent: Optional[int] = None  # partial's owner task (fix-up keeps it)


@dataclasses.dataclass(frozen=True)
class Span:
    """One completed interval on a (device, lane) timeline (seconds)."""

    device: int
    lane: int
    cat: str        # "compute" | "h2d" | "d2d" | "d2h"
    name: str
    start: float
    dur: float
    nbytes: int = 0
    task_id: int = -1
    kind: str = ""  # task kind of a compute span ("" for transfers)
    parent: Optional[int] = None  # owner task of a partial's span


class LinkTimeline:
    """A serially-reusable transfer resource.  ``acquire`` grants the
    earliest idle slot at or after the request time — contending
    transfers serialize, and a short transfer requested at an earlier
    virtual time *backfills* idle gaps left by already-reserved later
    slots (the sim loop issues batches in earliest-free-device order,
    not global virtual-time order, so gaps are a scheduling artifact,
    not link idleness).  Reservations are kept as disjoint, coalesced
    intervals; back-to-back grants merge, so the list stays short."""

    __slots__ = ("_busy", "busy_s")

    def __init__(self) -> None:
        self._busy: List[List[float]] = []  # sorted disjoint [start, end)
        self.busy_s = 0.0

    def acquire(self, t_req: float, dur: float) -> float:
        self.busy_s += dur
        start = t_req
        iv = self._busy
        i = bisect.bisect_right(iv, [start, float("inf")])
        if i > 0 and iv[i - 1][1] > start:
            start = iv[i - 1][1]
        while i < len(iv) and iv[i][0] < start + dur:
            start = iv[i][1]
            i += 1
        end = start + dur
        # coalesce with exact-touching neighbours
        if i > 0 and iv[i - 1][1] == start:
            iv[i - 1][1] = end
            if i < len(iv) and iv[i][0] == end:
                iv[i - 1][1] = iv[i][1]
                del iv[i]
        elif i < len(iv) and iv[i][0] == end:
            iv[i][0] = start
        else:
            iv.insert(i, [start, end])
        return start


def _processor_sharing(arrivals: Sequence[float],
                       works: Sequence[float]) -> List[float]:
    """Finish times of compute jobs under egalitarian processor
    sharing: job ``i`` arrives at ``arrivals[i]`` with ``works[i]``
    seconds of solo work; ``k`` concurrently-active jobs each progress
    at rate ``1/k``.  Models ``n_streams`` kernels co-resident on one
    device: their spans genuinely overlap in time while aggregate
    throughput stays at the device rate (a same-arrival batch finishes
    exactly when the serial sum would)."""
    order = sorted(range(len(arrivals)), key=lambda i: arrivals[i])
    finish = [0.0] * len(arrivals)
    remaining: Dict[int, float] = {}
    t = 0.0
    idx = 0
    while idx < len(order) or remaining:
        if not remaining:
            t = arrivals[order[idx]]
        while idx < len(order) and arrivals[order[idx]] <= t:
            j = order[idx]
            if works[j] <= 0.0:
                finish[j] = arrivals[j]  # no compute: instant
            else:
                remaining[j] = works[j]
            idx += 1
        if not remaining:
            continue
        k = len(remaining)
        next_arrival = arrivals[order[idx]] if idx < len(order) else None
        m = min(remaining.values())
        t_done = t + m * k
        if next_arrival is not None and next_arrival < t_done:
            dt = (next_arrival - t) / k
            for j in remaining:
                remaining[j] = max(0.0, remaining[j] - dt)
            t = next_arrival
            continue
        # subtract in *work* units (not via t_done - t, which loses
        # precision and can leave the min job fractionally unfinished
        # forever): the min job(s) land on exactly zero and complete
        for j in list(remaining):
            rem = remaining[j] - m
            if rem <= 0.0:
                finish[j] = t_done
                del remaining[j]
            else:
                remaining[j] = rem
        t = t_done
    return finish


class EventEngine:
    """Owns every stream/link timeline of one runtime session plus the
    recorded span list.  One instance per :class:`BlasxRuntime` in sim
    mode with ``time_model="events"``."""

    def __init__(self, cfg) -> None:
        self.cfg = cfg
        n = cfg.n_devices
        if cfg.shared_host_link:
            # one host lane per direction, shared by every device: H2D
            # transfers contend with each other (and D2H with D2H),
            # full duplex across directions — paper Table IV's
            # "bidirectional" measured link
            shared_h2d, shared_d2h = LinkTimeline(), LinkTimeline()
            self._h2d = [shared_h2d] * n
            self._d2h = [shared_d2h] * n
        else:
            self._h2d = [LinkTimeline() for _ in range(n)]
            self._d2h = [LinkTimeline() for _ in range(n)]
        # P2P rides dedicated switch lanes: per-device, no cross-device
        # contention (cfg comment in runtime.RuntimeConfig)
        self._d2d = [LinkTimeline() for _ in range(n)]
        # pod tier: per-device ICI links (a mesh_shard device's ring
        # hops and neighbor-tier fetches); dedicated point-to-point
        # fabric, so no cross-device contention either
        self._ici = [LinkTimeline() for _ in range(n)]
        self.spans: List[Span] = []
        self.truncated = False
        self.record = bool(getattr(cfg, "record_trace", True))

    # ------------------------------------------------------------- helpers
    def _link(self, kind: str, device: int) -> LinkTimeline:
        return {"h2d": self._h2d, "d2d": self._d2d,
                "d2h": self._d2h, "ici": self._ici}[kind][device]

    def _emit(self, device: int, lane: int, cat: str, name: str,
              start: float, dur: float, nbytes: int = 0,
              task_id: int = -1, kind: str = "",
              parent: Optional[int] = None) -> None:
        if not self.record:
            return
        if len(self.spans) >= MAX_TRACE_SPANS:
            self.truncated = True
            return
        self.spans.append(Span(device=device, lane=lane, cat=cat,
                               name=name, start=start, dur=dur,
                               nbytes=nbytes, task_id=task_id, kind=kind,
                               parent=parent))

    # ----------------------------------------------------------- schedule
    def schedule_batch(self, device: int, start: float,
                       items: Sequence[TimedTask], n_streams: int,
                       overlap: bool
                       ) -> Tuple[float, List[float], Dict[str, float]]:
        """Schedule one device batch starting at ``start``.

        With ``overlap`` each task runs on its own stream lane
        (``len(items) <= n_streams``, Alg. 1's ``take_top``): its
        fetches serialize on the link lanes, its compute span occupies
        the stream, its write-back rides the D2H lane.  Concurrent
        compute spans *share the device* — streams buy
        communication/computation overlap, not extra FLOPS — so
        compute progresses under egalitarian processor sharing: ``k``
        simultaneously-active tasks each run at ``1/k`` of the device
        rate (a warm 4-task batch shows 4 fully-overlapped compute
        spans whose common end equals the serial sum, exactly the lump
        model's compute-bound duration).  Without ``overlap`` (the
        fork-join supermatrix baseline) the whole batch chains on a
        single lane, so communication never hides behind compute.

        Returns ``(span, per-task finish times, per-kind link busy
        seconds charged by this batch)``.
        """
        busy = {"h2d": 0.0, "d2d": 0.0, "d2h": 0.0, "ici": 0.0}
        if not overlap:
            # fork-join: fetch -> compute -> write-back, task after
            # task, all on lane 0 — nothing ever hides behind compute
            finishes = []
            cursor = start
            for item in items:
                for x in item.fetches:
                    if x.secs <= 0.0:
                        continue
                    s = self._xfer(device, x, cursor, busy, item.task_id)
                    cursor = s + x.secs
                if item.compute_s > 0.0:
                    self._emit(device, 0, "compute", item.name, cursor,
                               item.compute_s, task_id=item.task_id,
                               kind=item.kind, parent=item.parent)
                    cursor += item.compute_s
                wb = item.writeback
                if wb is not None and wb.secs > 0.0:
                    s = self._xfer(device, wb, cursor, busy, item.task_id)
                    cursor = s + wb.secs
                finishes.append(cursor)
            span = max(finishes, default=start) - start
            return span, finishes, busy
        n_lanes = max(1, n_streams)
        arrivals: List[float] = []
        for item in items:
            cursor = start
            for x in item.fetches:
                if x.secs <= 0.0:
                    continue  # warm-cache hit: no transfer, no event
                s = self._xfer(device, x, cursor, busy, item.task_id)
                cursor = s + x.secs
            arrivals.append(cursor)
        compute_end = _processor_sharing(
            arrivals, [it.compute_s for it in items])
        finishes = []
        for idx, item in enumerate(items):
            if item.compute_s > 0.0:
                self._emit(device, idx % n_lanes, "compute", item.name,
                           arrivals[idx], compute_end[idx] - arrivals[idx],
                           task_id=item.task_id, kind=item.kind,
                           parent=item.parent)
            cursor = compute_end[idx]
            wb = item.writeback
            if wb is not None and wb.secs > 0.0:
                s = self._xfer(device, wb, cursor, busy, item.task_id)
                cursor = s + wb.secs
            finishes.append(cursor)
        span = max(finishes, default=start) - start
        return span, finishes, busy

    def _xfer(self, device: int, x: TimedXfer, cursor: float,
              busy: Dict[str, float], task_id: int) -> float:
        """Acquire the link for one transfer, charge busy seconds and
        emit its span; returns the granted start time.

        A d2d (or neighbor-tier ici) transfer with a known source rides
        the *serving* device's egress lane (and its span lands on that
        device's track in the trace): one over-popular holder now
        serializes its peers' fetches, which is exactly the drain the
        LRU peer rotation in ``MesixDirectory.peer_holder`` spreads
        out.  The busy-seconds charge stays with the requesting
        device's ledger — it is the one whose task waited on the
        wire."""
        lane_dev = (x.src if (x.kind in ("d2d", "ici") and x.src >= 0)
                    else device)
        s = self._link(x.kind, lane_dev).acquire(cursor, x.secs)
        busy[x.kind] += x.secs
        self._emit(lane_dev, LINK_LANES[x.kind], x.kind,
                   f"{x.kind} {x.label}", s, x.secs, x.nbytes, task_id)
        return s

    # -------------------------------------------------------------- trace
    def chrome_trace(self, extra: Optional[Dict[str, object]] = None) -> dict:
        """Chrome-trace (chrome://tracing / Perfetto) JSON of the
        recorded timeline: balanced B/E pairs, one process per device,
        one thread per stream/link lane, microsecond timestamps."""
        return build_chrome_trace(
            self.spans, self.cfg.n_devices, self.cfg.effective_streams,
            truncated=self.truncated, extra=extra)


def build_chrome_trace(spans: Sequence[Span], n_devices: int,
                       n_streams: int, truncated: bool = False,
                       extra: Optional[Dict[str, object]] = None) -> dict:
    lane_names = {i: f"stream{i}" for i in range(n_streams)}
    lane_names.update({v: k for k, v in LINK_LANES.items()})
    events: List[dict] = []
    for dev in range(n_devices):
        events.append({"ph": "M", "name": "process_name", "pid": dev,
                       "tid": 0, "args": {"name": f"device{dev}"}})
        for lane, lname in sorted(lane_names.items()):
            events.append({"ph": "M", "name": "thread_name", "pid": dev,
                           "tid": lane, "args": {"name": lname}})
            events.append({"ph": "M", "name": "thread_sort_index",
                           "pid": dev, "tid": lane,
                           "args": {"sort_index": lane}})
    # per-lane chronological emission keeps each (pid, tid) sequence
    # monotonic with properly nested B/E pairs (a lane never overlaps
    # itself: streams run one task chain, links are serially reusable)
    by_lane: Dict[Tuple[int, int], List[Span]] = {}
    for sp in spans:
        by_lane.setdefault((sp.device, sp.lane), []).append(sp)
    for (dev, lane), lane_spans in sorted(by_lane.items()):
        for sp in sorted(lane_spans, key=lambda s: s.start):
            args: Dict[str, object] = {"task_id": sp.task_id}
            if sp.nbytes:
                args["nbytes"] = sp.nbytes
            if sp.kind:
                args["kind"] = sp.kind
            if sp.parent is not None:
                args["parent"] = sp.parent
            events.append({"name": sp.name, "cat": sp.cat, "ph": "B",
                           "ts": sp.start * 1e6, "pid": dev, "tid": lane,
                           "args": args})
            events.append({"name": sp.name, "cat": sp.cat, "ph": "E",
                           "ts": (sp.start + sp.dur) * 1e6, "pid": dev,
                           "tid": lane})
    meta: Dict[str, object] = {"schema": TRACE_SCHEMA,
                               "n_devices": n_devices,
                               "n_streams": n_streams,
                               "truncated": truncated}
    if extra:
        meta.update(extra)
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": meta}


# ------------------------------------------------------------ validation
def validate_trace(trace: dict) -> Dict[str, object]:
    """Structural schema gate for an exported Chrome trace.

    Checks: top-level shape, required event fields, per-(pid, tid)
    monotonically non-decreasing timestamps, balanced and properly
    nested B/E pairs with matching names, and non-negative durations.
    Raises ``ValueError`` listing every violation; returns a summary
    dict (span/event counts, end timestamp) when the trace is valid.
    """
    problems: List[str] = []
    if not isinstance(trace, dict):
        raise ValueError("trace must be a JSON object")
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError("trace.traceEvents must be a list")
    other = trace.get("otherData")
    if not isinstance(other, dict) or other.get("schema") != TRACE_SCHEMA:
        problems.append(f"otherData.schema != {TRACE_SCHEMA}")
    stacks: Dict[Tuple[int, int], List[dict]] = {}
    last_ts: Dict[Tuple[int, int], float] = {}
    n_spans = 0
    end_ts = 0.0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("B", "E", "M"):
            problems.append(f"event {i}: unknown ph {ph!r}")
            continue
        if "pid" not in ev or "tid" not in ev:
            problems.append(f"event {i}: missing pid/tid")
            continue
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)):
            problems.append(f"event {i}: missing/non-numeric ts")
            continue
        lane = (ev["pid"], ev["tid"])
        if ts < last_ts.get(lane, 0.0) - 1e-9:
            problems.append(
                f"event {i}: ts {ts} not monotonic on pid={lane[0]} "
                f"tid={lane[1]} (last {last_ts[lane]})")
        last_ts[lane] = max(last_ts.get(lane, 0.0), ts)
        end_ts = max(end_ts, ts)
        stack = stacks.setdefault(lane, [])
        if ph == "B":
            if not ev.get("name"):
                problems.append(f"event {i}: B event without a name")
            stack.append(ev)
        else:  # E
            if not stack:
                problems.append(
                    f"event {i}: E without matching B on pid={lane[0]} "
                    f"tid={lane[1]}")
                continue
            b = stack.pop()
            if ev.get("name") not in (None, b.get("name")):
                problems.append(
                    f"event {i}: E name {ev.get('name')!r} != B name "
                    f"{b.get('name')!r}")
            if ts < b["ts"] - 1e-9:
                problems.append(f"event {i}: negative duration "
                                f"({b['ts']} -> {ts})")
            n_spans += 1
    for lane, stack in stacks.items():
        if stack:
            problems.append(f"{len(stack)} unbalanced B event(s) on "
                            f"pid={lane[0]} tid={lane[1]}")
    if problems:
        raise ValueError("invalid trace:\n  " + "\n  ".join(problems))
    return {"events": len(events), "spans": n_spans,
            "end_ts_us": end_ts, "lanes": len(last_ts)}


def trace_spans(trace: dict) -> List[dict]:
    """Reassemble ``{pid, tid, cat, name, start, end}`` spans from a
    validated trace's B/E pairs (test/analysis helper)."""
    out: List[dict] = []
    stacks: Dict[Tuple[int, int], List[dict]] = {}
    for ev in trace.get("traceEvents", ()):
        if ev.get("ph") == "B":
            stacks.setdefault((ev["pid"], ev["tid"]), []).append(ev)
        elif ev.get("ph") == "E":
            stack = stacks.get((ev["pid"], ev["tid"]))
            if stack:
                b = stack.pop()
                args = b.get("args") or {}
                out.append({"pid": ev["pid"], "tid": ev["tid"],
                            "cat": b.get("cat"), "name": b.get("name"),
                            "start": b["ts"], "end": ev["ts"],
                            "kind": args.get("kind", ""),
                            "task_id": args.get("task_id", -1),
                            "parent": args.get("parent")})
    return out


def max_concurrent(trace: dict, device: Optional[int] = None,
                   cat: str = "compute") -> int:
    """Peak number of simultaneously-open ``cat`` spans (optionally on
    one device) — the observable stream-concurrency of a run."""
    edges: List[Tuple[float, int]] = []
    for sp in trace_spans(trace):
        if sp["cat"] != cat:
            continue
        if device is not None and sp["pid"] != device:
            continue
        if sp["end"] <= sp["start"]:
            continue
        edges.append((sp["start"], 1))
        edges.append((sp["end"], -1))
    # close before open at identical timestamps: touching spans do not
    # count as concurrent
    edges.sort(key=lambda e: (e[0], e[1]))
    peak = cur = 0
    for _, delta in edges:
        cur += delta
        peak = max(peak, cur)
    return peak


def main(argv: Optional[Sequence[str]] = None) -> int:
    """CLI schema gate, fronted by
    ``python -m benchmarks.overlap --validate trace.json`` (running
    this module with ``-m`` directly works too, but trips a cosmetic
    runpy warning because the package imports it)."""
    import argparse
    import sys

    ap = argparse.ArgumentParser(
        prog="repro.core.events",
        description="validate an exported Chrome trace against the "
                    "event-engine schema")
    ap.add_argument("trace", help="path to a trace JSON file")
    args = ap.parse_args(argv)
    with open(args.trace) as f:
        trace = json.load(f)
    try:
        summary = validate_trace(trace)
    except ValueError as e:
        print(e, file=sys.stderr)
        return 1
    concurrency = {dev: max_concurrent(trace, device=dev)
                   for dev in range(trace["otherData"].get("n_devices", 0))}
    print(f"trace OK: {summary['spans']} spans / {summary['events']} "
          f"events across {summary['lanes']} lanes, ends at "
          f"{summary['end_ts_us']:.1f} us; peak concurrent compute "
          f"spans per device: {concurrency}")
    return 0


if __name__ == "__main__":  # pragma: no cover - exercised by CI
    import sys

    sys.exit(main())
