"""Global task queue + reservation stations (paper §IV-C, Fig. 4).

The paper uses the Michael–Scott non-blocking MPMC queue; under the
Python GIL, lock-freedom is moot, so we reproduce the *semantics* — a
shared global FIFO supporting concurrent dequeue (work sharing) — with
a lock-guarded deque plus a condition variable so threaded workers can
wait for TRSM dependencies to resolve.

The ReadyQueue is dependency aware: tasks with unmet ``deps`` are held
in a pending table and enqueued the moment their last producer
completes (the paper's TRSM intra-column chains).
"""
from __future__ import annotations

import collections
import threading
from typing import Dict, List, Optional, Sequence

from .task import Task


class ReadyQueue:
    # lock-discipline declarations (repro.analysis, docs/ANALYSIS.md):
    # _cv wraps _lock, so `with self._cv` counts as holding _lock.
    _GUARDED_BY = {"_lock": (
        "_tasks", "_ready", "_pending_deps", "_dependents",
        "_outstanding")}
    _LOCK_ALIASES = {"_cv": "_lock"}

    def __init__(self, tasks: Sequence[Task]):
        self._tasks: Dict[int, Task] = {t.task_id: t for t in tasks}
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._ready: collections.deque = collections.deque()
        self._pending_deps: Dict[int, int] = {}
        self._dependents: Dict[int, List[int]] = collections.defaultdict(list)
        self._outstanding = len(tasks)  # dequeued-but-not-completed + queued + pending
        for t in tasks:
            missing = len(t.deps)
            if missing == 0:
                self._ready.append(t.task_id)
            else:
                self._pending_deps[t.task_id] = missing
                for d in t.deps:
                    self._dependents[d].append(t.task_id)

    def __len__(self) -> int:
        with self._lock:
            return len(self._ready)

    def try_dequeue(self) -> Optional[Task]:
        """Non-blocking dequeue (sim mode / RS refill)."""
        with self._lock:
            if self._ready:
                return self._tasks[self._ready.popleft()]
            return None

    def dequeue_wait(self, timeout: float = 0.05) -> Optional[Task]:
        """Blocking dequeue for threaded workers: returns a task, or None
        when the queue is *drained* (all tasks completed).  A None with
        tasks still outstanding means "retry" (spurious wakeup)."""
        with self._cv:
            while not self._ready and self._outstanding > 0:
                self._cv.wait(timeout=timeout)
                if not self._ready and self._outstanding > 0:
                    return None  # let the caller try stealing instead
            if self._ready:
                return self._tasks[self._ready.popleft()]
            return None

    def complete(self, task: Task) -> None:
        """Mark a task done; release dependents whose deps are all met.

        Safe to call with a *foreign* task (one owned by another queue in
        a static split): only its dependency edges are resolved here."""
        with self._cv:
            if task.task_id in self._tasks:
                self._outstanding -= 1
            for dep_id in self._dependents.pop(task.task_id, ()):
                left = self._pending_deps[dep_id] - 1
                if left == 0:
                    del self._pending_deps[dep_id]
                    self._ready.append(dep_id)
                else:
                    self._pending_deps[dep_id] = left
            self._cv.notify_all()

    def requeue(self, task: Task) -> None:
        """Return a dequeued-but-never-completed task to the ready end
        (worker crash recovery: reservation stations are drained back
        here so no task is stranded).  The task was already counted in
        ``_outstanding`` when dequeued, so only the ready list moves."""
        with self._cv:
            if task.task_id not in self._tasks:
                raise ValueError(f"requeue of foreign task {task.task_id}")
            self._ready.append(task.task_id)
            self._cv.notify_all()

    def drained(self) -> bool:
        with self._lock:
            return self._outstanding == 0

    def has_ready(self) -> bool:
        with self._lock:
            return bool(self._ready)

    def pending_count(self) -> int:
        with self._lock:
            return len(self._pending_deps)


class ReservationStation:
    """Per-device task buffer (paper Fig. 4).  Each slot carries
    (priority, task); work stealing and priority scheduling act on it."""

    # lock-discipline declarations (repro.analysis, docs/ANALYSIS.md)
    _GUARDED_BY = {"_lock": ("_slots", "_prio")}

    def __init__(self, device_id: int, n_slots: int):
        self.device_id = device_id
        self.n_slots = n_slots
        self._slots: List[Task] = []
        self._prio: Dict[int, float] = {}
        self._lock = threading.Lock()

    def __len__(self) -> int:
        with self._lock:
            return len(self._slots)

    def free_slots(self) -> int:
        with self._lock:
            return self.n_slots - len(self._slots)

    def put(self, task: Task, priority: float) -> None:
        with self._lock:
            if len(self._slots) >= self.n_slots:
                raise RuntimeError("RS overflow")
            self._slots.append(task)
            self._prio[task.task_id] = priority

    def set_priorities(self, prio_fn) -> None:
        """Refresh priorities (paper: 'runtime refreshes the priorities in
        RS after new tasks coming in')."""
        with self._lock:
            for t in self._slots:
                self._prio[t.task_id] = prio_fn(t)

    def take_top(self, n: int) -> List[Task]:
        """Pop the top-n prioritized tasks (Alg. 1 line 19)."""
        with self._lock:
            self._slots.sort(key=lambda t: self._prio[t.task_id], reverse=True)
            taken = self._slots[:n]
            self._slots = self._slots[n:]
            for t in taken:
                self._prio.pop(t.task_id, None)
            return taken

    def drain(self) -> List[Task]:
        """Remove and return every buffered task (crash recovery)."""
        with self._lock:
            taken, self._slots = self._slots, []
            self._prio.clear()
            return taken

    def steal(self, prio_fn=None) -> Optional[Task]:
        """A peer steals the *lowest*-priority task — the one with the
        least locality value to this station's device.

        ``prio_fn`` re-evaluates each buffered task's priority (Eq. 3)
        against the device's *current* L1/L2 cache state before the
        victim is chosen.  Put-time priorities go stale as caches fill
        (``_fill_and_take`` only refreshes the thief's own station), so
        selecting on them could hand the thief a task whose input tiles
        are by now L1-hot here — the exact traffic stealing is meant to
        avoid.  Without ``prio_fn`` the stored priorities are used
        (FIFO-priority policies, unit tests)."""
        with self._lock:
            if not self._slots:
                return None
            if prio_fn is not None:
                for t in self._slots:
                    self._prio[t.task_id] = prio_fn(t)
            self._slots.sort(key=lambda t: self._prio[t.task_id], reverse=True)
            victim = self._slots.pop()
            self._prio.pop(victim.task_id, None)
            return victim
