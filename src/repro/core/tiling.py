"""Tile representation of matrices (paper §III-A).

A matrix of shape (M, N) with tile size T is logically partitioned into
ceil(M/T) x ceil(N/T) tiles; interior tiles are T x T, edge tiles are
ragged.  Tiles are identified by ``TileKey(matrix_id, i, j)`` — the
"host address" of the paper's runtime.  The runtime never copies the
full matrix; tasks carry tile keys and the engine materializes tile
views on demand.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterator, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True, order=True)
class TileKey:
    """Unique identity of one tile: which matrix, which (row, col) block."""

    matrix_id: str
    i: int
    j: int

    def __repr__(self) -> str:  # compact, used in ledgers/logs
        return f"{self.matrix_id}[{self.i},{self.j}]"


@dataclasses.dataclass(frozen=True)
class TileGrid:
    """Tile decomposition of one matrix (paper §III-A)."""

    matrix_id: str
    rows: int
    cols: int
    tile: int

    @property
    def n_tile_rows(self) -> int:
        return max(1, math.ceil(self.rows / self.tile))

    @property
    def n_tile_cols(self) -> int:
        return max(1, math.ceil(self.cols / self.tile))

    @property
    def n_tiles(self) -> int:
        return self.n_tile_rows * self.n_tile_cols

    def tile_shape(self, i: int, j: int) -> Tuple[int, int]:
        """Shape of tile (i, j); edge tiles are ragged."""
        self._check(i, j)
        h = min(self.tile, self.rows - i * self.tile)
        w = min(self.tile, self.cols - j * self.tile)
        return (h, w)

    def tile_slice(self, i: int, j: int) -> Tuple[slice, slice]:
        self._check(i, j)
        r0 = i * self.tile
        c0 = j * self.tile
        h, w = self.tile_shape(i, j)
        return (slice(r0, r0 + h), slice(c0, c0 + w))

    def key(self, i: int, j: int) -> TileKey:
        self._check(i, j)
        return TileKey(self.matrix_id, i, j)

    def nbytes(self, i: int, j: int, itemsize: int = 8) -> int:
        h, w = self.tile_shape(i, j)
        return h * w * itemsize

    def keys(self) -> Iterator[TileKey]:
        for i in range(self.n_tile_rows):
            for j in range(self.n_tile_cols):
                yield self.key(i, j)

    def _check(self, i: int, j: int) -> None:
        if not (0 <= i < self.n_tile_rows and 0 <= j < self.n_tile_cols):
            raise IndexError(
                f"tile ({i},{j}) out of grid "
                f"{self.n_tile_rows}x{self.n_tile_cols} of {self.matrix_id}"
            )


class TiledMatrix:
    """A matrix plus its tile grid.  Host-resident (paper: matrices stay in
    host RAM; GPUs operate out-of-core on tiles)."""

    def __init__(self, matrix_id: str, data, tile: int):
        self.data = np.asarray(data)
        if self.data.ndim != 2:
            raise ValueError(f"{matrix_id}: expected 2-D, got {self.data.shape}")
        self.grid = TileGrid(matrix_id, self.data.shape[0], self.data.shape[1], tile)

    @property
    def matrix_id(self) -> str:
        return self.grid.matrix_id

    @property
    def dtype(self) -> np.dtype:
        return self.data.dtype

    def read_tile(self, i: int, j: int) -> np.ndarray:
        rs, cs = self.grid.tile_slice(i, j)
        return self.data[rs, cs]

    def write_tile(self, i: int, j: int, value: np.ndarray) -> None:
        rs, cs = self.grid.tile_slice(i, j)
        expected = self.grid.tile_shape(i, j)
        if tuple(value.shape) != expected:
            raise ValueError(
                f"write_tile({i},{j}): shape {value.shape} != {expected}"
            )
        self.data[rs, cs] = value

    def nbytes(self, i: int, j: int) -> int:
        return self.grid.nbytes(i, j, self.data.itemsize)


class ShadowMatrix:
    """Shape-only stand-in for metadata-only runs (execute=False):
    carries the tile grid and byte sizes, never any data.  Lets the
    scheduling/cache/ledger machinery run at the paper's true scale
    (N up to 40K, any precision) without allocating gigabytes.
    ``dtype`` (preferred) or ``itemsize`` sets the byte accounting."""

    def __init__(self, matrix_id: str, rows: int, cols: int, tile: int,
                 itemsize: int = 8, dtype=None):
        self.grid = TileGrid(matrix_id, rows, cols, tile)
        self.dtype = np.dtype(dtype) if dtype is not None else None
        self.itemsize = (self.dtype.itemsize if self.dtype is not None
                         else itemsize)

    @property
    def matrix_id(self) -> str:
        return self.grid.matrix_id

    def nbytes(self, i: int, j: int) -> int:
        return self.grid.nbytes(i, j, self.itemsize)

    def read_tile(self, i: int, j: int):  # pragma: no cover
        raise RuntimeError("ShadowMatrix holds no data (execute=False runs)")

    def write_tile(self, i: int, j: int, value) -> None:  # pragma: no cover
        raise RuntimeError("ShadowMatrix holds no data (execute=False runs)")


def workcentric_parts(n_steps: int, n_owner: int, capacity: int,
                      ragged: bool) -> int:
    """How many partial-k tasks the work-centric split planner carves
    from one task's k-loop (Stream-K, arXiv 2301.03598); 0 leaves the
    task in owner form.

    Two triggers (see ``repro.core.task.plan_work_centric``):

    * *small problem* — the whole owner-task count is below the
      machine's device x stream ``capacity``, so every splittable task
      is cut into enough pieces to roughly fill two full waves;
    * *boundary tile* — on large problems only ragged output tiles
      split (in half), shortening the tail without perturbing the
      interior schedule.

    Deterministic and purely arithmetic so
    :func:`degree_of_parallelism` and the tuning-layer step estimates
    can mirror the planner exactly.
    """
    if n_steps < 2 or capacity <= 0 or n_owner <= 0:
        return 0
    if n_owner < capacity:
        return min(n_steps, max(2, -(-2 * capacity // n_owner)))
    if ragged:
        return min(n_steps, 2)
    return 0


def panel_parts(task_bytes: int, cache_bytes: int, n_steps: int) -> int:
    """How many panel-sized partials the pod-tier staging planner carves
    from one beyond-HBM task's k-loop (see
    ``repro.core.task.plan_panel_staged``); 0 leaves the task whole.

    A task whose k-loop input working set (``task_bytes``) fits the
    device's HBM (``cache_bytes``) keeps its tiles resident through the
    normal ALRU path and needs no staging.  Truly beyond-HBM tasks are
    cut into contiguous panels of at most half the HBM each (headroom
    for a concurrent stream) — ``ceil(task_bytes / (cache_bytes/2))``
    — capped at one panel per k-step.  Deterministic and purely
    arithmetic, like :func:`workcentric_parts`.
    """
    if cache_bytes <= 0 or n_steps < 2 or task_bytes <= cache_bytes:
        return 0
    budget = max(1, cache_bytes // 2)
    return min(n_steps, -(-task_bytes // budget))


def split_ranges(n_steps: int, n_parts: int) -> list:
    """Partition ``range(n_steps)`` into ``n_parts`` contiguous
    ``(start, stop)`` k-ranges whose sizes differ by at most one."""
    if n_parts <= 0:
        raise ValueError("n_parts must be positive")
    n_parts = min(n_parts, n_steps)
    base, extra = divmod(n_steps, n_parts)
    out = []
    start = 0
    for p in range(n_parts):
        stop = start + base + (1 if p < extra else 0)
        out.append((start, stop))
        start = stop
    return out


def degree_of_parallelism(m: int, n: int, tile: int, k: int = None,
                          work_centric: bool = False,
                          capacity: int = 8) -> int:
    """Paper Eq. 2: ceil(M/T) * ceil(N/T) independent output tiles.

    Under the work-centric mode the owner-only count undercounts what
    the scheduler actually sees: every split tile contributes its
    partial-k tasks *plus* the fix-up reduction.  ``k`` (defaults to
    ``m``) sets the k-loop depth and ``capacity`` the device x stream
    budget the split planner fills against (the default matches the
    stock 2-device, 4-stream :class:`~repro.core.runtime.RuntimeConfig`).
    """
    rows = math.ceil(m / tile)
    cols = math.ceil(n / tile)
    owner = rows * cols
    if not work_centric:
        return owner
    kk = m if k is None else k
    n_steps = max(1, math.ceil(kk / tile))
    parts = workcentric_parts(n_steps, owner, capacity, ragged=True)
    if parts == 0:
        return owner
    if owner < capacity:
        split = owner
    else:
        split = owner - (m // tile) * (n // tile)
    return owner + split * parts
