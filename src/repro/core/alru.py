"""ALRU — Approximate Least-Recently-Used tile cache (paper §IV-B, Alg. 2).

One ALRU per device implements that device's L1 tile cache over its
private RAM.  The vanilla LRU cannot be used because kernels are
asynchronous: the least-recent block may still be read by an in-flight
task.  Each block therefore carries a *reader* counter, atomically
incremented when a task acquires the tile and decremented at the next
stream-synchronization point (Alg. 1 line 17 ``ReaderUpdate``).
Eviction scans from the LRU end toward the front and discards the first
block with ``reader == 0`` — the *approximate* LRU victim.

The ALRU stores where the tile lives in the device heap
(``BlasxHeap`` offset = the paper's "GPU address").
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional

from .heap import BlasxHeap
from .tiling import TileKey


@dataclasses.dataclass
class LRUBlock:
    """One cached tile: host address (tile key), device address (heap
    offset), byte size, reader count, intrusive list links."""

    host_addr: TileKey
    gpu_addr: int
    nbytes: int
    reader: int = 0
    prev: Optional["LRUBlock"] = dataclasses.field(default=None, repr=False)
    next: Optional["LRUBlock"] = dataclasses.field(default=None, repr=False)


class Alru:
    def __init__(self, device_id: int, heap: BlasxHeap):
        self.device_id = device_id
        self.heap = heap
        self._map: Dict[TileKey, LRUBlock] = {}
        self._front: Optional[LRUBlock] = None  # most recently used
        self._back: Optional[LRUBlock] = None   # least recently used
        self._lock = threading.RLock()
        # instrumentation — cumulative across every run of a session
        # (a persistent context reuses one ALRU for many calls); the
        # lifetime_* counters survive reset_stats() so cross-call
        # eviction pressure stays observable.
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.lifetime_hits = 0
        self.lifetime_misses = 0
        self.lifetime_evictions = 0

    # ------------------------------------------------------------- queries
    def __contains__(self, key: TileKey) -> bool:
        with self._lock:
            return key in self._map

    def __len__(self) -> int:
        with self._lock:
            return len(self._map)

    def peek(self, key: TileKey) -> Optional[LRUBlock]:
        with self._lock:
            return self._map.get(key)

    def keys(self):
        with self._lock:
            return list(self._map.keys())

    # ----------------------------------------------------------- Alg.2 ops
    def translate(self, key: TileKey, nbytes: int) -> Optional[LRUBlock]:
        """Alg. 2 ``Translate``: host address -> cached block.

        On a hit the block moves to the front (recency) and is returned.
        On a miss a new block is allocated (evicting zero-reader LRU
        blocks as needed) and returned with ``fresh`` semantics: the
        caller must fill it (i.e. perform the H2D/P2P transfer) and the
        block's reader is already incremented for the requesting task.
        Returns None — with *no* blocks evicted — when the cache can
        never make room: every block is pinned by readers, or the
        pinned blocks fragment the heap so badly that no sequence of
        evictions yields ``nbytes`` contiguous.  The caller degrades
        to an uncached read (or synchronizes streams) and retries.
        """
        with self._lock:
            block = self._map.get(key)
            if block is not None:  # cache hit
                self.hits += 1
                self.lifetime_hits += 1
                self._unlink(block)
                self._push_front(block)
                block.reader += 1
                return block
            # miss: allocate, evicting as needed
            self.misses += 1
            self.lifetime_misses += 1
            gpu_addr = self.heap.malloc(nbytes)
            if gpu_addr is None:
                # over-eviction guard: on a fragmented heap with mixed
                # tile sizes, evicting zero-reader blocks one-by-one
                # could wipe the whole cache and *still* fail (pinned
                # blocks fence the free runs).  Prove attainability
                # first; if no amount of eviction can make room, fail
                # without touching a single resident block.
                evictable = {b.gpu_addr for b in self._map.values()
                             if b.reader == 0}
                if self.heap.largest_attainable_run(evictable) < nbytes:
                    return None  # caller degrades to an uncached read
            while gpu_addr is None:
                victim = self._dequeue()
                if victim is None:  # pragma: no cover - guarded above
                    return None  # everything pinned; caller must sync
                gpu_addr = self.heap.malloc(nbytes)
            block = self._enqueue(key, gpu_addr, nbytes)
            block.reader = 1
            block.fresh = True  # type: ignore[attr-defined]
            return block

    def release(self, key: TileKey) -> None:
        """Reader decrement at a synchronization point (Alg. 1 line 17)."""
        with self._lock:
            block = self._map.get(key)
            if block is None:
                return  # already evicted after its readers hit zero
            if block.reader <= 0:
                raise RuntimeError(f"release underflow on {key}")
            block.reader -= 1

    def invalidate(self, key: TileKey) -> bool:
        """MESI-X I transition: drop the tile if present (regardless of
        recency).  Refuses while readers are active."""
        with self._lock:
            block = self._map.get(key)
            if block is None:
                return False
            if block.reader > 0:
                raise RuntimeError(f"invalidate of in-use tile {key}")
            self._unlink(block)
            del self._map[key]
            self.heap.free(block.gpu_addr)
            return True

    def reset_stats(self) -> None:
        """Zero the per-session counters at a call/session boundary
        without touching resident blocks; lifetime_* keep counting."""
        with self._lock:
            self.hits = 0
            self.misses = 0
            self.evictions = 0

    # ---------------------------------------------------------- internals
    def _dequeue(self) -> Optional[LRUBlock]:
        """Alg. 2 ``Dequeue``: walk from the LRU end toward the front,
        evict the first block with zero readers and release its heap
        bytes.  ``on_evict`` fires only *after* ``heap.free`` so the
        MESI-X directory (and any other observer) never sees an
        evicted tile whose device bytes are still allocated."""
        block = self._back
        while block is not None:
            if block.reader == 0:
                self._unlink(block)
                del self._map[block.host_addr]
                self.heap.free(block.gpu_addr)
                self.evictions += 1
                self.lifetime_evictions += 1
                if self.on_evict is not None:
                    self.on_evict(self.device_id, block.host_addr)
                return block
            block = block.prev
        return None

    def _enqueue(self, key: TileKey, gpu_addr: int, nbytes: int) -> LRUBlock:
        """Alg. 2 ``Enqueue``: new block at the front."""
        block = LRUBlock(host_addr=key, gpu_addr=gpu_addr, nbytes=nbytes)
        self._map[key] = block
        self._push_front(block)
        return block

    def _push_front(self, block: LRUBlock) -> None:
        block.prev = None
        block.next = self._front
        if self._front is not None:
            self._front.prev = block
        self._front = block
        if self._back is None:
            self._back = block

    def _unlink(self, block: LRUBlock) -> None:
        if block.prev is not None:
            block.prev.next = block.next
        else:
            self._front = block.next
        if block.next is not None:
            block.next.prev = block.prev
        else:
            self._back = block.prev
        block.prev = block.next = None

    # eviction callback (set by the runtime to keep the MESI-X directory
    # and the device tile store in sync)
    on_evict = None

    # ------------------------------------------------------------ checking
    def check_invariants(self) -> None:
        with self._lock:
            seen = set()
            block = self._front
            prev = None
            while block is not None:
                if block.host_addr in seen:
                    raise RuntimeError("cycle / duplicate in ALRU list")
                seen.add(block.host_addr)
                if block.prev is not prev:
                    raise RuntimeError("broken prev link")
                if self._map.get(block.host_addr) is not block:
                    raise RuntimeError("map out of sync with list")
                prev = block
                block = block.next
            if self._back is not prev:
                raise RuntimeError("broken back pointer")
            if len(seen) != len(self._map):
                raise RuntimeError("list/map size mismatch")
