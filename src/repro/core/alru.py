"""ALRU — Approximate Least-Recently-Used tile cache (paper §IV-B, Alg. 2).

One ALRU per device implements that device's L1 tile cache over its
private RAM.  The vanilla LRU cannot be used because kernels are
asynchronous: the least-recent block may still be read by an in-flight
task.  Each block therefore carries a *reader* counter, atomically
incremented when a task acquires the tile and decremented at the next
stream-synchronization point (Alg. 1 line 17 ``ReaderUpdate``).
Eviction scans from the LRU end toward the front and discards the first
block with ``reader == 0`` — the *approximate* LRU victim.

The ALRU stores where the tile lives in the device heap
(``BlasxHeap`` offset = the paper's "GPU address").

Multi-tenant quotas (serving front end, ``repro.serve``)
--------------------------------------------------------
Each block optionally carries an *owner* tag — the tenant whose
request pulled the tile in.  With per-owner byte quotas configured
(:meth:`Alru.set_quota`) the cache becomes partitioned under
pressure:

* an owner at its quota evicts from its **own** LRU blocks first
  (never inflating its footprint past the quota);
* while any quota is configured, cross-owner eviction is forbidden —
  a flooding tenant can only reclaim its own blocks and untagged
  (``owner=None``) ones, so another tenant's warm working set
  survives the flood (the serving isolation invariant);
* when neither self-eviction nor untagged eviction can make room,
  :meth:`translate` returns ``None`` and the caller degrades to an
  uncached read, exactly like the all-pinned case.

With no quotas configured behaviour is byte-for-byte the legacy ALRU.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional

from .heap import BlasxHeap
from .tiling import TileKey


@dataclasses.dataclass
class LRUBlock:
    """One cached tile: host address (tile key), device address (heap
    offset), byte size, reader count, owner tenant (None = untagged),
    intrusive list links."""

    host_addr: TileKey
    gpu_addr: int
    nbytes: int
    reader: int = 0
    owner: Optional[str] = None
    prev: Optional["LRUBlock"] = dataclasses.field(default=None, repr=False)
    next: Optional["LRUBlock"] = dataclasses.field(default=None, repr=False)


class Alru:
    # lock-discipline declarations (repro.analysis, docs/ANALYSIS.md):
    # every field below may only be touched under _lock; the listed
    # helpers are only ever called with _lock already held; on_evict
    # is a user callback (never to be invoked under the lock without a
    # baseline justification).
    _GUARDED_BY = {"_lock": (
        "_map", "_front", "_back", "hits", "misses", "evictions",
        "lifetime_hits", "lifetime_misses", "lifetime_evictions",
        "_quota", "_owner_bytes", "quota_evictions",
        "quota_evictions_by_owner")}
    _LOCK_HELD = ("_dequeue", "_enqueue", "_push_front", "_unlink",
                  "_may_evict", "_drop_owner_bytes")
    _CALLBACKS = ("on_evict",)

    def __init__(self, device_id: int, heap: BlasxHeap):
        self.device_id = device_id
        self.heap = heap
        self._map: Dict[TileKey, LRUBlock] = {}
        self._front: Optional[LRUBlock] = None  # most recently used
        self._back: Optional[LRUBlock] = None   # least recently used
        self._lock = threading.RLock()
        # instrumentation — cumulative across every run of a session
        # (a persistent context reuses one ALRU for many calls); the
        # lifetime_* counters survive reset_stats() so cross-call
        # eviction pressure stays observable.
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.lifetime_hits = 0
        self.lifetime_misses = 0
        self.lifetime_evictions = 0
        # multi-tenant quota state: per-owner byte quotas, resident
        # bytes per owner, and evictions performed to keep an owner
        # under its own quota (the serving layer's "cache-quota
        # evictions" stat; cumulative, reset_stats leaves it alone
        # like the lifetime counters)
        self._quota: Dict[str, int] = {}
        self._owner_bytes: Dict[str, int] = {}
        self.quota_evictions = 0
        self.quota_evictions_by_owner: Dict[str, int] = {}

    # ------------------------------------------------------------- queries
    def __contains__(self, key: TileKey) -> bool:
        with self._lock:
            return key in self._map

    def __len__(self) -> int:
        with self._lock:
            return len(self._map)

    def peek(self, key: TileKey) -> Optional[LRUBlock]:
        with self._lock:
            return self._map.get(key)

    def keys(self):
        with self._lock:
            return list(self._map.keys())

    # ------------------------------------------------------ tenant quotas
    def set_quota(self, owner: str, nbytes: Optional[int]) -> None:
        """Cap ``owner``'s resident bytes at ``nbytes`` (None removes
        the cap).  The moment any quota exists, cross-owner eviction is
        disabled on this cache (see module docstring)."""
        with self._lock:
            if nbytes is None:
                self._quota.pop(owner, None)
                return
            self._quota[owner] = int(nbytes)
            # a cap below current residency applies now: trim the
            # owner's zero-reader LRU blocks down to it (pinned blocks
            # ride out their readers and are reclaimed by the next
            # over-quota miss)
            while self._owner_bytes.get(owner, 0) > int(nbytes):
                if self._dequeue(owner=owner, restrict=owner,
                                 quota_evict=True) is None:
                    break

    def quota_of(self, owner: Optional[str]) -> Optional[int]:
        with self._lock:
            return self._quota.get(owner) if owner is not None else None

    @property
    def quotas_enabled(self) -> bool:
        with self._lock:
            return bool(self._quota)

    def owner_bytes(self, owner: Optional[str]) -> int:
        """Resident cached bytes currently tagged with ``owner``."""
        with self._lock:
            return self._owner_bytes.get(owner, 0)

    def _may_evict(self, block: LRUBlock, owner: Optional[str]) -> bool:
        """Eviction permission under quotas: with any quota configured
        a requester may only reclaim its own blocks or untagged ones;
        without quotas (legacy) everything zero-reader is fair game."""
        if not self._quota:
            return True
        return block.owner is None or block.owner == owner

    # ----------------------------------------------------------- Alg.2 ops
    def translate(self, key: TileKey, nbytes: int,
                  owner: Optional[str] = None) -> Optional[LRUBlock]:
        """Alg. 2 ``Translate``: host address -> cached block.

        On a hit the block moves to the front (recency) and is returned.
        On a miss a new block is allocated (evicting zero-reader LRU
        blocks as needed) and returned with ``fresh`` semantics: the
        caller must fill it (i.e. perform the H2D/P2P transfer) and the
        block's reader is already incremented for the requesting task.
        Returns None — with *no* blocks evicted — when the cache can
        never make room: every block is pinned by readers, the pinned
        blocks fragment the heap so badly that no sequence of
        evictions yields ``nbytes`` contiguous, or (quota mode) the
        requesting ``owner`` is at its byte quota with nothing of its
        own evictable.  The caller degrades to an uncached read (or
        synchronizes streams) and retries.

        ``owner`` tags the block with the tenant whose request pulled
        it in; eviction permissions under quotas key off it (see
        module docstring).  A cache hit never re-tags: the first
        owner keeps the block (shared tiles stay attributed to whoever
        paid the transfer).
        """
        with self._lock:
            block = self._map.get(key)
            if block is not None:  # cache hit
                self.hits += 1
                self.lifetime_hits += 1
                self._unlink(block)
                self._push_front(block)
                block.reader += 1
                return block
            # miss: allocate, evicting as needed
            self.misses += 1
            self.lifetime_misses += 1
            quota = self._quota.get(owner) if owner is not None else None
            if quota is not None:
                if nbytes > quota:
                    return None  # can never fit under the cap
                # stay under the cap by reclaiming the owner's own LRU
                # blocks; other tenants' blocks are never touched here
                while self._owner_bytes.get(owner, 0) + nbytes > quota:
                    victim = self._dequeue(owner=owner, restrict=owner,
                                           quota_evict=True)
                    if victim is None:
                        return None  # own blocks all pinned: degrade
            gpu_addr = self.heap.malloc(nbytes)
            if gpu_addr is None:
                # over-eviction guard: on a fragmented heap with mixed
                # tile sizes, evicting zero-reader blocks one-by-one
                # could wipe the whole cache and *still* fail (pinned
                # blocks fence the free runs).  Prove attainability
                # first — counting only blocks this owner is *allowed*
                # to evict — and if no amount of permitted eviction can
                # make room, fail without touching a single resident
                # block.
                evictable = {b.gpu_addr for b in self._map.values()
                             if b.reader == 0 and self._may_evict(b, owner)}
                if self.heap.largest_attainable_run(evictable) < nbytes:
                    return None  # caller degrades to an uncached read
            while gpu_addr is None:
                victim = self._dequeue(owner=owner)
                if victim is None:  # pragma: no cover - guarded above
                    return None  # everything pinned; caller must sync
                gpu_addr = self.heap.malloc(nbytes)
            block = self._enqueue(key, gpu_addr, nbytes, owner)
            block.reader = 1
            block.fresh = True  # type: ignore[attr-defined]
            return block

    def release(self, key: TileKey) -> None:
        """Reader decrement at a synchronization point (Alg. 1 line 17)."""
        with self._lock:
            block = self._map.get(key)
            if block is None:
                return  # already evicted after its readers hit zero
            if block.reader <= 0:
                raise RuntimeError(f"release underflow on {key}")
            block.reader -= 1

    def invalidate(self, key: TileKey) -> bool:
        """MESI-X I transition: drop the tile if present (regardless of
        recency).  Refuses while readers are active."""
        with self._lock:
            block = self._map.get(key)
            if block is None:
                return False
            if block.reader > 0:
                raise RuntimeError(f"invalidate of in-use tile {key}")
            self._unlink(block)
            del self._map[key]
            self._drop_owner_bytes(block)
            self.heap.free(block.gpu_addr)
            return True

    def reset_stats(self) -> None:
        """Zero the per-session counters at a call/session boundary
        without touching resident blocks; lifetime_* keep counting."""
        with self._lock:
            self.hits = 0
            self.misses = 0
            self.evictions = 0

    # ---------------------------------------------------------- internals
    def _drop_owner_bytes(self, block: LRUBlock) -> None:
        """Deduct a departing block from its owner's residency count."""
        if block.owner is None:
            return
        left = self._owner_bytes.get(block.owner, 0) - block.nbytes
        if left > 0:
            self._owner_bytes[block.owner] = left
        else:
            self._owner_bytes.pop(block.owner, None)

    def _dequeue(self, owner: Optional[str] = None,
                 restrict: Optional[str] = None,
                 quota_evict: bool = False) -> Optional[LRUBlock]:
        """Alg. 2 ``Dequeue``: walk from the LRU end toward the front,
        evict the first block with zero readers and release its heap
        bytes.  ``on_evict`` fires only *after* ``heap.free`` so the
        MESI-X directory (and any other observer) never sees an
        evicted tile whose device bytes are still allocated.

        ``owner`` applies the quota-mode eviction permission filter
        (:meth:`_may_evict`); ``restrict`` narrows further to blocks
        of exactly that owner (quota self-eviction).  ``quota_evict``
        charges the eviction to the quota counters instead of the
        capacity ones — the serving stats distinguish "evicted to make
        room" from "evicted to stay under the tenant cap"."""
        block = self._back
        while block is not None:
            if block.reader == 0 and self._may_evict(block, owner) and \
                    (restrict is None or block.owner == restrict):
                self._unlink(block)
                del self._map[block.host_addr]
                self._drop_owner_bytes(block)
                self.heap.free(block.gpu_addr)
                self.evictions += 1
                self.lifetime_evictions += 1
                if quota_evict:
                    self.quota_evictions += 1
                    if block.owner is not None:
                        self.quota_evictions_by_owner[block.owner] = \
                            self.quota_evictions_by_owner.get(
                                block.owner, 0) + 1
                if self.on_evict is not None:
                    self.on_evict(self.device_id, block.host_addr)
                return block
            block = block.prev
        return None

    def _enqueue(self, key: TileKey, gpu_addr: int, nbytes: int,
                 owner: Optional[str] = None) -> LRUBlock:
        """Alg. 2 ``Enqueue``: new block at the front."""
        block = LRUBlock(host_addr=key, gpu_addr=gpu_addr, nbytes=nbytes,
                         owner=owner)
        self._map[key] = block
        if owner is not None:
            self._owner_bytes[owner] = \
                self._owner_bytes.get(owner, 0) + nbytes
        self._push_front(block)
        return block

    def _push_front(self, block: LRUBlock) -> None:
        block.prev = None
        block.next = self._front
        if self._front is not None:
            self._front.prev = block
        self._front = block
        if self._back is None:
            self._back = block

    def _unlink(self, block: LRUBlock) -> None:
        if block.prev is not None:
            block.prev.next = block.next
        else:
            self._front = block.next
        if block.next is not None:
            block.next.prev = block.prev
        else:
            self._back = block.prev
        block.prev = block.next = None

    # eviction callback (set by the runtime to keep the MESI-X directory
    # and the device tile store in sync)
    on_evict = None

    # ------------------------------------------------------------ checking
    def check_invariants(self) -> None:
        with self._lock:
            seen = set()
            block = self._front
            prev = None
            while block is not None:
                if block.host_addr in seen:
                    raise RuntimeError("cycle / duplicate in ALRU list")
                seen.add(block.host_addr)
                if block.prev is not prev:
                    raise RuntimeError("broken prev link")
                if self._map.get(block.host_addr) is not block:
                    raise RuntimeError("map out of sync with list")
                prev = block
                block = block.next
            if self._back is not prev:
                raise RuntimeError("broken back pointer")
            if len(seen) != len(self._map):
                raise RuntimeError("list/map size mismatch")
            # quota bookkeeping: _owner_bytes must equal the per-owner
            # sums over resident blocks (both ways: no stale owners),
            # and no quota'd owner may sit above its cap
            by_owner: Dict[str, int] = {}
            for b in self._map.values():
                if b.owner is not None:
                    by_owner[b.owner] = by_owner.get(b.owner, 0) + b.nbytes
            if by_owner != self._owner_bytes:
                raise RuntimeError(
                    f"owner byte ledger out of sync: walked {by_owner} "
                    f"!= tracked {self._owner_bytes}")
            for owner, cap in self._quota.items():
                resident = by_owner.get(owner, 0)
                if resident > cap:
                    # enforcement can only reclaim zero-reader blocks,
                    # so residency above a (freshly lowered) cap is
                    # legal exactly while every one of the owner's
                    # blocks is pinned by in-flight readers
                    pinned = sum(b.nbytes for b in self._map.values()
                                 if b.owner == owner and b.reader > 0)
                    if pinned < resident:
                        raise RuntimeError(
                            f"owner {owner!r} resident {resident} bytes "
                            f"exceeds quota {cap} with evictable blocks")
