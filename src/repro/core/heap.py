"""BLASX_Malloc: fast heap to amortize device alloc/dealloc (paper §IV-E, Fig. 6).

The paper pre-allocates one big chunk of GPU memory and manages it with
three structures: a meta-data list (segment length + occupancy), an
occupied list (hashtable address -> node for O(1) free) and an empty
list (free segments, first-fit).  Freeing coalesces with contiguous
neighbors.  We reproduce exactly that: a first-fit free-list allocator
with neighbor coalescing over a byte arena, plus counters so benchmarks
can contrast it against a "cudaMalloc"-style slow path (Fig. 5).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional


@dataclasses.dataclass
class _Segment:
    """Node of the meta-data list (Fig. 6): one contiguous byte range."""

    offset: int
    length: int
    occupied: bool
    prev: Optional["_Segment"] = dataclasses.field(default=None, repr=False)
    next: Optional["_Segment"] = dataclasses.field(default=None, repr=False)


class HeapError(Exception):
    pass


class BlasxHeap:
    """First-fit arena allocator with coalescing (BLASX_Malloc)."""

    def __init__(self, capacity: int):
        if capacity <= 0:
            raise ValueError("heap capacity must be positive")
        self.capacity = capacity
        head = _Segment(offset=0, length=capacity, occupied=False)
        self._head = head
        # occupied list: offset -> segment, the paper's hashtable for O(1) free
        self._occupied: Dict[int, _Segment] = {}
        # instrumentation
        self.n_alloc = 0
        self.n_free = 0
        self.n_split = 0
        self.n_coalesce = 0
        self.peak_used = 0
        self._used = 0

    # ------------------------------------------------------------------ api
    @property
    def used(self) -> int:
        return self._used

    @property
    def free_bytes(self) -> int:
        return self.capacity - self._used

    def malloc(self, size: int) -> Optional[int]:
        """First-fit allocation.  Returns byte offset or None when no
        segment is large enough (caller evicts via the ALRU and retries)."""
        if size <= 0:
            raise ValueError("malloc size must be positive")
        seg = self._head
        while seg is not None:
            if not seg.occupied and seg.length >= size:
                if seg.length > size:  # split: occupied node + residual free node
                    rest = _Segment(
                        offset=seg.offset + size,
                        length=seg.length - size,
                        occupied=False,
                        prev=seg,
                        next=seg.next,
                    )
                    if seg.next is not None:
                        seg.next.prev = rest
                    seg.next = rest
                    seg.length = size
                    self.n_split += 1
                seg.occupied = True
                self._occupied[seg.offset] = seg
                self.n_alloc += 1
                self._used += size
                self.peak_used = max(self.peak_used, self._used)
                return seg.offset
            seg = seg.next
        return None

    def free(self, offset: int) -> None:
        """O(1) lookup via the occupied hashtable, then coalesce with
        contiguous free neighbors (paper Fig. 6)."""
        seg = self._occupied.pop(offset, None)
        if seg is None:
            raise HeapError(f"free of unallocated offset {offset}")
        seg.occupied = False
        self.n_free += 1
        self._used -= seg.length
        # merge with next
        nxt = seg.next
        if nxt is not None and not nxt.occupied:
            seg.length += nxt.length
            seg.next = nxt.next
            if nxt.next is not None:
                nxt.next.prev = seg
            self.n_coalesce += 1
        # merge with prev
        prv = seg.prev
        if prv is not None and not prv.occupied:
            prv.length += seg.length
            prv.next = seg.next
            if seg.next is not None:
                seg.next.prev = prv
            self.n_coalesce += 1

    def largest_free_run(self) -> int:
        """Length of the largest currently-free contiguous segment."""
        return self.largest_attainable_run(())

    def largest_attainable_run(self, freeable_offsets) -> int:
        """Largest contiguous run reachable by freeing (any subset of)
        the occupied segments at ``freeable_offsets``.  Occupied
        segments *not* in the set are barriers (e.g. cache blocks
        pinned by in-flight readers).  Lets the ALRU prove that no
        amount of eviction can satisfy an allocation before it starts
        evicting (over-eviction guard)."""
        freeable = set(freeable_offsets)
        best = run = 0
        seg = self._head
        while seg is not None:
            if not seg.occupied or seg.offset in freeable:
                run += seg.length
                best = max(best, run)
            else:
                run = 0
            seg = seg.next
        return best

    # -------------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        """Used by property tests: segments tile the arena exactly, no two
        adjacent free segments, occupied table consistent."""
        seg = self._head
        offset = 0
        used = 0
        prev_free = False
        walked_occ = set()
        while seg is not None:
            if seg.offset != offset:
                raise HeapError(f"segment offset {seg.offset} != expected {offset}")
            if seg.length <= 0:
                raise HeapError("non-positive segment length")
            if seg.occupied:
                if self._occupied.get(seg.offset) is not seg:
                    raise HeapError("occupied table out of sync")
                walked_occ.add(seg.offset)
                used += seg.length
                prev_free = False
            else:
                if prev_free:
                    raise HeapError("two adjacent free segments (missed coalesce)")
                prev_free = True
            offset += seg.length
            seg = seg.next
        if offset != self.capacity:
            raise HeapError(f"segments cover {offset} != capacity {self.capacity}")
        if used != self._used:
            raise HeapError(f"used accounting {self._used} != actual {used}")
        # the table must hold exactly the occupied segments the walk saw:
        # the per-segment identity check above catches missing/aliased
        # entries, but only a cross-check against the walked set catches
        # stale entries for segments no longer (or never) in the list
        stale = set(self._occupied) - walked_occ
        if stale:
            raise HeapError(
                f"occupied table has {len(stale)} stale entr"
                f"{'y' if len(stale) == 1 else 'ies'} not backed by any "
                f"occupied segment: offsets {sorted(stale)[:8]}")
