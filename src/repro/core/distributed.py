"""Distributed tiled GEMM on the TPU mesh — BLASX's insights, SPMD-native.

The paper's two key communication ideas map onto the ICI ring:

* **L2 tile cache / P2P**: in the ring schedules below, after the first
  step every operand panel a device consumes arrives from its ICI
  *neighbor* (collective_permute), never from a distant shard or the
  host — the paper's "reduce CPU-GPU communication to GPU-GPU
  communication", taken to its limit (0 host traffic in steady state).

* **4-stream overlap**: each ring step's ``ppermute`` of the *next*
  panel is data-independent of the current panel's matmul, so XLA's
  async collectives run the ICI transfer under the MXU compute —
  double-buffered communication/computation overlap by construction.

* **Locality-first scheduling (Eq. 3)**: every device starts with the
  panel it already holds (its "L1-resident" tile) before touching
  remote panels — the +2-for-L1-hit priority, statically scheduled.

Provided collective matmuls (all shard_map kernels):

  ``ring_allgather_matmul``     Y[m, n/d]   = allgather_m(X[m/d, k]) @ W[k, n/d]
  ``ring_reduce_scatter_matmul``Y[m/d, n]   = reduce_m(X[m/d... k/d] @ W[k/d, n])
  ``distributed_gemm``          the out-of-core pod GEMM used by the
                                BLAS-at-pod-scale benchmarks/dry-run.

Each has a ``*_gspmd`` reference twin (plain einsum + jax collectives)
used as oracle and as the paper-faithful "unoptimized" baseline.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from ..kernels.pallas_compat import shard_map


def _acc_type(a_dtype, b_dtype):
    """MXU accumulation dtype: at least f32 (the bf16/f32 paths keep
    their historical f32 accumulate bit-for-bit), widened to f64 when
    either operand is 64-bit (jax_enable_x64 serving)."""
    return jnp.promote_types(jnp.float32,
                             jnp.promote_types(a_dtype, b_dtype))


# --------------------------------------------------------------------------
# shard_map bodies (take axis_name; composable inside larger programs)
# --------------------------------------------------------------------------
def ring_allgather_matmul(x_local: jax.Array, w_local: jax.Array,
                          axis_name: str) -> jax.Array:
    """Y_local[m, n/d] = (all-gather of X over ``axis_name``) @ W_local.

    X arrives sequence/row-sharded (m/d rows per device); W is
    column-sharded.  Instead of a monolithic all-gather (cuBLAS-XT's
    "move everything on demand"), panels circulate the ring and each
    device matmuls the panel it currently holds — panel k+1 is in
    flight (ppermute) while panel k multiplies.
    """
    # psum of a literal folds to a static int on every jax version;
    # lax.axis_size only exists on newer releases
    d = lax.psum(1, axis_name)
    idx = lax.axis_index(axis_name)
    m_local, _ = x_local.shape
    n_local = w_local.shape[1]
    perm = [(i, (i + 1) % d) for i in range(d)]

    y = jnp.zeros((d * m_local, n_local),
                  dtype=jnp.promote_types(x_local.dtype, w_local.dtype))
    chunk = x_local
    for s in range(d):
        nxt = lax.ppermute(chunk, axis_name, perm) if s < d - 1 else None
        # the panel now in hand originated at device (idx - s) mod d
        slot = (idx - s) % d
        part = jnp.dot(chunk, w_local, preferred_element_type=_acc_type(
            chunk.dtype, w_local.dtype)).astype(y.dtype)
        # both indices pinned to one dtype: under jax_enable_x64 a bare
        # 0 would be int64 next to the int32 traced slot index
        start = (slot * m_local).astype(jnp.int32)
        y = lax.dynamic_update_slice(y, part, (start, jnp.int32(0)))
        chunk = nxt
    return y


def ring_reduce_scatter_matmul(x_local: jax.Array, w_local: jax.Array,
                               axis_name: str) -> jax.Array:
    """Y_local[m/d, n] = reduce-scatter_m(X_local[m, k/d] @ W_local[k/d, n]).

    Row-parallel layer: every device holds a K-shard; the (m, n)
    partial products are reduce-scattered over rows by a ring in which
    the accumulator hop (ppermute) overlaps the *next* row-block's
    matmul.  The matmul is deliberately blocked by row so only one
    block is computed per ring step (BLASX's k-step interleaving).

    Ragged row counts (``m % d != 0`` — real serving shapes) are padded
    with zero rows up to the next ring multiple, so the returned shard
    is ``ceil(m/d)`` rows and the global output has ``d*ceil(m/d)``
    rows whose tail is zeros; callers slice (``tp_matmul`` /
    ``distributed_gemm`` do).
    """
    # psum of a literal folds to a static int on every jax version;
    # lax.axis_size only exists on newer releases
    d = lax.psum(1, axis_name)
    idx = lax.axis_index(axis_name)
    m = x_local.shape[0]
    mb = -(-m // d)
    if mb * d != m:  # pad-and-slice ragged shards (zero rows are inert)
        x_local = jnp.pad(x_local, ((0, mb * d - m), (0, 0)))
    perm = [(i, (i + 1) % d) for i in range(d)]

    def block(b):
        xs = lax.dynamic_slice_in_dim(x_local, b * mb, mb, axis=0)
        return jnp.dot(xs, w_local, preferred_element_type=_acc_type(
            xs.dtype, w_local.dtype))

    # start with the block that must travel the full ring (locality-first:
    # it is computed from the panel already resident on this device)
    acc = block((idx - 1) % d)
    for s in range(1, d):
        moved = lax.ppermute(acc, axis_name, perm)
        acc = moved + block((idx - 1 - s) % d)   # overlap: matmul vs hop
    return acc.astype(jnp.promote_types(x_local.dtype, w_local.dtype))


# ------------------------------------------------------- gspmd baselines
def gspmd_allgather_matmul(x_local, w_local, axis_name):
    x_full = lax.all_gather(x_local, axis_name, axis=0, tiled=True)
    return jnp.dot(x_full, w_local, preferred_element_type=_acc_type(
        x_full.dtype, w_local.dtype)
                   ).astype(jnp.promote_types(x_local.dtype, w_local.dtype))


def gspmd_reduce_scatter_matmul(x_local, w_local, axis_name):
    d = lax.psum(1, axis_name)
    m = x_local.shape[0]
    mb = -(-m // d)
    if mb * d != m:  # same pad-and-slice contract as the ring twin
        x_local = jnp.pad(x_local, ((0, mb * d - m), (0, 0)))
    part = jnp.dot(x_local, w_local, preferred_element_type=_acc_type(
        x_local.dtype, w_local.dtype))
    out = lax.psum_scatter(part, axis_name, scatter_dimension=0, tiled=True)
    return out.astype(jnp.promote_types(x_local.dtype, w_local.dtype))


MODES = {
    "ring": (ring_allgather_matmul, ring_reduce_scatter_matmul),
    "gspmd": (gspmd_allgather_matmul, gspmd_reduce_scatter_matmul),
}


# --------------------------------------------------------------------------
# High-level: out-of-core pod GEMM (the BLAS library at pod scale)
# --------------------------------------------------------------------------
def distributed_gemm(A: jax.Array, B: jax.Array, mesh: Mesh, *,
                     row_axis: str = "data", col_axis: str = "model",
                     mode: str = "ring") -> jax.Array:
    """C = A @ B on a 2-D device mesh.

    Layout (the tile-algebra layout of §III at shard granularity):
      A : P(row_axis, col_axis)   — both dims sharded (out-of-core tiles)
      B : P(col_axis, None)       — K-sharded
      C : P(row_axis, None)       — row-sharded result

    Every (row_axis) group runs an independent K-reduction over
    col_axis; with ``mode='ring'`` that reduction is the overlap-
    friendly ring reduce-scatter GEMM above, re-gathered to keep C's
    K-replicated layout.

    Ragged shapes (M not divisible by the row axis, K not divisible by
    the column axis, or a row-shard not divisible by the ring size) are
    padded with zeros internally and the result sliced back to
    ``(M, N)`` — the zero padding lives in the tail shard, so the slice
    is exact.
    """
    if mode not in MODES:
        raise ValueError(f"mode must be one of {sorted(MODES)}")
    dr = mesh.shape[row_axis]
    dc = mesh.shape[col_axis]
    m, k = A.shape
    m_pad = -(-m // dr) * dr
    k_pad = -(-k // dc) * dc
    if m_pad != m or k_pad != k:
        A = jnp.pad(A, ((0, m_pad - m), (0, k_pad - k)))
    if k_pad != k:
        B = jnp.pad(B, ((0, k_pad - k), (0, 0)))

    def body(a_blk, b_blk):
        # a_blk: (m/dr, k/dc); b_blk: (k/dc, n)
        if mode == "ring":
            y = ring_reduce_scatter_matmul(a_blk, b_blk, col_axis)
            y = lax.all_gather(y, col_axis, axis=0, tiled=True)
            # the ring kernel pads ragged row-shards up to the next
            # ring multiple; drop those rows so out_specs stay exact
            y = y[:a_blk.shape[0]]
        else:
            part = jnp.dot(a_blk, b_blk, preferred_element_type=_acc_type(
                a_blk.dtype, b_blk.dtype))
            y = lax.psum(part, col_axis).astype(
                jnp.promote_types(a_blk.dtype, b_blk.dtype))
        return y

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(row_axis, col_axis), P(col_axis, None)),
        out_specs=P(row_axis, None),
        check_rep=False,
    )
    C = fn(A, B)
    return C[:m] if m_pad != m else C


def tp_matmul(x: jax.Array, w: jax.Array, mesh: Mesh, *, axis: str = "model",
              kind: str = "column", mode: str = "ring",
              batch_axis: Optional[str] = "data") -> jax.Array:
    """Tensor-parallel projection for the model zoo.

    kind='column': x is sequence-sharded on ``axis``; W col-sharded;
                   returns activations col-sharded (full sequence).
    kind='row'   : x is feature-sharded on ``axis``; W row-sharded;
                   returns activations sequence-sharded on ``axis``.

    A sequence length not divisible by the ``axis`` ring is padded with
    zeros up to the next multiple and the result sliced back — ragged
    serving shapes work for both kinds and both modes.
    """
    ag, rs = MODES[mode]
    bspec = batch_axis if batch_axis else None
    d = mesh.shape[axis]
    s = x.shape[1]
    s_pad = -(-s // d) * d
    if s_pad != s:
        x = jnp.pad(x, ((0, 0), (0, s_pad - s), (0, 0)))

    if kind == "column":
        def body(xl, wl):
            x2 = xl.reshape(-1, xl.shape[-1])
            y = ag(x2, wl, axis)
            return y.reshape(xl.shape[0], -1, wl.shape[1])
        fn = shard_map(body, mesh=mesh,
                       in_specs=(P(bspec, axis, None), P(None, axis)),
                       out_specs=P(bspec, None, axis), check_rep=False)
        y = fn(x, w)
        return y[:, :s] if s_pad != s else y
    elif kind == "row":
        def body(xl, wl):
            x2 = xl.reshape(-1, xl.shape[-1])
            y = rs(x2, wl, axis)
            return y.reshape(xl.shape[0], -1, wl.shape[1])
        fn = shard_map(body, mesh=mesh,
                       in_specs=(P(bspec, None, axis), P(axis, None)),
                       out_specs=P(bspec, axis, None), check_rep=False)
        y = fn(x, w)
        return y[:, :s] if s_pad != s else y
    raise ValueError(f"kind must be column|row, got {kind}")
