"""Taskizing L3 BLAS (paper §IV-A, Eq. 1a-1f).

A *task* fully solves one output tile ``C_ij``.  It is represented as a
sequence of k-*steps* — each step multiplies two input tile references
and accumulates — plus an optional finalize op (TRSM's triangular
solve).  Tile references carry the transpose flag (the paper's §III-C
trick: never transpose the matrix, transpose the tile inside the
kernel) and a *fill* modifier for triangular/symmetric storage.

Task properties (paper §IV-A):
  * reading inputs is data-dependency free (except TRSM's intra-column
    chain, which we expose as explicit ``deps`` edges);
  * concurrent writes are race free — each task owns its C_ij;
  * workload varies per task (len(steps) depends on i/j/routine).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from .tiling import (TileGrid, TileKey, panel_parts, split_ranges,
                     workcentric_parts)


@dataclasses.dataclass
class Ledger:
    """Per-device communication/compute accounting (Tables IV/V, Fig. 8).

    Lives beside the task model (not the runtime) because both the
    scheduler (``core.runtime``) and the discrete-event timing engine
    (``core.events``) charge it — time flows from scheduled *tasks*.
    """

    h2d_bytes: int = 0
    d2h_bytes: int = 0
    d2d_bytes: int = 0
    # pod tier (device_class="mesh_shard"): bytes moved over the ICI
    # fabric — ring hops scattering freshly-filled host panels across
    # the shard ring plus neighbor-tier reads (capacity misses served
    # by a peer's HBM instead of host DRAM).  Together with h2d/d2h/d2d
    # this decomposes the comm volume exactly.
    ici_bytes: int = 0
    tasks: int = 0
    steals: int = 0
    flops: int = 0
    compute_time: float = 0.0     # modeled seconds
    comm_time: float = 0.0        # modeled seconds (total, incl. overlapped)
    unoverlapped_comm: float = 0.0  # Fig. 8 "COMM"
    busy_time: float = 0.0        # modeled wall contribution
    # sim-mode seconds the device spent with no batch in flight:
    # dependency waits (a batch delayed past the device clock) and
    # scheduler stall nudges both land here, so per-device
    # busy_time + idle_time always sums to the device clock
    idle_time: float = 0.0
    # per-link busy seconds this device put on the transfer lanes
    # (event engine only; the lump model has no per-link timelines)
    h2d_busy_s: float = 0.0
    d2d_busy_s: float = 0.0
    d2h_busy_s: float = 0.0
    # every ICI transfer charges exactly nbytes/ici_bw seconds, so in
    # the event engine ici_busy_s == ici_bytes/ici_bw by construction
    # (the pod bench lane gates that equality)
    ici_busy_s: float = 0.0
    # P2P seconds this device spent *serving* peers' L2 hits from its
    # own store (the egress side of d2d traffic; charged in both time
    # models).  A skew here means one holder is being drained while
    # its peers idle — the pathology the LRU peer rotation fixes.
    d2d_served_s: float = 0.0
    # batched-dispatch accounting (execute=True runs only): how many
    # k-steps went through the backend, how many grouped dispatches
    # they collapsed into, and what each engine actually executed —
    # ``batched_steps - kernel_launches`` is the "launches saved" that
    # the bench lane tracks across PRs.
    batched_steps: int = 0
    batched_groups: int = 0
    kernel_launches: int = 0
    engine_flops: Dict[str, int] = dataclasses.field(default_factory=dict)
    # work-centric (Stream-K) attribution: how much of this device's
    # scheduled work was partial-k tasks vs. fix-up reductions.  Owner
    # tasks are ``tasks - partial_tasks - fixup_tasks``; partial flops
    # are the k-range MAC shares, fixup flops the join + epilogue cost.
    partial_tasks: int = 0
    fixup_tasks: int = 0
    partial_flops: int = 0
    fixup_flops: int = 0

    @property
    def overlap_efficiency(self) -> float:
        """Fraction of modeled communication hidden under compute
        (1.0 when there was nothing to hide)."""
        if self.comm_time <= 0.0:
            return 1.0
        return max(0.0, 1.0 - self.unoverlapped_comm / self.comm_time)

# fill modifiers applied to the *stored* tile before the optional transpose
FILL_FULL = "full"
FILL_SYM_U = "sym_u"   # symmetrize from upper storage
FILL_SYM_L = "sym_l"
FILL_TRI_U = "tri_u"   # keep upper triangle (non-unit diag)
FILL_TRI_L = "tri_l"
FILL_TRI_UU = "tri_uu"  # upper, unit diagonal
FILL_TRI_LU = "tri_lu"


@dataclasses.dataclass(frozen=True)
class TileRef:
    key: TileKey
    trans: bool = False
    fill: str = FILL_FULL


@dataclasses.dataclass(frozen=True)
class Step:
    """One k-step: ``acc += op(a) @ op(b)``."""

    a: TileRef
    b: TileRef


@dataclasses.dataclass(frozen=True)
class Finalize:
    """TRSM finalize: ``C_ij = solve(tri(A_ii), alpha * B_ij - acc)``."""

    kind: str            # 'trsm'
    diag_ref: TileRef    # A_ii with triangular fill
    rhs_ref: TileRef     # B_ij
    lower: bool
    unit_diag: bool


# work-centric (Stream-K) task kinds — see ``plan_work_centric``
KIND_OWNER = "owner"      # Eq. 2 tile-owner task: full k-loop + epilogue
KIND_PARTIAL = "partial"  # one k-range of a split tile: gather + modeled
                          # compute only, never writes C_ij
KIND_FIXUP = "fixup"      # deterministic join: re-dispatches the whole
                          # k-loop (owner-identical numerics) and does
                          # the only write of C_ij


@dataclasses.dataclass
class Task:
    task_id: int
    routine: str
    out: TileKey                       # C_ij being solved
    i: int
    j: int
    steps: Tuple[Step, ...]
    alpha: float
    beta: float
    read_c: Optional[TileRef] = None   # C_ij input term (beta != 0)
    finalize: Optional[Finalize] = None
    deps: Tuple[int, ...] = ()         # task ids producing output tiles we read
    flops: int = 0
    # BLAS triangle semantics for diagonal tiles of SYRK/SYR2K: only this
    # triangle of the output tile is written; the rest keeps original C.
    out_mask: Optional[str] = None     # None | 'tri_u' | 'tri_l'
    # work-centric decomposition (KIND_*): partials carry the owner's
    # task id in ``parent`` and their steps slice in ``k_range``; the
    # fix-up keeps the owner's own id so downstream deps stay wired.
    kind: str = KIND_OWNER
    parent: Optional[int] = None
    k_range: Optional[Tuple[int, int]] = None

    def input_refs(self) -> List[TileRef]:
        """Every cacheable input tile (for Eq. 3 priority + transfers)."""
        refs: List[TileRef] = []
        for s in self.steps:
            refs.append(s.a)
            refs.append(s.b)
        if self.finalize is not None:
            refs.append(self.finalize.diag_ref)
            refs.append(self.finalize.rhs_ref)
        if self.read_c is not None:
            refs.append(self.read_c)
        return refs


def _step_flops(grids, step: Step) -> int:
    ga = grids[step.a.key.matrix_id]
    gb = grids[step.b.key.matrix_id]
    ha, wa = ga.tile_shape(step.a.key.i, step.a.key.j)
    if step.a.trans:
        ha, wa = wa, ha
    hb, wb = gb.tile_shape(step.b.key.i, step.b.key.j)
    if step.b.trans:
        hb, wb = wb, hb
    return 2 * ha * wa * wb


class TaskBuilder:
    """Shared machinery for the six routine taskizers."""

    def __init__(self, grids: dict):
        self.grids = {g.matrix_id: g for g in grids.values()} if isinstance(grids, dict) else {
            g.matrix_id: g for g in grids
        }
        self._next_id = 0
        self.tasks: List[Task] = []

    def add(self, **kw) -> Task:
        steps = kw.get("steps", ())
        flops = sum(_step_flops(self.grids, s) for s in steps)
        if kw.get("finalize") is not None:
            fin = kw["finalize"]
            g = self.grids[fin.diag_ref.key.matrix_id]
            t, _ = g.tile_shape(fin.diag_ref.key.i, fin.diag_ref.key.j)
            gc = self.grids[kw["out"].matrix_id]
            _, n = gc.tile_shape(kw["i"], kw["j"])
            flops += t * t * n  # triangular solve
        task = Task(task_id=self._next_id, flops=flops, **kw)
        self._next_id += 1
        self.tasks.append(task)
        return task


# --------------------------------------------------------------------------
# GEMM (Eq. 1a):  C_ij = alpha * sum_k op(A)_ik op(B)_kj + beta * C_ij
# --------------------------------------------------------------------------
def taskize_gemm(ga: TileGrid, gb: TileGrid, gc: TileGrid,
                 transa: str, transb: str,
                 alpha: float, beta: float) -> List[Task]:
    transa, transb = transa.upper()[0], transb.upper()[0]
    b = TaskBuilder({g.matrix_id: g for g in (ga, gb, gc)})
    kz = (ga.n_tile_cols if transa == "N" else ga.n_tile_rows)
    for i in range(gc.n_tile_rows):
        for j in range(gc.n_tile_cols):
            steps = []
            for k in range(kz):
                aref = (TileRef(ga.key(i, k)) if transa == "N"
                        else TileRef(ga.key(k, i), trans=True))
                bref = (TileRef(gb.key(k, j)) if transb == "N"
                        else TileRef(gb.key(j, k), trans=True))
                steps.append(Step(aref, bref))
            read_c = TileRef(gc.key(i, j)) if beta != 0.0 else None
            b.add(routine="gemm", out=gc.key(i, j), i=i, j=j,
                  steps=tuple(steps), alpha=alpha, beta=beta, read_c=read_c)
    return b.tasks


# --------------------------------------------------------------------------
# SYRK (Eq. 1b):  C_ij = alpha * sum_k A_ik A_jk^T + beta * C_ij   (trans=N)
#                 C_ij = alpha * sum_k A_ki^T A_kj + beta * C_ij   (trans=T)
# Only the ``uplo`` triangle of C is computed.
# --------------------------------------------------------------------------
def taskize_syrk(ga: TileGrid, gc: TileGrid, uplo: str, trans: str,
                 alpha: float, beta: float) -> List[Task]:
    uplo, trans = uplo.upper()[0], trans.upper()[0]
    b = TaskBuilder({g.matrix_id: g for g in (ga, gc)})
    kz = ga.n_tile_cols if trans == "N" else ga.n_tile_rows
    for i in range(gc.n_tile_rows):
        for j in range(gc.n_tile_cols):
            if (uplo == "U" and j < i) or (uplo == "L" and j > i):
                continue
            steps = []
            for k in range(kz):
                if trans == "N":
                    steps.append(Step(TileRef(ga.key(i, k)),
                                      TileRef(ga.key(j, k), trans=True)))
                else:
                    steps.append(Step(TileRef(ga.key(k, i), trans=True),
                                      TileRef(ga.key(k, j))))
            read_c = TileRef(gc.key(i, j)) if beta != 0.0 else None
            mask = ("tri_u" if uplo == "U" else "tri_l") if i == j else None
            b.add(routine="syrk", out=gc.key(i, j), i=i, j=j,
                  steps=tuple(steps), alpha=alpha, beta=beta, read_c=read_c,
                  out_mask=mask)
    return b.tasks


# --------------------------------------------------------------------------
# SYR2K (Eq. 1e): C_ij = alpha*sum_k A_ik B_jk^T + alpha*sum_k B_ik A_jk^T
#                        + beta*C_ij                                (trans=N)
# --------------------------------------------------------------------------
def taskize_syr2k(ga: TileGrid, gb: TileGrid, gc: TileGrid,
                  uplo: str, trans: str,
                  alpha: float, beta: float) -> List[Task]:
    uplo, trans = uplo.upper()[0], trans.upper()[0]
    b = TaskBuilder({g.matrix_id: g for g in (ga, gb, gc)})
    kz = ga.n_tile_cols if trans == "N" else ga.n_tile_rows
    for i in range(gc.n_tile_rows):
        for j in range(gc.n_tile_cols):
            if (uplo == "U" and j < i) or (uplo == "L" and j > i):
                continue
            steps = []
            for k in range(kz):
                if trans == "N":
                    steps.append(Step(TileRef(ga.key(i, k)),
                                      TileRef(gb.key(j, k), trans=True)))
                    steps.append(Step(TileRef(gb.key(i, k)),
                                      TileRef(ga.key(j, k), trans=True)))
                else:
                    steps.append(Step(TileRef(ga.key(k, i), trans=True),
                                      TileRef(gb.key(k, j))))
                    steps.append(Step(TileRef(gb.key(k, i), trans=True),
                                      TileRef(ga.key(k, j))))
            read_c = TileRef(gc.key(i, j)) if beta != 0.0 else None
            mask = ("tri_u" if uplo == "U" else "tri_l") if i == j else None
            b.add(routine="syr2k", out=gc.key(i, j), i=i, j=j,
                  steps=tuple(steps), alpha=alpha, beta=beta, read_c=read_c,
                  out_mask=mask)
    return b.tasks


# --------------------------------------------------------------------------
# SYMM (Eq. 1f, side=L): C_ij = alpha * sum_k sym(A)_ik B_kj + beta * C_ij
# A is symmetric with only ``uplo`` triangle stored:
#   upper storage: sym(A)_ik = A[i,k]        for k >= i
#                            = A[k,i]^T      for k <  i
# --------------------------------------------------------------------------
def taskize_symm(ga: TileGrid, gb: TileGrid, gc: TileGrid,
                 uplo: str, alpha: float, beta: float) -> List[Task]:
    uplo = uplo.upper()[0]
    b = TaskBuilder({g.matrix_id: g for g in (ga, gb, gc)})
    kz = ga.n_tile_cols
    sym_fill = FILL_SYM_U if uplo == "U" else FILL_SYM_L
    for i in range(gc.n_tile_rows):
        for j in range(gc.n_tile_cols):
            steps = []
            for k in range(kz):
                if k == i:
                    aref = TileRef(ga.key(i, i), fill=sym_fill)
                elif (uplo == "U") == (k > i):
                    # stored at [i,k] inside the stored triangle, no transpose
                    aref = TileRef(ga.key(i, k))
                else:
                    # mirrored: stored at [k,i], use transpose trick
                    aref = TileRef(ga.key(k, i), trans=True)
                steps.append(Step(aref, TileRef(gb.key(k, j))))
            read_c = TileRef(gc.key(i, j)) if beta != 0.0 else None
            b.add(routine="symm", out=gc.key(i, j), i=i, j=j,
                  steps=tuple(steps), alpha=alpha, beta=beta, read_c=read_c)
    return b.tasks


# --------------------------------------------------------------------------
# TRMM (Eq. 1d, side=L): C_ij = alpha * (sum_{k in tri} A_ik Cin_kj)
# where the diagonal step uses the triangular fill of A_ii.  The input
# matrix is read under id ``Cin`` (a snapshot) so tasks stay race free.
# --------------------------------------------------------------------------
def taskize_trmm(ga: TileGrid, gcin: TileGrid, gc: TileGrid,
                 uplo: str, transa: str, diag: str,
                 alpha: float) -> List[Task]:
    uplo, transa, diag = uplo.upper()[0], transa.upper()[0], diag.upper()[0]
    b = TaskBuilder({g.matrix_id: g for g in (ga, gcin, gc)})
    z = gc.n_tile_rows - 1
    # effective triangle of op(A): transpose flips it
    eff_upper = (uplo == "U") == (transa == "N")
    tri_fill = _tri_fill(uplo, diag)
    for i in range(gc.n_tile_rows):
        for j in range(gc.n_tile_cols):
            ks = range(i, z + 1) if eff_upper else range(0, i + 1)
            steps = []
            for k in ks:
                if k == i:
                    aref = _op_a(ga, transa, i, k, fill=tri_fill)
                else:
                    aref = _op_a(ga, transa, i, k)
                steps.append(Step(aref, TileRef(gcin.key(k, j))))
            b.add(routine="trmm", out=gc.key(i, j), i=i, j=j,
                  steps=tuple(steps), alpha=alpha, beta=0.0)
    return b.tasks


# --------------------------------------------------------------------------
# TRSM (Eq. 1c, side=L): solve op(A) X = alpha * B, X overwrites B.
#   X_ij = tri(A_ii)^{-1} (alpha*B_ij - sum_{k after i} op(A)_ik X_kj)
# Tasks within a column form a chain — expressed via ``deps``.
# --------------------------------------------------------------------------
def taskize_trsm(ga: TileGrid, gb: TileGrid, gc: TileGrid,
                 uplo: str, transa: str, diag: str,
                 alpha: float) -> List[Task]:
    uplo, transa, diag = uplo.upper()[0], transa.upper()[0], diag.upper()[0]
    b = TaskBuilder({g.matrix_id: g for g in (ga, gb, gc)})
    z = gc.n_tile_rows - 1
    eff_upper = (uplo == "U") == (transa == "N")
    tri_fill = _tri_fill(uplo, diag)
    order = range(z, -1, -1) if eff_upper else range(0, z + 1)
    # map (i, j) -> task id for dependency wiring
    tid = {}
    for j in range(gc.n_tile_cols):
        for i in order:
            ks = range(i + 1, z + 1) if eff_upper else range(0, i)
            steps = []
            deps = []
            for k in ks:
                steps.append(Step(_op_a(ga, transa, i, k), TileRef(gc.key(k, j))))
                deps.append(tid[(k, j)])
            fin = Finalize(
                kind="trsm",
                diag_ref=_op_a(ga, transa, i, i, fill=tri_fill),
                rhs_ref=TileRef(gb.key(i, j)),
                lower=not eff_upper,
                unit_diag=(diag == "U"),
            )
            t = b.add(routine="trsm", out=gc.key(i, j), i=i, j=j,
                      steps=tuple(steps), alpha=alpha, beta=0.0,
                      finalize=fin, deps=tuple(deps))
            tid[(i, j)] = t.task_id
    return b.tasks


def _op_a(ga: TileGrid, transa: str, i: int, k: int, fill: str = FILL_FULL) -> TileRef:
    """op(A)_ik: stored tile [i,k] if N, else [k,i] transposed (§III-C)."""
    if transa == "N":
        return TileRef(ga.key(i, k), fill=fill)
    return TileRef(ga.key(k, i), trans=True, fill=fill)


def _tri_fill(uplo: str, diag: str) -> str:
    if uplo == "U":
        return FILL_TRI_UU if diag == "U" else FILL_TRI_U
    return FILL_TRI_LU if diag == "U" else FILL_TRI_L


# --------------------------------------------------------------------------
# Work-centric (Stream-K) split planner — arXiv 2301.03598, beyond the paper
# --------------------------------------------------------------------------
def plan_work_centric(tasks: Sequence[Task], grids: Dict[str, TileGrid],
                      capacity: int) -> List[Task]:
    """Re-taskize an owner-mode task list so task count tracks FLOPs
    instead of output-tile count (Eq. 2's failure mode on small and
    ragged problems).

    Boundary/underfilled output tiles — and *every* tile of a problem
    whose owner-task count is below the device x stream ``capacity`` —
    get their k-loop cut into contiguous partial-k tasks
    (:func:`~repro.core.tiling.workcentric_parts` /
    :func:`~repro.core.tiling.split_ranges`), joined by one fix-up
    reduction task per split tile.

    Determinism rule (why numerics stay bitwise-identical to owner
    mode): a partial task carries only the *modeled* cost of its
    k-range — its gathers warm the caches and its flops share drives
    the virtual clock — but it never produces bytes of C_ij.  The
    fix-up keeps the owner task's id (downstream ``deps`` stay wired),
    re-dispatches the **full original k-loop** through the identical
    backend path, and performs the only write of C_ij.  The schedule
    (and the time model, and the backend) can therefore never change
    results; only modeled clocks move.  The fix-up's ``flops`` charge
    the join (one tile-sized add per partial) plus any finalize solve,
    not the MAC work already attributed to its partials.
    """
    tasks = list(tasks)
    if not tasks or capacity <= 0:
        return tasks
    n_owner = len(tasks)
    out_key_of = {t.task_id: t.out for t in tasks}
    next_id = max(t.task_id for t in tasks) + 1
    planned: List[Task] = []
    for t in tasks:
        if t.kind != KIND_OWNER:  # already split by an earlier planner
            planned.append(t)
            continue
        grid = grids[t.out.matrix_id]
        h, w = grid.tile_shape(t.i, t.j)
        ragged = h != grid.tile or w != grid.tile
        n_parts = workcentric_parts(len(t.steps), n_owner, capacity, ragged)
        if n_parts <= 1:
            planned.append(t)
            continue
        next_id = _split_task(t, n_parts, grids, out_key_of, next_id,
                              planned)
    return planned


def _split_task(t: Task, n_parts: int, grids: Dict[str, TileGrid],
                out_key_of: Dict[int, TileKey], next_id: int,
                planned: List[Task]) -> int:
    """Carve one owner task into ``n_parts`` contiguous partial-k tasks
    plus the fix-up join, appending them to ``planned``; returns the
    next free task id.  Shared by the work-centric (Stream-K) and the
    pod-tier panel-staging planners — both obey the same determinism
    rule (partials model cost only, the fix-up does the one write)."""
    # map deps to the k-steps that read their produced tile, so a
    # partial only waits on the producers of its own k-range; a dep
    # matching no step (defensive) stays on every piece
    step_keys = [{s.a.key, s.b.key} for s in t.steps]
    dep_steps = {}
    for d in t.deps:
        okey = out_key_of.get(d)
        idxs = {i for i, ks in enumerate(step_keys) if okey in ks}
        if idxs:
            dep_steps[d] = idxs
    step_fl = [_step_flops(grids, s) for s in t.steps]
    partial_ids = []
    for start, stop in split_ranges(len(t.steps), n_parts):
        span = set(range(start, stop))
        pdeps = tuple(d for d in t.deps
                      if d not in dep_steps or dep_steps[d] & span)
        planned.append(Task(
            task_id=next_id, routine=t.routine, out=t.out, i=t.i,
            j=t.j, steps=t.steps[start:stop], alpha=t.alpha, beta=0.0,
            deps=pdeps, flops=sum(step_fl[start:stop]),
            kind=KIND_PARTIAL, parent=t.task_id,
            k_range=(start, stop)))
        partial_ids.append(next_id)
        next_id += 1
    grid = grids[t.out.matrix_id]
    h, w = grid.tile_shape(t.i, t.j)
    solve_fl = max(0, t.flops - sum(step_fl))
    planned.append(dataclasses.replace(
        t, deps=t.deps + tuple(partial_ids),
        flops=n_parts * h * w + solve_fl,
        kind=KIND_FIXUP, k_range=(0, len(t.steps))))
    return next_id


def plan_panel_staged(tasks: Sequence[Task], matrices: Dict[str, object],
                      cache_bytes: int) -> List[Task]:
    """Pod-tier staging planner: cut beyond-HBM tasks into panel-sized
    partials joined by a fix-up, so host panels stream through the tile
    cache instead of bypassing it.

    A task whose k-loop input working set exceeds the per-device HBM
    (``cache_bytes``) cannot keep its tiles resident: every gather past
    capacity degrades to an uncached host read.  Splitting its k-loop
    into half-HBM panels that *do* fit
    (:func:`~repro.core.tiling.panel_parts`) lets each partial
    stage its panel through the ALRU/MESI-X machinery; the fix-up join
    then re-reads those panels from the shard ring's HBM over ICI (the
    hierarchy's third level) rather than from host DRAM.  Numerics are
    bitwise-identical to the unstaged run for the same reason the
    work-centric planner's are (see :func:`plan_work_centric` and
    :func:`_split_task`): partials never write C, the fix-up
    re-dispatches the full original k-loop.

    ``matrices`` maps matrix id to any object with ``.grid`` and
    ``.nbytes(i, j)`` (``TiledMatrix`` or ``ShadowMatrix``) so the
    working set is measured in the matrices' true storage bytes.
    """
    tasks = list(tasks)
    if not tasks or cache_bytes <= 0:
        return tasks
    grids = {mid: m.grid for mid, m in matrices.items()}
    out_key_of = {t.task_id: t.out for t in tasks}
    next_id = max(t.task_id for t in tasks) + 1
    planned: List[Task] = []
    for t in tasks:
        if t.kind != KIND_OWNER or len(t.steps) < 2:
            planned.append(t)
            continue
        seen = set()
        total = 0
        for ref in t.input_refs():
            if ref.key in seen:
                continue
            seen.add(ref.key)
            total += matrices[ref.key.matrix_id].nbytes(ref.key.i,
                                                        ref.key.j)
        n_parts = panel_parts(total, cache_bytes, len(t.steps))
        if n_parts <= 1:
            planned.append(t)
            continue
        next_id = _split_task(t, n_parts, grids, out_key_of, next_id,
                              planned)
    return planned


def total_flops(tasks: Sequence[Task]) -> int:
    return sum(t.flops for t in tasks)


def gemm_fraction(tasks: Sequence[Task]) -> float:
    """Table I: share of FLOPs spent in plain GEMM-shaped steps (full-fill
    multiply-accumulate) vs. triangular/diagonal special handling."""
    gemm_fl = 0
    other_fl = 0
    for t in tasks:
        for s in t.steps:
            fl = t.flops and _safe_step_flops(t, s)
            if s.a.fill == FILL_FULL and s.b.fill == FILL_FULL:
                gemm_fl += fl
            else:
                other_fl += fl
        if t.finalize is not None:
            other_fl += max(0, t.flops - sum(_safe_step_flops(t, s) for s in t.steps))
    denom = gemm_fl + other_fl
    return gemm_fl / denom if denom else 1.0


def _safe_step_flops(task: Task, step: Step) -> int:
    # steps within one task share tile size; apportion flops evenly
    return task.flops // max(1, len(task.steps)) if task.steps else 0
