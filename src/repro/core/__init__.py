"""repro.core — the BLASX reproduction: tile algebra, two-level tile
caches (ALRU + MESI-X), the locality-aware dynamic scheduling runtime,
and the public L3 BLAS API."""
from .blas3 import (gemm, ref_gemm, ref_symm, ref_syr2k, ref_syrk, ref_trmm,
                    ref_trsm, symm, syr2k, syrk, trmm, trsm)
from .runtime import BlasxRuntime, RuntimeConfig
from .tiling import TiledMatrix, TileGrid, TileKey, degree_of_parallelism

__all__ = [
    "gemm", "syrk", "syr2k", "symm", "trmm", "trsm",
    "ref_gemm", "ref_syrk", "ref_syr2k", "ref_symm", "ref_trmm", "ref_trsm",
    "BlasxRuntime", "RuntimeConfig",
    "TiledMatrix", "TileGrid", "TileKey", "degree_of_parallelism",
]
