"""repro.core — the BLASX reproduction: tile algebra, two-level tile
caches (ALRU + MESI-X), the locality-aware dynamic scheduling runtime,
and the legacy numpy-in/numpy-out L3 BLAS API.

The persistent-handle layer (``BlasxContext``, ``MatrixHandle``,
``BlasFuture``, ``cblas_*``) lives in ``repro.api``; the names are
re-exported here lazily so ``repro.core`` keeps no import-time
dependency on the api package (which itself imports core modules).
"""
from .blas3 import (gemm, ref_gemm, ref_symm, ref_syr2k, ref_syrk, ref_trmm,
                    ref_trsm, symm, syr2k, syrk, trmm, trsm)
from .dtypes import (SUPPORTED_DTYPES, canonical_dtype, promote_dtypes,
                     validate_backend_dtype)
from .runtime import BlasxRuntime, RuntimeConfig
from .tiling import TiledMatrix, TileGrid, TileKey, degree_of_parallelism

_API_NAMES = ("BlasxContext", "MatrixHandle", "BlasFuture",
              "default_context", "set_default_context")

__all__ = [
    "gemm", "syrk", "syr2k", "symm", "trmm", "trsm",
    "ref_gemm", "ref_syrk", "ref_syr2k", "ref_symm", "ref_trmm", "ref_trsm",
    "BlasxRuntime", "RuntimeConfig",
    "TiledMatrix", "TileGrid", "TileKey", "degree_of_parallelism",
    "SUPPORTED_DTYPES", "canonical_dtype", "promote_dtypes",
    "validate_backend_dtype",
    *_API_NAMES,
]


def __getattr__(name):
    if name in _API_NAMES:
        from .. import api

        return getattr(api, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
