"""The BLASX locality-aware dynamic scheduling runtime (paper §IV, Alg. 1).

Two execution modes share every data structure (ALRU, MESI-X directory,
heap, reservation stations, global ready queue, communication ledger):

  * ``threads`` — faithful to the paper: one host thread per device,
    demand-driven work sharing off the global queue, work stealing from
    peer reservation stations, asynchronous batch execution with
    reader-count release at the stream-sync point.
  * ``sim``     — a deterministic virtual-clock engine over the same
    components.  Devices consume tasks in earliest-free-time order
    (exactly the paper's "demand driven" behaviour, but reproducible),
    and per-batch time is modeled from device speed and link bandwidth.
    All Table III/V and Fig. 7/8/10 analogues run in this mode.

Scheduling policies (the paper's baselines are implemented, §II):

  * ``blasx``       — dynamic demand + stealing + Eq. 3 locality priority,
                      L1+L2 tile caches (the paper's contribution);
  * ``parsec``      — dynamic demand, L1 cache only, FIFO priority
                      (h-PaRSEC-like: no inter-GPU cache);
  * ``cublasxt``    — static round-robin tile assignment, NO tile cache
                      (on-demand transfer per k-step), 2 streams;
  * ``static``      — MAGMA-like static contiguous split proportional to
                      device speed, L1 cache, no stealing;
  * ``supermatrix`` — dynamic demand, no cache, fork-join (no
                      communication/computation overlap).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..backends import create_backend
from ..backends.base import StepGroupKey
from .alru import Alru
from .coherence import MesixDirectory
from .dtypes import promote_dtypes
from .events import EventEngine, TimedTask, TimedXfer
from .heap import BlasxHeap
from . import task as taskmod
from .task import KIND_FIXUP, KIND_PARTIAL, Ledger, Task, TileRef
from .taskqueue import ReadyQueue, ReservationStation
from .tile_kernels import get_solver, materialize
from .tiling import TiledMatrix, TileKey

# paper Table IV: measured DMA throughputs on Everest
H2D_BW = 6.54e9   # bytes/s, bidirectional host <-> device
D2D_BW = 7.80e9   # bytes/s, GPU <-> GPU peer
ICI_BW = 4.50e10  # bytes/s, per-link inter-chip interconnect (pod tier)
DEFAULT_PEAK_FLOPS = 1.43e12  # K40c double-precision-ish peak (paper §V-A)

# sentinel payload used by metadata-only runs (execute=False)
_METADATA_ONLY = np.empty(0)


def _tile_label(key) -> str:
    """Human-readable tile name for trace spans."""
    return f"{key.matrix_id}[{key.i},{key.j}]"


@dataclasses.dataclass(frozen=True)
class DeviceClass:
    """What one scheduler "device" *is* (pod tier).

    The paper's runtime schedules over a flat set of accelerators; at
    pod scale one scheduler device may instead be a whole ICI ring of
    mesh shards whose compute step is a ring-scheduled SPMD step
    (``repro.core.distributed``).  The class abstracts exactly the two
    places the difference matters to the runtime: how fast one
    "device" computes, and what a fresh host panel costs to scatter
    across it.  Everything else — ALRU, MESI-X, heap, queues — is
    class-agnostic.
    """

    name: str
    # compute step is a ring-scheduled pod step over `mesh_devices`
    # shards (core.distributed) rather than a single accelerator kernel
    ring: bool

    def peak_flops(self, peak: float, mesh_devices: int) -> float:
        """Effective peak of one scheduler device: a ``mesh_shard``
        device is a whole ring, so its peak is the per-shard peak
        times the ring size."""
        return peak * (mesh_devices if self.ring else 1)

    def hop_bytes(self, nbytes: int, mesh_devices: int) -> int:
        """ICI bytes one fresh host panel costs to scatter across the
        ring: a ring all-gather forwards ``(d-1)/d`` of the panel per
        shard (``ring_allgather_matmul``'s ppermute traffic).  Zero for
        plain accelerators — their fills never touch ICI."""
        if not self.ring or mesh_devices <= 1:
            return 0
        return nbytes * (mesh_devices - 1) // mesh_devices


DEVICE_CLASSES: Dict[str, DeviceClass] = {
    "accelerator": DeviceClass("accelerator", ring=False),
    "mesh_shard": DeviceClass("mesh_shard", ring=True),
}


@dataclasses.dataclass
class RuntimeConfig:
    n_devices: int = 2
    cache_bytes: int = 256 << 20          # per-device L1 tile-cache capacity
    n_streams: int = 4                    # paper: 4 concurrent tasks/streams
    rs_slots: Optional[int] = None        # RS capacity (default 2*n_streams)
    policy: str = "blasx"
    # execution backend: numpy | jax | pallas (see repro.backends).
    # ``kernel`` is the legacy spelling; ``backend`` wins when both are
    # given and the two are kept equal after __post_init__.
    kernel: str = "numpy"
    backend: Optional[str] = None
    speeds: Optional[Sequence[float]] = None   # realtime device speeds
    # what a static scheduler *believes* the speeds are (MAGMA/PaRSEC
    # assume constant nominal speed; realtime saturation differs — §IV-C)
    nominal_speeds: Optional[Sequence[float]] = None
    p2p_groups: Optional[Sequence[Sequence[int]]] = None  # default: one group
    mode: str = "sim"                     # sim | threads
    # sim-mode timing engine: "events" schedules every tile fetch,
    # compute span and write-back on per-stream/per-link timelines
    # (repro.core.events); "lump" is the seed max(compute, comm) model,
    # kept for the bitwise parity suite and A/B timing studies.
    # Numerics are identical under both (only modeled clocks differ).
    time_model: str = "events"
    # force communication/computation overlap on (True) or off (False)
    # regardless of policy; None derives it from the policy (only the
    # fork-join supermatrix baseline runs unoverlapped).  The overlap
    # bench lane uses this to measure the same policy both ways.
    overlap_comm: Optional[bool] = None
    # record the event timeline for trace() export (sim+events only).
    # None resolves to ``execute``: real runs record by default (the
    # ctx.trace() contract), metadata-scale shadow sweeps — the runs
    # big enough for span memory to matter — opt in explicitly.
    record_trace: Optional[bool] = None
    peak_flops: float = DEFAULT_PEAK_FLOPS
    h2d_bw: float = H2D_BW
    d2d_bw: float = D2D_BW
    # all devices share the host PCI-E root complex: concurrent H2D
    # transfers contend (the paper's "cuBLAS-XT overloads the PCI-E").
    # P2P transfers ride dedicated switch lanes and do not contend.
    shared_host_link: bool = True
    # execute=False: metadata-only run — full scheduling/cache/ledger
    # behaviour, no numerics.  Lets benchmarks run at the paper's true
    # scale (N=16384..40K, T=1024) on this 1-core host.
    execute: bool = True
    # work-centric (Stream-K) scheduling: split the k-loop of ragged /
    # underfilled output tiles (and of every tile of a small problem)
    # into partial tasks joined by a deterministic fix-up reduction —
    # see repro.core.task.plan_work_centric.  Numerics are bitwise
    # identical to owner mode; only the schedule (and modeled clocks)
    # change.  Searched by the runtime autotuner alongside tile size,
    # n_streams and policy.
    work_centric: bool = False
    # --- pod tier (3-level cache: host DRAM -> HBM -> ICI neighbor) ---
    # what one scheduler "device" is: "accelerator" (the paper's flat
    # model, bit-and-timing-identical to before this knob existed) or
    # "mesh_shard" (one device = a whole ICI ring of `mesh_devices`
    # shards whose compute step is a ring-scheduled pod step from
    # repro.core.distributed).  See DEVICE_CLASSES.
    device_class: str = "accelerator"
    mesh_devices: int = 1                 # ring size per mesh_shard device
    ici_bw: float = ICI_BW                # bytes/s per ICI link
    # panel staging (repro.core.task.plan_panel_staged): split
    # beyond-HBM tasks into panel-sized partials + fix-up so host
    # panels stream through the tile cache instead of bypassing it.
    # None derives from the device class (mesh shards stage, plain
    # accelerators don't); the pod bench forces False for its
    # direct-host baseline.  Bitwise-identical numerics either way.
    stage_panels: Optional[bool] = None
    seed: int = 0

    def __post_init__(self):
        if self.policy not in ("blasx", "parsec", "cublasxt", "static",
                               "supermatrix"):
            raise ValueError(f"unknown policy {self.policy}")
        if self.backend is None:
            self.backend = self.kernel
        if self.backend not in ("numpy", "jax", "pallas"):
            raise ValueError(f"unknown backend {self.backend}")
        if self.time_model not in ("events", "lump"):
            raise ValueError(f"unknown time_model {self.time_model}")
        if self.record_trace is None:
            self.record_trace = bool(self.execute)
        self.kernel = self.backend
        if self.speeds is None:
            self.speeds = [1.0] * self.n_devices
        if len(self.speeds) != self.n_devices:
            raise ValueError("speeds length != n_devices")
        if self.nominal_speeds is None:
            self.nominal_speeds = list(self.speeds)
        if self.rs_slots is None:
            self.rs_slots = 2 * self.n_streams
        if self.p2p_groups is None:
            self.p2p_groups = [list(range(self.n_devices))]
        if self.device_class not in DEVICE_CLASSES:
            raise ValueError(
                f"unknown device_class {self.device_class!r} "
                f"(expected one of {sorted(DEVICE_CLASSES)})")
        if self.ici_bw <= 0:
            raise ValueError("ici_bw must be positive")
        if self.dclass.ring:
            if self.mesh_devices < 2:
                raise ValueError(
                    "mesh_shard devices are whole ICI rings: "
                    "mesh_devices must be >= 2")
        elif self.mesh_devices != 1:
            raise ValueError(
                "mesh_devices != 1 requires device_class='mesh_shard'")

    @property
    def dclass(self) -> DeviceClass:
        return DEVICE_CLASSES[self.device_class]

    @property
    def stage_panels_on(self) -> bool:
        """Whether run() applies the panel-staging planner; explicit
        ``stage_panels`` wins, else the device class decides."""
        if self.stage_panels is not None:
            return self.stage_panels
        return self.dclass.ring

    @property
    def device_peak_flops(self) -> float:
        """Effective peak of ONE scheduler device (a mesh_shard device
        is a whole ring — see DeviceClass.peak_flops)."""
        return self.dclass.peak_flops(self.peak_flops, self.mesh_devices)

    @property
    def use_cache(self) -> bool:
        return self.policy in ("blasx", "parsec", "static")

    @property
    def use_l2(self) -> bool:
        return self.policy == "blasx"

    @property
    def use_priority(self) -> bool:
        return self.policy == "blasx"

    @property
    def use_stealing(self) -> bool:
        return self.policy in ("blasx", "parsec", "supermatrix")

    @property
    def static_assignment(self) -> Optional[str]:
        return {"cublasxt": "roundrobin", "static": "speed"}.get(self.policy)

    @property
    def overlap(self) -> bool:
        if self.overlap_comm is not None:
            return self.overlap_comm
        return self.policy != "supermatrix"

    @property
    def h2d_bw_eff(self) -> float:
        """Per-device host bandwidth under contention."""
        return self.h2d_bw / (self.n_devices if self.shared_host_link
                              else 1)

    @property
    def effective_streams(self) -> int:
        return 2 if self.policy == "cublasxt" else self.n_streams

    def topology(self) -> Dict[str, object]:
        """The fields that describe the *machine* this config models —
        device count/speeds, P2P grouping, link bandwidths, cache and
        compute capacity — excluding the knobs the runtime autotuner
        searches (tile size, ``n_streams``, ``policy``) and anything
        that cannot change modeled time (seed, trace recording).  The
        tuning layer fingerprints this dict: two configs with equal
        topologies share one tuning-cache namespace."""
        return {
            "n_devices": self.n_devices,
            "speeds": list(self.speeds),
            "nominal_speeds": list(self.nominal_speeds),
            "p2p_groups": [list(g) for g in self.p2p_groups],
            "cache_bytes": self.cache_bytes,
            "peak_flops": self.peak_flops,
            "h2d_bw": self.h2d_bw,
            "d2d_bw": self.d2d_bw,
            "shared_host_link": self.shared_host_link,
            "device_class": self.device_class,
            "mesh_devices": self.mesh_devices,
            "ici_bw": self.ici_bw,
        }


class DeviceSim:
    """One simulated accelerator: private heap + ALRU (L1 tile cache) +
    tile store (the actual bytes) + ledger."""

    def __init__(self, device_id: int, cfg: RuntimeConfig,
                 directory: MesixDirectory):
        self.id = device_id
        self.cfg = cfg
        self.speed = float(cfg.speeds[device_id])
        self.heap = BlasxHeap(cfg.cache_bytes)
        self.alru = Alru(device_id, self.heap)
        self.store: Dict[TileKey, np.ndarray] = {}
        self.ledger = Ledger()
        self.rs = ReservationStation(device_id, cfg.rs_slots)
        self.clock = 0.0  # sim-mode virtual time
        self._directory = directory
        # guards cross-thread writes into THIS device's ledger (threads
        # mode: a peer's worker charges d2d_served_s on an L2 fetch;
        # every other ledger write comes from the owning worker only)
        self.serve_lock = threading.Lock()

        def _on_evict(dev_id: int, key: TileKey) -> None:
            directory.on_evict(key, dev_id)
            self.store.pop(key, None)

        self.alru.on_evict = _on_evict


@dataclasses.dataclass
class _TaskExec:
    """In-flight execution record of one task within a device batch:
    materialized inputs gathered in phase 1, per-step products filled
    in by the backend dispatch in phase 2."""

    task: Task
    a_tiles: List[np.ndarray]
    b_tiles: List[np.ndarray]
    products: List[Optional[np.ndarray]]  # per-step path (mixed signatures)
    acc: Optional[np.ndarray] = None    # task-contraction path result
    diag: Optional[np.ndarray] = None   # TRSM diagonal tile
    rhs: Optional[np.ndarray] = None    # TRSM right-hand side
    cin: Optional[np.ndarray] = None    # beta != 0 C input
    # timed transfers collected while gathering/finalizing — the event
    # engine's raw material (kind, bytes, modeled seconds per movement)
    xfers: List[TimedXfer] = dataclasses.field(default_factory=list)
    wb: Optional[TimedXfer] = None      # finalize-phase write-back


class BlasxRuntime:
    """Executes taskized L3 BLAS calls over simulated devices (Alg. 1).

    A runtime is a *session*: ``run`` may be called any number of
    times and the tile caches (ALRU L1 + MESI-X L2), device clocks and
    communication ledgers persist across calls — tiles cached by one
    routine are served warm to the next, provided callers keep tile
    keys stable (unique matrix ids per matrix; see
    ``repro.api.BlasxContext``).  Ledgers accumulate; callers wanting
    per-call numbers snapshot around ``run`` (``CallRecord`` in the
    context layer does this).  ``reset()`` returns the session to a
    cold state, ``reset_stats()`` zeroes counters but keeps caches
    warm.
    """

    def __init__(self, cfg: RuntimeConfig):
        self.cfg = cfg
        self.directory = MesixDirectory(cfg.n_devices, cfg.p2p_groups)
        self.devices = [DeviceSim(d, cfg, self.directory)
                        for d in range(cfg.n_devices)]
        self.backend = create_backend(cfg.backend)
        self._solver = get_solver()
        self.runs = 0
        # serving front-end state (repro.serve): which tenant the
        # in-flight run belongs to (tags ALRU blocks for the quota
        # machinery) and its priority-class boost (additive Eq. 3
        # term).  Quotas live here too so reset() can reapply them to
        # the rebuilt devices.
        self._tenant: Optional[str] = None
        self._boost: float = 0.0
        self._tenant_quotas: Dict[str, int] = {}
        # the discrete-event timing engine only exists where virtual
        # clocks do: sim mode with time_model="events".  Threads mode
        # measures real wall time; "lump" keeps the seed max() model.
        self._engine: Optional[EventEngine] = (
            EventEngine(cfg) if cfg.mode == "sim"
            and cfg.time_model == "events" else None)

    # ------------------------------------------------------------- public
    def run(self, tasks: Sequence[Task], matrices: Dict[str, TiledMatrix],
            out_id: str, *, tenant: Optional[str] = None,
            priority_boost: float = 0.0) -> None:
        """Execute all tasks; the output matrix (``matrices[out_id]``) is
        updated in place tile by tile.

        ``tenant`` attributes every tile this run pulls into the ALRU
        caches to that owner (the serving layer's per-tenant quota
        machinery keys off the tag); ``priority_boost`` is the
        request's priority-class term, added to every task's Eq. 3
        locality priority for the duration of the run (the serving
        front end maps ``interactive``/``batch`` onto it)."""
        self._tenant = tenant
        self._boost = float(priority_boost)
        self.runs += 1
        if not tasks:
            return
        if self.cfg.work_centric:
            tasks = taskmod.plan_work_centric(
                tasks, {mid: m.grid for mid, m in matrices.items()},
                self.cfg.n_devices * self.cfg.effective_streams)
        if self.cfg.stage_panels_on:
            # pod tier: beyond-HBM tasks become panel-sized partials +
            # fix-up so host panels stream through the cache hierarchy
            # (runs after the work-centric planner; both skip non-owner
            # tasks, so the two compose without double-splitting)
            tasks = taskmod.plan_panel_staged(tasks, matrices,
                                              self.cfg.cache_bytes)
        self._matrices = matrices
        self._out_id = out_id
        if self.cfg.static_assignment:
            queues = self._static_split(tasks)
            self._queue = None
            self._static_queues = queues
        else:
            self._queue = ReadyQueue(tasks)
            self._static_queues = None
        self._completed: Dict[int, float] = {}
        if self.cfg.mode == "threads":
            self._run_threads(tasks)
        else:
            self._run_sim(tasks)

    # ----------------------------------------------------- static policies
    def _static_split(self, tasks: Sequence[Task]) -> List[ReadyQueue]:
        n = self.cfg.n_devices
        buckets: List[List[Task]] = [[] for _ in range(n)]
        if self.cfg.static_assignment == "roundrobin":
            for idx, t in enumerate(tasks):
                buckets[idx % n].append(t)
        else:  # contiguous split proportional to NOMINAL speed (MAGMA-like)
            total_speed = sum(self.cfg.nominal_speeds)
            total_fl = sum(t.flops for t in tasks) or 1
            shares = [s / total_speed for s in self.cfg.nominal_speeds]
            acc = 0.0
            dev = 0
            budget = shares[0] * total_fl
            for t in tasks:
                if acc > budget and dev < n - 1:
                    dev += 1
                    budget += shares[dev] * total_fl
                buckets[dev].append(t)
                acc += t.flops
        # NB: a static split cannot respect TRSM chains across devices;
        # ReadyQueue still enforces them (a device may stall — exactly the
        # pathology the paper ascribes to static scheduling).
        return [ReadyQueue(b) for b in buckets]

    # --------------------------------------------------------------- sim
    def _run_sim(self, tasks: Sequence[Task]) -> None:
        n_left = len(tasks)
        stall_guard = 0
        active = set(range(self.cfg.n_devices))
        while n_left > 0:
            d = min((self.devices[i] for i in active),
                    key=lambda x: (x.clock, x.id))
            batch = self._fill_and_take(d)
            if not batch:
                # will this device ever get work again?
                if len(d.rs) == 0 and not self.cfg.use_stealing:
                    src = (self._static_queues[d.id]
                           if self._static_queues is not None else self._queue)
                    if src.drained() and not src.has_ready():
                        active.discard(d.id)
                        if not active:
                            raise RuntimeError("all devices retired with "
                                               f"{n_left} tasks left")
                        continue
                stall_guard += 1
                if stall_guard > 8 * self.cfg.n_devices + 64:
                    raise RuntimeError(
                        "scheduler livelock: pending dependencies never "
                        "resolved (task DAG cycle?)")
                # nudge the starved device's clock past the next busy
                # one; the skipped time is *idle* (a dependency stall),
                # ledger-charged so busy + idle always sums to the
                # device clock instead of silently inflating makespan
                busy = [self.devices[i].clock for i in active
                        if self.devices[i] is not d]
                before = d.clock
                d.clock = max(d.clock, min(busy) if busy else d.clock) + 1e-9
                d.ledger.idle_time += d.clock - before
                continue
            stall_guard = 0
            ready_at = max((self._completed.get(dep, 0.0)
                            for t in batch for dep in t.deps), default=0.0)
            start = max(d.clock, ready_at)
            if start > d.clock:  # waited on a producer: idle, not busy
                d.ledger.idle_time += start - d.clock
            span, finishes = self._execute_batch(d, batch, start)
            d.clock = start + span
            d.ledger.busy_time += span
            for t, fin in zip(batch, finishes):
                self._completed[t.task_id] = fin
                self._complete(t)
                n_left -= 1

    def _pick_device(self) -> DeviceSim:
        return min(self.devices, key=lambda d: (d.clock, d.id))

    # ------------------------------------------------------------ threads
    def _run_threads(self, tasks: Sequence[Task]) -> None:
        n_left = [len(tasks)]
        cv = threading.Condition()   # signaled on completion and on error
        errors: List[BaseException] = []
        # per-device batch taken out of the RS but not yet completed —
        # a crashing worker leaves its entry for the post-join requeue
        inflight: Dict[int, List[Task]] = {}

        def done() -> bool:  # call with cv held
            return n_left[0] <= 0 or bool(errors)

        # completion generation: bumped on every completed batch so a
        # worker whose empty _fill_and_take raced a peer's completion
        # retries immediately instead of sleeping out the wait timeout
        gen = [0]

        def worker(d: DeviceSim) -> None:
            try:
                while True:
                    with cv:
                        if done():
                            return
                        my_gen = gen[0]
                    batch = self._fill_and_take(d)
                    if not batch:
                        # nothing runnable (deps pending / peers hold all
                        # work): park until a peer completes a batch or
                        # crashes.  The generation check closes the
                        # lost-wakeup window between the empty take and
                        # acquiring the cv; the timeout is a safety net
                        # against a missed notify, not a poll interval.
                        with cv:
                            if done():
                                return
                            if gen[0] == my_gen:
                                cv.wait(timeout=0.05)
                        continue
                    inflight[d.id] = batch
                    t0 = time.perf_counter()
                    self._execute_batch(d, batch)
                    d.ledger.busy_time += time.perf_counter() - t0
                    with cv:
                        # pop each task as it completes so an exception
                        # mid-loop leaves only the genuinely uncompleted
                        # tail for the crash-recovery requeue (a cleared-
                        # at-the-end list would requeue completed tasks)
                        pending = inflight[d.id]
                        while pending:
                            self._complete(pending[0])
                            n_left[0] -= 1
                            pending.pop(0)
                        gen[0] += 1
                        cv.notify_all()
            except BaseException as e:  # surface worker crashes
                with cv:
                    # append order under the lock = true failure order;
                    # errors[0] below is the first real failure
                    errors.append(e)
                    cv.notify_all()

        threads = [threading.Thread(target=worker, args=(d,), daemon=True)
                   for d in self.devices]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        if errors:
            # workers bailed out with work still parked in reservation
            # stations (their own refills + stolen tasks) and, for the
            # crashed worker, an in-flight batch already taken from its
            # RS.  Return all of it to the owning queue so the session's
            # task accounting shows no stranded tasks: every task is
            # either completed or dequeueable again.
            for d in self.devices:
                src = (self._static_queues[d.id]
                       if self._static_queues is not None else self._queue)
                for t in d.rs.drain():
                    src.requeue(t)
                for t in inflight.get(d.id, ()):
                    src.requeue(t)
            raise errors[0]

    # ------------------------------------------------- scheduling plumbing
    def _dequeue_for(self, d: DeviceSim) -> Optional[Task]:
        if self._static_queues is not None:
            return self._static_queues[d.id].try_dequeue()
        return self._queue.try_dequeue()

    def _complete(self, t: Task) -> None:
        if self._static_queues is not None:
            for q in self._static_queues:
                q.complete(t)  # owner decrements; others resolve dep edges
        else:
            self._queue.complete(t)

    def _fill_and_take(self, d: DeviceSim) -> List[Task]:
        # work sharing: refill RS from the global (or static) queue
        while d.rs.free_slots() > 0:
            t = self._dequeue_for(d)
            if t is None:
                break
            d.rs.put(t, self._priority(d, t))
        # work stealing: only when RS is empty and the queue gave nothing
        if len(d.rs) == 0 and self.cfg.use_stealing:
            victim = max((x for x in self.devices if x is not d),
                         key=lambda x: len(x.rs), default=None)
            if victim is not None and len(victim.rs) > 0:
                # refresh the victim station's priorities against the
                # VICTIM's current cache state (Eq. 3): put-time values
                # are stale once tiles landed in its L1/L2, and a stale
                # sort would let the thief walk off with an L1-hot task
                prio_fn = ((lambda t: self._priority(victim, t))
                           if self.cfg.use_priority else None)
                stolen = victim.rs.steal(prio_fn)
                if stolen is not None:
                    d.rs.put(stolen, self._priority(d, stolen))
                    d.ledger.steals += 1
        if len(d.rs) == 0:
            return []
        if self.cfg.use_priority:
            d.rs.set_priorities(lambda t: self._priority(d, t))
        return d.rs.take_top(self.cfg.effective_streams)

    def _priority(self, d: DeviceSim, t: Task) -> float:
        """Eq. 3: +2 per L1-resident input tile, +1 per L2 (peer) tile,
        plus the in-flight run's priority-class boost (serving front
        end: interactive requests outrank batch in every reservation
        station their tasks ever share)."""
        if not self.cfg.use_priority:
            return 0.0
        p = self._boost
        for ref in t.input_refs():
            if ref.key in d.alru:
                p += 2.0
            elif self.cfg.use_l2 and \
                    self.directory.peer_holder(ref.key, d.id) is not None:
                p += 1.0
        return p

    # ----------------------------------------------------------- execution
    def _execute_batch(self, d: DeviceSim, batch: List[Task],
                       start: float = 0.0) -> Tuple[float, List[float]]:
        """Run up to ``n_streams`` tasks as one overlapped batch; returns
        ``(modeled span, per-task finish times)`` relative to ``start``
        (sim mode; threads mode measures real wall time and ignores
        both).  Readers are released at the end — the paper's
        StreamsSynch + ReaderUpdate point.

        Execution is a three-phase pipeline:

          1. *gather*   — acquire every input tile through the two-level
             cache (all communication accounting happens here, in the
             same per-task order the sequential engine used);
          2. *dispatch* — group the batch's k-steps by
             (op, trans, fill, tile-shape, dtype) and hand each group to
             the execution backend as ONE batched call — the paper's
             stream-level concurrency, minus the per-step dispatch tax;
          3. *finalize* — per-task epilogue (alpha/beta, TRSM solve,
             triangle masks) and MESI-X write-back.

        Timing happens after the numerics: with the event engine every
        gathered transfer, per-task compute share and write-back is
        scheduled onto stream/link timelines (overlap and contention
        emerge); the "lump" model reproduces the seed
        ``max(compute, comm)``.  Both see identical tile data — the
        time model can never change results.

        Tasks in one batch are dependency-free w.r.t. each other (the
        ReadyQueue only releases a task after its deps *complete*, and
        completion happens after the batch), so hoisting all reads
        before all writes preserves the sequential semantics."""
        acquired: List[TileKey] = []
        comm_s = 0.0
        compute_each: List[float] = []
        recs: List[_TaskExec] = []
        try:
            for t in batch:
                rec, secs = self._gather_task(d, t, acquired)
                recs.append(rec)
                comm_s += secs
            if self.cfg.execute:
                self._dispatch_steps(d, recs)
            for rec in recs:
                comm_s += self._finalize_task(d, rec)
                compute_each.append(
                    rec.task.flops / (d.speed * self.cfg.device_peak_flops))
                d.ledger.tasks += 1
                d.ledger.flops += rec.task.flops
                if rec.task.kind == KIND_PARTIAL:
                    d.ledger.partial_tasks += 1
                    d.ledger.partial_flops += rec.task.flops
                elif rec.task.kind == KIND_FIXUP:
                    d.ledger.fixup_tasks += 1
                    d.ledger.fixup_flops += rec.task.flops
        except BaseException:
            # a failing batch must not leave its acquired tiles pinned:
            # the readers would never hit the release below, permanently
            # blocking eviction/invalidation of those blocks in this
            # session (each acquired entry is one translate increment)
            for key in acquired:
                d.alru.release(key)
            raise
        # reader update (the ALRU may evict these from now on)
        for key in acquired:
            d.alru.release(key)
        compute_s = sum(compute_each)
        d.ledger.compute_time += compute_s
        d.ledger.comm_time += comm_s
        if self._engine is not None:
            return self._schedule_events(d, recs, compute_each, compute_s,
                                         comm_s, start)
        # lump-sum model (time_model="lump" and threads mode): one
        # duration for the whole batch, all tasks finish together
        if self.cfg.overlap:
            d.ledger.unoverlapped_comm += max(0.0, comm_s - compute_s)
            dur = max(compute_s, comm_s)
        else:
            d.ledger.unoverlapped_comm += comm_s
            dur = compute_s + comm_s
        return dur, [start + dur] * len(batch)

    def _schedule_events(self, d: DeviceSim, recs: List["_TaskExec"],
                         compute_each: List[float], compute_s: float,
                         comm_s: float, start: float
                         ) -> Tuple[float, List[float]]:
        """Hand the batch's timed material to the discrete-event engine
        and charge the schedule-derived ledger metrics."""
        items = []
        for rec, comp in zip(recs, compute_each):
            t = rec.task
            items.append(TimedTask(
                task_id=t.task_id,
                name=f"{t.routine} C[{t.i},{t.j}]",
                compute_s=comp, fetches=rec.xfers, writeback=rec.wb,
                routine=t.routine, steps=len(t.steps), flops=t.flops,
                kind=t.kind, parent=t.parent))
        span, finishes, busy = self._engine.schedule_batch(
            d.id, start, items, self.cfg.effective_streams,
            self.cfg.overlap)
        led = d.ledger
        led.h2d_busy_s += busy["h2d"]
        led.d2d_busy_s += busy["d2d"]
        led.d2h_busy_s += busy["d2h"]
        led.ici_busy_s += busy["ici"]
        # Fig. 8 "COMM": batch span not covered by an equal amount of
        # compute — the generalization of the lump model's
        # max(0, comm - compute) to a multi-stream schedule.  Capped at
        # the batch's own link seconds: span beyond that is contention
        # *waiting* (Fig. 8 "OTHER"), not data movement.
        led.unoverlapped_comm += min(comm_s, max(0.0, span - compute_s))
        return span, finishes

    def _xfer_secs(self, kind: str, nbytes: int) -> float:
        """Modeled seconds for one transfer.  The event engine charges
        full link bandwidth — host-link contention emerges from
        serialization on the shared lane; the lump model (and threads
        mode) keeps the seed per-device bandwidth divide."""
        if kind == "d2d":
            return nbytes / self.cfg.d2d_bw
        if kind == "ici":
            # every ICI movement charges exactly nbytes/ici_bw, so the
            # events engine's ici_busy_s == ici_bytes/ici_bw holds by
            # construction (the pod bench gates this invariant)
            return nbytes / self.cfg.ici_bw
        if self._engine is not None:
            return nbytes / self.cfg.h2d_bw
        return nbytes / self.cfg.h2d_bw_eff

    def _gather_task(self, d: DeviceSim, t: Task,
                     acquired: List[TileKey]) -> Tuple["_TaskExec", float]:
        """Phase 1: pull every input tile of one task through the cache
        hierarchy (ledger-charged) and materialize it for compute.
        Every charged movement is also recorded on ``rec.xfers`` — the
        event engine's per-fetch raw material."""
        comm_s = 0.0
        rec = _TaskExec(task=t, a_tiles=[], b_tiles=[],
                        products=[None] * len(t.steps))
        # pod tier: a mesh_shard fix-up is a streaming ring-reduce over
        # the panels its partials staged — it reads each tile once, so
        # caching them would only displace the warm panels other tasks
        # are reusing.  Stream the re-gather through the bypass path
        # (own HBM free, peer ring over ICI, host as last resort)
        # instead of the ALRU.  Accelerator-class fix-ups keep the
        # caching gather (bit-and-timing parity with PR 9).
        streaming = t.kind == KIND_FIXUP and self.cfg.dclass.ring
        for step in t.steps:
            if streaming:
                a, s1 = self._bypass_read(d, step.a, rec.xfers)
                b, s2 = self._bypass_read(d, step.b, rec.xfers)
            else:
                a, s1 = self._acquire(d, step.a, acquired, rec.xfers)
                b, s2 = self._acquire(d, step.b, acquired, rec.xfers)
            comm_s += s1 + s2
            rec.a_tiles.append(a)
            rec.b_tiles.append(b)
        if t.finalize is not None:  # TRSM
            rec.diag, s1 = self._acquire(d, t.finalize.diag_ref, acquired,
                                         rec.xfers)
            rec.rhs, s2 = self._bypass_read(d, t.finalize.rhs_ref,
                                            rec.xfers)
            comm_s += s1 + s2
        elif t.read_c is not None:
            rec.cin, s3 = self._bypass_read(d, t.read_c, rec.xfers)
            comm_s += s3
        return rec, comm_s

    def _step_key(self, t: Task, step, a: np.ndarray, b: np.ndarray,
                  steps: int = 1) -> StepGroupKey:
        return StepGroupKey(
            op=t.routine, transa=step.a.trans, transb=step.b.trans,
            fill_a=step.a.fill, fill_b=step.b.fill,
            m=a.shape[0], k=a.shape[1], n=b.shape[1],
            dtype=str(promote_dtypes(a.dtype, b.dtype)), steps=steps)

    def _dispatch_steps(self, d: DeviceSim, recs: List["_TaskExec"]) -> None:
        """Phase 2: one backend call per same-signature group.

        A task whose k-steps all share one signature (the common case:
        every interior tile of GEMM/SYRK/TRSM sweeps) is dispatched as
        a single *item* — its whole k-loop contracts inside the backend
        (``acc = sum_j a_j @ b_j``), so same-shape tasks in the batch
        become one work-centric batched call.  Mixed-signature tasks
        (SYMM/TRMM diagonal fills, ragged edge tiles) degrade to
        per-step items within their signature groups."""
        task_groups: Dict[StepGroupKey, List[_TaskExec]] = {}
        step_groups: Dict[StepGroupKey, List[Tuple[_TaskExec, int]]] = {}
        for rec in recs:
            t = rec.task
            if not t.steps or t.kind == KIND_PARTIAL:
                # a partial-k task only prefetches and models compute;
                # its fix-up re-dispatches the whole k-loop through
                # this very path, so skipping here keeps launch counts
                # and numerics identical to owner mode
                continue
            keys = [self._step_key(t, step, rec.a_tiles[i], rec.b_tiles[i])
                    for i, step in enumerate(t.steps)]
            if len(set(keys)) == 1:
                key = dataclasses.replace(keys[0], steps=len(t.steps))
                task_groups.setdefault(key, []).append(rec)
            else:
                for i, key in enumerate(keys):
                    step_groups.setdefault(key, []).append((rec, i))
        led = d.ledger
        for key, t_recs in task_groups.items():
            res = self.backend.run_group(
                key, [a for r in t_recs for a in r.a_tiles],
                [b for r in t_recs for b in r.b_tiles])
            n_steps = key.steps * len(t_recs)
            led.batched_groups += 1
            led.batched_steps += n_steps
            led.kernel_launches += res.launches
            led.engine_flops[res.engine] = (
                led.engine_flops.get(res.engine, 0)
                + key.flops_per_item * len(t_recs))
            for rec, acc in zip(t_recs, res.products):
                rec.acc = acc
        for key, entries in step_groups.items():
            res = self.backend.run_group(
                key, [r.a_tiles[i] for r, i in entries],
                [r.b_tiles[i] for r, i in entries])
            led.batched_groups += 1
            led.batched_steps += len(entries)
            led.kernel_launches += res.launches
            led.engine_flops[res.engine] = (
                led.engine_flops.get(res.engine, 0)
                + key.flops_per_item * len(entries))
            for (rec, idx), prod in zip(entries, res.products):
                rec.products[idx] = prod

    def _finalize_task(self, d: DeviceSim, rec: "_TaskExec") -> float:
        """Phase 3: per-task epilogue + write-back; returns comm secs."""
        t = rec.task
        if t.kind == KIND_PARTIAL:
            # the sibling fix-up performs the owner-identical numerics
            # and the ONLY write of C_ij: partials never touch the
            # coherence directory and spill no accumulator (the modeled
            # join traffic is the fix-up's re-gather of the k-range
            # tiles the partials left warm in peer L1s)
            return 0.0
        out_grid = self._matrices[self._out_id]
        comm_s = 0.0
        if self.cfg.execute:
            acc: Optional[np.ndarray] = rec.acc
            if acc is None:
                for prod in rec.products:  # original k-step order
                    acc = prod if acc is None else acc + prod
            if acc is None:
                h, w = out_grid.grid.tile_shape(t.i, t.j)
                acc = np.zeros((h, w), dtype=out_grid.data.dtype)
            if t.finalize is not None:  # TRSM
                result = self._solver(rec.diag, t.alpha * rec.rhs - acc,
                                      lower=t.finalize.lower,
                                      unit_diag=t.finalize.unit_diag)
            else:
                result = t.alpha * acc
                if rec.cin is not None:
                    result = result + t.beta * rec.cin
            if t.out_mask is not None:
                # diagonal SYRK/SYR2K tile: only the uplo triangle is written
                orig = out_grid.read_tile(t.i, t.j)
                if t.out_mask == "tri_u":
                    result = np.triu(result) + np.tril(orig, -1)
                else:
                    result = np.tril(result) + np.triu(orig, 1)
        # MESI-X ephemeral M: write back to host immediately, invalidate
        # any cached copies, transition to I (Fig. 3).
        for holder in self.directory.on_write(t.out, d.id):
            self.devices[holder].alru.invalidate(t.out)
        if self.cfg.execute:
            out_grid.write_tile(t.i, t.j, result.astype(out_grid.data.dtype))
        wb = out_grid.nbytes(t.i, t.j)
        d.ledger.d2h_bytes += wb
        secs = self._xfer_secs("d2h", wb)
        rec.wb = TimedXfer("d2h", wb, secs, _tile_label(t.out))
        comm_s += secs
        return comm_s

    # ------------------------------------------------------ data movement
    def _acquire(self, d: DeviceSim, ref: TileRef, acquired: List[TileKey],
                 xfers: List[TimedXfer]) -> Tuple[np.ndarray, float]:
        """Fetch a cacheable input tile through the 2-level tile cache.
        Every charged movement is appended to ``xfers`` (cache hits add
        nothing — they cost no link time)."""
        key = ref.key
        mat = self._matrices[key.matrix_id]
        nbytes = mat.nbytes(key.i, key.j)
        if not self.cfg.use_cache:
            data, secs = self._bypass_read(d, ref, xfers)
            return data, secs

        block = d.alru.translate(key, nbytes, owner=self._tenant)
        if block is None:
            # every cached block pinned: degrade to an uncached read
            data, secs = self._bypass_read(d, ref, xfers)
            return data, secs
        acquired.append(key)
        secs = 0.0
        if getattr(block, "fresh", False):
            block.fresh = False
            peer = (self.directory.peer_holder(key, d.id)
                    if self.cfg.use_l2 else None)
            payload = None
            if peer is not None:
                payload = self.devices[peer].store.get(key)
            if payload is not None:  # L2 tile-cache hit: P2P fetch
                # pod tier: between mesh_shard devices the peer link IS
                # the ICI fabric — L2 serves ride it at ici_bw and are
                # ledgered as ici_bytes (d2d stays the PCIe-P2P lane of
                # plain accelerators), keeping the comm decomposition
                # exact per device class
                kind = "ici" if self.cfg.dclass.ring else "d2d"
                if kind == "ici":
                    d.ledger.ici_bytes += nbytes
                else:
                    d.ledger.d2d_bytes += nbytes
                secs = self._xfer_secs(kind, nbytes)
                xfers.append(TimedXfer(kind, nbytes, secs,
                                       _tile_label(key), src=peer))
                # egress accounting + LRU rotation on the SERVING side:
                # the peer's lane is the one being drained, and marking
                # the serve is what spreads the next hit to its
                # least-recently-used group mate.  The charge targets
                # ANOTHER device's ledger, so in threads mode it must
                # not race that device's own read-modify-writes.
                srv = self.devices[peer]
                with srv.serve_lock:
                    srv.ledger.d2d_served_s += secs
                self.directory.mark_served(peer)
            else:                    # miss in both levels: host fetch
                payload = (mat.read_tile(key.i, key.j).copy()
                           if self.cfg.execute else _METADATA_ONLY)
                d.ledger.h2d_bytes += nbytes
                secs = self._xfer_secs("h2d", nbytes)
                xfers.append(TimedXfer("h2d", nbytes, secs,
                                       _tile_label(key)))
                secs += self._ring_hop(d, key, nbytes, xfers)
            d.store[key] = payload
            self.directory.on_fill(key, d.id)
        data = d.store.get(key)
        if data is None:  # extremely unlikely: evicted between ops
            data = mat.read_tile(key.i, key.j).copy() if self.cfg.execute \
                else _METADATA_ONLY
            d.ledger.h2d_bytes += nbytes
            s2 = self._xfer_secs("h2d", nbytes)
            xfers.append(TimedXfer("h2d", nbytes, s2, _tile_label(key)))
            s2 += self._ring_hop(d, key, nbytes, xfers)
            secs += s2
        if not self.cfg.execute:
            return data, secs
        return materialize(data, ref), secs

    def _ring_hop(self, d: DeviceSim, key: TileKey, nbytes: int,
                  xfers: List[TimedXfer]) -> float:
        """Pod tier: a fresh host panel landing on a mesh_shard device
        must be scattered across its ICI ring (each shard forwards
        (mesh-1)/mesh of the bytes — ring_allgather_matmul's ppermute
        traffic).  Charged once per host fill; warm cache hits and
        plain accelerators pay nothing."""
        hop = self.cfg.dclass.hop_bytes(nbytes, self.cfg.mesh_devices)
        if hop <= 0:
            return 0.0
        d.ledger.ici_bytes += hop
        secs = self._xfer_secs("ici", hop)
        xfers.append(TimedXfer("ici", hop, secs, _tile_label(key)))
        return secs

    def _bypass_read(self, d: DeviceSim, ref: TileRef,
                     xfers: List[TimedXfer]) -> Tuple[np.ndarray, float]:
        """Uncached read (C_ij inputs / no-cache policies / pinned-full
        ALRU).  On a mesh_shard device with the L2 directory live this
        is where the cache hierarchy's THIRD level pays off: if a peer
        ring holds the tile (a staging partial left the panel warm in
        its L1), serve it over ICI at ``ici_bw`` instead of re-reading
        host DRAM — the fix-up join of a beyond-HBM task re-gathers its
        whole k-loop through this path."""
        key = ref.key
        mat = self._matrices[key.matrix_id]
        nbytes = mat.nbytes(key.i, key.j)
        if self.cfg.dclass.ring and self.cfg.use_l2:
            payload = d.store.get(key)
            if payload is not None:  # already in this ring's own HBM
                if not self.cfg.execute:
                    return _METADATA_ONLY, 0.0
                return materialize(payload, ref), 0.0
            peer = self.directory.peer_holder(key, d.id)
            payload = (self.devices[peer].store.get(key)
                       if peer is not None else None)
            if payload is not None:  # neighbor-tier (ICI) hit
                d.ledger.ici_bytes += nbytes
                secs = self._xfer_secs("ici", nbytes)
                xfers.append(TimedXfer("ici", nbytes, secs,
                                       _tile_label(key), src=peer))
                self.directory.mark_served(peer)
                if not self.cfg.execute:
                    return _METADATA_ONLY, secs
                return materialize(payload, ref), secs
        d.ledger.h2d_bytes += nbytes
        secs = self._xfer_secs("h2d", nbytes)
        xfers.append(TimedXfer("h2d", nbytes, secs, _tile_label(key)))
        secs += self._ring_hop(d, key, nbytes, xfers)
        if not self.cfg.execute:
            return _METADATA_ONLY, secs
        return materialize(mat.read_tile(key.i, key.j), ref), secs

    # ----------------------------------------------------------- sessions
    def set_tenant_quota(self, tenant: str, nbytes: Optional[int]) -> None:
        """Cap ``tenant``'s resident ALRU bytes on every device (None
        removes the cap).  While any quota is configured the caches
        refuse cross-tenant eviction — a flooding tenant recycles its
        own blocks instead of another tenant's warm set."""
        if nbytes is None:
            self._tenant_quotas.pop(tenant, None)
        else:
            self._tenant_quotas[tenant] = int(nbytes)
        for d in self.devices:
            d.alru.set_quota(tenant, nbytes)

    def reset(self) -> None:
        """Cold restart: drop every cached tile, rebuild the coherence
        directory, zero all ledgers and clocks.  The next ``run`` pays
        full H2D traffic again."""
        self.directory = MesixDirectory(self.cfg.n_devices,
                                        self.cfg.p2p_groups)
        self.devices = [DeviceSim(d, self.cfg, self.directory)
                        for d in range(self.cfg.n_devices)]
        self.runs = 0
        for tenant, nbytes in self._tenant_quotas.items():
            for d in self.devices:
                d.alru.set_quota(tenant, nbytes)
        if self._engine is not None:  # fresh timelines and trace
            self._engine = EventEngine(self.cfg)

    def reset_stats(self) -> None:
        """Zero ledgers and cache counters *without* evicting anything —
        session-boundary accounting for long-lived runtimes.  Device
        clocks are kept (they order the sim's virtual time); use the
        deltas of :meth:`makespan` across calls."""
        for d in self.devices:
            d.ledger = Ledger()
            d.alru.reset_stats()
        self.directory.writebacks = 0
        self.directory.invalidations = 0

    # --------------------------------------------------------------- stats
    def stats(self) -> Dict[str, Dict[str, float]]:
        out = {}
        for d in self.devices:
            led = dataclasses.asdict(d.ledger)
            led.update(l1_hits=d.alru.hits, l1_misses=d.alru.misses,
                       evictions=d.alru.evictions,
                       quota_evictions=d.alru.quota_evictions,
                       cache_used=d.heap.used, clock=d.clock,
                       overlap_efficiency=d.ledger.overlap_efficiency)
            out[f"device{d.id}"] = led
        return out

    def trace(self) -> dict:
        """Chrome-trace (chrome://tracing / Perfetto) JSON of every sim
        batch scheduled so far: one process per device, one thread per
        stream/link lane, balanced B/E spans (see
        ``repro.core.events``).  The trace accumulates across ``run``
        calls of a session; ``reset()`` starts a fresh one.  Outside
        the event engine (threads mode / ``time_model="lump"``) the
        trace is valid but empty."""
        from .events import build_chrome_trace
        extra = {
            "policy": self.cfg.policy,
            "backend": self.cfg.backend,
            "time_model": self.cfg.time_model,
            "mode": self.cfg.mode,
            "makespan_s": self.makespan(),
        }
        if self._engine is None:
            return build_chrome_trace([], self.cfg.n_devices,
                                      self.cfg.effective_streams,
                                      extra=extra)
        return self._engine.chrome_trace(extra=extra)

    def launch_stats(self) -> Dict[str, object]:
        """Batched-dispatch accounting across devices: how many k-steps
        ran, how many kernel launches they cost, and which engine did
        the flops — the bench lane's ``launches saved`` source."""
        engine_flops: Dict[str, int] = {}
        for d in self.devices:
            for eng, fl in d.ledger.engine_flops.items():
                engine_flops[eng] = engine_flops.get(eng, 0) + fl
        steps = sum(d.ledger.batched_steps for d in self.devices)
        launches = sum(d.ledger.kernel_launches for d in self.devices)
        return {
            "backend": self.cfg.backend,
            "tasks": sum(d.ledger.tasks for d in self.devices),
            "steps": steps,
            "groups": sum(d.ledger.batched_groups for d in self.devices),
            "kernel_launches": launches,
            "launches_saved": steps - launches,
            "engine_flops": engine_flops,
        }

    def total_comm_bytes(self) -> Dict[str, int]:
        return {
            "h2d": sum(d.ledger.h2d_bytes for d in self.devices),
            "d2h": sum(d.ledger.d2h_bytes for d in self.devices),
            "d2d": sum(d.ledger.d2d_bytes for d in self.devices),
            "ici": sum(d.ledger.ici_bytes for d in self.devices),
        }

    def makespan(self) -> float:
        """Sim-mode modeled wall time (max device clock)."""
        return max((d.clock for d in self.devices), default=0.0)
