"""Legacy numpy-in/numpy-out level-3 BLAS API (paper §III/§IV).

This is the compatibility surface of the two-layer API design: each of
the six L3 routines is a thin wrapper over a persistent
``repro.api.BlasxContext``.  By default calls go through one
module-cached context (``repro.api.default_context()``), so the
runtime and its ALRU/MESI-X tile caches are built once per process —
not per call.  ``config=`` runs a call on a fresh, private runtime;
``runtime=`` adopts an existing one (ledgers accumulate on it).

``side='R'`` cases reduce to the native left-side tile algorithms via
the transpose identities (op(A)^T X^T = alpha B^T), mirroring the
paper's §III-C trick at matrix granularity — the reduction happens
inside the context methods.

``tile=`` accepts an int (default 256) or ``"auto"``: the latter
resolves the tile size through the runtime autotuner
(``repro.tuning``) per (routine, shape bucket, dtype) — the sweep runs
once on the virtual clock and every later call is a tuning-cache hit.

Every routine also has a ``ref_*`` oracle (pure numpy) used by the
test suite and benchmarks.  For handle-based chaining, async
submission and the CBLAS layer, use ``repro.api`` directly.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from . import task as taskmod
from .runtime import BlasxRuntime, RuntimeConfig

DEFAULT_TILE = 256


def _finish(out) -> np.ndarray:
    """Extract the result array and drop the discarded output handle's
    cached tiles (TRSM/TRMM chains cache output tiles as step inputs;
    legacy callers never reuse the handle, so they'd be dead weight)."""
    data = out.array()
    out.invalidate()
    return data


def _context(config: Optional[RuntimeConfig],
             runtime: Optional[BlasxRuntime],
             backend: Optional[str] = None,
             device_class: Optional[str] = None,
             mesh: Optional[int] = None):
    """Resolve the executing context for one legacy call.

    ``backend`` selects the execution backend (numpy | jax | pallas)
    for this call; with ``runtime=`` it must match the runtime's own.
    ``device_class``/``mesh`` select the pod tier (a private context is
    built for the call — they cannot be combined with ``runtime=``).

    Imported lazily: ``repro.api`` depends on ``repro.core`` modules,
    so the dependency must point api -> core at import time."""
    from ..api.context import (BlasxContext, backend_context,
                               default_context)

    if device_class is not None or mesh is not None:
        return BlasxContext(config, backend=backend, runtime=runtime,
                            device_class=device_class, mesh=mesh)
    if runtime is not None:
        return BlasxContext(runtime=runtime, backend=backend)
    if config is not None:
        return BlasxContext(config, backend=backend)
    if backend is not None:
        # module-cached warm context per backend (mirrors the default)
        return backend_context(backend)
    return default_context()


# ============================================================== GEMM (1a)
def gemm(A, B, C=None, *, alpha=1.0, beta=0.0, transa="N", transb="N",
         tile=DEFAULT_TILE, config: Optional[RuntimeConfig] = None,
         runtime: Optional[BlasxRuntime] = None,
         backend: Optional[str] = None, dtype=None,
         device_class: Optional[str] = None,
         mesh: Optional[int] = None) -> np.ndarray:
    ctx = _context(config, runtime, backend, device_class, mesh)
    return _finish(ctx.gemm(A, B, C, alpha=alpha, beta=beta,
                            transa=transa, transb=transb, tile=tile,
                            dtype=dtype))


# ============================================================== SYRK (1b)
def syrk(A, C=None, *, alpha=1.0, beta=0.0, uplo="U", trans="N",
         tile=DEFAULT_TILE, config: Optional[RuntimeConfig] = None,
         runtime: Optional[BlasxRuntime] = None,
         backend: Optional[str] = None, dtype=None,
         device_class: Optional[str] = None,
         mesh: Optional[int] = None) -> np.ndarray:
    ctx = _context(config, runtime, backend, device_class, mesh)
    return _finish(ctx.syrk(A, C, alpha=alpha, beta=beta, uplo=uplo,
                            trans=trans, tile=tile, dtype=dtype))


# ============================================================= SYR2K (1e)
def syr2k(A, B, C=None, *, alpha=1.0, beta=0.0, uplo="U", trans="N",
          tile=DEFAULT_TILE, config: Optional[RuntimeConfig] = None,
          runtime: Optional[BlasxRuntime] = None,
          backend: Optional[str] = None, dtype=None,
          device_class: Optional[str] = None,
          mesh: Optional[int] = None) -> np.ndarray:
    ctx = _context(config, runtime, backend, device_class, mesh)
    return _finish(ctx.syr2k(A, B, C, alpha=alpha, beta=beta, uplo=uplo,
                             trans=trans, tile=tile, dtype=dtype))


# ============================================================== SYMM (1f)
def symm(A, B, C=None, *, alpha=1.0, beta=0.0, side="L", uplo="U",
         tile=DEFAULT_TILE, config: Optional[RuntimeConfig] = None,
         runtime: Optional[BlasxRuntime] = None,
         backend: Optional[str] = None, dtype=None,
         device_class: Optional[str] = None,
         mesh: Optional[int] = None) -> np.ndarray:
    ctx = _context(config, runtime, backend, device_class, mesh)
    return _finish(ctx.symm(A, B, C, alpha=alpha, beta=beta, side=side,
                            uplo=uplo, tile=tile, dtype=dtype))


# ============================================================== TRMM (1d)
def trmm(A, B, *, alpha=1.0, side="L", uplo="U", transa="N", diag="N",
         tile=DEFAULT_TILE, config: Optional[RuntimeConfig] = None,
         runtime: Optional[BlasxRuntime] = None,
         backend: Optional[str] = None, dtype=None,
         device_class: Optional[str] = None,
         mesh: Optional[int] = None) -> np.ndarray:
    ctx = _context(config, runtime, backend, device_class, mesh)
    return _finish(ctx.trmm(A, B, alpha=alpha, side=side, uplo=uplo,
                            transa=transa, diag=diag, tile=tile,
                            dtype=dtype))


# ============================================================== TRSM (1c)
def trsm(A, B, *, alpha=1.0, side="L", uplo="U", transa="N", diag="N",
         tile=DEFAULT_TILE, config: Optional[RuntimeConfig] = None,
         runtime: Optional[BlasxRuntime] = None,
         backend: Optional[str] = None, dtype=None,
         device_class: Optional[str] = None,
         mesh: Optional[int] = None) -> np.ndarray:
    ctx = _context(config, runtime, backend, device_class, mesh)
    return _finish(ctx.trsm(A, B, alpha=alpha, side=side, uplo=uplo,
                            transa=transa, diag=diag, tile=tile,
                            dtype=dtype))


# ==================================================== paper-scale shadows
def shadow_run(routine: str, n: int, *, tile: int,
               runtime: BlasxRuntime, k: Optional[int] = None,
               uplo: str = "U", beta: float = 1.0,
               dtype="float64") -> BlasxRuntime:
    """Metadata-only run of one L3 routine on square N (A/B/C all NxN,
    SYRK/SYR2K inner dim ``k`` or N).  Requires a runtime configured
    with ``execute=False``.  ``dtype`` sets the storage precision the
    byte accounting models.  Returns the runtime (ledgers populated)."""
    from .dtypes import canonical_dtype
    from .tiling import ShadowMatrix

    if runtime.cfg.execute:
        raise ValueError("shadow_run needs RuntimeConfig(execute=False)")
    dt = canonical_dtype(dtype)
    k = k or n
    mats = {
        "A": ShadowMatrix("A", n, k if routine in ("syrk", "syr2k") else n,
                          tile, dtype=dt),
        "B": ShadowMatrix("B", n, k if routine == "syr2k" else n, tile,
                          dtype=dt),
        "Cin": ShadowMatrix("Cin", n, n, tile, dtype=dt),
        "C": ShadowMatrix("C", n, n, tile, dtype=dt),
    }
    g = {m.matrix_id: m.grid for m in mats.values()}
    if routine == "gemm":
        tasks = taskmod.taskize_gemm(g["A"], g["B"], g["C"], "N", "N",
                                     1.0, beta)
    elif routine == "syrk":
        tasks = taskmod.taskize_syrk(g["A"], g["C"], uplo, "N", 1.0, beta)
    elif routine == "syr2k":
        tasks = taskmod.taskize_syr2k(g["A"], g["B"], g["C"], uplo, "N",
                                      1.0, beta)
    elif routine == "symm":
        tasks = taskmod.taskize_symm(g["A"], g["B"], g["C"], uplo, 1.0, beta)
    elif routine == "trmm":
        tasks = taskmod.taskize_trmm(g["A"], g["Cin"], g["C"], uplo, "N",
                                     "N", 1.0)
    elif routine == "trsm":
        tasks = taskmod.taskize_trsm(g["A"], g["B"], g["C"], uplo, "N",
                                     "N", 1.0)
    else:
        raise ValueError(routine)
    runtime.run(tasks, mats, "C")
    return runtime


# ====================================================== reference oracles
def ref_gemm(A, B, C=None, *, alpha=1.0, beta=0.0, transa="N", transb="N"):
    opa = A if transa.upper()[0] == "N" else A.T
    opb = B if transb.upper()[0] == "N" else B.T
    out = alpha * (opa @ opb)
    if C is not None and beta != 0.0:
        out = out + beta * C
    return out


def _sym(A, uplo):
    if uplo.upper()[0] == "U":
        return np.triu(A) + np.triu(A, 1).T
    return np.tril(A) + np.tril(A, -1).T


def _tri(A, uplo, diag):
    t = np.triu(A) if uplo.upper()[0] == "U" else np.tril(A)
    if diag.upper()[0] == "U":
        np.fill_diagonal(t, 1.0)
    return t


def _uplo_update(full, C, beta, uplo):
    """BLAS triangle semantics shared by SYRK/SYR2K: write
    ``full + beta*C`` into the ``uplo`` triangle, keep the original C
    (or zeros) elsewhere."""
    n = full.shape[0]
    base = np.zeros((n, n), full.dtype) if C is None else beta * np.asarray(C)
    out = np.array(np.zeros((n, n), full.dtype) if C is None
                   else np.asarray(C), dtype=full.dtype, copy=True)
    mask = np.triu(np.ones((n, n), bool)) if uplo.upper()[0] == "U" \
        else np.tril(np.ones((n, n), bool))
    out[mask] = (full + base)[mask]
    return out


def ref_syrk(A, C=None, *, alpha=1.0, beta=0.0, uplo="U", trans="N"):
    full = alpha * (A @ A.T if trans.upper()[0] == "N" else A.T @ A)
    return _uplo_update(full, C, beta, uplo)


def ref_syr2k(A, B, C=None, *, alpha=1.0, beta=0.0, uplo="U", trans="N"):
    if trans.upper()[0] == "N":
        full = alpha * (A @ B.T) + alpha * (B @ A.T)
    else:
        full = alpha * (A.T @ B) + alpha * (B.T @ A)
    return _uplo_update(full, C, beta, uplo)


def ref_symm(A, B, C=None, *, alpha=1.0, beta=0.0, side="L", uplo="U"):
    sa = _sym(A, uplo)
    prod = sa @ B if side.upper()[0] == "L" else B @ sa
    out = alpha * prod
    if C is not None and beta != 0.0:
        out = out + beta * np.asarray(C)
    return out


def ref_trmm(A, B, *, alpha=1.0, side="L", uplo="U", transa="N", diag="N"):
    ta = _tri(A, uplo, diag)
    opa = ta if transa.upper()[0] == "N" else ta.T
    return alpha * (opa @ B if side.upper()[0] == "L" else B @ opa)


def ref_trsm(A, B, *, alpha=1.0, side="L", uplo="U", transa="N", diag="N"):
    ta = _tri(A, uplo, diag)
    opa = ta if transa.upper()[0] == "N" else ta.T
    if side.upper()[0] == "L":
        return np.linalg.solve(opa, alpha * np.asarray(B))
    return np.linalg.solve(opa.T, alpha * np.asarray(B).T).T
