"""Public level-3 BLAS API (paper §III/§IV) — backward compatible, tiled,
executed by the BLASX runtime.

All six L3 routines are provided with numpy-array in/out semantics so
legacy BLAS callers can switch by changing an import (the paper's
"backward compatibility" goal).  ``side='R'`` cases are reduced to the
native left-side tile algorithms via the transpose identities
(op(A)^T X^T = alpha B^T), mirroring the paper's §III-C trick at matrix
granularity.

Every routine also has a ``ref_*`` oracle (pure numpy) used by the test
suite and benchmarks.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from . import task as taskmod
from .runtime import BlasxRuntime, RuntimeConfig
from .tiling import TiledMatrix

DEFAULT_TILE = 256


def _as2d(x, name):
    a = np.asarray(x)
    if a.ndim != 2:
        raise ValueError(f"{name} must be 2-D, got shape {a.shape}")
    return a


def _runtime(config: Optional[RuntimeConfig]) -> BlasxRuntime:
    return BlasxRuntime(config or RuntimeConfig(n_devices=1, mode="sim"))


def _grids(mats: Dict[str, TiledMatrix]):
    return {k: m.grid for k, m in mats.items()}


# ============================================================== GEMM (1a)
def gemm(A, B, C=None, *, alpha=1.0, beta=0.0, transa="N", transb="N",
         tile=DEFAULT_TILE, config: Optional[RuntimeConfig] = None,
         runtime: Optional[BlasxRuntime] = None) -> np.ndarray:
    A, B = _as2d(A, "A"), _as2d(B, "B")
    transa, transb = transa.upper()[0], transb.upper()[0]
    m = A.shape[0] if transa == "N" else A.shape[1]
    k = A.shape[1] if transa == "N" else A.shape[0]
    kb = B.shape[0] if transb == "N" else B.shape[1]
    n = B.shape[1] if transb == "N" else B.shape[0]
    if k != kb:
        raise ValueError(f"inner dims mismatch: {k} vs {kb}")
    if C is None:
        if beta != 0.0:
            raise ValueError("beta != 0 requires C")
        C = np.zeros((m, n), dtype=np.promote_types(A.dtype, B.dtype))
    C = np.array(_as2d(C, "C"), copy=True)
    if C.shape != (m, n):
        raise ValueError(f"C shape {C.shape} != ({m},{n})")

    mats = {
        "A": TiledMatrix("A", A, tile),
        "B": TiledMatrix("B", B, tile),
        "C": TiledMatrix("C", C, tile),
    }
    tasks = taskmod.taskize_gemm(mats["A"].grid, mats["B"].grid,
                                 mats["C"].grid, transa, transb, alpha, beta)
    rt = runtime or _runtime(config)
    rt.run(tasks, mats, "C")
    return mats["C"].data


# ============================================================== SYRK (1b)
def syrk(A, C=None, *, alpha=1.0, beta=0.0, uplo="U", trans="N",
         tile=DEFAULT_TILE, config: Optional[RuntimeConfig] = None,
         runtime: Optional[BlasxRuntime] = None) -> np.ndarray:
    A = _as2d(A, "A")
    trans = trans.upper()[0]
    n = A.shape[0] if trans == "N" else A.shape[1]
    if C is None:
        if beta != 0.0:
            raise ValueError("beta != 0 requires C")
        C = np.zeros((n, n), dtype=A.dtype)
    C = np.array(_as2d(C, "C"), copy=True)
    mats = {"A": TiledMatrix("A", A, tile), "C": TiledMatrix("C", C, tile)}
    tasks = taskmod.taskize_syrk(mats["A"].grid, mats["C"].grid,
                                 uplo, trans, alpha, beta)
    rt = runtime or _runtime(config)
    rt.run(tasks, mats, "C")
    return mats["C"].data


# ============================================================= SYR2K (1e)
def syr2k(A, B, C=None, *, alpha=1.0, beta=0.0, uplo="U", trans="N",
          tile=DEFAULT_TILE, config: Optional[RuntimeConfig] = None,
          runtime: Optional[BlasxRuntime] = None) -> np.ndarray:
    A, B = _as2d(A, "A"), _as2d(B, "B")
    trans = trans.upper()[0]
    n = A.shape[0] if trans == "N" else A.shape[1]
    if C is None:
        if beta != 0.0:
            raise ValueError("beta != 0 requires C")
        C = np.zeros((n, n), dtype=np.promote_types(A.dtype, B.dtype))
    C = np.array(_as2d(C, "C"), copy=True)
    mats = {"A": TiledMatrix("A", A, tile), "B": TiledMatrix("B", B, tile),
            "C": TiledMatrix("C", C, tile)}
    tasks = taskmod.taskize_syr2k(mats["A"].grid, mats["B"].grid,
                                  mats["C"].grid, uplo, trans, alpha, beta)
    rt = runtime or _runtime(config)
    rt.run(tasks, mats, "C")
    return mats["C"].data


# ============================================================== SYMM (1f)
def symm(A, B, C=None, *, alpha=1.0, beta=0.0, side="L", uplo="U",
         tile=DEFAULT_TILE, config: Optional[RuntimeConfig] = None,
         runtime: Optional[BlasxRuntime] = None) -> np.ndarray:
    side = side.upper()[0]
    A, B = _as2d(A, "A"), _as2d(B, "B")
    if side == "R":
        # C = alpha*B*A + beta*C  ==  (alpha*A*B^T + beta*C^T)^T
        Ct = None if C is None else np.ascontiguousarray(_as2d(C, "C").T)
        out = symm(A, np.ascontiguousarray(B.T), Ct, alpha=alpha, beta=beta,
                   side="L", uplo=uplo, tile=tile, config=config,
                   runtime=runtime)
        return np.ascontiguousarray(out.T)
    m, n = B.shape
    if A.shape != (m, m):
        raise ValueError(f"A must be ({m},{m}), got {A.shape}")
    if C is None:
        if beta != 0.0:
            raise ValueError("beta != 0 requires C")
        C = np.zeros((m, n), dtype=np.promote_types(A.dtype, B.dtype))
    C = np.array(_as2d(C, "C"), copy=True)
    mats = {"A": TiledMatrix("A", A, tile), "B": TiledMatrix("B", B, tile),
            "C": TiledMatrix("C", C, tile)}
    tasks = taskmod.taskize_symm(mats["A"].grid, mats["B"].grid,
                                 mats["C"].grid, uplo, alpha, beta)
    rt = runtime or _runtime(config)
    rt.run(tasks, mats, "C")
    return mats["C"].data


# ============================================================== TRMM (1d)
def trmm(A, B, *, alpha=1.0, side="L", uplo="U", transa="N", diag="N",
         tile=DEFAULT_TILE, config: Optional[RuntimeConfig] = None,
         runtime: Optional[BlasxRuntime] = None) -> np.ndarray:
    side = side.upper()[0]
    A, B = _as2d(A, "A"), _as2d(B, "B")
    if side == "R":
        # B := alpha * B * op(A)  ==  (alpha * op(A)^T * B^T)^T
        flip = "T" if transa.upper()[0] == "N" else "N"
        out = trmm(A, np.ascontiguousarray(B.T), alpha=alpha, side="L",
                   uplo=uplo, transa=flip, diag=diag, tile=tile,
                   config=config, runtime=runtime)
        return np.ascontiguousarray(out.T)
    m, n = B.shape
    if A.shape != (m, m):
        raise ValueError(f"A must be ({m},{m}), got {A.shape}")
    cin = np.array(B, copy=True)   # snapshot: tasks read Cin, write C
    cout = np.zeros_like(cin)
    mats = {"A": TiledMatrix("A", A, tile),
            "Cin": TiledMatrix("Cin", cin, tile),
            "C": TiledMatrix("C", cout, tile)}
    tasks = taskmod.taskize_trmm(mats["A"].grid, mats["Cin"].grid,
                                 mats["C"].grid, uplo, transa, diag, alpha)
    rt = runtime or _runtime(config)
    rt.run(tasks, mats, "C")
    return mats["C"].data


# ============================================================== TRSM (1c)
def trsm(A, B, *, alpha=1.0, side="L", uplo="U", transa="N", diag="N",
         tile=DEFAULT_TILE, config: Optional[RuntimeConfig] = None,
         runtime: Optional[BlasxRuntime] = None) -> np.ndarray:
    side = side.upper()[0]
    A, B = _as2d(A, "A"), _as2d(B, "B")
    if side == "R":
        # solve X*op(A) = alpha*B  ==  op(A)^T X^T = alpha B^T
        flip = "T" if transa.upper()[0] == "N" else "N"
        out = trsm(A, np.ascontiguousarray(B.T), alpha=alpha, side="L",
                   uplo=uplo, transa=flip, diag=diag, tile=tile,
                   config=config, runtime=runtime)
        return np.ascontiguousarray(out.T)
    m, n = B.shape
    if A.shape != (m, m):
        raise ValueError(f"A must be ({m},{m}), got {A.shape}")
    x = np.zeros((m, n), dtype=np.promote_types(A.dtype, B.dtype))
    mats = {"A": TiledMatrix("A", A, tile), "B": TiledMatrix("B", B, tile),
            "C": TiledMatrix("C", x, tile)}
    tasks = taskmod.taskize_trsm(mats["A"].grid, mats["B"].grid,
                                 mats["C"].grid, uplo, transa, diag, alpha)
    rt = runtime or _runtime(config)
    rt.run(tasks, mats, "C")
    return mats["C"].data


# ==================================================== paper-scale shadows
def shadow_run(routine: str, n: int, *, tile: int,
               runtime: BlasxRuntime, k: Optional[int] = None,
               uplo: str = "U", beta: float = 1.0) -> BlasxRuntime:
    """Metadata-only run of one L3 routine on square N (A/B/C all NxN,
    SYRK/SYR2K inner dim ``k`` or N).  Requires a runtime configured
    with ``execute=False``.  Returns the runtime (ledgers populated)."""
    from .tiling import ShadowMatrix

    if runtime.cfg.execute:
        raise ValueError("shadow_run needs RuntimeConfig(execute=False)")
    k = k or n
    mats = {
        "A": ShadowMatrix("A", n, k if routine in ("syrk", "syr2k") else n,
                          tile),
        "B": ShadowMatrix("B", n, k if routine == "syr2k" else n, tile),
        "Cin": ShadowMatrix("Cin", n, n, tile),
        "C": ShadowMatrix("C", n, n, tile),
    }
    g = {m.matrix_id: m.grid for m in mats.values()}
    if routine == "gemm":
        tasks = taskmod.taskize_gemm(g["A"], g["B"], g["C"], "N", "N",
                                     1.0, beta)
    elif routine == "syrk":
        tasks = taskmod.taskize_syrk(g["A"], g["C"], uplo, "N", 1.0, beta)
    elif routine == "syr2k":
        tasks = taskmod.taskize_syr2k(g["A"], g["B"], g["C"], uplo, "N",
                                      1.0, beta)
    elif routine == "symm":
        tasks = taskmod.taskize_symm(g["A"], g["B"], g["C"], uplo, 1.0, beta)
    elif routine == "trmm":
        tasks = taskmod.taskize_trmm(g["A"], g["Cin"], g["C"], uplo, "N",
                                     "N", 1.0)
    elif routine == "trsm":
        tasks = taskmod.taskize_trsm(g["A"], g["B"], g["C"], uplo, "N",
                                     "N", 1.0)
    else:
        raise ValueError(routine)
    runtime.run(tasks, mats, "C")
    return runtime


# ====================================================== reference oracles
def ref_gemm(A, B, C=None, *, alpha=1.0, beta=0.0, transa="N", transb="N"):
    opa = A if transa.upper()[0] == "N" else A.T
    opb = B if transb.upper()[0] == "N" else B.T
    out = alpha * (opa @ opb)
    if C is not None and beta != 0.0:
        out = out + beta * C
    return out


def _sym(A, uplo):
    if uplo.upper()[0] == "U":
        return np.triu(A) + np.triu(A, 1).T
    return np.tril(A) + np.tril(A, -1).T


def _tri(A, uplo, diag):
    t = np.triu(A) if uplo.upper()[0] == "U" else np.tril(A)
    if diag.upper()[0] == "U":
        np.fill_diagonal(t, 1.0)
    return t


def ref_syrk(A, C=None, *, alpha=1.0, beta=0.0, uplo="U", trans="N"):
    full = alpha * (A @ A.T if trans.upper()[0] == "N" else A.T @ A)
    n = full.shape[0]
    base = np.zeros((n, n), full.dtype) if C is None else beta * np.asarray(C)
    out = np.array(np.zeros((n, n), full.dtype) if C is None else np.asarray(C),
                   dtype=full.dtype, copy=True)
    mask = np.triu(np.ones((n, n), bool)) if uplo.upper()[0] == "U" \
        else np.tril(np.ones((n, n), bool))
    out[mask] = (full + base)[mask]
    return out


def ref_syr2k(A, B, C=None, *, alpha=1.0, beta=0.0, uplo="U", trans="N"):
    if trans.upper()[0] == "N":
        full = alpha * (A @ B.T) + alpha * (B @ A.T)
    else:
        full = alpha * (A.T @ B) + alpha * (B.T @ A)
    n = full.shape[0]
    base = np.zeros((n, n), full.dtype) if C is None else beta * np.asarray(C)
    out = np.array(np.zeros((n, n), full.dtype) if C is None else np.asarray(C),
                   dtype=full.dtype, copy=True)
    mask = np.triu(np.ones((n, n), bool)) if uplo.upper()[0] == "U" \
        else np.tril(np.ones((n, n), bool))
    out[mask] = (full + base)[mask]
    return out


def ref_symm(A, B, C=None, *, alpha=1.0, beta=0.0, side="L", uplo="U"):
    sa = _sym(A, uplo)
    prod = sa @ B if side.upper()[0] == "L" else B @ sa
    out = alpha * prod
    if C is not None and beta != 0.0:
        out = out + beta * np.asarray(C)
    return out


def ref_trmm(A, B, *, alpha=1.0, side="L", uplo="U", transa="N", diag="N"):
    ta = _tri(A, uplo, diag)
    opa = ta if transa.upper()[0] == "N" else ta.T
    return alpha * (opa @ B if side.upper()[0] == "L" else B @ opa)


def ref_trsm(A, B, *, alpha=1.0, side="L", uplo="U", transa="N", diag="N"):
    ta = _tri(A, uplo, diag)
    opa = ta if transa.upper()[0] == "N" else ta.T
    if side.upper()[0] == "L":
        return np.linalg.solve(opa, alpha * np.asarray(B))
    return np.linalg.solve(opa.T, alpha * np.asarray(B).T).T
