"""Tile materialization + per-tile solver kernels for the runtime.

Fill modifiers realize triangular/symmetric *storage* semantics: stored
tiles are always dense, only the ``uplo`` triangle is meaningful, so we
mask/symmetrize on load (before the §III-C transpose trick).

Step *execution* moved to the pluggable backends in
``repro.backends`` (numpy | jax | pallas, batched per step group).
The TRSM finalize solver stays here — it runs per task on the host
either way.
"""
from __future__ import annotations

import numpy as np

from .task import (FILL_FULL, FILL_SYM_L, FILL_SYM_U, FILL_TRI_L,
                   FILL_TRI_LU, FILL_TRI_U, FILL_TRI_UU, TileRef)


def apply_fill(tile: np.ndarray, fill: str) -> np.ndarray:
    if fill == FILL_FULL:
        return tile
    if fill == FILL_SYM_U:
        u = np.triu(tile)
        return u + np.triu(tile, 1).T
    if fill == FILL_SYM_L:
        lo = np.tril(tile)
        return lo + np.tril(tile, -1).T
    if fill == FILL_TRI_U:
        return np.triu(tile)
    if fill == FILL_TRI_L:
        return np.tril(tile)
    if fill == FILL_TRI_UU:
        t = np.triu(tile, 1)
        return t + np.eye(tile.shape[0], tile.shape[1], dtype=tile.dtype)
    if fill == FILL_TRI_LU:
        t = np.tril(tile, -1)
        return t + np.eye(tile.shape[0], tile.shape[1], dtype=tile.dtype)
    raise ValueError(f"unknown fill {fill}")


def materialize(tile: np.ndarray, ref: TileRef) -> np.ndarray:
    out = apply_fill(tile, ref.fill)
    if ref.trans:
        out = out.T
    return out


# ------------------------------------------------------------ TRSM solver
def solve_triangular(a: np.ndarray, b: np.ndarray, lower: bool,
                     unit_diag: bool) -> np.ndarray:
    """Tile-level triangular solve for the TRSM finalize step."""
    import scipy.linalg  # local import; only TRSM needs it

    return scipy.linalg.solve_triangular(
        a, b, lower=lower, unit_diagonal=unit_diag, check_finite=False)


def solve_triangular_np(a: np.ndarray, b: np.ndarray, lower: bool,
                        unit_diag: bool) -> np.ndarray:
    """Pure-numpy fallback when scipy is unavailable: forward/back
    substitution at tile granularity (row blocks of 1)."""
    n = a.shape[0]
    x = np.array(b, dtype=np.promote_types(a.dtype, b.dtype), copy=True)
    rng = range(n) if lower else range(n - 1, -1, -1)
    for r in rng:
        if lower:
            if r > 0:
                x[r] -= a[r, :r] @ x[:r]
        else:
            if r < n - 1:
                x[r] -= a[r, r + 1:] @ x[r + 1:]
        if not unit_diag:
            x[r] /= a[r, r]
    return x


def get_solver():
    try:
        import scipy.linalg  # noqa: F401

        return solve_triangular
    except ImportError:  # pragma: no cover
        return solve_triangular_np
