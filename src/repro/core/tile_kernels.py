"""Per-tile compute kernels for the threaded/simulated runtime.

The runtime is kernel-pluggable:
  * ``numpy``  — host BLAS via np.dot (default for the reproduction
                 engine: fast, multi-thread safe);
  * ``jax``    — jitted jnp.dot (per-tile XLA kernels);
  * ``pallas`` — the repro Pallas matmul in interpret mode (used by
                 tests to prove the TPU kernel composes with the
                 runtime; slow on CPU).

Fill modifiers realize triangular/symmetric *storage* semantics: stored
tiles are always dense, only the ``uplo`` triangle is meaningful, so we
mask/symmetrize on load (before the §III-C transpose trick).
"""
from __future__ import annotations

import functools

import numpy as np

from . import task as task_mod
from .task import (FILL_FULL, FILL_SYM_L, FILL_SYM_U, FILL_TRI_L,
                   FILL_TRI_LU, FILL_TRI_U, FILL_TRI_UU, TileRef)


def apply_fill(tile: np.ndarray, fill: str) -> np.ndarray:
    if fill == FILL_FULL:
        return tile
    if fill == FILL_SYM_U:
        u = np.triu(tile)
        return u + np.triu(tile, 1).T
    if fill == FILL_SYM_L:
        l = np.tril(tile)
        return l + np.tril(tile, -1).T
    if fill == FILL_TRI_U:
        return np.triu(tile)
    if fill == FILL_TRI_L:
        return np.tril(tile)
    if fill == FILL_TRI_UU:
        t = np.triu(tile, 1)
        return t + np.eye(tile.shape[0], tile.shape[1], dtype=tile.dtype)
    if fill == FILL_TRI_LU:
        t = np.tril(tile, -1)
        return t + np.eye(tile.shape[0], tile.shape[1], dtype=tile.dtype)
    raise ValueError(f"unknown fill {fill}")


def materialize(tile: np.ndarray, ref: TileRef) -> np.ndarray:
    out = apply_fill(tile, ref.fill)
    if ref.trans:
        out = out.T
    return out


# ----------------------------------------------------------------- kernels
def _matmul_numpy(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return np.dot(a, b)


@functools.lru_cache(maxsize=None)
def _jax_dot():
    import jax
    import jax.numpy as jnp

    @jax.jit
    def dot(a, b):
        return jnp.dot(a, b, preferred_element_type=jnp.float64
                       if a.dtype == jnp.float64 else jnp.float32)

    return dot


def _matmul_jax(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return np.asarray(_jax_dot()(a, b))


def _matmul_pallas(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    from ..kernels import ops as kops

    return np.asarray(kops.matmul(a, b, interpret=True))


MATMULS = {
    "numpy": _matmul_numpy,
    "jax": _matmul_jax,
    "pallas": _matmul_pallas,
}


def solve_triangular(a: np.ndarray, b: np.ndarray, lower: bool,
                     unit_diag: bool) -> np.ndarray:
    """Tile-level triangular solve for the TRSM finalize step."""
    import scipy.linalg  # local import; only TRSM needs it

    return scipy.linalg.solve_triangular(
        a, b, lower=lower, unit_diagonal=unit_diag, check_finite=False)


def solve_triangular_np(a: np.ndarray, b: np.ndarray, lower: bool,
                        unit_diag: bool) -> np.ndarray:
    """Pure-numpy fallback when scipy is unavailable: forward/back
    substitution at tile granularity (row blocks of 1)."""
    n = a.shape[0]
    x = np.array(b, dtype=np.promote_types(a.dtype, b.dtype), copy=True)
    rng = range(n) if lower else range(n - 1, -1, -1)
    for r in rng:
        if lower:
            if r > 0:
                x[r] -= a[r, :r] @ x[:r]
        else:
            if r < n - 1:
                x[r] -= a[r, r + 1:] @ x[r + 1:]
        if not unit_diag:
            x[r] /= a[r, r]
    return x


def get_solver():
    try:
        import scipy.linalg  # noqa: F401

        return solve_triangular
    except ImportError:  # pragma: no cover
        return solve_triangular_np
