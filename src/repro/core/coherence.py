"""MESI-X cache-coherence protocol for the two-level tile cache
(paper §IV-B, Fig. 3).

States are *derived* from the set of ALRUs tracking a tile:

  E (exclusive) — exactly one device's ALRU holds the tile
  S (shared)    — more than one device's ALRU holds it
  I (invalid)   — no ALRU holds it (tile lives only in host RAM)
  M (modified)  — ephemeral: a device wrote a C_ij tile; it is written
                  back to host RAM immediately and transitions to I.

The directory maps each tile key to its holder set; it also answers
L2-cache queries: "which *peer* device (same P2P group) holds this
tile?".  All mutations are lock-guarded — the paper's runtime does the
same with atomics.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence, Set

from .tiling import TileKey

STATE_E = "E"
STATE_S = "S"
STATE_I = "I"
STATE_M = "M"  # ephemeral; never observable at rest


class MesixDirectory:
    # lock-discipline declarations (repro.analysis, docs/ANALYSIS.md).
    # _group_of is immutable after __init__ and deliberately unlisted.
    _GUARDED_BY = {"_lock": (
        "_holders", "_served", "_serve_tick", "writebacks",
        "invalidations")}

    def __init__(self, n_devices: int, p2p_groups: Sequence[Sequence[int]]):
        """``p2p_groups`` — lists of device ids sharing a PCI-E switch /
        ICI neighborhood; L2 hits are only served within a group."""
        self.n_devices = n_devices
        self._holders: Dict[TileKey, Set[int]] = {}
        self._lock = threading.RLock()
        self._group_of: Dict[int, int] = {}
        for gid, group in enumerate(p2p_groups):
            for dev in group:
                self._group_of[dev] = gid
        for dev in range(n_devices):
            self._group_of.setdefault(dev, -1 - dev)  # isolated device
        # least-recently-served order for L2 peer selection: device ->
        # monotonic tick of its last P2P serve (absent = never served)
        self._served: Dict[int, int] = {}
        self._serve_tick = 0
        # instrumentation
        self.writebacks = 0
        self.invalidations = 0

    # ------------------------------------------------------------- queries
    def state(self, key: TileKey) -> str:
        with self._lock:
            holders = self._holders.get(key)
            if not holders:
                return STATE_I
            return STATE_E if len(holders) == 1 else STATE_S

    def holders(self, key: TileKey) -> Set[int]:
        with self._lock:
            return set(self._holders.get(key, ()))

    def peer_holder(self, key: TileKey, device_id: int) -> Optional[int]:
        """L2 tile-cache lookup: a device in the *same* P2P group holding
        the tile (excluding the requester), or None (=> fetch from host).

        Among multiple eligible holders the *least-recently-served* one
        is chosen (ties break toward the lowest id, so the pick stays
        deterministic).  Always answering the lowest id — the old
        behaviour — funnelled every L2 hit through one device and
        drained its D2D egress lane while its peers' lanes sat idle
        (skewed ``d2d_served_s``/``d2d_busy_s`` in the event-engine
        ledger).  The query itself is read-only; the runtime reports an
        actual P2P fetch via :meth:`mark_served`, which is what rotates
        the order."""
        gid = self._group_of[device_id]
        with self._lock:
            eligible = [dev for dev in self._holders.get(key, ())
                        if dev != device_id and self._group_of[dev] == gid]
            if not eligible:
                return None
            return min(eligible,
                       key=lambda dev: (self._served.get(dev, -1), dev))

    def mark_served(self, device_id: int) -> None:
        """Record that ``device_id`` just served a P2P fetch, moving it
        to the back of the least-recently-served order."""
        with self._lock:
            self._serve_tick += 1
            self._served[device_id] = self._serve_tick

    def same_group(self, a: int, b: int) -> bool:
        return self._group_of[a] == self._group_of[b]

    # ----------------------------------------------------------- mutations
    def on_fill(self, key: TileKey, device_id: int) -> str:
        """A device cached the tile (I->E, E->S, S->S)."""
        with self._lock:
            holders = self._holders.setdefault(key, set())
            holders.add(device_id)
            return STATE_E if len(holders) == 1 else STATE_S

    def on_evict(self, key: TileKey, device_id: int) -> str:
        """A device's ALRU dropped the tile (S->S/E, E->I)."""
        with self._lock:
            holders = self._holders.get(key)
            if holders is not None:
                holders.discard(device_id)
                if not holders:
                    del self._holders[key]
            return self.state(key)

    def on_write(self, key: TileKey, device_id: int) -> List[int]:
        """MESI-X write: a device produced a C_ij tile.  The M state is
        ephemeral — the caller writes the tile back to host RAM and we
        invalidate *all* cached copies (including the writer's), i.e.
        M -> I immediately (Fig. 3).  Returns the list of devices whose
        copies were invalidated, so the runtime can purge their ALRUs."""
        with self._lock:
            holders = sorted(self._holders.pop(key, ()))
            self.writebacks += 1
            self.invalidations += len(holders)
            return holders

    # ------------------------------------------------------------ checking
    def check_invariants(self) -> None:
        with self._lock:
            for key, holders in self._holders.items():
                if not holders:
                    raise RuntimeError(f"empty holder set kept for {key}")
                for dev in holders:
                    if not (0 <= dev < self.n_devices):
                        raise RuntimeError(f"bogus device {dev} holds {key}")

    def audit(self, alrus: Sequence) -> None:
        """Cross-check the directory against the actual caches: every
        holder entry must correspond to a resident block in that
        device's ALRU, and every resident block must be registered
        here.  The quota machinery evicts through the same
        ``on_evict`` path as capacity pressure, so tenant isolation
        must leave this bijection intact — the serve tests call this
        after flood runs.

        The ALRU queries run *outside* the directory lock, against a
        snapshot of the holder map: ALRU eviction fires ``on_evict``
        (which takes this lock) while holding the cache lock, so
        querying the caches with ``_lock`` held would take the two
        locks in the opposite order — the Alru<->MesixDirectory
        inversion LO001 forbids.  Callers run this under quiescence
        anyway (the bijection is only meaningful with no in-flight
        evictions), so the snapshot loses nothing."""
        with self._lock:
            snapshot = {key: sorted(holders)
                        for key, holders in self._holders.items()}
        for key, holders in snapshot.items():
            for dev in holders:
                if not (0 <= dev < len(alrus)):
                    raise RuntimeError(f"bogus device {dev} holds {key}")
                if key not in alrus[dev]:
                    raise RuntimeError(
                        f"directory says device {dev} holds {key} "
                        "but its ALRU has no such block")
        for dev, alru in enumerate(alrus):
            for key in alru.keys():
                if dev not in snapshot.get(key, ()):
                    raise RuntimeError(
                        f"device {dev} caches {key} but the "
                        "directory does not list it as a holder")
