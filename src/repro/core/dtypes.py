"""Precision registry for multi-precision L3 BLAS.

The paper reports its headline numbers for both SGEMM and DGEMM
(Figs. 7-9); this module is the single source of truth for which
storage dtypes the reproduction supports and on which execution
backends.  Everything downstream keys off :func:`canonical_dtype`:

  * ``float64`` / ``float32`` — every backend.  The numpy engine
    computes in the storage dtype; the jax/pallas engines accumulate
    in float32 (float64 only under ``jax_enable_x64``).
  * ``float16`` / ``bfloat16`` — jax and pallas backends only.  The
    per-step host-BLAS path has no fast half-precision story (numpy
    falls back to scalar loops for bfloat16), so the numpy backend
    rejects them with a clear error instead of silently crawling.
    Both engines accumulate half-precision inputs in float32 and cast
    the result back to the storage dtype.

Byte accounting is *storage*-dtype accounting: a tile's ``nbytes`` is
``h * w * dtype.itemsize``, so the ALRU/heap capacity model, the
MESI-X transfer ledger and the link-time comm model all become
precision-aware for free once the tiled matrices carry the right
dtype.

``bfloat16`` is a non-native numpy dtype provided by ``ml_dtypes``
(a jax dependency); on hosts without it the name is rejected with an
actionable message rather than an obscure ``TypeError``.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

# storage dtype name -> backends allowed to execute it
_ALL_BACKENDS: Tuple[str, ...] = ("numpy", "jax", "pallas")
SUPPORTED_DTYPES: Dict[str, Tuple[str, ...]] = {
    "float64": _ALL_BACKENDS,
    "float32": _ALL_BACKENDS,
    "float16": ("jax", "pallas"),
    "bfloat16": ("jax", "pallas"),
}


def canonical_dtype(dtype) -> np.dtype:
    """Normalize any dtype spelling (str, np.dtype, type, ml_dtypes
    scalar type) to the canonical ``np.dtype``; rejects dtypes outside
    the supported set."""
    try:
        dt = np.dtype(dtype)
    except TypeError:
        # 'bfloat16' only resolves once ml_dtypes has registered it
        # with numpy — import lazily so callers don't have to
        if "bfloat16" in str(dtype):
            try:
                import ml_dtypes  # noqa: F401

                dt = np.dtype(dtype)
            except (ImportError, TypeError):
                raise ValueError(
                    "dtype 'bfloat16' needs the ml_dtypes package "
                    "(ships with jax); it is not installed") from None
        else:
            raise ValueError(f"unsupported dtype {dtype!r}") from None
    if dt.name not in SUPPORTED_DTYPES:
        raise ValueError(
            f"unsupported dtype {dt.name!r}; L3 routines support "
            f"{sorted(SUPPORTED_DTYPES)}")
    return dt


def validate_backend_dtype(dtype, backend: str) -> np.dtype:
    """Check that ``backend`` can execute ``dtype``; returns the
    canonical dtype.  Half precisions are jax/pallas-only (see module
    docstring)."""
    dt = canonical_dtype(dtype)
    allowed = SUPPORTED_DTYPES[dt.name]
    if backend not in allowed:
        raise ValueError(
            f"dtype {dt.name!r} is not supported on the {backend!r} "
            f"backend (supported there: "
            f"{sorted(n for n, b in SUPPORTED_DTYPES.items() if backend in b)}; "
            f"{dt.name} needs one of {list(allowed)})")
    return dt


def promote_dtypes(a, b) -> np.dtype:
    """``np.promote_types`` with an equal-dtype fast path.  The fast
    path matters for non-native dtypes: it keeps bfloat16 groups at
    bfloat16 without relying on numpy's promotion table.  Pairs with
    no common dtype (bfloat16 x float16 — numpy's DTypePromotionError)
    get a clear error telling the caller to pick a precision."""
    da, db = np.dtype(a), np.dtype(b)
    if da == db:
        return da
    try:
        return np.promote_types(da, db)
    except TypeError:
        raise ValueError(
            f"no common precision between {da.name} and {db.name} "
            f"operands; pass an explicit dtype=") from None


# NB: the accumulation policy itself (f64 keeps f64 where the engine
# allows, everything narrower accumulates in f32) lives with the
# engines — jax_backend's preferred_element_type selection and the
# pallas kernels' f32 VMEM accumulator — not here: it depends on
# runtime engine state (jax_enable_x64) this module must not import.
