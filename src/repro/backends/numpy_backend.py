"""Host-BLAS reference backend — the seed runtime's behavior, extracted.

Each k-step is one ``np.dot`` call and each accumulate one numpy add:
no batching, one "launch" per step, per-step products summed in the
original k order (bitwise identical to the seed engine).  This is the
baseline the batched JAX/Pallas backends are measured against
(``kernel_launches == batched_steps`` on its ledger), and the
numerically-authoritative engine the parity suite compares to.

Precisions: float64 and float32, computed in the storage dtype (host
BLAS).  The half precisions (float16/bfloat16) are rejected upstream
by ``repro.core.dtypes`` — numpy has no fast kernels for them, so
they are jax/pallas-only.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from .base import ExecutionBackend, GroupResult, StepGroupKey


class NumpyBackend(ExecutionBackend):
    name = "numpy"

    def run_group(self, key: StepGroupKey, a_tiles: Sequence[np.ndarray],
                  b_tiles: Sequence[np.ndarray]) -> GroupResult:
        s = key.steps
        products = []
        for i in range(0, len(a_tiles), s):
            acc = np.dot(a_tiles[i], b_tiles[i])
            for j in range(i + 1, i + s):
                acc = acc + np.dot(a_tiles[j], b_tiles[j])
            products.append(acc)
        return GroupResult(products, launches=len(a_tiles),
                           engine=self.name)
