"""Batched XLA backend: a whole step group in one jitted dispatch.

The group's tiles are stacked into ``(G, steps, m, k)`` / ``(G, steps,
k, n)`` and the whole thing runs as a single jit-compiled call: the
per-item k-chains are folded into one ``(m, steps*k) @ (steps*k, n)``
contraction — a task's entire k-loop becomes ONE long-K GEMM (the
Stream-K-style work-centric unit) — and the G items ride a single
batched matmul.  XLA sees one well-shaped kernel instead of
``G * steps`` interpreted calls plus ``G * (steps-1)`` interpreted
adds, so both the per-step dispatch tax and the tiny-matmul
inefficiency disappear.  ``jax.jit`` keys its compile cache on the
abstract ``(G, steps, m, k, n, dtype)`` signature, so recurring tile
shapes (the common case: every full tile of a matrix shares one
shape) hit warm compiled executables.

Dtype handling (multi-precision contract, see ``repro.core.dtypes``):
tiles are staged in the group's *storage* dtype — float32 groups move
half the bytes of float64, bfloat16/float16 a quarter — and the
contraction accumulates at the engine's best precision: float64 only
when ``jax_enable_x64`` is on (default CPU jax computes in float32);
float32 for every narrower storage dtype (the MXU-canonical f32
accumulation for bf16/f16 inputs).  The result is cast back to the
group's promoted storage dtype, so callers always get the dtype
contract of the numpy engine.  ``jax.jit`` keys its compile cache on
the abstract ``(shape, dtype)`` signature, so every storage precision
gets its own specialized executable.  Float64 workloads on a
32-bit-configured jax trade precision, which is why the parity suite
pins float32 inputs.
"""
from __future__ import annotations

import functools
from typing import Sequence

import numpy as np

from .base import ExecutionBackend, GroupResult, StepGroupKey


@functools.lru_cache(maxsize=None)
def _group_contract():
    """Lazily import jax and build the jitted group kernel (one function;
    jit's own cache specializes it per shape/dtype)."""
    import jax
    import jax.numpy as jnp

    @jax.jit
    def run(a, b):  # a: (g, s, m, k)   b: (g, s, k, n)
        g, s, m, k = a.shape
        n = b.shape[-1]
        a2 = jnp.transpose(a, (0, 2, 1, 3)).reshape(g, m, s * k)
        b2 = b.reshape(g, s * k, n)
        # f32 accumulation for every sub-f64 storage dtype (f32, bf16,
        # f16); the caller casts back to the storage dtype afterwards
        pref = jnp.float64 if a.dtype == jnp.float64 else jnp.float32
        return jnp.matmul(a2, b2, preferred_element_type=pref)

    return run


def engine_dtype(want: str) -> str:
    """The *staging* dtype for a storage dtype: float64 narrows to
    float32 when jax runs without x64 (see module doc); float32 and
    the half precisions stage as-is — low-precision groups keep their
    small byte footprint and widen only inside the MXU/accumulator.
    Deliberately uncached — ``jax_enable_x64`` can be toggled at
    runtime and must be re-read per dispatch."""
    if want == "float64":
        import jax

        if not jax.config.jax_enable_x64:
            return "float32"
    return want


def stack_items(key: StepGroupKey, a_tiles: Sequence[np.ndarray],
                b_tiles: Sequence[np.ndarray]):
    """(G*steps) tile lists -> contiguous (G, steps, m, k) /
    (G, steps, k, n) staging buffers in the engine dtype (one fused
    cast-copy per tile; halves transfer bytes for f64-stored data on a
    32-bit engine)."""
    g = len(a_tiles) // key.steps
    eng = engine_dtype(key.dtype)
    a = np.empty((len(a_tiles), key.m, key.k), dtype=eng)
    b = np.empty((len(b_tiles), key.k, key.n), dtype=eng)
    for i, tile in enumerate(a_tiles):
        a[i] = tile
    for i, tile in enumerate(b_tiles):
        b[i] = tile
    return (a.reshape(g, key.steps, key.m, key.k),
            b.reshape(g, key.steps, key.k, key.n))


class JaxBackend(ExecutionBackend):
    name = "jax"

    def run_group(self, key: StepGroupKey, a_tiles: Sequence[np.ndarray],
                  b_tiles: Sequence[np.ndarray]) -> GroupResult:
        a, b = stack_items(key, a_tiles, b_tiles)
        out = np.asarray(_group_contract()(a, b))
        if out.dtype != np.dtype(key.dtype):
            out = out.astype(key.dtype)
        return GroupResult(list(out), launches=1, engine=self.name)
