"""Pluggable execution backends for the BLASX runtime.

``create_backend(name)`` is the factory the runtime uses; selection is
threaded through :class:`repro.core.runtime.RuntimeConfig(backend=...)`
→ :class:`repro.api.BlasxContext` → the ``blas3``/``cblas`` wrappers.

  * ``numpy``  — per-step host BLAS (the seed behavior; baseline);
  * ``jax``    — whole step group in one jitted XLA dispatch;
  * ``pallas`` — square full-fill groups through the repo's Pallas TPU
                 kernel, everything else via the jax path.
"""
from __future__ import annotations

from typing import Dict, Type

from .base import ExecutionBackend, GroupResult, StepGroupKey
from .jax_backend import JaxBackend
from .numpy_backend import NumpyBackend
from .pallas_backend import PallasBackend

BACKENDS: Dict[str, Type[ExecutionBackend]] = {
    "numpy": NumpyBackend,
    "jax": JaxBackend,
    "pallas": PallasBackend,
}


def available_backends():
    return tuple(BACKENDS)


def create_backend(name: str) -> ExecutionBackend:
    try:
        cls = BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; expected one of {tuple(BACKENDS)}"
        ) from None
    return cls()


__all__ = [
    "ExecutionBackend", "GroupResult", "StepGroupKey",
    "NumpyBackend", "JaxBackend", "PallasBackend",
    "BACKENDS", "available_backends", "create_backend",
]
