"""Execution-backend protocol: *how* tile math runs, decoupled from
*where/when* the scheduler runs it.

The BLASX runtime (``repro.core.runtime``) treats tiles as the basic
task unit: the scheduler picks a device and an order; every tile
k-step then has to be multiplied somewhere.  The seed implementation
executed each step as one interpreted host call — faithful scheduling,
but every step paid full per-call dispatch overhead.  An
:class:`ExecutionBackend` instead receives a *group* of same-shape
steps (grouped by the runtime per device batch) and may execute the
whole group as one batched dispatch — the software analogue of packing
concurrent tile kernels onto a stream.

Contract
--------
* Tiles arriving at a backend are already **materialized**: the fill
  mask (triangular/symmetric storage semantics) and the paper-§III-C
  transpose trick were applied on the host, so ``a_tiles[i]`` is
  ``(m, k)`` and ``b_tiles[i]`` is ``(k, n)`` exactly as multiplied.
  The originating ``op/trans/fill`` metadata still rides on the
  :class:`StepGroupKey` so backends can specialize (the Pallas backend
  only routes full-fill square groups to the TPU kernel).
* ``run_group`` must return one accumulator per *item* (a
  ``key.steps``-deep multiply-accumulate chain; see
  :class:`StepGroupKey`), in order, as numpy arrays (the runtime's
  cache/ledger layer is host-centric).
* Backends must be callable from several device worker threads at once
  (``mode="threads"``); compile caches are the only allowed state.
* ``launches`` in the returned :class:`GroupResult` is the number of
  kernel dispatches the group cost — the ledger currency behind the
  ``launches saved`` statistic.
"""
from __future__ import annotations

import abc
import dataclasses
from typing import List, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class StepGroupKey:
    """Batch signature: items sharing a key are dispatched together.

    One *item* is a ``steps``-deep multiply-accumulate chain
    ``acc = sum_j a_j @ b_j`` — a task's whole k-loop when the task is
    signature-uniform (the Stream-K-style work-centric unit), or a
    single step (``steps == 1``) when the runtime had to split a
    mixed-signature task.  ``m/k/n`` describe the *effective*
    (post-materialization) shape of one step's operands; ``dtype`` is
    the promoted accumulate dtype the caller expects back."""

    op: str        # originating routine ("gemm", "syrk", ...)
    transa: bool
    transb: bool
    fill_a: str    # task.FILL_* constants of the stored tiles
    fill_b: str
    m: int
    k: int
    n: int
    dtype: str
    steps: int = 1  # k-steps contracted per item

    @property
    def flops_per_item(self) -> int:
        return 2 * self.m * self.k * self.n * self.steps

    @property
    def full_fill(self) -> bool:
        """Plain GEMM-shaped multiply chain (the Pallas fast path)."""
        return self.fill_a == "full" and self.fill_b == "full"


@dataclasses.dataclass
class GroupResult:
    """What one grouped dispatch produced."""

    products: List[np.ndarray]   # one accumulator per item, in order
    launches: int                # kernel dispatches this group cost
    engine: str                  # engine that actually ran ("numpy"|"jax"|"pallas")


class ExecutionBackend(abc.ABC):
    """One batched tile-op dispatcher (see module docstring)."""

    name: str = "?"

    @abc.abstractmethod
    def run_group(self, key: StepGroupKey, a_tiles: Sequence[np.ndarray],
                  b_tiles: Sequence[np.ndarray]) -> GroupResult:
        """Execute ``len(a_tiles) // key.steps`` items — each the
        ``key.steps``-deep chain ``sum_j a[i*steps+j] @ b[i*steps+j]``
        over same-shape tiles (item-major order) — as one logical
        dispatch wherever the engine allows; returns one accumulator
        per item."""

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return f"{type(self).__name__}()"
