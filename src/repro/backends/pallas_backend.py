"""Pallas-kernel backend: full-fill tile groups on the repo's TPU kernel.

GEMM-shaped multiply chains over full (non-edge, non-triangular) tiles
are exactly what the repo's Pallas kernel (``repro.kernels.matmul``)
was built for: each item's k-chain folds into one
``(m, steps*k) @ (steps*k, n)`` matmul — long-K, MXU-aligned blocks
chosen by ``kernels.ops`` — and the group runs as one vmapped
``pallas_call`` dispatch.  A shape-keyed cache holds the jitted
batched kernels so each (steps, tile, dtype) signature compiles once
per process — every storage precision (f64/f32/bf16/f16) gets its own
compiled kernel, and the kernel's VMEM accumulator is float32
regardless of storage dtype (``preferred_element_type`` in
``kernels.matmul``), which is the f32-accumulation contract for
low-precision inputs.

Everything else (triangular/symmetric fills, mixed-signature tasks
split into single steps by the runtime) falls back to the batched
:class:`~repro.backends.jax_backend.JaxBackend` path for that group —
still one dispatch per group, just not through the Pallas kernel.

On hosts without a TPU the kernel runs in interpret mode (correct but
slow) — the point there is compositional testing, not speed; see the
README's "Execution backends" section.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import numpy as np

from .base import ExecutionBackend, GroupResult, StepGroupKey
from .jax_backend import JaxBackend, stack_items

# ops whose full-fill steps are plain C += A @ B tile multiplies
_PALLAS_OPS = ("gemm", "syrk", "syr2k", "symm")


@functools.lru_cache(maxsize=None)
def _use_interpret() -> bool:
    import jax

    return jax.default_backend() != "tpu"


@functools.lru_cache(maxsize=None)
def _batched_pallas_contract(steps: int, m: int, k: int, n: int,
                             dtype: str, interpret: bool):
    """Shape-keyed compile cache: one jitted vmapped Pallas matmul per
    (steps, tile shape, dtype) signature."""
    import jax
    import jax.numpy as jnp

    from ..kernels import ops as kops

    del steps, m, k, n, dtype  # cache key only; jit re-specializes

    @jax.jit
    def run(a, b):  # a: (g, s, m, k)   b: (g, s, k, n)
        g, s, mm, kk = a.shape
        nn = b.shape[-1]
        a2 = jnp.transpose(a, (0, 2, 1, 3)).reshape(g, mm, s * kk)
        b2 = b.reshape(g, s * kk, nn)
        return jax.vmap(
            lambda x, y: kops.matmul(x, y, interpret=interpret))(a2, b2)

    return run


class PallasBackend(ExecutionBackend):
    name = "pallas"

    def __init__(self, interpret: Optional[bool] = None):
        self._fallback = JaxBackend()
        self._interpret = interpret

    def _route_to_pallas(self, key: StepGroupKey) -> bool:
        return key.full_fill and key.op in _PALLAS_OPS

    def run_group(self, key: StepGroupKey, a_tiles: Sequence[np.ndarray],
                  b_tiles: Sequence[np.ndarray]) -> GroupResult:
        if not self._route_to_pallas(key):
            return self._fallback.run_group(key, a_tiles, b_tiles)
        interpret = (self._interpret if self._interpret is not None
                     else _use_interpret())
        fn = _batched_pallas_contract(key.steps, key.m, key.k, key.n,
                                      key.dtype, interpret)
        a, b = stack_items(key, a_tiles, b_tiles)
        out = np.asarray(fn(a, b))
        if out.dtype != np.dtype(key.dtype):
            out = out.astype(key.dtype)
        return GroupResult(list(out), launches=1, engine=self.name)
