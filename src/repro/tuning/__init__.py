"""repro.tuning — shape-adaptive runtime autotuning (paper Fig. 10).

Sweeps candidate ``(tile, n_streams, policy)`` configurations through
metadata-only shadow runs on the discrete-event virtual clock and
caches the winner per ``(topology fingerprint, backend, routine, shape
bucket, dtype)``.  A learned cost model (``repro.tuning.model``, ridge
regression on log-space features trained on the cache's own sweep
rows) can replace the sweep for unseen buckets: ``mode="auto"``
predicts per-candidate makespans, confirms the predicted winner
against the measured default in one shadow run, and falls back to the
full sweep when the model is untrained/untrusted or disproved.  Wired
into the API stack via ``BlasxContext(auto_tune=True | "auto")`` and
``tile="auto"`` on every surface; see ``docs/TUNING.md`` for the cache
layout and decision flow.
"""
from .autotuner import (MODES, Autotuner, TunedConfig, cache_key,
                        shape_bucket, topology_fingerprint)
from .cache import (ENV_CACHE_PATH, TuningCache, reset_shared_cache,
                    resolve_cache, shared_cache)
from .model import CostModel, feature_names, features, training_rows

__all__ = [
    "Autotuner", "TunedConfig", "TuningCache", "MODES",
    "shape_bucket", "topology_fingerprint", "cache_key",
    "shared_cache", "reset_shared_cache", "resolve_cache",
    "ENV_CACHE_PATH",
    "CostModel", "features", "feature_names", "training_rows",
]
