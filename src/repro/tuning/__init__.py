"""repro.tuning — shape-adaptive runtime autotuning (paper Fig. 10).

Sweeps candidate ``(tile, n_streams, policy)`` configurations through
metadata-only shadow runs on the discrete-event virtual clock and
caches the winner per ``(topology fingerprint, backend, routine, shape
bucket, dtype)``.  Wired into the API stack via
``BlasxContext(auto_tune=True)`` and ``tile="auto"`` on every surface;
see ``docs/ARCHITECTURE.md`` for the cache layout.
"""
from .autotuner import (Autotuner, TunedConfig, cache_key, shape_bucket,
                        topology_fingerprint)
from .cache import (ENV_CACHE_PATH, TuningCache, reset_shared_cache,
                    resolve_cache, shared_cache)

__all__ = [
    "Autotuner", "TunedConfig", "TuningCache",
    "shape_bucket", "topology_fingerprint", "cache_key",
    "shared_cache", "reset_shared_cache", "resolve_cache",
    "ENV_CACHE_PATH",
]
