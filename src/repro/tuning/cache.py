"""Persistent tuning cache — the autotuner's memory (and, since the
learned cost model landed, its training set).

One entry per ``(topology fingerprint, backend, routine, shape bucket,
dtype)`` key, holding the winning ``(tile, n_streams, policy)`` plus
the shadow-run evidence (per-candidate virtual-clock makespans — which
doubles as the :mod:`repro.tuning.model` training data).  Entries live
in process memory and, when a path is configured, in a JSON file so
the search runs once per machine — every later ``BlasxContext`` (or
process) starts warm and performs **zero** shadow-run sweeps for known
keys.  The fitted :class:`~repro.tuning.model.CostModel` state
persists in the same file (``"model"`` key) next to the entries it was
trained on.

Resolution order for the backing file:

* an explicit ``path=`` (``BlasxContext(tuning_cache="...")``,
  ``TuningCache("...")``; the empty string ``""`` forces memory-only
  even when the environment variable is set — benchmark lanes use it
  to stay deterministic under CI),
* else the ``BLASX_TUNING_CACHE`` environment variable (the CI bench
  lane sets it to upload the cache as an artifact),
* else memory-only (no file is ever written).

``shared_cache()`` returns the process-wide instance used by default:
two contexts with the same topology share it, which is what makes the
second context a pure cache hit.

Every entry also carries a **provenance** tag — ``"file"`` when it was
loaded from a backing file, ``"process"`` when it was put by this
process — surfaced through :meth:`TuningCache.origin` so
``ctx.tuning_report()`` can split cache hits into file-cache vs
process-cache hits.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Dict, List, Optional

ENV_CACHE_PATH = "BLASX_TUNING_CACHE"
CACHE_SCHEMA = 1


class TuningCache:
    """Thread-safe key -> entry store with optional JSON persistence.

    Entries are plain dicts (JSON-serializable); the autotuner owns
    their shape.  ``hits``/``misses`` count lookups for the
    ``tuning_report`` surface; ``version`` increments on every
    mutation so the cost model knows when its training set went stale.
    """

    # lock-discipline declarations (repro.analysis, docs/ANALYSIS.md):
    # put() holds _lock through save()'s file write by design (see
    # __init__), so save/load are listed as guarded mutators, not
    # exempted.
    _GUARDED_BY = {"_lock": (
        "_entries", "_origins", "_model_state", "version", "hits",
        "misses")}

    def __init__(self, path: Optional[str] = None):
        # path="" is an explicit memory-only override (no env fallback)
        self.path = (path or None) if path is not None else \
            os.environ.get(ENV_CACHE_PATH) or None
        # reentrant: put() holds the lock through save()'s file write so
        # concurrent puts cannot interleave on one tmp file
        self._lock = threading.RLock()
        self._entries: Dict[str, dict] = {}
        self._origins: Dict[str, str] = {}     # key -> "file" | "process"
        self._model_state: Optional[dict] = None
        self.version = 0
        self.hits = 0
        self.misses = 0
        if self.path and os.path.exists(self.path):
            self.load(self.path)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def keys(self) -> List[str]:
        with self._lock:
            return sorted(self._entries)

    def get(self, key: str) -> Optional[dict]:
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self.misses += 1
                return None
            self.hits += 1
            return dict(entry)

    def origin(self, key: str) -> Optional[str]:
        """``"file"`` if the entry came from a backing file,
        ``"process"`` if it was put by this process, ``None`` when the
        key is absent.  Does not touch the hit/miss counters."""
        with self._lock:
            return self._origins.get(key)

    def snapshot(self) -> Dict[str, dict]:
        """Copy of every entry, without touching the hit/miss counters
        (the cost model iterates this to build its training set)."""
        with self._lock:
            return {k: dict(v) for k, v in self._entries.items()}

    def put(self, key: str, entry: dict) -> None:
        """Store an entry and persist immediately when file-backed (a
        crash between sweeps then loses at most nothing).  The lock is
        held through the write — concurrent puts serialize instead of
        interleaving on one tmp file."""
        with self._lock:
            self._entries[key] = dict(entry)
            self._origins[key] = "process"
            self.version += 1
            if self.path:
                self.save(self.path)

    # -------------------------------------------------- model persistence
    def model_state(self) -> Optional[dict]:
        """The persisted :class:`~repro.tuning.model.CostModel` state
        (or ``None``); loaded from / saved to the same JSON file as the
        entries."""
        with self._lock:
            return dict(self._model_state) if self._model_state else None

    def set_model_state(self, state: Optional[dict]) -> None:
        """Attach fitted cost-model state; persisted on the next (or,
        when file-backed, this) save."""
        with self._lock:
            self._model_state = dict(state) if state else None
            if self.path:
                self.save(self.path)

    def load(self, path: str) -> int:
        """Merge entries (and any persisted model state) from a JSON
        cache file; returns how many entries were loaded.  Unknown
        schemas and unreadable/corrupt files are ignored rather than
        trusted — a damaged cache degrades to a re-sweep, never to a
        crash loop at context construction."""
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, ValueError):
            return 0
        if not isinstance(data, dict) or data.get("schema") != CACHE_SCHEMA:
            return 0
        entries = data.get("entries", {})
        if not isinstance(entries, dict):
            return 0
        model = data.get("model")
        with self._lock:
            self._entries.update(entries)
            for key in entries:
                self._origins[key] = "file"
            if isinstance(model, dict):
                self._model_state = model
            self.version += 1
            return len(entries)

    def save(self, path: Optional[str] = None) -> str:
        path = path or self.path
        if not path:
            raise ValueError("TuningCache has no backing path")
        tmp = f"{path}.tmp"
        with self._lock:
            payload = {"schema": CACHE_SCHEMA, "entries": self._entries}
            if self._model_state:
                payload["model"] = self._model_state
            with open(tmp, "w") as f:
                json.dump(payload, f, indent=2, sort_keys=True)
                f.write("\n")
            os.replace(tmp, path)
        return path

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._origins.clear()
            self._model_state = None
            self.version += 1
            self.hits = 0
            self.misses = 0


# --------------------------------------------------- process-shared default
_shared: Optional[TuningCache] = None
_shared_lock = threading.Lock()


def shared_cache() -> TuningCache:
    """The process-wide default cache (memory-backed unless
    ``BLASX_TUNING_CACHE`` is set): every ``BlasxContext(auto_tune=True)``
    without an explicit ``tuning_cache=`` shares it."""
    global _shared
    with _shared_lock:
        if _shared is None:
            _shared = TuningCache()
        return _shared


def reset_shared_cache() -> None:
    """Drop the process-wide cache (test isolation)."""
    global _shared
    with _shared_lock:
        _shared = None


def resolve_cache(spec) -> TuningCache:
    """``None`` -> process-shared, ``str`` -> file-backed (``""`` ->
    memory-only), instance -> itself (the ``tuning_cache=`` coercion
    used by the context layer)."""
    if spec is None:
        return shared_cache()
    if isinstance(spec, TuningCache):
        return spec
    if isinstance(spec, (str, os.PathLike)):
        return TuningCache(os.fspath(spec))
    raise TypeError(f"tuning_cache must be None, a path or a TuningCache, "
                    f"got {type(spec).__name__}")
