"""Persistent tuning cache — the autotuner's memory.

One entry per ``(topology fingerprint, backend, routine, shape bucket,
dtype)`` key, holding the winning ``(tile, n_streams, policy)`` plus
the shadow-sweep evidence (per-candidate virtual-clock makespans).
Entries live in process memory and, when a path is configured, in a
JSON file so the search runs once per machine — every later
``BlasxContext`` (or process) starts warm and performs **zero**
shadow-run sweeps for known keys.

Resolution order for the backing file:

* an explicit ``path=`` (``BlasxContext(tuning_cache="...")``,
  ``TuningCache("...")``),
* else the ``BLASX_TUNING_CACHE`` environment variable (the CI bench
  lane sets it to upload the cache as an artifact),
* else memory-only (no file is ever written).

``shared_cache()`` returns the process-wide instance used by default:
two contexts with the same topology share it, which is what makes the
second context a pure cache hit.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Dict, List, Optional

ENV_CACHE_PATH = "BLASX_TUNING_CACHE"
CACHE_SCHEMA = 1


class TuningCache:
    """Thread-safe key -> entry store with optional JSON persistence.

    Entries are plain dicts (JSON-serializable); the autotuner owns
    their shape.  ``hits``/``misses`` count lookups for the
    ``tuning_report`` surface.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path if path is not None else \
            os.environ.get(ENV_CACHE_PATH) or None
        # reentrant: put() holds the lock through save()'s file write so
        # concurrent puts cannot interleave on one tmp file
        self._lock = threading.RLock()
        self._entries: Dict[str, dict] = {}
        self.hits = 0
        self.misses = 0
        if self.path and os.path.exists(self.path):
            self.load(self.path)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def keys(self) -> List[str]:
        with self._lock:
            return sorted(self._entries)

    def get(self, key: str) -> Optional[dict]:
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self.misses += 1
                return None
            self.hits += 1
            return dict(entry)

    def put(self, key: str, entry: dict) -> None:
        """Store an entry and persist immediately when file-backed (a
        crash between sweeps then loses at most nothing).  The lock is
        held through the write — concurrent puts serialize instead of
        interleaving on one tmp file."""
        with self._lock:
            self._entries[key] = dict(entry)
            if self.path:
                self.save(self.path)

    def load(self, path: str) -> int:
        """Merge entries from a JSON cache file; returns how many were
        loaded.  Unknown schemas and unreadable/corrupt files are
        ignored rather than trusted — a damaged cache degrades to a
        re-sweep, never to a crash loop at context construction."""
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, ValueError):
            return 0
        if not isinstance(data, dict) or data.get("schema") != CACHE_SCHEMA:
            return 0
        entries = data.get("entries", {})
        if not isinstance(entries, dict):
            return 0
        with self._lock:
            self._entries.update(entries)
            return len(entries)

    def save(self, path: Optional[str] = None) -> str:
        path = path or self.path
        if not path:
            raise ValueError("TuningCache has no backing path")
        tmp = f"{path}.tmp"
        with self._lock:
            with open(tmp, "w") as f:
                json.dump({"schema": CACHE_SCHEMA,
                           "entries": self._entries}, f, indent=2,
                          sort_keys=True)
                f.write("\n")
            os.replace(tmp, path)
        return path

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self.hits = 0
            self.misses = 0


# --------------------------------------------------- process-shared default
_shared: Optional[TuningCache] = None
_shared_lock = threading.Lock()


def shared_cache() -> TuningCache:
    """The process-wide default cache (memory-backed unless
    ``BLASX_TUNING_CACHE`` is set): every ``BlasxContext(auto_tune=True)``
    without an explicit ``tuning_cache=`` shares it."""
    global _shared
    with _shared_lock:
        if _shared is None:
            _shared = TuningCache()
        return _shared


def reset_shared_cache() -> None:
    """Drop the process-wide cache (test isolation)."""
    global _shared
    with _shared_lock:
        _shared = None


def resolve_cache(spec) -> TuningCache:
    """``None`` -> process-shared, ``str`` -> file-backed, instance ->
    itself (the ``tuning_cache=`` coercion used by the context layer)."""
    if spec is None:
        return shared_cache()
    if isinstance(spec, TuningCache):
        return spec
    if isinstance(spec, (str, os.PathLike)):
        return TuningCache(os.fspath(spec))
    raise TypeError(f"tuning_cache must be None, a path or a TuningCache, "
                    f"got {type(spec).__name__}")
