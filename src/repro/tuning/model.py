"""Learned cost model for the runtime autotuner (beyond the paper).

The sweep-based :class:`~repro.tuning.autotuner.Autotuner` pays one
metadata shadow run per candidate for every cold ``(routine, shape
bucket, dtype)`` — the right cost structure for a handful of shapes,
the wrong one for serving traffic whose shape distribution is ragged
and long-tailed (every new bucket is a full sweep).  Following the
direction of "Machine-Learning-Driven Runtime Optimization of BLAS
Level 3" (arXiv 2406.19621), this module learns the sweep's cost
function instead of re-measuring it:

* **training data** — the rows the
  :class:`~repro.tuning.cache.TuningCache` already accumulates: every
  swept entry stores *all* candidate makespans, so one 13-candidate
  sweep contributes 13 labeled examples for free (model-adopted
  entries contribute only their *measured* confirmation rows — the
  model never trains on its own predictions);
* **features** — log-space shape/bucket dims and aspect ratios, dtype
  itemsize, routine and policy one-hots, candidate ``tile`` /
  ``n_streams`` (with quadratic tile terms, because Fig. 10's
  makespan-vs-tile curve is U-shaped and a purely linear model in
  ``log tile`` could never have an interior argmin), a per-routine
  step-count estimate, and the topology-fingerprint fields
  (:meth:`~repro.core.runtime.RuntimeConfig.topology`);
* **model** — ridge regression on standardized features predicting
  ``log(makespan)``, solved in closed form with numpy: dependency-free,
  deterministic, microseconds to fit at tuning-cache scale;
* **uncertainty** — a residual-based prediction interval: the
  training-residual RMSE in log space (degrees-of-freedom corrected).
  The autotuner's ``auto`` mode only trusts the model when this
  interval is tight (``rmse <= max_rmse`` with ``n_rows >= min_rows``)
  *and* the predicted winner shadow-verifies ``<= default`` in a
  confirmation run — the tuned-never-worse-than-default guarantee is
  enforced on measured makespans, never on predictions.

Model state (coefficients, scaler, residual stats) round-trips through
:meth:`CostModel.state` / :meth:`CostModel.from_state` and persists
inside the tuning-cache JSON file next to the entries it was fitted
on (see :meth:`~repro.tuning.cache.TuningCache.set_model_state`).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence

import numpy as np

# one-hot vocabularies are fixed so feature vectors are stable across
# processes (the model state persists; an open vocabulary would shift
# column meanings between fit and predict)
ROUTINES = ("gemm", "syrk", "syr2k", "symm", "trmm", "trsm")
POLICIES = ("blasx", "parsec", "cublasxt", "static")

# auto-mode trust gate defaults (Autotuner can override): the model is
# only consulted once it has seen at least MIN_ROWS measured candidate
# rows and its dof-corrected log-residual RMSE is below MAX_RMSE
# (0.35 in log space ~= a +-42% one-sigma band — loose enough to admit
# a freshly bootstrapped model, and safe because every adoption is
# still confirmed against a measured default makespan)
MIN_ROWS = 24
MAX_RMSE = 0.35


def _step_estimate(routine: str, bucket, tile: int,
                   work_centric: bool = False, capacity: int = 8) -> int:
    """Per-routine tile-task k-step count (mirrors
    ``Autotuner._step_estimate``; duplicated here so the model module
    stays importable without the tuner).  Under the work-centric mode
    every split tile re-walks its k-loop once more — the partials'
    slices plus the fix-up's full re-dispatch — mirroring
    ``repro.core.tiling.workcentric_parts``: all tiles split on small
    problems (owner count below ``capacity``), only ragged boundary
    tiles split on large ones."""
    m, k, n = bucket
    rows = math.ceil(m / tile)
    cols = math.ceil(n / tile)
    depth = math.ceil(k / tile)
    factor = 1
    if routine in ("syrk", "syr2k"):
        rows = cols = math.ceil(n / tile)
        ntasks = rows * (rows + 1) // 2
        factor = 2 if routine == "syr2k" else 1
        interior = (n // tile) * ((n // tile) + 1) // 2
    else:
        if routine in ("symm", "trmm", "trsm"):
            depth = math.ceil(m / tile)
        ntasks = rows * cols
        interior = (m // tile) * (n // tile)
    base = ntasks * depth * factor
    if not work_centric or depth * factor < 2:
        return base
    split = ntasks if ntasks < capacity else max(0, ntasks - interior)
    return base + split * depth * factor


def feature_names(topology: Dict[str, object]) -> List[str]:
    """Stable feature ordering for a given topology field set."""
    names = ["lm", "lk", "ln", "aspect_mn", "aspect_mk", "litemsize",
             "ltile", "ltile2", "ltile_x_dims", "lstreams", "lstreams2",
             "lsteps", "work_centric"]
    names += [f"routine_{r}" for r in ROUTINES]
    names += [f"policy_{p}" for p in POLICIES]
    names += [f"topo_{k}" for k in sorted(topology)
              if isinstance(topology[k], (int, float, bool))]
    return names


def features(routine: str, bucket, dtype_name: str,
             topology: Dict[str, object], tile: int, n_streams: int,
             policy: str, work_centric: bool = False) -> Dict[str, float]:
    """One feature dict for a (problem, candidate) pair.

    Everything multiplicative lives in log2 space — makespan is
    roughly a product of work, granularity and machine terms, so its
    log is roughly linear in these.  ``ltile2`` and ``ltile_x_dims``
    give the regression the curvature to place Fig. 10's interior
    optimum; ``lsteps`` encodes the routine-specific task count the
    schedule actually dispatches (partial-k tasks included when the
    candidate runs work-centric — owner-only counting would blind the
    model exactly on the small/ragged shapes the mode targets)."""
    m, k, n = bucket
    lm, lk, ln = math.log2(m), math.log2(k), math.log2(n)
    lt = math.log2(tile)
    ls = math.log2(max(1, n_streams))
    n_devices = topology.get("n_devices", 2)
    capacity = max(1, int(n_devices) * max(1, n_streams))
    out: Dict[str, float] = {
        "lm": lm, "lk": lk, "ln": ln,
        "aspect_mn": lm - ln, "aspect_mk": lm - lk,
        "litemsize": math.log2(np.dtype(dtype_name).itemsize),
        "ltile": lt, "ltile2": lt * lt,
        "ltile_x_dims": lt * (lm + lk + ln) / 3.0,
        "lstreams": ls, "lstreams2": ls * ls,
        "lsteps": math.log2(max(1, _step_estimate(
            routine, bucket, tile, work_centric=work_centric,
            capacity=capacity))),
        "work_centric": 1.0 if work_centric else 0.0,
    }
    for r in ROUTINES:
        out[f"routine_{r}"] = 1.0 if routine == r else 0.0
    for p in POLICIES:
        out[f"policy_{p}"] = 1.0 if policy == p else 0.0
    for key in sorted(topology):
        v = topology[key]
        if isinstance(v, bool):
            out[f"topo_{key}"] = 1.0 if v else 0.0
        elif isinstance(v, (int, float)):
            out[f"topo_{key}"] = math.log2(v) if v > 0 else float(v)
    return out


def training_rows(cache, fingerprint: str, backend: str,
                  topology: Dict[str, object]) -> List[Dict[str, object]]:
    """Extract (features, log-makespan) training rows from every cache
    entry under this tuner's ``fingerprint/backend`` namespace.

    Only *measured* candidate rows are used — swept entries carry the
    whole sweep, model-adopted entries carry just their confirmation
    runs — so the model never fits its own predictions.  Entries
    missing a stored topology (pre-model cache files) fall back to the
    caller's: the key prefix already guarantees the fingerprint
    matches."""
    prefix = f"{fingerprint}/{backend}/"
    rows: List[Dict[str, object]] = []
    for key, entry in cache.snapshot().items():
        if not key.startswith(prefix):
            continue
        routine = entry.get("routine")
        bucket = entry.get("bucket")
        dtype_name = entry.get("dtype")
        if routine not in ROUTINES or not bucket or not dtype_name:
            continue
        topo = entry.get("topology") or topology
        for cand in entry.get("candidates", ()):
            span = cand.get("makespan")
            if not span or span <= 0 or cand.get("policy") not in POLICIES:
                continue
            rows.append({
                "features": features(routine, tuple(bucket), dtype_name,
                                     topo, cand["tile"], cand["n_streams"],
                                     cand["policy"],
                                     work_centric=bool(
                                         cand.get("work_centric", False))),
                "log_makespan": math.log(span),
            })
    return rows


class CostModel:
    """Ridge regression on log-space features -> log(makespan).

    Closed-form fit (``(X'X + lam*n*I)^-1 X'y`` on standardized
    columns), so training is deterministic and costs microseconds at
    tuning-cache scale.  ``rmse`` is the degrees-of-freedom-corrected
    training-residual RMSE in log space — the residual-based
    prediction-interval width the autotuner's trust gate checks."""

    STATE_SCHEMA = 1

    def __init__(self, ridge_lambda: float = 1e-3):
        self.ridge_lambda = float(ridge_lambda)
        self.names: List[str] = []
        self.mean: Optional[np.ndarray] = None
        self.scale: Optional[np.ndarray] = None
        self.coef: Optional[np.ndarray] = None
        self.intercept: float = 0.0
        self.rmse: float = float("inf")
        self.n_rows: int = 0

    @property
    def trained(self) -> bool:
        return self.coef is not None

    def fit(self, rows: Sequence[Dict[str, object]]) -> "CostModel":
        """Fit on ``training_rows`` output; a no-op (untrained model)
        when there are fewer rows than features would make the solve
        meaningful."""
        if not rows:
            return self
        self.names = sorted(rows[0]["features"])
        X = np.array([[r["features"].get(name, 0.0) for name in self.names]
                      for r in rows], dtype=np.float64)
        y = np.array([r["log_makespan"] for r in rows], dtype=np.float64)
        n, d = X.shape
        self.mean = X.mean(axis=0)
        std = X.std(axis=0)
        # constant columns (e.g. topology fields under one fingerprint)
        # carry no information: scale 1 keeps them harmlessly at zero
        self.scale = np.where(std > 0, std, 1.0)
        Xs = (X - self.mean) / self.scale
        self.intercept = float(y.mean())
        yc = y - self.intercept
        lam = self.ridge_lambda * n
        A = Xs.T @ Xs + lam * np.eye(d)
        self.coef = np.linalg.solve(A, Xs.T @ yc)
        resid = Xs @ self.coef - yc
        dof = max(1, n - d)
        self.rmse = float(np.sqrt(float(resid @ resid) / n) *
                          math.sqrt(n / dof)) if n > d else float("inf")
        self.n_rows = n
        return self

    def predict(self, feats: Dict[str, float]) -> float:
        """Predicted makespan in (virtual-clock) seconds."""
        if not self.trained:
            raise RuntimeError("CostModel is not trained")
        x = np.array([feats.get(name, 0.0) for name in self.names],
                     dtype=np.float64)
        xs = (x - self.mean) / self.scale
        return math.exp(self.intercept + float(xs @ self.coef))

    def interval(self, feats: Dict[str, float],
                 z: float = 1.0) -> tuple:
        """Residual-based prediction interval ``(lo, hi)`` in seconds:
        the point prediction times ``exp(+-z * rmse)``."""
        p = self.predict(feats)
        half = z * (self.rmse if math.isfinite(self.rmse) else 10.0)
        return (p * math.exp(-half), p * math.exp(half))

    # ------------------------------------------------------- persistence
    def state(self) -> dict:
        """JSON-serializable model state (persisted inside the tuning
        cache file by the autotuner)."""
        if not self.trained:
            return {"schema": self.STATE_SCHEMA, "trained": False}
        return {
            "schema": self.STATE_SCHEMA,
            "trained": True,
            "ridge_lambda": self.ridge_lambda,
            "feature_names": list(self.names),
            "mean": [float(v) for v in self.mean],
            "scale": [float(v) for v in self.scale],
            "coef": [float(v) for v in self.coef],
            "intercept": self.intercept,
            "rmse": self.rmse,
            "n_rows": self.n_rows,
        }

    @classmethod
    def from_state(cls, state: Optional[dict]) -> "CostModel":
        """Rebuild from :meth:`state` output; malformed/foreign state
        degrades to an untrained model (the tuner then refits from the
        cache rows — never a crash)."""
        model = cls()
        if (not isinstance(state, dict)
                or state.get("schema") != cls.STATE_SCHEMA
                or not state.get("trained")):
            return model
        try:
            model.ridge_lambda = float(state["ridge_lambda"])
            model.names = list(state["feature_names"])
            model.mean = np.asarray(state["mean"], dtype=np.float64)
            model.scale = np.asarray(state["scale"], dtype=np.float64)
            model.coef = np.asarray(state["coef"], dtype=np.float64)
            model.intercept = float(state["intercept"])
            model.rmse = float(state["rmse"])
            model.n_rows = int(state["n_rows"])
            if not (len(model.names) == model.mean.size == model.scale.size
                    == model.coef.size):
                raise ValueError("inconsistent state arrays")
        except (KeyError, TypeError, ValueError):
            return cls()
        return model
