"""Shape-adaptive runtime autotuner (paper Fig. 10, beyond the paper).

The paper exposes exactly one tuning parameter — the tile size T — and
Fig. 10 shows L3 throughput is sharply sensitive to it: small tiles
under-saturate device and link (2T^3 flops vs 3T^2 bytes moved), big
tiles starve parallelism (Eq. 2), and the best T depends on the
routine, the problem shape and the device topology.  The repo's
scheduling knobs (``n_streams``, ``policy``) interact with T the same
way.  Instead of one fixed default, the :class:`Autotuner` closes the
loop at runtime:

1. bucket the problem shape (next power of two per dim) so one search
   covers a neighbourhood of shapes;
2. sweep candidate ``(tile, n_streams, policy)`` configurations through
   **metadata-only shadow runs** (``execute=False``) on the
   discrete-event engine (``time_model="events"``) — full
   scheduling/cache/link behaviour, zero numerics, so a sweep costs
   milliseconds even at paper scale;
3. pick the candidate with the best virtual-clock makespan (ties break
   toward the earlier candidate; the default config is always candidate
   zero, so the tuned pick can never be worse than the default under
   the same cost model);
4. persist the winner in the :class:`~repro.tuning.cache.TuningCache`
   keyed by ``topology fingerprint / backend / routine / shape bucket /
   dtype`` — later contexts (and processes, with a file-backed cache)
   start warm and never re-sweep.

Everything is virtual-clock deterministic: the same topology and shape
always produce the same pick, on any host.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
from typing import Dict, List, Optional, Sequence, Tuple

from ..core import task as taskmod
from ..core.dtypes import canonical_dtype
from ..core.runtime import BlasxRuntime, RuntimeConfig
from ..core.tiling import ShadowMatrix
from .cache import TuningCache, resolve_cache

ROUTINES = ("gemm", "syrk", "syr2k", "symm", "trmm", "trsm")

# candidate tile sizes (paper Fig. 10 sweeps 256..4096; 128 covers the
# small-shape end the paper never ran)
DEFAULT_TILE_CANDIDATES = (128, 256, 512, 1024, 2048)
# stream counts worth trying: the paper's 4, the cublasxt-style 2, and
# a deeper pipe for link-bound shapes
DEFAULT_STREAM_CANDIDATES = (2, 4, 8)
# policies worth trying at runtime: the paper's contribution and the
# static speed-proportional split (which wins when stealing/priority
# overhead buys nothing, e.g. perfectly regular single-routine sweeps)
DEFAULT_POLICY_CANDIDATES = ("blasx", "static")

# shadow-run budget: skip candidate tiles whose taskization would
# schedule more than this many k-steps (a metadata sweep should stay
# in the milliseconds; the default tile is exempt so the baseline
# makespan always exists)
MAX_SHADOW_STEPS = 60_000
MIN_BUCKET = 64


def shape_bucket(m: int, k: int, n: int) -> Tuple[int, int, int]:
    """Round each dimension up to the next power of two (floor 64): one
    sweep serves every shape in the bucket."""
    def up(x: int) -> int:
        return max(MIN_BUCKET, 1 << max(0, math.ceil(math.log2(max(1, x)))))
    return (up(m), up(k), up(n))


def topology_fingerprint(cfg: RuntimeConfig) -> str:
    """Stable hash of the machine-describing config fields (see
    :meth:`RuntimeConfig.topology`)."""
    blob = json.dumps(cfg.topology(), sort_keys=True)
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


def cache_key(fingerprint: str, backend: str, routine: str,
              bucket: Tuple[int, int, int], dtype_name: str) -> str:
    m, k, n = bucket
    return f"{fingerprint}/{backend}/{routine}/{m}x{k}x{n}/{dtype_name}"


@dataclasses.dataclass(frozen=True)
class TunedConfig:
    """The autotuner's answer for one (routine, shape bucket, dtype)."""

    tile: int
    n_streams: int
    policy: str
    makespan: float           # winning virtual-clock makespan (seconds)
    default_makespan: float   # the fixed-default config's makespan
    source: str               # "swept" | "cache"
    key: str = ""

    @property
    def speedup_vs_default(self) -> float:
        return (self.default_makespan / self.makespan
                if self.makespan > 0 else 1.0)


def _shadow_tasks(routine: str, bucket: Tuple[int, int, int], tile: int,
                  dtype) -> Tuple[List, Dict[str, ShadowMatrix], str]:
    """Taskize one routine at bucket scale over shape-only matrices.
    Operand shapes mirror the context-layer calls (side='L', trans='N',
    uplo='U', beta=0 — the tuned knobs dominate the schedule, not the
    variant flags, and one canonical variant keeps sweeps cheap)."""
    m, k, n = bucket
    dt = canonical_dtype(dtype)
    if routine == "gemm":
        mats = {"A": ShadowMatrix("A", m, k, tile, dtype=dt),
                "B": ShadowMatrix("B", k, n, tile, dtype=dt),
                "C": ShadowMatrix("C", m, n, tile, dtype=dt)}
        tasks = taskmod.taskize_gemm(mats["A"].grid, mats["B"].grid,
                                     mats["C"].grid, "N", "N", 1.0, 0.0)
    elif routine == "syrk":
        mats = {"A": ShadowMatrix("A", n, k, tile, dtype=dt),
                "C": ShadowMatrix("C", n, n, tile, dtype=dt)}
        tasks = taskmod.taskize_syrk(mats["A"].grid, mats["C"].grid,
                                     "U", "N", 1.0, 0.0)
    elif routine == "syr2k":
        mats = {"A": ShadowMatrix("A", n, k, tile, dtype=dt),
                "B": ShadowMatrix("B", n, k, tile, dtype=dt),
                "C": ShadowMatrix("C", n, n, tile, dtype=dt)}
        tasks = taskmod.taskize_syr2k(mats["A"].grid, mats["B"].grid,
                                      mats["C"].grid, "U", "N", 1.0, 0.0)
    elif routine == "symm":
        mats = {"A": ShadowMatrix("A", m, m, tile, dtype=dt),
                "B": ShadowMatrix("B", m, n, tile, dtype=dt),
                "C": ShadowMatrix("C", m, n, tile, dtype=dt)}
        tasks = taskmod.taskize_symm(mats["A"].grid, mats["B"].grid,
                                     mats["C"].grid, "U", 1.0, 0.0)
    elif routine == "trmm":
        mats = {"A": ShadowMatrix("A", m, m, tile, dtype=dt),
                "Cin": ShadowMatrix("Cin", m, n, tile, dtype=dt),
                "C": ShadowMatrix("C", m, n, tile, dtype=dt)}
        tasks = taskmod.taskize_trmm(mats["A"].grid, mats["Cin"].grid,
                                     mats["C"].grid, "U", "N", "N", 1.0)
    elif routine == "trsm":
        mats = {"A": ShadowMatrix("A", m, m, tile, dtype=dt),
                "B": ShadowMatrix("B", m, n, tile, dtype=dt),
                "C": ShadowMatrix("C", m, n, tile, dtype=dt)}
        tasks = taskmod.taskize_trsm(mats["A"].grid, mats["B"].grid,
                                     mats["C"].grid, "U", "N", "N", 1.0)
    else:
        raise ValueError(f"unknown routine {routine!r} "
                         f"(expected one of {ROUTINES})")
    return tasks, mats, "C"


class Autotuner:
    """Per-topology configuration search over metadata shadow runs.

    Parameters
    ----------
    cfg:
        The base :class:`RuntimeConfig` — its topology fields define
        the fingerprint; its ``(n_streams, policy)`` plus
        ``default_tile`` form candidate zero (the fixed default every
        sweep is measured against).
    cache:
        ``None`` (process-shared), a path, or a
        :class:`~repro.tuning.cache.TuningCache`.
    tiles / streams / policies:
        Candidate overrides (benchmark lanes restrict these to bound
        sweep cost).
    default_tile:
        The stack-wide fixed default (``repro.api.context.DEFAULT_TILE``
        unless told otherwise).
    """

    def __init__(self, cfg: RuntimeConfig, cache=None, *,
                 tiles: Sequence[int] = DEFAULT_TILE_CANDIDATES,
                 streams: Sequence[int] = DEFAULT_STREAM_CANDIDATES,
                 policies: Sequence[str] = DEFAULT_POLICY_CANDIDATES,
                 default_tile: int = 256):
        self.cfg = cfg
        self.cache: TuningCache = resolve_cache(cache)
        self.fingerprint = topology_fingerprint(cfg)
        self.tiles = tuple(tiles)
        self.streams = tuple(streams)
        self.policies = tuple(policies)
        self.default_tile = int(default_tile)
        self.sweeps = 0          # shadow runs performed by THIS tuner
        self.cache_hits = 0
        self._events: List[dict] = []   # tuning_report raw material

    # ------------------------------------------------------------ search
    def tune(self, routine: str, m: int, k: Optional[int] = None,
             n: Optional[int] = None, dtype="float64") -> TunedConfig:
        """Return the tuned config for one problem (cache hit or sweep)."""
        k = m if k is None else k
        n = m if n is None else n
        bucket = shape_bucket(m, k, n)
        dt_name = canonical_dtype(dtype).name
        key = cache_key(self.fingerprint, self.cfg.backend, routine,
                        bucket, dt_name)
        entry = self.cache.get(key)
        if entry is not None and entry.get("space") != self._space():
            # the entry was swept against a DIFFERENT default config or
            # candidate space (e.g. a bench lane's restricted tiles):
            # its default_makespan is not this tuner's default and its
            # argmin never saw this tuner's candidates, so the
            # tuned<=default guarantee would silently stop holding.
            # Treat as a miss and re-sweep (the fresh entry overwrites).
            entry = None
        if entry is not None:
            self.cache_hits += 1
            best = TunedConfig(tile=entry["tile"],
                               n_streams=entry["n_streams"],
                               policy=entry["policy"],
                               makespan=entry["makespan"],
                               default_makespan=entry["default_makespan"],
                               source="cache", key=key)
            self._events.append({"key": key, "source": "cache",
                                 "swept": 0, **entry})
            return best
        candidates = self._candidates(routine, bucket)
        results = []
        for tile, ns, policy in candidates:
            span = self._shadow_makespan(routine, bucket, tile, dt_name,
                                         ns, policy)
            self.sweeps += 1
            results.append({"tile": tile, "n_streams": ns,
                            "policy": policy, "makespan": span})
        # candidate zero IS the fixed default: the argmin can therefore
        # never be worse than it (the acceptance invariant)
        default_span = results[0]["makespan"]
        best_row = min(results, key=lambda r: r["makespan"])
        entry = {
            "routine": routine, "bucket": list(bucket), "dtype": dt_name,
            "tile": best_row["tile"], "n_streams": best_row["n_streams"],
            "policy": best_row["policy"],
            "makespan": best_row["makespan"],
            "default_makespan": default_span,
            "candidates": results,
            "space": self._space(),
        }
        self.cache.put(key, entry)
        self._events.append({"key": key, "source": "swept",
                             "swept": len(results), **entry})
        return TunedConfig(tile=best_row["tile"],
                           n_streams=best_row["n_streams"],
                           policy=best_row["policy"],
                           makespan=best_row["makespan"],
                           default_makespan=default_span,
                           source="swept", key=key)

    def _space(self) -> dict:
        """What a cached entry's verdict depends on besides the key:
        the default config it was measured against and the candidate
        space its argmin saw.  Hits require an exact match — a tuner
        with a different default tile / streams / policy or a wider
        candidate set must re-sweep, or 'tuned never worse than
        default' would quietly refer to someone else's default."""
        return {
            "default": [self.default_tile, self.cfg.n_streams,
                        self.cfg.policy],
            "tiles": list(self.tiles),
            "streams": list(self.streams),
            "policies": list(self.policies),
        }

    def _candidates(self, routine: str,
                    bucket: Tuple[int, int, int]) -> List[Tuple[int, int, str]]:
        """Ordered candidate list; the fixed default config comes first
        and is never budget-filtered."""
        m, k, n = bucket
        default = (self.default_tile, self.cfg.n_streams, self.cfg.policy)
        out = [default]
        for tile in self.tiles:
            if tile > max(m, k, n):
                continue            # degenerate: one tile holds everything
            if self._step_estimate(routine, bucket, tile) > MAX_SHADOW_STEPS:
                continue            # sweep budget: skip pathological grids
            for ns in self.streams:
                for policy in self.policies:
                    cand = (tile, ns, policy)
                    if cand != default and cand not in out:
                        out.append(cand)
        return out

    @staticmethod
    def _step_estimate(routine: str, bucket: Tuple[int, int, int],
                       tile: int) -> int:
        m, k, n = bucket
        rows = math.ceil(m / tile)
        cols = math.ceil(n / tile)
        depth = math.ceil(k / tile)
        if routine in ("syrk", "syr2k"):
            rows = cols = math.ceil(n / tile)
            return rows * (rows + 1) // 2 * depth * (2 if routine == "syr2k"
                                                     else 1)
        if routine in ("symm", "trmm", "trsm"):
            depth = math.ceil(m / tile)
        return rows * cols * depth

    def _shadow_makespan(self, routine: str, bucket: Tuple[int, int, int],
                         tile: int, dtype: str, n_streams: int,
                         policy: str) -> float:
        """One metadata-only run of (routine, bucket) under a candidate
        config; returns the virtual-clock makespan."""
        cfg = dataclasses.replace(
            self.cfg, mode="sim", time_model="events", execute=False,
            record_trace=False, n_streams=n_streams, rs_slots=None,
            policy=policy)
        tasks, mats, out_id = _shadow_tasks(routine, bucket, tile, dtype)
        rt = BlasxRuntime(cfg)
        rt.run(tasks, mats, out_id)
        return rt.makespan()

    # ------------------------------------------------------------- report
    def report(self) -> dict:
        """Introspection surface behind ``ctx.tuning_report()``."""
        return {
            "fingerprint": self.fingerprint,
            "backend": self.cfg.backend,
            "cache_path": self.cache.path,
            "cache_entries": len(self.cache),
            "sweeps": self.sweeps,
            "cache_hits": self.cache_hits,
            "tile_candidates": list(self.tiles),
            "stream_candidates": list(self.streams),
            "policy_candidates": list(self.policies),
            "entries": [dict(e) for e in self._events],
        }
